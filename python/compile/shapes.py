"""AOT shape manifest: which (op, shape) artifacts `aot.py` compiles.

The Rust runtime's shape-bucket router pads working sets up to the next
compiled width, so the bucket list below is the contract between the two
sides. `CELER_AOT_PROFILE=full` adds the leukemia-sim sized buckets used
by `examples/xla_engine_demo.rs --full` (slower to compile).
"""

import os

# (n, w, f): f CD epochs on an (n, w) working-set block.
INNER_SOLVE_SMALL = [(48, 64, 10), (48, 128, 10), (48, 256, 10), (48, 512, 10)]
INNER_SOLVE_FULL = [(72, 128, 10), (72, 256, 10), (72, 512, 10), (72, 1024, 10)]

# (n, p): full-design ops (scores / dual rescale / ISTA), p padded to the
# scores kernel tile (256).
FULL_DESIGN_SMALL = [(48, 512)]
FULL_DESIGN_FULL = [(72, 7168)]

# (k+1, n): extrapolation buffers (K = 5).
EXTRAPOLATE_SMALL = [(6, 48)]
EXTRAPOLATE_FULL = [(6, 72)]


def profile():
    return os.environ.get("CELER_AOT_PROFILE", "small")


def manifest_shapes():
    full = profile() == "full"
    inner = INNER_SOLVE_SMALL + (INNER_SOLVE_FULL if full else [])
    design = FULL_DESIGN_SMALL + (FULL_DESIGN_FULL if full else [])
    extrap = EXTRAPOLATE_SMALL + (EXTRAPOLATE_FULL if full else [])
    return {"inner_solve": inner, "full_design": design, "extrapolate": extrap}
