"""Pallas kernel: `f` cyclic coordinate-descent epochs on a dense
working-set block (Layer 1; the inner-solver hot spot of Algorithm 1).

TPU mapping (DESIGN.md §Hardware-Adaptation): the whole (n, w) block is a
single BlockSpec block resident in VMEM across all `f` epochs — the
HBM→VMEM transfer is amortized over `f · w` column updates. The column
loop is inherently sequential (each update feeds the next through the
shared residual), so it targets the VPU (dot + axpy), not the MXU; the
MXU work of the pipeline lives in `scores.py` / `extrapolation.py`.

Zero-padded columns (the shape-bucket router in `rust/src/runtime/` pads
working sets up to the compiled width) have zero norm and are skipped
arithmetically: their gradient and soft-threshold are identically zero.

Kernels are lowered with ``interpret=True``: the CPU PJRT runtime cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
any backend (including the Rust `xla` crate client) runs bit-for-bit.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _cd_epoch_kernel(x_ref, beta_ref, r_ref, lam_ref, beta_out, r_out, *, num_epochs):
    """One grid program: `num_epochs` full cyclic epochs over the block."""
    x = x_ref[...]  # (n, w) resident for the whole call
    lam = lam_ref[0]
    w = x.shape[1]
    norms_sq = jnp.sum(x * x, axis=0)  # (w,)
    safe_nrm = jnp.where(norms_sq > 0.0, norms_sq, 1.0)

    def col_update(j, carry):
        beta, r = carry
        xj = lax.dynamic_slice_in_dim(x, j, 1, axis=1)[:, 0]  # (n,)
        nrm = safe_nrm[j]
        g = jnp.dot(xj, r)
        old = beta[j]
        tentative = old + g / nrm
        new = jnp.sign(tentative) * jnp.maximum(0.0, jnp.abs(tentative) - lam / nrm)
        new = jnp.where(norms_sq[j] > 0.0, new, old)  # padded column: frozen
        r = r + (old - new) * xj
        beta = beta.at[j].set(new)
        return beta, r

    def epoch(_, carry):
        return lax.fori_loop(0, w, col_update, carry)

    beta, r = lax.fori_loop(0, num_epochs, epoch, (beta_ref[...], r_ref[...]))
    beta_out[...] = beta
    r_out[...] = r


@functools.partial(jax.jit, static_argnames=("num_epochs",))
def cd_epochs(x, beta, r, lam, num_epochs=10):
    """Run `num_epochs` cyclic CD epochs; returns (beta, r).

    Args:
      x:    (n, w) dense working-set block.
      beta: (w,) current coefficients for the block.
      r:    (n,) residual ``y − X_W β`` (full-problem residual restricted
            to this subproblem's fit).
      lam:  scalar λ (shape (1,) array).
    """
    n, w = x.shape
    lam = jnp.asarray(lam).reshape((1,))
    kernel = functools.partial(_cd_epoch_kernel, num_epochs=num_epochs)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((w,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ),
        interpret=True,
    )(x, beta, r, lam)
