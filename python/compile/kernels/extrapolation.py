"""Pallas kernel: Gram matrix of residual differences (Layer 1).

`U = diffs(Rbuf)` is a tall-skinny (n, K) matrix (K = 5 by default), so
`UᵀU` is one MXU pass per n-tile accumulated in f32 on a real TPU; here a
single block suffices for the AOT shapes we ship. The K×K solve that
follows is done at Layer 2 (`model.gauss_solve`) — it is O(K³) scalar
work, far too small for a kernel.

interpret=True for CPU-PJRT executability (see cd_epoch.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_diffs_kernel(rbuf_ref, g_out):
    rbuf = rbuf_ref[...]  # (K+1, n)
    u = rbuf[1:, :] - rbuf[:-1, :]  # (K, n)
    g_out[...] = jnp.dot(u, u.T)  # (K, K) — the MXU pass


@jax.jit
def gram_diffs(rbuf):
    """UᵀU from the (K+1, n) residual ring buffer."""
    kp1, _n = rbuf.shape
    k = kp1 - 1
    return pl.pallas_call(
        _gram_diffs_kernel,
        out_shape=jax.ShapeDtypeStruct((k, k), rbuf.dtype),
        interpret=True,
    )(rbuf)
