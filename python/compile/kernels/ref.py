"""Pure-numpy oracles for the Pallas kernels.

Each function here is the *specification* of a kernel in this package:
deliberately simple, loop-based, and independent of JAX tracing, so the
pytest/hypothesis suites can compare kernel outputs against an
implementation that can be audited line by line.
"""

import numpy as np


def soft_threshold(x, u):
    """ST(x, u) = sign(x) * max(0, |x| - u), elementwise."""
    return np.sign(x) * np.maximum(0.0, np.abs(x) - u)


def ref_cd_epochs(x, beta, r, lam, num_epochs=1):
    """`num_epochs` cyclic CD epochs on the dense (n, w) block `x`.

    `r` must equal ``y - x @ beta`` on entry; both are updated in copy.
    Zero-padded columns (norm 0) are left untouched.
    """
    x = np.asarray(x, dtype=np.float64)
    beta = np.array(beta, dtype=np.float64, copy=True)
    r = np.array(r, dtype=np.float64, copy=True)
    w = x.shape[1]
    norms_sq = (x * x).sum(axis=0)
    for _ in range(num_epochs):
        for j in range(w):
            nrm = norms_sq[j]
            if nrm == 0.0:
                continue
            g = x[:, j] @ r
            old = beta[j]
            new = soft_threshold(old + g / nrm, lam / nrm)
            if new != old:
                r += (old - new) * x[:, j]
                beta[j] = new
    return beta, r


def ref_scores(x, theta, col_norms):
    """Gap-Safe scores d_j(θ) = (1 - |x_jᵀθ|)/‖x_j‖ (Eq. 10).

    Columns with zero norm get a large finite sentinel (they can never
    enter a working set).
    """
    x = np.asarray(x, dtype=np.float64)
    xtheta = x.T @ np.asarray(theta, dtype=np.float64)
    safe = np.where(col_norms > 0.0, col_norms, 1.0)
    d = (1.0 - np.abs(xtheta)) / safe
    return np.where(col_norms > 0.0, d, np.finfo(np.float64).max)


def ref_gram_diffs(rbuf):
    """UᵀU from the (K+1, n) residual buffer, U = consecutive diffs."""
    rbuf = np.asarray(rbuf, dtype=np.float64)
    u = rbuf[1:] - rbuf[:-1]  # (K, n)
    return u @ u.T


def ref_extrapolate(rbuf):
    """Full dual extrapolation (Definition 1).

    Returns (r_accel, min_pivot): min_pivot ≤ 0 signals a singular system
    (caller falls back to θ_res, paper §5).
    """
    rbuf = np.asarray(rbuf, dtype=np.float64)
    k = rbuf.shape[0] - 1
    g = ref_gram_diffs(rbuf)
    # unpivoted Gaussian elimination (G is PSD), tracking the min pivot
    a = g.copy()
    b = np.ones(k)
    min_pivot = np.inf
    for col in range(k):
        piv = a[col, col]
        min_pivot = min(min_pivot, piv)
        if piv <= 0.0 or not np.isfinite(piv):
            return rbuf[-1].copy(), 0.0
        for row in range(col + 1, k):
            f = a[row, col] / piv
            a[row, col:] -= f * a[col, col:]
            b[row] -= f * b[col]
    z = np.zeros(k)
    for row in range(k - 1, -1, -1):
        z[row] = (b[row] - a[row, row + 1 :] @ z[row + 1 :]) / a[row, row]
    s = z.sum()
    if abs(s) < 1e-300:
        return rbuf[-1].copy(), 0.0
    c = z / s
    # c_i applies to the NEWER residual of diff i: rbuf[i+1]
    r_accel = (c[:, None] * rbuf[1:]).sum(axis=0)
    return r_accel, float(min_pivot)


def ref_ista_epoch(x, y, beta, lam, mu):
    """One ISTA step: β⁺ = ST(β + Xᵀ(y − Xβ)/μ, λ/μ)."""
    x = np.asarray(x, dtype=np.float64)
    r = np.asarray(y, dtype=np.float64) - x @ beta
    return soft_threshold(beta + (x.T @ r) / mu, lam / mu)


def ref_primal_dual_gap(x, y, beta, theta, lam):
    """(P(β), D(θ), gap)."""
    x = np.asarray(x, dtype=np.float64)
    r = y - x @ beta
    p = 0.5 * (r @ r) + lam * np.abs(beta).sum()
    diff = theta - y / lam
    d = 0.5 * (y @ y) - 0.5 * lam * lam * (diff @ diff)
    return p, d, p - d
