"""Pallas kernel: tiled Gap-Safe scores `d_j(θ)` (Layer 1, Eq. 10).

This is the MXU-shaped piece of the pipeline: `Xᵀθ` is a (p, n) × (n,)
matvec. The grid tiles the feature dimension so only an (n, TILE_P) slab
of the design matrix is resident in VMEM per program; on a real TPU each
tile is one MXU pass (bf16-able) accumulated in f32. Padded tail columns
(zero norm) receive a large finite sentinel so they sort to the end of
any working-set selection.

interpret=True for CPU-PJRT executability (see cd_epoch.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Finite sentinel for unusable (empty / padded) columns. Large enough to
# lose every working-set selection, small enough to stay exactly
# representable and comparable.
EMPTY_COL_SCORE = 1e300

DEFAULT_TILE = 256


def _scores_kernel(x_ref, theta_ref, d_out):
    x = x_ref[...]  # (n, tile)
    theta = theta_ref[...]  # (n,)
    xtheta = jnp.dot(x.T, theta)  # (tile,) — the MXU pass
    norms = jnp.sqrt(jnp.sum(x * x, axis=0))
    safe = jnp.where(norms > 0.0, norms, 1.0)
    d = (1.0 - jnp.abs(xtheta)) / safe
    d_out[...] = jnp.where(norms > 0.0, d, EMPTY_COL_SCORE)


@functools.partial(jax.jit, static_argnames=("tile",))
def gap_safe_scores(x, theta, tile=DEFAULT_TILE):
    """d_j(θ) for every column of `x`; p must be a multiple of `tile`
    (the AOT shape buckets guarantee this; pad with zero columns).
    """
    n, p = x.shape
    if p % tile != 0:
        raise ValueError(f"p={p} must be a multiple of tile={tile}")
    grid = (p // tile,)
    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, tile), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), x.dtype),
        interpret=True,
    )(x, theta)
