"""AOT lowering: JAX/Pallas (Layers 1–2) → HLO text artifacts for the
Rust runtime (Layer 3).

HLO **text** is the interchange format, NOT serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. Functions are lowered with ``return_tuple=True`` and
unwrapped with ``to_tuple*`` on the Rust side.

Python runs ONLY here (``make artifacts``); the Rust binary is
self-contained once ``artifacts/`` exists.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model, shapes  # noqa: E402

DTYPE = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def lower_inner_solve(n, w, f):
    fn = lambda x, y, beta, lam: model.inner_solve_block(  # noqa: E731
        x, y, beta, lam, num_epochs=f
    )
    return jax.jit(fn).lower(spec(n, w), spec(n), spec(w), spec())


def lower_gap_scores(n, p):
    return jax.jit(model.gap_scores).lower(
        spec(n, p), spec(n), spec(p), spec(n), spec()
    )


def lower_theta_res(n, p):
    return jax.jit(model.theta_from_residual).lower(spec(n, p), spec(n), spec())


def lower_extrapolate(kp1, n):
    return jax.jit(model.extrapolate).lower(spec(kp1, n))


def lower_ista_epoch(n, p):
    return jax.jit(model.ista_epoch).lower(
        spec(n, p), spec(n), spec(p), spec(), spec()
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []

    def emit(name, lowered, op, **params):
        path = os.path.join(args.out, name)
        text = to_hlo_text(lowered)
        with open(path, "w") as fh:
            fh.write(text)
        entries.append({"op": op, "file": name, **params})
        print(f"wrote {path} ({len(text)} chars)")

    sh = shapes.manifest_shapes()
    for n, w, f in sh["inner_solve"]:
        emit(
            f"inner_solve_n{n}_w{w}_f{f}.hlo.txt",
            lower_inner_solve(n, w, f),
            "inner_solve",
            n=n,
            w=w,
            f=f,
        )
    for n, p in sh["full_design"]:
        emit(
            f"gap_scores_n{n}_p{p}.hlo.txt",
            lower_gap_scores(n, p),
            "gap_scores",
            n=n,
            p=p,
        )
        emit(
            f"theta_res_n{n}_p{p}.hlo.txt",
            lower_theta_res(n, p),
            "theta_res",
            n=n,
            p=p,
        )
        emit(
            f"ista_epoch_n{n}_p{p}.hlo.txt",
            lower_ista_epoch(n, p),
            "ista_epoch",
            n=n,
            p=p,
        )
    for kp1, n in sh["extrapolate"]:
        emit(
            f"extrapolate_k{kp1 - 1}_n{n}.hlo.txt",
            lower_extrapolate(kp1, n),
            "extrapolate",
            k=kp1 - 1,
            n=n,
        )

    manifest = {"version": 1, "dtype": "f64", "profile": shapes.profile(), "artifacts": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"manifest: {len(entries)} artifacts ({shapes.profile()} profile)")


if __name__ == "__main__":
    main()
