"""Layer 2: JAX compute graphs for the CELER inner solver.

These functions compose the Layer-1 Pallas kernels into the units the
Rust coordinator executes through AOT-compiled HLO artifacts:

- ``inner_solve_block`` — `f` CD epochs on a working-set block,
- ``gap_scores``        — primal/dual/gap + Gap-Safe d_j scores,
- ``extrapolate``       — Definition-1 dual extrapolation,
- ``ista_epoch``        — the Theorem-1 ISTA step.

Everything lowers to *pure HLO*: in particular the K×K solve is an
explicit Gaussian elimination (``gauss_solve``) because
``jnp.linalg.solve`` emits LAPACK custom-calls registered by jaxlib's
Python runtime, which do not exist in the standalone xla_extension
runtime the Rust side links against.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels.cd_epoch import cd_epochs
from compile.kernels.extrapolation import gram_diffs
from compile.kernels.scores import gap_safe_scores


def gauss_solve(a, b):
    """Solve the small PSD system ``a z = b`` by unpivoted Gaussian
    elimination, returning ``(z, min_pivot)``.

    ``min_pivot`` ≤ ~0 flags a (numerically) singular system; the Rust
    coordinator then falls back to θ_res for the round (paper §5). For a
    PSD Gram matrix unpivoted elimination is numerically adequate — and
    crucially it lowers to plain HLO ops.
    """
    k = a.shape[0]

    def elim(col, carry):
        a, b, min_piv = carry
        piv = a[col, col]
        min_piv = jnp.minimum(min_piv, piv)
        safe = jnp.where(jnp.abs(piv) > 0.0, piv, 1.0)
        factors = jnp.where(jnp.arange(k) > col, a[:, col] / safe, 0.0)
        a = a - factors[:, None] * a[col, None, :]
        b = b - factors * b[col]
        return a, b, min_piv

    a, b, min_piv = lax.fori_loop(
        0, k, elim, (a, b, jnp.asarray(jnp.inf, dtype=a.dtype))
    )

    def back(i, z):
        row = k - 1 - i
        acc = b[row] - jnp.dot(a[row], z)
        piv = a[row, row]
        safe = jnp.where(jnp.abs(piv) > 0.0, piv, 1.0)
        return z.at[row].set(acc / safe)

    z = lax.fori_loop(0, k, back, jnp.zeros(k, dtype=a.dtype))
    return z, min_piv


@functools.partial(jax.jit, static_argnames=("num_epochs",))
def inner_solve_block(x, y, beta, lam, num_epochs=10):
    """`num_epochs` cyclic CD epochs on the (n, w) block.

    Returns (beta, r) with r = y − xβ maintained inside the kernel.
    """
    r = y - x @ beta
    lam = jnp.asarray(lam).reshape((1,))
    return cd_epochs(x, beta, r, lam, num_epochs=num_epochs)


@jax.jit
def gap_scores(x, y, beta, theta, lam):
    """Primal, dual, duality gap and Gap-Safe scores in one pass.

    Returns (primal, dual, gap, d) where d[j] = (1−|x_jᵀθ|)/‖x_j‖.
    """
    r = y - x @ beta
    primal = 0.5 * jnp.dot(r, r) + lam * jnp.sum(jnp.abs(beta))
    diff = theta - y / lam
    dual = 0.5 * jnp.dot(y, y) - 0.5 * lam * lam * jnp.dot(diff, diff)
    d = gap_safe_scores(x, theta, tile=min(x.shape[1], 256))
    return primal, dual, primal - dual, d


@jax.jit
def extrapolate(rbuf):
    """Definition-1 dual extrapolation from the (K+1, n) residual buffer.

    Returns (r_accel, min_pivot): the caller must discard r_accel when
    min_pivot ≤ tol (singular system → θ_res fallback, paper §5).
    """
    g = gram_diffs(rbuf)  # (K, K) via the Pallas kernel
    k = g.shape[0]
    z, min_piv = gauss_solve(g, jnp.ones(k, dtype=rbuf.dtype))
    s = jnp.sum(z)
    safe_s = jnp.where(jnp.abs(s) > 0.0, s, 1.0)
    c = z / safe_s
    # c_i applies to the NEWER residual of diff i: rbuf[i+1]
    r_accel = jnp.tensordot(c, rbuf[1:], axes=1)
    # degenerate normalization also signals fallback
    min_piv = jnp.where(jnp.abs(s) > 1e-300, min_piv, jnp.zeros_like(min_piv))
    return r_accel, min_piv


@jax.jit
def theta_from_residual(x, r, lam):
    """θ_res = r / max(λ, ‖Xᵀr‖_∞) (Eq. 4) plus the correlations Xᵀθ."""
    xtr = x.T @ r
    denom = jnp.maximum(lam, jnp.max(jnp.abs(xtr)))
    return r / denom, xtr / denom


@jax.jit
def ista_epoch(x, y, beta, lam, mu):
    """β⁺ = ST(β + Xᵀ(y − Xβ)/μ, λ/μ) — the Theorem-1 iteration."""
    r = y - x @ beta
    t = beta + (x.T @ r) / mu
    return jnp.sign(t) * jnp.maximum(0.0, jnp.abs(t) - lam / mu)
