"""Layer-1 kernel correctness: Pallas (interpret) vs pure-numpy oracles.

Hypothesis sweeps shapes, seeds and λ; every kernel must match its
``ref.py`` specification to float64 precision.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cd_epoch import cd_epochs
from compile.kernels.extrapolation import gram_diffs
from compile.kernels.scores import gap_safe_scores, EMPTY_COL_SCORE
from compile import model


def make_problem(seed, n, w, pad=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, w))
    x /= np.maximum(np.linalg.norm(x, axis=0), 1e-12)
    if pad:
        x = np.concatenate([x, np.zeros((n, pad))], axis=1)
    y = rng.normal(size=n)
    y /= np.linalg.norm(y)
    return x, y


# ---------------------------------------------------------------- cd_epoch
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    n=st.integers(4, 24),
    w=st.integers(1, 16),
    epochs=st.integers(1, 4),
    lam_ratio=st.floats(0.05, 0.9),
)
def test_cd_epochs_matches_ref(seed, n, w, epochs, lam_ratio):
    x, y = make_problem(seed, n, w)
    lam = lam_ratio * np.max(np.abs(x.T @ y))
    if lam <= 0:
        return
    beta0 = np.zeros(w)
    r0 = y.copy()
    beta_k, r_k = cd_epochs(x, beta0, r0, lam, num_epochs=epochs)
    beta_r, r_r = ref.ref_cd_epochs(x, beta0, r0, lam, num_epochs=epochs)
    np.testing.assert_allclose(beta_k, beta_r, atol=1e-12)
    np.testing.assert_allclose(r_k, r_r, atol=1e-12)


def test_cd_epochs_zero_padded_columns_stay_zero():
    x, y = make_problem(0, 16, 8, pad=8)
    lam = 0.3 * np.max(np.abs(x.T @ y))
    beta, r = cd_epochs(x, np.zeros(16), y.copy(), lam, num_epochs=3)
    assert np.all(beta[8:] == 0.0), "padded columns must stay zero"
    beta_r, r_r = ref.ref_cd_epochs(x, np.zeros(16), y, lam, num_epochs=3)
    np.testing.assert_allclose(beta, beta_r, atol=1e-12)
    np.testing.assert_allclose(r, r_r, atol=1e-12)


def test_cd_epochs_warm_start_consistency():
    x, y = make_problem(1, 20, 10)
    lam = 0.2 * np.max(np.abs(x.T @ y))
    b1, r1 = cd_epochs(x, np.zeros(10), y.copy(), lam, num_epochs=2)
    b2, r2 = cd_epochs(x, b1, r1, lam, num_epochs=2)
    b4, r4 = cd_epochs(x, np.zeros(10), y.copy(), lam, num_epochs=4)
    np.testing.assert_allclose(b2, b4, atol=1e-12)
    np.testing.assert_allclose(r2, r4, atol=1e-12)


def test_cd_epochs_decreases_objective():
    x, y = make_problem(2, 30, 12)
    lam = 0.1 * np.max(np.abs(x.T @ y))
    obj = lambda b, r: 0.5 * r @ r + lam * np.abs(b).sum()  # noqa: E731
    beta, r = np.zeros(12), y.copy()
    prev = obj(beta, r)
    for _ in range(5):
        beta, r = cd_epochs(x, beta, r, lam, num_epochs=1)
        cur = obj(np.asarray(beta), np.asarray(r))
        assert cur <= prev + 1e-12
        prev = cur


# ---------------------------------------------------------------- scores
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    n=st.integers(4, 32),
    tiles=st.integers(1, 4),
    tile=st.sampled_from([4, 8, 16]),
)
def test_scores_match_ref(seed, n, tiles, tile):
    p = tiles * tile
    x, _ = make_problem(seed, n, p)
    rng = np.random.default_rng(seed + 1)
    theta = rng.normal(size=n) * 0.1
    d_k = gap_safe_scores(x, theta, tile=tile)
    d_r = ref.ref_scores(x, theta, np.linalg.norm(x, axis=0))
    np.testing.assert_allclose(d_k, d_r, atol=1e-12)


def test_scores_empty_columns_get_sentinel():
    x, _ = make_problem(3, 10, 4, pad=4)
    theta = np.zeros(10)
    d = gap_safe_scores(x, theta, tile=4)
    assert np.all(np.asarray(d[4:]) == EMPTY_COL_SCORE)


def test_scores_rejects_bad_tile():
    x, _ = make_problem(4, 8, 6)
    with pytest.raises(ValueError):
        gap_safe_scores(x, np.zeros(8), tile=4)


# ------------------------------------------------------------ extrapolation
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), n=st.integers(3, 20), k=st.integers(2, 6))
def test_gram_diffs_matches_ref(seed, n, k):
    rng = np.random.default_rng(seed)
    rbuf = rng.normal(size=(k + 1, n))
    g_k = gram_diffs(rbuf)
    g_r = ref.ref_gram_diffs(rbuf)
    np.testing.assert_allclose(g_k, g_r, atol=1e-10)


def test_extrapolate_accelerates_var():
    # Theorem-1 mechanism: on a VAR sequence the extrapolated point is far
    # closer to the fixed point than the newest iterate. With K = dim the
    # Gram matrix stays nonsingular (K = dim+1 would be exact but
    # degenerate — that regime is covered by the Rust constrained solver).
    rng = np.random.default_rng(5)
    dim = 3
    q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
    a = q @ np.diag([0.9, 0.7, 0.4]) @ q.T  # slow modes: acceleration visible
    b = rng.normal(size=dim)
    xstar = np.linalg.solve(np.eye(dim) - a, b)
    k = dim
    xs = [np.zeros(dim)]
    for _ in range(4 + k + 1):  # short warmup, far from convergence
        xs.append(a @ xs[-1] + b)
    rbuf = np.stack(xs[-(k + 1):])
    r_acc, min_piv = model.extrapolate(rbuf)
    assert float(min_piv) > 0
    err_acc = np.linalg.norm(np.asarray(r_acc) - xstar)
    err_last = np.linalg.norm(rbuf[-1] - xstar)
    assert err_acc < 0.05 * err_last, (err_acc, err_last)
    # kernel+L2 pipeline agrees with the numpy oracle exactly
    r_ref, piv_ref = ref.ref_extrapolate(rbuf)
    np.testing.assert_allclose(r_acc, r_ref, atol=1e-12)
    assert (float(min_piv) > 0) == (piv_ref > 0)


def test_extrapolate_singular_flags_fallback():
    # constant buffer → all diffs zero → min_pivot = 0 → caller falls back
    rbuf = np.ones((4, 6))
    _, min_piv = model.extrapolate(rbuf)
    assert float(min_piv) <= 1e-12
