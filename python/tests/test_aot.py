"""AOT pipeline: lowering must produce HLO text the standalone runtime
can ingest (no LAPACK/Mosaic custom-calls), and the lowered graphs must
execute (via jax) to the same numbers as the eager functions."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from compile import aot, model


def test_hlo_text_is_pure(tmp_path):
    lowered = aot.lower_inner_solve(8, 4, 2)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "custom-call" not in text, "LAPACK/Mosaic custom-calls break the Rust runtime"


def test_all_ops_lower_without_custom_calls():
    for lowered in [
        aot.lower_gap_scores(8, 16),
        aot.lower_theta_res(8, 16),
        aot.lower_extrapolate(4, 8),
        aot.lower_ista_epoch(8, 16),
    ]:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "custom-call" not in text


def test_lowered_inner_solve_matches_eager():
    rng = np.random.default_rng(0)
    n, w, f = 8, 4, 3
    x = rng.normal(size=(n, w))
    y = rng.normal(size=n)
    beta = np.zeros(w)
    lam = 0.2 * np.max(np.abs(x.T @ y))
    lowered = aot.lower_inner_solve(n, w, f)
    compiled = lowered.compile()
    got_beta, got_r = compiled(x, y, beta, lam)
    want_beta, want_r = model.inner_solve_block(x, y, beta, lam, num_epochs=f)
    np.testing.assert_allclose(got_beta, want_beta, atol=1e-12)
    np.testing.assert_allclose(got_r, want_r, atol=1e-12)


def test_manifest_written(tmp_path):
    import json
    import subprocess
    import sys

    out = tmp_path / "arts"
    env = dict(**__import__("os").environ)
    env["CELER_AOT_PROFILE"] = "small"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=__file__.rsplit("/", 2)[0],
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert manifest["dtype"] == "f64"
    ops = {e["op"] for e in manifest["artifacts"]}
    assert {"inner_solve", "gap_scores", "theta_res", "extrapolate", "ista_epoch"} <= ops
    for e in manifest["artifacts"]:
        text = (out / e["file"]).read_text()
        assert text.startswith("HloModule")
