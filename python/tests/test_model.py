"""Layer-2 graph correctness: model.py vs numpy references."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_problem(seed, n, p):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, p))
    x /= np.maximum(np.linalg.norm(x, axis=0), 1e-12)
    y = rng.normal(size=n)
    y /= np.linalg.norm(y)
    return x, y


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), k=st.integers(1, 8))
def test_gauss_solve_matches_numpy(seed, k):
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(k, k + 2))
    g = u @ u.T + 1e-6 * np.eye(k)  # PSD, well-conditioned
    z, min_piv = model.gauss_solve(g, np.ones(k))
    assert float(min_piv) > 0
    np.testing.assert_allclose(z, np.linalg.solve(g, np.ones(k)), atol=1e-8)


def test_gauss_solve_singular_min_pivot():
    g = np.zeros((3, 3))
    _, min_piv = model.gauss_solve(g, np.ones(3))
    assert float(min_piv) <= 0.0


def test_inner_solve_block_matches_ref():
    x, y = make_problem(0, 24, 16)
    lam = 0.2 * np.max(np.abs(x.T @ y))
    beta0 = np.zeros(16)
    beta, r = model.inner_solve_block(x, y, beta0, lam, num_epochs=10)
    beta_ref, r_ref = ref.ref_cd_epochs(x, beta0, y.copy(), lam, num_epochs=10)
    np.testing.assert_allclose(beta, beta_ref, atol=1e-12)
    np.testing.assert_allclose(r, r_ref, atol=1e-12)
    # residual invariant
    np.testing.assert_allclose(r, y - x @ np.asarray(beta), atol=1e-12)


def test_gap_scores_matches_numpy():
    x, y = make_problem(1, 16, 256)
    rng = np.random.default_rng(2)
    beta = rng.normal(size=256) * (rng.uniform(size=256) < 0.05)
    lam = 0.3 * np.max(np.abs(x.T @ y))
    theta = (y - x @ beta)
    theta = theta / max(lam, np.max(np.abs(x.T @ theta)))
    p, d, gap, scores = model.gap_scores(x, y, beta, theta, lam)
    p_ref, d_ref, gap_ref = ref.ref_primal_dual_gap(x, y, beta, theta, lam)
    np.testing.assert_allclose(float(p), p_ref, atol=1e-12)
    np.testing.assert_allclose(float(d), d_ref, atol=1e-12)
    np.testing.assert_allclose(float(gap), gap_ref, atol=1e-12)
    np.testing.assert_allclose(
        scores, ref.ref_scores(x, theta, np.linalg.norm(x, axis=0)), atol=1e-12
    )
    assert gap_ref >= -1e-12, "feasible dual point -> nonnegative gap"


def test_theta_from_residual_feasible():
    x, y = make_problem(3, 20, 64)
    lam = 0.1 * np.max(np.abs(x.T @ y))
    theta, xtheta = model.theta_from_residual(x, y, lam)
    assert np.max(np.abs(xtheta)) <= 1.0 + 1e-12
    np.testing.assert_allclose(xtheta, x.T @ np.asarray(theta), atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_ista_epoch_matches_ref(seed):
    x, y = make_problem(seed, 12, 20)
    rng = np.random.default_rng(seed + 1)
    beta = rng.normal(size=20) * 0.1
    lam = 0.2 * np.max(np.abs(x.T @ y))
    mu = np.linalg.norm(x, ord=2) ** 2
    out = model.ista_epoch(x, y, beta, lam, mu)
    np.testing.assert_allclose(out, ref.ref_ista_epoch(x, y, beta, lam, mu), atol=1e-12)


def test_ista_converges_to_cd_solution():
    x, y = make_problem(4, 24, 12)
    lam = 0.3 * np.max(np.abs(x.T @ y))
    mu = np.linalg.norm(x, ord=2) ** 2
    beta = np.zeros(12)
    for _ in range(3000):
        beta = np.asarray(model.ista_epoch(x, y, beta, lam, mu))
    beta_cd, _ = ref.ref_cd_epochs(x, np.zeros(12), y.copy(), lam, num_epochs=3000)
    np.testing.assert_allclose(beta, beta_cd, atol=1e-8)
