//! Figures 6 & 7 (Appendix A.1): sensitivity of dual extrapolation to
//! the gap frequency `f` and the depth `K`.
//!
//! Paper findings to reproduce: small f → noisy gaps, large f → slow
//! convergence to the true suboptimality, f = 10 best (Fig. 6); K barely
//! matters (Fig. 7).
//!
//! ```bash
//! cargo run --release --example fig67_param_sweep [-- --mini]
//! ```

use celer::data::synth;
use celer::lasso::{dual, primal};
use celer::report::Table;
use celer::solvers::cd::{cd_solve, CdConfig};

fn gap_accel_at_epochs(
    ds: &synth::SynthDataset,
    lambda: f64,
    f: usize,
    k: usize,
    max_epochs: usize,
    checkpoints: &[usize],
) -> Vec<Option<f64>> {
    let out = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig {
            tol: 1e-14,
            max_epochs,
            gap_freq: f,
            k,
            best_dual: false,
            trace: true,
            ..Default::default()
        },
    );
    checkpoints
        .iter()
        .map(|&cp| {
            out.trace
                .iter()
                .filter(|c| c.epoch <= cp)
                .last()
                .and_then(|c| c.dual_accel.map(|d| (c.primal - d).max(0.0)))
        })
        .collect()
}

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::leukemia_mini(0) } else { synth::leukemia_sim(0) };
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    let max_epochs = if mini { 400 } else { 600 };
    let checkpoints = [100, 200, 400, max_epochs];

    // true suboptimality reference
    let reference = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig { tol: 1e-14, max_epochs: 100_000, ..Default::default() },
    );
    let p_star = primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
    println!("dataset={} λ=λ_max/20, P* = {:.10}", ds.name, p_star);

    // --- Fig 6: sweep f at K = 5 ---
    let mut t6 = Table::new(
        "Fig 6 — gap(θ_accel) vs f (K = 5)",
        &["f", "ep100", "ep200", "ep400", "final"],
    );
    for f in [1usize, 2, 5, 10, 20, 50] {
        let gaps = gap_accel_at_epochs(&ds, lambda, f, 5, max_epochs, &checkpoints);
        let mut row = vec![f.to_string()];
        row.extend(
            gaps.iter()
                .map(|g| g.map(|v| format!("{v:.2e}")).unwrap_or_else(|| "—".into())),
        );
        t6.row(row);
    }
    print!("{}", t6.render());
    t6.save_csv(std::path::Path::new("results/fig6_f_sweep.csv")).ok();

    // --- Fig 7: sweep K at f = 10 ---
    let mut t7 = Table::new(
        "Fig 7 — gap(θ_accel) vs K (f = 10)",
        &["K", "ep100", "ep200", "ep400", "final"],
    );
    for k in [2usize, 3, 4, 5, 7, 10] {
        let gaps = gap_accel_at_epochs(&ds, lambda, 10, k, max_epochs, &checkpoints);
        let mut row = vec![k.to_string()];
        row.extend(
            gaps.iter()
                .map(|g| g.map(|v| format!("{v:.2e}")).unwrap_or_else(|| "—".into())),
        );
        t7.row(row);
    }
    print!("{}", t7.render());
    t7.save_csv(std::path::Path::new("results/fig7_k_sweep.csv")).ok();
    println!("\npaper check: f=10 best trade-off (Fig 6); K nearly irrelevant (Fig 7).");
}
