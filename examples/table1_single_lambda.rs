//! Table 1: single-λ solve times on the Finance-like dataset,
//! λ = λ_max/20, cold start (β⁰ = 0), ε ∈ {1e-2, 1e-3, 1e-4, 1e-6}.
//!
//! Solvers: CELER (prune), BLITZ, scikit-learn-style vanilla CD. The
//! paper reports 5/25/470 s at ε=1e-2 scaling to 10/30/∞ at 1e-6 — the
//! *ordering and widening ratio* are the reproduction target.
//!
//! ```bash
//! cargo run --release --example table1_single_lambda [-- --mini]
//! ```

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::report::{fmt_secs, Table};
use celer::solvers::path::{run_path, PathSolver};
use std::time::Instant;

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::finance_mini(0) } else { synth::finance_sim(0) };
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    println!(
        "dataset={} n={} p={} λ = λ_max/20, cold start",
        ds.name,
        ds.x.n(),
        ds.x.p()
    );

    let tols = [1e-2, 1e-3, 1e-4, 1e-6];
    let solvers = ["celer-prune", "blitz", "cd-vanilla"];
    // vanilla CD gets an epoch budget so the table completes (the paper
    // reports "-" for scikit-learn at 1e-6 for the same reason).
    let mut table = Table::new(
        "Table 1 — time to reach ε (seconds)",
        &["solver", "1e-2", "1e-3", "1e-4", "1e-6"],
    );
    let mut rows: Vec<Vec<String>> = solvers.iter().map(|s| vec![s.to_string()]).collect();
    for &tol in &tols {
        for (si, s) in solvers.iter().enumerate() {
            let mut solver = PathSolver::by_name(s, tol).unwrap();
            if let PathSolver::VanillaCd(cfg) = &mut solver {
                cfg.max_epochs = if mini { 20_000 } else { 5_000 };
            }
            let t0 = Instant::now();
            let res = run_path(&ds.x, &ds.y, &[lambda], &solver, false);
            let secs = t0.elapsed().as_secs_f64();
            let step = &res.steps[0];
            rows[si].push(if step.converged {
                fmt_secs(secs)
            } else {
                format!("— (gap {:.0e})", step.gap)
            });
        }
    }
    for r in rows {
        table.row(r);
    }
    print!("{}", table.render());
    table.save_csv(std::path::Path::new("results/table1_single_lambda.csv")).ok();
    println!("\npaper check: CELER < BLITZ ≪ vanilla CD, gap widening as ε ↓.");
}
