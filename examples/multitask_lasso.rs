//! §7 extension demo: Multi-Task Lasso with dual extrapolation and a
//! CELER-style working-set loop, on a synthetic multi-task regression
//! problem (shared row support across q tasks).
//!
//! ```bash
//! cargo run --release --example multitask_lasso
//! ```

use celer::data::dense::DenseMatrix;
use celer::data::design::DesignMatrix;
use celer::multitask::solver::{
    mt_bcd_solve, mt_celer_solve, mt_lambda_max, mt_primal, MtConfig,
};
use celer::multitask::TaskMatrix;
use celer::report::{fmt_secs, Table};
use celer::util::rng::Rng;
use std::time::Instant;

fn main() {
    let (n, p, q, support) = (100, 5000, 8, 20);
    let mut rng = Rng::new(0);
    // unit-norm Gaussian design
    let mut data = vec![0.0; n * p];
    for v in data.iter_mut() {
        *v = rng.normal();
    }
    for j in 0..p {
        let nrm: f64 = data[j * n..(j + 1) * n].iter().map(|v| v * v).sum::<f64>().sqrt();
        for v in data[j * n..(j + 1) * n].iter_mut() {
            *v /= nrm;
        }
    }
    // row-sparse ground truth: `support` rows active across all q tasks
    let mut b_true = TaskMatrix::zeros(p, q);
    for &j in &rng.sample_indices(p, support) {
        for t in 0..q {
            b_true.row_mut(j)[t] = rng.normal();
        }
    }
    // Y = X B* + noise, row-major n×q (built from the raw columns; the
    // solvers themselves go through the shared multi-RHS lane kernels)
    let mut y = vec![0.0; n * q];
    for j in 0..p {
        let col = &data[j * n..(j + 1) * n];
        let row = b_true.row(j);
        if row.iter().all(|&v| v == 0.0) {
            continue;
        }
        for (i, &xv) in col.iter().enumerate() {
            for t in 0..q {
                y[i * q + t] += row[t] * xv;
            }
        }
    }
    for v in y.iter_mut() {
        *v += 0.1 * rng.normal();
    }
    let x = DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data));

    let lmax = mt_lambda_max(&x, &y, q);
    let lambda = lmax / 10.0;
    let tol = 1e-8;
    println!("Multi-Task Lasso: n={n} p={p} q={q} |row-support*|={support} λ=λ_max/10 ε={tol:.0e}\n");

    let t0 = Instant::now();
    let celer = mt_celer_solve(&x, &y, q, lambda, &MtConfig { tol, ..Default::default() });
    let t_celer = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let bcd = mt_bcd_solve(&x, &y, q, lambda, None, &MtConfig { tol, ..Default::default() });
    let t_bcd = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let plain = mt_bcd_solve(
        &x,
        &y,
        q,
        lambda,
        None,
        &MtConfig { tol, extrapolate: false, ..Default::default() },
    );
    let t_plain = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "Multi-Task Lasso solvers",
        &["solver", "time", "gap", "row support", "epochs", "converged"],
    );
    for (name, out, secs) in [
        ("celer-mt (WS + extrapolation)", &celer, t_celer),
        ("bcd-mt (extrapolation)", &bcd, t_bcd),
        ("bcd-mt (θ_res only)", &plain, t_plain),
    ] {
        t.row(vec![
            name.into(),
            fmt_secs(secs),
            format!("{:.2e}", out.gap),
            out.b.support().len().to_string(),
            out.epochs.to_string(),
            out.converged.to_string(),
        ]);
    }
    print!("{}", t.render());
    let pc = mt_primal(&celer.r, &celer.b, lambda);
    let pb = mt_primal(&bcd.r, &bcd.b, lambda);
    println!("\nobjective agreement |ΔP| = {:.2e}; speedup vs full BCD: {:.1}×", (pc - pb).abs(), t_bcd / t_celer.max(1e-12));
}
