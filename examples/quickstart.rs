//! Quickstart: solve one Lasso instance with CELER and compare against
//! vanilla coordinate descent.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::report::{fmt_sci, fmt_secs, Table};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::celer::{celer_solve_on, CelerConfig};
use celer::solvers::path::{lambda_grid, lasso_path, run_path, PathSolver};
use std::time::Instant;

fn main() {
    // leukemia-like dense dataset (n=72, p=7129), λ = λ_max / 20
    let ds = synth::leukemia_sim(0);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    let tol = 1e-6;
    println!(
        "dataset={} n={} p={} λ=λ_max/20={:.4e} ε={tol:.0e}\n",
        ds.name,
        ds.x.n(),
        ds.x.p(),
        lambda
    );

    let t0 = Instant::now();
    let celer_out =
        celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig { tol, ..Default::default() });
    let celer_time = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let cd_out = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol, ..CdConfig::vanilla() });
    let cd_time = t0.elapsed().as_secs_f64();

    let mut table = Table::new(
        "CELER vs vanilla CD (scikit-learn baseline)",
        &["solver", "time", "gap", "|support|", "epochs", "converged"],
    );
    table.row(vec![
        "celer-prune".into(),
        fmt_secs(celer_time),
        fmt_sci(celer_out.gap()),
        celer_out.support_size().to_string(),
        celer_out.result.epochs.to_string(),
        celer_out.result.converged.to_string(),
    ]);
    table.row(vec![
        "cd-vanilla".into(),
        fmt_secs(cd_time),
        fmt_sci(cd_out.gap),
        cd_out.support_size().to_string(),
        cd_out.epochs.to_string(),
        cd_out.converged.to_string(),
    ]);
    print!("{}", table.render());
    println!("\nspeedup: {:.1}×", cd_time / celer_time.max(1e-12));

    // solutions agree
    let pc = celer::lasso::primal::primal(&ds.x, &ds.y, &celer_out.result.beta, lambda);
    let pv = celer::lasso::primal::primal(&ds.x, &ds.y, &cd_out.beta, lambda);
    println!("objective agreement: |ΔP| = {:.2e}", (pc - pv).abs());

    // --- the headline computation: a warm-started λ path, sequential
    //     grid walk vs the batched multi-λ engine (B lanes per sweep) ---
    let lanes = 8;
    let grid = lambda_grid(dual::lambda_max(&ds.x, &ds.y), 0.05, 20);
    let t0 = Instant::now();
    let seq = run_path(
        &ds.x,
        &ds.y,
        &grid,
        &PathSolver::by_name("gapsafe-cd-accel", tol).unwrap(),
        false,
    );
    let t_seq = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let bat = lasso_path(&ds.x, &ds.y, &grid, tol, lanes, false, &celer::penalty::L1);
    let t_bat = t0.elapsed().as_secs_f64();
    assert!(seq.all_converged() && bat.all_converged());

    let mut table = Table::new(
        &format!("λ path, {} values λ_max → λ_max/20 (ε = {tol:.0e})", grid.len()),
        &["schedule", "time", "Σ epochs", "final |support|"],
    );
    let batched_label = format!("batched B={lanes}");
    for (name, res, secs) in
        [("sequential", &seq, t_seq), (batched_label.as_str(), &bat, t_bat)]
    {
        table.row(vec![
            name.into(),
            fmt_secs(secs),
            res.steps.iter().map(|s| s.epochs).sum::<usize>().to_string(),
            res.steps.last().unwrap().support_size.to_string(),
        ]);
    }
    print!("\n{}", table.render());
    println!("batched-path speedup: {:.2}×", t_seq / t_bat.max(1e-12));
}
