//! Elastic-net λ-path demo on the penalty-generic engine.
//!
//! The `Penalty` trait routes the elastic net (and weighted ℓ₁) through
//! the same CELER working-set core as the plain Lasso: the penalty
//! supplies the prox, the dual rescale denominator, the conjugate term
//! in the dual objective and the Gap-Safe pricing scores — the outer
//! loop is untouched. This example walks a warm-started λ path with the
//! named `"celer-enet"` path solver (α = ½), then compares three mixing
//! ratios α at one λ to show the ridge term shrinking the support.
//!
//! Run with: `cargo run --release --example elastic_net_path [-- --mini]`

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::penalty::ElasticNet;
use celer::report::{fmt_sci, fmt_secs, Table};
use celer::solvers::celer::{celer_penalty_solve_on_ws, CelerConfig};
use celer::solvers::engine::Workspace;
use celer::solvers::path::{lambda_grid, run_path, PathSolver};

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::leukemia_mini(0) } else { synth::leukemia_sim(0) };
    println!("dataset={} n={} p={}", ds.name, ds.x.n(), ds.x.p());

    // --- warm-started path with the named solver (α = ½) ---
    // The grid anchors at the elastic net's own λ_max = ‖Xᵀy‖_∞/α, so
    // the first grid point certifies the empty model.
    let alpha = 0.5;
    let pen = ElasticNet::new(alpha);
    let lmax = dual::penalty_lambda_max(&ds.x, &ds.y, &pen);
    let grid = lambda_grid(lmax, 0.05, if mini { 8 } else { 20 });
    let tol = 1e-8;
    println!(
        "α = {alpha}, λ_max = {} (= ‖Xᵀy‖_∞/α), grid of {} down to λ_max/20, ε = {tol:.0e}",
        fmt_sci(lmax),
        grid.len()
    );

    let solver = PathSolver::by_name("celer-enet", tol).expect("named penalty solver");
    let sw = std::time::Instant::now();
    let res = run_path(&ds.x, &ds.y, &grid, &solver, false);
    let elapsed = sw.elapsed().as_secs_f64();

    let mut table = Table::new(
        "elastic-net path (warm-started, gap-certified)",
        &["λ/λ_max", "gap", "|support|", "inner epochs", "time"],
    );
    for step in &res.steps {
        table.row(vec![
            format!("{:.3}", step.lambda / lmax),
            fmt_sci(step.gap),
            step.support_size.to_string(),
            step.epochs.to_string(),
            fmt_secs(step.seconds),
        ]);
    }
    print!("{}", table.render());
    println!("total {} — every gap ≤ ε: {}", fmt_secs(elapsed), res.all_converged());
    assert!(res.all_converged(), "path must certify every λ");

    // --- one λ, three mixing ratios: more ridge ⇒ denser, smaller β ---
    let mut table = Table::new(
        "mixing-ratio sweep at λ = λ_max(α)/10",
        &["α", "gap", "|support|", "‖β‖₁", "inner epochs"],
    );
    let mut ws = Workspace::new();
    for alpha in [0.9, 0.5, 0.2] {
        let pen = ElasticNet::new(alpha);
        let lambda = dual::penalty_lambda_max(&ds.x, &ds.y, &pen) / 10.0;
        let out = celer_penalty_solve_on_ws(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &pen,
            &CelerConfig { tol, ..Default::default() },
            &mut ws,
        );
        assert!(out.result.converged, "α={alpha}: gap {}", out.result.gap);
        table.row(vec![
            format!("{alpha}"),
            fmt_sci(out.result.gap),
            out.support_size().to_string(),
            format!("{:.4}", celer::lasso::primal::l1_norm(&out.result.beta)),
            out.result.epochs.to_string(),
        ]);
    }
    print!("\n{}", table.render());
}
