//! Figure 3: Gap Safe screening performance with θ_res vs θ_accel.
//!
//! Dynamic Gap Safe CD on the sparse finance-sim dataset at λ = λ_max/5:
//! the number of screened features per epoch grows much faster when the
//! dual point is extrapolated, which translates directly into wall-clock
//! (the paper reports 70 s vs 290 s on the real Finance data).
//!
//! ```bash
//! cargo run --release --example fig3_screening            # finance-sim
//! cargo run --release --example fig3_screening -- --mini  # test-scale
//! ```

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::report::{fmt_secs, Table};
use celer::solvers::cd::{cd_solve, CdConfig};
use std::time::Instant;

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::finance_mini(0) } else { synth::finance_sim(0) };
    // The paper uses λ_max/5 on the real Finance data; the synthetic
    // stand-in is better conditioned at matched λ-ratio, so the same
    // screening difficulty sits at λ_max/20 (see DESIGN.md §4).
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    println!(
        "dataset={} n={} p={} nnz={} λ = λ_max/20, ε = 1e-6",
        ds.name,
        ds.x.n(),
        ds.x.p(),
        ds.x.nnz()
    );

    let base = CdConfig {
        tol: 1e-8,
        max_epochs: 10_000,
        screen: true,
        trace: true,
        best_dual: true,
        ..Default::default()
    };

    let t0 = Instant::now();
    let res_run = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { extrapolate: false, ..base.clone() });
    let time_res = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let acc_run = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { extrapolate: true, ..base });
    let time_acc = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "Fig 3 — features screened by the dynamic Gap Safe rule",
        &["epoch", "screened (θ_res)", "screened (θ_accel)"],
    );
    let rows = res_run.trace.len().max(acc_run.trace.len());
    for i in 0..rows {
        let e = res_run
            .trace
            .get(i)
            .map(|c| c.epoch)
            .or_else(|| acc_run.trace.get(i).map(|c| c.epoch))
            .unwrap();
        t.row(vec![
            e.to_string(),
            res_run
                .trace
                .get(i)
                .map(|c| c.n_screened.to_string())
                .unwrap_or_else(|| "(done)".into()),
            acc_run
                .trace
                .get(i)
                .map(|c| c.n_screened.to_string())
                .unwrap_or_else(|| "(done)".into()),
        ]);
    }
    print!("{}", t.render());
    t.save_csv(std::path::Path::new("results/fig3_screening.csv")).ok();

    println!("\nwall-clock to ε=1e-8:");
    println!("  Gap Safe + θ_res   : {} ({} epochs)", fmt_secs(time_res), res_run.epochs);
    println!("  Gap Safe + θ_accel : {} ({} epochs)", fmt_secs(time_acc), acc_run.epochs);
    println!(
        "  speedup {:.2}× (paper: 290 s → 70 s ≈ 4.1× on the real Finance data)",
        time_res / time_acc.max(1e-12)
    );
}
