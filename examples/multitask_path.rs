//! §7 path demo: a warm-started Multi-Task Lasso λ path on the block
//! engine — B̂(λ_i) seeds λ_{i+1} and one persistent block workspace
//! (B, R, XᵀR blocks, extrapolation ring, the nested working-set
//! workspace) serves the whole grid with no per-λ reallocation.
//!
//! ```bash
//! cargo run --release --example multitask_path
//! ```

use celer::data::dense::DenseMatrix;
use celer::data::design::DesignMatrix;
use celer::multitask::solver::{mt_celer_solve, mt_lambda_max, mt_primal, MtConfig};
use celer::multitask::TaskMatrix;
use celer::report::{fmt_secs, Table};
use celer::solvers::path::{lambda_grid, run_mt_path};
use celer::util::rng::Rng;
use std::time::Instant;

fn main() {
    let (n, p, q, support) = (80, 2000, 6, 15);
    let mut rng = Rng::new(0);
    // unit-norm Gaussian design
    let mut data = vec![0.0; n * p];
    for v in data.iter_mut() {
        *v = rng.normal();
    }
    for j in 0..p {
        let nrm: f64 = data[j * n..(j + 1) * n].iter().map(|v| v * v).sum::<f64>().sqrt();
        for v in data[j * n..(j + 1) * n].iter_mut() {
            *v /= nrm;
        }
    }
    // row-sparse ground truth shared by all q tasks
    let mut b_true = TaskMatrix::zeros(p, q);
    for &j in &rng.sample_indices(p, support) {
        for t in 0..q {
            b_true.row_mut(j)[t] = rng.normal();
        }
    }
    let mut y = vec![0.0; n * q];
    for j in 0..p {
        let col = &data[j * n..(j + 1) * n];
        let row = b_true.row(j);
        if row.iter().all(|&v| v == 0.0) {
            continue;
        }
        for (i, &xv) in col.iter().enumerate() {
            for t in 0..q {
                y[i * q + t] += row[t] * xv;
            }
        }
    }
    for v in y.iter_mut() {
        *v += 0.05 * rng.normal();
    }
    let x = DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data));

    let lmax = mt_lambda_max(&x, &y, q);
    let grid = lambda_grid(lmax, 0.05, 12);
    let tol = 1e-8;
    let cfg = MtConfig { tol, ..Default::default() };
    println!(
        "Multi-Task Lasso path: n={n} p={p} q={q} |row-support*|={support} \
         grid={} λ ∈ [λ_max/20, λ_max] ε={tol:.0e}\n",
        grid.len()
    );

    let t0 = Instant::now();
    let path = run_mt_path(&x, &y, q, &grid, &cfg, false);
    let t_path = t0.elapsed().as_secs_f64();

    let mut table = Table::new(
        "warm-started MT path (one reused block workspace)",
        &["λ/λ_max", "time", "gap", "row support", "inner epochs", "converged"],
    );
    for step in &path.steps {
        table.row(vec![
            format!("{:.3}", step.lambda / lmax),
            fmt_secs(step.seconds),
            format!("{:.2e}", step.gap),
            step.support_size.to_string(),
            step.epochs.to_string(),
            step.converged.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\npath total {} ({} λ's, all converged: {})",
        fmt_secs(path.total_seconds),
        path.steps.len(),
        path.all_converged()
    );

    // cross-check: a cold one-shot solve at the final λ agrees with the
    // warm-started chain's endpoint
    let lam_final = *grid.last().unwrap();
    let t0 = Instant::now();
    let cold = mt_celer_solve(&x, &y, q, lam_final, &cfg);
    let t_cold = t0.elapsed().as_secs_f64();
    let p_cold = mt_primal(&cold.r, &cold.b, lam_final);
    println!(
        "cold solve at λ_min: P = {p_cold:.6e} in {} (warm path amortizes {} grid points in {})",
        fmt_secs(t_cold),
        path.steps.len(),
        fmt_secs(t_path)
    );
}
