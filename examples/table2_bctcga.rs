//! Table 2 (Appendix A.4): Lasso path on the dense bcTCGA-like dataset.
//!
//! CELER (no pruning, i.e. the safe variant) vs BLITZ, path λ_max →
//! λ_max/100, ε ∈ {1e-2, 1e-4, 1e-6, 1e-8}. The paper's footnote about
//! BLITZ stopping on its internal primal-decrease test at the tightest ε
//! is reproduced via `primal_decrease_tol`.
//!
//! ```bash
//! cargo run --release --example table2_bctcga [-- --mini]
//! ```

use celer::coordinator;
use celer::data::design::DesignOps;
use celer::data::synth;
use celer::report::{fmt_secs, Table};
use celer::solvers::path::{run_path, PathSolver};
use celer::solvers::blitz::BlitzConfig;
use celer::solvers::celer::CelerConfig;

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::leukemia_mini(7) } else { synth::bctcga_sim(0) };
    let num = if mini { 10 } else { 100 };
    let grid = coordinator::standard_grid(&ds, 100.0, num);
    println!(
        "dataset={} n={} p={} — dense path, {} λ's",
        ds.name,
        ds.x.n(),
        ds.x.p(),
        num
    );

    let tols = [1e-2, 1e-4, 1e-6, 1e-8];
    let mut table = Table::new(
        "Table 2 — path time to ε (CELER no-prune vs BLITZ)",
        &["ε", "celer (safe)", "blitz", "blitz internal-stop?"],
    );
    for &tol in &tols {
        let celer_solver =
            PathSolver::CelerSafe(CelerConfig { tol, ..CelerConfig::safe() });
        let blitz_solver = PathSolver::Blitz(BlitzConfig {
            tol,
            // the C++ Blitz internal heuristic the paper's footnote mentions
            primal_decrease_tol: if tol <= 1e-8 { 1e-12 } else { 0.0 },
            ..Default::default()
        });
        let rc = run_path(&ds.x, &ds.y, &grid, &celer_solver, false);
        let rb = run_path(&ds.x, &ds.y, &grid, &blitz_solver, false);
        let blitz_early = rb.steps.iter().any(|s| !s.converged);
        table.row(vec![
            format!("{tol:.0e}"),
            fmt_secs(rc.total_seconds),
            fmt_secs(rb.total_seconds),
            if blitz_early { "yes (gap not ≤ ε everywhere)" } else { "no" }.to_string(),
        ]);
    }
    print!("{}", table.render());
    table.save_csv(std::path::Path::new("results/table2_bctcga.csv")).ok();
    println!("\npaper check: CELER < BLITZ at every ε, ratio narrowing at 1e-8 (255 vs 286 s).");
}
