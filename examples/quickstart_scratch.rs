use celer::data::synth;
use celer::lasso::{dual, primal};
use celer::solvers::cd::{cd_solve, CdConfig};

fn main() {
    let ds = synth::leukemia_sim(0);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    let reference = cd_solve(&ds.x, &ds.y, lambda, None,
        &CdConfig { tol: 1e-14, max_epochs: 100_000, ..Default::default() });
    let p_star = primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
    let out = cd_solve(&ds.x, &ds.y, lambda, None,
        &CdConfig { tol: 1e-12, max_epochs: 2000, best_dual: false, trace: true, ..Default::default() });
    for chk in out.trace.iter().step_by(5) {
        println!("ep {:4} subopt {:.2e} gap_res {:.2e} gap_acc {:?}",
            chk.epoch, (chk.primal - p_star).max(0.0), chk.primal - chk.dual_res,
            chk.dual_accel.map(|d| format!("{:.2e}", chk.primal - d)));
    }
    println!("support {} / n {} converged {} epochs {}", out.support_size(), 72, out.converged, out.epochs);
}
