//! Three-layer composition demo: the same Algorithm-1 solve driven by
//! (a) the native Rust engine and (b) the AOT XLA engine executing the
//! Pallas/JAX artifacts through PJRT — byte-identical iterate semantics,
//! no Python on the request path.
//!
//! Requires `make artifacts` (small profile covers leukemia-mini).
//!
//! ```bash
//! cargo run --release --example xla_engine_demo
//! ```

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::report::{fmt_secs, Table};
use celer::runtime::{engine_cd_solve, NativeEngine, XlaEngine};
use std::time::Instant;

fn main() {
    let ds = synth::leukemia_mini(0);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
    let tol = 1e-8;
    let (n, p) = (ds.x.n(), ds.x.p());
    let mut x_cm = Vec::new();
    ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut x_cm);
    println!("dataset={} n={n} p={p} λ=λ_max/10 ε={tol:.0e}", ds.name);

    let dir = celer::runtime::default_artifacts_dir();
    let mut xla = match XlaEngine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot load artifacts from {}: {e:#}\nrun `make artifacts` first", dir.display());
            std::process::exit(1);
        }
    };
    let mut native = NativeEngine;

    let t0 = Instant::now();
    let out_native =
        engine_cd_solve(&mut native, &x_cm, n, p, &ds.y, lambda, tol, 2000, 5).unwrap();
    let t_native = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let out_xla = engine_cd_solve(&mut xla, &x_cm, n, p, &ds.y, lambda, tol, 2000, 5).unwrap();
    let t_xla = t0.elapsed().as_secs_f64();

    let mut table = Table::new(
        "native vs XLA/PJRT engine (identical Algorithm-1 schedule)",
        &["engine", "time", "gap", "|support|", "10-epoch blocks", "converged"],
    );
    for (name, out, t) in
        [("native", &out_native, t_native), ("xla (AOT HLO)", &out_xla, t_xla)]
    {
        table.row(vec![
            name.into(),
            fmt_secs(t),
            format!("{:.2e}", out.gap),
            out.beta.iter().filter(|&&b| b != 0.0).count().to_string(),
            out.blocks.to_string(),
            out.converged.to_string(),
        ]);
    }
    print!("{}", table.render());

    // numerical agreement of the solutions
    let max_diff = out_native
        .beta
        .iter()
        .zip(&out_xla.beta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |β_native − β_xla| = {max_diff:.3e}");
    assert!(max_diff < 1e-8, "engines must agree");
    assert_eq!(out_native.blocks, out_xla.blocks, "same schedule");
    println!("OK: Layers 1–3 compose (Pallas kernel → HLO artifact → PJRT → coordinator).");
}
