//! Figures 8 & 9 (Appendix A.2): working-set growth policies under
//! under- and over-shooting initial sizes.
//!
//! Undershoot: p₁ = 10 ≪ |Ŝ| (λ = λ_max/20); geometric ×2 reaches the
//! target quickly without exploding (×4 overshoots, linear crawls).
//! Overshoot: p₁ = 500 ≫ |Ŝ| (λ = λ_max/5); the support-based pruning
//! rule immediately shrinks W₂.
//!
//! ```bash
//! cargo run --release --example fig89_ws_policies [-- --mini]
//! ```

use celer::data::synth;
use celer::lasso::dual;
use celer::report::Table;
use celer::solvers::celer::{celer_solve_on, CelerConfig};
use celer::ws::{GrowthPolicy, WsPolicy};

fn ws_sizes(
    ds: &synth::SynthDataset,
    lambda: f64,
    p1: usize,
    growth: GrowthPolicy,
) -> Vec<usize> {
    let cfg = CelerConfig {
        tol: 1e-8,
        ws: WsPolicy { p1, growth, prune: true },
        ..Default::default()
    };
    let out = celer_solve_on(&ds.x, &ds.y, lambda, None, &cfg);
    out.iterations.iter().filter(|i| i.ws_size > 0).map(|i| i.ws_size).collect()
}

fn table_for(ds: &synth::SynthDataset, lambda: f64, p1: usize, title: &str, path: &str) {
    let policies: [(&str, GrowthPolicy); 4] = [
        ("geo ×2", GrowthPolicy::Geometric { factor: 2 }),
        ("geo ×4", GrowthPolicy::Geometric { factor: 4 }),
        ("lin +10", GrowthPolicy::Linear { increment: 10 }),
        ("lin +50", GrowthPolicy::Linear { increment: 50 }),
    ];
    let runs: Vec<Vec<usize>> =
        policies.iter().map(|(_, g)| ws_sizes(ds, lambda, p1, *g)).collect();
    let iters = runs.iter().map(|r| r.len()).max().unwrap_or(0);
    let mut t = Table::new(title, &["iter", "geo ×2", "geo ×4", "lin +10", "lin +50"]);
    for i in 0..iters {
        t.row(vec![
            (i + 1).to_string(),
            runs[0].get(i).map(|v| v.to_string()).unwrap_or_else(|| "(done)".into()),
            runs[1].get(i).map(|v| v.to_string()).unwrap_or_else(|| "(done)".into()),
            runs[2].get(i).map(|v| v.to_string()).unwrap_or_else(|| "(done)".into()),
            runs[3].get(i).map(|v| v.to_string()).unwrap_or_else(|| "(done)".into()),
        ]);
    }
    print!("{}", t.render());
    t.save_csv(std::path::Path::new(path)).ok();
}

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::leukemia_mini(0) } else { synth::leukemia_sim(0) };
    let lmax = dual::lambda_max(&ds.x, &ds.y);

    // reference support sizes for context
    for (ratio, label) in [(20.0, "λ_max/20"), (5.0, "λ_max/5")] {
        let out = celer_solve_on(
            &ds.x,
            &ds.y,
            lmax / ratio,
            None,
            &CelerConfig { tol: 1e-10, ..Default::default() },
        );
        println!("|Ŝ({label})| = {}", out.support_size());
    }
    println!();

    table_for(
        &ds,
        lmax / 20.0,
        10,
        "Fig 8 — WS sizes, undershoot (p₁ = 10, λ = λ_max/20)",
        "results/fig8_ws_undershoot.csv",
    );
    table_for(
        &ds,
        lmax / 5.0,
        500,
        "Fig 9 — WS sizes, overshoot (p₁ = 500, λ = λ_max/5)",
        "results/fig9_ws_overshoot.csv",
    );
    println!("paper check: geo ×2 reaches |Ŝ| fast without huge WS (Fig 8);");
    println!("support-based sizing shrinks an oversized W immediately (Fig 9).");
}
