//! Figures 4 & 10: Lasso path times on the Finance-like dataset.
//!
//! Solve the path λ_max → λ_max/100 (100 λ's; `--coarse` → 10 λ's as in
//! Fig. 10) with CELER (safe & prune) and BLITZ at several tolerances,
//! warm-started. The paper's claim: CELER beats BLITZ at every ε, both
//! variants behave similarly.
//!
//! ```bash
//! cargo run --release --example fig4_path            # finance-sim, Fig 4
//! cargo run --release --example fig4_path -- --coarse  # Fig 10
//! cargo run --release --example fig4_path -- --mini    # test-scale
//! ```

use celer::coordinator::{self, PathJob};
use celer::data::design::DesignOps;
use celer::data::synth;
use celer::report::{fmt_secs, Table};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let mini = args.iter().any(|a| a == "--mini");
    let coarse = args.iter().any(|a| a == "--coarse");
    let ds = if mini { synth::finance_mini(0) } else { synth::finance_sim(0) };
    let num = if coarse { 10 } else { 100 };
    let grid = coordinator::standard_grid(&ds, 100.0, num);
    let tols = [1e-2, 1e-4, 1e-6];
    let solvers = ["celer-prune", "celer-safe", "blitz"];
    println!(
        "{} — path λ_max → λ_max/100, {} values ({}), n={} p={}",
        if coarse { "Fig 10" } else { "Fig 4" },
        num,
        ds.name,
        ds.x.n(),
        ds.x.p()
    );

    let mut table = Table::new(
        "path time to ε (warm-started)",
        &["ε", "celer-prune", "celer-safe", "blitz", "blitz/celer-prune"],
    );
    for &tol in &tols {
        let jobs: Vec<PathJob> = solvers
            .iter()
            .map(|s| PathJob {
                solver_name: s.to_string(),
                tol,
                grid: grid.clone(),
                store_betas: false,
            })
            .collect();
        let results = coordinator::run_path_jobs(&ds, jobs, 3).expect("valid solvers");
        let times: Vec<f64> = results.iter().map(|r| r.total_seconds).collect();
        for r in &results {
            assert!(
                r.all_converged(),
                "{} failed to converge at ε={tol:.0e}",
                r.solver
            );
        }
        table.row(vec![
            format!("{tol:.0e}"),
            fmt_secs(times[0]),
            fmt_secs(times[1]),
            fmt_secs(times[2]),
            format!("{:.2}×", times[2] / times[0].max(1e-12)),
        ]);
    }
    print!("{}", table.render());
    table
        .save_csv(std::path::Path::new(if coarse {
            "results/fig10_path_coarse.csv"
        } else {
            "results/fig4_path.csv"
        }))
        .ok();
    println!("\npaper check: CELER < BLITZ at every ε; safe ≈ prune.");
}
