//! Figure 2: duality gap with θ_res vs θ_accel vs true suboptimality.
//!
//! Cyclic CD (Algorithm 1) on leukemia-sim at λ = λ_max/20, cold start,
//! *without* the Eq.-13 monotonicity (as in the paper's §6.1) so the raw
//! behaviour of each dual point is visible.
//!
//! ```bash
//! cargo run --release --example fig2_dual_gap            # leukemia-sim
//! cargo run --release --example fig2_dual_gap -- --mini  # test-scale
//! ```

use celer::data::synth;
use celer::lasso::{dual, primal};
use celer::report::Table;
use celer::solvers::cd::{cd_solve, CdConfig};

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::leukemia_mini(0) } else { synth::leukemia_sim(0) };
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    println!("dataset={} λ = λ_max/20 = {:.4e}", ds.name, lambda);

    // P(β̂) to machine precision (not available to a practitioner).
    let reference = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig { tol: 1e-14, max_epochs: 100_000, ..Default::default() },
    );
    let p_star = primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
    println!("P(β̂) = {p_star:.12} (gap {:.1e})", reference.gap);

    // traced run, no monotone best-dual (§6.1 setting)
    let out = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig {
            tol: 1e-10,
            max_epochs: 2000,
            best_dual: false,
            trace: true,
            ..Default::default()
        },
    );

    let mut t = Table::new(
        "Fig 2 — P(β^t) − D(θ) per epoch",
        &["epoch", "true subopt", "gap θ_res", "gap θ_accel"],
    );
    let mut first_res_1e6 = None;
    let mut first_acc_1e6 = None;
    for (i, chk) in out.trace.iter().enumerate() {
        let subopt = chk.primal - p_star;
        let gap_res = chk.primal - chk.dual_res;
        let gap_acc = chk.dual_accel.map(|d| chk.primal - d);
        if gap_res <= 1e-6 && first_res_1e6.is_none() {
            first_res_1e6 = Some(chk.epoch);
        }
        if gap_acc.map(|g| g <= 1e-6).unwrap_or(false) && first_acc_1e6.is_none() {
            first_acc_1e6 = Some(chk.epoch);
        }
        if i % 10 == 0 {
            t.row(vec![
                chk.epoch.to_string(),
                format!("{:.3e}", subopt.max(0.0)),
                format!("{gap_res:.3e}"),
                gap_acc.map(|g| format!("{g:.3e}")).unwrap_or_else(|| "—".into()),
            ]);
        }
    }
    print!("{}", t.render());
    t.save_csv(std::path::Path::new("results/fig2_dual_gap.csv")).ok();

    println!(
        "\npaper check (gap ≤ 1e-6): θ_accel at epoch {:?}, θ_res at epoch {:?} — \
         the paper reports roughly a 2× epoch gap on leukemia",
        first_acc_1e6, first_res_1e6
    );
}
