//! Sharded out-of-core λ path: split one design across two column-store
//! shards — each with its own file, chunk cache, and prefetch stream —
//! and solve a warm-started λ path in exact f64 and in the streamed-f32
//! sweep mode, checking both against the in-memory solve bit by bit.
//!
//! ```bash
//! cargo run --release --example sharded_path
//! ```
//!
//! The flow mirrors a design too large for one spindle or socket:
//!
//! 1. generate a sparse design and write it as two shard files
//!    (`celer convert --shards 2` does the same from svmlight input);
//! 2. open them as one [`ShardedStore`] with tiny chunk budgets, so
//!    both shards genuinely stream, each behind its own prefetcher;
//! 3. run the λ path on `DesignMatrix::Sharded` in f64 and again with
//!    `Precision::F32` (chunk-streamed f32 shadow — no full-design f32
//!    copy is ever resident), comparing certificates to the resident
//!    CSC solve, then print per-shard and combined io counters.

use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::shard::{self, ShardedStore};
use celer::data::synth;
use celer::lasso::dual;
use celer::report::{fmt_secs, Table};
use celer::solvers::batch::BatchConfig;
use celer::solvers::engine::Workspace;
use celer::solvers::path::{lambda_grid, lasso_path, run_path_batched, PathResult};
use celer::solvers::Precision;
use std::time::Instant;

fn bit_identical(a: &PathResult, b: &PathResult) -> bool {
    a.steps.len() == b.steps.len()
        && a.steps.iter().zip(&b.steps).all(|(sa, sb)| {
            sa.gap.to_bits() == sb.gap.to_bits()
                && sa
                    .beta
                    .as_ref()
                    .unwrap()
                    .iter()
                    .zip(sb.beta.as_ref().unwrap())
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        })
}

fn main() {
    let ds = synth::finance_mini(0);
    let out = std::env::temp_dir()
        .join(format!("celer_sharded_path_example_{}.cstore", std::process::id()));
    let paths = shard::shard_paths(&out, 2);
    let metas = shard::write_sharded_store(&paths, &ds.x, &ds.y).expect("write shards");
    for (path, meta) in paths.iter().zip(&metas) {
        println!(
            "wrote shard {} (n={} cols={} nnz={}, {} bytes)",
            path.display(),
            meta.n,
            meta.p,
            meta.nnz,
            std::fs::metadata(path).map(|m| m.len()).unwrap_or(0),
        );
    }

    // 4 KiB chunks + a 3-chunk cache per shard: nothing close to
    // resident, and two independent prefetch streams.
    let store = ShardedStore::open_with(&paths, 4 << 10, 3).expect("open sharded store");
    println!(
        "opened {} shards, col bounds {:?}, {} chunks total\n",
        store.num_shards(),
        store.col_starts(),
        (0..store.num_shards()).map(|s| store.shard(s).nchunks()).sum::<usize>(),
    );
    let x_sh = DesignMatrix::Sharded(store);

    let tol = 1e-8;
    let lanes = 4;
    let grid = lambda_grid(dual::lambda_max(&ds.x, &ds.y), 0.05, 12);

    // exact f64 lanes: sharded vs in-memory
    let t0 = Instant::now();
    let mem = lasso_path(&ds.x, &ds.y, &grid, tol, lanes, true, &celer::penalty::L1);
    let t_mem = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let sh64 = lasso_path(&x_sh, &ds.y, &grid, tol, lanes, true, &celer::penalty::L1);
    let t_sh64 = t0.elapsed().as_secs_f64();
    assert!(mem.all_converged() && sh64.all_converged());

    // streamed-f32 sweep mode: the CD epochs run on per-chunk f32
    // shadows riding each shard's prefetch stream; gaps are exact f64.
    let cfg32 = BatchConfig { tol: 1e-7, lanes, precision: Precision::F32, ..Default::default() };
    let mut ws = Workspace::new();
    let t0 = Instant::now();
    let mem32 = run_path_batched(&ds.x, &ds.y, &grid, &cfg32, true, &mut ws);
    let t_mem32 = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let sh32 = run_path_batched(&x_sh, &ds.y, &grid, &cfg32, true, &mut ws);
    let t_sh32 = t0.elapsed().as_secs_f64();
    assert!(mem32.all_converged() && sh32.all_converged());

    let mut table = Table::new(
        &format!("λ path ({} values, B = {lanes})", grid.len()),
        &["design / sweep", "time", "Σ epochs", "final |support|"],
    );
    for (name, res, secs) in [
        ("in-memory CSC, f64", &mem, t_mem),
        ("2-shard store, f64", &sh64, t_sh64),
        ("in-memory CSC, f32 sweep", &mem32, t_mem32),
        ("2-shard store, streamed f32", &sh32, t_sh32),
    ] {
        table.row(vec![
            name.into(),
            fmt_secs(secs),
            res.steps.iter().map(|s| s.epochs).sum::<usize>().to_string(),
            res.steps.last().unwrap().support_size.to_string(),
        ]);
    }
    print!("{}", table.render());

    let id64 = bit_identical(&mem, &sh64);
    let id32 = bit_identical(&mem32, &sh32);
    println!("\nf64 certificates bit-identical across sharding:          {}", yn(id64));
    println!("streamed-f32 certificates match resident-f32 bitwise:    {}", yn(id32));
    assert!(id64 && id32, "sharding must be invisible to the math");

    if let DesignMatrix::Sharded(ref store) = x_sh {
        // The streamed-f32 run kept at most cache × chunk f32 bytes
        // per shard resident; report the bound next to the traffic.
        let shadow = store.shadow_f32();
        if let Some((_, _, bound)) = shadow.stream_stats() {
            println!(
                "\nstreamed f32 shadow bound: {:.1} KiB resident vs {:.1} KiB full copy",
                bound as f64 / 1024.0,
                (store.nnz() * 8) as f64 / 1024.0,
            );
        }
        for (s, io) in store.io_stats_per_shard().iter().enumerate() {
            let (c0, c1) = store.shard_cols(s);
            println!(
                "io shard {s} [cols {c0}..{c1}]: read {:.1} MiB in {} chunk loads \
                 ({} sync misses); prefetch {} loads, {} hits, {:.1} MiB",
                io.bytes_read as f64 / (1024.0 * 1024.0),
                io.chunks_loaded,
                io.sync_misses,
                io.prefetch_loads,
                io.prefetch_hits,
                io.bytes_prefetched as f64 / (1024.0 * 1024.0),
            );
        }
        let io = store.io_stats();
        println!(
            "io combined: read {:.1} MiB in {} chunk loads ({} sync misses); \
             prefetch {} loads, {} hits, {:.1} MiB",
            io.bytes_read as f64 / (1024.0 * 1024.0),
            io.chunks_loaded,
            io.sync_misses,
            io.prefetch_loads,
            io.prefetch_hits,
            io.bytes_prefetched as f64 / (1024.0 * 1024.0),
        );
    }
    for p in &paths {
        let _ = std::fs::remove_file(p);
    }
}

fn yn(b: bool) -> &'static str {
    if b { "YES" } else { "NO" }
}
