//! END-TO-END driver: the full system on a real small workload.
//!
//! Proves all layers compose on the paper's headline experiment shape:
//! 1. synthesize + preprocess the Finance-like sparse dataset
//!    (n=2000, p≈200k, the paper's §6.2 pipeline),
//! 2. run the coordinator: a 100-point λ-path (λ_max → λ_max/100) with
//!    warm starts, CELER vs BLITZ vs Gap-Safe CD, cells in parallel,
//! 3. verify every grid point converged and the solutions agree with an
//!    independent high-precision solve at 3 sampled λ's,
//! 4. report the headline metric: path wall-clock per solver + speedups.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! cargo run --release --example lasso_path_e2e [-- --mini]
//! ```

use celer::coordinator::{self, PathJob};
use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::primal;
use celer::report::{fmt_secs, Table};
use celer::solvers::cd::{cd_solve, CdConfig};
use std::time::Instant;

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let t_total = Instant::now();

    // ---- 1. data ----
    let t0 = Instant::now();
    let ds = if mini { synth::finance_mini(0) } else { synth::finance_sim(0) };
    println!(
        "[1/4] dataset {}: n={} p={} nnz={} (density {:.4}%) generated+preprocessed in {}",
        ds.name,
        ds.x.n(),
        ds.x.p(),
        ds.x.nnz(),
        100.0 * ds.x.density(),
        fmt_secs(t0.elapsed().as_secs_f64())
    );

    // ---- 2. coordinated path runs ----
    let num = if mini { 20 } else { 100 };
    let tol = 1e-6;
    let grid = coordinator::standard_grid(&ds, 100.0, num);
    let solvers = ["celer-prune", "celer-safe", "blitz"];
    let jobs: Vec<PathJob> = solvers
        .iter()
        .map(|s| PathJob {
            solver_name: s.to_string(),
            tol,
            grid: grid.clone(),
            store_betas: true,
        })
        .collect();
    println!("[2/4] λ-path: {num} values λ_max → λ_max/100, ε = {tol:.0e}, one worker per solver (times are contended; see fig4 for solo timings)");
    let results = coordinator::run_path_jobs(&ds, jobs, 3).expect("solvers valid");

    // ---- 3. verification ----
    let mut verified = 0;
    for &i in &[0usize, num / 2, num - 1] {
        let lambda = grid[i];
        let reference = cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CdConfig { tol: tol / 100.0, ..Default::default() },
        );
        let p_ref = primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
        for r in &results {
            let beta = r.steps[i].beta.as_ref().unwrap();
            let p_got = primal::primal(&ds.x, &ds.y, beta, lambda);
            assert!(
                p_got - p_ref <= 2.0 * tol,
                "{} at λ#{i}: {p_got} vs reference {p_ref}",
                r.solver
            );
            verified += 1;
        }
    }
    let all_ok = results.iter().all(|r| r.all_converged());
    println!("[3/4] verification: {verified} (solver, λ) cells checked vs high-precision reference; all grid points converged: {all_ok}");
    assert!(all_ok, "every grid point must reach ε");

    // ---- 4. headline report ----
    let celer_time = results[0].total_seconds;
    let mut table = Table::new(
        "end-to-end Lasso path (warm-started, parallel cells)",
        &["solver", "path time", "Σ epochs", "final |S|", "vs celer-prune"],
    );
    for r in &results {
        table.row(vec![
            r.solver.clone(),
            fmt_secs(r.total_seconds),
            r.steps.iter().map(|s| s.epochs).sum::<usize>().to_string(),
            r.steps.last().unwrap().support_size.to_string(),
            format!("{:.2}×", r.total_seconds / celer_time.max(1e-12)),
        ]);
    }
    print!("[4/4]\n{}", table.render());
    table.save_csv(std::path::Path::new("results/lasso_path_e2e.csv")).ok();
    println!("total driver time: {}", fmt_secs(t_total.elapsed().as_secs_f64()));
}
