//! Out-of-core λ path: solve a full lasso path directly from an on-disk
//! column store and check it is bit-identical to the in-memory solve.
//!
//! ```bash
//! cargo run --release --example ooc_path
//! ```
//!
//! The flow mirrors a dataset that does not fit in RAM:
//!
//! 1. generate a sparse design and write it as a `.cstore` file
//!    (`celer convert` does the same from svmlight input);
//! 2. open it as an [`OocColumnStore`] with a deliberately tiny chunk
//!    budget and cache, so the path genuinely streams: the prefetch
//!    thread pulls chunk c+1 from disk while the solver sweeps chunk c;
//! 3. run the warm-started λ path on `DesignMatrix::Ooc` and on the
//!    resident CSC, and compare β and the gap certificates bit by bit.

use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::ooc::{self, OocColumnStore};
use celer::data::synth;
use celer::lasso::dual;
use celer::report::{fmt_secs, Table};
use celer::solvers::path::{lambda_grid, lasso_path};
use std::time::Instant;

fn main() {
    let ds = synth::finance_mini(0);
    let path = std::env::temp_dir()
        .join(format!("celer_ooc_path_example_{}.cstore", std::process::id()));
    let meta = ooc::write_store(&path, &ds.x, &ds.y).expect("write store");
    println!(
        "wrote {} (n={} p={} nnz={}, {} bytes)",
        path.display(),
        meta.n,
        meta.p,
        meta.nnz,
        std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0),
    );

    // 4 KiB chunks + a 3-chunk cache: nothing close to resident.
    let store = OocColumnStore::open_with(&path, 4 << 10, 3).expect("open store");
    println!("opened as {} chunks, cache capacity 3\n", store.nchunks());
    let x_ooc = DesignMatrix::Ooc(store);

    let tol = 1e-8;
    let lanes = 4;
    let grid = lambda_grid(dual::lambda_max(&ds.x, &ds.y), 0.05, 12);

    let t0 = Instant::now();
    let mem = lasso_path(&ds.x, &ds.y, &grid, tol, lanes, true, &celer::penalty::L1);
    let t_mem = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let ooc_res = lasso_path(&x_ooc, &ds.y, &grid, tol, lanes, true, &celer::penalty::L1);
    let t_ooc = t0.elapsed().as_secs_f64();
    assert!(mem.all_converged() && ooc_res.all_converged());

    let mut identical = true;
    for (sm, so) in mem.steps.iter().zip(&ooc_res.steps) {
        identical &= sm.gap.to_bits() == so.gap.to_bits();
        let (bm, bo) = (sm.beta.as_ref().unwrap(), so.beta.as_ref().unwrap());
        identical &= bm.iter().zip(bo).all(|(a, b)| a.to_bits() == b.to_bits());
    }

    let mut table = Table::new(
        &format!("λ path ({} values, ε = {tol:.0e}, B = {lanes})", grid.len()),
        &["design", "time", "Σ epochs", "final |support|"],
    );
    for (name, res, secs) in [("in-memory CSC", &mem, t_mem), ("on-disk store", &ooc_res, t_ooc)] {
        table.row(vec![
            name.into(),
            fmt_secs(secs),
            res.steps.iter().map(|s| s.epochs).sum::<usize>().to_string(),
            res.steps.last().unwrap().support_size.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nβ and gap certificates bit-identical across storage: {}",
        if identical { "YES" } else { "NO" }
    );
    assert!(identical, "storage must be invisible to the math");

    if let DesignMatrix::Ooc(ref store) = x_ooc {
        let io = store.io_stats();
        println!(
            "synchronous io: {:.1} MiB in {} chunk loads ({} cache misses on the sweep path); \
             prefetch: {} loads, {} hits, {:.1} MiB",
            io.bytes_read as f64 / (1024.0 * 1024.0),
            io.chunks_loaded,
            io.sync_misses,
            io.prefetch_loads,
            io.prefetch_hits,
            io.bytes_prefetched as f64 / (1024.0 * 1024.0),
        );
    }
    let _ = std::fs::remove_file(&path);
}
