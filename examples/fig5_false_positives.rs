//! Figure 5: false positives of GLMNET vs CELER on a Lasso path.
//!
//! GLMNET's stopping criterion controls *primal decrease*, not the
//! duality gap, so at loose ε its supports contain many features outside
//! the equicorrelation set (determined by running CELER at ε = 1e-14 and
//! applying the Gap Safe rule). CELER, which controls the gap, keeps the
//! false-positive count near zero.
//!
//! ```bash
//! cargo run --release --example fig5_false_positives            # leukemia-sim
//! cargo run --release --example fig5_false_positives -- --mini
//! ```

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::report::Table;
use celer::screening::{d_score, gap_safe_radius};
use celer::solvers::celer::{celer_solve_on, CelerConfig};
use celer::solvers::path::{lambda_grid, run_path, PathSolver};

/// Equicorrelation complement: features the Gap Safe rule certifies to be
/// OUTSIDE the equicorrelation set at λ, using a ≈machine-precision pair.
fn certified_zeros(
    x: &celer::data::design::DesignMatrix,
    y: &[f64],
    lambda: f64,
) -> Vec<bool> {
    let out = celer_solve_on(x, y, lambda, None, &CelerConfig { tol: 1e-14, ..Default::default() });
    let theta = &out.result.theta;
    let gap = out.gap().max(0.0);
    let radius = gap_safe_radius(gap, lambda);
    let p = x.p();
    let mut xtheta = vec![0.0; p];
    x.xt_vec(theta, &mut xtheta);
    (0..p)
        .map(|j| {
            let norm = x.col_norm_sq(j).sqrt();
            norm > 0.0 && d_score(xtheta[j].abs(), norm) > radius
        })
        .collect()
}

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::leukemia_mini(0) } else { synth::leukemia_sim(0) };
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lmax, 0.01, if mini { 10 } else { 20 });
    println!("dataset={} — Lasso path, {} λ's, λ_max → λ_max/100", ds.name, grid.len());

    // certified non-equicorrelation features per λ (ground truth)
    let zeros_per_lambda: Vec<Vec<bool>> =
        grid.iter().map(|&l| certified_zeros(&ds.x, &ds.y, l)).collect();

    let tols = [1e-2, 1e-4, 1e-6, 1e-8];
    let mut table = Table::new(
        "Fig 5 — false positives (support ∩ certified-zero set), summed over the path",
        &["ε", "GLMNET", "CELER"],
    );
    for &tol in &tols {
        let mut fp = [0usize; 2];
        for (s, name) in ["glmnet", "celer-prune"].iter().enumerate() {
            let solver = PathSolver::by_name(name, tol).unwrap();
            let res = run_path(&ds.x, &ds.y, &grid, &solver, true);
            for (step, zeros) in res.steps.iter().zip(&zeros_per_lambda) {
                let beta = step.beta.as_ref().unwrap();
                fp[s] += beta
                    .iter()
                    .enumerate()
                    .filter(|(j, &b)| b != 0.0 && zeros[*j])
                    .count();
            }
        }
        table.row(vec![format!("{tol:.0e}"), fp[0].to_string(), fp[1].to_string()]);
    }
    print!("{}", table.render());
    table.save_csv(std::path::Path::new("results/fig5_false_positives.csv")).ok();
    println!("\npaper check: GLMNET ≫ CELER at loose ε; both → 0 as ε tightens.");
}
