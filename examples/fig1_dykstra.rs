//! Figure 1: Dykstra's algorithm in the Lasso dual on the 2×2 toy.
//!
//! (b) cyclic order: end-of-epoch dual iterates follow a noiseless VAR —
//!     K=4 extrapolation finds θ̂ to machine precision within ~5 epochs;
//! (c) shuffled order: the trajectory is irregular and extrapolates badly;
//! (d) dual suboptimality ‖θ^t − θ̂‖ with and without acceleration.
//!
//! ```bash
//! cargo run --release --example fig1_dykstra
//! ```

use celer::data::synth;
use celer::lasso::dual;
use celer::report::Table;
use celer::solvers::dykstra::{dual_suboptimality_curves, dykstra_lasso_dual, Order};

fn main() {
    let ds = synth::toy_2x2();
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 4.0;
    let epochs = 15;
    let k = 4;

    // --- (b)/(c): iterates per epoch ---
    let cyc = dykstra_lasso_dual(&ds.x, &ds.y, lambda, epochs, Order::Cyclic);
    let shf = dykstra_lasso_dual(&ds.x, &ds.y, lambda, epochs, Order::Shuffle { seed: 42 });
    let mut t = Table::new(
        "Fig 1b/1c — dual iterates θ^t per epoch (toy 2×2)",
        &["epoch", "cyclic θ₁", "cyclic θ₂", "shuffle θ₁", "shuffle θ₂"],
    );
    for e in 0..epochs.min(8) {
        t.row(vec![
            (e + 1).to_string(),
            format!("{:+.6}", cyc.theta_per_epoch[e][0]),
            format!("{:+.6}", cyc.theta_per_epoch[e][1]),
            format!("{:+.6}", shf.theta_per_epoch[e][0]),
            format!("{:+.6}", shf.theta_per_epoch[e][1]),
        ]);
    }
    print!("{}", t.render());

    // --- (d): dual suboptimality with and without extrapolation ---
    let (cyc_plain, cyc_accel) =
        dual_suboptimality_curves(&ds.x, &ds.y, lambda, epochs, Order::Cyclic, k, 50_000);
    let (shf_plain, shf_accel) = dual_suboptimality_curves(
        &ds.x,
        &ds.y,
        lambda,
        epochs,
        Order::Shuffle { seed: 42 },
        k,
        50_000,
    );
    let mut t = Table::new(
        "Fig 1d — dual suboptimality ‖θ^t − θ̂‖ (K = 4 extrapolation)",
        &["epoch", "cyclic", "cyclic+extr", "shuffle", "shuffle+extr"],
    );
    for e in 0..epochs {
        t.row(vec![
            (e + 1).to_string(),
            format!("{:.3e}", cyc_plain[e]),
            format!("{:.3e}", cyc_accel[e]),
            format!("{:.3e}", shf_plain[e]),
            format!("{:.3e}", shf_accel[e]),
        ]);
    }
    print!("{}", t.render());
    t.save_csv(std::path::Path::new("results/fig1_dykstra.csv")).ok();

    let at = (k + 1).min(epochs - 1);
    println!(
        "\npaper check: cyclic extrapolation hits machine precision by epoch {} \
         ({:.1e}), plain cyclic is at {:.1e}",
        at + 1,
        cyc_accel[at],
        cyc_plain[at]
    );
}
