//! Sparse logistic regression λ-path demo on the datafit-generic engine.
//!
//! Synthetic binary labels (sign of a sparse linear signal), a
//! warm-started λ path via `glm_path`, and the per-λ duality-gap
//! certificates from the extrapolated dual point — the GLM follow-up
//! paper's headline workflow on this crate's CELER core.
//!
//! Run with: `cargo run --release --example logreg_path [-- --mini]`

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::datafit::GlmFamily;
use celer::report::{fmt_sci, fmt_secs, Table};
use celer::solvers::celer::CelerConfig;
use celer::solvers::glm::logreg_lambda_max;
use celer::solvers::path::{glm_path, lambda_grid};

fn main() {
    let mini = std::env::args().any(|a| a == "--mini");
    let ds = if mini { synth::logreg_mini(0) } else { synth::leukemia_sim(0) };
    // Binary labels: sign of the (noisy) sparse signal.
    let y = synth::sign_labels(&ds.y);
    let pos = y.iter().filter(|&&v| v > 0.0).count();
    println!(
        "dataset={} n={} p={} labels: +{pos}/−{}",
        ds.name,
        ds.x.n(),
        ds.x.p(),
        y.len() - pos
    );

    let lmax = logreg_lambda_max(&ds.x, &y);
    let grid = lambda_grid(lmax, 0.05, if mini { 8 } else { 20 });
    let tol = 1e-8;
    println!(
        "λ_max = {} (= ‖Xᵀy‖_∞/2), grid of {} down to λ_max/20, ε = {tol:.0e}",
        fmt_sci(lmax),
        grid.len()
    );

    let cfg = CelerConfig { tol, ..Default::default() };
    let sw = std::time::Instant::now();
    let res = glm_path(&ds.x, &y, GlmFamily::Logistic, &grid, &cfg, false);
    let elapsed = sw.elapsed().as_secs_f64();

    let mut table = Table::new(
        "sparse logreg path (warm-started, gap-certified)",
        &["λ/λ_max", "gap", "|support|", "inner epochs", "time"],
    );
    for step in &res.steps {
        table.row(vec![
            format!("{:.3}", step.lambda / lmax),
            fmt_sci(step.gap),
            step.support_size.to_string(),
            step.epochs.to_string(),
            fmt_secs(step.seconds),
        ]);
    }
    print!("{}", table.render());
    println!(
        "total {} — every gap ≤ ε: {}",
        fmt_secs(elapsed),
        res.all_converged()
    );
    assert!(res.all_converged(), "path must certify every λ");
}
