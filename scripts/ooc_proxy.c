/* C proxy for the out-of-core streaming-sweep benchmark (BENCH_9).
 *
 * The container this repo grows in has no Rust toolchain, so the
 * committed BENCH_9.json numbers are measured with this gcc mirror of
 * rust/benches/ooc_stream.rs. It reproduces the data/ooc.rs pipeline
 * end to end:
 *
 *   - writes a synthetic sparse design to a temp file in the exact
 *     CELERCS1 v1 layout (header | y f64 | indptr u64 | indices u32 |
 *     data f64), zeros dropped;
 *   - streams it back in byte-bounded column chunks via pread, with a
 *     pthread prefetcher double-buffering chunk c+1 while the main
 *     thread decodes/sweeps chunk c (mirror of the celer-ooc-prefetch
 *     thread + two-slot handoff);
 *   - arm 1 sweeps every column with a single-lane gather dot;
 *   - arm 2 serves B = 8 lambda-lanes per fetched column (mirror of
 *     csc::lane_dot_entries' pair-processed loop);
 *   - arm 3 is the write-side lane axpy.
 *
 * The measured amortization factor is B * t(1-lane) / t(B-lane): how
 * many of the B lanes ride for free on one fetch+decode. Like the Rust
 * bench, re-reads hit the OS page cache — this measures the streaming
 * pipeline (syscall + decode + kernel), not cold-device I/O.
 *
 * Build + run:
 *   gcc -O3 -march=native -pthread -o /tmp/ooc_proxy scripts/ooc_proxy.c && /tmp/ooc_proxy
 * Output lines:
 *   proxy <name> n=.. p=.. b=.. iters=.. min_ns=.. mean_ns=.. bytes_per_s=.. cols_per_s=.. amort=..
 *
 * Sharded variant (BENCH_10, mirror of data/shard.rs ShardedStore):
 * compile with -DNSHARDS=k to replace the single-store arms with a
 * k-shard aggregate-bandwidth measurement — the design's columns split
 * into k contiguous-range files, each swept by its own thread behind
 * its own double-buffered prefetcher (shard-aligned parallelism: no
 * worker ever touches another shard's stream). NSHARDS=1 is the
 * one-stream baseline; the acceptance ratio is
 * bytes_per_s(k=2) / bytes_per_s(k=1).
 *
 *   gcc -O3 -march=native -pthread -DNSHARDS=2 -o /tmp/shard_proxy scripts/ooc_proxy.c
 * Output line:
 *   proxy sharded_stream_sweep n=.. p=.. shards=k b=.. iters=.. min_ns=.. mean_ns=.. bytes_per_s=..
 */
#define _GNU_SOURCE
#include <fcntl.h>
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

#ifndef N
#define N 512
#endif
#ifndef P
#define P 16384
#endif
#define B 8
#ifndef DENSITY
#define DENSITY 0.05
#endif
#ifndef ITERS
#define ITERS 12
#endif

#define HEADER_LEN 40
#define ENTRY_BYTES 12 /* u32 row index + f64 value */

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec * 1e9 + (double)ts.tv_nsec;
}

/* xorshift64* — deterministic fill, matches the spirit of util::rng */
static unsigned long long rng_state = 0x9e3779b97f4a7c15ULL;
static double uniform01(void) {
    rng_state ^= rng_state >> 12;
    rng_state ^= rng_state << 25;
    rng_state ^= rng_state >> 27;
    unsigned long long z = rng_state * 0x2545F4914F6CDD1DULL;
    return (double)(z >> 11) / 9007199254740992.0;
}

/* ---- store creation: CELERCS1 v1 layout --------------------------- */

static uint64_t indptr[P + 1];

static uint64_t write_store(const char *path) {
    uint32_t *indices = malloc(sizeof(uint32_t) * (size_t)N * P);
    double *data = malloc(sizeof(double) * (size_t)N * P);
    if (!indices || !data) exit(1);
    uint64_t nnz = 0;
    for (int j = 0; j < P; j++) {
        indptr[j] = nnz;
        for (int i = 0; i < N; i++) {
            if (uniform01() < DENSITY) {
                indices[nnz] = (uint32_t)i;
                data[nnz] = uniform01() - 0.5;
                nnz++;
            }
        }
    }
    indptr[P] = nnz;

    FILE *f = fopen(path, "wb");
    if (!f) exit(1);
    uint32_t version = 1, flags = 0;
    uint64_t n64 = N, p64 = P;
    fwrite("CELERCS1", 1, 8, f);
    fwrite(&version, 4, 1, f);
    fwrite(&flags, 4, 1, f);
    fwrite(&n64, 8, 1, f);
    fwrite(&p64, 8, 1, f);
    fwrite(&nnz, 8, 1, f);
    for (int i = 0; i < N; i++) {
        double yi = uniform01() - 0.5;
        fwrite(&yi, 8, 1, f);
    }
    fwrite(indptr, 8, P + 1, f);
    fwrite(indices, 4, nnz, f);
    fwrite(data, 8, nnz, f);
    fclose(f);
    free(indices);
    free(data);
    return nnz;
}

/* ---- chunk plan: greedy byte-bounded column ranges ---------------- */

static int chunk_starts[P + 2];
static int nchunks;
static uint64_t idx_off, data_off;
static uint64_t max_chunk_entries;

static void plan_chunks(uint64_t nnz, uint64_t chunk_bytes) {
    idx_off = HEADER_LEN + 8ULL * N + 8ULL * (P + 1);
    data_off = idx_off + 4ULL * nnz;
    nchunks = 0;
    max_chunk_entries = 0;
    int j = 0;
    while (j < P) {
        chunk_starts[nchunks++] = j;
        int start = j;
        uint64_t bytes = 0;
        while (j < P) {
            uint64_t col = (indptr[j + 1] - indptr[j]) * ENTRY_BYTES;
            if (j > start && bytes + col > chunk_bytes) break;
            bytes += col;
            j++;
        }
        uint64_t e = indptr[j] - indptr[start];
        if (e > max_chunk_entries) max_chunk_entries = e;
    }
    chunk_starts[nchunks] = P;
}

/* ---- double-buffered prefetch (mirror of ooc.rs Prefetcher) ------- */

typedef struct {
    uint32_t *idx;
    double *val;
    unsigned char *raw_idx;
    unsigned char *raw_val;
    uint64_t entry0;
} Slot;

static Slot slots[2];
static int store_fd;

static void load_chunk(int c, Slot *s) {
    int j0 = chunk_starts[c], j1 = chunk_starts[c + 1];
    uint64_t e0 = indptr[j0], e1 = indptr[j1];
    uint64_t ne = e1 - e0;
    s->entry0 = e0;
    /* two pread calls + explicit LE decode, like ooc.rs load_chunk */
    if (pread(store_fd, s->raw_idx, 4 * ne, (off_t)(idx_off + 4 * e0)) != (ssize_t)(4 * ne)) exit(2);
    if (pread(store_fd, s->raw_val, 8 * ne, (off_t)(data_off + 8 * e0)) != (ssize_t)(8 * ne)) exit(2);
    memcpy(s->idx, s->raw_idx, 4 * ne);
    memcpy(s->val, s->raw_val, 8 * ne);
}

static pthread_mutex_t pf_m = PTHREAD_MUTEX_INITIALIZER;
static pthread_cond_t pf_cv = PTHREAD_COND_INITIALIZER;
static int pf_want = -1, pf_done = -1, pf_shutdown = 0;

static void *prefetch_main(void *arg) {
    (void)arg;
    for (;;) {
        pthread_mutex_lock(&pf_m);
        while (pf_want < 0 && !pf_shutdown) pthread_cond_wait(&pf_cv, &pf_m);
        if (pf_shutdown) {
            pthread_mutex_unlock(&pf_m);
            return NULL;
        }
        int c = pf_want;
        pf_want = -1;
        pthread_mutex_unlock(&pf_m);
        load_chunk(c, &slots[c % 2]);
        pthread_mutex_lock(&pf_m);
        pf_done = c;
        pthread_cond_signal(&pf_cv);
        pthread_mutex_unlock(&pf_m);
    }
}

static void request(int c) {
    pthread_mutex_lock(&pf_m);
    pf_want = c;
    pthread_cond_signal(&pf_cv);
    pthread_mutex_unlock(&pf_m);
}

static void wait_done(int c) {
    pthread_mutex_lock(&pf_m);
    while (pf_done < c) pthread_cond_wait(&pf_cv, &pf_m);
    pthread_mutex_unlock(&pf_m);
}

/* ---- sweep kernels over one chunk's decoded entries --------------- */

/* single-lane gather dot: 4-way accumulators, mirror of simd::gather_dot */
__attribute__((noinline)) static double gdot1(const uint32_t *idx, const double *val, uint64_t ne,
                                              const double *v) {
    double a0 = 0, a1 = 0, a2 = 0, a3 = 0;
    uint64_t m = ne - (ne % 4);
    for (uint64_t t = 0; t < m; t += 4) {
        a0 += val[t] * v[idx[t]];
        a1 += val[t + 1] * v[idx[t + 1]];
        a2 += val[t + 2] * v[idx[t + 2]];
        a3 += val[t + 3] * v[idx[t + 3]];
    }
    for (uint64_t t = m; t < ne; t++) a0 += val[t] * v[idx[t]];
    return (a0 + a1) + (a2 + a3);
}

/* B-lane gather dot: each entry loaded once, pair-processed lanes
 * (mirror of csc::lane_dot_entries) */
__attribute__((noinline)) static void gdotB(const uint32_t *idx, const double *val, uint64_t ne,
                                            const double *v, double *out) {
    for (int k = 0; k < B; k++) out[k] = 0.0;
    for (uint64_t t = 0; t < ne; t++) {
        uint32_t i = idx[t];
        double x = val[t];
        for (int k = 0; k < B; k += 2) {
            out[k] += x * v[(size_t)k * N + i];
            out[k + 1] += x * v[(size_t)(k + 1) * N + i];
        }
    }
}

/* B-lane gather axpy (mirror of csc::lane_axpy_entries) */
__attribute__((noinline)) static void gaxpyB(const uint32_t *idx, const double *val, uint64_t ne,
                                             const double *alphas, double *v) {
    for (uint64_t t = 0; t < ne; t++) {
        uint32_t i = idx[t];
        double x = val[t];
        for (int k = 0; k < B; k++) v[(size_t)k * N + i] += alphas[k] * x;
    }
}

/* one full streaming sweep: prefetch pipeline + per-column kernel */
typedef void (*col_fn)(const uint32_t *idx, const double *val, uint64_t ne, double *v, double *sink);

static void sweep(col_fn f, double *v, double *sink) {
    pf_done = -1;
    load_chunk(0, &slots[0]); /* prime slot 0 synchronously */
    for (int c = 0; c < nchunks; c++) {
        if (c > 0) wait_done(c);
        if (c + 1 < nchunks) request(c + 1);
        Slot *s = &slots[c % 2];
        for (int j = chunk_starts[c]; j < chunk_starts[c + 1]; j++) {
            uint64_t rel = indptr[j] - s->entry0;
            f(s->idx + rel, s->val + rel, indptr[j + 1] - indptr[j], v, sink);
        }
    }
}

static void col_dot1(const uint32_t *idx, const double *val, uint64_t ne, double *v, double *sink) {
    *sink += gdot1(idx, val, ne, v);
}

static void col_dotB(const uint32_t *idx, const double *val, uint64_t ne, double *v, double *sink) {
    double out[B];
    gdotB(idx, val, ne, v, out);
    *sink += out[0];
}

static double ALPHAS[B];

static void col_axpyB(const uint32_t *idx, const double *val, uint64_t ne, double *v, double *sink) {
    gaxpyB(idx, val, ne, ALPHAS, v);
    *sink += 0.0;
}

static double bench_min(col_fn f, double *v, double *mean_ns_out) {
    double sink = 0.0;
    sweep(f, v, &sink); /* warmup */
    double min_ns = 1e30, sum_ns = 0.0;
    for (int it = 0; it < ITERS; it++) {
        double t0 = now_ns();
        sweep(f, v, &sink);
        double dt = now_ns() - t0;
        if (dt < min_ns) min_ns = dt;
        sum_ns += dt;
    }
    if (sink == 12345.678) fprintf(stderr, "sink\n"); /* defeat DCE */
    *mean_ns_out = sum_ns / ITERS;
    return min_ns;
}

#ifdef NSHARDS

/* ---- sharded variant: NSHARDS column-range files, one sweep thread
 *      with its own prefetcher per shard (mirror of data/shard.rs) ---
 *
 * Reads use O_DIRECT so every chunk fetch is a real device I/O
 * (page-cache re-reads would measure memcpy, not storage): per-stream
 * reads are synchronous QD-1, so aggregate bandwidth grows with the
 * number of independent shard streams keeping the device queue fed —
 * the effect `ShardedStore`'s per-shard prefetch threads exploit. The
 * ~32 KiB chunk budget keeps each fetch latency-bound (the regime
 * where stream count matters); if O_DIRECT is unsupported the proxy
 * falls back to buffered reads and says so (direct=0 in the output).
 *
 * Each shard worker issues its own chunk reads inline — the worker IS
 * the shard's prefetch stream, pinned at queue depth 1 like the Rust
 * Prefetcher. (A separate handoff thread per shard, as in ooc.rs,
 * adds two context switches per chunk; on a single-core container
 * that scheduling artifact dominates the device effect under
 * measurement, so the proxy folds the stream into the worker.)
 */

#ifndef SHARD_CHUNK_BYTES
#define SHARD_CHUNK_BYTES 32768
#endif
#define DIRECT_ALIGN 4096ULL

static int use_direct = 1;

typedef struct {
    int id;
    int j0, j1; /* global column range owned by this shard */
    int fd;
    uint64_t ioff, doff; /* file offsets of the index / data segments */
    int cstarts[P + 2];  /* chunk starts in *global* column indices */
    int nch;
    uint64_t maxe;
    Slot sl[1];
    double *v; /* private length-N vector: no cross-shard sharing */
    double sink;
} ShardS;

static ShardS shardv[NSHARDS];
static pthread_barrier_t shard_bar;

/* Write the columns [j0, j1) as a standalone store file of shape
 * (N, j1-j0) with the full y segment — byte-compatible with what
 * shard::write_sharded_store emits per shard. */
static void write_shard_file(const char *path, const uint32_t *indices, const double *data,
                             const double *y, int j0, int j1) {
    FILE *f = fopen(path, "wb");
    if (!f) exit(1);
    uint32_t version = 1, flags = 0;
    uint64_t n64 = N, p64 = (uint64_t)(j1 - j0);
    uint64_t nnz_s = indptr[j1] - indptr[j0];
    fwrite("CELERCS1", 1, 8, f);
    fwrite(&version, 4, 1, f);
    fwrite(&flags, 4, 1, f);
    fwrite(&n64, 8, 1, f);
    fwrite(&p64, 8, 1, f);
    fwrite(&nnz_s, 8, 1, f);
    fwrite(y, 8, N, f);
    for (int j = j0; j <= j1; j++) {
        uint64_t local = indptr[j] - indptr[j0];
        fwrite(&local, 8, 1, f);
    }
    fwrite(indices + indptr[j0], 4, nnz_s, f);
    fwrite(data + indptr[j0], 8, nnz_s, f);
    fclose(f);
}

/* Per-shard greedy byte-bounded chunk plan, like plan_chunks but over
 * the shard's own column range with its own chunk budget. */
static void shard_plan(ShardS *sh, uint64_t chunk_bytes) {
    uint64_t nnz_s = indptr[sh->j1] - indptr[sh->j0];
    sh->ioff = HEADER_LEN + 8ULL * N + 8ULL * (sh->j1 - sh->j0 + 1);
    sh->doff = sh->ioff + 4ULL * nnz_s;
    sh->nch = 0;
    sh->maxe = 0;
    int j = sh->j0;
    while (j < sh->j1) {
        sh->cstarts[sh->nch++] = j;
        int start = j;
        uint64_t bytes = 0;
        while (j < sh->j1) {
            uint64_t col = (indptr[j + 1] - indptr[j]) * ENTRY_BYTES;
            if (j > start && bytes + col > chunk_bytes) break;
            bytes += col;
            j++;
        }
        uint64_t e = indptr[j] - indptr[start];
        if (e > sh->maxe) sh->maxe = e;
    }
    sh->cstarts[sh->nch] = sh->j1;
}

/* O_DIRECT needs 4 KiB-aligned offsets/lengths/buffers: read the
 * covering aligned window into the (aligned) raw buffer and decode
 * from the interior. A short read is fine as long as it covers the
 * entries we asked for (the file tail is not block-aligned). */
static void aligned_read(int fd, unsigned char *raw, unsigned char *dst, uint64_t off,
                         uint64_t len) {
    if (!use_direct) {
        if (pread(fd, raw, len, (off_t)off) != (ssize_t)len) exit(2);
        memcpy(dst, raw, len);
        return;
    }
    uint64_t a0 = off & ~(DIRECT_ALIGN - 1);
    uint64_t a1 = (off + len + DIRECT_ALIGN - 1) & ~(DIRECT_ALIGN - 1);
    ssize_t got = pread(fd, raw, a1 - a0, (off_t)a0);
    if (got < (ssize_t)(off - a0 + len)) exit(2);
    memcpy(dst, raw + (off - a0), len);
}

static void shard_load_chunk(ShardS *sh, int c, Slot *s) {
    int j0 = sh->cstarts[c], j1 = sh->cstarts[c + 1];
    uint64_t e0 = indptr[j0], e1 = indptr[j1]; /* global entry indices */
    uint64_t el = e0 - indptr[sh->j0];         /* shard-local file offset */
    uint64_t ne = e1 - e0;
    s->entry0 = e0;
    aligned_read(sh->fd, s->raw_idx, (unsigned char *)s->idx, sh->ioff + 4 * el, 4 * ne);
    aligned_read(sh->fd, s->raw_val, (unsigned char *)s->val, sh->doff + 8 * el, 8 * ne);
}

/* One full streaming sweep over this shard's columns: the worker
 * drives its own chunk stream — fetch, decode, single-lane gather dot
 * per column — so each shard keeps exactly one read in flight. */
static void shard_sweep(ShardS *sh) {
    for (int c = 0; c < sh->nch; c++) {
        Slot *s = &sh->sl[0];
        shard_load_chunk(sh, c, s);
        for (int j = sh->cstarts[c]; j < sh->cstarts[c + 1]; j++) {
            uint64_t rel = indptr[j] - s->entry0;
            sh->sink += gdot1(s->idx + rel, s->val + rel, indptr[j + 1] - indptr[j], sh->v);
        }
    }
}

static void *shard_worker(void *arg) {
    ShardS *sh = arg;
    for (int it = 0; it < ITERS + 1; it++) { /* +1 warmup */
        pthread_barrier_wait(&shard_bar);
        shard_sweep(sh);
        pthread_barrier_wait(&shard_bar);
    }
    return NULL;
}

int main(void) {
    /* generate the full design once (identical rng stream to the
     * single-store arms), then split it into NSHARDS files */
    uint32_t *indices = malloc(sizeof(uint32_t) * (size_t)N * P);
    double *data = malloc(sizeof(double) * (size_t)N * P);
    double *y = malloc(sizeof(double) * N);
    if (!indices || !data || !y) return 1;
    uint64_t nnz = 0;
    for (int j = 0; j < P; j++) {
        indptr[j] = nnz;
        for (int i = 0; i < N; i++) {
            if (uniform01() < DENSITY) {
                indices[nnz] = (uint32_t)i;
                data[nnz] = uniform01() - 0.5;
                nnz++;
            }
        }
    }
    indptr[P] = nnz;
    for (int i = 0; i < N; i++) y[i] = uniform01() - 0.5;

    char paths[NSHARDS][256];
    for (int s = 0; s < NSHARDS; s++) {
        ShardS *sh = &shardv[s];
        sh->id = s;
        sh->j0 = (int)((long long)s * P / NSHARDS);
        sh->j1 = (int)((long long)(s + 1) * P / NSHARDS);
        snprintf(paths[s], sizeof paths[s], "/tmp/celer_shard_proxy_%d.s%d", (int)getpid(), s);
        write_shard_file(paths[s], indices, data, y, sh->j0, sh->j1);
        /* latency-bound chunk budget (see the O_DIRECT note above) */
        shard_plan(sh, SHARD_CHUNK_BYTES);
        sh->fd = -1;
        if (use_direct) sh->fd = open(paths[s], O_RDONLY | O_DIRECT);
        if (sh->fd < 0) {
            use_direct = 0;
            sh->fd = open(paths[s], O_RDONLY);
        }
        if (sh->fd < 0) return 1;
        for (int b = 0; b < 1; b++) {
            sh->sl[b].idx = malloc(4 * sh->maxe);
            sh->sl[b].val = malloc(8 * sh->maxe);
            /* raw windows are aligned-start + aligned-end padded */
            if (posix_memalign((void **)&sh->sl[b].raw_idx, DIRECT_ALIGN,
                               4 * sh->maxe + 2 * DIRECT_ALIGN) ||
                posix_memalign((void **)&sh->sl[b].raw_val, DIRECT_ALIGN,
                               8 * sh->maxe + 2 * DIRECT_ALIGN))
                return 1;
            if (!sh->sl[b].idx || !sh->sl[b].val) return 1;
        }
        sh->v = malloc(sizeof(double) * (size_t)N);
        for (size_t i = 0; i < (size_t)N; i++) sh->v[i] = uniform01() - 0.5;
        sh->sink = 0.0;
    }
    free(indices);
    free(data);
    free(y);

    pthread_barrier_init(&shard_bar, NULL, NSHARDS + 1);
    pthread_t workers[NSHARDS];
    for (int s = 0; s < NSHARDS; s++) pthread_create(&workers[s], NULL, shard_worker, &shardv[s]);

    double min_ns = 1e30, sum_ns = 0.0;
    for (int it = 0; it < ITERS + 1; it++) {
        double t0 = now_ns();
        pthread_barrier_wait(&shard_bar); /* release all shard sweeps */
        pthread_barrier_wait(&shard_bar); /* all shards done */
        double dt = now_ns() - t0;
        if (it == 0) continue; /* warmup */
        if (dt < min_ns) min_ns = dt;
        sum_ns += dt;
    }
    for (int s = 0; s < NSHARDS; s++) pthread_join(workers[s], NULL);

    double sink = 0.0;
    for (int s = 0; s < NSHARDS; s++) sink += shardv[s].sink;
    if (sink == 12345.678) fprintf(stderr, "sink\n"); /* defeat DCE */

    /* aggregate logical stream traffic per sweep across all shards */
    double sweep_bytes = (double)nnz * ENTRY_BYTES;
    printf("proxy sharded_stream_sweep n=%d p=%d shards=%d b=1 iters=%d min_ns=%.0f "
           "mean_ns=%.0f bytes_per_s=%.3e cols_per_s=%.3e direct=%d\n",
           N, P, NSHARDS, ITERS, min_ns, sum_ns / ITERS, sweep_bytes / (min_ns / 1e9),
           P / (min_ns / 1e9), use_direct);
    int total_chunks = 0;
    for (int s = 0; s < NSHARDS; s++) total_chunks += shardv[s].nch;
    printf("# shards=%d chunks=%d nnz=%llu chunk_bytes=%d\n", NSHARDS, total_chunks,
           (unsigned long long)nnz, (int)SHARD_CHUNK_BYTES);

    for (int s = 0; s < NSHARDS; s++) {
        close(shardv[s].fd);
        unlink(paths[s]);
    }
    return 0;
}

#else /* !NSHARDS: the single-store arms (BENCH_9) */

int main(void) {
    char path[256];
    snprintf(path, sizeof path, "/tmp/celer_ooc_proxy_%d.cstore", (int)getpid());
    uint64_t nnz = write_store(path);
    /* same chunk policy as the Rust bench: ~64 chunks, cache < chunks */
    uint64_t chunk_bytes = nnz * ENTRY_BYTES / 64;
    if (chunk_bytes < 4096) chunk_bytes = 4096;
    plan_chunks(nnz, chunk_bytes);

    store_fd = open(path, O_RDONLY);
    if (store_fd < 0) return 1;
    for (int s = 0; s < 2; s++) {
        slots[s].idx = malloc(4 * max_chunk_entries);
        slots[s].val = malloc(8 * max_chunk_entries);
        slots[s].raw_idx = malloc(4 * max_chunk_entries);
        slots[s].raw_val = malloc(8 * max_chunk_entries);
        if (!slots[s].idx || !slots[s].val || !slots[s].raw_idx || !slots[s].raw_val) return 1;
    }
    pthread_t pf;
    pthread_create(&pf, NULL, prefetch_main, NULL);

    double *v = malloc(sizeof(double) * (size_t)B * N);
    for (size_t i = 0; i < (size_t)B * N; i++) v[i] = uniform01() - 0.5;
    for (int k = 0; k < B; k++) ALPHAS[k] = 1e-9 * (k + 1);

    double mean1, meanB, meanA;
    double min1 = bench_min(col_dot1, v, &mean1);
    double minB = bench_min(col_dotB, v, &meanB);
    double minA = bench_min(col_axpyB, v, &meanA);

    double sweep_bytes = (double)nnz * ENTRY_BYTES;
    printf("proxy ooc_stream_sweep n=%d p=%d b=%d iters=%d min_ns=%.0f mean_ns=%.0f "
           "bytes_per_s=%.3e cols_per_s=%.3e amort=%.2f\n",
           N, P, B, ITERS, minB, meanB, sweep_bytes / (minB / 1e9), P / (minB / 1e9),
           B * min1 / minB);
    printf("proxy ooc_stream_axpy n=%d p=%d b=%d iters=%d min_ns=%.0f mean_ns=%.0f "
           "bytes_per_s=%.3e cols_per_s=%.3e amort=%.2f\n",
           N, P, B, ITERS, minA, meanA, sweep_bytes / (minA / 1e9), P / (minA / 1e9),
           B * min1 / minA);
    printf("# chunks=%d chunk_bytes=%llu nnz=%llu\n", nchunks,
           (unsigned long long)chunk_bytes, (unsigned long long)nnz);

    pthread_mutex_lock(&pf_m);
    pf_shutdown = 1;
    pthread_cond_signal(&pf_cv);
    pthread_mutex_unlock(&pf_m);
    pthread_join(pf, NULL);
    close(store_fd);
    unlink(path);
    return 0;
}

#endif /* NSHARDS */
