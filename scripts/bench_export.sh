#!/usr/bin/env bash
# Export kernel / streaming microbenchmarks to BENCH_<pr>.json.
#
# PR selector (picks bench target, proxy source and output file):
#   --pr 6   kernel layer: rust/benches/hotpath_micro.rs, gcc mirror
#            scripts/simd_proxy.c, writes BENCH_6.json   (default)
#   --pr 9   out-of-core streaming sweep: rust/benches/ooc_stream.rs,
#            gcc mirror scripts/ooc_proxy.c, writes BENCH_9.json
#   --pr 10  sharded aggregate stream bandwidth: gcc mirror
#            scripts/ooc_proxy.c built with -DNSHARDS={1,2} (O_DIRECT
#            cold reads, one stream per shard), writes BENCH_10.json
#
# Modes (pick one source of numbers):
#   scripts/bench_export.sh [--pr N]           run `cargo bench` and parse
#                                              its `bench ...` lines
#   scripts/bench_export.sh [--pr N] --proxy   no Rust toolchain: build and
#                                              run the gcc mirror at two
#                                              shapes, parse `proxy ...` lines
#   scripts/bench_export.sh [--pr N] --dry-run parse an embedded sample
#                                              transcript — exercises the
#                                              parser without running anything
#                                              (CI bench-smoke step)
#
#   --out FILE    output path (default: BENCH_<pr>.json at the repo root)
#
# Output schema: a JSON object with provenance metadata and one record per
# bench arm: {kernel, shape, iters, ns_per_iter, gflops|null} plus, for
# streaming arms, optional {bytes_per_s, cols_per_s, amort}.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
OUT=""
MODE="cargo"
PR=6

while [ $# -gt 0 ]; do
    case "$1" in
        --proxy) MODE="proxy" ;;
        --dry-run) MODE="dry-run" ;;
        --pr) PR="$2"; shift ;;
        --out) OUT="$2"; shift ;;
        *) echo "unknown arg: $1" >&2; exit 2 ;;
    esac
    shift
done

case "$PR" in
    6)
        BENCH_TARGET="hotpath_micro"
        PROXY_SRC="simd_proxy.c"
        TITLE="BENCH_6 kernel layer (util::simd + lane tiles + f32 sweep)"
        NOTES="speedup = scalar ns_per_iter / kernel ns_per_iter at the same shape; the acceptance arm is the large shape, where the column stream exceeds cache"
        ;;
    9)
        BENCH_TARGET="ooc_stream"
        PROXY_SRC="ooc_proxy.c"
        TITLE="BENCH_9 out-of-core column store (streaming sweep + lane amortization)"
        NOTES="amort = B * t(1-lane sweep) / t(B-lane sweep): lanes served per fetch+decode of one column chunk; acceptance bar is amort >= B/2 on the sweep arm. bytes_per_s counts logical store traffic (12 B/entry); re-reads hit the OS page cache, so this measures the streaming pipeline, not cold-device I/O"
        ;;
    10)
        BENCH_TARGET="ooc_stream"
        PROXY_SRC="ooc_proxy.c"
        TITLE="BENCH_10 sharded column store (aggregate per-shard stream bandwidth)"
        NOTES="acceptance: bytes_per_s at shards=2 >= 1.6x bytes_per_s at shards=1 at the same (out-of-core, ~32 MiB) shape. Reads are O_DIRECT (no guest page cache) at a 32 KiB latency-bound chunk budget; each shard worker keeps one read in flight, so aggregate bandwidth grows with the number of independent shard streams feeding the device queue — the effect ShardedStore's per-shard prefetch threads exploit. Measured on a single-core container: the win is deeper device queue depth, not parallel compute"
        ;;
    *) echo "unknown --pr $PR (known: 6, 9, 10)" >&2; exit 2 ;;
esac
[ -n "$OUT" ] || OUT="$ROOT/BENCH_$PR.json"

# ---- collect raw bench lines -------------------------------------------

RAW="$(mktemp)"
trap 'rm -f "$RAW"' EXIT

case "$MODE" in
    cargo)
        command -v cargo >/dev/null 2>&1 || {
            echo "cargo not found; use --proxy (gcc mirror) or --dry-run" >&2
            exit 1
        }
        (cd "$ROOT/rust" && cargo bench --bench "$BENCH_TARGET") | tee "$RAW"
        ;;
    proxy)
        command -v gcc >/dev/null 2>&1 || { echo "gcc not found" >&2; exit 1; }
        BIN="$(mktemp -u)"
        case "$PR" in
            6)
                gcc -O3 -march=native -o "$BIN" "$ROOT/scripts/$PROXY_SRC"
                "$BIN" | tee "$RAW"                                # n=4096  p=256
                gcc -O3 -march=native -DN=262144 -DP=32 -DITERS=15 -o "$BIN" \
                    "$ROOT/scripts/$PROXY_SRC"
                "$BIN" | tee -a "$RAW"                             # n=262144 p=32
                ;;
            9)
                gcc -O3 -march=native -pthread -o "$BIN" "$ROOT/scripts/$PROXY_SRC"
                "$BIN" | tee "$RAW"                                # n=512  p=16384
                gcc -O3 -march=native -pthread -DN=2048 -DP=65536 -DDENSITY=0.02 \
                    -DITERS=8 -o "$BIN" "$ROOT/scripts/$PROXY_SRC"
                "$BIN" | tee -a "$RAW"                             # ~32 MB store
                ;;
            10)
                # One shape only, and a big one: a small (~5 MiB) store
                # fits the host-side cache of the virtio device, so its
                # "cold" O_DIRECT reads measure cache latency jitter,
                # not device-queue scaling. ~32 MiB keeps both shard
                # streams genuinely out-of-core.
                : > "$RAW"
                for K in 1 2; do
                    gcc -O3 -march=native -pthread -Wno-unused-function \
                        -DNSHARDS=$K -DN=2048 -DP=65536 -DDENSITY=0.02 \
                        -DITERS=10 -o "$BIN" "$ROOT/scripts/$PROXY_SRC"
                    "$BIN" | tee -a "$RAW"                         # ~32 MB store
                done
                ;;
        esac
        rm -f "$BIN"
        ;;
    dry-run)
        cat > "$RAW" <<'SAMPLE'
bench hot/lanes_dot_scalar_dense_n4096_b8    iters=12  min=    9.9ms mean=   10.6ms max=   11.2ms
bench hot/lanes_dot_blocked_dense_n4096_b8   iters=12  min=    5.7ms mean=    5.8ms max=    6.1ms
bench hot/f32_cd_epoch_dense_n4096_p256      iters=12  min=  950.0µs mean=  1.1ms max=    1.3ms
proxy lanes_axpy_blocked_dense n=262144 p=32 b=8 iters=15 min_ns=30302168 mean_ns=38059655 gflops=4.43
stream ooc_stream_sweep_n512_p16384 n=512 p=16384 b=8 iters=12 min_ns=2105882 bytes_per_s=2.391e+09 cols_per_s=7.780e+06 amort=4.72
proxy sharded_stream_sweep n=512 p=16384 shards=2 b=1 iters=12 min_ns=10492867 bytes_per_s=4.798e+08 cols_per_s=1.561e+06 direct=1
SAMPLE
        ;;
esac

# ---- parse into JSON ----------------------------------------------------

HOST="$(uname -srm 2>/dev/null || echo unknown)"
CPU="$(grep -m1 'model name' /proc/cpuinfo 2>/dev/null | sed 's/.*: //' || echo unknown)"
case "$MODE" in
    cargo)
        RUSTC_V="$(rustc --version 2>/dev/null || echo 'rustc unknown')"
        CARGO_V="$(cargo --version 2>/dev/null || echo 'cargo unknown')"
        PROV="cargo-bench (rust/benches/$BENCH_TARGET.rs; $RUSTC_V; $CARGO_V)"
        ;;
    proxy)
        PROV="gcc-proxy (scripts/$PROXY_SRC, -O3 -march=native, no fast-math; same kernels/accumulator contract as the Rust implementation — no Rust toolchain in this environment)"
        ;;
    dry-run) PROV="dry-run sample (parser smoke test, NOT measurements)" ;;
esac

# Stage the JSON and only publish it once it verifiably holds at least
# one bench record: a failed or empty bench run must exit non-zero (the
# `set -euo pipefail` above propagates the bench exit code itself), not
# overwrite a previous export with an empty results array.
STAGED="$(mktemp)"
trap 'rm -f "$RAW" "$STAGED"' EXIT

{
    printf '{\n'
    printf '  "bench": "%s",\n' "$TITLE"
    printf '  "provenance": "%s",\n' "$PROV"
    printf '  "host": "%s",\n' "$HOST"
    printf '  "cpu": "%s",\n' "$CPU"
    printf '  "notes": "%s",\n' "$NOTES"
    printf '  "results": [\n'
    # Normalize the µs glyph so awk sees single-byte units, then parse the
    # Rust harness format (`bench <name> iters=N min=<v><unit> ...`) and the
    # key=value formats: `proxy <name> n=.. iters=N min_ns=.. [gflops=..]`
    # from the gcc mirrors and `stream <name> ... bytes_per_s=.. amort=..`
    # from rust/benches/ooc_stream.rs.
    sed 's/µs/us/g' "$RAW" | awk '
        function tons(v, unit) {
            if (unit == "us") return v * 1e3
            if (unit == "ms") return v * 1e6
            if (unit == "s")  return v * 1e9
            return v
        }
        function emit(kernel, shape, iters, ns, gflops, extra) {
            if (count++) printf ",\n"
            printf "    {\"kernel\": \"%s\", \"shape\": \"%s\", \"iters\": %d, \"ns_per_iter\": %.0f, \"gflops\": %s%s}", \
                kernel, shape, iters, ns, gflops, extra
        }
        $1 == "bench" {
            line = $0
            iters = 0; minv = ""; unit = ""
            if (match(line, /iters=[0-9]+/))
                iters = substr(line, RSTART + 6, RLENGTH - 6) + 0
            if (match(line, /min=[ ]*[0-9.]+(us|ms|s)/)) {
                m = substr(line, RSTART + 4, RLENGTH - 4)
                gsub(/ /, "", m)
                unit = m; gsub(/[0-9.]/, "", unit)
                minv = m; gsub(/[a-z]/, "", minv)
            }
            if (minv != "")
                emit($2, "see kernel name", iters, tons(minv + 0, unit), "null", "")
            next
        }
        $1 == "proxy" || $1 == "stream" {
            n = ""; p = ""; b = ""; iters = 0; ns = 0; gf = "null"
            bps = ""; cps = ""; am = ""; shards = ""; direct = ""
            for (i = 3; i <= NF; i++) {
                split($i, kv, "=")
                if (kv[1] == "n") n = kv[2]
                if (kv[1] == "p") p = kv[2]
                if (kv[1] == "b") b = kv[2]
                if (kv[1] == "shards") shards = kv[2]
                if (kv[1] == "direct") direct = kv[2]
                if (kv[1] == "iters") iters = kv[2] + 0
                if (kv[1] == "min_ns") ns = kv[2] + 0
                if (kv[1] == "gflops") gf = kv[2]
                if (kv[1] == "bytes_per_s") bps = kv[2]
                if (kv[1] == "cols_per_s") cps = kv[2]
                if (kv[1] == "amort") am = kv[2]
            }
            extra = ""
            if (bps != "") extra = extra sprintf(", \"bytes_per_s\": %.4g", bps + 0)
            if (cps != "") extra = extra sprintf(", \"cols_per_s\": %.4g", cps + 0)
            if (am != "")  extra = extra sprintf(", \"amort\": %s", am)
            if (shards != "") extra = extra sprintf(", \"shards\": %s", shards)
            if (direct != "") extra = extra sprintf(", \"direct_io\": %s", direct)
            shape = "n=" n " p=" p " b=" b
            if (shards != "") shape = shape " shards=" shards
            emit($2, shape, iters, ns, gf, extra)
            next
        }
    '
    printf '\n  ]\n}\n'
} > "$STAGED"

if ! grep -q '"kernel":' "$STAGED"; then
    echo "no bench records parsed from $MODE output; refusing to write $OUT" >&2
    exit 1
fi
mv "$STAGED" "$OUT"
trap 'rm -f "$RAW"' EXIT

echo "wrote $OUT" >&2
