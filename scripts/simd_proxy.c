/* C proxy for the `util::simd` kernel-layer microbenchmarks.
 *
 * The container this repo grows in has no Rust toolchain, so the
 * committed BENCH_6.json numbers for the kernel layer are measured with
 * this gcc mirror of the exact same kernels (same accumulator widths,
 * same BLOCK=256 lane tiling, NO -ffast-math — gcc, like rustc, may not
 * reassociate the strict-FP reduction, so the scalar arm stays scalar
 * and the multi-accumulator arm vectorizes). Shapes match the
 * `hot/lanes_*` arms of rust/benches/hotpath_micro.rs: n=4096, p=256,
 * B=8 lanes.
 *
 * Build + run:  gcc -O3 -march=native -o /tmp/simd_proxy scripts/simd_proxy.c && /tmp/simd_proxy
 * Output lines: proxy <kernel> n=<n> p=<p> b=<b> iters=<k> min_ns=<..> mean_ns=<..> gflops=<..>
 */
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#ifndef N
#define N 4096
#endif
#ifndef P
#define P 256
#endif
#define B 8
#define BLOCK 256
#ifndef ITERS
#define ITERS 30
#endif

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec * 1e9 + (double)ts.tv_nsec;
}

/* xorshift64* — deterministic fill, matches the spirit of util::rng */
static unsigned long long rng_state = 0x9e3779b97f4a7c15ULL;
static double uniform(void) {
    rng_state ^= rng_state >> 12;
    rng_state ^= rng_state << 25;
    rng_state ^= rng_state >> 27;
    unsigned long long z = rng_state * 0x2545F4914F6CDD1DULL;
    return (double)(z >> 11) / 9007199254740992.0 - 0.5;
}

/* ---- scalar baselines: single sequential accumulator -------------- */

__attribute__((noinline)) static double dot_scalar(const double *a, const double *b, size_t n) {
    double acc = 0.0;
    for (size_t i = 0; i < n; i++) acc += a[i] * b[i];
    return acc;
}

__attribute__((noinline)) static void axpy_scalar(double alpha, const double *x, double *y, size_t n) {
    for (size_t i = 0; i < n; i++) y[i] += alpha * x[i];
}

/* ---- util::simd mirror: width-8 accumulators, pairwise tree ------- */

__attribute__((noinline)) static double dot_unrolled8(const double *a, const double *b, size_t n) {
    double acc[8] = {0, 0, 0, 0, 0, 0, 0, 0};
    size_t m = n - (n % 8);
    for (size_t i = 0; i < m; i += 8)
        for (int w = 0; w < 8; w++) acc[w] += a[i + w] * b[i + w];
    for (size_t i = m; i < n; i++) acc[i % 8] += a[i] * b[i];
    return ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
}

/* dense col_dot_lanes mirror: BLOCK-row tiles, the column tile is
 * loaded once and dotted against all B lane slices while hot */
__attribute__((noinline)) static void dot_lanes_blocked(const double *col, const double *v, double *out) {
    for (int k = 0; k < B; k++) out[k] = 0.0;
    for (size_t s = 0; s < N; s += BLOCK) {
        size_t e = s + BLOCK > N ? N : s + BLOCK;
        for (int k = 0; k < B; k++) out[k] += dot_unrolled8(col + s, v + (size_t)k * N + s, e - s);
    }
}

/* dense col_axpy_lanes mirror */
__attribute__((noinline)) static void axpy_lanes_blocked(const double *col, const double *alphas, double *v) {
    for (size_t s = 0; s < N; s += BLOCK) {
        size_t e = s + BLOCK > N ? N : s + BLOCK;
        for (int k = 0; k < B; k++) {
            double a = alphas[k];
            double *dst = v + (size_t)k * N + s;
            for (size_t i = 0; i < e - s; i++) dst[i] += a * col[s + i];
        }
    }
}

typedef void (*epoch_fn)(const double *x, double *v, double *sink);

static void report(const char *name, epoch_fn f, const double *x, double *v, double flops) {
    double sink = 0.0;
    f(x, v, &sink); /* warmup */
    double min_ns = 1e30, sum_ns = 0.0;
    for (int it = 0; it < ITERS; it++) {
        double t0 = now_ns();
        f(x, v, &sink);
        double dt = now_ns() - t0;
        if (dt < min_ns) min_ns = dt;
        sum_ns += dt;
    }
    if (sink == 12345.678) fprintf(stderr, "sink\n"); /* defeat DCE */
    double mean_ns = sum_ns / ITERS;
    printf("proxy %s n=%d p=%d b=%d iters=%d min_ns=%.0f mean_ns=%.0f gflops=%.2f\n",
           name, N, P, B, ITERS, min_ns, mean_ns, flops / min_ns);
}

/* ---- one "epoch" per arm: a full pass over all P columns ---------- */

static void ep_dot_scalar(const double *x, double *v, double *sink) {
    for (int j = 0; j < P; j++)
        for (int k = 0; k < B; k++) *sink += dot_scalar(x + (size_t)j * N, v + (size_t)k * N, N);
}

static void ep_dot_simd_perlane(const double *x, double *v, double *sink) {
    for (int j = 0; j < P; j++)
        for (int k = 0; k < B; k++) *sink += dot_unrolled8(x + (size_t)j * N, v + (size_t)k * N, N);
}

static void ep_dot_blocked(const double *x, double *v, double *sink) {
    double out[B];
    for (int j = 0; j < P; j++) {
        dot_lanes_blocked(x + (size_t)j * N, v, out);
        *sink += out[0];
    }
}

static double ALPHAS[B];

static void ep_axpy_scalar(const double *x, double *v, double *sink) {
    for (int j = 0; j < P; j++)
        for (int k = 0; k < B; k++) axpy_scalar(ALPHAS[k], x + (size_t)j * N, v + (size_t)k * N, N);
    *sink += v[0];
}

static void ep_axpy_blocked(const double *x, double *v, double *sink) {
    for (int j = 0; j < P; j++) axpy_lanes_blocked(x + (size_t)j * N, ALPHAS, v);
    *sink += v[0];
}

int main(void) {
    double *x = malloc(sizeof(double) * (size_t)N * P);
    double *v = malloc(sizeof(double) * (size_t)N * B);
    if (!x || !v) return 1;
    for (size_t i = 0; i < (size_t)N * P; i++) x[i] = uniform();
    for (size_t i = 0; i < (size_t)N * B; i++) v[i] = uniform();
    for (int k = 0; k < B; k++) ALPHAS[k] = (k % 2 == 0 ? 1e-9 : -1e-9);

    double dot_flops = 2.0 * N * P * B;  /* mul+add per element, all lanes */
    double axpy_flops = 2.0 * N * P * B;

    report("lanes_dot_scalar_dense", ep_dot_scalar, x, v, dot_flops);
    report("lanes_dot_simd_perlane_dense", ep_dot_simd_perlane, x, v, dot_flops);
    report("lanes_dot_blocked_dense", ep_dot_blocked, x, v, dot_flops);
    report("lanes_axpy_scalar_dense", ep_axpy_scalar, x, v, axpy_flops);
    report("lanes_axpy_blocked_dense", ep_axpy_blocked, x, v, axpy_flops);

    free(x);
    free(v);
    return 0;
}
