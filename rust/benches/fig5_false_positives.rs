//! Bench: Figure 5 — GLMNET vs CELER false-positive counts along a path
//! (the cost of running both paths + the FP property itself).

use celer::coordinator;
use celer::data::synth;
use celer::report::bench;
use celer::solvers::path::{run_path, PathSolver};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::leukemia_sim(0) } else { synth::leukemia_mini(0) };
    let grid = coordinator::standard_grid(&ds, 100.0, if full { 20 } else { 8 });
    let iters = if full { 1 } else { 3 };

    bench::time("fig5/glmnet_path_loose", iters, || {
        let solver = PathSolver::by_name("glmnet", 1e-3).unwrap();
        let res = run_path(&ds.x, &ds.y, &grid, &solver, true);
        assert_eq!(res.steps.len(), grid.len());
    });
    bench::time("fig5/celer_path_loose", iters, || {
        let solver = PathSolver::by_name("celer-prune", 1e-3).unwrap();
        let res = run_path(&ds.x, &ds.y, &grid, &solver, true);
        assert!(res.all_converged());
    });
    // property: at the loosest ε, GLMNET's final supports are at least as
    // large as CELER's (the false-positive mechanism)
    let g = run_path(&ds.x, &ds.y, &grid, &PathSolver::by_name("glmnet", 1e-2).unwrap(), false);
    let c = run_path(&ds.x, &ds.y, &grid, &PathSolver::by_name("celer-prune", 1e-2).unwrap(), false);
    let sg: usize = g.steps.iter().map(|s| s.support_size).sum();
    let sc: usize = c.steps.iter().map(|s| s.support_size).sum();
    println!("fig5 Σ|support|: glmnet={sg} celer={sc} (paper: glmnet inflated at loose ε)");
}
