//! Bench: Table 1 — cold-start single-λ solves at λ_max/20 on the
//! Finance-like dataset: CELER vs BLITZ vs vanilla CD, per tolerance.

use celer::data::synth;
use celer::lasso::dual;
use celer::report::bench;
use celer::solvers::blitz::{blitz_solve, BlitzConfig};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::celer::{celer_solve_on, CelerConfig};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::finance_sim(0) } else { synth::finance_mini(0) };
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    let iters = if full { 1 } else { 3 };
    let tols: &[f64] = if full { &[1e-2, 1e-4, 1e-6] } else { &[1e-2, 1e-6] };

    for &tol in tols {
        let tc = bench::time(&format!("table1/celer_eps{tol:.0e}"), iters, || {
            let out =
                celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig { tol, ..Default::default() });
            assert!(out.result.converged);
        });
        let tb = bench::time(&format!("table1/blitz_eps{tol:.0e}"), iters, || {
            let out = blitz_solve(&ds.x, &ds.y, lambda, None, &BlitzConfig { tol, ..Default::default() });
            assert!(out.result.converged);
        });
        let tv = bench::time(&format!("table1/cd_vanilla_eps{tol:.0e}"), iters, || {
            let out = cd_solve(
                &ds.x,
                &ds.y,
                lambda,
                None,
                &CdConfig { tol, max_epochs: 100_000, ..CdConfig::vanilla() },
            );
            assert!(out.converged);
        });
        println!(
            "table1 ε={tol:.0e}: blitz/celer {:.2}×, cd/celer {:.2}× (paper at 1e-4: 3.4×, 300×)",
            tb.min_s / tc.min_s.max(1e-12),
            tv.min_s / tc.min_s.max(1e-12)
        );
    }
}
