//! Bench: Figure 2 — CD with θ_res vs θ_accel gap evaluation on the
//! leukemia-like dense problem at λ_max/20.

use celer::data::synth;
use celer::lasso::dual;
use celer::report::bench;
use celer::solvers::cd::{cd_solve, CdConfig};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::leukemia_sim(0) } else { synth::leukemia_mini(0) };
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    let max_epochs = if full { 2000 } else { 400 };
    let iters = if full { 3 } else { 10 };

    let base = CdConfig {
        tol: 1e-10,
        max_epochs,
        best_dual: false,
        trace: true,
        ..Default::default()
    };
    bench::time("fig2/cd_trace_res_only", iters, || {
        let out =
            cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { extrapolate: false, ..base.clone() });
        assert!(!out.trace.is_empty());
    });
    bench::time("fig2/cd_trace_with_accel", iters, || {
        let out = cd_solve(&ds.x, &ds.y, lambda, None, &base);
        // the Fig-2 claim: the accelerated gap dominates somewhere
        let wins = out
            .trace
            .iter()
            .filter(|c| c.dual_accel.map(|d| d > c.dual_res).unwrap_or(false))
            .count();
        assert!(wins > 0, "θ_accel must beat θ_res at least once");
    });
}
