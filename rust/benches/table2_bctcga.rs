//! Bench: Table 2 (Appendix A.4) — dense bcTCGA-like path, CELER
//! (no-prune) vs BLITZ.

use celer::coordinator;
use celer::data::synth;
use celer::report::bench;
use celer::solvers::path::{run_path, PathSolver};

fn main() {
    let full = bench::full_scale();
    // CI scale: a dense mini stand-in; full scale: the real 536×17323 shape
    let ds = if full { synth::bctcga_sim(0) } else { synth::leukemia_mini(7) };
    let grid = coordinator::standard_grid(&ds, 100.0, if full { 100 } else { 10 });
    let iters = if full { 1 } else { 3 };

    for &tol in if full { &[1e-2, 1e-4][..] } else { &[1e-4][..] } {
        let tc = bench::time(&format!("table2/celer_safe_eps{tol:.0e}"), iters, || {
            let solver = PathSolver::by_name("celer-safe", tol).unwrap();
            assert!(run_path(&ds.x, &ds.y, &grid, &solver, false).all_converged());
        });
        let tb = bench::time(&format!("table2/blitz_eps{tol:.0e}"), iters, || {
            let solver = PathSolver::by_name("blitz", tol).unwrap();
            assert!(run_path(&ds.x, &ds.y, &grid, &solver, false).all_converged());
        });
        println!(
            "table2 ε={tol:.0e}: blitz/celer {:.2}× (paper: 22/6 at 1e-2 → 286/255 at 1e-8)",
            tb.min_s / tc.min_s.max(1e-12)
        );
    }
}
