//! Bench: Figure 3 — dynamic Gap Safe screening with θ_res vs θ_accel on
//! the sparse Finance-like dataset at λ_max/5 (wall-clock is the metric
//! the paper reports: 290 s vs 70 s).

use celer::data::synth;
use celer::lasso::dual;
use celer::report::bench;
use celer::solvers::cd::{cd_solve, CdConfig};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::finance_sim(0) } else { synth::finance_mini(0) };
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
    let iters = if full { 2 } else { 10 };
    let base = CdConfig { tol: 1e-6, screen: true, trace: true, ..Default::default() };

    let t_res = bench::time("fig3/gapsafe_theta_res", iters, || {
        let out =
            cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { extrapolate: false, ..base.clone() });
        assert!(out.converged);
    });
    let t_acc = bench::time("fig3/gapsafe_theta_accel", iters, || {
        let out = cd_solve(&ds.x, &ds.y, lambda, None, &base);
        assert!(out.converged);
    });
    println!(
        "fig3 speedup θ_accel vs θ_res: {:.2}× (paper: ≈4.1×)",
        t_res.min_s / t_acc.min_s.max(1e-12)
    );
}
