//! Bench: Figures 6 & 7 — sensitivity of extrapolated CD to the gap
//! frequency f and the depth K (cost of the traced sweeps).

use celer::data::synth;
use celer::lasso::dual;
use celer::report::bench;
use celer::solvers::cd::{cd_solve, CdConfig};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::leukemia_sim(0) } else { synth::leukemia_mini(0) };
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    let max_epochs = if full { 600 } else { 200 };
    let iters = if full { 2 } else { 5 };

    for f in [1usize, 10, 50] {
        bench::time(&format!("fig6/cd_f{f}"), iters, || {
            let out = cd_solve(
                &ds.x,
                &ds.y,
                lambda,
                None,
                &CdConfig {
                    tol: 1e-14,
                    max_epochs,
                    gap_freq: f,
                    best_dual: false,
                    trace: true,
                    ..Default::default()
                },
            );
            assert_eq!(out.epochs, max_epochs);
        });
    }
    for k in [2usize, 5, 10] {
        bench::time(&format!("fig7/cd_k{k}"), iters, || {
            let out = cd_solve(
                &ds.x,
                &ds.y,
                lambda,
                None,
                &CdConfig {
                    tol: 1e-14,
                    max_epochs,
                    k,
                    best_dual: false,
                    trace: true,
                    ..Default::default()
                },
            );
            assert_eq!(out.epochs, max_epochs);
        });
    }
}
