//! Bench: Figures 8 & 9 — CELER under different working-set growth
//! policies with under-/over-shooting initial sizes.

use celer::data::synth;
use celer::lasso::dual;
use celer::report::bench;
use celer::solvers::celer::{celer_solve_on, CelerConfig};
use celer::ws::{GrowthPolicy, WsPolicy};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::leukemia_sim(0) } else { synth::leukemia_mini(0) };
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let iters = if full { 2 } else { 5 };

    let cases: [(&str, f64, usize, GrowthPolicy); 4] = [
        ("fig8/undershoot_geo2", lmax / 20.0, 10, GrowthPolicy::Geometric { factor: 2 }),
        ("fig8/undershoot_lin10", lmax / 20.0, 10, GrowthPolicy::Linear { increment: 10 }),
        ("fig9/overshoot_geo2", lmax / 5.0, 500, GrowthPolicy::Geometric { factor: 2 }),
        ("fig9/overshoot_geo4", lmax / 5.0, 500, GrowthPolicy::Geometric { factor: 4 }),
    ];
    for (name, lambda, p1, growth) in cases {
        bench::time(name, iters, || {
            let out = celer_solve_on(
                &ds.x,
                &ds.y,
                lambda,
                None,
                &CelerConfig {
                    tol: 1e-8,
                    ws: WsPolicy { p1, growth, prune: true },
                    ..Default::default()
                },
            );
            assert!(out.result.converged);
        });
    }
}
