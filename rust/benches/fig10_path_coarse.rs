//! Bench: Figure 10 (Appendix A.3) — the Fig-4 path on a coarse 10-λ
//! grid; CELER must still beat BLITZ.

use celer::coordinator;
use celer::data::synth;
use celer::report::bench;
use celer::solvers::path::{run_path, PathSolver};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::finance_sim(0) } else { synth::finance_mini(0) };
    let grid = coordinator::standard_grid(&ds, 100.0, 10);
    let iters = if full { 2 } else { 5 };

    let t_celer = bench::time("fig10/coarse_path_celer", iters, || {
        let solver = PathSolver::by_name("celer-prune", 1e-6).unwrap();
        assert!(run_path(&ds.x, &ds.y, &grid, &solver, false).all_converged());
    });
    let t_blitz = bench::time("fig10/coarse_path_blitz", iters, || {
        let solver = PathSolver::by_name("blitz", 1e-6).unwrap();
        assert!(run_path(&ds.x, &ds.y, &grid, &solver, false).all_converged());
    });
    println!("fig10 blitz/celer: {:.2}×", t_blitz.min_s / t_celer.min_s.max(1e-12));
}
