//! Microbenchmarks of the L3 hot paths feeding the §Perf iteration loop:
//! sparse/dense CD epochs, Xᵀv scans, working-set selection, gather,
//! extrapolation solve. These are the quantities the profile-driven
//! optimization pass tracks in EXPERIMENTS.md §Perf.

use celer::data::dense::DenseMatrix;
use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::synth;
use celer::data::view::DesignView;
use celer::extrapolation::ResidualBuffer;
use celer::lasso::dual;
use celer::multitask::solver::mt_lambda_max;
use celer::report::bench;
use celer::solvers::block::{solve_blocks, BlockCdStrategy, BlockWorkspace};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::engine::{EngineConfig, Init, StopRule};
use celer::solvers::path::{lambda_grid, run_path, PathSolver};
use celer::util::select::k_smallest_indices;
use celer::util::soft_threshold;

/// Penalty-trait epoch cost: the same dense CD epoch as
/// `hot/dense_cd_epoch`, but with the update supplied by a [`Penalty`]'s
/// prox (ℓ₁ / elastic net) or block prox (group-ℓ₂ with the Frobenius
/// majorizer). `hot/prox_l1_epoch_dense` vs `hot/dense_cd_epoch` is the
/// abstraction overhead of the trait dispatch — the acceptance bar for
/// the penalty layer is parity (the `P = L1` prox inlines to the same
/// soft-threshold).
fn bench_prox_epochs(tag: &str, x: &DesignMatrix, y: &[f64], iters: usize) {
    use celer::penalty::{ElasticNet, GroupLasso, Penalty, L1};
    let p = x.p();
    let norms = x.col_norms_sq();
    let lambda = dual::lambda_max(x, y) / 10.0;

    fn separable_epoch<P: Penalty>(
        name: &str,
        pen: &P,
        x: &DesignMatrix,
        y: &[f64],
        norms: &[f64],
        lambda: f64,
        iters: usize,
    ) {
        let p = x.p();
        let mut beta = vec![0.0; p];
        let mut r = y.to_vec();
        bench::time(name, iters, || {
            for j in 0..p {
                let nrm = norms[j];
                if nrm == 0.0 {
                    continue;
                }
                let g = x.col_dot(j, &r);
                let old = beta[j];
                let new = pen.prox(j, old + g / nrm, lambda, nrm);
                if new != old {
                    x.col_axpy(j, old - new, &mut r);
                    beta[j] = new;
                }
            }
        });
    }
    separable_epoch(&format!("hot/prox_l1_epoch_{tag}"), &L1, x, y, &norms, lambda, iters);
    separable_epoch(
        &format!("hot/prox_enet_epoch_{tag}"),
        &ElasticNet::new(0.5),
        x,
        y,
        &norms,
        lambda,
        iters,
    );

    // group-ℓ₂: one block prox per group, Frobenius majorizer L_g = Σ‖x_j‖²
    let pen = GroupLasso::new(4);
    let mut beta = vec![0.0; p];
    let mut r = y.to_vec();
    let mut u = [0.0f64; 4];
    let mut b_new = [0.0f64; 4];
    bench::time(&format!("hot/prox_group_epoch_{tag}"), iters, || {
        let mut start = 0;
        while start < p {
            let end = (start + 4).min(p);
            let w = end - start;
            let l_g: f64 = norms[start..end].iter().sum();
            if l_g == 0.0 {
                start = end;
                continue;
            }
            for (k, j) in (start..end).enumerate() {
                u[k] = beta[j] + x.col_dot(j, &r) / l_g;
            }
            pen.prox_vec(&u[..w], lambda, l_g, &mut b_new[..w]);
            for (k, j) in (start..end).enumerate() {
                let old = beta[j];
                if b_new[k] != old {
                    x.col_axpy(j, old - b_new[k], &mut r);
                    beta[j] = b_new[k];
                }
            }
            start = end;
        }
    });
}

/// The `k` columns most |correlated| with y — a realistic working set.
fn top_correlated(x: &DesignMatrix, y: &[f64], k: usize) -> Vec<usize> {
    let mut xty = vec![0.0; x.p()];
    x.xt_vec(y, &mut xty);
    let scores: Vec<f64> = xty.iter().map(|v| -v.abs()).collect();
    let mut cols = k_smallest_indices(&scores, k.min(x.p()));
    cols.sort_unstable();
    cols
}

/// Benchmark one working-set inner solve both ways: materialized copy of
/// `X_W` (the pre-refactor hot path) vs. a zero-copy [`DesignView`]. The
/// acceptance bar for the refactor is view ≤ materialized.
fn bench_ws_inner_solve(tag: &str, x: &DesignMatrix, y: &[f64], iters: usize) {
    let lambda = dual::lambda_max(x, y) / 20.0;
    let cols = top_correlated(x, y, 200);
    let norms = x.col_norms_sq();
    // Epoch-capped so both sides do identical, bounded work per iteration.
    let cfg = CdConfig { tol: 1e-12, max_epochs: 50, ..Default::default() };

    bench::time(&format!("hot/ws_inner_materialized_{tag}"), iters, || {
        let sub = x.select_columns(&cols);
        let out = cd_solve(&sub, y, lambda, None, &cfg);
        assert!(out.epochs > 0);
    });
    match x {
        DesignMatrix::Dense(d) => {
            bench::time(&format!("hot/ws_inner_view_{tag}"), iters, || {
                let view = DesignView::new(d, &cols, &norms);
                let out = cd_solve(&view, y, lambda, None, &cfg);
                assert!(out.epochs > 0);
            });
        }
        DesignMatrix::Sparse(s) => {
            bench::time(&format!("hot/ws_inner_view_{tag}"), iters, || {
                let view = DesignView::new(s, &cols, &norms);
                let out = cd_solve(&view, y, lambda, None, &cfg);
                assert!(out.epochs > 0);
            });
        }
        DesignMatrix::Ooc(o) => {
            bench::time(&format!("hot/ws_inner_view_{tag}"), iters, || {
                let view = DesignView::new(o, &cols, &norms);
                let out = cd_solve(&view, y, lambda, None, &cfg);
                assert!(out.epochs > 0);
            });
        }
        DesignMatrix::Sharded(sh) => {
            bench::time(&format!("hot/ws_inner_view_{tag}"), iters, || {
                let view = DesignView::new(sh, &cols, &norms);
                let out = cd_solve(&view, y, lambda, None, &cfg);
                assert!(out.epochs > 0);
            });
        }
    }
}

/// Benchmark a full λ path both ways: the sequential per-λ chain vs the
/// batched multi-λ engine (B lanes of interleaved CD over shared design
/// sweeps). The acceptance bar for the batch layer is batched ≤
/// sequential wall-clock at identical gap certification.
fn bench_batched_path(tag: &str, x: &DesignMatrix, y: &[f64], iters: usize) {
    let lmax = dual::lambda_max(x, y);
    let grid = lambda_grid(lmax, 0.1, 10);
    let tol = 1e-6;
    let seq = PathSolver::by_name("gapsafe-cd-accel", tol).unwrap();
    bench::time(&format!("hot/path_sequential_{tag}"), iters, || {
        let res = run_path(x, y, &grid, &seq, false);
        assert!(res.all_converged());
    });
    let bat = PathSolver::by_name("cd-batched", tol).unwrap();
    bench::time(&format!("hot/path_batched_{tag}"), iters, || {
        let res = run_path(x, y, &grid, &bat, false);
        assert!(res.all_converged());
    });
}

/// The pre-pool baseline: spawn + join fresh OS threads on every call
/// via `std::thread::scope` with static chunking — exactly what
/// `util::par` did before the persistent worker pool. Kept here so
/// `hot/pool_vs_scope_*` quantifies the spawn amortization.
fn scoped_xt_vec(x: &DesignMatrix, v: &[f64], out: &mut [f64]) {
    let threads = celer::util::par::num_threads();
    if threads <= 1 {
        for (j, o) in out.iter_mut().enumerate() {
            *o = x.col_dot(j, v);
        }
        return;
    }
    let chunk = out.len().div_ceil(threads).max(1);
    std::thread::scope(|s| {
        for (c, slice) in out.chunks_mut(chunk).enumerate() {
            s.spawn(move || {
                let base = c * chunk;
                for (k, o) in slice.iter_mut().enumerate() {
                    *o = x.col_dot(base + k, v);
                }
            });
        }
    });
}

/// Persistent pool vs per-call spawn on the gap-check scan (`xt_vec`).
/// The acceptance bar for the pool is pooled ≤ scoped at every size:
/// identical arithmetic, no spawn latency, warm caches.
fn bench_pool_vs_scope(tag: &str, x: &DesignMatrix, v: &[f64], iters: usize) {
    let p = x.p();
    let mut out = vec![0.0; p];
    bench::time(&format!("hot/pool_vs_scope_pooled_{tag}_p{p}"), iters, || {
        x.xt_vec(v, &mut out);
    });
    bench::time(&format!("hot/pool_vs_scope_scoped_{tag}_p{p}"), iters, || {
        scoped_xt_vec(x, v, &mut out);
    });
}

/// Fused one-pass kernels vs their separate-scan equivalents: the dual
/// rescale pair (Xᵀv, ‖Xᵀv‖_∞) and the KKT violation scan.
fn bench_fused_scans(tag: &str, x: &DesignMatrix, v: &[f64], iters: usize) {
    let p = x.p();
    let mut out = vec![0.0; p];
    bench::time(&format!("hot/fused_xt_absmax_{tag}_p{p}"), iters, || {
        let m = x.xt_vec_abs_max(v, &mut out);
        assert!(m >= 0.0);
    });
    bench::time(&format!("hot/separate_xt_absmax_{tag}_p{p}"), iters, || {
        x.xt_vec(v, &mut out);
        let m = out.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(m >= 0.0);
    });
    let beta = vec![0.0; p];
    let lambda = x.xt_abs_max(v) / 2.0;
    let mut viol = Vec::new();
    bench::time(&format!("hot/fused_kkt_scan_{tag}_p{p}"), iters, || {
        let m = celer::lasso::kkt::violations_with_max(x, v, &beta, lambda, &mut viol);
        assert!(m >= 0.0);
    });
    bench::time(&format!("hot/separate_kkt_scan_{tag}_p{p}"), iters, || {
        let vv = celer::lasso::kkt::violations(x, v, &beta, lambda);
        let m = celer::lasso::kkt::max_violation(x, v, &beta, lambda);
        assert!(m >= 0.0 && vv.len() == p);
    });
}

/// Multi-RHS column traffic in isolation: B separate `col_dot`s per
/// column vs one `col_dot_lanes` sweep that loads the column once.
fn bench_lane_ops(tag: &str, x: &DesignMatrix, iters: usize) {
    let n = x.n();
    let p = x.p();
    let b = 8;
    let mut rng = celer::util::rng::Rng::new(3);
    let v: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();
    let lanes: Vec<usize> = (0..b).collect();
    let mut out = vec![0.0; b];
    bench::time(&format!("hot/col_dot_perlane_{tag}_b{b}"), iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            for &k in &lanes {
                acc += x.col_dot(j, &v[k * n..(k + 1) * n]);
            }
        }
        assert!(acc.is_finite());
    });
    bench::time(&format!("hot/col_dot_lanes_{tag}_b{b}"), iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            x.col_dot_lanes(j, &v, n, &lanes, &mut out);
            acc += out[0];
        }
        assert!(acc.is_finite());
    });
}

/// Naive single-accumulator dot — the pre-SIMD baseline. The sequential
/// dependence on `acc` blocks autovectorization, which is exactly what
/// the `util::simd` multi-accumulator kernels fix; kept as a bench arm
/// so BENCH_6.json quantifies the kernel layer against it.
#[inline(never)]
fn scalar_dot(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Kernel-layer microbench: per-lane column traffic on a large dense
/// problem built in-bench (n=4096, p=256, B=8 — the residual set is
/// ~256 KiB, the design 8 MiB, so column loads dominate), three arms
/// per op: scalar single-accumulator baseline, unrolled simd kernel
/// called per lane, and the cache-blocked lane sweep.
fn bench_simd_lane_kernels(iters: usize) {
    let (n, p, b) = (4096usize, 256usize, 8usize);
    let mut rng = celer::util::rng::Rng::new(21);
    let data: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
    let x = DenseMatrix::from_col_major(n, p, data.clone());
    let v: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();
    let lanes: Vec<usize> = (0..b).collect();
    let mut out = vec![0.0; b];

    bench::time(&format!("hot/lanes_dot_scalar_dense_n{n}_b{b}"), iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            let col = &data[j * n..(j + 1) * n];
            for &k in &lanes {
                acc += scalar_dot(col, &v[k * n..(k + 1) * n]);
            }
        }
        assert!(acc.is_finite());
    });
    bench::time(&format!("hot/lanes_dot_simd_perlane_dense_n{n}_b{b}"), iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            for &k in &lanes {
                acc += x.col_dot(j, &v[k * n..(k + 1) * n]);
            }
        }
        assert!(acc.is_finite());
    });
    bench::time(&format!("hot/lanes_dot_blocked_dense_n{n}_b{b}"), iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            x.col_dot_lanes(j, &v, n, &lanes, &mut out);
            acc += out[0];
        }
        assert!(acc.is_finite());
    });

    // Tiny alternating alphas keep the accumulated buffer bounded over
    // the whole bench run without a per-iteration reset.
    let alphas: Vec<f64> = (0..b).map(|t| if t % 2 == 0 { 1e-9 } else { -1e-9 }).collect();
    let mut vm = v.clone();
    bench::time(&format!("hot/lanes_axpy_scalar_dense_n{n}_b{b}"), iters, || {
        for j in 0..p {
            let col = &data[j * n..(j + 1) * n];
            for (t, &k) in lanes.iter().enumerate() {
                let dst = &mut vm[k * n..(k + 1) * n];
                for i in 0..n {
                    dst[i] += alphas[t] * col[i];
                }
            }
        }
    });
    bench::time(&format!("hot/lanes_axpy_simd_perlane_dense_n{n}_b{b}"), iters, || {
        for j in 0..p {
            for (t, &k) in lanes.iter().enumerate() {
                x.col_axpy(j, alphas[t], &mut vm[k * n..(k + 1) * n]);
            }
        }
    });
    bench::time(&format!("hot/lanes_axpy_blocked_dense_n{n}_b{b}"), iters, || {
        for j in 0..p {
            x.col_axpy_lanes(j, &alphas, &mut vm, n, &lanes);
        }
    });
    assert!(vm.iter().all(|u| u.is_finite()));
}

/// f32 sweep epoch vs f64 epoch on the same large dense shape — the
/// memory-traffic half of the `Precision::F32` story (the design stream
/// is halved; certification cost is excluded on purpose, it amortizes
/// over `gap_freq` epochs).
fn bench_f32_epoch(iters: usize) {
    let (n, p) = (4096usize, 256usize);
    let mut rng = celer::util::rng::Rng::new(22);
    let data: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
    let x = DenseMatrix::from_col_major(n, p, data);
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let norms = x.col_norms_sq();
    let lambda = dual::lambda_max(&x, &y) / 10.0;

    let mut beta = vec![0.0f64; p];
    let mut r = y.clone();
    bench::time(&format!("hot/f64_cd_epoch_dense_n{n}_p{p}"), iters, || {
        for j in 0..p {
            let g = x.col_dot(j, &r);
            let old = beta[j];
            let new = soft_threshold(old + g / norms[j], lambda / norms[j]);
            if new != old {
                x.col_axpy(j, old - new, &mut r);
                beta[j] = new;
            }
        }
    });

    let shadow = x.shadow_f32();
    let norms32: Vec<f32> = norms.iter().map(|&v| v as f32).collect();
    let lam32 = lambda as f32;
    let mut beta32 = vec![0.0f32; p];
    let mut r32: Vec<f32> = y.iter().map(|&v| v as f32).collect();
    bench::time(&format!("hot/f32_cd_epoch_dense_n{n}_p{p}"), iters, || {
        for j in 0..p {
            let g = shadow.col_dot(j, &r32);
            let old = beta32[j];
            let new = celer::util::soft_threshold_f32(old + g / norms32[j], lam32 / norms32[j]);
            if new != old {
                shadow.col_axpy(j, old - new, &mut r32);
                beta32[j] = new;
            }
        }
    });
}

/// Legacy strided row-major multi-RHS column dot (the pre-refactor
/// `DesignOpsMt::col_dot_strided` shape), kept in the bench so
/// `mt/strided_vs_lanes_*` quantifies the kernel unification: q strided
/// dots per column over a row-major n×q matrix vs one `col_dot_lanes`
/// sweep over the lane-major layout (the column's values — and, for
/// CSC, its row indices — loaded and decoded once for all q tasks).
fn strided_col_dot(x: &DesignMatrix, j: usize, m: &[f64], q: usize, t: usize) -> f64 {
    match x {
        DesignMatrix::Dense(d) => {
            let mut acc = 0.0;
            for (i, &v) in d.col(j).iter().enumerate() {
                acc += v * m[i * q + t];
            }
            acc
        }
        DesignMatrix::Sparse(sp) => {
            let (idx, val) = sp.col(j);
            let mut acc = 0.0;
            for k in 0..idx.len() {
                acc += val[k] * m[idx[k] as usize * q + t];
            }
            acc
        }
        DesignMatrix::Ooc(o) => o.with_col(j, |idx, val| {
            let mut acc = 0.0;
            for k in 0..idx.len() {
                acc += val[k] * m[idx[k] as usize * q + t];
            }
            acc
        }),
        DesignMatrix::Sharded(sh) => sh.with_col(j, |idx, val| {
            let mut acc = 0.0;
            for k in 0..idx.len() {
                acc += val[k] * m[idx[k] as usize * q + t];
            }
            acc
        }),
    }
}

/// Multi-task design traffic: per-(column, task) strided dots vs one
/// multi-RHS lane sweep per column.
fn bench_mt_kernels(tag: &str, x: &DesignMatrix, iters: usize) {
    let n = x.n();
    let p = x.p();
    let q = 8;
    let mut rng = celer::util::rng::Rng::new(9);
    let m_row: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect(); // row-major n×q
    let mut m_lanes = Vec::new();
    celer::multitask::rowmajor_to_lanes(&m_row, n, q, &mut m_lanes);
    let lanes: Vec<usize> = (0..q).collect();
    let mut out = vec![0.0; q];
    bench::time(&format!("mt/strided_vs_lanes_{tag}_strided_q{q}"), iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            for t in 0..q {
                acc += strided_col_dot(x, j, &m_row, q, t);
            }
        }
        assert!(acc.is_finite());
    });
    bench::time(&format!("mt/strided_vs_lanes_{tag}_lanes_q{q}"), iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            x.col_dot_lanes(j, &m_lanes, n, &lanes, &mut out);
            acc += out[0];
        }
        assert!(acc.is_finite());
    });
}

/// MT working-set inner solve both ways: materialized `select_columns`
/// copy (the pre-refactor MT hot path) vs a zero-copy [`DesignView`],
/// epoch-capped so both sides do identical bounded work per iteration.
fn bench_mt_inner_solve(tag: &str, x: &DesignMatrix, iters: usize) {
    let n = x.n();
    let q = 4;
    let mut rng = celer::util::rng::Rng::new(13);
    let y_row: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
    let mut y_lanes = Vec::new();
    celer::multitask::rowmajor_to_lanes(&y_row, n, q, &mut y_lanes);
    // a realistic working set: columns most correlated with task 0
    let cols = top_correlated(x, &y_lanes[..n], 200);
    let norms = x.col_norms_sq();
    let lambda = mt_lambda_max(x, &y_row, q) / 20.0;
    let cfg = EngineConfig {
        tol: 1e-12,
        max_epochs: 50,
        gap_freq: 10,
        k: 5,
        extrapolate: true,
        best_dual: true,
        screen: false,
        trace: false,
        stop: StopRule::DualityGap,
        ..EngineConfig::default()
    };
    let mut ws = BlockWorkspace::new();
    bench::time(&format!("mt/ws_inner_materialized_{tag}"), iters, || {
        let sub = x.select_columns(&cols);
        let out = solve_blocks(
            &sub,
            &y_lanes,
            q,
            lambda,
            Init::Zeros,
            None,
            &cfg,
            &mut ws,
            &mut BlockCdStrategy,
        );
        assert!(out.epochs > 0);
    });
    bench::time(&format!("mt/ws_inner_view_{tag}"), iters, || {
        let view = DesignView::new(x, &cols, &norms);
        let out = solve_blocks(
            &view,
            &y_lanes,
            q,
            lambda,
            Init::Zeros,
            None,
            &cfg,
            &mut ws,
            &mut BlockCdStrategy,
        );
        assert!(out.epochs > 0);
    });
}

/// Sparse-GLM hot paths: one CELER-logreg working-set solve vs the
/// full-design prox-Newton reference, per storage kind. The acceptance
/// bar mirrors the quadratic story — the WS solve should not lose to the
/// full sweep once the support is sparse.
fn bench_glm(tag: &str, x: &DesignMatrix, y_raw: &[f64], iters: usize) {
    use celer::datafit::Logistic;
    use celer::solvers::celer::CelerConfig;
    use celer::solvers::glm::{glm_cd_solve, logreg_lambda_max, sparse_logreg_solve};
    let y = synth::sign_labels(y_raw);
    let lambda = logreg_lambda_max(x, &y) / 10.0;
    let tol = 1e-6;
    bench::time(&format!("glm/logreg_ws_{tag}"), iters, || {
        let out = sparse_logreg_solve(
            x,
            &y,
            lambda,
            None,
            &CelerConfig { tol, ..Default::default() },
        );
        assert!(out.result.converged);
    });
    bench::time(&format!("glm/logreg_full_{tag}"), iters, || {
        let out = glm_cd_solve(
            x,
            &y,
            lambda,
            None,
            &Logistic,
            &celer::solvers::cd::CdConfig { tol, screen: true, ..Default::default() },
        );
        assert!(out.converged);
    });
}

fn main() {
    let full = bench::full_scale();
    let sparse = if full { synth::finance_sim(0) } else { synth::finance_mini(0) };
    let dense = if full { synth::leukemia_sim(0) } else { synth::leukemia_mini(0) };
    let iters = if full { 5 } else { 20 };

    // --- sparse CD epoch (the dominant inner-loop cost) ---
    {
        let x = &sparse.x;
        let p = x.p();
        let norms = x.col_norms_sq();
        let lambda = dual::lambda_max(x, &sparse.y) / 10.0;
        let mut beta = vec![0.0; p];
        let mut r = sparse.y.clone();
        bench::time(&format!("hot/sparse_cd_epoch_nnz{}", x.nnz()), iters, || {
            for j in 0..p {
                let nrm = norms[j];
                if nrm == 0.0 {
                    continue;
                }
                let g = x.col_dot(j, &r);
                let old = beta[j];
                let new = soft_threshold(old + g / nrm, lambda / nrm);
                if new != old {
                    x.col_axpy(j, old - new, &mut r);
                    beta[j] = new;
                }
            }
        });
    }

    // --- dense CD epoch ---
    {
        let x = &dense.x;
        let (n, p) = (x.n(), x.p());
        let _ = n;
        let norms = x.col_norms_sq();
        let lambda = dual::lambda_max(x, &dense.y) / 10.0;
        let mut beta = vec![0.0; p];
        let mut r = dense.y.clone();
        bench::time(&format!("hot/dense_cd_epoch_p{p}"), iters, || {
            for j in 0..p {
                let g = x.col_dot(j, &r);
                let old = beta[j];
                let new = soft_threshold(old + g / norms[j], lambda / norms[j]);
                if new != old {
                    x.col_axpy(j, old - new, &mut r);
                    beta[j] = new;
                }
            }
        });
    }

    // --- penalty-trait epochs (prox dispatch vs the hardcoded ST) ---
    bench_prox_epochs("dense", &dense.x, &dense.y, iters);

    // --- full Xᵀv scan (gap/screening cost, parallelized) ---
    {
        let x = &sparse.x;
        let mut out = vec![0.0; x.p()];
        bench::time("hot/sparse_xt_vec", iters, || {
            x.xt_vec(&sparse.y, &mut out);
        });
        bench::time("hot/sparse_xt_abs_max", iters, || {
            let m = x.xt_abs_max(&sparse.y);
            assert!(m > 0.0);
        });
    }

    // --- working-set selection over p scores ---
    {
        let p = sparse.x.p();
        let mut rng = celer::util::rng::Rng::new(1);
        let scores: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        bench::time(&format!("hot/ws_select_k_smallest_p{p}"), iters, || {
            let ws = k_smallest_indices(&scores, 200);
            assert_eq!(ws.len(), 200);
        });
    }

    // --- working-set gather (sub-design materialization) ---
    {
        let x = &sparse.x;
        let cols: Vec<usize> = (0..200.min(x.p())).collect();
        bench::time("hot/ws_select_columns", iters, || {
            let sub = x.select_columns(&cols);
            assert_eq!(sub.p(), cols.len());
        });
    }

    // --- working-set inner solve: materialized copy vs zero-copy view ---
    // (the CELER/Blitz hot path; the view must be at least as fast)
    bench_ws_inner_solve("dense", &dense.x, &dense.y, iters);
    bench_ws_inner_solve("sparse", &sparse.x, &sparse.y, iters);

    // --- persistent pool vs per-call spawn + fused vs separate scans ---
    // (small and large p, dense and sparse: the spawn amortization and
    // scan fusion are the pool PR's headline quantities)
    {
        let small_dense = synth::leukemia_mini(7); // p = 500
        let large_dense = synth::leukemia_sim(7); // p = 7129
        for (tag, ds) in [("dense_small", &small_dense), ("dense_large", &large_dense)] {
            bench_pool_vs_scope(tag, &ds.x, &ds.y, iters);
            bench_fused_scans(tag, &ds.x, &ds.y, iters);
        }
        let small_sparse = synth::finance_mini(7); // p = 2000
        bench_pool_vs_scope("sparse_small", &small_sparse.x, &small_sparse.y, iters);
        bench_fused_scans("sparse_small", &small_sparse.x, &small_sparse.y, iters);
        // Large-p CSC whose scan clears the sparse work model
        // (p × mean-nnz ≈ 32768 × 13 ≥ the parallel threshold).
        let large_sparse = synth::sparse_scan_stress(7);
        bench_pool_vs_scope("sparse_large", &large_sparse.x, &large_sparse.y, iters);
        bench_fused_scans("sparse_large", &large_sparse.x, &large_sparse.y, iters);
        if full {
            bench_pool_vs_scope("sparse_full", &sparse.x, &sparse.y, iters);
            bench_fused_scans("sparse_full", &sparse.x, &sparse.y, iters);
        }
    }

    // --- multi-RHS column traffic: per-lane col_dot vs one lane sweep ---
    bench_lane_ops("dense", &dense.x, iters);
    bench_lane_ops("sparse", &sparse.x, iters);

    // --- kernel layer: scalar baseline vs unrolled simd vs blocked lane
    // sweeps, plus the f32 sweep epoch (the BENCH_6 headline arms) ---
    bench_simd_lane_kernels(iters);
    bench_f32_epoch(iters);

    // --- multi-task block kernels: legacy strided row-major dots vs the
    // unified lane sweep, and materialized vs view MT inner solves ---
    bench_mt_kernels("dense", &dense.x, iters);
    bench_mt_kernels("sparse", &sparse.x, iters);
    bench_mt_inner_solve("dense", &dense.x, iters);
    bench_mt_inner_solve("sparse", &sparse.x, iters);

    // --- full λ path: sequential chain vs batched multi-λ engine ---
    // (the batch layer's headline quantity, dense and CSC)
    bench_batched_path("dense", &dense.x, &dense.y, iters.min(5));
    bench_batched_path("sparse", &sparse.x, &sparse.y, iters.min(5));

    // --- sparse GLM (logistic) working-set vs full prox-Newton ---
    bench_glm("dense", &dense.x, &dense.y, iters.min(5));
    bench_glm("sparse", &sparse.x, &sparse.y, iters.min(5));

    // --- extrapolation solve (K = 5) ---
    {
        let n = sparse.x.n();
        let mut rng = celer::util::rng::Rng::new(2);
        let mut buf = ResidualBuffer::new(5);
        for _ in 0..6 {
            let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            buf.push(&r);
        }
        bench::time("hot/extrapolate_k5", iters, || {
            let out = buf.extrapolate();
            assert!(out.is_some());
        });
    }
}
