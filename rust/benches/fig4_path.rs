//! Bench: Figure 4 — 100-λ Lasso path on the Finance-like dataset:
//! CELER (prune/safe) vs BLITZ at ε = 1e-6.

use celer::coordinator;
use celer::data::synth;
use celer::report::bench;
use celer::solvers::path::{run_path, PathSolver};

fn main() {
    let full = bench::full_scale();
    let ds = if full { synth::finance_sim(0) } else { synth::finance_mini(0) };
    let num = if full { 100 } else { 25 };
    let grid = coordinator::standard_grid(&ds, 100.0, num);
    let iters = if full { 1 } else { 3 };

    let mut mins = Vec::new();
    for name in ["celer-prune", "celer-safe", "blitz"] {
        let solver = PathSolver::by_name(name, 1e-6).unwrap();
        let t = bench::time(&format!("fig4/path_{name}"), iters, || {
            let res = run_path(&ds.x, &ds.y, &grid, &solver, false);
            assert!(res.all_converged(), "{name}");
        });
        mins.push((name, t.min_s));
    }
    println!(
        "fig4 blitz/celer-prune: {:.2}× (paper: CELER wins at every ε)",
        mins[2].1 / mins[0].1.max(1e-12)
    );
}
