//! Streaming-sweep benchmark for the out-of-core column store
//! (BENCH_9): disk → chunk cache → lane kernels.
//!
//! Three questions, three arms:
//!
//! 1. `ooc/stream_dot_1lane` — sweep every column once with a
//!    single-RHS `col_dot`, cache sized BELOW the chunk count so every
//!    sweep re-streams the store from disk (the prefetcher overlaps the
//!    next chunk with the current sweep). This is the per-lane cost of
//!    an unbatched pass.
//! 2. `ooc/stream_dot_lanes_b8` — the same disk traffic serving B = 8
//!    λ-lanes per fetched column (`col_dot_lanes`). The measured
//!    **amortization factor** is `B · t(1-lane) / t(B-lane)`: how many
//!    of the B lanes ride for free on one fetch. Acceptance bar for
//!    PR 9 is ≥ B/2.
//! 3. `ooc/stream_axpy_lanes_b8` — the write-side lane kernel over the
//!    same stream.
//!
//! Besides the standard `bench ...` lines, each configuration emits one
//! machine-readable `stream <name> k=v ...` line (same shape as the gcc
//! proxy's `proxy ...` lines) with bytes/s, columns/s and the
//! amortization factor — `scripts/bench_export.sh --pr 9` parses these
//! into BENCH_9.json.

use celer::data::csc::CscMatrix;
use celer::data::design::DesignOps;
use celer::data::ooc::{self, OocColumnStore};
use celer::report::bench;
use celer::util::rng::Rng;

const B: usize = 8;

struct Shape {
    tag: &'static str,
    n: usize,
    p: usize,
    density: f64,
    iters: usize,
}

fn build_store(shape: &Shape, path: &std::path::Path) -> (OocColumnStore, usize) {
    let mut rng = Rng::new(9);
    let mut dense = vec![0.0; shape.n * shape.p];
    for v in dense.iter_mut() {
        if rng.uniform() < shape.density {
            *v = rng.normal();
        }
    }
    let csc = CscMatrix::from_dense(shape.n, shape.p, &dense);
    let y: Vec<f64> = (0..shape.n).map(|_| rng.normal()).collect();
    let nnz = csc.nnz();
    ooc::write_store(path, &csc, &y).expect("write bench store");
    // Chunks sized so the store spans many chunks, cache held to 3 — a
    // full sweep cannot be resident, so every iteration streams from
    // disk (page cache) through the prefetch pipeline.
    let chunk_bytes = (nnz * 12 / 64).max(4096);
    let store = OocColumnStore::open_with(path, chunk_bytes, 3).expect("open bench store");
    assert!(store.nchunks() > 6, "want a genuinely chunked stream");
    (store, nnz)
}

fn run_shape(shape: &Shape) {
    let path = std::env::temp_dir()
        .join(format!("celer_ooc_bench_{}_{}.cstore", std::process::id(), shape.tag));
    let (store, nnz) = build_store(shape, &path);
    let (n, p) = (shape.n, shape.p);
    let mut rng = Rng::new(11);
    let v: Vec<f64> = (0..B * n).map(|_| rng.normal()).collect();
    let lanes: Vec<usize> = (0..B).collect();
    let alphas: Vec<f64> = (0..B).map(|t| 1e-9 * (t as f64 + 1.0)).collect();

    // Arm 1: one lane per fetched column.
    let mut sink = 0.0f64;
    let t1 = bench::time(&format!("ooc/stream_dot_1lane_{}", shape.tag), shape.iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            acc += store.col_dot(j, &v[..n]);
        }
        sink += acc;
    });

    // Arm 2: B lanes per fetched column — same disk traffic.
    let mut out = vec![0.0f64; B];
    let tb = bench::time(&format!("ooc/stream_dot_lanes_b{B}_{}", shape.tag), shape.iters, || {
        let mut acc = 0.0;
        for j in 0..p {
            store.col_dot_lanes(j, &v, n, &lanes, &mut out);
            acc += out[0];
        }
        sink += acc;
    });

    // Arm 3: the write-side lane kernel (tiny alphas keep v finite).
    let mut vw = v.clone();
    let ta = bench::time(&format!("ooc/stream_axpy_lanes_b{B}_{}", shape.tag), shape.iters, || {
        for j in 0..p {
            store.col_axpy_lanes(j, &alphas, &mut vw, n, &lanes);
        }
    });
    sink += vw[0];
    assert!(sink.is_finite());

    // One sweep touches every stored entry once: 12 bytes (u32 idx +
    // f64 value) per entry of logical stream traffic.
    let sweep_bytes = (nnz * 12) as f64;
    let amort = B as f64 * t1.min_s / tb.min_s;
    let io = store.io_stats();
    println!(
        "stream ooc_stream_sweep_{} n={} p={} b={B} iters={} min_ns={:.0} \
         bytes_per_s={:.3e} cols_per_s={:.3e} amort={:.2}",
        shape.tag,
        n,
        p,
        tb.iters,
        tb.min_s * 1e9,
        sweep_bytes / tb.min_s,
        p as f64 / tb.min_s,
        amort,
    );
    println!(
        "stream ooc_stream_axpy_{} n={} p={} b={B} iters={} min_ns={:.0} \
         bytes_per_s={:.3e} cols_per_s={:.3e} amort={:.2}",
        shape.tag,
        n,
        p,
        ta.iters,
        ta.min_s * 1e9,
        sweep_bytes / ta.min_s,
        p as f64 / ta.min_s,
        B as f64 * t1.min_s / ta.min_s,
    );
    println!(
        "# ooc io counters {}: bytes_read={} chunks_loaded={} sync_misses={} \
         prefetch_loads={} prefetch_hits={} bytes_prefetched={}",
        shape.tag,
        io.bytes_read,
        io.chunks_loaded,
        io.sync_misses,
        io.prefetch_loads,
        io.prefetch_hits,
        io.bytes_prefetched,
    );
    let _ = std::fs::remove_file(&path);
}

fn main() {
    let shapes: &[Shape] = if bench::full_scale() {
        &[
            Shape { tag: "n4096_p65536", n: 4096, p: 65536, density: 0.02, iters: 10 },
            Shape { tag: "n512_p262144", n: 512, p: 262144, density: 0.05, iters: 10 },
        ]
    } else {
        &[Shape { tag: "n512_p16384", n: 512, p: 16384, density: 0.05, iters: 12 }]
    };
    for s in shapes {
        run_shape(s);
    }
}
