//! Bench: Figure 1 — Dykstra in the Lasso dual (cyclic vs shuffle) and
//! the extrapolated convergence curve on the 2×2 toy.

use celer::data::synth;
use celer::lasso::dual;
use celer::report::bench;
use celer::solvers::dykstra::{dual_suboptimality_curves, dykstra_lasso_dual, Order};

fn main() {
    let ds = synth::toy_2x2();
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 4.0;
    let epochs = if bench::full_scale() { 200 } else { 40 };

    bench::time("fig1/dykstra_cyclic", 20, || {
        let out = dykstra_lasso_dual(&ds.x, &ds.y, lambda, epochs, Order::Cyclic);
        assert_eq!(out.theta_per_epoch.len(), epochs);
    });
    bench::time("fig1/dykstra_shuffle", 20, || {
        let out =
            dykstra_lasso_dual(&ds.x, &ds.y, lambda, epochs, Order::Shuffle { seed: 1 });
        assert_eq!(out.theta_per_epoch.len(), epochs);
    });
    bench::time("fig1/suboptimality_curves_k4", 10, || {
        let (plain, accel) =
            dual_suboptimality_curves(&ds.x, &ds.y, lambda, epochs, Order::Cyclic, 4, 20_000);
        // the paper's machine-precision claim, asserted on every run
        assert!(accel[6] < 1e-10 || accel[6] < plain[6] * 1e-3);
    });
}
