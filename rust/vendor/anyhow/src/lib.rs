//! Offline, dependency-free subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses — [`Result`],
//! [`Error`], `anyhow!`, `bail!`, `ensure!` — with the same semantics:
//! an opaque error carrying a message, `?`-convertible from any
//! `std::error::Error`. Like the real crate, [`Error`] deliberately does
//! NOT implement `std::error::Error` (that would conflict with the
//! blanket `From` impl); it implements `Debug` + `Display`, which is all
//! `fn main() -> anyhow::Result<()>` and error printing need.

use std::fmt;

/// Opaque error: a message plus (optionally) the `Display` rendering of a
/// source error chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prefix the message with more context (poor man's `.context()`).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/470ab2")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let e = anyhow!("inline {v}", v = 7);
        assert_eq!(e.to_string(), "inline 7");

        fn bails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            ensure!(flag);
            if flag {
                Ok(1)
            } else {
                bail!("unreachable {}", 0)
            }
        }
        assert_eq!(bails(true).unwrap(), 1);
        assert_eq!(bails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn context_prefixes() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
