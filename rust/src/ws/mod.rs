//! Working-set construction policies (paper §4 and Appendix A.2).
//!
//! Features are ranked by the Gap-Safe score `d_j(θ)` (smaller = more
//! important) and the `p_t` smallest form the working set `W_t`.
//! Growth policies:
//!
//! - **safe** (monotone doubling): `p_t = min(2·p_{t-1}, p)`, with
//!   `W_{t-1} ⊆ W_t` forced by setting `d_j = −1` for j ∈ W_{t-1};
//! - **prune**: `p_t = min(2·|S_{β^{t-1}}|, p)`, with only the current
//!   support forced in (`d_j = −1` for j ∈ S_{β^{t-1}}`) — the WS can
//!   shrink if the support is small;
//! - plus the ablation policies of Appendix A.2: geometric growth with
//!   factor γ and linear growth `p_t = min(γ + |S|, p)`.
//!
//! The score vector handed to [`build_working_set`] is produced by
//! [`crate::screening::fill_d_scores`] from the cached `Xᵀθ` of the
//! gap check — on the pool-backed runtime the whole
//! gap-check → dual-rescale → price → build sequence therefore touches
//! the design exactly once (the fused `xt_vec_abs_max` pass); selection
//! itself is O(p) on cached scores.
//!
//! The machinery is block-width agnostic: the Multi-Task outer loop
//! (paper §7, [`crate::multitask::solver`]) feeds the same
//! [`build_working_set`] with the block d-scores
//! `d_j(Θ) = (1 − ‖x_jᵀΘ‖₂)/‖x_j‖` (row norms in place of `|x_jᵀθ|`,
//! from the fused block pass of
//! [`crate::solvers::block::xt_rows_max`]) — scores are scores.

use crate::util::select::k_smallest_indices;

/// Default initial working-set size (paper: p₁ = 100).
pub const DEFAULT_P1: usize = 100;

/// How the working-set size evolves between outer iterations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GrowthPolicy {
    /// `p_t = min(γ · base, p)` where base is |S| (prune) or p_{t-1} (safe).
    Geometric { factor: usize },
    /// `p_t = min(γ + |S_{β^{t-1}}|, p)` (Appendix A.2, Eq. 16).
    Linear { increment: usize },
}

/// Full working-set policy.
#[derive(Debug, Clone, Copy)]
pub struct WsPolicy {
    /// Initial size p₁ (used when no warm start is given).
    pub p1: usize,
    pub growth: GrowthPolicy,
    /// Pruning variant (Eq. 14): base the size on the support, allow
    /// shrinking. When false, the safe monotone variant is used.
    pub prune: bool,
}

impl Default for WsPolicy {
    fn default() -> Self {
        WsPolicy { p1: DEFAULT_P1, growth: GrowthPolicy::Geometric { factor: 2 }, prune: true }
    }
}

impl WsPolicy {
    /// Paper's safe (monotone, non-pruning) variant.
    pub fn safe() -> Self {
        WsPolicy { prune: false, ..Default::default() }
    }

    /// Next working-set size.
    ///
    /// `t` is the 1-based outer-iteration index; `prev_size` = |W_{t-1}|,
    /// `support_size` = |S_{β^{t-1}}|, `p` the feature count.
    pub fn next_size(&self, t: usize, prev_size: usize, support_size: usize, p: usize) -> usize {
        if t <= 1 {
            return self.p1.min(p).max(1);
        }
        let size = match (self.growth, self.prune) {
            (GrowthPolicy::Geometric { factor }, true) => factor * support_size.max(1),
            (GrowthPolicy::Geometric { factor }, false) => factor * prev_size.max(1),
            (GrowthPolicy::Linear { increment }, _) => increment + support_size,
        };
        size.clamp(1, p)
    }
}

/// Build the working set: the `pt` features with smallest scores, with the
/// features in `forced` guaranteed membership (their score is overridden
/// to −1, matching Algorithm 4's monotonicity trick).
///
/// Features with a non-finite score are **excluded** no matter how large
/// `pt` is: `d_j(θ) = +∞` marks an empty column (zero norm), which can
/// never enter the model. Centralizing the exclusion here means callers
/// rank with raw [`crate::screening::d_score`] values and never
/// special-case infinities.
///
/// `scores` is modified in place (forced entries set to −1.0). The result
/// is sorted in increasing index order.
pub fn build_working_set(scores: &mut [f64], forced: &[usize], pt: usize) -> Vec<usize> {
    for &j in forced {
        scores[j] = -1.0;
    }
    // Every finite score sorts before +∞, so capping the selection count
    // at the number of finite scores keeps empty columns out entirely.
    let n_selectable = scores.iter().filter(|s| s.is_finite()).count();
    let mut ws = k_smallest_indices(scores, pt.min(n_selectable));
    ws.sort_unstable();
    ws
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_iteration_uses_p1() {
        let pol = WsPolicy::default();
        assert_eq!(pol.next_size(1, 0, 0, 1000), 100);
        assert_eq!(pol.next_size(1, 0, 0, 30), 30, "clamped to p");
    }

    #[test]
    fn prune_follows_support() {
        let pol = WsPolicy::default(); // geometric x2, prune
        assert_eq!(pol.next_size(2, 400, 25, 1000), 50);
        // support can shrink the WS (the pruning point of Fig. 9)
        assert_eq!(pol.next_size(3, 50, 5, 1000), 10);
        // and grows quickly when support is large
        assert_eq!(pol.next_size(4, 10, 600, 1000), 1000);
    }

    #[test]
    fn safe_doubles_monotonically() {
        let pol = WsPolicy::safe();
        assert_eq!(pol.next_size(2, 100, 3, 10_000), 200);
        assert_eq!(pol.next_size(3, 200, 3, 10_000), 400);
        assert_eq!(pol.next_size(9, 8000, 3, 10_000), 10_000);
    }

    #[test]
    fn linear_policy() {
        let pol = WsPolicy {
            p1: 10,
            growth: GrowthPolicy::Linear { increment: 50 },
            prune: false,
        };
        assert_eq!(pol.next_size(2, 10, 7, 1000), 57);
    }

    #[test]
    fn geometric_factor_4() {
        let pol = WsPolicy {
            p1: 10,
            growth: GrowthPolicy::Geometric { factor: 4 },
            prune: true,
        };
        assert_eq!(pol.next_size(2, 10, 30, 1000), 120);
    }

    #[test]
    fn empty_support_still_progresses() {
        let pol = WsPolicy::default();
        // support empty (all-zero beta): size must stay >= 1 so the solver
        // cannot stall
        assert!(pol.next_size(2, 100, 0, 1000) >= 1);
    }

    #[test]
    fn build_ws_forces_members_and_sorts() {
        let mut scores = vec![0.9, 0.1, 0.5, 0.2, 0.8];
        let ws = build_working_set(&mut scores, &[4], 3);
        assert_eq!(ws.len(), 3);
        assert!(ws.contains(&4), "forced member included");
        assert!(ws.contains(&1), "best score included");
        assert!(ws.windows(2).all(|w| w[0] < w[1]), "sorted");
    }

    #[test]
    fn build_ws_caps_at_p() {
        let mut scores = vec![0.3, 0.1];
        let ws = build_working_set(&mut scores, &[], 10);
        assert_eq!(ws, vec![0, 1]);
    }

    #[test]
    fn build_ws_excludes_empty_columns() {
        // d_score of a zero-norm column is +∞; it must never be selected,
        // even when pt exceeds the number of usable features.
        let mut scores = vec![0.9, f64::INFINITY, 0.1, f64::INFINITY, 0.5];
        let ws = build_working_set(&mut scores, &[], 5);
        assert_eq!(ws, vec![0, 2, 4]);
        // forced members are still honored alongside the exclusion
        let mut scores = vec![0.9, f64::INFINITY, 0.1, f64::INFINITY, 0.5];
        let ws = build_working_set(&mut scores, &[0], 2);
        assert!(ws.contains(&0));
        assert!(ws.contains(&2));
        assert_eq!(ws.len(), 2);
        // degenerate: everything empty → empty working set
        let mut scores = vec![f64::INFINITY; 4];
        assert!(build_working_set(&mut scores, &[], 4).is_empty());
    }
}
