//! Compute-engine abstraction over the inner-solver numerics.
//!
//! Two interchangeable backends execute the same four operations:
//! - [`NativeEngine`] — pure Rust (any shape, production hot path);
//! - [`super::xla_exec::XlaEngine`] — AOT HLO artifacts via PJRT
//!   (fixed shape buckets, zero-padded by the router).
//!
//! [`engine_cd_solve`] is Algorithm 1 written *entirely against the
//! engine interface*: every numeric step (CD epochs, dual rescaling,
//! extrapolation, gap) goes through engine calls, so running it with the
//! XLA engine exercises the full AOT request path end-to-end.

use crate::util::soft_threshold;

/// Dense, column-major design-block numerics.
pub trait Engine {
    fn name(&self) -> &'static str;

    /// `f` cyclic CD epochs on the (n, w) block. `x_cm` is column-major
    /// (w contiguous columns of length n). Returns (β, r).
    fn inner_solve(
        &mut self,
        x_cm: &[f64],
        n: usize,
        w: usize,
        y: &[f64],
        beta: &[f64],
        lambda: f64,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>)>;

    /// (P(β), D(θ), gap, d-scores) on the (n, p) design.
    fn gap_scores(
        &mut self,
        x_cm: &[f64],
        n: usize,
        p: usize,
        y: &[f64],
        beta: &[f64],
        theta: &[f64],
        lambda: f64,
    ) -> anyhow::Result<(f64, f64, f64, Vec<f64>)>;

    /// θ_res = r / max(λ, ‖Xᵀr‖_∞) and the correlations Xᵀθ.
    fn theta_res(
        &mut self,
        x_cm: &[f64],
        n: usize,
        p: usize,
        r: &[f64],
        lambda: f64,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>)>;

    /// Dual extrapolation from the (k+1, n) row-major residual buffer.
    /// Returns (r_accel, min_pivot); min_pivot ≤ tol ⇒ caller falls back.
    fn extrapolate(
        &mut self,
        rbuf: &[f64],
        k: usize,
        n: usize,
    ) -> anyhow::Result<(Vec<f64>, f64)>;
}

/// Pure-Rust engine (reference + production).
#[derive(Debug, Default)]
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn inner_solve(
        &mut self,
        x_cm: &[f64],
        n: usize,
        w: usize,
        y: &[f64],
        beta: &[f64],
        lambda: f64,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
        anyhow::ensure!(x_cm.len() == n * w);
        let mut beta = beta.to_vec();
        // r = y − Xβ
        let mut r = y.to_vec();
        for j in 0..w {
            if beta[j] != 0.0 {
                let col = &x_cm[j * n..(j + 1) * n];
                for i in 0..n {
                    r[i] -= beta[j] * col[i];
                }
            }
        }
        let norms_sq: Vec<f64> = (0..w)
            .map(|j| crate::util::linalg::dot(&x_cm[j * n..(j + 1) * n], &x_cm[j * n..(j + 1) * n]))
            .collect();
        for _ in 0..10 {
            for j in 0..w {
                let nrm = norms_sq[j];
                if nrm == 0.0 {
                    continue;
                }
                let col = &x_cm[j * n..(j + 1) * n];
                let g = crate::util::linalg::dot(col, &r);
                let old = beta[j];
                let new = soft_threshold(old + g / nrm, lambda / nrm);
                if new != old {
                    crate::util::linalg::axpy(old - new, col, &mut r);
                    beta[j] = new;
                }
            }
        }
        Ok((beta, r))
    }

    fn gap_scores(
        &mut self,
        x_cm: &[f64],
        n: usize,
        p: usize,
        y: &[f64],
        beta: &[f64],
        theta: &[f64],
        lambda: f64,
    ) -> anyhow::Result<(f64, f64, f64, Vec<f64>)> {
        anyhow::ensure!(x_cm.len() == n * p);
        let mut r = y.to_vec();
        for j in 0..p {
            if beta[j] != 0.0 {
                let col = &x_cm[j * n..(j + 1) * n];
                for i in 0..n {
                    r[i] -= beta[j] * col[i];
                }
            }
        }
        let primal = crate::lasso::primal::primal_from_residual(&r, beta, lambda);
        let dual = crate::lasso::dual::dual_objective(y, theta, lambda);
        let mut d = vec![0.0; p];
        for j in 0..p {
            let col = &x_cm[j * n..(j + 1) * n];
            let norm = crate::util::linalg::dot(col, col).sqrt();
            if norm > 0.0 {
                d[j] = (1.0 - crate::util::linalg::dot(col, theta).abs()) / norm;
            } else {
                d[j] = crate::runtime::EMPTY_COL_SCORE;
            }
        }
        Ok((primal, dual, primal - dual, d))
    }

    fn theta_res(
        &mut self,
        x_cm: &[f64],
        n: usize,
        p: usize,
        r: &[f64],
        lambda: f64,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
        anyhow::ensure!(x_cm.len() == n * p);
        let mut xtr = vec![0.0; p];
        for j in 0..p {
            xtr[j] = crate::util::linalg::dot(&x_cm[j * n..(j + 1) * n], r);
        }
        let denom = xtr.iter().fold(lambda, |m, v| m.max(v.abs()));
        let theta: Vec<f64> = r.iter().map(|&v| v / denom).collect();
        for v in xtr.iter_mut() {
            *v /= denom;
        }
        Ok((theta, xtr))
    }

    fn extrapolate(&mut self, rbuf: &[f64], k: usize, n: usize) -> anyhow::Result<(Vec<f64>, f64)> {
        anyhow::ensure!(rbuf.len() == (k + 1) * n);
        // Gram of consecutive diffs; unpivoted elimination tracking the
        // min pivot — byte-compatible with the L2 graph (model.extrapolate).
        let diffs: Vec<Vec<f64>> = (0..k)
            .map(|i| {
                let (a, b) = (&rbuf[i * n..(i + 1) * n], &rbuf[(i + 1) * n..(i + 2) * n]);
                (0..n).map(|t| b[t] - a[t]).collect()
            })
            .collect();
        let cols: Vec<&[f64]> = diffs.iter().map(|d| d.as_slice()).collect();
        let mut g = crate::util::linalg::gram(&cols);
        let mut b = vec![1.0; k];
        let mut min_piv = f64::INFINITY;
        for col in 0..k {
            let piv = g[col * k + col];
            min_piv = min_piv.min(piv);
            let safe = if piv.abs() > 0.0 { piv } else { 1.0 };
            for row in (col + 1)..k {
                let f = g[row * k + col] / safe;
                if f != 0.0 {
                    for c in col..k {
                        g[row * k + c] -= f * g[col * k + c];
                    }
                    b[row] -= f * b[col];
                }
            }
        }
        let mut z = vec![0.0; k];
        for row in (0..k).rev() {
            let mut acc = b[row];
            for c in (row + 1)..k {
                acc -= g[row * k + c] * z[c];
            }
            let piv = g[row * k + row];
            z[row] = acc / if piv.abs() > 0.0 { piv } else { 1.0 };
        }
        let s: f64 = z.iter().sum();
        let min_piv = if s.abs() > 1e-300 { min_piv } else { 0.0 };
        let safe_s = if s.abs() > 0.0 { s } else { 1.0 };
        let mut r_accel = vec![0.0; n];
        for i in 0..k {
            let c = z[i] / safe_s;
            let newer = &rbuf[(i + 1) * n..(i + 2) * n];
            for t in 0..n {
                r_accel[t] += c * newer[t];
            }
        }
        Ok((r_accel, min_piv))
    }
}

/// Result of [`engine_cd_solve`].
#[derive(Debug, Clone)]
pub struct EngineSolveResult {
    pub beta: Vec<f64>,
    pub r: Vec<f64>,
    pub theta: Vec<f64>,
    pub gap: f64,
    /// Inner-solve calls made (each is `f` = 10 CD epochs).
    pub blocks: usize,
    pub converged: bool,
    /// Extrapolation rounds that hit the singular fallback.
    pub singular_fallbacks: usize,
}

/// Algorithm 1 driven purely through an [`Engine`]: `f`-epoch CD blocks +
/// θ_res / θ_accel duals + gap stopping, on a dense (n, p) problem.
///
/// `k` is the extrapolation depth; the residual ring buffer lives here
/// (state management is Layer-3 territory), while all O(n·p) numerics go
/// through the engine.
pub fn engine_cd_solve<E: Engine>(
    engine: &mut E,
    x_cm: &[f64],
    n: usize,
    p: usize,
    y: &[f64],
    lambda: f64,
    tol: f64,
    max_blocks: usize,
    k: usize,
) -> anyhow::Result<EngineSolveResult> {
    let mut beta = vec![0.0; p];
    let mut r = y.to_vec();
    let mut rbuf: Vec<Vec<f64>> = Vec::new();
    let mut best_theta = vec![0.0; n];
    let mut best_dual = f64::NEG_INFINITY;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut blocks = 0;
    let mut singular_fallbacks = 0;

    for _ in 0..max_blocks {
        let (nb, nr) = engine.inner_solve(x_cm, n, p, y, &beta, lambda)?;
        beta = nb;
        r = nr;
        blocks += 1;

        // ring buffer of residuals (k+1 most recent)
        rbuf.push(r.clone());
        if rbuf.len() > k + 1 {
            rbuf.remove(0);
        }

        // θ_res
        let (theta_res, _) = engine.theta_res(x_cm, n, p, &r, lambda)?;
        let mut cand: Vec<Vec<f64>> = vec![theta_res];
        // θ_accel
        if rbuf.len() == k + 1 {
            let flat: Vec<f64> = rbuf.iter().flatten().copied().collect();
            let (r_acc, min_piv) = engine.extrapolate(&flat, k, n)?;
            if min_piv > 1e-300 {
                let (theta_acc, _) = engine.theta_res(x_cm, n, p, &r_acc, lambda)?;
                cand.push(theta_acc);
            } else {
                singular_fallbacks += 1;
            }
        }
        for theta in cand {
            let (_, dval, _, _) =
                engine.gap_scores(x_cm, n, p, y, &beta, &theta, lambda)?;
            if dval > best_dual {
                best_dual = dval;
                best_theta = theta;
            }
        }
        let (pval, _, _, _) =
            engine.gap_scores(x_cm, n, p, y, &beta, &best_theta, lambda)?;
        gap = pval - best_dual;
        if gap <= tol {
            converged = true;
            break;
        }
    }
    Ok(EngineSolveResult {
        beta,
        r,
        theta: best_theta,
        gap,
        blocks,
        converged,
        singular_fallbacks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::design::DesignOps;
    use crate::data::synth;

    fn dense_cm(ds: &synth::SynthDataset) -> (Vec<f64>, usize, usize) {
        let (n, p) = (ds.x.n(), ds.x.p());
        let mut buf = Vec::new();
        ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut buf);
        (buf, n, p)
    }

    #[test]
    fn native_engine_matches_cd_solver() {
        let ds = synth::leukemia_mini(60);
        let (x_cm, n, p) = dense_cm(&ds);
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let mut eng = NativeEngine;
        let out = engine_cd_solve(&mut eng, &x_cm, n, p, &ds.y, lambda, 1e-9, 500, 5).unwrap();
        assert!(out.converged, "gap={}", out.gap);
        let reference = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-11, ..Default::default() },
        );
        let pe = crate::lasso::primal::primal(&ds.x, &ds.y, &out.beta, lambda);
        let pr = crate::lasso::primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
        assert!((pe - pr).abs() < 1e-7, "engine {pe} vs cd {pr}");
    }

    #[test]
    fn native_inner_solve_respects_padding() {
        let ds = synth::leukemia_mini(61);
        let (mut x_cm, n, p) = dense_cm(&ds);
        // pad 7 zero columns
        let pad = 7;
        x_cm.extend(std::iter::repeat(0.0).take(pad * n));
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let mut eng = NativeEngine;
        let beta0 = vec![0.0; p + pad];
        let (beta, _) = eng.inner_solve(&x_cm, n, p + pad, &ds.y, &beta0, lambda).unwrap();
        assert!(beta[p..].iter().all(|&b| b == 0.0), "padded betas stay zero");
    }

    #[test]
    fn native_extrapolate_flags_singular() {
        let mut eng = NativeEngine;
        let rbuf = vec![1.0; 3 * 4]; // constant buffer, k=2, n=4
        let (_, min_piv) = eng.extrapolate(&rbuf, 2, 4).unwrap();
        assert!(min_piv <= 1e-300);
    }

    #[test]
    fn native_theta_res_feasible() {
        let ds = synth::leukemia_mini(62);
        let (x_cm, n, p) = dense_cm(&ds);
        let mut eng = NativeEngine;
        let (theta, xtheta) = eng.theta_res(&x_cm, n, p, &ds.y, 0.01).unwrap();
        assert!(xtheta.iter().all(|v| v.abs() <= 1.0 + 1e-12));
        // cross-check against the DesignMatrix implementation
        let expect = crate::lasso::dual::rescale_to_feasible(&ds.x, &ds.y, 0.01);
        for i in 0..n {
            assert!((theta[i] - expect[i]).abs() < 1e-12);
        }
    }
}
