//! Runtime layer: engine abstraction (native vs. XLA/PJRT), the AOT
//! artifact registry and the shape-bucket router.

pub mod artifacts;
pub mod engine;
pub mod xla_exec;

pub use artifacts::{ArtifactRegistry, ArtifactSpec};
pub use engine::{engine_cd_solve, Engine, EngineSolveResult, NativeEngine};
pub use xla_exec::XlaEngine;

/// Sentinel score for empty/padded columns — must match
/// `python/compile/kernels/scores.py::EMPTY_COL_SCORE`.
pub const EMPTY_COL_SCORE: f64 = 1e300;

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("CELER_ARTIFACTS_DIR") {
        return dir.into();
    }
    // try relative to CWD, then relative to the executable's repo layout
    let cwd = std::path::PathBuf::from("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    if let Ok(mut exe) = std::env::current_exe() {
        // target/{release,debug}/... -> repo root
        for _ in 0..4 {
            exe.pop();
            let cand = exe.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
        }
    }
    cwd
}
