//! XLA/PJRT execution engine: loads the AOT HLO-text artifacts produced
//! by `python -m compile.aot` and runs them on the PJRT CPU client.
//!
//! This is the request-path realization of the three-layer architecture:
//! Python lowered the Pallas kernels + JAX graphs once at build time; at
//! run time this module compiles the HLO text with the in-process XLA
//! (xla_extension 0.5.1) and executes it with concrete buffers — no
//! Python interpreter anywhere.
//!
//! Shape policy: every artifact has static shapes; the engine pads
//! requests up to the registered bucket (zero columns are arithmetic
//! no-ops for every op we ship — validated by the padding tests in
//! `python/tests/` and `rust/tests/integration_runtime.rs`).
//!
//! **Build gating:** the PJRT bindings live in the external `xla`
//! (xla_extension) crate, which is not available in the offline build.
//! The real engine compiles only with `--features xla`, which is a
//! manual unlock: vendor the crate AND add it to `[dependencies]` in
//! `rust/Cargo.toml` (see the `[features]` comment there — an optional
//! dependency cannot be pre-declared because cargo would try to resolve
//! it even with the feature off). The default build ships a stub whose
//! `load` fails with a clear message, so every caller that handles the
//! artifacts-missing case (CLI, tests) degrades gracefully.

#[cfg(feature = "xla")]
mod real {
    use crate::runtime::artifacts::{ArtifactRegistry, ArtifactSpec};
    use crate::runtime::engine::Engine;
    use std::collections::HashMap;
    use std::path::Path;

    /// PJRT-backed engine over the artifact registry.
    pub struct XlaEngine {
        registry: ArtifactRegistry,
        client: xla::PjRtClient,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl XlaEngine {
        /// Load the manifest in `dir` and create a CPU PJRT client.
        pub fn load(dir: &Path) -> anyhow::Result<Self> {
            let registry = ArtifactRegistry::load(dir)?;
            anyhow::ensure!(
                registry.dtype == "f64",
                "artifacts must be f64, got {}",
                registry.dtype
            );
            let client = xla::PjRtClient::cpu()?;
            Ok(XlaEngine { registry, client, cache: HashMap::new() })
        }

        /// The artifact registry backing this engine.
        pub fn registry(&self) -> &ArtifactRegistry {
            &self.registry
        }

        fn compile(&mut self, spec: &ArtifactSpec) -> anyhow::Result<()> {
            if self.cache.contains_key(&spec.file) {
                return Ok(());
            }
            let path = self.registry.path_of(spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(spec.file.clone(), exe);
            Ok(())
        }

        fn run(
            &mut self,
            spec: &ArtifactSpec,
            args: &[xla::Literal],
        ) -> anyhow::Result<Vec<xla::Literal>> {
            self.compile(spec)?;
            let exe = self.cache.get(&spec.file).expect("just compiled");
            let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True
            Ok(result.to_tuple()?)
        }
    }

    /// Column-major (n rows, w cols) → row-major literal of shape [n, w],
    /// zero-padding to `w_pad` columns.
    fn matrix_literal(
        x_cm: &[f64],
        n: usize,
        w: usize,
        w_pad: usize,
    ) -> anyhow::Result<xla::Literal> {
        debug_assert_eq!(x_cm.len(), n * w);
        let mut rm = vec![0.0f64; n * w_pad];
        for j in 0..w {
            let col = &x_cm[j * n..(j + 1) * n];
            for i in 0..n {
                rm[i * w_pad + j] = col[i];
            }
        }
        Ok(xla::Literal::vec1(&rm).reshape(&[n as i64, w_pad as i64])?)
    }

    fn vec_literal(v: &[f64], pad_to: usize) -> anyhow::Result<xla::Literal> {
        if v.len() == pad_to {
            return Ok(xla::Literal::vec1(v));
        }
        let mut padded = v.to_vec();
        padded.resize(pad_to, 0.0);
        Ok(xla::Literal::vec1(&padded))
    }

    fn scalar_literal(v: f64) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    fn to_f64_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f64>> {
        Ok(lit.to_vec::<f64>()?)
    }

    impl Engine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn inner_solve(
            &mut self,
            x_cm: &[f64],
            n: usize,
            w: usize,
            y: &[f64],
            beta: &[f64],
            lambda: f64,
        ) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
            let spec = self
                .registry
                .inner_solve_bucket(n, w)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no inner_solve artifact for n={n}, w>={w}; regenerate with \
                         CELER_AOT_PROFILE=full make artifacts"
                    )
                })?
                .clone();
            let args = vec![
                matrix_literal(x_cm, n, w, spec.w)?,
                vec_literal(y, n)?,
                vec_literal(beta, spec.w)?,
                scalar_literal(lambda),
            ];
            let out = self.run(&spec, &args)?;
            anyhow::ensure!(out.len() == 2, "inner_solve returns (beta, r)");
            let mut beta_out = to_f64_vec(&out[0])?;
            beta_out.truncate(w);
            let r_out = to_f64_vec(&out[1])?;
            Ok((beta_out, r_out))
        }

        fn gap_scores(
            &mut self,
            x_cm: &[f64],
            n: usize,
            p: usize,
            y: &[f64],
            beta: &[f64],
            theta: &[f64],
            lambda: f64,
        ) -> anyhow::Result<(f64, f64, f64, Vec<f64>)> {
            let spec = self
                .registry
                .full_design_bucket("gap_scores", n, p)
                .ok_or_else(|| anyhow::anyhow!("no gap_scores artifact for n={n}, p>={p}"))?
                .clone();
            let args = vec![
                matrix_literal(x_cm, n, p, spec.p)?,
                vec_literal(y, n)?,
                vec_literal(beta, spec.p)?,
                vec_literal(theta, n)?,
                scalar_literal(lambda),
            ];
            let out = self.run(&spec, &args)?;
            anyhow::ensure!(out.len() == 4, "gap_scores returns 4 values");
            let primal = out[0].get_first_element::<f64>()?;
            let dual = out[1].get_first_element::<f64>()?;
            let gap = out[2].get_first_element::<f64>()?;
            let mut d = to_f64_vec(&out[3])?;
            d.truncate(p);
            Ok((primal, dual, gap, d))
        }

        fn theta_res(
            &mut self,
            x_cm: &[f64],
            n: usize,
            p: usize,
            r: &[f64],
            lambda: f64,
        ) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
            let spec = self
                .registry
                .full_design_bucket("theta_res", n, p)
                .ok_or_else(|| anyhow::anyhow!("no theta_res artifact for n={n}, p>={p}"))?
                .clone();
            let args = vec![
                matrix_literal(x_cm, n, p, spec.p)?,
                vec_literal(r, n)?,
                scalar_literal(lambda),
            ];
            let out = self.run(&spec, &args)?;
            anyhow::ensure!(out.len() == 2, "theta_res returns (theta, xtheta)");
            let theta = to_f64_vec(&out[0])?;
            let mut xtheta = to_f64_vec(&out[1])?;
            xtheta.truncate(p);
            Ok((theta, xtheta))
        }

        fn extrapolate(
            &mut self,
            rbuf: &[f64],
            k: usize,
            n: usize,
        ) -> anyhow::Result<(Vec<f64>, f64)> {
            let spec = self
                .registry
                .extrapolate_bucket(k, n)
                .ok_or_else(|| anyhow::anyhow!("no extrapolate artifact for k={k}, n={n}"))?
                .clone();
            anyhow::ensure!(rbuf.len() == (k + 1) * n);
            // rbuf is already row-major (k+1, n)
            let lit = xla::Literal::vec1(rbuf).reshape(&[(k + 1) as i64, n as i64])?;
            let out = self.run(&spec, &[lit])?;
            anyhow::ensure!(out.len() == 2, "extrapolate returns (r_accel, min_pivot)");
            let r_accel = to_f64_vec(&out[0])?;
            let min_piv = out[1].get_first_element::<f64>()?;
            Ok((r_accel, min_piv))
        }
    }

    /// ISTA step through an artifact (used by the Theorem-1 demo).
    impl XlaEngine {
        pub fn ista_epoch(
            &mut self,
            x_cm: &[f64],
            n: usize,
            p: usize,
            y: &[f64],
            beta: &[f64],
            lambda: f64,
            mu: f64,
        ) -> anyhow::Result<Vec<f64>> {
            let spec = self
                .registry
                .full_design_bucket("ista_epoch", n, p)
                .ok_or_else(|| anyhow::anyhow!("no ista_epoch artifact for n={n}, p>={p}"))?
                .clone();
            let args = vec![
                matrix_literal(x_cm, n, p, spec.p)?,
                vec_literal(y, n)?,
                vec_literal(beta, spec.p)?,
                scalar_literal(lambda),
                scalar_literal(mu),
            ];
            let out = self.run(&spec, &args)?;
            anyhow::ensure!(out.len() == 1, "ista_epoch returns (beta,)");
            let mut b = to_f64_vec(&out[0])?;
            b.truncate(p);
            Ok(b)
        }
    }
}

#[cfg(feature = "xla")]
pub use real::XlaEngine;

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::runtime::artifacts::ArtifactRegistry;
    use crate::runtime::engine::Engine;
    use std::path::Path;

    fn unavailable() -> anyhow::Error {
        anyhow::anyhow!(
            "the XLA/PJRT backend is unavailable: celer was built without the \
             `xla` cargo feature (the xla_extension bindings cannot be fetched \
             in the offline build). Vendor the crate, add it to [dependencies] \
             in rust/Cargo.toml (see the [features] comment), and rebuild with \
             `--features xla` — or use `--engine native`."
        )
    }

    /// Offline stub: same API surface as the real engine, but `load`
    /// always fails with an actionable message.
    pub struct XlaEngine {
        registry: ArtifactRegistry,
    }

    impl XlaEngine {
        /// Always fails in offline builds (after surfacing manifest
        /// problems first, so the error actionable to the user is the
        /// most specific one).
        pub fn load(dir: &Path) -> anyhow::Result<Self> {
            let _registry = ArtifactRegistry::load(dir)?;
            Err(unavailable())
        }

        /// The artifact registry backing this engine.
        pub fn registry(&self) -> &ArtifactRegistry {
            &self.registry
        }

        pub fn ista_epoch(
            &mut self,
            _x_cm: &[f64],
            _n: usize,
            _p: usize,
            _y: &[f64],
            _beta: &[f64],
            _lambda: f64,
            _mu: f64,
        ) -> anyhow::Result<Vec<f64>> {
            Err(unavailable())
        }
    }

    impl Engine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-stub"
        }

        fn inner_solve(
            &mut self,
            _x_cm: &[f64],
            _n: usize,
            _w: usize,
            _y: &[f64],
            _beta: &[f64],
            _lambda: f64,
        ) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
            Err(unavailable())
        }

        fn gap_scores(
            &mut self,
            _x_cm: &[f64],
            _n: usize,
            _p: usize,
            _y: &[f64],
            _beta: &[f64],
            _theta: &[f64],
            _lambda: f64,
        ) -> anyhow::Result<(f64, f64, f64, Vec<f64>)> {
            Err(unavailable())
        }

        fn theta_res(
            &mut self,
            _x_cm: &[f64],
            _n: usize,
            _p: usize,
            _r: &[f64],
            _lambda: f64,
        ) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
            Err(unavailable())
        }

        fn extrapolate(
            &mut self,
            _rbuf: &[f64],
            _k: usize,
            _n: usize,
        ) -> anyhow::Result<(Vec<f64>, f64)> {
            Err(unavailable())
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaEngine;
