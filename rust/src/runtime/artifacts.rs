//! AOT artifact manifest + shape-bucket registry.
//!
//! `python -m compile.aot` (Layers 1–2) writes `artifacts/manifest.json`
//! describing every compiled HLO module and its static shapes. The Rust
//! side never recompiles Python — it routes each request to the smallest
//! compiled bucket that fits and zero-pads, a serving-style design.

use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};

/// One compiled artifact (an HLO-text module with fixed shapes).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub op: String,
    pub file: String,
    /// Observation count n (rows).
    pub n: usize,
    /// Block width w (inner_solve) — 0 when not applicable.
    pub w: usize,
    /// Padded feature count p (full-design ops) — 0 when not applicable.
    pub p: usize,
    /// Extrapolation depth K — 0 when not applicable.
    pub k: usize,
    /// Epochs per inner_solve call — 0 when not applicable.
    pub f: usize,
}

/// Parsed manifest with bucket lookup.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub dtype: String,
    pub artifacts: Vec<ArtifactSpec>,
}

impl ArtifactRegistry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::from_json(dir, &text)
    }

    /// Parse a manifest document.
    pub fn from_json(dir: &Path, text: &str) -> anyhow::Result<Self> {
        let doc = parse(text)?;
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let dtype = doc
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f64")
            .to_string();
        let mut artifacts = Vec::new();
        for e in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts array"))?
        {
            let field = |k: &str| e.get(k).and_then(Json::as_usize).unwrap_or(0);
            artifacts.push(ArtifactSpec {
                op: e
                    .get("op")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing op"))?
                    .to_string(),
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing file"))?
                    .to_string(),
                n: field("n"),
                w: field("w"),
                p: field("p"),
                k: field("k"),
                f: field("f"),
            });
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), dtype, artifacts })
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Smallest `inner_solve` bucket with matching n and width ≥ w.
    pub fn inner_solve_bucket(&self, n: usize, w: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.op == "inner_solve" && a.n == n && a.w >= w)
            .min_by_key(|a| a.w)
    }

    /// Smallest full-design bucket (by op name) with matching n, p ≥ p_req.
    pub fn full_design_bucket(&self, op: &str, n: usize, p: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.op == op && a.n == n && a.p >= p)
            .min_by_key(|a| a.p)
    }

    /// Extrapolation bucket for (k, n).
    pub fn extrapolate_bucket(&self, k: usize, n: usize) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.op == "extrapolate" && a.k == k && a.n == n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "version": 1, "dtype": "f64", "profile": "small",
      "artifacts": [
        {"op": "inner_solve", "file": "a.hlo.txt", "n": 48, "w": 64, "f": 10},
        {"op": "inner_solve", "file": "b.hlo.txt", "n": 48, "w": 128, "f": 10},
        {"op": "gap_scores", "file": "c.hlo.txt", "n": 48, "p": 512},
        {"op": "extrapolate", "file": "d.hlo.txt", "k": 5, "n": 48}
      ]
    }"#;

    fn reg() -> ArtifactRegistry {
        ArtifactRegistry::from_json(Path::new("/tmp/arts"), MANIFEST).unwrap()
    }

    #[test]
    fn parses_manifest() {
        let r = reg();
        assert_eq!(r.artifacts.len(), 4);
        assert_eq!(r.dtype, "f64");
        assert_eq!(r.artifacts[0].w, 64);
        assert_eq!(r.path_of(&r.artifacts[0]), Path::new("/tmp/arts/a.hlo.txt"));
    }

    #[test]
    fn bucket_selection_picks_smallest_fit() {
        let r = reg();
        assert_eq!(r.inner_solve_bucket(48, 10).unwrap().w, 64);
        assert_eq!(r.inner_solve_bucket(48, 64).unwrap().w, 64);
        assert_eq!(r.inner_solve_bucket(48, 65).unwrap().w, 128);
        assert!(r.inner_solve_bucket(48, 129).is_none());
        assert!(r.inner_solve_bucket(99, 10).is_none(), "n must match exactly");
    }

    #[test]
    fn full_design_and_extrapolate_buckets() {
        let r = reg();
        assert_eq!(r.full_design_bucket("gap_scores", 48, 500).unwrap().p, 512);
        assert!(r.full_design_bucket("gap_scores", 48, 513).is_none());
        assert!(r.extrapolate_bucket(5, 48).is_some());
        assert!(r.extrapolate_bucket(4, 48).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = r#"{"version": 9, "artifacts": []}"#;
        assert!(ArtifactRegistry::from_json(Path::new("/x"), bad).is_err());
    }
}
