//! Report writers: fixed-width ASCII tables (stdout) and CSV dumps, used
//! by every `examples/` figure/table driver and the bench harness.

use std::fmt::Write as _;

/// A simple fixed-width table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "# {}", self.title);
        }
        let sep: String = widths.iter().map(|w| format!("+{}", "-".repeat(w + 2))).collect::<String>() + "+";
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(line, "| {:<width$} ", cell, width = widths[c]);
            }
            line + "|"
        };
        let _ = writeln!(out, "{sep}");
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        let _ = writeln!(out, "{sep}");
        out
    }

    /// Write as CSV (RFC-4180-ish quoting).
    pub fn write_csv<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        writeln!(w, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","))?;
        for row in &self.rows {
            writeln!(w, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","))?;
        }
        Ok(())
    }

    /// Save CSV next to the repo's results directory.
    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        self.write_csv(&mut f)
    }
}

/// Minimal benchmark harness (offline build: no criterion). Used by
/// every `rust/benches/*` target — each paper table/figure has one.
pub mod bench {
    use std::time::Instant;

    /// Timing summary over repeated runs.
    #[derive(Debug, Clone)]
    pub struct Timing {
        pub name: String,
        pub iters: usize,
        pub mean_s: f64,
        pub min_s: f64,
        pub max_s: f64,
    }

    impl Timing {
        pub fn report(&self) -> String {
            format!(
                "bench {:<40} iters={:<3} min={:>10} mean={:>10} max={:>10}",
                self.name,
                self.iters,
                super::fmt_secs(self.min_s),
                super::fmt_secs(self.mean_s),
                super::fmt_secs(self.max_s)
            )
        }
    }

    /// Time `f` over `iters` runs (plus one warmup).
    pub fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Timing {
        f(); // warmup
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters.max(1) {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let sum: f64 = times.iter().sum();
        let timing = Timing {
            name: name.to_string(),
            iters: times.len(),
            mean_s: sum / times.len() as f64,
            min_s: times.iter().copied().fold(f64::INFINITY, f64::min),
            max_s: times.iter().copied().fold(0.0, f64::max),
        };
        println!("{}", timing.report());
        timing
    }

    /// True when the full paper-scale benchmark was requested
    /// (`CELER_BENCH_FULL=1 cargo bench`); default is the CI-scale run.
    pub fn full_scale() -> bool {
        std::env::var("CELER_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
    }
}

/// Format seconds human-readably (µs → s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Format a float in compact scientific notation.
pub fn fmt_sci(v: f64) -> String {
    format!("{v:.2e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["solver", "time"]);
        t.row(vec!["celer".into(), "5s".into()]);
        t.row(vec!["blitz-longer-name".into(), "25s".into()]);
        let s = t.render();
        assert!(s.contains("# demo"));
        assert!(s.contains("| celer"));
        let lines: Vec<&str> = s.lines().collect();
        // all body lines same width
        let w = lines[1].len();
        assert!(lines[2..].iter().all(|l| l.len() == w));
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let mut buf = Vec::new();
        t.write_csv(&mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_secs(0.0000005), "0.5µs");
        assert_eq!(fmt_secs(0.005), "5.0ms");
        assert_eq!(fmt_secs(2.5), "2.50s");
    }
}
