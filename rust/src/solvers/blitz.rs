//! Reimplementation of BLITZ (Johnson & Guestrin, ICML 2015), the paper's
//! main working-set baseline.
//!
//! Faithful to what the paper's §7 identifies as the structural
//! difference with CELER: BLITZ's analysis requires its outer dual point
//! to be a **feasible barycenter** between the previous dual point and the
//! subproblem-rescaled residual,
//!
//! ```text
//! θ^t = θ^{t-1} + α·(φ^t − θ^{t-1}),   φ^t = r / max(λ, ‖X_{W}ᵀr‖_∞),
//! ```
//!
//! with the largest α ∈ [0, 1] keeping `‖Xᵀθ^t‖_∞ ≤ 1`. This prevents it
//! from using extrapolated dual points, which is exactly the handicap the
//! paper measures (Fig. 4, Tables 1–2).
//!
//! Simplifications vs. the C++ release (documented in DESIGN.md §4):
//! working-set capacity doubles instead of being sized by Blitz's
//! auxiliary subproblem, and the time-based internal heuristics are
//! reduced to a primal-decrease test.
//!
//! Subproblems are solved on a zero-copy [`DesignView`] of `X_{W_t}`
//! through the shared [`crate::solvers::engine`] — no per-iteration
//! column materialization.

use crate::data::design::{DesignMatrix, DesignOps};
use crate::data::view::DesignView;
use crate::lasso::{dual, primal};
use crate::solvers::celer::CelerIteration;
use crate::solvers::engine::{self, CdStrategy, EngineConfig, Init, StopRule, Workspace};
use crate::solvers::SolveResult;
use crate::util::error::{FaultEvent, SolveOutcome};
use crate::ws::build_working_set;
use std::time::Instant;

/// BLITZ configuration.
#[derive(Debug, Clone)]
pub struct BlitzConfig {
    /// Duality-gap tolerance ε.
    pub tol: f64,
    pub max_outer: usize,
    /// Initial working-set size.
    pub p1: usize,
    /// Subproblem tolerance ratio (ε_t = ratio · g_t).
    pub inner_tol_ratio: f64,
    pub max_inner_epochs: usize,
    pub gap_freq: usize,
    /// Internal stop on primal stagnation (the behaviour the paper's
    /// Table 2 footnote describes). Disabled when 0.
    pub primal_decrease_tol: f64,
}

impl Default for BlitzConfig {
    fn default() -> Self {
        BlitzConfig {
            tol: 1e-6,
            max_outer: 100,
            p1: 100,
            inner_tol_ratio: 0.3,
            max_inner_epochs: 10_000,
            gap_freq: 10,
            primal_decrease_tol: 0.0,
        }
    }
}

/// BLITZ output mirrors CELER's (same per-iteration schema).
#[derive(Debug, Clone)]
pub struct BlitzOutput {
    pub result: SolveResult,
    pub iterations: Vec<CelerIteration>,
    /// True when the run ended on the internal primal-stagnation test
    /// rather than the duality gap.
    pub stopped_internally: bool,
}

/// Largest α ∈ [0, 1] with `‖a + α(b − a)‖_∞ ≤ 1` where `a = Xᵀθ`,
/// `b = Xᵀφ` (per-feature convex line search).
fn max_feasible_step(a: &[f64], b: &[f64]) -> f64 {
    let mut alpha: f64 = 1.0;
    for j in 0..a.len() {
        let (aj, bj) = (a[j], b[j]);
        if bj > 1.0 {
            // |a + α(b−a)| hits +1 from below
            let denom = bj - aj;
            if denom > 0.0 {
                alpha = alpha.min((1.0 - aj) / denom);
            }
        } else if bj < -1.0 {
            let denom = bj - aj;
            if denom < 0.0 {
                alpha = alpha.min((-1.0 - aj) / denom);
            }
        }
    }
    alpha.clamp(0.0, 1.0)
}

/// Solve the Lasso with the BLITZ working-set scheme.
pub fn blitz_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &BlitzConfig,
) -> BlitzOutput {
    let mut ws = Workspace::new();
    blitz_solve_ws(x, y, lambda, beta0, cfg, &mut ws)
}

/// [`blitz_solve`] on a caller-provided reusable [`Workspace`].
pub fn blitz_solve_ws(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &BlitzConfig,
    ws: &mut Workspace,
) -> BlitzOutput {
    // Dispatch once; the outer loop and the view-based inner solves then
    // monomorphize for the concrete storage kind.
    match x {
        DesignMatrix::Dense(d) => blitz_generic(d, y, lambda, beta0, cfg, ws),
        DesignMatrix::Sparse(s) => blitz_generic(s, y, lambda, beta0, cfg, ws),
        DesignMatrix::Ooc(o) => blitz_generic(o, y, lambda, beta0, cfg, ws),
        DesignMatrix::Sharded(sh) => blitz_generic(sh, y, lambda, beta0, cfg, ws),
    }
}

fn blitz_generic<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &BlitzConfig,
    ws: &mut Workspace,
) -> BlitzOutput {
    let n = x.n();
    let p = x.p();
    let start = Instant::now();

    // ---- outer-loop state in the reusable workspace ----
    ws.init_primal(x, y, beta0);

    let lmax = dual::lambda_max(x, y).max(f64::MIN_POSITIVE);
    ws.theta.clear();
    ws.theta.extend(y.iter().map(|&v| v / lmax));
    ws.xtheta.resize(p, 0.0);
    x.xt_vec(&ws.theta, &mut ws.xtheta);
    // xtheta_inner doubles as the Xᵀφ buffer of the barycenter update
    ws.xtheta_inner.resize(p, 0.0);
    ws.d_scores.resize(p, 0.0);

    let mut inner_ws = ws.take_inner();
    let mut iterations = Vec::new();
    let mut ws_idx: Vec<usize> = Vec::new();
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut stopped_internally = false;
    let mut total_epochs = 0usize;
    let mut all_faults: Vec<FaultEvent> = Vec::new();
    let mut prev_primal = f64::INFINITY;

    // initial φ uses the full design (no WS yet)
    for t in 1..=cfg.max_outer {
        // ---- barycenter dual update ----
        // φ = r / max(λ, ‖X_{W}ᵀ r‖_∞); at t = 1, W = full problem and
        // the shared allocation-free rescale (fused Xᵀr + its norm in
        // one sharded pass) materializes φ into the workspace buffer.
        // Later iterations max over the working set only, so the plain
        // fill plus a |W_t|-sized scan is the cheaper shape.
        let denom = if t == 1 || ws_idx.is_empty() {
            dual::rescale_to_feasible_into(
                x,
                &ws.r,
                lambda,
                &mut ws.xtheta_inner,
                &mut ws.theta_res,
            )
        } else {
            x.xt_vec(&ws.r, &mut ws.xtheta_inner);
            let mut d = lambda;
            for &j in &ws_idx {
                d = d.max(ws.xtheta_inner[j].abs());
            }
            let r = &ws.r;
            ws.theta_res.clear();
            ws.theta_res.extend(r.iter().map(|&v| v / d));
            d
        };
        let inv = 1.0 / denom;
        // line search on cached correlations: a = Xᵀθ, b = Xᵀφ = Xᵀr/denom
        for v in ws.xtheta_inner.iter_mut() {
            *v *= inv;
        }
        let alpha = max_feasible_step(&ws.xtheta, &ws.xtheta_inner);
        for i in 0..n {
            ws.theta[i] += alpha * (ws.theta_res[i] - ws.theta[i]);
        }
        for j in 0..p {
            ws.xtheta[j] += alpha * (ws.xtheta_inner[j] - ws.xtheta[j]);
        }

        // ---- global gap / stopping ----
        let p_val = primal::primal_from_residual(&ws.r, &ws.beta, lambda);
        gap = p_val - dual::dual_objective(y, &ws.theta, lambda);
        let support = primal::support(&ws.beta);
        if gap <= cfg.tol {
            converged = true;
            iterations.push(CelerIteration {
                t,
                gap,
                ws_size: 0,
                support_size: support.len(),
                inner_epochs: 0,
                seconds: start.elapsed().as_secs_f64(),
                dual_winner: 0,
            });
            break;
        }
        if cfg.primal_decrease_tol > 0.0 && prev_primal - p_val < cfg.primal_decrease_tol {
            stopped_internally = true;
            break;
        }
        prev_primal = p_val;

        // ---- working set: smallest d_j(θ), capacity doubling ----
        // (empty columns get an infinite d_score; build_working_set
        // excludes non-finite scores centrally)
        crate::screening::fill_d_scores(&ws.xtheta, &ws.col_norms, &mut ws.d_scores);
        let pt =
            if t == 1 { cfg.p1 } else { (2 * ws_idx.len()).max(cfg.p1) }.min(p).max(support.len());
        ws_idx = build_working_set(&mut ws.d_scores, &support, pt);

        // ---- inner solve on a zero-copy view of X_{W_t} (θ_res only) ----
        ws.beta_ws.clear();
        {
            let beta = &ws.beta;
            ws.beta_ws.extend(ws_idx.iter().map(|&j| beta[j]));
        }
        let inner_cfg = EngineConfig {
            tol: cfg.inner_tol_ratio * gap,
            max_epochs: cfg.max_inner_epochs,
            gap_freq: cfg.gap_freq,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: false,
            best_dual: true,
            screen: false,
            trace: false,
            stop: StopRule::DualityGap,
            ..EngineConfig::default()
        };
        let inner_epochs = {
            let view = DesignView::new(x, &ws_idx, &ws.norms_sq);
            let outcome = engine::solve(
                &view,
                y,
                lambda,
                Init::Warm(&ws.beta_ws),
                None,
                &inner_cfg,
                &mut inner_ws,
                &mut CdStrategy,
            );
            all_faults.extend_from_slice(outcome.status.faults());
            outcome.epochs
        };
        total_epochs += inner_epochs;
        ws.beta.fill(0.0);
        for (i, &j) in ws_idx.iter().enumerate() {
            ws.beta[j] = inner_ws.beta[i];
        }
        ws.r.copy_from_slice(&inner_ws.r);

        iterations.push(CelerIteration {
            t,
            gap,
            ws_size: ws_idx.len(),
            support_size: support.len(),
            inner_epochs,
            seconds: start.elapsed().as_secs_f64(),
            dual_winner: 0,
        });
    }

    ws.put_inner(inner_ws);
    // An internal primal-stagnation stop is BLITZ's own success mode,
    // not a budget failure; it still reports as unconverged-by-gap.
    let status = SolveOutcome::from_run(converged, gap, total_epochs, all_faults);
    let result = SolveResult {
        beta: ws.beta.clone(),
        r: ws.r.clone(),
        theta: ws.theta.clone(),
        gap,
        epochs: total_epochs,
        converged,
        trace: Vec::new(),
        status,
    };
    BlitzOutput { result, iterations, stopped_internally }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn max_step_cases() {
        // already feasible target: full step
        assert_eq!(max_feasible_step(&[0.2, -0.5], &[0.9, 0.4]), 1.0);
        // b exceeds +1: α = (1-a)/(b-a)
        let a = [0.5];
        let b = [2.0];
        let alpha = max_feasible_step(&a, &b);
        assert!((alpha - (0.5 / 1.5)).abs() < 1e-12);
        // symmetric negative case
        let alpha = max_feasible_step(&[-0.5], &[-2.0]);
        assert!((alpha - (0.5 / 1.5)).abs() < 1e-12);
        // mixed features: min over features
        let alpha = max_feasible_step(&[0.0, 0.0], &[4.0, 2.0]);
        assert!((alpha - 0.25).abs() < 1e-12);
    }

    #[test]
    fn solves_to_gap() {
        let ds = synth::leukemia_mini(30);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
        let out = blitz_solve(&ds.x, &ds.y, lambda, None, &BlitzConfig { tol: 1e-8, ..Default::default() });
        assert!(out.result.converged, "gap = {}", out.result.gap);
        // objective agrees with CD reference
        let cd = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-10, ..Default::default() },
        );
        let pb = primal::primal(&ds.x, &ds.y, &out.result.beta, lambda);
        let pc = primal::primal(&ds.x, &ds.y, &cd.beta, lambda);
        assert!(pb - pc <= 2e-8, "blitz {pb} vs cd {pc}");
    }

    #[test]
    fn dual_point_always_feasible() {
        let ds = synth::leukemia_mini(31);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
        let out = blitz_solve(&ds.x, &ds.y, lambda, None, &BlitzConfig { tol: 1e-6, ..Default::default() });
        assert!(dual::is_feasible(&ds.x, &out.result.theta, 1e-9));
    }

    #[test]
    fn sparse_problem_converges() {
        let ds = synth::finance_mini(32);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let out = blitz_solve(&ds.x, &ds.y, lambda, None, &BlitzConfig::default());
        assert!(out.result.converged);
    }

    #[test]
    fn internal_stop_triggers_on_tight_tolerance() {
        let ds = synth::leukemia_mini(33);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
        let out = blitz_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &BlitzConfig { tol: 1e-14, primal_decrease_tol: 1e-10, ..Default::default() },
        );
        // either it reached the (very tight) gap or it stopped internally
        assert!(out.result.converged || out.stopped_internally);
    }

    #[test]
    fn workspace_variant_matches_one_shot() {
        let ds = synth::leukemia_mini(34);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 12.0;
        let cfg = BlitzConfig { tol: 1e-8, ..Default::default() };
        let one_shot = blitz_solve(&ds.x, &ds.y, lambda, None, &cfg);
        let mut ws = Workspace::new();
        let _ = blitz_solve_ws(&ds.x, &ds.y, lambda * 2.0, None, &cfg, &mut ws);
        let reused = blitz_solve_ws(&ds.x, &ds.y, lambda, None, &cfg, &mut ws);
        assert_eq!(one_shot.result.beta, reused.result.beta);
        assert_eq!(one_shot.result.gap, reused.result.gap);
    }
}
