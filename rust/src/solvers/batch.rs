//! Batched multi-λ solver engine: several λ's of a path solved
//! concurrently over shared design sweeps.
//!
//! # Why
//!
//! The paper's headline experiments (Table 1, Fig. 4) are *path*
//! computations: a decreasing λ grid solved with warm starts, where Gap
//! Safe sequential rules (Ndiaye et al.) make each successive λ cheaper.
//! The sequential driver in [`crate::solvers::path`] walks the grid one
//! λ at a time, which means every CD epoch re-streams the design matrix
//! for a *single* residual. On large problems the epoch is memory-bound:
//! the dominant cost is loading each column's values (and, for CSC,
//! decoding its row indices), not the multiply-adds.
//!
//! The batch engine amortizes that traffic. B *lanes* — adjacent grid
//! cells λ_{k}, …, λ_{k+B−1}, each with its own β, residual, dual state
//! and screening state — run their Algorithm-1 CD epochs interleaved
//! over a **single pass over the columns**: one
//! [`DesignOps::col_dot_lanes`] computes `x_jᵀr_k` for every live lane
//! with the column loaded once, and one [`DesignOps::col_axpy_lanes`]
//! applies all lane updates on the way out.
//!
//! B defaults to [`auto_lanes`] (lanes × n residual footprint vs. a
//! cache budget; `BatchConfig::lanes = 0`), and heavy sweeps are
//! lane-sharded across the persistent worker pool (see
//! [`BatchCdStrategy`]) — lanes are independent within an epoch, so the
//! parallel schedule is bit-identical to the serial one.
//!
//! # Lane lifecycle
//!
//! ```text
//!  λ grid (descending) ──┬─▶ lane 0 ─ epochs ─ gap ≤ ε ─▶ retire ─┐
//!                        ├─▶ lane 1 ─ epochs ─ gap ≤ ε ─▶ retire ─┼─▶ results
//!                        └─▶ …       (per-lane Gap Safe screening) ┘
//!        refill: a retired slot loads the next grid cell, warm-started
//!        from the deepest (smallest-λ) solution retired so far
//! ```
//!
//! Every `gap_freq` epochs each lane runs its own duality-gap check
//! (θ_res and, via the per-lane extrapolation ring, θ_accel — Def. 1 /
//! Eq. 13 of the paper) and dynamic Gap Safe screening (Eq. 9; the
//! `d_j` pricing of Eq. 10–11). A converged lane *retires*: its solution
//! is recorded, its slot immediately loads the next λ from the grid, and
//! the new lane warm-starts from the most-converged (deepest-in-grid)
//! retired solution — the batched analogue of the sequential path's
//! β̂(λ_i) → λ_{i+1} warm start.
//!
//! # Equivalence
//!
//! Each lane runs exactly the Algorithm-1 epoch/check sequence of the
//! sequential engine, so every grid point's solution is gap-certified at
//! the same ε; `tests/prop_batch_path.rs` pins batched ≡ sequential
//! (supports and objectives) on dense and sparse designs.

use crate::data::design::DesignOps;
use crate::data::shadow::ShadowF32;
use crate::lasso::primal;
use crate::penalty::{Penalty, L1};
use crate::screening::ScreeningState;
use crate::solvers::engine::MAX_RECOVERIES;
use crate::solvers::sweep32::MAX_F32_EPOCHS;
use crate::solvers::{DualScratch, DualState, Precision};
use crate::util::error::{FaultEvent, FaultKind, RecoveryAction, SolveOutcome};
use crate::util::fault::FaultPlan;
use crate::util::{soft_threshold, soft_threshold_f32};
use std::time::Instant;

/// Configuration of the batched multi-λ engine (the union of the
/// sequential [`EngineConfig`](crate::solvers::engine::EngineConfig)
/// knobs plus the lane count B).
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Per-λ duality-gap tolerance ε.
    pub tol: f64,
    /// Per-lane epoch cap (a lane retires unconverged at the cap).
    pub max_epochs: usize,
    /// Gap/dual evaluation frequency `f` in epochs (paper default: 10).
    pub gap_freq: usize,
    /// Extrapolation depth K (paper default: 5).
    pub k: usize,
    /// Compute θ_accel (Definition 1) per lane.
    pub extrapolate: bool,
    /// Keep the best dual point across checks (Eq. 13).
    pub best_dual: bool,
    /// Per-lane dynamic Gap Safe screening.
    pub screen: bool,
    /// Number of concurrent λ lanes B (clamped to the grid size; 1
    /// degenerates to the sequential engine's schedule). **0 = auto**:
    /// pick B from the problem shape via [`auto_lanes`]. An explicit
    /// non-zero value always wins.
    pub lanes: usize,
    /// Arithmetic precision of the lane sweeps. [`Precision::F32`] runs
    /// the interleaved CD epochs on an f32 design shadow with per-lane
    /// f64 certification at every gap check (see [`BatchF32Strategy`]);
    /// gaps and screening stay exact f64 either way.
    pub precision: Precision,
    /// Wall-clock budget in seconds (`None` = unlimited). On expiry,
    /// in-flight lanes retire unconverged and still-unassigned grid
    /// cells are not attempted — already-retired cells keep their gap
    /// certificates (partial-but-certified), so the result list may be
    /// shorter than the grid.
    pub max_seconds: Option<f64>,
    /// Fault-injection plan (testing; no-op unless `fault-inject`).
    pub faults: FaultPlan,
}

/// Residual-footprint budget for [`auto_lanes`]: B lanes keep B·n f64
/// residuals hot across every column sweep, and ~2 MiB keeps them
/// L2/L3-resident on typical parts.
const LANE_CACHE_BUDGET_BYTES: usize = 2 << 20;

/// Pick a lane count from n: as many lanes as fit the residual cache
/// budget, clamped to [2, 32]. Small n (residuals cheap to keep hot)
/// gets wide batches; large n collapses toward a few lanes so the
/// interleaved sweep stays cache-resident.
///
/// Deliberately a function of the problem shape only — **not** of
/// `CELER_NUM_THREADS` or the worker-pool size — because the lane count
/// shapes the warm-start chain and therefore the solutions' exact bits:
/// keying it on machine properties would break the thread-count
/// invariance the parallel runtime guarantees (see `util::par`).
pub fn auto_lanes(n: usize) -> usize {
    (LANE_CACHE_BUDGET_BYTES / (8 * n.max(1))).clamp(2, 32)
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            tol: 1e-6,
            max_epochs: 50_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
            best_dual: true,
            screen: true,
            lanes: 0,
            precision: Precision::F64,
            max_seconds: None,
            faults: FaultPlan::none(),
        }
    }
}

/// One retired lane = one solved grid point.
#[derive(Debug, Clone)]
pub struct BatchLaneResult {
    /// Position in the input grid (results are returned grid-ordered).
    pub grid_idx: usize,
    pub lambda: f64,
    pub beta: Vec<f64>,
    /// Duality gap at retirement.
    pub gap: f64,
    /// Epochs this lane consumed.
    pub epochs: usize,
    pub converged: bool,
    /// Wall-clock seconds the lane was resident. Lanes share the sweep,
    /// so unlike the sequential path these intervals overlap.
    pub seconds: f64,
    /// Typed outcome of this lane (certified / budget / recovered).
    pub status: SolveOutcome,
}

/// Per-slot bookkeeping (which grid cell the slot is solving).
#[derive(Debug, Clone, Default)]
struct LaneMeta {
    grid_idx: usize,
    epochs: usize,
    /// Seconds offset (from solve start) at which the lane was loaded.
    t0: f64,
}

/// Reusable state of the batch engine: B lanes of (β, r, dual state,
/// screening state) in lane-strided buffers, plus the shared design
/// caches and sweep scratch. Like the sequential
/// [`Workspace`](crate::solvers::engine::Workspace), buffers are
/// resized — never reallocated once warm — across grids.
#[derive(Default)]
pub struct BatchWorkspace {
    /// Cached `‖x_j‖²` (shared by every lane).
    norms_sq: Vec<f64>,
    /// Cached `‖x_j‖` for screening.
    col_norms: Vec<f64>,
    /// Lane-strided primal iterates: lane k's β is `beta[k·p .. (k+1)·p]`.
    beta: Vec<f64>,
    /// Lane-strided residuals: lane k's r is `r[k·n .. (k+1)·n]`.
    r: Vec<f64>,
    /// Per-slot λ.
    lane_lambda: Vec<f64>,
    /// Per-slot dual machinery (θ, Xᵀθ, extrapolation ring).
    dual: Vec<DualState>,
    /// Per-slot gap-check scratch (one extrapolation scratch per lane).
    scratch: Vec<DualScratch>,
    /// Per-slot dynamic screening state.
    screening: Vec<ScreeningState>,
    meta: Vec<LaneMeta>,
    /// Live slot ids.
    live: Vec<usize>,
    /// Per-column scratch for the serial interleaved sweep.
    sweep: SweepScratch,
    /// Sorted copy of `live` for the lane-sharded parallel sweep
    /// (rebuilt, not reallocated, each pooled epoch).
    sorted_live: Vec<usize>,
    /// Per-group scratch for the lane-sharded parallel sweep (one slot
    /// per pool group, warm across epochs).
    group_scratch: Vec<SweepScratch>,
    /// Warm-start seed: the deepest (smallest-λ) retired solution.
    seed_beta: Vec<f64>,
    /// Per-slot watchdog retry counter (reset when a new grid cell
    /// loads, preserved across recovery reloads of the same cell).
    lane_recoveries: Vec<usize>,
    /// Per-slot fault events for the cell currently in the slot.
    lane_faults: Vec<Vec<FaultEvent>>,
}

/// Reusable per-column scratch of one interleaved CD sweep. The serial
/// sweep uses the [`BatchWorkspace`]'s instance (allocation-free once
/// warm); the lane-sharded parallel sweep gives each slot-range group
/// its own short-lived instance (≤ B entries per vector).
#[derive(Debug, Default)]
pub struct SweepScratch {
    /// Global slot ids active at the current column.
    pub act: Vec<usize>,
    /// The same lanes, rebased to the local `beta`/`r` slices (equal to
    /// `act` in the serial sweep, `act[t] − slot_base` in a group).
    pub act_local: Vec<usize>,
    /// Per-active-lane correlations `x_jᵀr_k`.
    pub g: Vec<f64>,
    /// Per-active-lane coefficient deltas.
    pub delta: Vec<f64>,
}

impl BatchWorkspace {
    pub fn new() -> Self {
        BatchWorkspace::default()
    }
}

/// One interleaved sweep's view of the lane state, handed to a
/// [`BatchStrategy`]. Lane k's vectors are the strided slices
/// `beta[k·p..]` / `r[k·n..]`; only slots listed in `live` participate.
pub struct LaneSweep<'a> {
    pub n: usize,
    pub p: usize,
    /// Observations (needed by strategies that recompute exact
    /// residuals mid-sweep, e.g. the f32 strategy's escalation).
    pub y: &'a [f64],
    /// Per-slot λ (indexed by slot id, not by position in `live`).
    pub lambdas: &'a [f64],
    /// Live slot ids.
    pub live: &'a [usize],
    /// Per-slot screening state (a lane skips its screened-out columns).
    pub screening: &'a [ScreeningState],
    /// Shared cached `‖x_j‖²`.
    pub norms_sq: &'a [f64],
    /// Lane-strided β (lanes × p).
    pub beta: &'a mut [f64],
    /// Lane-strided residuals (lanes × n).
    pub r: &'a mut [f64],
    /// Reusable per-column scratch for the serial interleaved sweep.
    pub scratch: &'a mut SweepScratch,
    /// Reusable sorted-live buffer for the lane-sharded parallel sweep.
    pub sorted_live: &'a mut Vec<usize>,
    /// Reusable per-group scratches for the lane-sharded parallel sweep
    /// (grown to the group count on first pooled epoch, warm after).
    pub group_scratch: &'a mut Vec<SweepScratch>,
}

/// A batched solver strategy: one interleaved primal epoch over all live
/// lanes in a single pass over the columns. The batched analogue of
/// [`Strategy`](crate::solvers::engine::Strategy). Generic over the
/// (separable) [`Penalty`] so multi-λ elastic-net / weighted-ℓ₁ paths
/// ride the same one-sweep-per-epoch machinery; `P` defaults to [`L1`],
/// whose instantiation is bit-identical to the historical sweep.
pub trait BatchStrategy<D: DesignOps, P: Penalty = L1> {
    /// Run one epoch for every live lane, updating each lane's (β, r).
    fn sweep(&mut self, x: &D, s: &mut LaneSweep<'_>, penalty: &P);

    /// Called after `slot` is (re)loaded with a grid cell — any
    /// per-slot iteration state the strategy keeps is stale. Default:
    /// no-op (the f64 strategy is stateless).
    fn slot_loaded(&mut self, slot: usize) {
        let _ = slot;
    }

    /// Make `slot`'s f64 `(β, r)` authoritative before a gap check.
    /// Strategies iterating in reduced precision promote their iterate
    /// and recompute `r = y − Xβ` exactly here, so the dual point, gap
    /// and Gap Safe screening that follow never consult rounded state.
    /// Default: no-op (the f64 state already is the iterate).
    fn sync_slot_state(
        &mut self,
        x: &D,
        y: &[f64],
        slot: usize,
        beta_slot: &mut [f64],
        r_slot: &mut [f64],
    ) {
        let _ = (x, y, slot, beta_slot, r_slot);
    }
}

/// Cyclic coordinate descent interleaved across lanes (Algorithm 1 per
/// lane, one design sweep for all of them): for each column j, the
/// correlations `x_jᵀr_k` of every lane still holding j are computed by
/// one [`DesignOps::col_dot_lanes`], the per-lane soft-threshold updates
/// are applied, and one [`DesignOps::col_axpy_lanes`] propagates all
/// residual updates.
///
/// When the epoch is heavy enough (live lanes × design cost clears the
/// work threshold of `util::par`), the sweep is **lane-sharded** over
/// the persistent worker pool: the slot-id space is partitioned into
/// contiguous ranges and each pool shard runs the full column sweep for
/// the live lanes of its range. Lanes never read each other's state
/// inside an epoch, so any grouping yields bit-identical per-lane
/// trajectories — parallelism changes the schedule, never the result.
pub struct BatchCdStrategy;

/// Immutable context of one interleaved CD sweep over a slot range.
#[derive(Clone, Copy)]
struct SweepCtx<'a> {
    n: usize,
    p: usize,
    /// First slot id covered by the `beta`/`r` slices handed alongside
    /// (0 for the serial whole-buffer sweep).
    slot_base: usize,
    /// Per-slot λ, indexed by **global** slot id.
    lambdas: &'a [f64],
    /// Per-slot screening state, indexed by global slot id.
    screening: &'a [ScreeningState],
    norms_sq: &'a [f64],
}

/// One interleaved CD epoch for `slots` (global slot ids, all within
/// the range backing `beta`/`r`). Each lane's update sequence is
/// exactly Algorithm 1 on its own (β, r); lanes interact only through
/// the shared column loads, which is what makes the group-parallel
/// sweep bit-identical to the serial interleaved one.
fn cd_sweep_slots<D: DesignOps, P: Penalty>(
    x: &D,
    ctx: &SweepCtx<'_>,
    slots: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
    scratch: &mut SweepScratch,
    penalty: &P,
) {
    let (n, p) = (ctx.n, ctx.p);
    let SweepScratch { act, act_local, g, delta } = scratch;
    for j in 0..p {
        let nrm = ctx.norms_sq[j];
        if nrm == 0.0 {
            continue;
        }
        act.clear();
        act_local.clear();
        for &slot in slots {
            if !ctx.screening[slot].is_screened(j) {
                act.push(slot);
                act_local.push(slot - ctx.slot_base);
            }
        }
        if act.is_empty() {
            continue;
        }
        g.clear();
        g.resize(act.len(), 0.0);
        x.col_dot_lanes(j, r, n, act_local, g);
        delta.clear();
        let mut any_update = false;
        for (t, &sl) in act_local.iter().enumerate() {
            let bj = &mut beta[sl * p + j];
            let old = *bj;
            // ℓ₁ keeps the historical single-division expression bit for
            // bit; other separable penalties go through their prox.
            let new = if P::IS_L1 {
                soft_threshold(old + g[t] / nrm, ctx.lambdas[act[t]] / nrm)
            } else {
                penalty.prox(j, old + g[t] / nrm, ctx.lambdas[act[t]], nrm)
            };
            *bj = new;
            let d = old - new;
            any_update |= d != 0.0;
            delta.push(d);
        }
        if any_update {
            x.col_axpy_lanes(j, delta, r, n, act_local);
        }
    }
}

impl<D: DesignOps, P: Penalty> BatchStrategy<D, P> for BatchCdStrategy {
    fn sweep(&mut self, x: &D, s: &mut LaneSweep<'_>, penalty: &P) {
        let (n, p) = (s.n, s.p);
        let slots_total = if p > 0 { s.beta.len() / p } else { 0 };
        // One epoch streams the whole design once per live lane.
        let work = s.live.len().saturating_mul(p).saturating_mul(x.col_cost_hint());
        let groups = if crate::util::par::parallel_shards(work) {
            crate::util::par::num_threads().min(s.live.len())
        } else {
            1
        };
        let ctx = SweepCtx {
            n,
            p,
            slot_base: 0,
            lambdas: s.lambdas,
            screening: s.screening,
            norms_sq: s.norms_sq,
        };
        if groups <= 1 || slots_total == 0 {
            cd_sweep_slots(x, &ctx, s.live, s.beta, s.r, s.scratch, penalty);
            return;
        }
        // Lane-sharded parallel sweep: partition the *live lanes* (not
        // the raw slot-id space — live slots can cluster, e.g. at the
        // tail of a grid) into equal-count contiguous chunks of the
        // sorted slot-id order. Sorted contiguous chunks span disjoint
        // slot-id intervals, which makes each group's lane-strided
        // buffer region disjoint from every other group's. Lane order
        // within a sweep does not affect any lane's arithmetic, so the
        // sort changes nothing but the schedule. All buffers (the
        // sorted-live copy and the per-group scratches) live in the
        // workspace — warm epochs allocate nothing.
        let sorted: &mut Vec<usize> = s.sorted_live;
        sorted.clear();
        sorted.extend_from_slice(s.live);
        sorted.sort_unstable();
        let per = sorted.len().div_ceil(groups);
        let n_groups = sorted.len().div_ceil(per);
        if s.group_scratch.len() < n_groups {
            s.group_scratch.resize_with(n_groups, SweepScratch::default);
        }
        let beta_ptr = crate::util::pool::SyncPtr(s.beta.as_mut_ptr());
        let r_ptr = crate::util::pool::SyncPtr(s.r.as_mut_ptr());
        let scr_ptr = crate::util::pool::SyncPtr(s.group_scratch.as_mut_ptr());
        let sorted: &[usize] = sorted;
        crate::util::pool::global().run(n_groups, &|gi| {
            let a = gi * per;
            let b = (a + per).min(sorted.len());
            if a >= b {
                return;
            }
            let slots = &sorted[a..b];
            let lo = slots[0];
            let hi = slots[b - a - 1] + 1;
            // SAFETY: groups cover disjoint slot-id intervals (sorted
            // contiguous chunks), so these are non-overlapping
            // sub-slices of the lane-strided buffers (a manual
            // split_at_mut across pool shards); each group also owns
            // scratch slot `gi` exclusively.
            let beta_g =
                unsafe { std::slice::from_raw_parts_mut(beta_ptr.0.add(lo * p), (hi - lo) * p) };
            let r_g =
                unsafe { std::slice::from_raw_parts_mut(r_ptr.0.add(lo * n), (hi - lo) * n) };
            let scratch = unsafe { &mut *scr_ptr.0.add(gi) };
            let group_ctx = SweepCtx { slot_base: lo, ..ctx };
            cd_sweep_slots(x, &group_ctx, slots, beta_g, r_g, scratch, penalty);
        });
    }
}

/// Interleaved CD in f32 with per-lane f64 certification — the batched
/// analogue of [`F32CdStrategy`](crate::solvers::sweep32::F32CdStrategy),
/// selected by [`BatchConfig::precision`]` = Precision::F32`.
///
/// Every lane runs the same f32-sweep / f64-certify / escalate state
/// machine as the sequential strategy (see `solvers/sweep32.rs`), but
/// the f32 epochs are interleaved over one pass of the f32 design
/// shadow: one [`ShadowF32::col_dot_lanes`] per column for all f32
/// lanes, one [`ShadowF32::col_axpy_lanes`] on the way out. Lanes that
/// escalate (f32 fixed point, or [`MAX_F32_EPOCHS`] spent) drop into an
/// interleaved **f64** sweep over the original design and stay there.
///
/// Both sweeps are run serially — never lane-sharded over the worker
/// pool — so `CELER_NUM_THREADS` invariance holds trivially for the f32
/// mode. (The pooled schedule would also be bit-identical, as lanes are
/// independent; serial is simply the conservative choice for the new
/// path.)
pub struct BatchF32Strategy {
    shadow: ShadowF32,
    /// Lane-strided f32 iterates mirroring the workspace layout.
    beta32: Vec<f32>,
    r32: Vec<f32>,
    norms32: Vec<f32>,
    /// Per-slot: f32 mirror matches the slot's f64 state.
    synced: Vec<bool>,
    /// Per-slot: permanently escalated to f64 sweeps.
    f64_mode: Vec<bool>,
    f32_epochs: Vec<usize>,
    /// Per-slot: made at least one update in the current f32 sweep.
    updated: Vec<bool>,
    /// Per-column scratch of the f32 sweep.
    act: Vec<usize>,
    g32: Vec<f32>,
    delta32: Vec<f32>,
    /// Live-slot partition rebuilt each sweep.
    f32_slots: Vec<usize>,
    f64_slots: Vec<usize>,
    f64_scratch: SweepScratch,
}

impl BatchF32Strategy {
    /// Build the strategy (and the f32 design shadow) for one grid.
    pub fn new<D: DesignOps>(x: &D) -> Self {
        BatchF32Strategy {
            shadow: x.shadow_f32(),
            beta32: Vec::new(),
            r32: Vec::new(),
            norms32: Vec::new(),
            synced: Vec::new(),
            f64_mode: Vec::new(),
            f32_epochs: Vec::new(),
            updated: Vec::new(),
            act: Vec::new(),
            g32: Vec::new(),
            delta32: Vec::new(),
            f32_slots: Vec::new(),
            f64_slots: Vec::new(),
            f64_scratch: SweepScratch::default(),
        }
    }

    /// True once `slot` has escalated to f64 sweeps.
    pub fn slot_escalated(&self, slot: usize) -> bool {
        self.f64_mode.get(slot).copied().unwrap_or(false)
    }

    fn ensure_slots(&mut self, slots: usize) {
        if self.synced.len() < slots {
            self.synced.resize(slots, false);
            self.f64_mode.resize(slots, false);
            self.f32_epochs.resize(slots, 0);
            self.updated.resize(slots, false);
        }
    }
}

impl<D: DesignOps, P: Penalty> BatchStrategy<D, P> for BatchF32Strategy {
    fn sweep(&mut self, x: &D, s: &mut LaneSweep<'_>, penalty: &P) {
        let (n, p) = (s.n, s.p);
        let slots_total = if p > 0 { s.beta.len() / p } else { 0 };
        self.ensure_slots(slots_total);
        // f32 lane tiles get the same shard-local first touch as the
        // f64 buffers (see solve_grid_penalty's lane-buffer setup).
        if self.beta32.len() < slots_total * p {
            crate::util::par::resize_first_touch(&mut self.beta32, slots_total * p);
        }
        if self.r32.len() < slots_total * n {
            crate::util::par::resize_first_touch(&mut self.r32, slots_total * n);
        }
        if self.norms32.len() != s.norms_sq.len() {
            self.norms32 = s.norms_sq.iter().map(|&v| v as f32).collect();
        }
        let BatchF32Strategy {
            shadow,
            beta32,
            r32,
            norms32,
            synced,
            f64_mode,
            f32_epochs,
            updated,
            act,
            g32,
            delta32,
            f32_slots,
            f64_slots,
            f64_scratch,
        } = self;

        f32_slots.clear();
        f64_slots.clear();
        for &slot in s.live {
            if f64_mode[slot] {
                f64_slots.push(slot);
            } else if !P::IS_L1 {
                // The f32 fast path only implements the plain ℓ₁ prox;
                // other penalties escalate at load. No promotion needed:
                // the slot's f64 (β, r) set by `load_lane` is already
                // authoritative (the f32 mirror was never synced).
                f64_mode[slot] = true;
                f64_slots.push(slot);
            } else {
                f32_slots.push(slot);
            }
        }

        // ---- f32 lanes: sync mirrors, one interleaved f32 sweep ----
        for &slot in f32_slots.iter() {
            updated[slot] = false;
            if !synced[slot] {
                for (d, &v) in
                    beta32[slot * p..(slot + 1) * p].iter_mut().zip(&s.beta[slot * p..])
                {
                    *d = v as f32;
                }
                for (d, &v) in r32[slot * n..(slot + 1) * n].iter_mut().zip(&s.r[slot * n..]) {
                    *d = v as f32;
                }
                synced[slot] = true;
            }
        }
        if !f32_slots.is_empty() {
            for j in 0..p {
                let nrm = norms32[j];
                if nrm <= 0.0 {
                    // ‖x_j‖² zero, or underflowed to 0 in f32: leave the
                    // column to the (eventual) f64 phase of each lane.
                    continue;
                }
                act.clear();
                for &slot in f32_slots.iter() {
                    if !s.screening[slot].is_screened(j) {
                        act.push(slot);
                    }
                }
                if act.is_empty() {
                    continue;
                }
                g32.clear();
                g32.resize(act.len(), 0.0);
                shadow.col_dot_lanes(j, r32, n, act, g32);
                delta32.clear();
                let mut any_update = false;
                for (t, &slot) in act.iter().enumerate() {
                    let bj = &mut beta32[slot * p + j];
                    let old = *bj;
                    let new =
                        soft_threshold_f32(old + g32[t] / nrm, s.lambdas[slot] as f32 / nrm);
                    *bj = new;
                    let d = old - new;
                    if d != 0.0 {
                        any_update = true;
                        updated[slot] = true;
                    }
                    delta32.push(d);
                }
                if any_update {
                    shadow.col_axpy_lanes(j, delta32, r32, n, act);
                }
            }
            // Escalation: a zero-update f32 epoch is an exact f32 fixed
            // point; the epoch cap backstops f32 limit cycles.
            for &slot in f32_slots.iter() {
                f32_epochs[slot] += 1;
                if !updated[slot] || f32_epochs[slot] >= MAX_F32_EPOCHS {
                    let beta_slot = &mut s.beta[slot * p..(slot + 1) * p];
                    for (b, &b32) in beta_slot.iter_mut().zip(&beta32[slot * p..]) {
                        *b = b32 as f64;
                    }
                    primal::residual(x, s.y, beta_slot, &mut s.r[slot * n..(slot + 1) * n]);
                    f64_mode[slot] = true;
                }
            }
        }

        // ---- escalated lanes: one interleaved f64 sweep (serial) ----
        if !f64_slots.is_empty() {
            let ctx = SweepCtx {
                n,
                p,
                slot_base: 0,
                lambdas: s.lambdas,
                screening: s.screening,
                norms_sq: s.norms_sq,
            };
            cd_sweep_slots(x, &ctx, f64_slots, s.beta, s.r, f64_scratch, penalty);
        }
    }

    fn slot_loaded(&mut self, slot: usize) {
        self.ensure_slots(slot + 1);
        self.synced[slot] = false;
        self.f64_mode[slot] = false;
        self.f32_epochs[slot] = 0;
    }

    fn sync_slot_state(
        &mut self,
        x: &D,
        y: &[f64],
        slot: usize,
        beta_slot: &mut [f64],
        r_slot: &mut [f64],
    ) {
        if self.slot_escalated(slot) || !self.synced.get(slot).copied().unwrap_or(false) {
            // f64 state is already authoritative.
            return;
        }
        let p = beta_slot.len();
        for (b, &b32) in beta_slot.iter_mut().zip(&self.beta32[slot * p..]) {
            *b = b32 as f64;
        }
        primal::residual(x, y, beta_slot, r_slot);
        // Screening may mutate (β, r) right after the check; re-sync the
        // f32 mirror at the next sweep.
        self.synced[slot] = false;
    }
}

/// Load grid cell `grid_idx` (λ = `lambda`) into slot `slot`: β from the
/// current warm-start seed, residual via one matvec, fresh dual /
/// screening state.
fn load_lane<D: DesignOps>(
    ws: &mut BatchWorkspace,
    x: &D,
    y: &[f64],
    slot: usize,
    grid_idx: usize,
    lambda: f64,
    cfg: &BatchConfig,
    start: &Instant,
) {
    let n = x.n();
    let p = x.p();
    let BatchWorkspace { beta, r, lane_lambda, dual, scratch, screening, meta, seed_beta, .. } = ws;
    lane_lambda[slot] = lambda;
    meta[slot] = LaneMeta { grid_idx, epochs: 0, t0: start.elapsed().as_secs_f64() };
    let beta_slot = &mut beta[slot * p..(slot + 1) * p];
    beta_slot.copy_from_slice(seed_beta);
    let r_slot = &mut r[slot * n..(slot + 1) * n];
    primal::residual(x, y, beta_slot, r_slot);
    dual[slot].reset(n, p, cfg.k.max(1), cfg.extrapolate, cfg.best_dual);
    scratch[slot].prepare(n, p);
    screening[slot].reset_all_active(p);
}

/// Solve every λ in `grid` (descending, as produced by
/// [`lambda_grid`](crate::solvers::path::lambda_grid)) with B
/// interleaved lanes. Returns one [`BatchLaneResult`] per grid point, in
/// grid order.
///
/// `beta0` seeds the first B lanes (and the warm-start chain) — `None`
/// starts from zeros, which is exact for the conventional λ_max-anchored
/// grid.
///
/// Shorthand for [`solve_grid_penalty`] with the plain ℓ₁ penalty.
pub fn solve_grid<D: DesignOps, S: BatchStrategy<D, L1>>(
    x: &D,
    y: &[f64],
    grid: &[f64],
    beta0: Option<&[f64]>,
    cfg: &BatchConfig,
    ws: &mut BatchWorkspace,
    strategy: &mut S,
) -> Vec<BatchLaneResult> {
    solve_grid_penalty(x, y, grid, beta0, cfg, ws, strategy, &L1)
}

/// Penalty-generic [`solve_grid`]: B interleaved lanes of
/// `½‖y − Xβ‖² + Ω_λ(β)` for any separable [`Penalty`] (ℓ₁, elastic net,
/// weighted ℓ₁). Each lane's dual point, gap and Gap Safe screening go
/// through the penalty-aware machinery; the `P = L1` instantiation takes
/// the historical code paths bit for bit (pinned against the sequential
/// engine in `tests/prop_batch_path.rs`).
#[allow(clippy::too_many_arguments)]
pub fn solve_grid_penalty<D: DesignOps, P: Penalty, S: BatchStrategy<D, P>>(
    x: &D,
    y: &[f64],
    grid: &[f64],
    beta0: Option<&[f64]>,
    cfg: &BatchConfig,
    ws: &mut BatchWorkspace,
    strategy: &mut S,
    penalty: &P,
) -> Vec<BatchLaneResult> {
    debug_assert!(P::SEPARABLE, "batched lanes require a coordinate-separable penalty");
    let n = x.n();
    let p = x.p();
    assert_eq!(y.len(), n);
    if grid.is_empty() {
        return Vec::new();
    }
    // lanes = 0 → autotuned from the problem shape (see `auto_lanes`).
    let lanes = if cfg.lanes == 0 { auto_lanes(n) } else { cfg.lanes };
    let b = lanes.max(1).min(grid.len());
    let start = Instant::now();

    // ---- shared design caches ----
    crate::solvers::engine::fill_norm_caches(x, &mut ws.norms_sq, &mut ws.col_norms);

    // ---- lane buffers (capacity reused across grids) ----
    // First allocation goes through the pool so each shard of the lane
    // tiles is first-touched by the worker that sweeps it (shard-local
    // NUMA placement); contents are identical to a plain resize.
    ws.beta.clear();
    crate::util::par::resize_first_touch(&mut ws.beta, b * p);
    ws.r.clear();
    crate::util::par::resize_first_touch(&mut ws.r, b * n);
    ws.lane_lambda.clear();
    ws.lane_lambda.resize(b, 0.0);
    ws.dual.resize_with(b, DualState::default);
    ws.scratch.resize_with(b, DualScratch::default);
    ws.screening.resize_with(b, ScreeningState::default);
    ws.meta.clear();
    ws.meta.resize(b, LaneMeta::default());
    ws.lane_recoveries.clear();
    ws.lane_recoveries.resize(b, 0);
    ws.lane_faults.iter_mut().for_each(Vec::clear);
    ws.lane_faults.resize_with(b, Vec::new);
    ws.seed_beta.clear();
    match beta0 {
        Some(seed) => {
            assert_eq!(seed.len(), p);
            ws.seed_beta.extend_from_slice(seed);
        }
        None => ws.seed_beta.resize(p, 0.0),
    }

    let mut results: Vec<BatchLaneResult> = Vec::with_capacity(grid.len());
    let mut next_grid = 0usize;
    // Grid index backing `seed_beta` (deepest retired so far).
    let mut seed_idx: Option<usize> = None;

    ws.live.clear();
    for slot in 0..b {
        load_lane(ws, x, y, slot, next_grid, grid[next_grid], cfg, &start);
        strategy.slot_loaded(slot);
        ws.live.push(slot);
        next_grid += 1;
    }

    while !ws.live.is_empty() {
        // ---- one interleaved epoch over every live lane ----
        {
            let BatchWorkspace {
                norms_sq,
                beta,
                r,
                lane_lambda,
                screening,
                live,
                sweep,
                sorted_live,
                group_scratch,
                ..
            } = ws;
            let mut ctx = LaneSweep {
                n,
                p,
                y,
                lambdas: lane_lambda.as_slice(),
                live: live.as_slice(),
                screening: screening.as_slice(),
                norms_sq: norms_sq.as_slice(),
                beta: beta.as_mut_slice(),
                r: r.as_mut_slice(),
                scratch: sweep,
                sorted_live,
                group_scratch,
            };
            strategy.sweep(x, &mut ctx, penalty);
        }

        // ---- per-lane gap checks, screening, retirement, refill ----
        let mut li = 0;
        while li < ws.live.len() {
            let slot = ws.live[li];
            ws.meta[slot].epochs += 1;
            let epochs = ws.meta[slot].epochs;
            let at_cap = epochs >= cfg.max_epochs;
            if epochs % cfg.gap_freq != 0 && !at_cap {
                li += 1;
                continue;
            }
            let lambda = ws.lane_lambda[slot];
            let (gap, converged, fault) = {
                let BatchWorkspace { beta, r, dual, scratch, screening, col_norms, .. } = ws;
                let r_slot = &mut r[slot * n..(slot + 1) * n];
                let beta_slot = &mut beta[slot * p..(slot + 1) * p];
                // Reduced-precision strategies promote their iterate and
                // recompute r exactly here; everything below (dual point,
                // gap, screening, stop test) then runs on exact f64.
                strategy.sync_slot_state(x, y, slot, beta_slot, r_slot);
                cfg.faults.inject_nan_residual(epochs, r_slot);
                // The penalty-generic dual / primal / screening calls all
                // delegate to the historical ℓ₁ routines when P = L1, so
                // the default path's bits are unchanged.
                dual[slot].update_penalty(x, y, lambda, r_slot, &mut scratch[slot], penalty);
                let p_val = primal::penalty_primal_from_residual(r_slot, beta_slot, lambda, penalty);
                let gap = p_val - dual[slot].dval;
                // ---- per-lane non-finite watchdog ----
                let fault = if !gap.is_finite() {
                    Some(if !p_val.is_finite() {
                        FaultKind::NonFiniteResidual
                    } else if !dual[slot].dval.is_finite() {
                        FaultKind::NonFiniteDual
                    } else {
                        FaultKind::NonFiniteGap
                    })
                } else {
                    None
                };
                let converged = fault.is_none() && gap <= cfg.tol;
                // Screen only while unconverged (same invariant as the
                // sequential engine: the reported (β, gap) pair is the
                // one that passed the stopping test). Never screen off a
                // corrupted gap.
                if cfg.screen && !converged && fault.is_none() {
                    screening[slot].screen_penalty(
                        x,
                        &dual[slot].xtheta,
                        col_norms,
                        gap,
                        lambda,
                        penalty,
                        beta_slot,
                        r_slot,
                    );
                }
                (gap, converged, fault)
            };
            if let Some(kind) = fault {
                if ws.lane_recoveries[slot] < MAX_RECOVERIES {
                    // Roll the lane back to its certified warm-start
                    // seed: reload the same grid cell (exact residual
                    // recompute, fresh dual ring + screening state),
                    // keeping the epoch count so `max_epochs` still
                    // bounds this lane's total work.
                    ws.lane_recoveries[slot] += 1;
                    ws.lane_faults[slot].push(FaultEvent {
                        kind,
                        epoch: epochs,
                        action: RecoveryAction::Restarted,
                    });
                    let grid_idx = ws.meta[slot].grid_idx;
                    load_lane(ws, x, y, slot, grid_idx, lambda, cfg, &start);
                    strategy.slot_loaded(slot);
                    ws.meta[slot].epochs = epochs;
                    li += 1;
                    continue;
                }
                // Retry budget exhausted: quarantine the grid cell —
                // retire it unconverged on the certified seed with the
                // trivial +∞ certificate (never NaN), without poisoning
                // the warm-start chain.
                ws.lane_faults[slot].push(FaultEvent {
                    kind,
                    epoch: epochs,
                    action: RecoveryAction::Quarantined,
                });
                let meta = ws.meta[slot].clone();
                let status = SolveOutcome::from_run(
                    false,
                    f64::INFINITY,
                    epochs,
                    std::mem::take(&mut ws.lane_faults[slot]),
                );
                results.push(BatchLaneResult {
                    grid_idx: meta.grid_idx,
                    lambda,
                    beta: ws.seed_beta.clone(),
                    gap: f64::INFINITY,
                    epochs,
                    converged: false,
                    seconds: start.elapsed().as_secs_f64() - meta.t0,
                    status,
                });
                if next_grid < grid.len() {
                    load_lane(ws, x, y, slot, next_grid, grid[next_grid], cfg, &start);
                    strategy.slot_loaded(slot);
                    ws.lane_recoveries[slot] = 0;
                    next_grid += 1;
                    li += 1;
                } else {
                    ws.live.swap_remove(li);
                }
                continue;
            }
            if converged || at_cap {
                let meta = ws.meta[slot].clone();
                let beta_out = ws.beta[slot * p..(slot + 1) * p].to_vec();
                // The deepest retired solution seeds future lanes: on a
                // descending grid it is the closest solved neighbour of
                // every still-unassigned λ.
                let deeper = match seed_idx {
                    None => true,
                    Some(s) => meta.grid_idx > s,
                };
                if deeper {
                    ws.seed_beta.clear();
                    ws.seed_beta.extend_from_slice(&beta_out);
                    seed_idx = Some(meta.grid_idx);
                }
                let status = SolveOutcome::from_run(
                    converged,
                    gap,
                    epochs,
                    std::mem::take(&mut ws.lane_faults[slot]),
                );
                results.push(BatchLaneResult {
                    grid_idx: meta.grid_idx,
                    lambda,
                    beta: beta_out,
                    gap,
                    epochs,
                    converged,
                    seconds: start.elapsed().as_secs_f64() - meta.t0,
                    status,
                });
                if next_grid < grid.len() {
                    load_lane(ws, x, y, slot, next_grid, grid[next_grid], cfg, &start);
                    strategy.slot_loaded(slot);
                    ws.lane_recoveries[slot] = 0;
                    next_grid += 1;
                    li += 1;
                } else {
                    // The slot swapped into position `li` has not been
                    // checked this round yet, so `li` stays put.
                    ws.live.swap_remove(li);
                }
            } else {
                li += 1;
            }
        }

        // ---- wall-clock budget ----
        if let Some(limit) = cfg.max_seconds {
            if start.elapsed().as_secs_f64() >= limit {
                // Retire every in-flight lane unconverged; already
                // retired cells keep their certificates and unassigned
                // cells are not attempted (partial-but-certified).
                for li in 0..ws.live.len() {
                    let slot = ws.live[li];
                    let meta = ws.meta[slot].clone();
                    let status = SolveOutcome::from_run(
                        false,
                        f64::INFINITY,
                        meta.epochs,
                        std::mem::take(&mut ws.lane_faults[slot]),
                    );
                    results.push(BatchLaneResult {
                        grid_idx: meta.grid_idx,
                        lambda: ws.lane_lambda[slot],
                        beta: ws.beta[slot * p..(slot + 1) * p].to_vec(),
                        gap: f64::INFINITY,
                        epochs: meta.epochs,
                        converged: false,
                        seconds: start.elapsed().as_secs_f64() - meta.t0,
                        status,
                    });
                }
                ws.live.clear();
                break;
            }
        }
    }

    results.sort_by_key(|res| res.grid_idx);
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lasso::dual;
    use crate::solvers::cd::{cd_solve, CdConfig};
    use crate::solvers::path::lambda_grid;

    fn cfg(tol: f64, lanes: usize) -> BatchConfig {
        BatchConfig { tol, lanes, ..Default::default() }
    }

    #[test]
    fn single_lane_matches_sequential_cd() {
        // B = 1 degenerates to the sequential engine's schedule: each
        // grid point must converge to the same gap-certified objective.
        let ds = crate::data::synth::leukemia_mini(60);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.1, 4);
        let mut ws = BatchWorkspace::new();
        let tol = 1e-9;
        let out = solve_grid(&ds.x, &ds.y, &grid, None, &cfg(tol, 1), &mut ws, &mut BatchCdStrategy);
        assert_eq!(out.len(), grid.len());
        for (i, lane) in out.iter().enumerate() {
            assert_eq!(lane.grid_idx, i);
            assert!(lane.converged, "λ#{i} converged");
            assert!(lane.gap <= tol, "λ#{i} gap {}", lane.gap);
            let reference = cd_solve(
                &ds.x,
                &ds.y,
                grid[i],
                None,
                &CdConfig { tol: tol / 10.0, screen: true, ..Default::default() },
            );
            let p_batch = crate::lasso::primal::primal(&ds.x, &ds.y, &lane.beta, grid[i]);
            let p_ref = crate::lasso::primal::primal(&ds.x, &ds.y, &reference.beta, grid[i]);
            assert!(p_batch - p_ref <= 2.0 * tol, "λ#{i}: {p_batch} vs {p_ref}");
        }
    }

    #[test]
    fn more_lanes_than_grid_points() {
        let ds = crate::data::synth::leukemia_mini(61);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.2, 3);
        let mut ws = BatchWorkspace::new();
        let out =
            solve_grid(&ds.x, &ds.y, &grid, None, &cfg(1e-8, 16), &mut ws, &mut BatchCdStrategy);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|l| l.converged));
        // grid-ordered results
        for w in out.windows(2) {
            assert!(w[0].grid_idx < w[1].grid_idx);
        }
    }

    #[test]
    fn lambda_at_lambda_max_retires_with_empty_support() {
        let ds = crate::data::synth::leukemia_mini(62);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = [lmax * 1.01, lmax * 0.5];
        let mut ws = BatchWorkspace::new();
        let out =
            solve_grid(&ds.x, &ds.y, &grid, None, &cfg(1e-8, 2), &mut ws, &mut BatchCdStrategy);
        assert!(out[0].converged);
        assert_eq!(crate::lasso::primal::support_size(&out[0].beta), 0);
        assert!(crate::lasso::primal::support_size(&out[1].beta) > 0);
    }

    #[test]
    fn workspace_reuse_is_equivalent_to_fresh() {
        let ds = crate::data::synth::leukemia_mini(63);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.1, 6);
        let c = cfg(1e-9, 3);
        let mut fresh = BatchWorkspace::new();
        let a = solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut fresh, &mut BatchCdStrategy);
        let mut reused = BatchWorkspace::new();
        // dirty the workspace with a different grid and lane count first
        let other = lambda_grid(lmax, 0.5, 2);
        let _ =
            solve_grid(&ds.x, &ds.y, &other, None, &cfg(1e-6, 2), &mut reused, &mut BatchCdStrategy);
        let b = solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut reused, &mut BatchCdStrategy);
        assert_eq!(a.len(), b.len());
        for (la, lb) in a.iter().zip(&b) {
            assert_eq!(la.epochs, lb.epochs);
            assert_eq!(la.beta, lb.beta);
        }
    }

    #[test]
    fn auto_lanes_tracks_problem_shape() {
        // tiny residuals → wide batches; huge residuals → few lanes
        assert_eq!(auto_lanes(1), 32);
        assert_eq!(auto_lanes(100), 32);
        assert_eq!(auto_lanes(1_000_000), 2);
        assert!(auto_lanes(10_000) >= auto_lanes(100_000));
        for n in [1usize, 50, 5_000, 500_000, 50_000_000] {
            let b = auto_lanes(n);
            assert!((2..=32).contains(&b), "n={n} → B={b}");
        }
    }

    #[test]
    fn lanes_zero_resolves_to_auto_and_converges() {
        let ds = crate::data::synth::leukemia_mini(65);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.1, 6);
        let tol = 1e-9;
        let auto_cfg = BatchConfig { tol, ..Default::default() };
        assert_eq!(auto_cfg.lanes, 0, "default is auto");
        let mut ws = BatchWorkspace::new();
        let auto = solve_grid(&ds.x, &ds.y, &grid, None, &auto_cfg, &mut ws, &mut BatchCdStrategy);
        assert!(auto.iter().all(|l| l.converged));
        // explicit override at the resolved value is bit-identical
        let n = crate::data::design::DesignOps::n(&ds.x);
        let explicit_cfg = BatchConfig { tol, lanes: auto_lanes(n), ..Default::default() };
        let mut ws2 = BatchWorkspace::new();
        let explicit =
            solve_grid(&ds.x, &ds.y, &grid, None, &explicit_cfg, &mut ws2, &mut BatchCdStrategy);
        assert_eq!(auto.len(), explicit.len());
        for (a, e) in auto.iter().zip(&explicit) {
            assert_eq!(a.beta, e.beta);
            assert_eq!(a.epochs, e.epochs);
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_scope_bitwise() {
        // The lane-sharded pooled sweep must be bit-identical to the
        // serial interleaved sweep (lanes are independent within an
        // epoch); `run_serial` forces the serial path for the reference.
        // `dense_scan_stress` (64 × 8192) crosses the work threshold
        // (live × p × n = 4·8192·64 ≈ 2·10⁶ ≥ 2¹⁸), so the pooled path
        // actually runs whenever threads > 1.
        let big = crate::data::synth::dense_scan_stress(77);
        let minis = [crate::data::synth::leukemia_mini(66), crate::data::synth::finance_mini(66)];
        for ds in minis.iter().chain(std::iter::once(&big)) {
            let lmax = dual::lambda_max(&ds.x, &ds.y);
            let grid = lambda_grid(lmax, 0.3, 6);
            let c = cfg(1e-6, 4);
            let mut ws = BatchWorkspace::new();
            let pooled = solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut ws, &mut BatchCdStrategy);
            let mut ws2 = BatchWorkspace::new();
            let serial = crate::util::par::run_serial(|| {
                solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut ws2, &mut BatchCdStrategy)
            });
            assert_eq!(pooled.len(), serial.len());
            for (a, b) in pooled.iter().zip(&serial) {
                assert_eq!(a.beta, b.beta, "λ#{} ({})", a.grid_idx, ds.name);
                assert_eq!(a.epochs, b.epochs);
                assert_eq!(a.gap.to_bits(), b.gap.to_bits());
            }
        }
    }

    #[test]
    fn f32_lanes_match_f64_grid() {
        // Every grid point solved by the f32 strategy is f64-certified
        // at the same ε, so objectives agree with the f64 strategy to
        // the sum of tolerances (the iterates themselves differ: the
        // f32 phase takes a different trajectory).
        for ds in [crate::data::synth::leukemia_mini(68), crate::data::synth::finance_mini(68)] {
            let lmax = dual::lambda_max(&ds.x, &ds.y);
            let grid = lambda_grid(lmax, 0.1, 5);
            let tol = 1e-8;
            let c64 = cfg(tol, 3);
            let c32 = BatchConfig { precision: Precision::F32, ..c64.clone() };
            let mut ws = BatchWorkspace::new();
            let a = solve_grid(&ds.x, &ds.y, &grid, None, &c64, &mut ws, &mut BatchCdStrategy);
            let mut ws2 = BatchWorkspace::new();
            let mut strat = BatchF32Strategy::new(&ds.x);
            let b = solve_grid(&ds.x, &ds.y, &grid, None, &c32, &mut ws2, &mut strat);
            assert_eq!(a.len(), b.len());
            for (la, lb) in a.iter().zip(&b) {
                assert!(lb.converged, "λ#{} ({})", lb.grid_idx, ds.name);
                assert!(lb.gap <= tol);
                let pa = crate::lasso::primal::primal(&ds.x, &ds.y, &la.beta, la.lambda);
                let pb = crate::lasso::primal::primal(&ds.x, &ds.y, &lb.beta, lb.lambda);
                assert!(
                    (pa - pb).abs() <= 2.0 * tol,
                    "λ#{} ({}): {pa} vs {pb}",
                    la.grid_idx,
                    ds.name
                );
            }
            // ε = 1e-8 sits far below f32 resolution: every lane must
            // have escalated before certifying.
            let b_lanes = c32.lanes.min(grid.len());
            assert!((0..b_lanes).all(|s| strat.slot_escalated(s)));
        }
    }

    #[test]
    fn f32_lanes_are_pool_invariant() {
        // The f32 sweep never touches the worker pool, so pooled and
        // forced-serial runs must be bit-identical.
        let ds = crate::data::synth::leukemia_mini(69);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.2, 4);
        let c =
            BatchConfig { tol: 1e-7, lanes: 2, precision: Precision::F32, ..Default::default() };
        let mut ws = BatchWorkspace::new();
        let mut s1 = BatchF32Strategy::new(&ds.x);
        let pooled = solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut ws, &mut s1);
        let mut ws2 = BatchWorkspace::new();
        let mut s2 = BatchF32Strategy::new(&ds.x);
        let serial = crate::util::par::run_serial(|| {
            solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut ws2, &mut s2)
        });
        assert_eq!(pooled.len(), serial.len());
        for (a, b) in pooled.iter().zip(&serial) {
            assert_eq!(a.beta, b.beta);
            assert_eq!(a.epochs, b.epochs);
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
        }
    }

    #[test]
    fn empty_grid_is_empty() {
        let ds = crate::data::synth::leukemia_mini(64);
        let mut ws = BatchWorkspace::new();
        let out = solve_grid(
            &ds.x,
            &ds.y,
            &[],
            None,
            &BatchConfig::default(),
            &mut ws,
            &mut BatchCdStrategy,
        );
        assert!(out.is_empty());
    }
}
