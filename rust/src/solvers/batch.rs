//! Batched multi-λ solver engine: several λ's of a path solved
//! concurrently over shared design sweeps.
//!
//! # Why
//!
//! The paper's headline experiments (Table 1, Fig. 4) are *path*
//! computations: a decreasing λ grid solved with warm starts, where Gap
//! Safe sequential rules (Ndiaye et al.) make each successive λ cheaper.
//! The sequential driver in [`crate::solvers::path`] walks the grid one
//! λ at a time, which means every CD epoch re-streams the design matrix
//! for a *single* residual. On large problems the epoch is memory-bound:
//! the dominant cost is loading each column's values (and, for CSC,
//! decoding its row indices), not the multiply-adds.
//!
//! The batch engine amortizes that traffic. B *lanes* — adjacent grid
//! cells λ_{k}, …, λ_{k+B−1}, each with its own β, residual, dual state
//! and screening state — run their Algorithm-1 CD epochs interleaved
//! over a **single pass over the columns**: one
//! [`DesignOps::col_dot_lanes`] computes `x_jᵀr_k` for every live lane
//! with the column loaded once, and one [`DesignOps::col_axpy_lanes`]
//! applies all lane updates on the way out.
//!
//! # Lane lifecycle
//!
//! ```text
//!  λ grid (descending) ──┬─▶ lane 0 ─ epochs ─ gap ≤ ε ─▶ retire ─┐
//!                        ├─▶ lane 1 ─ epochs ─ gap ≤ ε ─▶ retire ─┼─▶ results
//!                        └─▶ …       (per-lane Gap Safe screening) ┘
//!        refill: a retired slot loads the next grid cell, warm-started
//!        from the deepest (smallest-λ) solution retired so far
//! ```
//!
//! Every `gap_freq` epochs each lane runs its own duality-gap check
//! (θ_res and, via the per-lane extrapolation ring, θ_accel — Def. 1 /
//! Eq. 13 of the paper) and dynamic Gap Safe screening (Eq. 9; the
//! `d_j` pricing of Eq. 10–11). A converged lane *retires*: its solution
//! is recorded, its slot immediately loads the next λ from the grid, and
//! the new lane warm-starts from the most-converged (deepest-in-grid)
//! retired solution — the batched analogue of the sequential path's
//! β̂(λ_i) → λ_{i+1} warm start.
//!
//! # Equivalence
//!
//! Each lane runs exactly the Algorithm-1 epoch/check sequence of the
//! sequential engine, so every grid point's solution is gap-certified at
//! the same ε; `tests/prop_batch_path.rs` pins batched ≡ sequential
//! (supports and objectives) on dense and sparse designs.

use crate::data::design::DesignOps;
use crate::lasso::primal;
use crate::screening::ScreeningState;
use crate::solvers::{DualScratch, DualState};
use crate::util::soft_threshold;
use std::time::Instant;

/// Configuration of the batched multi-λ engine (the union of the
/// sequential [`EngineConfig`](crate::solvers::engine::EngineConfig)
/// knobs plus the lane count B).
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Per-λ duality-gap tolerance ε.
    pub tol: f64,
    /// Per-lane epoch cap (a lane retires unconverged at the cap).
    pub max_epochs: usize,
    /// Gap/dual evaluation frequency `f` in epochs (paper default: 10).
    pub gap_freq: usize,
    /// Extrapolation depth K (paper default: 5).
    pub k: usize,
    /// Compute θ_accel (Definition 1) per lane.
    pub extrapolate: bool,
    /// Keep the best dual point across checks (Eq. 13).
    pub best_dual: bool,
    /// Per-lane dynamic Gap Safe screening.
    pub screen: bool,
    /// Number of concurrent λ lanes B (clamped to the grid size; 1
    /// degenerates to the sequential engine's schedule).
    pub lanes: usize,
}

/// Default lane count: wide enough to amortize column traffic, small
/// enough that B residual lanes stay cache-resident on typical n.
pub const DEFAULT_LANES: usize = 8;

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            tol: 1e-6,
            max_epochs: 50_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
            best_dual: true,
            screen: true,
            lanes: DEFAULT_LANES,
        }
    }
}

/// One retired lane = one solved grid point.
#[derive(Debug, Clone)]
pub struct BatchLaneResult {
    /// Position in the input grid (results are returned grid-ordered).
    pub grid_idx: usize,
    pub lambda: f64,
    pub beta: Vec<f64>,
    /// Duality gap at retirement.
    pub gap: f64,
    /// Epochs this lane consumed.
    pub epochs: usize,
    pub converged: bool,
    /// Wall-clock seconds the lane was resident. Lanes share the sweep,
    /// so unlike the sequential path these intervals overlap.
    pub seconds: f64,
}

/// Per-slot bookkeeping (which grid cell the slot is solving).
#[derive(Debug, Clone, Default)]
struct LaneMeta {
    grid_idx: usize,
    epochs: usize,
    /// Seconds offset (from solve start) at which the lane was loaded.
    t0: f64,
}

/// Reusable state of the batch engine: B lanes of (β, r, dual state,
/// screening state) in lane-strided buffers, plus the shared design
/// caches and sweep scratch. Like the sequential
/// [`Workspace`](crate::solvers::engine::Workspace), buffers are
/// resized — never reallocated once warm — across grids.
#[derive(Default)]
pub struct BatchWorkspace {
    /// Cached `‖x_j‖²` (shared by every lane).
    norms_sq: Vec<f64>,
    /// Cached `‖x_j‖` for screening.
    col_norms: Vec<f64>,
    /// Lane-strided primal iterates: lane k's β is `beta[k·p .. (k+1)·p]`.
    beta: Vec<f64>,
    /// Lane-strided residuals: lane k's r is `r[k·n .. (k+1)·n]`.
    r: Vec<f64>,
    /// Per-slot λ.
    lane_lambda: Vec<f64>,
    /// Per-slot dual machinery (θ, Xᵀθ, extrapolation ring).
    dual: Vec<DualState>,
    /// Per-slot gap-check scratch (one extrapolation scratch per lane).
    scratch: Vec<DualScratch>,
    /// Per-slot dynamic screening state.
    screening: Vec<ScreeningState>,
    meta: Vec<LaneMeta>,
    /// Live slot ids.
    live: Vec<usize>,
    /// Sweep scratch: lanes active at the current column.
    act: Vec<usize>,
    /// Sweep scratch: per-active-lane correlations `x_jᵀr_k`.
    g: Vec<f64>,
    /// Sweep scratch: per-active-lane coefficient deltas.
    delta: Vec<f64>,
    /// Warm-start seed: the deepest (smallest-λ) retired solution.
    seed_beta: Vec<f64>,
}

impl BatchWorkspace {
    pub fn new() -> Self {
        BatchWorkspace::default()
    }
}

/// One interleaved sweep's view of the lane state, handed to a
/// [`BatchStrategy`]. Lane k's vectors are the strided slices
/// `beta[k·p..]` / `r[k·n..]`; only slots listed in `live` participate.
pub struct LaneSweep<'a> {
    pub n: usize,
    pub p: usize,
    /// Per-slot λ (indexed by slot id, not by position in `live`).
    pub lambdas: &'a [f64],
    /// Live slot ids.
    pub live: &'a [usize],
    /// Per-slot screening state (a lane skips its screened-out columns).
    pub screening: &'a [ScreeningState],
    /// Shared cached `‖x_j‖²`.
    pub norms_sq: &'a [f64],
    /// Lane-strided β (lanes × p).
    pub beta: &'a mut [f64],
    /// Lane-strided residuals (lanes × n).
    pub r: &'a mut [f64],
    /// Reusable per-column scratch: active slots at the column.
    pub act: &'a mut Vec<usize>,
    /// Reusable per-column scratch: correlations for `act`.
    pub g: &'a mut Vec<f64>,
    /// Reusable per-column scratch: deltas for `act`.
    pub delta: &'a mut Vec<f64>,
}

/// A batched solver strategy: one interleaved primal epoch over all live
/// lanes in a single pass over the columns. The batched analogue of
/// [`Strategy`](crate::solvers::engine::Strategy).
pub trait BatchStrategy<D: DesignOps> {
    /// Run one epoch for every live lane, updating each lane's (β, r).
    fn sweep(&mut self, x: &D, s: &mut LaneSweep<'_>);
}

/// Cyclic coordinate descent interleaved across lanes (Algorithm 1 per
/// lane, one design sweep for all of them): for each column j, the
/// correlations `x_jᵀr_k` of every lane still holding j are computed by
/// one [`DesignOps::col_dot_lanes`], the per-lane soft-threshold updates
/// are applied, and one [`DesignOps::col_axpy_lanes`] propagates all
/// residual updates.
pub struct BatchCdStrategy;

impl<D: DesignOps> BatchStrategy<D> for BatchCdStrategy {
    fn sweep(&mut self, x: &D, s: &mut LaneSweep<'_>) {
        let (n, p) = (s.n, s.p);
        let live: &[usize] = s.live;
        let lambdas: &[f64] = s.lambdas;
        let norms_sq: &[f64] = s.norms_sq;
        let screening: &[ScreeningState] = s.screening;
        for j in 0..p {
            let nrm = norms_sq[j];
            if nrm == 0.0 {
                continue;
            }
            s.act.clear();
            for &slot in live {
                if !screening[slot].is_screened(j) {
                    s.act.push(slot);
                }
            }
            if s.act.is_empty() {
                continue;
            }
            s.g.clear();
            s.g.resize(s.act.len(), 0.0);
            x.col_dot_lanes(j, s.r, n, s.act, s.g);
            s.delta.clear();
            let mut any_update = false;
            for (t, &slot) in s.act.iter().enumerate() {
                let bj = &mut s.beta[slot * p + j];
                let old = *bj;
                let new = soft_threshold(old + s.g[t] / nrm, lambdas[slot] / nrm);
                *bj = new;
                let d = old - new;
                any_update |= d != 0.0;
                s.delta.push(d);
            }
            if any_update {
                x.col_axpy_lanes(j, s.delta, s.r, n, s.act);
            }
        }
    }
}

/// Load grid cell `grid_idx` (λ = `lambda`) into slot `slot`: β from the
/// current warm-start seed, residual via one matvec, fresh dual /
/// screening state.
fn load_lane<D: DesignOps>(
    ws: &mut BatchWorkspace,
    x: &D,
    y: &[f64],
    slot: usize,
    grid_idx: usize,
    lambda: f64,
    cfg: &BatchConfig,
    start: &Instant,
) {
    let n = x.n();
    let p = x.p();
    let BatchWorkspace { beta, r, lane_lambda, dual, scratch, screening, meta, seed_beta, .. } = ws;
    lane_lambda[slot] = lambda;
    meta[slot] = LaneMeta { grid_idx, epochs: 0, t0: start.elapsed().as_secs_f64() };
    let beta_slot = &mut beta[slot * p..(slot + 1) * p];
    beta_slot.copy_from_slice(seed_beta);
    let r_slot = &mut r[slot * n..(slot + 1) * n];
    primal::residual(x, y, beta_slot, r_slot);
    dual[slot].reset(n, p, cfg.k.max(1), cfg.extrapolate, cfg.best_dual);
    scratch[slot].prepare(n, p);
    screening[slot].reset_all_active(p);
}

/// Solve every λ in `grid` (descending, as produced by
/// [`lambda_grid`](crate::solvers::path::lambda_grid)) with B
/// interleaved lanes. Returns one [`BatchLaneResult`] per grid point, in
/// grid order.
///
/// `beta0` seeds the first B lanes (and the warm-start chain) — `None`
/// starts from zeros, which is exact for the conventional λ_max-anchored
/// grid.
pub fn solve_grid<D: DesignOps, S: BatchStrategy<D>>(
    x: &D,
    y: &[f64],
    grid: &[f64],
    beta0: Option<&[f64]>,
    cfg: &BatchConfig,
    ws: &mut BatchWorkspace,
    strategy: &mut S,
) -> Vec<BatchLaneResult> {
    let n = x.n();
    let p = x.p();
    assert_eq!(y.len(), n);
    if grid.is_empty() {
        return Vec::new();
    }
    let b = cfg.lanes.max(1).min(grid.len());
    let start = Instant::now();

    // ---- shared design caches ----
    crate::solvers::engine::fill_norm_caches(x, &mut ws.norms_sq, &mut ws.col_norms);

    // ---- lane buffers (capacity reused across grids) ----
    ws.beta.clear();
    ws.beta.resize(b * p, 0.0);
    ws.r.clear();
    ws.r.resize(b * n, 0.0);
    ws.lane_lambda.clear();
    ws.lane_lambda.resize(b, 0.0);
    ws.dual.resize_with(b, DualState::default);
    ws.scratch.resize_with(b, DualScratch::default);
    ws.screening.resize_with(b, ScreeningState::default);
    ws.meta.clear();
    ws.meta.resize(b, LaneMeta::default());
    ws.seed_beta.clear();
    match beta0 {
        Some(seed) => {
            assert_eq!(seed.len(), p);
            ws.seed_beta.extend_from_slice(seed);
        }
        None => ws.seed_beta.resize(p, 0.0),
    }

    let mut results: Vec<BatchLaneResult> = Vec::with_capacity(grid.len());
    let mut next_grid = 0usize;
    // Grid index backing `seed_beta` (deepest retired so far).
    let mut seed_idx: Option<usize> = None;

    ws.live.clear();
    for slot in 0..b {
        load_lane(ws, x, y, slot, next_grid, grid[next_grid], cfg, &start);
        ws.live.push(slot);
        next_grid += 1;
    }

    while !ws.live.is_empty() {
        // ---- one interleaved epoch over every live lane ----
        {
            let BatchWorkspace {
                norms_sq, beta, r, lane_lambda, screening, live, act, g, delta, ..
            } = ws;
            let mut ctx = LaneSweep {
                n,
                p,
                lambdas: lane_lambda.as_slice(),
                live: live.as_slice(),
                screening: screening.as_slice(),
                norms_sq: norms_sq.as_slice(),
                beta: beta.as_mut_slice(),
                r: r.as_mut_slice(),
                act,
                g,
                delta,
            };
            strategy.sweep(x, &mut ctx);
        }

        // ---- per-lane gap checks, screening, retirement, refill ----
        let mut li = 0;
        while li < ws.live.len() {
            let slot = ws.live[li];
            ws.meta[slot].epochs += 1;
            let epochs = ws.meta[slot].epochs;
            let at_cap = epochs >= cfg.max_epochs;
            if epochs % cfg.gap_freq != 0 && !at_cap {
                li += 1;
                continue;
            }
            let lambda = ws.lane_lambda[slot];
            let (gap, converged) = {
                let BatchWorkspace { beta, r, dual, scratch, screening, col_norms, .. } = ws;
                let r_slot = &mut r[slot * n..(slot + 1) * n];
                let beta_slot = &mut beta[slot * p..(slot + 1) * p];
                dual[slot].update(x, y, lambda, r_slot, &mut scratch[slot]);
                let p_val = primal::primal_from_residual(r_slot, beta_slot, lambda);
                let gap = p_val - dual[slot].dval;
                let converged = gap <= cfg.tol;
                // Screen only while unconverged (same invariant as the
                // sequential engine: the reported (β, gap) pair is the
                // one that passed the stopping test).
                if cfg.screen && !converged {
                    screening[slot].screen(
                        x,
                        &dual[slot].xtheta,
                        col_norms,
                        gap,
                        lambda,
                        beta_slot,
                        r_slot,
                    );
                }
                (gap, converged)
            };
            if converged || at_cap {
                let meta = ws.meta[slot].clone();
                let beta_out = ws.beta[slot * p..(slot + 1) * p].to_vec();
                // The deepest retired solution seeds future lanes: on a
                // descending grid it is the closest solved neighbour of
                // every still-unassigned λ.
                let deeper = match seed_idx {
                    None => true,
                    Some(s) => meta.grid_idx > s,
                };
                if deeper {
                    ws.seed_beta.clear();
                    ws.seed_beta.extend_from_slice(&beta_out);
                    seed_idx = Some(meta.grid_idx);
                }
                results.push(BatchLaneResult {
                    grid_idx: meta.grid_idx,
                    lambda,
                    beta: beta_out,
                    gap,
                    epochs,
                    converged,
                    seconds: start.elapsed().as_secs_f64() - meta.t0,
                });
                if next_grid < grid.len() {
                    load_lane(ws, x, y, slot, next_grid, grid[next_grid], cfg, &start);
                    next_grid += 1;
                    li += 1;
                } else {
                    // The slot swapped into position `li` has not been
                    // checked this round yet, so `li` stays put.
                    ws.live.swap_remove(li);
                }
            } else {
                li += 1;
            }
        }
    }

    results.sort_by_key(|res| res.grid_idx);
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lasso::dual;
    use crate::solvers::cd::{cd_solve, CdConfig};
    use crate::solvers::path::lambda_grid;

    fn cfg(tol: f64, lanes: usize) -> BatchConfig {
        BatchConfig { tol, lanes, ..Default::default() }
    }

    #[test]
    fn single_lane_matches_sequential_cd() {
        // B = 1 degenerates to the sequential engine's schedule: each
        // grid point must converge to the same gap-certified objective.
        let ds = crate::data::synth::leukemia_mini(60);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.1, 4);
        let mut ws = BatchWorkspace::new();
        let tol = 1e-9;
        let out = solve_grid(&ds.x, &ds.y, &grid, None, &cfg(tol, 1), &mut ws, &mut BatchCdStrategy);
        assert_eq!(out.len(), grid.len());
        for (i, lane) in out.iter().enumerate() {
            assert_eq!(lane.grid_idx, i);
            assert!(lane.converged, "λ#{i} converged");
            assert!(lane.gap <= tol, "λ#{i} gap {}", lane.gap);
            let reference = cd_solve(
                &ds.x,
                &ds.y,
                grid[i],
                None,
                &CdConfig { tol: tol / 10.0, screen: true, ..Default::default() },
            );
            let p_batch = crate::lasso::primal::primal(&ds.x, &ds.y, &lane.beta, grid[i]);
            let p_ref = crate::lasso::primal::primal(&ds.x, &ds.y, &reference.beta, grid[i]);
            assert!(p_batch - p_ref <= 2.0 * tol, "λ#{i}: {p_batch} vs {p_ref}");
        }
    }

    #[test]
    fn more_lanes_than_grid_points() {
        let ds = crate::data::synth::leukemia_mini(61);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.2, 3);
        let mut ws = BatchWorkspace::new();
        let out =
            solve_grid(&ds.x, &ds.y, &grid, None, &cfg(1e-8, 16), &mut ws, &mut BatchCdStrategy);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|l| l.converged));
        // grid-ordered results
        for w in out.windows(2) {
            assert!(w[0].grid_idx < w[1].grid_idx);
        }
    }

    #[test]
    fn lambda_at_lambda_max_retires_with_empty_support() {
        let ds = crate::data::synth::leukemia_mini(62);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = [lmax * 1.01, lmax * 0.5];
        let mut ws = BatchWorkspace::new();
        let out =
            solve_grid(&ds.x, &ds.y, &grid, None, &cfg(1e-8, 2), &mut ws, &mut BatchCdStrategy);
        assert!(out[0].converged);
        assert_eq!(crate::lasso::primal::support_size(&out[0].beta), 0);
        assert!(crate::lasso::primal::support_size(&out[1].beta) > 0);
    }

    #[test]
    fn workspace_reuse_is_equivalent_to_fresh() {
        let ds = crate::data::synth::leukemia_mini(63);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.1, 6);
        let c = cfg(1e-9, 3);
        let mut fresh = BatchWorkspace::new();
        let a = solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut fresh, &mut BatchCdStrategy);
        let mut reused = BatchWorkspace::new();
        // dirty the workspace with a different grid and lane count first
        let other = lambda_grid(lmax, 0.5, 2);
        let _ =
            solve_grid(&ds.x, &ds.y, &other, None, &cfg(1e-6, 2), &mut reused, &mut BatchCdStrategy);
        let b = solve_grid(&ds.x, &ds.y, &grid, None, &c, &mut reused, &mut BatchCdStrategy);
        assert_eq!(a.len(), b.len());
        for (la, lb) in a.iter().zip(&b) {
            assert_eq!(la.epochs, lb.epochs);
            assert_eq!(la.beta, lb.beta);
        }
    }

    #[test]
    fn empty_grid_is_empty() {
        let ds = crate::data::synth::leukemia_mini(64);
        let mut ws = BatchWorkspace::new();
        let out = solve_grid(
            &ds.x,
            &ds.y,
            &[],
            None,
            &BatchConfig::default(),
            &mut ws,
            &mut BatchCdStrategy,
        );
        assert!(out.is_empty());
    }
}
