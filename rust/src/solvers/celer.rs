//! CELER (Algorithm 4): working-set solver with dual extrapolation —
//! *Constraint Elimination for the Lasso with Extrapolated Residuals*.
//!
//! Outer loop:
//! 1. build the best dual point among {θ^{t-1}, θ_inner^{t-1}, θ_res^t}
//!    (the inner point carries the *extrapolated* information — this is
//!    what Blitz structurally cannot use, §7);
//! 2. stop on the global duality gap;
//! 3. rank features by `d_j(θ)` and form the working set (safe doubling or
//!    pruning policy);
//! 4. approximately solve the subproblem on `X_{W_t}` with Algorithm 1
//!    (CD + dual extrapolation), warm-started.

use crate::data::design::{DesignMatrix, DesignOps};
use crate::lasso::{dual, primal, LassoProblem};
use crate::screening::d_score;
use crate::solvers::cd::{cd_solve, CdConfig};
use crate::solvers::SolveResult;
use crate::ws::{build_working_set, WsPolicy};
use std::time::Instant;

/// Per-outer-iteration record (drives Figs. 8/9 and the path reports).
#[derive(Debug, Clone)]
pub struct CelerIteration {
    /// 1-based outer iteration.
    pub t: usize,
    /// Global duality gap at the start of the iteration.
    pub gap: f64,
    /// Working-set size |W_t| (0 on the final, converged check).
    pub ws_size: usize,
    /// Support size |S_{β^{t-1}}|.
    pub support_size: usize,
    /// Epochs consumed by the inner solver.
    pub inner_epochs: usize,
    /// Wall-clock since solve start.
    pub seconds: f64,
    /// Which dual candidate won: 0 = previous, 1 = inner, 2 = residual.
    pub dual_winner: usize,
}

/// CELER configuration.
#[derive(Debug, Clone)]
pub struct CelerConfig {
    /// Global duality-gap tolerance ε.
    pub tol: f64,
    /// Maximum outer iterations.
    pub max_outer: usize,
    /// Working-set policy (size growth + pruning).
    pub ws: WsPolicy,
    /// Subproblem tolerance ratio ε̄ (prune mode: ε_t = ε̄·g_t).
    pub inner_tol_ratio: f64,
    /// Inner-solver epoch cap per outer iteration.
    pub max_inner_epochs: usize,
    /// Inner gap frequency f.
    pub gap_freq: usize,
    /// Extrapolation depth K.
    pub k: usize,
    /// Use dual extrapolation in the inner solver. Disabling this is the
    /// ablation that isolates the WS strategy from the dual point quality.
    pub extrapolate: bool,
}

impl Default for CelerConfig {
    fn default() -> Self {
        CelerConfig {
            tol: 1e-6,
            max_outer: 100,
            ws: WsPolicy::default(),
            inner_tol_ratio: 0.3,
            max_inner_epochs: 10_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
        }
    }
}

impl CelerConfig {
    /// Paper's "safe" variant (monotone doubling working sets, inner tol ε).
    pub fn safe() -> Self {
        CelerConfig { ws: WsPolicy::safe(), ..Default::default() }
    }
}

/// CELER output: solution + per-iteration trace.
#[derive(Debug, Clone)]
pub struct CelerOutput {
    pub result: SolveResult,
    pub iterations: Vec<CelerIteration>,
}

impl CelerOutput {
    pub fn support_size(&self) -> usize {
        self.result.support_size()
    }
    pub fn gap(&self) -> f64 {
        self.result.gap
    }
}

/// Solve a [`LassoProblem`] with CELER.
pub fn celer_solve(pb: &LassoProblem, cfg: &CelerConfig) -> CelerOutput {
    celer_solve_on(&pb.x, &pb.y, pb.lambda, None, cfg)
}

/// CELER on explicit data with optional warm start.
pub fn celer_solve_on(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
) -> CelerOutput {
    let (n, p) = (x.n(), x.p());
    let start = Instant::now();

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    let mut r = vec![0.0; n];
    primal::residual(x, y, &beta, &mut r);

    let col_norms: Vec<f64> = x.col_norms_sq().iter().map(|v| v.sqrt()).collect();

    // init: θ⁰ = θ⁰_inner = y / ‖Xᵀy‖_∞ (Algorithm 4)
    let lmax = dual::lambda_max(x, y).max(f64::MIN_POSITIVE);
    let mut theta: Vec<f64> = y.iter().map(|&v| v / lmax).collect();
    let mut theta_inner = theta.clone();

    // warm start: p₁ = |S_{β⁰}| when β⁰ ≠ 0 (Algorithm 4)
    let mut policy = cfg.ws;
    let s0 = primal::support_size(&beta);
    if s0 > 0 {
        policy.p1 = s0;
    }

    let mut iterations: Vec<CelerIteration> = Vec::new();
    let mut xtr = vec![0.0; p];
    let mut xtheta = vec![0.0; p];
    // Xᵀθ_inner, maintained by the rescale step (one design sweep serves
    // both the feasibility rescale and next iteration's pricing).
    let mut xtheta_inner = vec![0.0; p];
    x.xt_vec(&theta_inner, &mut xtheta_inner);
    let mut d_scores = vec![0.0; p];
    let mut prev_ws: Vec<usize> = primal::support(&beta);
    let mut prev_ws_size = 0usize;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut total_inner_epochs = 0usize;

    let mut prev_gap = f64::INFINITY;
    for t in 1..=cfg.max_outer {
        // ---- θ^t = argmax D over {θ^{t-1}, θ_inner^{t-1}, θ_res^t} ----
        x.xt_vec(&r, &mut xtr);
        let mut denom = lambda;
        for &v in xtr.iter() {
            denom = denom.max(v.abs());
        }
        let theta_res: Vec<f64> = r.iter().map(|&v| v / denom).collect();
        let winner = dual::best_dual_point(y, lambda, &[&theta, &theta_inner, &theta_res]);
        match winner {
            1 => theta.copy_from_slice(&theta_inner),
            2 => theta.copy_from_slice(&theta_res),
            _ => {}
        }

        // Pricing (d_j ranking) deliberately uses only the FRESH dual
        // candidates {θ_inner^{t-1}, θ_res^t}: a stale-but-tight θ^{t-1}
        // (e.g. the y/λ_max initialization at small λ) yields stale
        // priorities and can freeze the working set while the gap
        // stagnates. The gap/stopping test above still uses the monotone
        // argmax-of-three point, exactly as Algorithm 4 prescribes.
        // Correlations for θ_inner are cached from the rescale pass below
        // (§Perf: saves one full Xᵀ· sweep per outer iteration).
        let rank_winner =
            dual::best_dual_point(y, lambda, &[&theta_inner, &theta_res]);
        if rank_winner == 1 {
            for (o, &v) in xtheta.iter_mut().zip(xtr.iter()) {
                *o = v / denom;
            }
        } else {
            xtheta.copy_from_slice(&xtheta_inner);
        }

        // ---- global gap / stop ----
        let p_val = primal::primal_from_residual(&r, &beta, lambda);
        gap = p_val - dual::dual_objective(y, &theta, lambda);
        let support = primal::support(&beta);
        if gap <= cfg.tol {
            converged = true;
            iterations.push(CelerIteration {
                t,
                gap,
                ws_size: 0,
                support_size: support.len(),
                inner_epochs: 0,
                seconds: start.elapsed().as_secs_f64(),
                dual_winner: winner,
            });
            break;
        }

        // ---- working set ----
        for j in 0..p {
            d_scores[j] = d_score(xtheta[j].abs(), col_norms[j]);
            if d_scores[j].is_infinite() {
                // empty column: keep out of the WS by a huge finite score
                d_scores[j] = f64::MAX;
            }
        }
        // Stagnation safeguard: when an outer iteration barely improved
        // the gap, the working set was too small (or mis-prioritized) —
        // fall back to monotone doubling for this round, which restores
        // the safe variant's convergence guarantee.
        let stagnated = t >= 2 && gap > 0.9 * prev_gap;
        prev_gap = gap;
        let forced_vec: Vec<usize>;
        let forced: &[usize] = if policy.prune && !stagnated {
            &support
        } else if policy.prune {
            // stagnation in prune mode: keep the previous WS too
            forced_vec = {
                let mut f = prev_ws.clone();
                f.extend(support.iter().copied());
                f.sort_unstable();
                f.dedup();
                f
            };
            &forced_vec
        } else {
            &prev_ws
        };
        let mut pt = policy.next_size(t, prev_ws_size, support.len(), p);
        if stagnated {
            pt = pt.max((2 * prev_ws_size).min(p));
        }
        let pt = pt.max(forced.len()); // forced members always fit
        let ws = build_working_set(&mut d_scores, forced, pt);

        // ---- inner solve on X_{W_t} ----
        let eps_t =
            if policy.prune { cfg.inner_tol_ratio * gap } else { cfg.tol };
        let x_ws = x.select_columns(&ws);
        let beta_ws: Vec<f64> = ws.iter().map(|&j| beta[j]).collect();
        let inner_cfg = CdConfig {
            tol: eps_t,
            max_epochs: cfg.max_inner_epochs,
            gap_freq: cfg.gap_freq,
            k: cfg.k,
            extrapolate: cfg.extrapolate,
            best_dual: true,
            screen: false,
            trace: false,
        };
        let inner = cd_solve(&x_ws, y, lambda, Some(&beta_ws), &inner_cfg);
        total_inner_epochs += inner.epochs;

        // ---- lift the subproblem solution back ----
        beta.fill(0.0);
        for (i, &j) in ws.iter().enumerate() {
            beta[j] = inner.beta[i];
        }
        r.copy_from_slice(&inner.r);

        // θ_inner: subproblem-feasible; rescale to be feasible for the
        // full design. (Algorithm 4 writes max(λ, ‖Xᵀθ‖_∞) which only
        // applies to residual-scale vectors; θ is already unit-scale so
        // the correct rescaling is max(1, ‖Xᵀθ‖_∞).) The Xᵀθ_inner sweep
        // is kept — it doubles as next iteration's pricing vector.
        x.xt_vec(&inner.theta, &mut xtheta_inner);
        let s = xtheta_inner.iter().fold(1.0f64, |m, &v| m.max(v.abs()));
        let inv_s = 1.0 / s;
        theta_inner.clear();
        theta_inner.extend(inner.theta.iter().map(|&v| v * inv_s));
        for v in xtheta_inner.iter_mut() {
            *v *= inv_s;
        }

        iterations.push(CelerIteration {
            t,
            gap,
            ws_size: ws.len(),
            support_size: support.len(),
            inner_epochs: inner.epochs,
            seconds: start.elapsed().as_secs_f64(),
            dual_winner: winner,
        });
        prev_ws_size = ws.len();
        prev_ws = ws;
    }

    let epochs = total_inner_epochs;
    let result = SolveResult { beta, r, theta, gap, epochs, converged, trace: Vec::new() };
    CelerOutput { result, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::solvers::cd::{cd_solve, CdConfig};

    fn check_matches_cd(seed: u64, ratio: f64, cfg: &CelerConfig) {
        let ds = synth::leukemia_mini(seed);
        let lambda = dual::lambda_max(&ds.x, &ds.y) * ratio;
        let out = celer_solve_on(&ds.x, &ds.y, lambda, None, cfg);
        assert!(out.result.converged, "celer converged, gap={}", out.gap());
        let reference = cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CdConfig { tol: cfg.tol / 10.0, ..Default::default() },
        );
        let p_celer = primal::primal(&ds.x, &ds.y, &out.result.beta, lambda);
        let p_cd = primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
        assert!(
            p_celer - p_cd <= 2.0 * cfg.tol,
            "celer {p_celer} vs cd {p_cd} (tol {})",
            cfg.tol
        );
    }

    #[test]
    fn prune_matches_cd() {
        check_matches_cd(20, 0.1, &CelerConfig { tol: 1e-8, ..Default::default() });
    }

    #[test]
    fn safe_matches_cd() {
        check_matches_cd(21, 0.1, &CelerConfig { tol: 1e-8, ..CelerConfig::safe() });
    }

    #[test]
    fn tight_tolerance() {
        check_matches_cd(22, 0.05, &CelerConfig { tol: 1e-12, ..Default::default() });
    }

    #[test]
    fn sparse_problem() {
        let ds = synth::finance_mini(23);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let out = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig::default());
        assert!(out.result.converged);
        // verify gap claim against an independent computation
        let p_val = primal::primal(&ds.x, &ds.y, &out.result.beta, lambda);
        let d_val = dual::dual_objective(&ds.y, &out.result.theta, lambda);
        assert!((p_val - d_val - out.gap()).abs() < 1e-10);
        assert!(dual::is_feasible(&ds.x, &out.result.theta, 1e-9));
    }

    #[test]
    fn warm_start_initializes_ws_from_support() {
        let ds = synth::leukemia_mini(24);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
        let first = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig::default());
        let warm = celer_solve_on(
            &ds.x,
            &ds.y,
            lambda,
            Some(&first.result.beta),
            &CelerConfig::default(),
        );
        assert!(warm.result.converged);
        // warm start from the solution: one outer iteration, zero inner work
        assert_eq!(warm.iterations.len(), 1);
        assert_eq!(warm.iterations[0].inner_epochs, 0);
    }

    #[test]
    fn ws_sizes_follow_policy() {
        let ds = synth::leukemia_mini(25);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
        let cfg = CelerConfig { tol: 1e-10, ..CelerConfig::safe() };
        let out = celer_solve_on(&ds.x, &ds.y, lambda, None, &cfg);
        // safe mode: sizes double (until capped) and are monotone
        let sizes: Vec<usize> =
            out.iterations.iter().filter(|i| i.ws_size > 0).map(|i| i.ws_size).collect();
        for w in sizes.windows(2) {
            assert!(w[1] >= w[0], "safe WS sizes are monotone: {sizes:?}");
        }
        assert_eq!(sizes[0], 100, "p1 = 100 by default");
    }

    #[test]
    fn gap_decreases_across_outer_iterations() {
        let ds = synth::leukemia_mini(26);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
        let out = celer_solve_on(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CelerConfig { tol: 1e-10, ..Default::default() },
        );
        let gaps: Vec<f64> = out.iterations.iter().map(|i| i.gap).collect();
        for w in gaps.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-9),
                "outer gaps non-increasing: {gaps:?}"
            );
        }
    }
}
