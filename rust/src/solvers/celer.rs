//! CELER (Algorithm 4): working-set solver with dual extrapolation —
//! *Constraint Elimination for the Lasso with Extrapolated Residuals*.
//!
//! Outer loop:
//! 1. build the best dual point among {θ^{t-1}, θ_inner^{t-1}, θ_res^t}
//!    (the inner point carries the *extrapolated* information — this is
//!    what Blitz structurally cannot use, §7);
//! 2. stop on the global duality gap;
//! 3. rank features by `d_j(θ)` and form the working set (safe doubling or
//!    pruning policy);
//! 4. approximately solve the subproblem on `X_{W_t}` with Algorithm 1
//!    (CD + dual extrapolation), warm-started.
//!
//! The subproblem is a *restriction*, not a new matrix: step 4 runs on a
//! zero-copy [`DesignView`] of `X_{W_t}` through the shared
//! [`crate::solvers::engine`], with all outer- and inner-loop buffers
//! living in a reusable [`Workspace`]. One outer iteration performs no
//! design-matrix copies and (once the workspace is warm) no allocation.
//!
//! The outer loop itself is datafit-generic ([`celer_solve_datafit`],
//! the GLM follow-up's Algorithm 2): everything above reads only the
//! generalized residual `−∇F(Xβ)` and the datafit's primal/dual values,
//! so sparse logistic / Poisson regression
//! ([`crate::solvers::glm`]) run the exact same pricing, working-set
//! growth and view-based inner solves with a prox-Newton epoch swapped
//! in for the CD epoch.

use crate::data::design::{DesignMatrix, DesignOps};
use crate::data::view::DesignView;
use crate::datafit::{Datafit, Quadratic};
use crate::lasso::{dual, primal, LassoProblem};
use crate::penalty::{Penalty, L1};
use crate::solvers::engine::{self, CdStrategy, EngineConfig, Init, StopRule, Strategy, Workspace};
use crate::solvers::SolveResult;
use crate::util::error::{FaultEvent, SolveError, SolveOutcome};
use crate::util::fault::FaultPlan;
use crate::ws::{build_working_set, WsPolicy};
use std::time::Instant;

/// Per-outer-iteration record (drives Figs. 8/9 and the path reports).
#[derive(Debug, Clone)]
pub struct CelerIteration {
    /// 1-based outer iteration.
    pub t: usize,
    /// Global duality gap at the start of the iteration.
    pub gap: f64,
    /// Working-set size |W_t| (0 on the final, converged check).
    pub ws_size: usize,
    /// Support size |S_{β^{t-1}}|.
    pub support_size: usize,
    /// Epochs consumed by the inner solver.
    pub inner_epochs: usize,
    /// Wall-clock since solve start.
    pub seconds: f64,
    /// Which dual candidate won: 0 = previous, 1 = inner, 2 = residual.
    pub dual_winner: usize,
}

/// CELER configuration.
#[derive(Debug, Clone)]
pub struct CelerConfig {
    /// Global duality-gap tolerance ε.
    pub tol: f64,
    /// Maximum outer iterations.
    pub max_outer: usize,
    /// Working-set policy (size growth + pruning).
    pub ws: WsPolicy,
    /// Subproblem tolerance ratio ε̄ (prune mode: ε_t = ε̄·g_t).
    pub inner_tol_ratio: f64,
    /// Inner-solver epoch cap per outer iteration.
    pub max_inner_epochs: usize,
    /// Inner gap frequency f.
    pub gap_freq: usize,
    /// Extrapolation depth K.
    pub k: usize,
    /// Use dual extrapolation in the inner solver. Disabling this is the
    /// ablation that isolates the WS strategy from the dual point quality.
    pub extrapolate: bool,
    /// Wall-clock budget in seconds (`None` = unlimited). Checked after
    /// every global gap evaluation: on expiry the outer loop stops and
    /// returns the current iterate with its fresh gap —
    /// partial-but-certified (`SolveOutcome::BudgetExhausted`).
    pub max_seconds: Option<f64>,
    /// Fault-injection plan, forwarded to every inner engine solve.
    pub faults: FaultPlan,
}

impl Default for CelerConfig {
    fn default() -> Self {
        CelerConfig {
            tol: 1e-6,
            max_outer: 100,
            ws: WsPolicy::default(),
            inner_tol_ratio: 0.3,
            max_inner_epochs: 10_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
            max_seconds: None,
            faults: FaultPlan::none(),
        }
    }
}

impl CelerConfig {
    /// Paper's "safe" variant (monotone doubling working sets, inner tol ε).
    pub fn safe() -> Self {
        CelerConfig { ws: WsPolicy::safe(), ..Default::default() }
    }
}

/// CELER output: solution + per-iteration trace.
#[derive(Debug, Clone)]
pub struct CelerOutput {
    pub result: SolveResult,
    pub iterations: Vec<CelerIteration>,
}

impl CelerOutput {
    pub fn support_size(&self) -> usize {
        self.result.support_size()
    }
    pub fn gap(&self) -> f64 {
        self.result.gap
    }
}

/// Solve a [`LassoProblem`] with CELER.
pub fn celer_solve(pb: &LassoProblem, cfg: &CelerConfig) -> CelerOutput {
    celer_solve_on(&pb.x, &pb.y, pb.lambda, None, cfg)
}

/// Validating front door for [`celer_solve_on`]: rejects non-finite
/// design/label entries, dimension mismatches and a bad λ with a typed
/// [`SolveError`] *before* the first outer iteration, then runs the
/// exact same solve (bit-identical results on valid inputs).
pub fn try_celer_solve_on(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
) -> Result<CelerOutput, SolveError> {
    crate::data::validate::validate_problem(x, y)?;
    if !lambda.is_finite() || lambda <= 0.0 {
        return Err(SolveError::BadGrid {
            index: 0,
            value: lambda,
            reason: "lambda must be finite and > 0",
        });
    }
    Ok(celer_solve_on(x, y, lambda, beta0, cfg))
}

/// CELER on explicit data with optional warm start.
pub fn celer_solve_on(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
) -> CelerOutput {
    let mut ws = Workspace::new();
    celer_solve_on_ws(x, y, lambda, beta0, cfg, &mut ws)
}

/// [`celer_solve_on`] on a caller-provided reusable [`Workspace`]: the
/// λ-path driver reuses one workspace for the whole warm-started path,
/// eliminating per-λ reallocation of β / r / Xᵀr / the extrapolation ring.
pub fn celer_solve_on_ws(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
    ws: &mut Workspace,
) -> CelerOutput {
    // Dispatch once; outer loop and view-based inner solves monomorphize.
    match x {
        DesignMatrix::Dense(d) => celer_generic(d, y, lambda, beta0, cfg, ws),
        DesignMatrix::Sparse(s) => celer_generic(s, y, lambda, beta0, cfg, ws),
        DesignMatrix::Ooc(o) => celer_generic(o, y, lambda, beta0, cfg, ws),
        DesignMatrix::Sharded(sh) => celer_generic(sh, y, lambda, beta0, cfg, ws),
    }
}

fn celer_generic<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
    ws: &mut Workspace,
) -> CelerOutput {
    celer_solve_datafit(x, y, lambda, beta0, &Quadratic, cfg, ws, &mut CdStrategy)
}

/// The CELER outer loop (Algorithm 4 / the GLM follow-up's Algorithm 2),
/// generic over the [`Datafit`]: pricing, working-set growth, the
/// argmax-of-three dual point and the zero-copy [`DesignView`] inner
/// solves all run on the **generalized residual** `−∇F(Xβ)`; `strategy`
/// supplies the inner epochs (plain [`CdStrategy`] for the quadratic
/// fit, [`ProxNewtonCd`](crate::solvers::glm::ProxNewtonCd) for sparse
/// GLMs). The `F = Quadratic` instantiation is what [`celer_solve_on`]
/// runs — bit-identical to the historical quadratic-only loop.
///
/// Shorthand for [`celer_solve_penalty`] with the plain ℓ₁ penalty.
pub fn celer_solve_datafit<D, F, S>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    datafit: &F,
    cfg: &CelerConfig,
    ws: &mut Workspace,
    strategy: &mut S,
) -> CelerOutput
where
    D: DesignOps,
    F: Datafit,
    S: for<'v> Strategy<DesignView<'v, D>, F>,
{
    celer_solve_penalty(x, y, lambda, beta0, datafit, &L1, cfg, ws, strategy)
}

/// [`celer_solve_on_ws`] for a generic separable [`Penalty`] (quadratic
/// datafit, [`CdStrategy`] inner epochs): the entry point the λ-path
/// drivers use for elastic-net and weighted-ℓ₁ paths. Dispatches the
/// design once, like [`celer_solve_on_ws`].
pub fn celer_penalty_solve_on_ws<P: Penalty>(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    penalty: &P,
    cfg: &CelerConfig,
    ws: &mut Workspace,
) -> CelerOutput {
    match x {
        DesignMatrix::Dense(d) => {
            celer_solve_penalty(d, y, lambda, beta0, &Quadratic, penalty, cfg, ws, &mut CdStrategy)
        }
        DesignMatrix::Sparse(s) => {
            celer_solve_penalty(s, y, lambda, beta0, &Quadratic, penalty, cfg, ws, &mut CdStrategy)
        }
        DesignMatrix::Ooc(o) => {
            celer_solve_penalty(o, y, lambda, beta0, &Quadratic, penalty, cfg, ws, &mut CdStrategy)
        }
        DesignMatrix::Sharded(sh) => {
            celer_solve_penalty(sh, y, lambda, beta0, &Quadratic, penalty, cfg, ws, &mut CdStrategy)
        }
    }
}

/// Evaluate the penalty-generic dual `D(θ) = −F*(−λθ) − λΣω*(x_jᵀθ)`:
/// the quadratic dual value minus the penalty's conjugate term (one
/// `Xᵀθ` sweep, only when the conjugate is non-trivial).
fn penalty_dual_value<D: DesignOps, F: Datafit, P: Penalty>(
    x: &D,
    datafit: &F,
    penalty: &P,
    y: &[f64],
    theta: &[f64],
    lambda: f64,
    cache: f64,
    xtr: &mut Vec<f64>,
) -> f64 {
    let mut v = datafit.dual(y, theta, lambda, cache);
    if !P::INDICATOR_DUAL {
        xtr.resize(x.p(), 0.0);
        x.xt_vec(theta, xtr);
        v -= penalty.conjugate(lambda, xtr, 1.0);
    }
    v
}

/// Penalty-generic [`dual::glm_best_dual_point`] (Eq. 13): same
/// in-order strict-argmax contract, with each candidate's dual value
/// including the conjugate term. Returns `(winner, best dual value)` so
/// the caller's gap needs no re-evaluation.
fn penalty_best_dual_point<D: DesignOps, F: Datafit, P: Penalty>(
    x: &D,
    datafit: &F,
    penalty: &P,
    y: &[f64],
    lambda: f64,
    cache: f64,
    candidates: &[&[f64]],
    xtr: &mut Vec<f64>,
) -> (usize, f64) {
    let mut best = 0;
    let mut best_val = f64::NEG_INFINITY;
    for (i, th) in candidates.iter().enumerate() {
        let v = penalty_dual_value(x, datafit, penalty, y, th, lambda, cache, xtr);
        if v > best_val {
            best_val = v;
            best = i;
        }
    }
    (best, best_val)
}

/// The penalty-generic CELER outer loop: pricing, the working set and
/// the dual candidates all come from the [`Penalty`]'s dual norm,
/// d-scores and conjugate (see `crate::penalty` for the conventions).
/// Separable penalties only — group-ℓ₂ runs through the plain engine
/// ([`engine::solve_penalty`]), whose group-CD epochs don't need
/// feature-level working sets. The `P = L1` instantiation takes the
/// exact historical expressions at every ℓ₁ touchpoint (fused
/// rescale, `‖·‖_∞` pricing, `glm_primal_value`) — pinned in
/// `tests/prop_penalty.rs`.
pub fn celer_solve_penalty<D, F, P, S>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    datafit: &F,
    penalty: &P,
    cfg: &CelerConfig,
    ws: &mut Workspace,
    strategy: &mut S,
) -> CelerOutput
where
    D: DesignOps,
    F: Datafit,
    P: Penalty,
    S: for<'v> Strategy<DesignView<'v, D>, F, P>,
{
    debug_assert!(P::SEPARABLE, "group penalties run through engine::solve_penalty");
    let n = x.n();
    let p = x.p();
    let start = Instant::now();

    // ---- outer-loop state in the reusable workspace ----
    ws.init_primal_datafit(x, y, beta0, datafit);
    let cache = datafit.conj_cache(y);

    // init: θ⁰ = θ⁰_inner = r(0) / ‖Xᵀr(0)‖_∞ with r(0) = −∇F(0)
    // (Algorithm 4's y/‖Xᵀy‖_∞, generalized to the datafit's residual
    // at zero — the same vector that anchors λ_max). Generic penalties
    // divide by max(λ, Ω^D(Xᵀr(0))) instead: for a penalty without a
    // dual constraint (elastic net) the slab norm is 0 and the natural
    // unconstrained candidate r(0)/λ comes out.
    let mut r0_buf = Vec::new();
    let r0 = datafit.residual_at_zero(y, &mut r0_buf);
    let lmax = if P::IS_L1 {
        x.xt_abs_max(r0).max(f64::MIN_POSITIVE)
    } else {
        ws.scratch.xtr.resize(p, 0.0);
        x.xt_vec(r0, &mut ws.scratch.xtr);
        datafit
            .rescale_denom(lambda, penalty.dual_norm(lambda, &ws.scratch.xtr))
            .max(f64::MIN_POSITIVE)
    };
    ws.theta.clear();
    ws.theta.extend(r0.iter().map(|&v| v / lmax));
    ws.theta_inner.clear();
    ws.theta_inner.extend_from_slice(&ws.theta);
    ws.theta_res.resize(n, 0.0);

    // warm start: p₁ = |S_{β⁰}| when β⁰ ≠ 0 (Algorithm 4)
    let mut policy = cfg.ws;
    let s0 = primal::support_size(&ws.beta);
    if s0 > 0 {
        policy.p1 = s0;
    }

    let mut iterations: Vec<CelerIteration> = Vec::new();
    ws.scratch.prepare(n, p);
    ws.xtheta.resize(p, 0.0);
    // Xᵀθ_inner, maintained by the rescale step (one design sweep serves
    // both the feasibility rescale and next iteration's pricing).
    ws.xtheta_inner.resize(p, 0.0);
    x.xt_vec(&ws.theta_inner, &mut ws.xtheta_inner);
    ws.d_scores.resize(p, 0.0);

    let mut inner_ws = ws.take_inner();
    let mut prev_ws: Vec<usize> = primal::support(&ws.beta);
    let mut prev_ws_size = 0usize;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut total_inner_epochs = 0usize;
    // Fault events surfaced by the inner engine's watchdog, across all
    // outer iterations; they dominate the final `SolveOutcome`.
    let mut all_faults: Vec<FaultEvent> = Vec::new();

    let mut prev_gap = f64::INFINITY;
    for t in 1..=cfg.max_outer {
        // ---- θ^t = argmax D over {θ^{t-1}, θ_inner^{t-1}, θ_res^t} ----
        // Allocation-free fused Eq. 4 rescale: Xᵀr and ‖Xᵀr‖_∞ in one
        // sharded pass, θ_res into the workspace buffer; the denominator
        // honors the datafit's `rescale_denom` hook, like the engine's
        // dual update.
        let denom = if P::IS_L1 {
            dual::glm_rescale_to_feasible_into(
                x,
                &ws.r,
                lambda,
                datafit,
                &mut ws.scratch.xtr,
                &mut ws.theta_res,
            )
        } else {
            dual::penalty_rescale_to_feasible_into(
                x,
                &ws.r,
                lambda,
                penalty,
                &mut ws.scratch.xtr,
                &mut ws.theta_res,
            )
        };
        let (winner, d_best) = if P::IS_L1 {
            let w = dual::glm_best_dual_point(
                datafit,
                y,
                lambda,
                cache,
                &[&ws.theta, &ws.theta_inner, &ws.theta_res],
            );
            (w, f64::NAN) // L1 recomputes D(θ) below, as historically
        } else {
            penalty_best_dual_point(
                x,
                datafit,
                penalty,
                y,
                lambda,
                cache,
                &[&ws.theta, &ws.theta_inner, &ws.theta_res],
                &mut ws.scratch.xtr_acc,
            )
        };
        match winner {
            1 => {
                let (theta, theta_inner) = (&mut ws.theta, &ws.theta_inner);
                theta.copy_from_slice(theta_inner);
            }
            2 => {
                let (theta, theta_res) = (&mut ws.theta, &ws.theta_res);
                theta.copy_from_slice(theta_res);
            }
            _ => {}
        }

        // Pricing (d_j ranking) deliberately uses only the FRESH dual
        // candidates {θ_inner^{t-1}, θ_res^t}: a stale-but-tight θ^{t-1}
        // (e.g. the y/λ_max initialization at small λ) yields stale
        // priorities and can freeze the working set while the gap
        // stagnates. The gap/stopping test above still uses the monotone
        // argmax-of-three point, exactly as Algorithm 4 prescribes.
        // Correlations for θ_inner are cached from the rescale pass below
        // (§Perf: saves one full Xᵀ· sweep per outer iteration).
        let rank_winner = if P::IS_L1 {
            dual::glm_best_dual_point(datafit, y, lambda, cache, &[&ws.theta_inner, &ws.theta_res])
        } else {
            penalty_best_dual_point(
                x,
                datafit,
                penalty,
                y,
                lambda,
                cache,
                &[&ws.theta_inner, &ws.theta_res],
                &mut ws.scratch.xtr_acc,
            )
            .0
        };
        if rank_winner == 1 {
            let (xtheta, xtr) = (&mut ws.xtheta, &ws.scratch.xtr);
            for (o, &v) in xtheta.iter_mut().zip(xtr.iter()) {
                *o = v / denom;
            }
        } else {
            let (xtheta, xtheta_inner) = (&mut ws.xtheta, &ws.xtheta_inner);
            xtheta.copy_from_slice(xtheta_inner);
        }

        // ---- global gap / stop ----
        let p_val = if P::IS_L1 {
            primal::glm_primal_value(datafit, y, &ws.xw, &ws.r, &ws.beta, lambda)
        } else {
            datafit.value(y, &ws.xw, &ws.r) + penalty.value(lambda, &ws.beta)
        };
        gap = if P::IS_L1 {
            p_val - datafit.dual(y, &ws.theta, lambda, cache)
        } else {
            // d_best is D(θ^t) of the winner just copied into ws.theta.
            p_val - d_best
        };
        let support = primal::support(&ws.beta);
        if gap <= cfg.tol {
            converged = true;
            iterations.push(CelerIteration {
                t,
                gap,
                ws_size: 0,
                support_size: support.len(),
                inner_epochs: 0,
                seconds: start.elapsed().as_secs_f64(),
                dual_winner: winner,
            });
            break;
        }
        // Wall-clock budget: checked right after the global gap, so the
        // returned iterate always carries a freshly evaluated certificate
        // even when the budget expires (partial-but-certified).
        if let Some(limit) = cfg.max_seconds {
            if start.elapsed().as_secs_f64() >= limit {
                iterations.push(CelerIteration {
                    t,
                    gap,
                    ws_size: 0,
                    support_size: support.len(),
                    inner_epochs: 0,
                    seconds: start.elapsed().as_secs_f64(),
                    dual_winner: winner,
                });
                break;
            }
        }

        // ---- working set ----
        // (empty columns get d_j = +∞ and are excluded centrally by
        // build_working_set — no sentinel values needed here)
        crate::screening::fill_d_scores_penalty(
            &ws.xtheta,
            &ws.col_norms,
            lambda,
            penalty,
            &mut ws.d_scores,
        );
        // Stagnation safeguard: when an outer iteration barely improved
        // the gap, the working set was too small (or mis-prioritized) —
        // fall back to monotone doubling for this round, which restores
        // the safe variant's convergence guarantee.
        let stagnated = t >= 2 && gap > 0.9 * prev_gap;
        prev_gap = gap;
        let forced_vec: Vec<usize>;
        let forced: &[usize] = if policy.prune && !stagnated {
            &support
        } else if policy.prune {
            // stagnation in prune mode: keep the previous WS too
            forced_vec = {
                let mut f = prev_ws.clone();
                f.extend(support.iter().copied());
                f.sort_unstable();
                f.dedup();
                f
            };
            &forced_vec
        } else {
            &prev_ws
        };
        let mut pt = policy.next_size(t, prev_ws_size, support.len(), p);
        if stagnated {
            pt = pt.max((2 * prev_ws_size).min(p));
        }
        let pt = pt.max(forced.len()); // forced members always fit
        let ws_idx = build_working_set(&mut ws.d_scores, forced, pt);

        // ---- inner solve on a zero-copy view of X_{W_t} ----
        let eps_t =
            if policy.prune { cfg.inner_tol_ratio * gap } else { cfg.tol };
        ws.beta_ws.clear();
        {
            let beta = &ws.beta;
            ws.beta_ws.extend(ws_idx.iter().map(|&j| beta[j]));
        }
        let inner_cfg = EngineConfig {
            tol: eps_t,
            max_epochs: cfg.max_inner_epochs,
            gap_freq: cfg.gap_freq,
            k: cfg.k,
            extrapolate: cfg.extrapolate,
            best_dual: true,
            screen: false,
            trace: false,
            stop: StopRule::DualityGap,
            // Hand the inner solve whatever budget is left so a single
            // long subproblem cannot blow far past the outer limit.
            max_seconds: cfg
                .max_seconds
                .map(|l| (l - start.elapsed().as_secs_f64()).max(0.0)),
            faults: cfg.faults.clone(),
        };
        let inner_epochs = {
            // The view's columns are locally indexed, so per-feature
            // penalties (weighted ℓ₁) must be restricted alongside the
            // design; index-independent penalties restrict to themselves.
            let sub_penalty = penalty.restrict(&ws_idx);
            let view = DesignView::new(x, &ws_idx, &ws.norms_sq);
            let outcome = engine::solve_penalty(
                &view,
                y,
                lambda,
                Init::Warm(&ws.beta_ws),
                None,
                &inner_cfg,
                &mut inner_ws,
                strategy,
                datafit,
                &sub_penalty,
            );
            all_faults.extend_from_slice(outcome.status.faults());
            outcome.epochs
        };
        total_inner_epochs += inner_epochs;

        // ---- lift the subproblem solution back ----
        // β is supported inside W_t (prune forces S ⊆ W_t), so the
        // subproblem's predictor/residual are the full problem's too.
        ws.beta.fill(0.0);
        for (i, &j) in ws_idx.iter().enumerate() {
            ws.beta[j] = inner_ws.beta[i];
        }
        ws.r.copy_from_slice(&inner_ws.r);
        ws.xw.copy_from_slice(&inner_ws.xw);

        // θ_inner: subproblem-feasible; rescale to be feasible for the
        // full design. (Algorithm 4 writes max(λ, ‖Xᵀθ‖_∞) which only
        // applies to residual-scale vectors; θ is already unit-scale so
        // the correct rescaling is max(1, ‖Xᵀθ‖_∞).) The Xᵀθ_inner sweep
        // is kept — it doubles as next iteration's pricing vector — and
        // the fused kernel returns its norm without a second p-scan.
        let s = if P::IS_L1 {
            x.xt_vec_abs_max(&inner_ws.dual.theta, &mut ws.xtheta_inner).max(1.0)
        } else {
            // Generic slab lift max(1, Ω^D(Xᵀθ)); for penalties without a
            // dual constraint Ω^D = 0, so the subproblem point passes
            // through unscaled (it is already globally admissible).
            x.xt_vec(&inner_ws.dual.theta, &mut ws.xtheta_inner);
            penalty.dual_norm(lambda, &ws.xtheta_inner).max(1.0)
        };
        let inv_s = 1.0 / s;
        ws.theta_inner.clear();
        ws.theta_inner.extend(inner_ws.dual.theta.iter().map(|&v| v * inv_s));
        for v in ws.xtheta_inner.iter_mut() {
            *v *= inv_s;
        }

        iterations.push(CelerIteration {
            t,
            gap,
            ws_size: ws_idx.len(),
            support_size: support.len(),
            inner_epochs,
            seconds: start.elapsed().as_secs_f64(),
            dual_winner: winner,
        });
        prev_ws_size = ws_idx.len();
        prev_ws = ws_idx;
    }

    ws.put_inner(inner_ws);
    let status = SolveOutcome::from_run(converged, gap, total_inner_epochs, all_faults);
    let result = SolveResult {
        beta: ws.beta.clone(),
        r: ws.r.clone(),
        theta: ws.theta.clone(),
        gap,
        epochs: total_inner_epochs,
        converged,
        trace: Vec::new(),
        status,
    };
    CelerOutput { result, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::solvers::cd::{cd_solve, CdConfig};

    fn check_matches_cd(seed: u64, ratio: f64, cfg: &CelerConfig) {
        let ds = synth::leukemia_mini(seed);
        let lambda = dual::lambda_max(&ds.x, &ds.y) * ratio;
        let out = celer_solve_on(&ds.x, &ds.y, lambda, None, cfg);
        assert!(out.result.converged, "celer converged, gap={}", out.gap());
        let reference = cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CdConfig { tol: cfg.tol / 10.0, ..Default::default() },
        );
        let p_celer = primal::primal(&ds.x, &ds.y, &out.result.beta, lambda);
        let p_cd = primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
        assert!(
            p_celer - p_cd <= 2.0 * cfg.tol,
            "celer {p_celer} vs cd {p_cd} (tol {})",
            cfg.tol
        );
    }

    #[test]
    fn prune_matches_cd() {
        check_matches_cd(20, 0.1, &CelerConfig { tol: 1e-8, ..Default::default() });
    }

    #[test]
    fn safe_matches_cd() {
        check_matches_cd(21, 0.1, &CelerConfig { tol: 1e-8, ..CelerConfig::safe() });
    }

    #[test]
    fn tight_tolerance() {
        check_matches_cd(22, 0.05, &CelerConfig { tol: 1e-12, ..Default::default() });
    }

    #[test]
    fn sparse_problem() {
        let ds = synth::finance_mini(23);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let out = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig::default());
        assert!(out.result.converged);
        // verify gap claim against an independent computation
        let p_val = primal::primal(&ds.x, &ds.y, &out.result.beta, lambda);
        let d_val = dual::dual_objective(&ds.y, &out.result.theta, lambda);
        assert!((p_val - d_val - out.gap()).abs() < 1e-10);
        assert!(dual::is_feasible(&ds.x, &out.result.theta, 1e-9));
    }

    #[test]
    fn warm_start_initializes_ws_from_support() {
        let ds = synth::leukemia_mini(24);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
        let first = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig::default());
        let warm = celer_solve_on(
            &ds.x,
            &ds.y,
            lambda,
            Some(&first.result.beta),
            &CelerConfig::default(),
        );
        assert!(warm.result.converged);
        // warm start from the solution: one outer iteration, zero inner work
        assert_eq!(warm.iterations.len(), 1);
        assert_eq!(warm.iterations[0].inner_epochs, 0);
    }

    #[test]
    fn ws_sizes_follow_policy() {
        let ds = synth::leukemia_mini(25);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
        let cfg = CelerConfig { tol: 1e-10, ..CelerConfig::safe() };
        let out = celer_solve_on(&ds.x, &ds.y, lambda, None, &cfg);
        // safe mode: sizes double (until capped) and are monotone
        let sizes: Vec<usize> =
            out.iterations.iter().filter(|i| i.ws_size > 0).map(|i| i.ws_size).collect();
        for w in sizes.windows(2) {
            assert!(w[1] >= w[0], "safe WS sizes are monotone: {sizes:?}");
        }
        assert_eq!(sizes[0], 100, "p1 = 100 by default");
    }

    #[test]
    fn gap_decreases_across_outer_iterations() {
        let ds = synth::leukemia_mini(26);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
        let out = celer_solve_on(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CelerConfig { tol: 1e-10, ..Default::default() },
        );
        let gaps: Vec<f64> = out.iterations.iter().map(|i| i.gap).collect();
        for w in gaps.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-9),
                "outer gaps non-increasing: {gaps:?}"
            );
        }
    }

    #[test]
    fn workspace_variant_matches_one_shot() {
        let ds = synth::leukemia_mini(27);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
        let cfg = CelerConfig { tol: 1e-9, ..Default::default() };
        let one_shot = celer_solve_on(&ds.x, &ds.y, lambda, None, &cfg);
        let mut ws = Workspace::new();
        // dirty the workspace with a different λ first
        let _ = celer_solve_on_ws(&ds.x, &ds.y, lambda * 3.0, None, &cfg, &mut ws);
        let reused = celer_solve_on_ws(&ds.x, &ds.y, lambda, None, &cfg, &mut ws);
        assert_eq!(one_shot.result.beta, reused.result.beta);
        assert_eq!(one_shot.result.gap, reused.result.gap);
        assert_eq!(one_shot.iterations.len(), reused.iterations.len());
    }
}
