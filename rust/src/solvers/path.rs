//! Lasso path driver (§6.3): solve along a decreasing λ grid with warm
//! starts, for any of the registered solvers.
//!
//! One [`Workspace`] is reused for the entire path: after the first grid
//! point every buffer (β, r, Xᵀr, dual state, extrapolation ring, the
//! nested working-set workspace) is already sized, so subsequent λ steps
//! run without per-λ reallocation.
//!
//! Two execution modes feed the grid:
//!
//! - **sequential** (every [`PathSolver`] except `BatchedCd`): one λ at
//!   a time, β̂(λ_i) warm-starting λ_{i+1};
//! - **batched** ([`PathSolver::BatchedCd`], the [`lasso_path`]
//!   default): the grid feeds B concurrent lanes of the
//!   [`batch`](crate::solvers::batch) engine, whose interleaved CD
//!   epochs share each design sweep across lanes.

use crate::data::design::DesignMatrix;
use crate::datafit::GlmFamily;
use crate::lasso::dual;
use crate::multitask::solver::{mt_celer_solve_ws, MtConfig};
use crate::multitask::TaskMatrix;
use crate::penalty::{ElasticNet, Penalty, L1};
use crate::solvers::batch::{self, BatchCdStrategy, BatchConfig};
use crate::solvers::blitz::{blitz_solve_ws, BlitzConfig};
use crate::solvers::cd::{cd_solve_ws, CdConfig};
use crate::solvers::celer::{celer_penalty_solve_on_ws, celer_solve_on_ws, CelerConfig};
use crate::solvers::engine::Workspace;
use crate::solvers::glm::{glm_celer_solve_ws, ProxNewtonCd};
use crate::solvers::glmnet::{glmnet_solve_ws, GlmnetConfig};
use crate::solvers::Precision;
use crate::util::error::{SolveError, SolveOutcome};
use std::time::Instant;

/// Log-spaced λ grid from `λ_max` down to `λ_max · min_ratio` (inclusive),
/// the GLMNET / scikit-learn convention.
pub fn lambda_grid(lambda_max: f64, min_ratio: f64, num: usize) -> Vec<f64> {
    assert!(num >= 1);
    assert!(min_ratio > 0.0 && min_ratio < 1.0);
    if num == 1 {
        return vec![lambda_max];
    }
    (0..num)
        .map(|i| lambda_max * min_ratio.powf(i as f64 / (num - 1) as f64))
        .collect()
}

/// Which solver runs the path.
#[derive(Debug, Clone)]
pub enum PathSolver {
    CelerPrune(CelerConfig),
    CelerSafe(CelerConfig),
    Blitz(BlitzConfig),
    Glmnet(GlmnetConfig),
    /// Vanilla cyclic CD with θ_res gap stopping (scikit-learn).
    VanillaCd(CdConfig),
    /// CD + dynamic Gap Safe screening; `extrapolate` picks θ_accel/θ_res.
    GapSafeCd(CdConfig),
    /// Batched multi-λ CD: B grid cells solved concurrently over shared
    /// design sweeps (see [`crate::solvers::batch`]).
    BatchedCd(BatchConfig),
    /// Multi-Task CELER on the block-coefficient engine
    /// ([`crate::solvers::block`]), run at q = 1 on the scalar grid —
    /// the block engine's q = 1 path is the scalar path, so this slots
    /// into any grid job; true q > 1 grids go through [`run_mt_path`].
    MultiTask(MtConfig),
    /// Sparse logistic regression with CELER on the datafit-generic
    /// engine ([`crate::solvers::glm`]). Grid jobs binarize continuous
    /// targets by sign (±1 targets pass through unchanged), so
    /// "celer-logreg" slots into any coordinator grid; call
    /// [`glm_path`] directly for true-label paths or the Poisson fit.
    CelerLogreg(CelerConfig),
    /// Elastic net `½‖y − Xβ‖² + λ(α‖β‖₁ + ½(1−α)‖β‖₂²)` with CELER on
    /// the penalty-generic engine; the second field is the mixing
    /// ratio α ∈ (0, 1).
    CelerEnet(CelerConfig, f64),
    /// Weighted ℓ₁ with the column-norm weights of
    /// [`crate::penalty::scale_weights`] (empty columns unreachable at
    /// weight ∞), solved with CELER on the penalty-generic engine.
    CelerWlasso(CelerConfig),
}

impl PathSolver {
    pub fn name(&self) -> &'static str {
        match self {
            PathSolver::CelerPrune(_) => "celer-prune",
            PathSolver::CelerSafe(_) => "celer-safe",
            PathSolver::Blitz(_) => "blitz",
            PathSolver::Glmnet(_) => "glmnet",
            PathSolver::VanillaCd(_) => "cd-vanilla",
            PathSolver::GapSafeCd(c) => {
                if c.extrapolate {
                    "gapsafe-cd-accel"
                } else {
                    "gapsafe-cd-res"
                }
            }
            PathSolver::BatchedCd(_) => "cd-batched",
            PathSolver::MultiTask(_) => "celer-mt",
            PathSolver::CelerLogreg(_) => "celer-logreg",
            PathSolver::CelerEnet(..) => "celer-enet",
            PathSolver::CelerWlasso(_) => "celer-wlasso",
        }
    }

    /// Default instance by name, at tolerance `tol`.
    pub fn by_name(name: &str, tol: f64) -> Option<PathSolver> {
        Some(match name {
            "celer-prune" | "celer" => {
                PathSolver::CelerPrune(CelerConfig { tol, ..Default::default() })
            }
            "celer-safe" => PathSolver::CelerSafe(CelerConfig { tol, ..CelerConfig::safe() }),
            "blitz" => PathSolver::Blitz(BlitzConfig { tol, ..Default::default() }),
            "glmnet" => PathSolver::Glmnet(GlmnetConfig { tol, ..Default::default() }),
            "cd-vanilla" | "sklearn" => {
                PathSolver::VanillaCd(CdConfig { tol, ..CdConfig::vanilla() })
            }
            "gapsafe-cd-res" => PathSolver::GapSafeCd(CdConfig {
                tol,
                screen: true,
                extrapolate: false,
                ..Default::default()
            }),
            "gapsafe-cd-accel" => PathSolver::GapSafeCd(CdConfig {
                tol,
                screen: true,
                extrapolate: true,
                ..Default::default()
            }),
            "cd-batched" | "batched" => {
                PathSolver::BatchedCd(BatchConfig { tol, ..Default::default() })
            }
            "celer-mt" | "mt-celer" => {
                PathSolver::MultiTask(MtConfig { tol, ..Default::default() })
            }
            "celer-logreg" | "logreg" => {
                PathSolver::CelerLogreg(CelerConfig { tol, ..Default::default() })
            }
            // α = ½: the conventional even split between the ℓ₁ and
            // ridge terms (scikit-learn's `l1_ratio` default).
            "celer-enet" | "enet" => {
                PathSolver::CelerEnet(CelerConfig { tol, ..Default::default() }, 0.5)
            }
            "celer-wlasso" | "wlasso" => {
                PathSolver::CelerWlasso(CelerConfig { tol, ..Default::default() })
            }
            _ => return None,
        })
    }
}

/// One solved grid point.
#[derive(Debug, Clone)]
pub struct PathStep {
    pub lambda: f64,
    pub seconds: f64,
    /// Epochs (CD) or total inner epochs (WS solvers).
    pub epochs: usize,
    pub gap: f64,
    pub support_size: usize,
    pub converged: bool,
    /// Solution, kept when `store_betas` was requested.
    pub beta: Option<Vec<f64>>,
    /// Typed outcome of this grid point (certified / budget / recovered).
    pub status: SolveOutcome,
}

/// A full path result.
#[derive(Debug, Clone)]
pub struct PathResult {
    pub solver: String,
    pub steps: Vec<PathStep>,
    pub total_seconds: f64,
}

impl PathResult {
    pub fn all_converged(&self) -> bool {
        self.steps.iter().all(|s| s.converged)
    }

    /// Aggregate typed outcome of the whole path: fault events anywhere
    /// dominate, then any budget-exhausted step, else certified.
    pub fn status(&self) -> SolveOutcome {
        let mut agg = SolveOutcome::Certified;
        for s in &self.steps {
            agg.absorb(s.status.clone());
        }
        agg
    }
}

/// Run a λ path with warm starts (β̂(λ_i) initializes λ_{i+1}).
pub fn run_path(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    solver: &PathSolver,
    store_betas: bool,
) -> PathResult {
    let mut ws = Workspace::new();
    run_path_with_workspace(x, y, grid, solver, store_betas, &mut ws)
}

/// The paper's headline computation (Table 1 / Fig. 4): solve a full λ
/// grid. Runs on the batched multi-λ engine — `lanes` concurrent grid
/// cells per design sweep (`0` autotunes B from the problem shape via
/// [`auto_lanes`](crate::solvers::batch::auto_lanes)); pass a
/// sequential [`PathSolver`] to [`run_path`] instead for the one-λ-at-a-
/// time schedule.
///
/// Generic over the (separable) [`Penalty`]: pass [`L1`] for the plain
/// Lasso path (bit-identical to the historical driver) or e.g. an
/// [`ElasticNet`] to run the whole multi-λ elastic-net path on the same
/// shared-sweep lane machinery.
pub fn lasso_path<P: Penalty>(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    tol: f64,
    lanes: usize,
    store_betas: bool,
    penalty: &P,
) -> PathResult {
    let cfg = BatchConfig { tol, lanes, ..Default::default() };
    let mut ws = Workspace::new();
    run_path_batched_penalty(x, y, grid, &cfg, store_betas, &mut ws, penalty)
}

/// [`run_path`] on a caller-provided [`Workspace`] (e.g. the coordinator
/// can keep one workspace per worker thread across many path jobs).
pub fn run_path_with_workspace(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    solver: &PathSolver,
    store_betas: bool,
    ws: &mut Workspace,
) -> PathResult {
    run_path_budgeted(x, y, grid, solver, store_betas, None, ws)
}

/// [`run_path_with_workspace`] under an overall wall-clock budget: when
/// `max_seconds` expires, the remaining grid points are skipped and the
/// partial path is returned. Every step already in `steps` keeps its gap
/// certificate — the budget only truncates the grid, it never degrades a
/// solved point. For [`PathSolver::BatchedCd`] the budget is forwarded
/// into [`BatchConfig::max_seconds`] (tightening any existing limit).
pub fn run_path_budgeted(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    solver: &PathSolver,
    store_betas: bool,
    max_seconds: Option<f64>,
    ws: &mut Workspace,
) -> PathResult {
    if let PathSolver::BatchedCd(cfg) = solver {
        let mut cfg = cfg.clone();
        if let Some(limit) = max_seconds {
            cfg.max_seconds = Some(cfg.max_seconds.map_or(limit, |c| c.min(limit)));
        }
        return run_path_batched(x, y, grid, &cfg, store_betas, ws);
    }
    if let PathSolver::CelerLogreg(cfg) = solver {
        // Grid jobs arrive with whatever targets the dataset has;
        // logistic regression needs ±1 labels, so binarize by sign
        // (identity on label vectors).
        let labels = crate::datafit::sign_labels(y);
        let mut res = glm_path_budgeted_with_workspace(
            x,
            &labels,
            GlmFamily::Logistic,
            grid,
            cfg,
            store_betas,
            max_seconds,
            ws,
        );
        res.solver = solver.name().to_string();
        return res;
    }
    let start = Instant::now();
    let p = crate::data::design::DesignOps::p(x);
    // Weighted-ℓ₁ column-norm weights are a property of the design, not
    // of λ: built lazily, at most once for the whole grid.
    let mut wlasso_penalty: Option<crate::penalty::WeightedL1> = None;
    let mut beta = vec![0.0; p];
    let mut steps = Vec::with_capacity(grid.len());
    let mut lambda_prev = dual::lambda_max(x, y);
    for &lambda in grid {
        if let Some(limit) = max_seconds {
            if start.elapsed().as_secs_f64() >= limit {
                break;
            }
        }
        let t0 = Instant::now();
        let (new_beta, gap, epochs, converged, status) = match solver {
            PathSolver::CelerPrune(cfg) | PathSolver::CelerSafe(cfg) => {
                let out = celer_solve_on_ws(x, y, lambda, Some(&beta), cfg, ws);
                let r = out.result;
                (r.beta, r.gap, r.epochs, r.converged, r.status)
            }
            PathSolver::Blitz(cfg) => {
                let out = blitz_solve_ws(x, y, lambda, Some(&beta), cfg, ws);
                let r = out.result;
                (r.beta, r.gap, r.epochs, r.converged, r.status)
            }
            PathSolver::Glmnet(cfg) => {
                let out = glmnet_solve_ws(x, y, lambda, lambda_prev, Some(&beta), cfg, ws);
                (out.beta, out.gap, out.epochs, out.converged, out.status)
            }
            PathSolver::VanillaCd(cfg) | PathSolver::GapSafeCd(cfg) => {
                let out = cd_solve_ws(x, y, lambda, Some(&beta), cfg, ws);
                (out.beta, out.gap, out.epochs, out.converged, out.status)
            }
            PathSolver::MultiTask(cfg) => {
                // q = 1 block solve: same problem, block-engine schedule.
                let mut mtws = ws.take_mt();
                let out = mt_celer_solve_ws(x, y, 1, lambda, Some(&beta), cfg, &mut mtws);
                ws.put_mt(mtws);
                (out.b.data, out.gap, out.epochs, out.converged, out.status)
            }
            PathSolver::CelerEnet(cfg, l1_ratio) => {
                let pen = ElasticNet::new(*l1_ratio);
                let out = celer_penalty_solve_on_ws(x, y, lambda, Some(&beta), &pen, cfg, ws);
                let r = out.result;
                (r.beta, r.gap, r.epochs, r.converged, r.status)
            }
            PathSolver::CelerWlasso(cfg) => {
                let pen = wlasso_penalty.get_or_insert_with(|| {
                    crate::penalty::WeightedL1::new(crate::penalty::scale_weights(x))
                });
                let out = celer_penalty_solve_on_ws(x, y, lambda, Some(&beta), &*pen, cfg, ws);
                let r = out.result;
                (r.beta, r.gap, r.epochs, r.converged, r.status)
            }
            PathSolver::BatchedCd(_) => unreachable!("handled by run_path_batched"),
            PathSolver::CelerLogreg(_) => unreachable!("handled by glm_path_with_workspace"),
        };
        beta = new_beta;
        steps.push(PathStep {
            lambda,
            seconds: t0.elapsed().as_secs_f64(),
            epochs,
            gap,
            support_size: crate::lasso::primal::support_size(&beta),
            converged,
            beta: if store_betas { Some(beta.clone()) } else { None },
            status,
        });
        lambda_prev = lambda;
    }
    PathResult {
        solver: solver.name().to_string(),
        steps,
        total_seconds: start.elapsed().as_secs_f64(),
    }
}

/// Run the grid on the batched multi-λ engine: the grid feeds B lanes,
/// converged lanes retire and their slots load the next cell (see
/// [`crate::solvers::batch`]). The lane workspace lives inside the
/// engine [`Workspace`] (`ws.batch`), so a coordinator worker reuses it
/// across jobs like every other solver buffer.
pub fn run_path_batched(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    cfg: &BatchConfig,
    store_betas: bool,
    ws: &mut Workspace,
) -> PathResult {
    run_path_batched_penalty(x, y, grid, cfg, store_betas, ws, &L1)
}

/// Penalty-generic [`run_path_batched`]: the same lane engine solving
/// `½‖y − Xβ‖² + Ω_λ(β)` at every grid cell for any separable
/// [`Penalty`]. `P = L1` takes the historical code paths bit for bit.
pub fn run_path_batched_penalty<P: Penalty>(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    cfg: &BatchConfig,
    store_betas: bool,
    ws: &mut Workspace,
    penalty: &P,
) -> PathResult {
    let start = Instant::now();
    let mut lanes_ws = ws.take_batch();
    // Dispatch once so the interleaved sweeps monomorphize per storage;
    // `cfg.precision` picks the f64 or f32-sweep strategy.
    let results = match x {
        DesignMatrix::Dense(d) => match cfg.precision {
            Precision::F64 => batch::solve_grid_penalty(
                d,
                y,
                grid,
                None,
                cfg,
                &mut lanes_ws,
                &mut BatchCdStrategy,
                penalty,
            ),
            Precision::F32 => {
                let mut strat = batch::BatchF32Strategy::new(d);
                batch::solve_grid_penalty(d, y, grid, None, cfg, &mut lanes_ws, &mut strat, penalty)
            }
        },
        DesignMatrix::Sparse(s) => match cfg.precision {
            Precision::F64 => batch::solve_grid_penalty(
                s,
                y,
                grid,
                None,
                cfg,
                &mut lanes_ws,
                &mut BatchCdStrategy,
                penalty,
            ),
            Precision::F32 => {
                let mut strat = batch::BatchF32Strategy::new(s);
                batch::solve_grid_penalty(s, y, grid, None, cfg, &mut lanes_ws, &mut strat, penalty)
            }
        },
        DesignMatrix::Ooc(o) => match cfg.precision {
            Precision::F64 => batch::solve_grid_penalty(
                o,
                y,
                grid,
                None,
                cfg,
                &mut lanes_ws,
                &mut BatchCdStrategy,
                penalty,
            ),
            Precision::F32 => {
                let mut strat = batch::BatchF32Strategy::new(o);
                batch::solve_grid_penalty(o, y, grid, None, cfg, &mut lanes_ws, &mut strat, penalty)
            }
        },
        DesignMatrix::Sharded(sh) => match cfg.precision {
            Precision::F64 => batch::solve_grid_penalty(
                sh,
                y,
                grid,
                None,
                cfg,
                &mut lanes_ws,
                &mut BatchCdStrategy,
                penalty,
            ),
            Precision::F32 => {
                // `shadow_f32()` on a ShardedStore is chunk-streamed per
                // shard — the f32 lanes ride every prefetch stream.
                let mut strat = batch::BatchF32Strategy::new(sh);
                batch::solve_grid_penalty(sh, y, grid, None, cfg, &mut lanes_ws, &mut strat, penalty)
            }
        },
    };
    ws.put_batch(lanes_ws);
    let steps = results
        .into_iter()
        .map(|lane| {
            let support_size = crate::lasso::primal::support_size(&lane.beta);
            PathStep {
                lambda: lane.lambda,
                seconds: lane.seconds,
                epochs: lane.epochs,
                gap: lane.gap,
                support_size,
                converged: lane.converged,
                status: lane.status,
                beta: if store_betas { Some(lane.beta) } else { None },
            }
        })
        .collect();
    PathResult {
        solver: PathSolver::BatchedCd(cfg.clone()).name().to_string(),
        steps,
        total_seconds: start.elapsed().as_secs_f64(),
    }
}

/// Run a sparse-GLM λ path (logistic or Poisson) with warm starts:
/// β̂(λ_i) seeds λ_{i+1}, exactly the sequential chain of [`run_path`]
/// with the datafit swapped. Logistic targets must be ±1 labels,
/// Poisson targets non-negative counts (asserted).
pub fn glm_path(
    x: &DesignMatrix,
    y: &[f64],
    family: GlmFamily,
    grid: &[f64],
    cfg: &CelerConfig,
    store_betas: bool,
) -> PathResult {
    let mut ws = Workspace::new();
    glm_path_with_workspace(x, y, family, grid, cfg, store_betas, &mut ws)
}

/// [`glm_path`] on a caller-provided [`Workspace`]: the engine buffers
/// (β, generalized residual, predictor, dual state, extrapolation ring,
/// nested working-set workspace) **and** one [`ProxNewtonCd`] scratch
/// (IRLS weights, model residual, line-search snapshots) are reused for
/// every λ — no per-λ reallocation once warm, matching the quadratic
/// path driver.
pub fn glm_path_with_workspace(
    x: &DesignMatrix,
    y: &[f64],
    family: GlmFamily,
    grid: &[f64],
    cfg: &CelerConfig,
    store_betas: bool,
    ws: &mut Workspace,
) -> PathResult {
    glm_path_budgeted_with_workspace(x, y, family, grid, cfg, store_betas, None, ws)
}

/// [`glm_path_with_workspace`] under an overall wall-clock budget: like
/// [`run_path_budgeted`], expiry truncates the grid and the partial path
/// keeps every already-earned gap certificate.
#[allow(clippy::too_many_arguments)]
pub fn glm_path_budgeted_with_workspace(
    x: &DesignMatrix,
    y: &[f64],
    family: GlmFamily,
    grid: &[f64],
    cfg: &CelerConfig,
    store_betas: bool,
    max_seconds: Option<f64>,
    ws: &mut Workspace,
) -> PathResult {
    let start = Instant::now();
    let p = crate::data::design::DesignOps::p(x);
    let mut strategy = ProxNewtonCd::default();
    let mut beta = vec![0.0; p];
    let mut steps = Vec::with_capacity(grid.len());
    for &lambda in grid {
        if let Some(limit) = max_seconds {
            if start.elapsed().as_secs_f64() >= limit {
                break;
            }
        }
        let t0 = Instant::now();
        let out = glm_celer_solve_ws(x, y, family, lambda, Some(&beta), cfg, ws, &mut strategy);
        let status = out.result.status;
        beta = out.result.beta;
        steps.push(PathStep {
            lambda,
            seconds: t0.elapsed().as_secs_f64(),
            epochs: out.result.epochs,
            gap: out.result.gap,
            support_size: crate::lasso::primal::support_size(&beta),
            converged: out.result.converged,
            beta: if store_betas { Some(beta.clone()) } else { None },
            status,
        });
    }
    PathResult {
        solver: format!("celer-{}", family.name()),
        steps,
        total_seconds: start.elapsed().as_secs_f64(),
    }
}

/// Validating front door for [`run_path`]: rejects non-finite designs,
/// labels, and grids with a typed [`SolveError`] before any epoch runs.
pub fn try_run_path(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    solver: &PathSolver,
    store_betas: bool,
) -> Result<PathResult, SolveError> {
    crate::data::validate::validate_problem(x, y)?;
    crate::data::validate::validate_grid(grid)?;
    Ok(run_path(x, y, grid, solver, store_betas))
}

/// Validating front door for [`lasso_path`].
pub fn try_lasso_path<P: Penalty>(
    x: &DesignMatrix,
    y: &[f64],
    grid: &[f64],
    tol: f64,
    lanes: usize,
    store_betas: bool,
    penalty: &P,
) -> Result<PathResult, SolveError> {
    crate::data::validate::validate_problem(x, y)?;
    crate::data::validate::validate_grid(grid)?;
    if !(tol.is_finite() && tol > 0.0) {
        return Err(SolveError::BadConfig { what: format!("tol must be finite and > 0, got {tol}") });
    }
    Ok(lasso_path(x, y, grid, tol, lanes, store_betas, penalty))
}

/// Validating front door for [`glm_path`]: additionally checks the label
/// domain of the datafit family (±1 for logistic, non-negative for
/// Poisson) so bad targets surface as [`SolveError::LabelDomain`]
/// instead of a panic deep in the engine.
pub fn try_glm_path(
    x: &DesignMatrix,
    y: &[f64],
    family: GlmFamily,
    grid: &[f64],
    cfg: &CelerConfig,
    store_betas: bool,
) -> Result<PathResult, SolveError> {
    crate::data::validate::validate_problem(x, y)?;
    crate::data::validate::validate_family_labels(family, y)?;
    crate::data::validate::validate_grid(grid)?;
    Ok(glm_path(x, y, family, grid, cfg, store_betas))
}

/// One solved grid point of a Multi-Task λ path (paper §7).
#[derive(Debug, Clone)]
pub struct MtPathStep {
    pub lambda: f64,
    pub seconds: f64,
    /// Total inner (working-set subproblem) epochs.
    pub epochs: usize,
    pub gap: f64,
    /// Row-support size `|{j : B_j ≠ 0}|`.
    pub support_size: usize,
    pub converged: bool,
    /// Solution blocks, kept when `store_b` was requested.
    pub b: Option<TaskMatrix>,
}

/// A full Multi-Task path result.
#[derive(Debug, Clone)]
pub struct MtPathResult {
    pub steps: Vec<MtPathStep>,
    pub total_seconds: f64,
}

impl MtPathResult {
    pub fn all_converged(&self) -> bool {
        self.steps.iter().all(|s| s.converged)
    }
}

/// Run a Multi-Task Lasso λ path with warm starts: B̂(λ_i) seeds
/// λ_{i+1}, exactly the sequential warm-start chain of [`run_path`]
/// lifted to width-q blocks. `y` is row-major n×q.
pub fn run_mt_path(
    x: &DesignMatrix,
    y: &[f64],
    q: usize,
    grid: &[f64],
    cfg: &MtConfig,
    store_b: bool,
) -> MtPathResult {
    let mut ws = Workspace::new();
    run_mt_path_with_workspace(x, y, q, grid, cfg, store_b, &mut ws)
}

/// [`run_mt_path`] on a caller-provided [`Workspace`]: the block
/// workspace lives in `ws.mt` (like `ws.batch` for batched runs), so a
/// coordinator worker thread reuses one set of block buffers — B, R,
/// XᵀR blocks, extrapolation ring, the nested inner workspace — across
/// every MT path job it claims. No per-λ reallocation once warm.
pub fn run_mt_path_with_workspace(
    x: &DesignMatrix,
    y: &[f64],
    q: usize,
    grid: &[f64],
    cfg: &MtConfig,
    store_b: bool,
    ws: &mut Workspace,
) -> MtPathResult {
    let start = Instant::now();
    let p = crate::data::design::DesignOps::p(x);
    let mut mtws = ws.take_mt();
    let mut b = vec![0.0; p * q];
    let mut steps = Vec::with_capacity(grid.len());
    for &lambda in grid {
        let t0 = Instant::now();
        let out = mt_celer_solve_ws(x, y, q, lambda, Some(&b), cfg, &mut mtws);
        b.copy_from_slice(&out.b.data);
        steps.push(MtPathStep {
            lambda,
            seconds: t0.elapsed().as_secs_f64(),
            epochs: out.epochs,
            gap: out.gap,
            support_size: out.b.support().len(),
            converged: out.converged,
            b: if store_b { Some(out.b) } else { None },
        });
    }
    ws.put_mt(mtws);
    MtPathResult { steps, total_seconds: start.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn grid_is_log_spaced() {
        let g = lambda_grid(10.0, 0.01, 3);
        assert_eq!(g.len(), 3);
        assert!((g[0] - 10.0).abs() < 1e-12);
        assert!((g[1] - 1.0).abs() < 1e-12);
        assert!((g[2] - 0.1).abs() < 1e-12);
        assert_eq!(lambda_grid(5.0, 0.5, 1), vec![5.0]);
    }

    #[test]
    fn path_solvers_agree_on_final_objective() {
        let ds = synth::leukemia_mini(50);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.05, 5);
        let tol = 1e-8;
        let mut finals = Vec::new();
        for name in ["celer-prune", "celer-safe", "blitz", "cd-vanilla", "gapsafe-cd-accel"] {
            let solver = PathSolver::by_name(name, tol).unwrap();
            let res = run_path(&ds.x, &ds.y, &grid, &solver, true);
            assert!(res.all_converged(), "{name} converged");
            let beta = res.steps.last().unwrap().beta.as_ref().unwrap();
            finals.push(crate::lasso::primal::primal(&ds.x, &ds.y, beta, *grid.last().unwrap()));
        }
        for w in finals.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-6, "{finals:?}");
        }
    }

    #[test]
    fn support_grows_along_path() {
        let ds = synth::leukemia_mini(51);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax * 0.99, 0.05, 8);
        let solver = PathSolver::by_name("celer", 1e-6).unwrap();
        let res = run_path(&ds.x, &ds.y, &grid, &solver, false);
        let first = res.steps.first().unwrap().support_size;
        let last = res.steps.last().unwrap().support_size;
        assert!(last > first, "support grows: {first} -> {last}");
    }

    #[test]
    fn unknown_solver_name() {
        assert!(PathSolver::by_name("nope", 1e-6).is_none());
    }

    #[test]
    fn batched_path_matches_sequential_objectives() {
        let ds = synth::leukemia_mini(52);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.05, 6);
        let tol = 1e-9;
        let seq = run_path(
            &ds.x,
            &ds.y,
            &grid,
            &PathSolver::by_name("gapsafe-cd-accel", tol).unwrap(),
            true,
        );
        let bat = lasso_path(&ds.x, &ds.y, &grid, tol, 4, true, &crate::penalty::L1);
        assert_eq!(bat.solver, "cd-batched");
        assert!(seq.all_converged() && bat.all_converged());
        for (i, (ss, sb)) in seq.steps.iter().zip(&bat.steps).enumerate() {
            assert!(sb.gap <= tol, "λ#{i} gap {}", sb.gap);
            let ps = crate::lasso::primal::primal(
                &ds.x,
                &ds.y,
                ss.beta.as_ref().unwrap(),
                grid[i],
            );
            let pb = crate::lasso::primal::primal(
                &ds.x,
                &ds.y,
                sb.beta.as_ref().unwrap(),
                grid[i],
            );
            // both gap-certified at tol ⇒ objectives within 2·tol
            assert!((ps - pb).abs() <= 2.0 * tol, "λ#{i}: {ps} vs {pb}");
        }
    }

    #[test]
    fn penalty_solver_name_roundtrips() {
        for (name, alias) in [("celer-enet", "enet"), ("celer-wlasso", "wlasso")] {
            assert_eq!(PathSolver::by_name(name, 1e-6).unwrap().name(), name);
            assert_eq!(PathSolver::by_name(alias, 1e-6).unwrap().name(), name);
        }
    }

    #[test]
    fn enet_and_wlasso_paths_certify_every_step() {
        // Both penalty-generic solvers must walk a warm-started grid
        // with a gap certificate at every λ. The enet grid is anchored
        // at its own λ_max = ‖Xᵀy‖_∞/α so the first cell starts sparse.
        let ds = synth::leukemia_mini(57);
        let tol = 1e-8;
        for name in ["celer-enet", "celer-wlasso"] {
            let solver = PathSolver::by_name(name, tol).unwrap();
            let lmax = match &solver {
                PathSolver::CelerEnet(_, a) => dual::lambda_max(&ds.x, &ds.y) / a,
                _ => dual::lambda_max(&ds.x, &ds.y),
            };
            let grid = lambda_grid(lmax, 0.05, 5);
            let res = run_path(&ds.x, &ds.y, &grid, &solver, true);
            assert_eq!(res.solver, name);
            assert!(res.all_converged(), "{name} converged");
            for s in &res.steps {
                assert!(s.gap <= tol, "{name}: gap {} at λ {}", s.gap, s.lambda);
            }
            // support grows down the path, and something is selected
            assert!(res.steps.last().unwrap().support_size > 0, "{name}");
        }
    }

    #[test]
    fn batched_enet_path_matches_sequential_enet() {
        // The batched lanes and the sequential CELER solver run very
        // different schedules; agreement of the certified objectives
        // pins the penalty threading of both.
        let ds = synth::leukemia_mini(58);
        let alpha = 0.5;
        let pen = crate::penalty::ElasticNet::new(alpha);
        let lmax = dual::lambda_max(&ds.x, &ds.y) / alpha;
        let grid = lambda_grid(lmax, 0.05, 5);
        let tol = 1e-9;
        let bat = lasso_path(&ds.x, &ds.y, &grid, tol, 3, true, &pen);
        let seq = run_path(
            &ds.x,
            &ds.y,
            &grid,
            &PathSolver::CelerEnet(CelerConfig { tol, ..Default::default() }, alpha),
            true,
        );
        assert!(bat.all_converged() && seq.all_converged());
        for (i, (sb, ss)) in bat.steps.iter().zip(&seq.steps).enumerate() {
            let pb = enet_objective(&ds, sb.beta.as_ref().unwrap(), grid[i], &pen);
            let ps = enet_objective(&ds, ss.beta.as_ref().unwrap(), grid[i], &pen);
            assert!((pb - ps).abs() <= 2.0 * tol, "λ#{i}: {pb} vs {ps}");
        }
    }

    fn enet_objective(
        ds: &synth::SynthDataset,
        beta: &[f64],
        lambda: f64,
        pen: &crate::penalty::ElasticNet,
    ) -> f64 {
        use crate::penalty::Penalty as _;
        let mut r = vec![0.0; ds.y.len()];
        crate::lasso::primal::residual(&ds.x, &ds.y, beta, &mut r);
        0.5 * crate::util::linalg::dot(&r, &r) + pen.value(lambda, beta)
    }

    #[test]
    fn batched_solver_name_roundtrip() {
        let s = PathSolver::by_name("cd-batched", 1e-6).unwrap();
        assert_eq!(s.name(), "cd-batched");
        assert_eq!(PathSolver::by_name("batched", 1e-6).unwrap().name(), "cd-batched");
    }

    #[test]
    fn mt_solver_name_roundtrip_and_grid_agreement() {
        // "celer-mt" runs q = 1 block solves inside the ordinary grid
        // machinery and must certify the same objectives as the scalar
        // solvers.
        let s = PathSolver::by_name("celer-mt", 1e-6).unwrap();
        assert_eq!(s.name(), "celer-mt");
        assert_eq!(PathSolver::by_name("mt-celer", 1e-6).unwrap().name(), "celer-mt");
        let ds = synth::leukemia_mini(53);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.05, 5);
        let tol = 1e-9;
        let mt =
            run_path(&ds.x, &ds.y, &grid, &PathSolver::by_name("celer-mt", tol).unwrap(), true);
        let sc = run_path(&ds.x, &ds.y, &grid, &PathSolver::by_name("celer", tol).unwrap(), true);
        assert!(mt.all_converged() && sc.all_converged());
        for (i, (a, b)) in mt.steps.iter().zip(&sc.steps).enumerate() {
            let pa = crate::lasso::primal::primal(&ds.x, &ds.y, a.beta.as_ref().unwrap(), grid[i]);
            let pb = crate::lasso::primal::primal(&ds.x, &ds.y, b.beta.as_ref().unwrap(), grid[i]);
            assert!((pa - pb).abs() <= 2.0 * tol, "λ#{i}: {pa} vs {pb}");
            assert_eq!(a.support_size, b.support_size, "λ#{i}");
        }
    }

    #[test]
    fn logreg_solver_name_roundtrip_and_grid_runs() {
        let s = PathSolver::by_name("celer-logreg", 1e-6).unwrap();
        assert_eq!(s.name(), "celer-logreg");
        assert_eq!(PathSolver::by_name("logreg", 1e-6).unwrap().name(), "celer-logreg");
        // continuous targets are binarized by sign, so the solver runs
        // on any grid job; every step must carry a gap certificate.
        let ds = synth::leukemia_mini(55);
        let labels = crate::data::synth::sign_labels(&ds.y);
        let lmax = crate::solvers::glm::logreg_lambda_max(&ds.x, &labels);
        let grid = lambda_grid(lmax, 0.1, 4);
        let tol = 1e-7;
        let res = run_path(
            &ds.x,
            &ds.y,
            &grid,
            &PathSolver::by_name("celer-logreg", tol).unwrap(),
            true,
        );
        assert_eq!(res.solver, "celer-logreg");
        assert!(res.all_converged());
        for s in &res.steps {
            assert!(s.gap <= tol, "gap {} at λ {}", s.gap, s.lambda);
        }
        // support grows down the path
        assert!(
            res.steps.last().unwrap().support_size >= res.steps[0].support_size
        );
    }

    #[test]
    fn glm_path_warm_starts_reduce_work() {
        use crate::datafit::GlmFamily;
        let ds = synth::logreg_mini(56);
        let lmax = crate::solvers::glm::logreg_lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax, 0.05, 5);
        let cfg = crate::solvers::celer::CelerConfig { tol: 1e-7, ..Default::default() };
        let res = glm_path(&ds.x, &ds.y, GlmFamily::Logistic, &grid, &cfg, false);
        assert_eq!(res.solver, "celer-logistic");
        assert!(res.all_converged());
        // a cold solve at the last λ must cost at least as much as the
        // warm-started final path step
        let cold = crate::solvers::glm::sparse_logreg_solve(
            &ds.x,
            &ds.y,
            *grid.last().unwrap(),
            None,
            &cfg,
        );
        assert!(cold.result.epochs >= res.steps.last().unwrap().epochs);
    }

    #[test]
    fn mt_path_converges_and_reuses_workspace() {
        // True q > 1 path: warm-started, gap-certified at every λ, and
        // bit-identical whether the workspace is fresh or reused.
        use crate::multitask::solver::{mt_lambda_max, MtConfig};
        use crate::util::rng::Rng;
        let ds = synth::leukemia_mini(54);
        let (n, q) = (crate::data::design::DesignOps::n(&ds.x), 3);
        let mut rng = Rng::new(11);
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        let lmax = mt_lambda_max(&ds.x, &y, q);
        let grid = lambda_grid(lmax, 0.1, 5);
        let cfg = MtConfig { tol: 1e-8, ..Default::default() };
        let fresh = run_mt_path(&ds.x, &y, q, &grid, &cfg, true);
        assert!(fresh.all_converged());
        assert_eq!(fresh.steps.len(), grid.len());
        // support grows down the path
        let first = fresh.steps.first().unwrap().support_size;
        let last = fresh.steps.last().unwrap().support_size;
        assert!(last >= first, "support non-shrinking: {first} -> {last}");
        // dirty workspace → identical trajectory
        let mut ws = Workspace::new();
        let _ = run_mt_path_with_workspace(&ds.x, &y, q, &grid[..2], &cfg, false, &mut ws);
        let reused = run_mt_path_with_workspace(&ds.x, &y, q, &grid, &cfg, true, &mut ws);
        for (a, b) in fresh.steps.iter().zip(&reused.steps) {
            assert_eq!(a.epochs, b.epochs);
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
            assert_eq!(a.b.as_ref().unwrap().data, b.b.as_ref().unwrap().data);
        }
    }
}
