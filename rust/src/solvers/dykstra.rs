//! Dykstra's alternating-projection algorithm in the Lasso dual
//! (paper §2.3, Algorithms 2–3, Figure 1).
//!
//! The Lasso dual is the projection of `y/λ` onto `Δ_X = ∩_j C_j` with
//! slabs `C_j = {θ : |x_jᵀθ| ≤ 1}`. Dykstra's algorithm over the slabs is
//! *exactly* cyclic CD on the primal, with `r = λθ` playing the residual
//! role. This module implements Algorithm 3 with cyclic or shuffled
//! projection order and records the end-of-epoch dual iterates, which is
//! what Figure 1 visualizes.

use crate::data::design::DesignOps;
use crate::util::rng::Rng;
use crate::util::soft_threshold;

/// Projection order across epochs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Order {
    /// Same order 1..p every epoch — iterates follow a VAR (extrapolable).
    Cyclic,
    /// Order reshuffled each epoch (Fig. 1c) — trajectory is irregular.
    Shuffle { seed: u64 },
}

/// Output of a Dykstra run.
#[derive(Debug, Clone)]
pub struct DykstraOutput {
    /// Dual iterate θ = r/λ at the end of each epoch.
    pub theta_per_epoch: Vec<Vec<f64>>,
    /// Final primal coefficients β (from the CD correspondence).
    pub beta: Vec<f64>,
    /// Final residual r = λθ.
    pub r: Vec<f64>,
}

/// Run Dykstra's algorithm (Algorithm 3) for `epochs` epochs.
pub fn dykstra_lasso_dual<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    epochs: usize,
    order: Order,
) -> DykstraOutput {
    let (n, p) = (x.n(), x.p());
    assert_eq!(y.len(), n);
    let norms_sq = x.col_norms_sq();
    let mut r = y.to_vec();
    let mut beta = vec![0.0; p];
    let mut theta_per_epoch = Vec::with_capacity(epochs);
    let mut perm: Vec<usize> = (0..p).collect();
    let mut rng = match order {
        Order::Shuffle { seed } => Some(Rng::new(seed)),
        Order::Cyclic => None,
    };
    for _ in 0..epochs {
        if let Some(rng) = rng.as_mut() {
            rng.shuffle(&mut perm);
        }
        for &j in &perm {
            if norms_sq[j] == 0.0 {
                continue;
            }
            // Algorithm 3 line by line (r̃ = r + x_j β̃_j, then project):
            // equivalent to the CD update with λ = 1 scaling folded in.
            let g = x.col_dot(j, &r);
            let old = beta[j];
            let new = soft_threshold(old + g / norms_sq[j], lambda / norms_sq[j]);
            if new != old {
                x.col_axpy(j, old - new, &mut r);
                beta[j] = new;
            }
        }
        theta_per_epoch.push(r.iter().map(|&v| v / lambda).collect());
    }
    DykstraOutput { theta_per_epoch, beta, r }
}

/// Dual suboptimality `‖θ^t − θ̂‖` per epoch, with θ̂ from a long cyclic
/// run (`ref_epochs`). Returns (plain, extrapolated-K) curves — Fig. 1d.
pub fn dual_suboptimality_curves<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    epochs: usize,
    order: Order,
    k: usize,
    ref_epochs: usize,
) -> (Vec<f64>, Vec<f64>) {
    let theta_hat = dykstra_lasso_dual(x, y, lambda, ref_epochs, Order::Cyclic)
        .theta_per_epoch
        .pop()
        .expect("ref run produced iterates");
    let run = dykstra_lasso_dual(x, y, lambda, epochs, order);
    let mut plain = Vec::with_capacity(epochs);
    let mut accel = Vec::with_capacity(epochs);
    let mut buf = crate::extrapolation::ResidualBuffer::new(k);
    for theta in &run.theta_per_epoch {
        plain.push(crate::util::linalg::dist_sq(theta, &theta_hat).sqrt());
        buf.push(theta);
        let extr = buf.extrapolate().unwrap_or_else(|| theta.clone());
        accel.push(crate::util::linalg::dist_sq(&extr, &theta_hat).sqrt());
    }
    (plain, accel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::data::design::DesignOps;

    #[test]
    fn matches_cd_exactly() {
        // Dykstra in the dual IS cyclic CD: residuals must match epoch by
        // epoch with a CD run at the same order.
        let ds = synth::toy_2x2();
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 3.0;
        let dyk = dykstra_lasso_dual(&ds.x, &ds.y, lambda, 20, Order::Cyclic);
        // independent CD implementation
        let cd = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig {
                tol: 0.0,
                max_epochs: 20,
                gap_freq: 100,
                ..Default::default()
            },
        );
        for j in 0..2 {
            assert!((dyk.beta[j] - cd.beta[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn iterates_converge_to_projection() {
        let ds = synth::toy_2x2();
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 4.0;
        let out = dykstra_lasso_dual(&ds.x, &ds.y, lambda, 3000, Order::Cyclic);
        let theta = out.theta_per_epoch.last().unwrap();
        // θ̂ must be dual-feasible
        assert!(ds.x.xt_abs_max(theta) <= 1.0 + 1e-9);
        // and satisfy the projection optimality: θ̂ = (y − Xβ̂)/λ
        let mut r = vec![0.0; 2];
        crate::lasso::primal::residual(&ds.x, &ds.y, &out.beta, &mut r);
        for i in 0..2 {
            assert!((theta[i] - r[i] / lambda).abs() < 1e-12);
        }
    }

    #[test]
    fn cyclic_extrapolates_to_machine_precision() {
        // Fig. 1b/1d: with cyclic order and K=4, extrapolation reaches the
        // solution many orders of magnitude earlier than the plain
        // iterates.
        let ds = synth::toy_2x2();
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 4.0;
        let (plain, accel) =
            dual_suboptimality_curves(&ds.x, &ds.y, lambda, 40, Order::Cyclic, 4, 20_000);
        // past the warmup (K+1 = 5 epochs), accel error collapses
        let late_accel = accel[8];
        let late_plain = plain[8];
        assert!(
            late_accel < 1e-10 || late_accel < late_plain * 1e-3,
            "extrapolated {late_accel} vs plain {late_plain}"
        );
    }

    #[test]
    fn shuffle_returns_different_trajectory() {
        let ds = synth::toy_2x2();
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 4.0;
        let cyc = dykstra_lasso_dual(&ds.x, &ds.y, lambda, 10, Order::Cyclic);
        let shf = dykstra_lasso_dual(&ds.x, &ds.y, lambda, 10, Order::Shuffle { seed: 3 });
        let same = cyc
            .theta_per_epoch
            .iter()
            .zip(&shf.theta_per_epoch)
            .all(|(a, b)| crate::util::linalg::dist_sq(a, b) < 1e-24);
        assert!(!same, "shuffled order must change the trajectory");
    }
}
