//! Block-coefficient solver engine (paper §7): the scalar engine of
//! [`crate::solvers::engine`] generalized from width-1 coefficients to
//! width-`q` coefficient blocks.
//!
//! The paper's §7 observation is that the whole CELER methodology — the
//! Eq. 4 dual rescale, Definition-1 extrapolation, Gap Safe screening
//! (Eq. 9) and `d_j` working-set pricing (Eqs. 10–11) — carries over
//! verbatim to any row-separable block penalty once three scalars become
//! block quantities:
//!
//! | scalar engine                  | block engine (width q)              |
//! |--------------------------------|-------------------------------------|
//! | coefficient `β_j`              | block `B_j ∈ R^q` (`beta[j·q..]`)   |
//! | residual `r ∈ R^n`             | `R ∈ R^{n×q}`, stored lane-major    |
//! | `x_jᵀr`, `‖Xᵀr‖_∞`             | `x_jᵀR ∈ R^q`, `max_j ‖x_jᵀR‖₂`     |
//! | soft-threshold `ST`            | group soft-threshold `BST` (Eq. 21) |
//! | `|x_jᵀθ|` d-scores             | `‖x_jᵀΘ‖₂` d-scores                 |
//!
//! **Layouts.** The residual/dual matrices are *lane-major*: task `t`'s
//! n-vector is the contiguous slice `[t·n .. (t+1)·n]`, exactly the lane
//! layout of the batched engine — which is what lets every multi-RHS
//! column access go through the one pair of design kernels
//! ([`DesignOps::col_dot_lanes`] / [`DesignOps::col_axpy_lanes`]:
//! row-blocked single sweep for dense, decode-each-entry-once for CSC,
//! index translation for [`DesignView`](crate::data::view::DesignView)).
//! Coefficients are *row-major blocks*: feature `j`'s block is
//! `beta[j·q .. (j+1)·q]` (the `TaskMatrix` layout), matching the CD
//! access pattern of one block per column visit.
//!
//! **q = 1 is the scalar engine.** Every block kernel branches `q == 1`
//! to the *same* scalar kernels the sequential engine calls
//! (`col_dot`/`col_axpy`, `soft_threshold`, `xt_vec_abs_max`,
//! `primal_from_residual`), in the same order — so the block engine at
//! q = 1 is bit-identical to [`engine::solve`] with
//! [`CdStrategy`](crate::solvers::engine::CdStrategy), pinned by
//! `tests/prop_multitask.rs`.
//!
//! All full-p scans (norm caches, the fused correlation/row-norm pass of
//! [`xt_rows_max`]) run shard-deterministically on the persistent worker
//! pool via [`crate::util::par`], so block solves are bit-identical for
//! any `CELER_NUM_THREADS`.

use crate::data::design::DesignOps;
use crate::extrapolation::{ExtrapScratch, ResidualBuffer};
use crate::lasso::{dual, primal};
use crate::multitask::block_soft_threshold;
use crate::screening::ScreeningState;
use crate::solvers::engine::{self, EngineConfig, EngineOutcome, Init, StopRule, MAX_RECOVERIES};
use crate::solvers::{DualChoice, GapCheck};
use crate::util::error::{FaultEvent, FaultKind, RecoveryAction, SolveOutcome};
use crate::util::soft_threshold;
use std::time::Instant;

/// `Σ_j ‖B_j‖₂` over width-`q` blocks (the ℓ2,1 norm of Eq. 20); `q = 1`
/// takes the exact scalar ℓ1 path ([`primal::l1_norm`]).
pub fn l21_norm_blocks(beta: &[f64], q: usize) -> f64 {
    if q == 1 {
        return primal::l1_norm(beta);
    }
    // Width-8 accumulator fold over the block norms (see `util::simd`
    // for the reduction-order contract).
    crate::util::simd::sum_by(beta.len() / q, |j| {
        crate::util::linalg::norm(&beta[j * q..(j + 1) * q])
    })
}

/// Block primal `P(B) = ½‖R‖_F² + λ Σ_j ‖B_j‖₂` from a maintained
/// residual; `q = 1` is exactly [`primal::primal_from_residual`].
pub fn primal_from_residual_blocks(r: &[f64], beta: &[f64], q: usize, lambda: f64) -> f64 {
    if q == 1 {
        return primal::primal_from_residual(r, beta, lambda);
    }
    0.5 * crate::util::linalg::dot(r, r) + lambda * l21_norm_blocks(beta, q)
}

/// `out = Y − XB` (lane-major q×n), the block analogue of
/// [`primal::residual`] (which it calls exactly when q = 1): accumulate
/// `XB` with the multi-RHS axpy, then subtract from `Y` — the same
/// matvec-then-subtract sequence as the scalar path.
pub fn residual_blocks<D: DesignOps>(
    x: &D,
    y: &[f64],
    q: usize,
    lanes: &[usize],
    beta: &[f64],
    out: &mut [f64],
) {
    if q == 1 {
        primal::residual(x, y, beta, out);
        return;
    }
    let n = x.n();
    let p = x.p();
    assert_eq!(beta.len(), p * q);
    assert_eq!(y.len(), q * n);
    assert_eq!(out.len(), q * n);
    out.fill(0.0);
    for j in 0..p {
        let bj = &beta[j * q..(j + 1) * q];
        if bj.iter().any(|&v| v != 0.0) {
            x.col_axpy_lanes(j, bj, out, n, lanes);
        }
    }
    for i in 0..y.len() {
        out[i] = y[i] - out[i];
    }
}

/// Row support of a p×q block matrix: rows with any non-zero entry
/// (`q = 1`: exactly [`primal::support`]).
pub fn block_support(beta: &[f64], q: usize) -> Vec<usize> {
    if q == 1 {
        return primal::support(beta);
    }
    beta.chunks_exact(q)
        .enumerate()
        .filter(|(_, b)| b.iter().any(|&v| v != 0.0))
        .map(|(j, _)| j)
        .collect()
}

/// Fused block correlation pass: fill `block[j·q .. (j+1)·q] = x_jᵀV`
/// (V the lane-major q×n matrix `v`, one [`DesignOps::col_dot_lanes`]
/// per column), `rows[j] = ‖x_jᵀV‖₂`, and return `max_j rows[j]` —
/// everything the Frobenius dual rescale of Eq. 4 generalized to §7
/// (`Θ = R / max(λ, max_j ‖x_jᵀR‖₂)`) and the §7 `d_j` pricing need, in
/// one shard-deterministic pooled pass ([`crate::util::par::par_fill_rows_max`]).
///
/// `q = 1` delegates to the scalar fused [`DesignOps::xt_vec_abs_max`],
/// reproducing the scalar engine's bits exactly (`rows` then holds
/// `|block[j]|`, which is what the block d-scores consume).
pub fn xt_rows_max<D: DesignOps>(
    x: &D,
    v: &[f64],
    n: usize,
    q: usize,
    lanes: &[usize],
    block: &mut [f64],
    rows: &mut [f64],
) -> f64 {
    let p = x.p();
    assert_eq!(v.len(), q * n);
    assert_eq!(block.len(), p * q);
    assert_eq!(rows.len(), p);
    if q == 1 {
        let m = x.xt_vec_abs_max(v, block);
        let blk: &[f64] = block;
        crate::util::par::par_fill_cost(rows, 1, |j| blk[j].abs());
        return m;
    }
    let cost = x.col_cost_hint().saturating_mul(q);
    crate::util::par::par_fill_rows_max(block, rows, q, cost, |j, slot| {
        x.col_dot_lanes(j, v, n, lanes, slot);
        crate::util::linalg::norm(slot)
    })
}

/// Reusable scratch for [`BlockDualState::update`]: the block analogue
/// of [`DualScratch`](crate::solvers::DualScratch) — correlation blocks,
/// their row norms, and the extrapolated dual point, so a block gap
/// check performs no heap allocation once warm.
#[derive(Debug, Clone, Default)]
pub struct BlockDualScratch {
    /// `XᵀR` for the current residual (p×q row-major blocks).
    pub xtr: Vec<f64>,
    /// Row norms `‖x_jᵀR‖₂` (length p).
    pub xtr_rows: Vec<f64>,
    /// `XᵀR_accel` for the extrapolated residual (p×q).
    pub xtr_acc: Vec<f64>,
    /// Row norms for the extrapolated correlations (length p).
    pub xtr_acc_rows: Vec<f64>,
    /// Rescaled extrapolated dual point Θ_accel (lane-major q×n).
    pub theta_acc: Vec<f64>,
    /// Extrapolation temporaries (K diff vectors of length q·n, Gram,
    /// r_accel) — one ring scratch per block solve lane.
    pub extrap: ExtrapScratch,
}

impl BlockDualScratch {
    /// Size the buffers for an (n, q, p) problem, reusing capacity.
    pub fn prepare(&mut self, n: usize, q: usize, p: usize) {
        self.xtr.resize(p * q, 0.0);
        self.xtr_rows.resize(p, 0.0);
        self.xtr_acc.resize(p * q, 0.0);
        self.xtr_acc_rows.resize(p, 0.0);
        self.theta_acc.resize(q * n, 0.0);
    }
}

/// Block dual-point machinery: the §7 generalization of
/// [`DualState`](crate::solvers::DualState). Maintains the residual ring
/// over the vectorized q·n residuals (Definition 1 applies row-wise, so
/// extrapolation runs on the flattened matrices), computes Θ_res and
/// Θ_accel with the Frobenius rescale, and keeps the best dual point
/// (Eq. 13). `‖Y‖_F²` is cached once per solve — the satellite fix for
/// the legacy `mt_dual` recomputing it at every gap check.
#[derive(Debug, Clone)]
pub struct BlockDualState {
    pub buffer: ResidualBuffer,
    /// Best dual point so far (lane-major q×n, feasible).
    pub theta: Vec<f64>,
    /// Cached row norms `‖x_jᵀΘ‖₂` for the best point (length p) — what
    /// block screening and the §7 `d_j` pricing consume. At q = 1 this
    /// is `|x_jᵀθ|`, the absolute value of the scalar engine's cache.
    pub xtheta_rows: Vec<f64>,
    /// D(Θ) for the best point.
    pub dval: f64,
    /// Cached `‖Y‖_F²` (`NaN` until the first update after a reset).
    pub y_norm_sq: f64,
    /// Use Θ_accel at all.
    pub extrapolate: bool,
    /// Keep the best-of {previous, res, accel} (Eq. 13).
    pub monotone: bool,
    /// Last choice made.
    pub last_choice: DualChoice,
}

impl Default for BlockDualState {
    fn default() -> Self {
        BlockDualState {
            buffer: ResidualBuffer::new(1),
            theta: Vec::new(),
            xtheta_rows: Vec::new(),
            dval: f64::NEG_INFINITY,
            y_norm_sq: f64::NAN,
            extrapolate: false,
            monotone: true,
            last_choice: DualChoice::Residual,
        }
    }
}

impl BlockDualState {
    /// Re-initialize for a fresh (n, q, p) solve, reusing capacity.
    pub fn reset(
        &mut self,
        n: usize,
        q: usize,
        p: usize,
        k: usize,
        extrapolate: bool,
        monotone: bool,
    ) {
        self.buffer.reset(k);
        self.theta.clear();
        self.theta.resize(q * n, 0.0);
        self.xtheta_rows.clear();
        self.xtheta_rows.resize(p, 0.0);
        self.dval = f64::NEG_INFINITY;
        self.y_norm_sq = f64::NAN;
        self.extrapolate = extrapolate;
        self.monotone = monotone;
        self.last_choice = DualChoice::Residual;
    }

    /// Ingest the current residual (lane-major q×n), refresh Θ, and
    /// return (D(Θ_res), D(Θ_accel) if computed). Mirrors
    /// [`DualState::update`](crate::solvers::DualState::update) step for
    /// step; at q = 1 the arithmetic is identical to it.
    pub fn update<D: DesignOps>(
        &mut self,
        x: &D,
        y: &[f64],
        n: usize,
        q: usize,
        lanes: &[usize],
        lambda: f64,
        r: &[f64],
        scratch: &mut BlockDualScratch,
    ) -> (f64, Option<f64>) {
        self.buffer.push(r);
        let p = x.p();
        scratch.prepare(n, q, p);
        if self.y_norm_sq.is_nan() {
            self.y_norm_sq = crate::util::linalg::dot(y, y);
        }

        // Θ_res = R / max(λ, max_j ‖x_jᵀR‖₂): the fused block pass
        // yields the correlation blocks, their row norms and the max in
        // one pooled sweep.
        let denom = lambda
            .max(xt_rows_max(x, r, n, q, lanes, &mut scratch.xtr, &mut scratch.xtr_rows));
        let inv = 1.0 / denom;
        let d_res = {
            // D(Θ_res) without materializing Θ_res: Θ = R·inv
            let mut dist_sq = 0.0;
            for i in 0..y.len() {
                let d = r[i] * inv - y[i] / lambda;
                dist_sq += d * d;
            }
            0.5 * self.y_norm_sq - 0.5 * lambda * lambda * dist_sq
        };

        let mut best_val = d_res;
        let mut best = DualChoice::Residual;

        let mut d_accel_out = None;
        if self.extrapolate && self.buffer.extrapolate_into(&mut scratch.extrap) {
            let r_acc = &scratch.extrap.r_accel;
            let denom_a = lambda.max(xt_rows_max(
                x,
                r_acc,
                n,
                q,
                lanes,
                &mut scratch.xtr_acc,
                &mut scratch.xtr_acc_rows,
            ));
            let inv_a = 1.0 / denom_a;
            for (t, &v) in scratch.theta_acc.iter_mut().zip(r_acc.iter()) {
                *t = v * inv_a;
            }
            for v in scratch.xtr_acc_rows.iter_mut() {
                *v *= inv_a;
            }
            let d_acc =
                dual::dual_objective_cached(y, &scratch.theta_acc, lambda, self.y_norm_sq);
            d_accel_out = Some(d_acc);
            if d_acc > best_val {
                best_val = d_acc;
                best = DualChoice::Extrapolated;
            }
        }

        if self.monotone && self.dval >= best_val {
            self.last_choice = DualChoice::Previous;
            return (d_res, d_accel_out);
        }

        match best {
            DualChoice::Extrapolated => {
                self.theta.clear();
                self.theta.extend_from_slice(&scratch.theta_acc);
                self.xtheta_rows.clear();
                self.xtheta_rows.extend_from_slice(&scratch.xtr_acc_rows);
                self.dval = best_val;
            }
            _ => {
                self.theta.clear();
                self.theta.extend(r.iter().map(|&v| v * inv));
                self.xtheta_rows.clear();
                self.xtheta_rows.extend(scratch.xtr_rows.iter().map(|&v| v * inv));
                self.dval = d_res;
            }
        }
        self.last_choice = best;
        (d_res, d_accel_out)
    }
}

/// One block epoch's view of the solver state, handed to a
/// [`BlockStrategy`]. `beta` holds p row-major width-q blocks, `r` the
/// lane-major q×n residual; `u`/`delta` are q-wide per-column scratch.
pub struct BlockEpochCtx<'a> {
    pub n: usize,
    pub q: usize,
    pub lambda: f64,
    /// Identity lane map `[0, 1, …, q−1]` for the multi-RHS kernels.
    pub lanes: &'a [usize],
    pub norms_sq: &'a [f64],
    pub active: &'a [usize],
    pub beta: &'a mut [f64],
    pub r: &'a mut [f64],
    pub u: &'a mut [f64],
    pub delta: &'a mut [f64],
}

/// A block solver strategy: one primal epoch over width-q blocks — the
/// block analogue of [`Strategy`](crate::solvers::engine::Strategy).
pub trait BlockStrategy<D: DesignOps> {
    /// Run one primal epoch, updating `ctx.beta` and `ctx.r` in place.
    fn epoch(&mut self, x: &D, ctx: &mut BlockEpochCtx<'_>);
}

/// Cyclic block coordinate descent (Eq. 21: `B_j ← BST(B_j + x_jᵀR/‖x_j‖²,
/// λ/‖x_j‖²)`): per column, one [`DesignOps::col_dot_lanes`] computes the
/// q correlations with the column loaded once, the group soft-threshold
/// updates the block, and one [`DesignOps::col_axpy_lanes`] writes all q
/// residual updates back. At q = 1 this is exactly the scalar
/// [`CdStrategy`](crate::solvers::engine::CdStrategy) epoch.
pub struct BlockCdStrategy;

impl<D: DesignOps> BlockStrategy<D> for BlockCdStrategy {
    fn epoch(&mut self, x: &D, c: &mut BlockEpochCtx<'_>) {
        let q = c.q;
        if q == 1 {
            // Exact scalar Algorithm-1 epoch (engine::CdStrategy).
            for &j in c.active {
                let nrm = c.norms_sq[j];
                let g = x.col_dot(j, c.r);
                let old = c.beta[j];
                let new = soft_threshold(old + g / nrm, c.lambda / nrm);
                if new != old {
                    x.col_axpy(j, old - new, c.r);
                    c.beta[j] = new;
                }
            }
            return;
        }
        for &j in c.active {
            let nrm = c.norms_sq[j];
            // u = B_j + x_jᵀR / ‖x_j‖² (one multi-RHS sweep of column j)
            x.col_dot_lanes(j, c.r, c.n, c.lanes, c.u);
            let base = j * q;
            for t in 0..q {
                c.u[t] = c.beta[base + t] + c.u[t] / nrm;
            }
            block_soft_threshold(c.u, c.lambda / nrm);
            let mut any_update = false;
            for t in 0..q {
                let d = c.beta[base + t] - c.u[t];
                c.delta[t] = d;
                any_update |= d != 0.0;
            }
            if any_update {
                x.col_axpy_lanes(j, c.delta, c.r, c.n, c.lanes);
                c.beta[base..base + q].copy_from_slice(c.u);
            }
        }
    }
}

/// Reusable block solver state: the width-q generalization of the engine
/// [`Workspace`](crate::solvers::engine::Workspace). One block workspace
/// serves any number of sequential solves (different λ, q, working sets);
/// buffers are resized — never reallocated once warm. The outer
/// working-set loop (Multi-Task CELER, [`crate::multitask::solver`])
/// keeps its dual candidates and pricing buffers here too, and nests an
/// `inner` block workspace for its subproblem solves on zero-copy
/// [`DesignView`](crate::data::view::DesignView)s.
#[derive(Default)]
pub struct BlockWorkspace {
    /// Block width of the most recent run.
    pub q: usize,
    /// Primal iterate: p row-major width-q blocks.
    pub beta: Vec<f64>,
    /// Maintained residual (lane-major q×n).
    pub r: Vec<f64>,
    /// Check-time residual copy.
    pub r_check: Vec<f64>,
    /// Cached `‖x_j‖²` for the current design.
    pub norms_sq: Vec<f64>,
    /// Cached `‖x_j‖` (screening / pricing use plain norms).
    pub col_norms: Vec<f64>,
    /// Engine-maintained active set.
    pub active: Vec<usize>,
    /// Identity lane map `[0, …, q−1]` for the multi-RHS kernels.
    pub lanes: Vec<usize>,
    /// Block dual machinery (Θ, row norms, extrapolation ring).
    pub dual: BlockDualState,
    /// Gap-check scratch (XᵀR blocks, row norms, Θ_accel).
    pub scratch: BlockDualScratch,
    /// Dynamic Gap Safe screening state (block d-scores).
    pub screening: ScreeningState,
    /// q-wide CD scratch: the candidate block u.
    pub u: Vec<f64>,
    /// q-wide CD scratch: per-task coefficient deltas.
    pub delta: Vec<f64>,
    /// Outer-loop (MT CELER) dual candidates, lane-major q×n each.
    pub theta: Vec<f64>,
    pub theta_inner: Vec<f64>,
    pub theta_res: Vec<f64>,
    /// Outer-loop cached pricing row norms `‖x_jᵀΘ‖₂`.
    pub xtheta_rows: Vec<f64>,
    pub xtheta_inner_rows: Vec<f64>,
    pub d_scores: Vec<f64>,
    /// Subproblem warm-start blocks (|W_t|×q).
    pub beta_ws: Vec<f64>,
    /// Lane-major transposition of the caller's row-major Y.
    pub y_lanes: Vec<f64>,
    /// Watchdog checkpoint: blocks at the last certified gap check.
    pub ckpt_beta: Vec<f64>,
    /// Watchdog checkpoint: lane-major residual at the last certified check.
    pub ckpt_r: Vec<f64>,
    /// Watchdog checkpoint: dual point at the last certified check.
    pub ckpt_theta: Vec<f64>,
    /// Nested workspace for inner (working-set) solves.
    pub inner: Option<Box<BlockWorkspace>>,
}

impl BlockWorkspace {
    pub fn new() -> Self {
        BlockWorkspace::default()
    }

    /// Initialize the primal state for a width-q solve on `x`: cached
    /// column norms, blocks from `beta0` (zeros when `None`), and the
    /// residual `R = Y − XB`. The block analogue of
    /// [`Workspace::init_primal`](crate::solvers::engine::Workspace::init_primal).
    pub fn init_primal<D: DesignOps>(&mut self, x: &D, y: &[f64], q: usize, beta0: Option<&[f64]>) {
        let n = x.n();
        let p = x.p();
        assert!(q >= 1, "block width q must be >= 1");
        assert_eq!(y.len(), q * n, "y must be lane-major q×n");
        self.q = q;
        self.lanes.clear();
        self.lanes.extend(0..q);
        engine::fill_norm_caches(x, &mut self.norms_sq, &mut self.col_norms);
        self.beta.resize(p * q, 0.0);
        match beta0 {
            Some(b) => {
                assert_eq!(b.len(), p * q, "warm start must be p×q blocks");
                self.beta.copy_from_slice(b);
            }
            None => self.beta.fill(0.0),
        }
        self.r.resize(q * n, 0.0);
        residual_blocks(x, y, q, &self.lanes, &self.beta, &mut self.r);
        self.u.resize(q, 0.0);
        self.delta.resize(q, 0.0);
    }

    /// Take the nested inner workspace (creating it on first use); hand
    /// it back via [`BlockWorkspace::put_inner`].
    pub fn take_inner(&mut self) -> Box<BlockWorkspace> {
        self.inner.take().unwrap_or_default()
    }

    /// Return the nested inner workspace after an inner solve.
    pub fn put_inner(&mut self, inner: Box<BlockWorkspace>) {
        self.inner = Some(inner);
    }
}

/// Run the block engine: `strategy` epochs over `x` until the duality
/// gap drops below `cfg.tol` or `cfg.max_epochs` is reached. The
/// solution is left in `ws` (blocks in `ws.beta`, lane-major residual in
/// `ws.r`, dual point in `ws.dual.theta`). Mirrors [`engine::solve`]
/// step for step; only [`StopRule::DualityGap`] is supported (a weighted
/// primal-decrease block rule is GLM future work, see ROADMAP).
pub fn solve_blocks<D: DesignOps, S: BlockStrategy<D>>(
    x: &D,
    y: &[f64],
    q: usize,
    lambda: f64,
    init: Init<'_>,
    active0: Option<&[usize]>,
    cfg: &EngineConfig,
    ws: &mut BlockWorkspace,
    strategy: &mut S,
) -> EngineOutcome {
    let n = x.n();
    let p = x.p();
    assert_eq!(y.len(), q * n, "y must be lane-major q×n");
    assert!(
        matches!(cfg.stop, StopRule::DualityGap),
        "the block engine supports only StopRule::DualityGap"
    );
    let start = Instant::now();
    let beta0 = match init {
        Init::Zeros => None,
        Init::Warm(b) => Some(b),
        Init::Resume => panic!("Init::Resume is not supported by the block engine"),
    };

    // ---- buffers (capacity reused across runs) ----
    ws.init_primal(x, y, q, beta0);
    ws.dual.reset(n, q, p, cfg.k.max(1), cfg.extrapolate, cfg.best_dual);
    ws.scratch.prepare(n, q, p);
    ws.screening.reset_all_active(p);
    ws.r_check.resize(q * n, 0.0);

    // ---- active set (same construction as the scalar engine) ----
    ws.active.clear();
    match active0 {
        Some(a) => {
            let norms = &ws.norms_sq;
            ws.active.extend(a.iter().copied().filter(|&j| norms[j] > 0.0));
        }
        None => {
            let norms = &ws.norms_sq;
            ws.active.extend((0..p).filter(|&j| norms[j] > 0.0));
        }
    }

    let mut trace: Vec<GapCheck> = Vec::new();
    let mut gap = f64::INFINITY;
    let mut epochs = 0usize;
    let mut converged = false;

    // ---- watchdog state (mirrors the scalar engine) ----
    // The initial iterate is trivially certified (its gap is just
    // unknown), so recovery always has a finite state to roll back to —
    // pure memcpys on the fault-free path, no arithmetic changes.
    let mut faults: Vec<FaultEvent> = Vec::new();
    let mut recoveries = 0usize;
    let mut ckpt_primal = f64::INFINITY;
    let mut ckpt_gap = f64::INFINITY;
    ws.ckpt_beta.resize(ws.beta.len(), 0.0);
    ws.ckpt_beta.copy_from_slice(&ws.beta);
    ws.ckpt_r.resize(ws.r.len(), 0.0);
    ws.ckpt_r.copy_from_slice(&ws.r);
    ws.ckpt_theta.resize(q * n, 0.0);
    ws.ckpt_theta.copy_from_slice(&ws.dual.theta);

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        // ---- one primal block epoch ----
        {
            let BlockWorkspace { beta, r, active, norms_sq, lanes, u, delta, .. } = ws;
            let mut ctx = BlockEpochCtx {
                n,
                q,
                lambda,
                lanes: lanes.as_slice(),
                norms_sq: norms_sq.as_slice(),
                active: active.as_slice(),
                beta: beta.as_mut_slice(),
                r: r.as_mut_slice(),
                u: u.as_mut_slice(),
                delta: delta.as_mut_slice(),
            };
            strategy.epoch(x, &mut ctx);
        }

        if epoch % cfg.gap_freq == 0 || epoch == cfg.max_epochs {
            cfg.faults.inject_nan_residual(epoch, &mut ws.r);
            ws.r_check.copy_from_slice(&ws.r);
            let (d_res, d_accel) =
                ws.dual.update(x, y, n, q, &ws.lanes, lambda, &ws.r_check, &mut ws.scratch);
            let p_val = primal_from_residual_blocks(&ws.r_check, &ws.beta, q, lambda);
            gap = p_val - ws.dual.dval;
            // ---- non-finite / divergence watchdog ----
            let diverged = ckpt_primal.is_finite()
                && p_val.is_finite()
                && p_val > 100.0 * (ckpt_primal.abs() + 1.0);
            if !gap.is_finite() && !(p_val.is_finite() && ws.dual.dval.is_finite()) || diverged {
                let kind = if !p_val.is_finite() {
                    FaultKind::NonFiniteResidual
                } else if !ws.dual.dval.is_finite() {
                    FaultKind::NonFiniteDual
                } else if diverged {
                    FaultKind::PrimalDivergence
                } else {
                    FaultKind::NonFiniteGap
                };
                if recoveries < MAX_RECOVERIES {
                    recoveries += 1;
                    ws.beta.copy_from_slice(&ws.ckpt_beta);
                    ws.r.copy_from_slice(&ws.ckpt_r);
                    // flush the extrapolation ring: the corrupted
                    // residuals must not feed Definition-1 extrapolation
                    ws.dual.reset(n, q, p, cfg.k.max(1), cfg.extrapolate, cfg.best_dual);
                    faults.push(FaultEvent { kind, epoch, action: RecoveryAction::RolledBack });
                    gap = ckpt_gap;
                    continue;
                }
                faults.push(FaultEvent { kind, epoch, action: RecoveryAction::Aborted });
                ws.beta.copy_from_slice(&ws.ckpt_beta);
                ws.r.copy_from_slice(&ws.ckpt_r);
                ws.dual.theta.resize(q * n, 0.0);
                ws.dual.theta.copy_from_slice(&ws.ckpt_theta);
                gap = ckpt_gap;
                converged = false;
                break;
            }
            // Screen only while unconverged (same invariant as the
            // scalar engine: the reported (B, gap) pair is the one that
            // passed the stopping test).
            if cfg.screen && gap > cfg.tol {
                ws.screening.screen_block(
                    x,
                    &ws.dual.xtheta_rows,
                    &ws.col_norms,
                    gap,
                    lambda,
                    n,
                    q,
                    &ws.lanes,
                    &mut ws.beta,
                    &mut ws.r,
                );
                let screening = &ws.screening;
                ws.active.retain(|&j| !screening.is_screened(j));
            }
            // This check passed the watchdog: refresh the certified
            // checkpoint (post-screening, so a rollback restores state
            // consistent with the screened active set).
            ws.ckpt_beta.copy_from_slice(&ws.beta);
            ws.ckpt_r.copy_from_slice(&ws.r);
            ws.ckpt_theta.copy_from_slice(&ws.dual.theta);
            ckpt_primal = p_val;
            ckpt_gap = gap;
            if cfg.trace {
                trace.push(GapCheck {
                    epoch,
                    primal: p_val,
                    dual_res: d_res,
                    dual_accel: d_accel,
                    gap,
                    n_screened: ws.screening.n_screened(),
                    seconds: start.elapsed().as_secs_f64(),
                });
            }
            if gap <= cfg.tol {
                converged = true;
                break;
            }
            if let Some(limit) = cfg.max_seconds {
                if start.elapsed().as_secs_f64() >= limit {
                    break;
                }
            }
        }
    }

    let status = SolveOutcome::from_run(converged, gap, epochs, faults);
    EngineOutcome { gap, epochs, converged, trace, status }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csc::CscMatrix;
    use crate::data::dense::DenseMatrix;
    use crate::data::design::DesignMatrix;
    use crate::solvers::engine::{solve, CdStrategy, Workspace};
    use crate::util::rng::Rng;

    fn engine_cfg(tol: f64, screen: bool) -> EngineConfig {
        EngineConfig {
            tol,
            max_epochs: 10_000,
            gap_freq: 10,
            k: 5,
            extrapolate: true,
            best_dual: true,
            screen,
            trace: false,
            stop: StopRule::DualityGap,
            ..EngineConfig::default()
        }
    }

    fn random_block_problem(
        seed: u64,
        n: usize,
        p: usize,
        q: usize,
        density: f64,
    ) -> (DesignMatrix, DesignMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0; n * p];
        for v in data.iter_mut() {
            if rng.uniform() < density {
                *v = rng.normal();
            }
        }
        let d = DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data.clone()));
        let s = DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &data));
        let y: Vec<f64> = (0..q * n).map(|_| rng.normal()).collect();
        (d, s, y)
    }

    #[test]
    fn helpers_reduce_to_scalar_at_q1() {
        let beta = [1.0, -2.0, 0.0, 0.5];
        assert_eq!(l21_norm_blocks(&beta, 1), primal::l1_norm(&beta));
        let r = [0.5, -0.25, 4.0];
        assert_eq!(
            primal_from_residual_blocks(&r, &beta, 1, 0.3).to_bits(),
            primal::primal_from_residual(&r, &beta, 0.3).to_bits()
        );
        assert_eq!(block_support(&beta, 1), primal::support(&beta));
    }

    #[test]
    fn residual_blocks_matches_per_task() {
        let (d, s, y) = random_block_problem(10, 9, 7, 3, 0.6);
        let mut rng = Rng::new(4);
        let beta: Vec<f64> = (0..7 * 3).map(|_| rng.normal()).collect();
        let lanes: Vec<usize> = (0..3).collect();
        for x in [&d, &s] {
            let mut out = vec![0.0; 3 * 9];
            residual_blocks(x, &y, 3, &lanes, &beta, &mut out);
            // per-task oracle: r_t = y_t − X β_{·t}
            for t in 0..3 {
                let bt: Vec<f64> = (0..7).map(|j| beta[j * 3 + t]).collect();
                let mut rt = vec![0.0; 9];
                primal::residual(x, &y[t * 9..(t + 1) * 9], &bt, &mut rt);
                for i in 0..9 {
                    assert!((out[t * 9 + i] - rt[i]).abs() < 1e-12, "t={t} i={i}");
                }
            }
        }
    }

    #[test]
    fn xt_rows_max_matches_oracle() {
        let (d, s, y) = random_block_problem(11, 12, 10, 4, 0.5);
        let lanes: Vec<usize> = (0..4).collect();
        for x in [&d, &s] {
            let mut block = vec![0.0; 10 * 4];
            let mut rows = vec![0.0; 10];
            let m = xt_rows_max(x, &y, 12, 4, &lanes, &mut block, &mut rows);
            let mut expect_max = 0.0f64;
            for j in 0..10 {
                let mut acc = 0.0;
                for t in 0..4 {
                    let v = x.col_dot(j, &y[t * 12..(t + 1) * 12]);
                    assert!((block[j * 4 + t] - v).abs() < 1e-12, "block j={j} t={t}");
                    acc += v * v;
                }
                let nrm = acc.sqrt();
                assert!((rows[j] - nrm).abs() < 1e-12, "rows j={j}");
                expect_max = expect_max.max(nrm);
            }
            assert!((m - expect_max).abs() < 1e-12);
        }
    }

    #[test]
    fn q1_block_engine_is_bitwise_scalar_engine() {
        // The tentpole invariant: q = 1 compiles down to exactly the
        // scalar engine's arithmetic (same kernels, same order).
        let ds = crate::data::synth::leukemia_mini(90);
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 10.0;
        for screen in [false, true] {
            let cfg = engine_cfg(1e-9, screen);
            let mut sws = Workspace::new();
            let a = solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut sws, &mut CdStrategy);
            let mut bws = BlockWorkspace::new();
            let b = solve_blocks(
                &ds.x,
                &ds.y,
                1,
                lambda,
                Init::Zeros,
                None,
                &cfg,
                &mut bws,
                &mut BlockCdStrategy,
            );
            assert_eq!(a.epochs, b.epochs, "screen={screen}");
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
            assert_eq!(a.converged, b.converged);
            assert_eq!(sws.beta, bws.beta);
            assert_eq!(sws.r, bws.r);
            assert_eq!(sws.dual.theta, bws.dual.theta);
        }
    }

    #[test]
    fn block_solve_certifies_gap_and_row_sparsity() {
        let (d, _, y) = random_block_problem(12, 16, 24, 3, 1.0);
        let lanes: Vec<usize> = (0..3).collect();
        // λ at a fraction of the block λ_max
        let mut block = vec![0.0; 24 * 3];
        let mut rows = vec![0.0; 24];
        let lmax = xt_rows_max(&d, &y, 16, 3, &lanes, &mut block, &mut rows);
        let lambda = lmax / 4.0;
        let cfg = engine_cfg(1e-9, true);
        let mut ws = BlockWorkspace::new();
        let out =
            solve_blocks(&d, &y, 3, lambda, Init::Zeros, None, &cfg, &mut ws, &mut BlockCdStrategy);
        assert!(out.converged, "gap {}", out.gap);
        // dual feasibility: max_j ‖x_jᵀΘ‖₂ ≤ 1
        let m = xt_rows_max(&d, &ws.dual.theta, 16, 3, &lanes, &mut block, &mut rows);
        assert!(m <= 1.0 + 1e-10, "feasible, got {m}");
        // the gap claim is recomputable
        let p_val = primal_from_residual_blocks(&ws.r, &ws.beta, 3, lambda);
        let d_val = dual::dual_objective(&y, &ws.dual.theta, lambda);
        assert!((p_val - d_val - out.gap).abs() < 1e-10);
        // row sparsity: each block entirely zero or entirely active
        for j in 0..24 {
            let row = &ws.beta[j * 3..(j + 1) * 3];
            let nz = row.iter().filter(|&&v| v != 0.0).count();
            assert!(nz == 0 || nz == 3, "row {j}: {row:?}");
        }
    }

    #[test]
    fn dense_and_sparse_block_solves_agree() {
        let (d, s, y) = random_block_problem(13, 14, 18, 2, 0.4);
        let lanes: Vec<usize> = (0..2).collect();
        let mut block = vec![0.0; 18 * 2];
        let mut rows = vec![0.0; 18];
        let lmax = xt_rows_max(&d, &y, 14, 2, &lanes, &mut block, &mut rows);
        let lambda = lmax / 5.0;
        let cfg = engine_cfg(1e-10, true);
        let mut wd = BlockWorkspace::new();
        let od =
            solve_blocks(&d, &y, 2, lambda, Init::Zeros, None, &cfg, &mut wd, &mut BlockCdStrategy);
        let mut wsp = BlockWorkspace::new();
        let os =
            solve_blocks(&s, &y, 2, lambda, Init::Zeros, None, &cfg, &mut wsp, &mut BlockCdStrategy);
        assert!(od.converged && os.converged);
        for (a, b) in wd.beta.iter().zip(&wsp.beta) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn workspace_reuse_is_equivalent_to_fresh() {
        let (d, _, y) = random_block_problem(14, 12, 20, 3, 1.0);
        let lanes: Vec<usize> = (0..3).collect();
        let mut block = vec![0.0; 20 * 3];
        let mut rows = vec![0.0; 20];
        let lmax = xt_rows_max(&d, &y, 12, 3, &lanes, &mut block, &mut rows);
        let lambda = lmax / 6.0;
        let cfg = engine_cfg(1e-9, true);
        let mut fresh = BlockWorkspace::new();
        let a = solve_blocks(
            &d,
            &y,
            3,
            lambda,
            Init::Zeros,
            None,
            &cfg,
            &mut fresh,
            &mut BlockCdStrategy,
        );
        let mut reused = BlockWorkspace::new();
        // dirty with a different λ and width first
        let y1 = &y[..12];
        let _ = solve_blocks(
            &d,
            y1,
            1,
            lambda * 2.0,
            Init::Zeros,
            None,
            &cfg,
            &mut reused,
            &mut BlockCdStrategy,
        );
        let b = solve_blocks(
            &d,
            &y,
            3,
            lambda,
            Init::Zeros,
            None,
            &cfg,
            &mut reused,
            &mut BlockCdStrategy,
        );
        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.gap.to_bits(), b.gap.to_bits());
        assert_eq!(fresh.beta, reused.beta);
        assert_eq!(fresh.r, reused.r);
        assert_eq!(fresh.dual.theta, reused.dual.theta);
    }
}
