//! GLMNET-style Lasso solver (Friedman, Hastie & Tibshirani, 2010):
//! sequential strong rules + ever-active set + KKT verification, with the
//! package's *primal-decrease* stopping criterion.
//!
//! This baseline exists to reproduce Figure 5: because the stopping rule
//! does not control the duality gap, the identified supports contain many
//! features outside the equicorrelation set ("false positives") at loose
//! tolerances — unlike gap-controlled solvers.

use crate::data::design::{DesignMatrix, DesignOps};
use crate::lasso::{dual, primal};
use crate::solvers::SolveResult;
use crate::util::soft_threshold;

/// GLMNET-style configuration.
#[derive(Debug, Clone)]
pub struct GlmnetConfig {
    /// Primal-decrease stopping threshold ε (NOT a duality gap!).
    pub tol: f64,
    /// Strong-rule / KKT passes cap.
    pub max_outer: usize,
    /// Inner CD epoch cap per pass.
    pub max_inner_epochs: usize,
    /// KKT violation tolerance when verifying candidates.
    pub kkt_tol: f64,
}

impl Default for GlmnetConfig {
    fn default() -> Self {
        GlmnetConfig { tol: 1e-6, max_outer: 50, max_inner_epochs: 10_000, kkt_tol: 1e-12 }
    }
}

/// Solve one point of a λ-path GLMNET-style.
///
/// `lambda_prev` is the previous (larger) λ on the path — the sequential
/// strong rule keeps features with `|x_jᵀr⁰| ≥ 2λ − λ_prev`. For a cold
/// start pass `lambda_prev = λ_max`.
pub fn glmnet_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    lambda_prev: f64,
    beta0: Option<&[f64]>,
    cfg: &GlmnetConfig,
) -> SolveResult {
    let (n, p) = (x.n(), x.p());
    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    let mut r = vec![0.0; n];
    primal::residual(x, y, &beta, &mut r);
    let norms_sq = x.col_norms_sq();

    // ---- sequential strong rule on the warm-start residual ----
    let mut xtr = vec![0.0; p];
    x.xt_vec(&r, &mut xtr);
    let strong_thresh = 2.0 * lambda - lambda_prev;
    let mut in_strong: Vec<bool> = (0..p)
        .map(|j| norms_sq[j] > 0.0 && xtr[j].abs() >= strong_thresh)
        .collect();
    // ever-active set starts from the warm-start support
    let mut in_active: Vec<bool> = (0..p).map(|j| beta[j] != 0.0).collect();
    for j in 0..p {
        if in_active[j] {
            in_strong[j] = true;
        }
    }
    let mut active: Vec<usize> = (0..p).filter(|&j| in_active[j]).collect();
    if active.is_empty() {
        // seed with the strong set (GLMNET's first pass solves on it)
        active = (0..p).filter(|&j| in_strong[j]).collect();
        for &j in &active {
            in_active[j] = true;
        }
    }

    let mut epochs = 0usize;
    let mut converged = false;
    for _pass in 0..cfg.max_outer {
        // ---- CD on the active set until primal decrease < tol ----
        let mut prev_obj = primal::primal_from_residual(&r, &beta, lambda);
        for _ in 0..cfg.max_inner_epochs {
            epochs += 1;
            for &j in &active {
                let nrm = norms_sq[j];
                if nrm == 0.0 {
                    continue;
                }
                let g = x.col_dot(j, &r);
                let old = beta[j];
                let new = soft_threshold(old + g / nrm, lambda / nrm);
                if new != old {
                    x.col_axpy(j, old - new, &mut r);
                    beta[j] = new;
                }
            }
            let obj = primal::primal_from_residual(&r, &beta, lambda);
            if prev_obj - obj < cfg.tol {
                break;
            }
            prev_obj = obj;
        }

        // ---- KKT on the strong set ----
        x.xt_vec(&r, &mut xtr);
        let mut added = false;
        for j in 0..p {
            if in_strong[j] && !in_active[j] && xtr[j].abs() > lambda + cfg.kkt_tol {
                in_active[j] = true;
                active.push(j);
                added = true;
            }
        }
        if added {
            continue;
        }
        // ---- KKT on all features (strong-rule violations are rare) ----
        for j in 0..p {
            if !in_active[j] && norms_sq[j] > 0.0 && xtr[j].abs() > lambda + cfg.kkt_tol {
                in_active[j] = true;
                in_strong[j] = true;
                active.push(j);
                added = true;
            }
        }
        if !added {
            converged = true;
            break;
        }
    }

    // report a duality gap for diagnostics (GLMNET itself never computes it)
    let theta = dual::rescale_to_feasible(x, &r, lambda);
    let gap = primal::primal_from_residual(&r, &beta, lambda)
        - dual::dual_objective(y, &theta, lambda);
    let _ = n;
    SolveResult { beta, r, theta, gap, epochs, converged, trace: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn reaches_stationarity_with_tight_tol() {
        let ds = synth::leukemia_mini(40);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let lambda = lmax / 10.0;
        let out = glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-14, ..Default::default() });
        assert!(out.converged);
        // with a tight primal tolerance the solution matches gap-based CD
        let cd = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-12, ..Default::default() },
        );
        let pg = primal::primal(&ds.x, &ds.y, &out.beta, lambda);
        let pc = primal::primal(&ds.x, &ds.y, &cd.beta, lambda);
        assert!((pg - pc).abs() < 1e-7, "glmnet {pg} vs cd {pc}");
    }

    #[test]
    fn loose_tol_inflates_support() {
        // The Fig. 5 phenomenon: under a loose primal-decrease criterion the
        // support carries extra features vs. the tight solution.
        let ds = synth::leukemia_mini(41);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let lambda = lmax / 20.0;
        let loose =
            glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-4, ..Default::default() });
        let tight =
            glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-14, ..Default::default() });
        assert!(
            loose.support_size() >= tight.support_size(),
            "loose {} vs tight {}",
            loose.support_size(),
            tight.support_size()
        );
    }

    #[test]
    fn kkt_satisfied_on_active_set() {
        let ds = synth::leukemia_mini(42);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let lambda = lmax / 5.0;
        let out = glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-12, ..Default::default() });
        // no feature may violate KKT grossly at convergence
        let viol = crate::lasso::kkt::max_violation(&ds.x, &out.r, &out.beta, lambda);
        assert!(viol < 1e-3, "violation {viol}");
    }

    #[test]
    fn warm_start_path_step() {
        let ds = synth::leukemia_mini(43);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let l1 = lmax / 2.0;
        let l2 = lmax / 4.0;
        let first = glmnet_solve(&ds.x, &ds.y, l1, lmax, None, &GlmnetConfig::default());
        let second = glmnet_solve(&ds.x, &ds.y, l2, l1, Some(&first.beta), &GlmnetConfig::default());
        assert!(second.converged);
        assert!(second.support_size() >= first.support_size());
    }
}
