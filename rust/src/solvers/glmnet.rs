//! GLMNET-style Lasso solver (Friedman, Hastie & Tibshirani, 2010):
//! sequential strong rules + ever-active set + KKT verification, with the
//! package's *primal-decrease* stopping criterion.
//!
//! This baseline exists to reproduce Figure 5: because the stopping rule
//! does not control the duality gap, the identified supports contain many
//! features outside the equicorrelation set ("false positives") at loose
//! tolerances — unlike gap-controlled solvers.
//!
//! The inner CD-until-primal-stagnation loop is the shared
//! [`crate::solvers::engine`] under [`StopRule::PrimalDecrease`]; this
//! file owns only the strong-rule / KKT outer passes.

use crate::data::design::{DesignMatrix, DesignOps};
use crate::lasso::{dual, primal};
use crate::solvers::engine::{self, CdStrategy, EngineConfig, Init, StopRule, Workspace};
use crate::solvers::SolveResult;
use crate::util::error::{FaultEvent, SolveOutcome};

/// GLMNET-style configuration.
#[derive(Debug, Clone)]
pub struct GlmnetConfig {
    /// Primal-decrease stopping threshold ε (NOT a duality gap!).
    pub tol: f64,
    /// Strong-rule / KKT passes cap.
    pub max_outer: usize,
    /// Inner CD epoch cap per pass.
    pub max_inner_epochs: usize,
    /// KKT violation tolerance when verifying candidates.
    pub kkt_tol: f64,
}

impl Default for GlmnetConfig {
    fn default() -> Self {
        GlmnetConfig { tol: 1e-6, max_outer: 50, max_inner_epochs: 10_000, kkt_tol: 1e-12 }
    }
}

/// Solve one point of a λ-path GLMNET-style.
///
/// `lambda_prev` is the previous (larger) λ on the path — the sequential
/// strong rule keeps features with `|x_jᵀr⁰| ≥ 2λ − λ_prev`. For a cold
/// start pass `lambda_prev = λ_max`.
pub fn glmnet_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    lambda_prev: f64,
    beta0: Option<&[f64]>,
    cfg: &GlmnetConfig,
) -> SolveResult {
    let mut ws = Workspace::new();
    glmnet_solve_ws(x, y, lambda, lambda_prev, beta0, cfg, &mut ws)
}

/// [`glmnet_solve`] on a caller-provided reusable [`Workspace`].
pub fn glmnet_solve_ws(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    lambda_prev: f64,
    beta0: Option<&[f64]>,
    cfg: &GlmnetConfig,
    ws: &mut Workspace,
) -> SolveResult {
    // Dispatch once so the inner loops monomorphize per storage kind.
    match x {
        DesignMatrix::Dense(d) => glmnet_generic(d, y, lambda, lambda_prev, beta0, cfg, ws),
        DesignMatrix::Sparse(s) => glmnet_generic(s, y, lambda, lambda_prev, beta0, cfg, ws),
        DesignMatrix::Ooc(o) => glmnet_generic(o, y, lambda, lambda_prev, beta0, cfg, ws),
        DesignMatrix::Sharded(sh) => glmnet_generic(sh, y, lambda, lambda_prev, beta0, cfg, ws),
    }
}

fn glmnet_generic<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    lambda_prev: f64,
    beta0: Option<&[f64]>,
    cfg: &GlmnetConfig,
    ws: &mut Workspace,
) -> SolveResult {
    let n = x.n();
    let p = x.p();

    // ---- iterate + cached norms live in the workspace ----
    ws.init_primal(x, y, beta0);

    // ---- sequential strong rule on the warm-start residual ----
    ws.scratch.prepare(n, p);
    x.xt_vec(&ws.r, &mut ws.scratch.xtr);
    let strong_thresh = 2.0 * lambda - lambda_prev;
    let mut in_strong: Vec<bool> = {
        let norms = &ws.norms_sq;
        let xtr = &ws.scratch.xtr;
        (0..p).map(|j| norms[j] > 0.0 && xtr[j].abs() >= strong_thresh).collect()
    };
    // ever-active set starts from the warm-start support
    let mut in_active: Vec<bool> = ws.beta.iter().map(|&b| b != 0.0).collect();
    for j in 0..p {
        if in_active[j] {
            in_strong[j] = true;
        }
    }
    let mut active: Vec<usize> = (0..p).filter(|&j| in_active[j]).collect();
    if active.is_empty() {
        // seed with the strong set (GLMNET's first pass solves on it)
        active = (0..p).filter(|&j| in_strong[j]).collect();
        for &j in &active {
            in_active[j] = true;
        }
    }

    let inner_cfg = EngineConfig {
        tol: cfg.tol,
        max_epochs: cfg.max_inner_epochs,
        gap_freq: 1,
        k: 1,
        extrapolate: false,
        best_dual: false,
        screen: false,
        trace: false,
        stop: StopRule::PrimalDecrease,
        ..EngineConfig::default()
    };

    let mut epochs = 0usize;
    let mut converged = false;
    let mut all_faults: Vec<FaultEvent> = Vec::new();
    for _pass in 0..cfg.max_outer {
        // ---- CD on the active set until primal decrease < tol ----
        let outcome =
            engine::solve(x, y, lambda, Init::Resume, Some(&active), &inner_cfg, ws, &mut CdStrategy);
        epochs += outcome.epochs;
        all_faults.extend_from_slice(outcome.status.faults());

        // ---- KKT on the strong set ----
        // Fused scan: Xᵀr plus its infinity norm in one sharded pass.
        // When even the max correlation clears nobody's threshold, both
        // candidate scans below are skipped entirely.
        let amax = x.xt_vec_abs_max(&ws.r, &mut ws.scratch.xtr);
        let mut added = false;
        if amax > lambda + cfg.kkt_tol {
            let xtr = &ws.scratch.xtr;
            for j in 0..p {
                if in_strong[j] && !in_active[j] && xtr[j].abs() > lambda + cfg.kkt_tol {
                    in_active[j] = true;
                    active.push(j);
                    added = true;
                }
            }
            if !added {
                // ---- KKT on all features (strong-rule violations are rare) ----
                let norms = &ws.norms_sq;
                for j in 0..p {
                    if !in_active[j] && norms[j] > 0.0 && xtr[j].abs() > lambda + cfg.kkt_tol {
                        in_active[j] = true;
                        in_strong[j] = true;
                        active.push(j);
                        added = true;
                    }
                }
            }
        }
        if !added {
            converged = true;
            break;
        }
    }

    // report a duality gap for diagnostics (GLMNET itself never computes
    // it) — allocation-free on the workspace's θ / Xᵀr buffers.
    let _ = dual::rescale_to_feasible_into(x, &ws.r, lambda, &mut ws.scratch.xtr, &mut ws.theta);
    let gap = primal::primal_from_residual(&ws.r, &ws.beta, lambda)
        - dual::dual_objective(y, &ws.theta, lambda);
    let status = SolveOutcome::from_run(converged, gap, epochs, all_faults);
    SolveResult {
        beta: ws.beta.clone(),
        r: ws.r.clone(),
        theta: ws.theta.clone(),
        gap,
        epochs,
        converged,
        trace: Vec::new(),
        status,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn reaches_stationarity_with_tight_tol() {
        let ds = synth::leukemia_mini(40);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let lambda = lmax / 10.0;
        let out = glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-14, ..Default::default() });
        assert!(out.converged);
        // with a tight primal tolerance the solution matches gap-based CD
        let cd = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-12, ..Default::default() },
        );
        let pg = primal::primal(&ds.x, &ds.y, &out.beta, lambda);
        let pc = primal::primal(&ds.x, &ds.y, &cd.beta, lambda);
        assert!((pg - pc).abs() < 1e-7, "glmnet {pg} vs cd {pc}");
    }

    #[test]
    fn loose_tol_inflates_support() {
        // The Fig. 5 phenomenon: under a loose primal-decrease criterion the
        // support carries extra features vs. the tight solution.
        let ds = synth::leukemia_mini(41);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let lambda = lmax / 20.0;
        let loose =
            glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-4, ..Default::default() });
        let tight =
            glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-14, ..Default::default() });
        assert!(
            loose.support_size() >= tight.support_size(),
            "loose {} vs tight {}",
            loose.support_size(),
            tight.support_size()
        );
    }

    #[test]
    fn kkt_satisfied_on_active_set() {
        let ds = synth::leukemia_mini(42);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let lambda = lmax / 5.0;
        let out = glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-12, ..Default::default() });
        // no feature may violate KKT grossly at convergence
        let viol = crate::lasso::kkt::max_violation(&ds.x, &out.r, &out.beta, lambda);
        assert!(viol < 1e-3, "violation {viol}");
    }

    #[test]
    fn warm_start_path_step() {
        let ds = synth::leukemia_mini(43);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let l1 = lmax / 2.0;
        let l2 = lmax / 4.0;
        let first = glmnet_solve(&ds.x, &ds.y, l1, lmax, None, &GlmnetConfig::default());
        let second = glmnet_solve(&ds.x, &ds.y, l2, l1, Some(&first.beta), &GlmnetConfig::default());
        assert!(second.converged);
        assert!(second.support_size() >= first.support_size());
    }

    #[test]
    fn workspace_variant_matches_one_shot() {
        let ds = synth::leukemia_mini(44);
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let lambda = lmax / 8.0;
        let cfg = GlmnetConfig::default();
        let one_shot = glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &cfg);
        let mut ws = Workspace::new();
        let _ = glmnet_solve_ws(&ds.x, &ds.y, lmax / 2.0, lmax, None, &cfg, &mut ws);
        let reused = glmnet_solve_ws(&ds.x, &ds.y, lambda, lmax, None, &cfg, &mut ws);
        assert_eq!(one_shot.beta, reused.beta);
        assert_eq!(one_shot.epochs, reused.epochs);
    }
}
