//! f32 sweep / f64 certify: the mixed-precision CD strategy behind
//! [`Precision::F32`](crate::solvers::Precision).
//!
//! # State machine
//!
//! ```text
//!        ┌──────────────── f32 SWEEP ────────────────┐
//!        │ CD epochs on (β₃₂, r₃₂) over the f32      │
//!        │ design shadow — half the memory traffic   │
//!        └──────────┬──────────────────┬─────────────┘
//!     gap check due │                  │ f32 fixed point reached
//!                   ▼                  │ (zero-update epoch) or
//!        ┌─── f64 CERTIFY ───┐         │ f32 epoch budget spent
//!        │ β ← cast(β₃₂)     │         ▼
//!        │ r ← y − Xβ (f64)  │   ┌── f64 ESCALATE ──┐
//!        │ gap, screening,   │   │ certify once,    │
//!        │ stop: exact f64   │   │ then plain f64   │
//!        └──────────┬────────┘   │ CD epochs forever│
//!   check survived, │            └──────────────────┘
//!   maybe screened  ▼
//!        (β₃₂, r₃₂) ← cast(β, r)   [resync: picks up screening]
//! ```
//!
//! Certification is what keeps the safety guarantees intact: the f32
//! iterate is *never* consulted by a certificate. At every gap check the
//! engine calls [`Strategy::sync_check_state`], which promotes β₃₂ into
//! the f64 workspace and recomputes `r = y − Xβ` exactly in f64; the
//! dual point (Eq. 4), the duality gap, and the Gap Safe screening test
//! all run on those exact values, so a reported gap ≤ ε means exactly
//! what it means in pure-f64 mode, and screening never discards a
//! feature based on rounded arithmetic.
//!
//! Escalation is what guarantees termination at tolerances below f32
//! resolution: an f32 CD sweep that makes **zero** coefficient updates
//! has reached an exact f32 fixed point and can never progress again, so
//! the strategy permanently switches to f64 epochs from the certified
//! iterate (the f32 phase then amounts to a very cheap warm start). A
//! hard budget of [`MAX_F32_EPOCHS`] f32 epochs backstops the switch
//! against rounding-induced limit cycles that never reach an exact
//! fixed point, so a `Precision::F32` solve converges whenever the
//! corresponding f64 solve does.

use crate::data::design::DesignOps;
use crate::data::shadow::ShadowF32;
use crate::datafit::Quadratic;
use crate::lasso::primal;
use crate::solvers::engine::Strategy;
use crate::util::{soft_threshold, soft_threshold_f32};

/// Hard cap on f32 epochs before escalating to f64 sweeps. Stall
/// detection (a zero-update epoch) almost always fires first; the cap
/// only backstops pathological f32 limit cycles.
pub const MAX_F32_EPOCHS: usize = 1_000;

/// Cyclic CD in f32 with f64 certification at every gap check.
pub struct F32CdStrategy {
    shadow: ShadowF32,
    beta32: Vec<f32>,
    r32: Vec<f32>,
    norms32: Vec<f32>,
    /// f32 state mirrors the engine's (β, r). Cleared after every
    /// certification so the next epoch re-syncs (screening may have
    /// zeroed coefficients and patched the residual in between).
    synced: bool,
    /// Permanently switched to f64 epochs.
    f64_mode: bool,
    f32_epochs: usize,
}

impl F32CdStrategy {
    /// Build the strategy (and the f32 design shadow) for one solve.
    pub fn new<D: DesignOps>(x: &D) -> Self {
        F32CdStrategy {
            shadow: x.shadow_f32(),
            beta32: Vec::new(),
            r32: Vec::new(),
            norms32: Vec::new(),
            synced: false,
            f64_mode: false,
            f32_epochs: 0,
        }
    }

    /// True once the strategy has escalated to f64 sweeps.
    pub fn escalated(&self) -> bool {
        self.f64_mode
    }

    fn promote(&self, beta: &mut [f64]) {
        for (b, &b32) in beta.iter_mut().zip(self.beta32.iter()) {
            *b = b32 as f64;
        }
    }

    fn escalate<D: DesignOps>(&mut self, x: &D, y: &[f64], beta: &mut [f64], r: &mut [f64]) {
        self.promote(beta);
        primal::residual(x, y, beta, r);
        self.f64_mode = true;
    }
}

impl<D: DesignOps> Strategy<D> for F32CdStrategy {
    fn epoch(
        &mut self,
        x: &D,
        y: &[f64],
        lambda: f64,
        beta: &mut [f64],
        r: &mut [f64],
        _xw: &mut [f64],
        active: &[usize],
        norms_sq: &[f64],
        _datafit: &Quadratic,
        _penalty: &crate::penalty::L1,
    ) {
        if self.f64_mode {
            // Post-escalation: the plain f64 CD epoch (identical to
            // `CdStrategy`), continuing from the certified iterate.
            for &j in active {
                let nrm = norms_sq[j];
                let g = x.col_dot(j, r);
                let old = beta[j];
                let new = soft_threshold(old + g / nrm, lambda / nrm);
                if new != old {
                    x.col_axpy(j, old - new, r);
                    beta[j] = new;
                }
            }
            return;
        }
        if !self.synced {
            self.beta32.clear();
            self.beta32.extend(beta.iter().map(|&b| b as f32));
            self.r32.clear();
            self.r32.extend(r.iter().map(|&v| v as f32));
            if self.norms32.len() != norms_sq.len() {
                self.norms32 = norms_sq.iter().map(|&v| v as f32).collect();
            }
            self.synced = true;
        }
        let lam = lambda as f32;
        let mut any_update = false;
        for &j in active {
            let nrm = self.norms32[j];
            if nrm <= 0.0 {
                // ‖x_j‖² underflowed to 0 in f32; leave the coordinate
                // to the (eventual) f64 phase rather than divide by 0.
                continue;
            }
            let g = self.shadow.col_dot(j, &self.r32);
            let old = self.beta32[j];
            let new = soft_threshold_f32(old + g / nrm, lam / nrm);
            if new != old {
                self.shadow.col_axpy(j, old - new, &mut self.r32);
                self.beta32[j] = new;
                any_update = true;
            }
        }
        self.f32_epochs += 1;
        if !any_update || self.f32_epochs >= MAX_F32_EPOCHS {
            self.escalate(x, y, beta, r);
        }
    }

    fn sync_check_state(&mut self, x: &D, y: &[f64], beta: &mut [f64], r: &mut [f64]) {
        if self.f64_mode || !self.synced {
            // f64 state is already authoritative.
            return;
        }
        self.promote(beta);
        primal::residual(x, y, beta, r);
        // Screening may mutate (β, r) right after the check; re-sync the
        // f32 mirror at the next epoch.
        self.synced = false;
    }

    fn on_fault(&mut self) -> crate::util::error::RecoveryAction {
        // The engine rolled (β, r) back to the last certified
        // checkpoint. The f32 mirror may carry the corruption that
        // triggered the fault, so do NOT promote it — escalate to f64
        // epochs from the restored f64 state instead (the strongest
        // recovery the precision ladder offers).
        self.f64_mode = true;
        self.synced = false;
        crate::util::error::RecoveryAction::EscalatedF64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::solvers::engine::{self, Init, Workspace};

    #[test]
    fn f32_strategy_converges_and_certifies() {
        let ds = synth::leukemia_mini(21);
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let cfg = crate::solvers::cd::CdConfig { tol: 1e-8, ..Default::default() }.engine();
        let mut ws = Workspace::new();
        let mut strat = F32CdStrategy::new(&ds.x);
        let out =
            engine::solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut ws, &mut strat);
        assert!(out.converged, "f32 sweep mode terminates below f32 resolution");
        assert!(out.gap <= 1e-8);
        // the certified invariant: the workspace residual is the exact
        // f64 residual of the returned β
        let mut r_exact = vec![0.0; ds.x.n()];
        primal::residual(&ds.x, &ds.y, &ws.beta, &mut r_exact);
        assert_eq!(ws.r, r_exact, "returned r is the exact f64 residual");
        // a tolerance this far below f32 resolution forces escalation
        assert!(strat.escalated());
    }

    #[test]
    fn zero_update_epoch_escalates() {
        // λ ≥ λ_max: β = 0 is optimal, the very first f32 epoch makes no
        // update, and the strategy must escalate rather than spin.
        let ds = synth::leukemia_mini(22);
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) * 1.01;
        let cfg = crate::solvers::cd::CdConfig::default().engine();
        let mut ws = Workspace::new();
        let mut strat = F32CdStrategy::new(&ds.x);
        let out =
            engine::solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut ws, &mut strat);
        assert!(out.converged);
        assert!(strat.escalated());
        assert!(ws.beta.iter().all(|&b| b == 0.0));
    }
}
