//! Lasso solvers: the paper's CELER plus every baseline it compares to.
//!
//! All gap-controlled solvers run through one [`engine`]: a shared
//! iterate/check loop over reusable [`engine::Workspace`] buffers. The
//! per-solver files contribute only their strategy (CD epoch, proximal
//! step, working-set outer loop) — see `ARCHITECTURE.md`.

pub mod batch;
pub mod blitz;
pub mod block;
pub mod cd;
pub mod celer;
pub mod dykstra;
pub mod engine;
pub mod glm;
pub mod glmnet;
pub mod ista;
pub mod path;
pub mod sweep32;

use crate::data::design::DesignOps;
use crate::extrapolation::ResidualBuffer;
use crate::lasso::primal;

/// Arithmetic precision of the CD **iteration** (epochs). Certificates
/// are unaffected: whatever the sweep precision, residual, duality gap,
/// and Gap Safe screening are recomputed in f64 before any screen/stop
/// decision, so every gap bound the engine emits is an exact f64
/// certificate (see `solvers/sweep32.rs` for the state machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Pure f64 (bit-identical to the historical solver path).
    #[default]
    F64,
    /// f32 sweeps on an f32 design shadow, f64 certification at every
    /// gap check; escalates to f64 sweeps at the f32 fixed point.
    F32,
}

/// One duality-gap evaluation record (every `f` epochs).
#[derive(Debug, Clone)]
pub struct GapCheck {
    /// Epoch at which the check ran (1-based).
    pub epoch: usize,
    /// Primal objective P(β).
    pub primal: f64,
    /// Dual objective of the residual-rescaled point θ_res.
    pub dual_res: f64,
    /// Dual objective of the extrapolated point θ_accel (when available).
    pub dual_accel: Option<f64>,
    /// Gap of the point actually used by the solver this round.
    pub gap: f64,
    /// Total features screened so far (0 when screening is off).
    pub n_screened: usize,
    /// Wall-clock seconds since the solver started.
    pub seconds: f64,
}

/// Result of an inner/standalone solver run.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub beta: Vec<f64>,
    /// Generalized residual `−∇F(Xβ)` (= `y − Xβ` for the quadratic
    /// datafit).
    pub r: Vec<f64>,
    /// Best feasible dual point found.
    pub theta: Vec<f64>,
    /// Final duality gap (w.r.t. this solver's problem).
    pub gap: f64,
    /// Epochs (outer iterations for WS solvers) consumed.
    pub epochs: usize,
    pub converged: bool,
    /// Per-gap-check trace (empty unless tracing was enabled).
    pub trace: Vec<GapCheck>,
    /// How the run ended (`Certified` / `BudgetExhausted` / `Recovered`
    /// — see [`crate::util::error::SolveOutcome`]). `Recovered` results
    /// with `converged = true` are still gap-certified.
    pub status: crate::util::error::SolveOutcome,
}

impl SolveResult {
    pub fn support_size(&self) -> usize {
        primal::support_size(&self.beta)
    }

    pub fn support(&self) -> Vec<usize> {
        primal::support(&self.beta)
    }
}

/// Which dual point the solver ended up using at a gap check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualChoice {
    Previous,
    Residual,
    Extrapolated,
}

/// Reusable scratch for [`DualState::update`]: correlation and dual-point
/// buffers that would otherwise be allocated at every gap check. Owned by
/// the engine [`engine::Workspace`] so one set of buffers serves an
/// entire warm-started λ path.
#[derive(Debug, Clone, Default)]
pub struct DualScratch {
    /// `Xᵀr` for the current residual (length p).
    pub xtr: Vec<f64>,
    /// `Xᵀr_accel` for the extrapolated residual (length p).
    pub xtr_acc: Vec<f64>,
    /// Rescaled extrapolated dual point θ_accel (length n).
    pub theta_acc: Vec<f64>,
    /// Extrapolation temporaries (K diff vectors, Gram matrix, r_accel)
    /// that `ResidualBuffer::extrapolate` used to allocate per call.
    pub extrap: crate::extrapolation::ExtrapScratch,
}

impl DualScratch {
    /// Size the buffers for an (n, p) problem, reusing capacity.
    pub fn prepare(&mut self, n: usize, p: usize) {
        self.xtr.resize(p, 0.0);
        self.xtr_acc.resize(p, 0.0);
        self.theta_acc.resize(n, 0.0);
    }
}

/// Shared dual-point machinery (Eq. 4, Def. 1, Eq. 13): maintains the
/// residual ring buffer, computes θ_res and θ_accel, and optionally keeps
/// the best-so-far dual point for monotonicity.
#[derive(Debug, Clone)]
pub struct DualState {
    pub buffer: ResidualBuffer,
    /// Best dual point so far (feasible).
    pub theta: Vec<f64>,
    /// Correlations Xᵀθ for the best point (needed by screening / WS).
    pub xtheta: Vec<f64>,
    /// D(θ) for the best point.
    pub dval: f64,
    /// Cached `‖y‖²` for the current solve (`NaN` until the first
    /// [`DualState::update`] after a reset). `y` never changes within a
    /// solve, so every dual evaluation of the solve reuses this instead
    /// of re-running an O(n) pass per gap check. For a non-quadratic
    /// datafit ([`DualState::update_datafit`]) it holds that datafit's
    /// [`conj_cache`](crate::datafit::Datafit::conj_cache) instead.
    pub y_norm_sq: f64,
    /// Use θ_accel at all.
    pub extrapolate: bool,
    /// Keep the best-of {previous, res, accel} (Eq. 13). When false the
    /// freshly computed best of {res, accel} is used (Fig. 2 setting).
    pub monotone: bool,
    /// Last choice made.
    pub last_choice: DualChoice,
}

impl Default for DualState {
    fn default() -> Self {
        DualState {
            buffer: ResidualBuffer::new(1),
            theta: Vec::new(),
            xtheta: Vec::new(),
            dval: f64::NEG_INFINITY,
            y_norm_sq: f64::NAN,
            extrapolate: false,
            monotone: true,
            last_choice: DualChoice::Residual,
        }
    }
}

impl DualState {
    pub fn new(n: usize, p: usize, k: usize, extrapolate: bool, monotone: bool) -> Self {
        let mut s = DualState::default();
        s.reset(n, p, k, extrapolate, monotone);
        s
    }

    /// Re-initialize for a fresh solve, reusing the buffers' capacity.
    pub fn reset(&mut self, n: usize, p: usize, k: usize, extrapolate: bool, monotone: bool) {
        self.buffer.reset(k);
        self.theta.clear();
        self.theta.resize(n, 0.0);
        self.xtheta.clear();
        self.xtheta.resize(p, 0.0);
        self.dval = f64::NEG_INFINITY;
        self.y_norm_sq = f64::NAN;
        self.extrapolate = extrapolate;
        self.monotone = monotone;
        self.last_choice = DualChoice::Residual;
    }

    /// Ingest the current residual, refresh θ, and return
    /// (D(θ_res), D(θ_accel) if computed).
    ///
    /// All O(n)/O(p) temporaries live in `scratch`, so a check performs no
    /// heap allocation once the buffers are warm. Shorthand for
    /// [`DualState::update_datafit`] with the quadratic (Lasso) datafit.
    pub fn update<D: DesignOps>(
        &mut self,
        x: &D,
        y: &[f64],
        lambda: f64,
        r: &[f64],
        scratch: &mut DualScratch,
    ) -> (f64, Option<f64>) {
        self.update_datafit(x, y, lambda, r, scratch, &crate::datafit::Quadratic)
    }

    /// Datafit-generic [`DualState::update`]: `r` is the **generalized
    /// residual** `−∇F(Xβ)` of the datafit (the plain residual for the
    /// quadratic fit), which the Eq. 4 rescale, the extrapolation ring
    /// and the best-dual bookkeeping consume identically across GLMs —
    /// the GLM follow-up paper's central observation. `y_norm_sq` holds
    /// the datafit's conjugate cache (`‖y‖²` for quadratic). The
    /// quadratic instantiation is bit-identical to the historical
    /// hardcoded update (pinned in `tests/prop_glm.rs`).
    pub fn update_datafit<D: DesignOps, F: crate::datafit::Datafit>(
        &mut self,
        x: &D,
        y: &[f64],
        lambda: f64,
        r: &[f64],
        scratch: &mut DualScratch,
        datafit: &F,
    ) -> (f64, Option<f64>) {
        self.buffer.push(r);
        let n = y.len();
        let p = x.p();
        scratch.xtr.resize(p, 0.0);
        if self.y_norm_sq.is_nan() {
            self.y_norm_sq = datafit.conj_cache(y);
        }

        // θ_res = r / max(λ, ‖Xᵀr‖_∞); the fused kernel yields Xᵀr and
        // its norm in one sharded pass (no second serial p-scan).
        let denom = datafit.rescale_denom(lambda, x.xt_vec_abs_max(r, &mut scratch.xtr));
        let inv = 1.0 / denom;
        // D(θ_res) without materializing θ_res: θ = r·inv
        let d_res = datafit.dual_scaled(y, r, inv, lambda, self.y_norm_sq);

        let mut best_val = d_res;
        let mut best = DualChoice::Residual;

        // θ_accel (written into scratch, copied into self only if it
        // wins). The extrapolated residual itself lands in
        // `scratch.extrap.r_accel` — no per-check allocation. For a
        // non-quadratic datafit the extrapolated point can leave the
        // conjugate domain; `Datafit::dual` then returns −∞ and the
        // candidate simply loses the comparison below.
        let mut d_accel_out = None;
        if self.extrapolate && self.buffer.extrapolate_into(&mut scratch.extrap) {
            let r_acc = &scratch.extrap.r_accel;
            scratch.xtr_acc.resize(p, 0.0);
            scratch.theta_acc.resize(n, 0.0);
            let denom_a =
                datafit.rescale_denom(lambda, x.xt_vec_abs_max(r_acc, &mut scratch.xtr_acc));
            let inv_a = 1.0 / denom_a;
            for (t, &v) in scratch.theta_acc.iter_mut().zip(r_acc.iter()) {
                *t = v * inv_a;
            }
            for v in scratch.xtr_acc.iter_mut() {
                *v *= inv_a;
            }
            let d_acc = datafit.dual(y, &scratch.theta_acc, lambda, self.y_norm_sq);
            d_accel_out = Some(d_acc);
            if d_acc > best_val {
                best_val = d_acc;
                best = DualChoice::Extrapolated;
            }
        }

        if self.monotone && self.dval >= best_val {
            // keep previous θ
            self.last_choice = DualChoice::Previous;
            return (d_res, d_accel_out);
        }

        match best {
            DualChoice::Extrapolated => {
                self.theta.clear();
                self.theta.extend_from_slice(&scratch.theta_acc);
                self.xtheta.clear();
                self.xtheta.extend_from_slice(&scratch.xtr_acc);
                self.dval = best_val;
            }
            _ => {
                self.theta.clear();
                self.theta.extend(r.iter().map(|&v| v * inv));
                self.xtheta.clear();
                self.xtheta.extend(scratch.xtr.iter().map(|&v| v * inv));
                self.dval = d_res;
            }
        }
        self.last_choice = best;
        (d_res, d_accel_out)
    }

    /// Penalty-generic [`DualState::update`] (quadratic datafit): the
    /// Eq. 4 rescale denominator becomes `max(λ, Ω^D(Xᵀr))` with the
    /// penalty's dual norm, and penalties with a finite conjugate
    /// (elastic net) subtract `λ·Σω*(x_jᵀθ)` from every dual candidate.
    /// The `P = L1` instantiation delegates wholesale to
    /// [`DualState::update_datafit`], so the ℓ₁ path is the historical
    /// code, bit for bit (pinned in `tests/prop_penalty.rs`).
    pub fn update_penalty<D: DesignOps, P: crate::penalty::Penalty>(
        &mut self,
        x: &D,
        y: &[f64],
        lambda: f64,
        r: &[f64],
        scratch: &mut DualScratch,
        penalty: &P,
    ) -> (f64, Option<f64>) {
        if P::IS_L1 {
            return self.update_datafit(x, y, lambda, r, scratch, &crate::datafit::Quadratic);
        }
        let datafit = &crate::datafit::Quadratic;
        self.buffer.push(r);
        let n = y.len();
        let p = x.p();
        scratch.xtr.resize(p, 0.0);
        if self.y_norm_sq.is_nan() {
            self.y_norm_sq = datafit.conj_cache(y);
        }

        // θ_res = r / max(λ, Ω^D(Xᵀr)). The generic dual norm needs the
        // full correlation vector, so the fused abs-max kernel is
        // bypassed here (penalties other than ℓ₁ only).
        x.xt_vec(r, &mut scratch.xtr);
        let denom = datafit.rescale_denom(lambda, penalty.dual_norm(lambda, &scratch.xtr));
        let inv = 1.0 / denom;
        let mut d_res = datafit.dual_scaled(y, r, inv, lambda, self.y_norm_sq);
        if !P::INDICATOR_DUAL {
            // Xᵀθ = (Xᵀr)·inv without materializing θ.
            d_res -= penalty.conjugate(lambda, &scratch.xtr, inv);
        }

        let mut best_val = d_res;
        let mut best = DualChoice::Residual;

        let mut d_accel_out = None;
        if self.extrapolate && self.buffer.extrapolate_into(&mut scratch.extrap) {
            let r_acc = &scratch.extrap.r_accel;
            scratch.xtr_acc.resize(p, 0.0);
            scratch.theta_acc.resize(n, 0.0);
            x.xt_vec(r_acc, &mut scratch.xtr_acc);
            let denom_a =
                datafit.rescale_denom(lambda, penalty.dual_norm(lambda, &scratch.xtr_acc));
            let inv_a = 1.0 / denom_a;
            for (t, &v) in scratch.theta_acc.iter_mut().zip(r_acc.iter()) {
                *t = v * inv_a;
            }
            for v in scratch.xtr_acc.iter_mut() {
                *v *= inv_a;
            }
            let mut d_acc = datafit.dual(y, &scratch.theta_acc, lambda, self.y_norm_sq);
            if !P::INDICATOR_DUAL {
                // xtr_acc already holds Xᵀθ_accel (scaled in place above).
                d_acc -= penalty.conjugate(lambda, &scratch.xtr_acc, 1.0);
            }
            d_accel_out = Some(d_acc);
            if d_acc > best_val {
                best_val = d_acc;
                best = DualChoice::Extrapolated;
            }
        }

        if self.monotone && self.dval >= best_val {
            self.last_choice = DualChoice::Previous;
            return (d_res, d_accel_out);
        }

        match best {
            DualChoice::Extrapolated => {
                self.theta.clear();
                self.theta.extend_from_slice(&scratch.theta_acc);
                self.xtheta.clear();
                self.xtheta.extend_from_slice(&scratch.xtr_acc);
                self.dval = best_val;
            }
            _ => {
                self.theta.clear();
                self.theta.extend(r.iter().map(|&v| v * inv));
                self.xtheta.clear();
                self.xtheta.extend(scratch.xtr.iter().map(|&v| v * inv));
                self.dval = d_res;
            }
        }
        self.last_choice = best;
        (d_res, d_accel_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::data::design::DesignMatrix;

    #[test]
    fn dual_state_monotone() {
        let x = DesignMatrix::Dense(DenseMatrix::from_row_major(
            2,
            2,
            &[1.0, 0.0, 0.0, 1.0],
        ));
        let y = vec![3.0, 0.5];
        let lambda = 1.0;
        let mut ds = DualState::new(2, 2, 3, false, true);
        let mut scratch = DualScratch::default();
        // good residual first (close to optimal residual [1, 0.5])
        let (d1, _) = ds.update(&x, &y, lambda, &[1.0, 0.5], &mut scratch);
        assert!(ds.dval >= d1 - 1e-15);
        let v1 = ds.dval;
        // much worse residual: monotone state must keep the old point
        ds.update(&x, &y, lambda, &[-3.0, 2.0], &mut scratch);
        assert!(ds.dval >= v1 - 1e-15);
        assert_eq!(ds.last_choice, DualChoice::Previous);
    }

    #[test]
    fn dual_state_feasibility() {
        use crate::data::design::DesignOps;
        let x = DesignMatrix::Dense(DenseMatrix::from_row_major(
            3,
            2,
            &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
        ));
        let y = vec![1.0, 2.0, 3.0];
        let mut ds = DualState::new(3, 2, 2, true, true);
        let mut scratch = DualScratch::default();
        for r in [[1.0, 0.0, 2.0], [0.9, 0.1, 1.9], [0.8, 0.2, 1.8], [0.75, 0.25, 1.75]] {
            ds.update(&x, &y, 0.5, &r, &mut scratch);
            assert!(x.xt_abs_max(&ds.theta) <= 1.0 + 1e-10, "theta stays feasible");
            // xtheta cache must match X^T theta
            let mut expect = vec![0.0; 2];
            x.xt_vec(&ds.theta, &mut expect);
            for j in 0..2 {
                assert!((ds.xtheta[j] - expect[j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn reset_reuses_state_cleanly() {
        let x = DesignMatrix::Dense(DenseMatrix::from_row_major(
            2,
            2,
            &[1.0, 0.0, 0.0, 1.0],
        ));
        let y = vec![3.0, 0.5];
        let mut ds = DualState::new(2, 2, 3, false, true);
        let mut scratch = DualScratch::default();
        ds.update(&x, &y, 1.0, &[1.0, 0.5], &mut scratch);
        assert!(ds.dval.is_finite());
        ds.reset(2, 2, 3, false, true);
        assert_eq!(ds.dval, f64::NEG_INFINITY);
        assert!(ds.theta.iter().all(|&v| v == 0.0));
        // behaves like a fresh state after reset
        let (d1, _) = ds.update(&x, &y, 1.0, &[1.0, 0.5], &mut scratch);
        assert!(ds.dval >= d1 - 1e-15);
    }
}
