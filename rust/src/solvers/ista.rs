//! ISTA / FISTA proximal-gradient solvers (Beck & Teboulle, 2009).
//!
//! ISTA is the setting of the paper's Theorem 1: after finite support
//! identification its iterates form a noiseless VAR process, so dual
//! extrapolation provably converges to θ̂. We reuse the same
//! [`DualState`] machinery as CD.

use crate::data::design::DesignOps;
use crate::lasso::primal;
use crate::solvers::{DualState, GapCheck, SolveResult};
use crate::util::soft_threshold;
use std::time::Instant;

/// Configuration for [`ista_solve`].
#[derive(Debug, Clone)]
pub struct IstaConfig {
    pub tol: f64,
    pub max_epochs: usize,
    /// Gap evaluation frequency in epochs.
    pub gap_freq: usize,
    /// Extrapolation depth K.
    pub k: usize,
    pub extrapolate: bool,
    pub best_dual: bool,
    /// FISTA momentum (Nesterov acceleration on the primal).
    pub fista: bool,
    pub trace: bool,
}

impl Default for IstaConfig {
    fn default() -> Self {
        IstaConfig {
            tol: 1e-6,
            max_epochs: 100_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
            best_dual: true,
            fista: false,
            trace: false,
        }
    }
}

/// Largest eigenvalue of `XᵀX` (squared spectral norm of X) by power
/// iteration — the ISTA step size is `1/μ`.
pub fn spectral_norm_sq<D: DesignOps>(x: &D, iters: usize, seed: u64) -> f64 {
    let (n, p) = (x.n(), x.p());
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let mut xv = vec![0.0; n];
    let mut w = vec![0.0; p];
    let mut lam = 0.0;
    for _ in 0..iters {
        let nv = crate::util::linalg::norm(&v);
        if nv == 0.0 {
            return 0.0;
        }
        for t in v.iter_mut() {
            *t /= nv;
        }
        x.matvec(&v, &mut xv);
        x.xt_vec(&xv, &mut w);
        let new_lam = crate::util::linalg::dot(&v, &w);
        if (new_lam - lam).abs() <= 1e-12 * new_lam.abs().max(1.0) {
            lam = new_lam;
            break;
        }
        lam = new_lam;
        std::mem::swap(&mut v, &mut w);
    }
    lam.max(0.0)
}

/// Solve the Lasso with ISTA (or FISTA when `cfg.fista`).
pub fn ista_solve<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &IstaConfig,
) -> SolveResult {
    let (n, p) = (x.n(), x.p());
    let start = Instant::now();
    let mu = spectral_norm_sq(x, 200, 0xC0FFEE).max(1e-300);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    let mut z = beta.clone(); // FISTA extrapolation point
    let mut t_mom = 1.0f64;
    let mut r = vec![0.0; n];
    primal::residual(x, y, &z, &mut r);

    let mut dual = DualState::new(n, p, cfg.k, cfg.extrapolate, cfg.best_dual);
    let mut xtr = vec![0.0; p];
    let mut grad = vec![0.0; p];
    let mut trace = Vec::new();
    let mut gap = f64::INFINITY;
    let mut epochs = 0;
    let mut converged = false;

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        // gradient step at z: β⁺ = ST(z + Xᵀr/μ, λ/μ) with r = y − Xz
        x.xt_vec(&r, &mut grad);
        let beta_prev = if cfg.fista { Some(beta.clone()) } else { None };
        for j in 0..p {
            beta[j] = soft_threshold(z[j] + grad[j] / mu, lambda / mu);
        }
        if cfg.fista {
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_mom * t_mom).sqrt());
            let prev = beta_prev.unwrap();
            let coef = (t_mom - 1.0) / t_next;
            for j in 0..p {
                z[j] = beta[j] + coef * (beta[j] - prev[j]);
            }
            t_mom = t_next;
        } else {
            z.copy_from_slice(&beta);
        }
        primal::residual(x, y, &z, &mut r);

        if epoch % cfg.gap_freq == 0 || epoch == cfg.max_epochs {
            // dual state wants the residual at β (not z)
            let mut r_beta = vec![0.0; n];
            if cfg.fista {
                primal::residual(x, y, &beta, &mut r_beta);
            } else {
                r_beta.copy_from_slice(&r);
            }
            let (d_res, d_accel) = dual.update(x, y, lambda, &r_beta, &mut xtr);
            let p_val = primal::primal_from_residual(&r_beta, &beta, lambda);
            gap = p_val - dual.dval;
            if cfg.trace {
                trace.push(GapCheck {
                    epoch,
                    primal: p_val,
                    dual_res: d_res,
                    dual_accel: d_accel,
                    gap,
                    n_screened: 0,
                    seconds: start.elapsed().as_secs_f64(),
                });
            }
            if gap <= cfg.tol {
                converged = true;
                break;
            }
        }
    }
    let mut r_final = vec![0.0; n];
    primal::residual(x, y, &beta, &mut r_final);
    SolveResult { beta, r: r_final, theta: dual.theta, gap, epochs, converged, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::data::synth;
    use crate::lasso::dual as d;

    #[test]
    fn spectral_norm_matches_known() {
        // X = diag(3, 1) -> ||X||_2^2 = 9
        let x = DenseMatrix::from_row_major(2, 2, &[3.0, 0.0, 0.0, 1.0]);
        let mu = spectral_norm_sq(&x, 500, 1);
        assert!((mu - 9.0).abs() < 1e-6, "mu={mu}");
    }

    #[test]
    fn ista_matches_cd_solution() {
        let ds = synth::leukemia_mini(10);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 5.0;
        let ista = ista_solve(&ds.x, &ds.y, lambda, None, &IstaConfig { tol: 1e-10, ..Default::default() });
        let cd = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-10, ..Default::default() },
        );
        assert!(ista.converged);
        let pi = crate::lasso::primal::primal(&ds.x, &ds.y, &ista.beta, lambda);
        let pc = crate::lasso::primal::primal(&ds.x, &ds.y, &cd.beta, lambda);
        assert!((pi - pc).abs() < 1e-8, "ISTA {pi} vs CD {pc}");
    }

    #[test]
    fn fista_not_slower_than_ista() {
        let ds = synth::leukemia_mini(11);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 10.0;
        let base = IstaConfig { tol: 1e-8, ..Default::default() };
        let ista = ista_solve(&ds.x, &ds.y, lambda, None, &base);
        let fista = ista_solve(&ds.x, &ds.y, lambda, None, &IstaConfig { fista: true, ..base });
        assert!(fista.converged);
        assert!(
            fista.epochs <= ista.epochs,
            "FISTA ({}) should need no more epochs than ISTA ({})",
            fista.epochs,
            ista.epochs
        );
    }

    #[test]
    fn theorem1_extrapolation_converges_to_theta_hat() {
        // Theorem 1: with ISTA residuals, θ_accel → θ̂. Check that after
        // enough epochs the accelerated dual objective is very close to
        // the optimal dual value (gap of the extrapolated point ≈ 0).
        let ds = synth::leukemia_mini(12);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 5.0;
        let out = ista_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &IstaConfig { tol: 1e-12, trace: true, best_dual: false, ..Default::default() },
        );
        assert!(out.converged);
        let p_star = crate::lasso::primal::primal(&ds.x, &ds.y, &out.beta, lambda);
        let last = out.trace.last().unwrap();
        let d_acc = last.dual_accel.expect("extrapolation active by the end");
        // dual value of extrapolated point ~ P* (strong duality)
        assert!(
            (p_star - d_acc).abs() < 1e-7,
            "θ_accel near-optimal: P*={p_star}, D(θ_accel)={d_acc}"
        );
    }
}
