//! ISTA / FISTA proximal-gradient solvers (Beck & Teboulle, 2009).
//!
//! ISTA is the setting of the paper's Theorem 1: after finite support
//! identification its iterates form a noiseless VAR process, so dual
//! extrapolation provably converges to θ̂. The gap-check loop and dual
//! machinery are the shared [`crate::solvers::engine`]; this file only
//! supplies the proximal-gradient epoch (and FISTA's momentum bookkeeping)
//! as a [`Strategy`].

use crate::data::design::DesignOps;
use crate::lasso::primal;
use crate::solvers::engine::{self, EngineConfig, Init, StopRule, Strategy, Workspace};
use crate::solvers::SolveResult;
use crate::util::soft_threshold;

/// Configuration for [`ista_solve`].
#[derive(Debug, Clone)]
pub struct IstaConfig {
    pub tol: f64,
    pub max_epochs: usize,
    /// Gap evaluation frequency in epochs.
    pub gap_freq: usize,
    /// Extrapolation depth K.
    pub k: usize,
    pub extrapolate: bool,
    pub best_dual: bool,
    /// FISTA momentum (Nesterov acceleration on the primal).
    pub fista: bool,
    pub trace: bool,
}

impl Default for IstaConfig {
    fn default() -> Self {
        IstaConfig {
            tol: 1e-6,
            max_epochs: 100_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
            best_dual: true,
            fista: false,
            trace: false,
        }
    }
}

/// Largest eigenvalue of `XᵀX` (squared spectral norm of X) by power
/// iteration — the ISTA step size is `1/μ`.
pub fn spectral_norm_sq<D: DesignOps>(x: &D, iters: usize, seed: u64) -> f64 {
    let (n, p) = (x.n(), x.p());
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let mut xv = vec![0.0; n];
    let mut w = vec![0.0; p];
    let mut lam = 0.0;
    for _ in 0..iters {
        let nv = crate::util::linalg::norm(&v);
        if nv == 0.0 {
            return 0.0;
        }
        for t in v.iter_mut() {
            *t /= nv;
        }
        x.matvec(&v, &mut xv);
        x.xt_vec(&xv, &mut w);
        let new_lam = crate::util::linalg::dot(&v, &w);
        if (new_lam - lam).abs() <= 1e-12 * new_lam.abs().max(1.0) {
            lam = new_lam;
            break;
        }
        lam = new_lam;
        std::mem::swap(&mut v, &mut w);
    }
    lam.max(0.0)
}

/// The proximal-gradient epoch. Invariant: the engine-maintained residual
/// is `y − Xz` where `z` is the (momentum) iterate the gradient step
/// reads; for plain ISTA `z = β` so it coincides with the usual residual.
struct IstaStrategy {
    /// Lipschitz constant `‖X‖₂²`; step size is `1/μ`.
    mu: f64,
    /// Momentum point z (equals β when `fista` is off).
    z: Vec<f64>,
    /// Previous β (FISTA momentum combination).
    beta_prev: Vec<f64>,
    /// Gradient scratch `Xᵀr`.
    grad: Vec<f64>,
    /// Momentum scalar t_k.
    t_mom: f64,
    fista: bool,
    /// True until the first epoch initializes `z` from the warm start.
    fresh: bool,
}

impl<D: DesignOps> Strategy<D> for IstaStrategy {
    fn epoch(
        &mut self,
        x: &D,
        y: &[f64],
        lambda: f64,
        beta: &mut [f64],
        r: &mut [f64],
        _xw: &mut [f64],
        _active: &[usize],
        _norms_sq: &[f64],
        _datafit: &crate::datafit::Quadratic,
        _penalty: &crate::penalty::L1,
    ) {
        let p = beta.len();
        if self.fresh {
            // z⁰ = β⁰; the engine already set r = y − Xβ⁰ = y − Xz⁰.
            self.z.clear();
            self.z.extend_from_slice(beta);
            self.beta_prev.resize(p, 0.0);
            self.grad.resize(p, 0.0);
            self.fresh = false;
        }
        // gradient step at z: β⁺ = ST(z + Xᵀr/μ, λ/μ) with r = y − Xz
        x.xt_vec(r, &mut self.grad);
        if self.fista {
            self.beta_prev.copy_from_slice(beta);
        }
        for j in 0..p {
            beta[j] = soft_threshold(self.z[j] + self.grad[j] / self.mu, lambda / self.mu);
        }
        if self.fista {
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * self.t_mom * self.t_mom).sqrt());
            let coef = (self.t_mom - 1.0) / t_next;
            for j in 0..p {
                self.z[j] = beta[j] + coef * (beta[j] - self.beta_prev[j]);
            }
            self.t_mom = t_next;
        } else {
            self.z.copy_from_slice(beta);
        }
        primal::residual(x, y, &self.z, r);
    }

    fn fill_check_residual(&mut self, x: &D, y: &[f64], beta: &[f64], r: &[f64], out: &mut [f64]) {
        // dual state wants the residual at β (not the momentum point z)
        if self.fista {
            primal::residual(x, y, beta, out);
        } else {
            out.copy_from_slice(r);
        }
    }

    fn finalize(&mut self, x: &D, y: &[f64], beta: &[f64], r: &mut [f64]) {
        // leave the workspace residual at β, not at z
        primal::residual(x, y, beta, r);
    }
}

/// Solve the Lasso with ISTA (or FISTA when `cfg.fista`).
pub fn ista_solve<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &IstaConfig,
) -> SolveResult {
    let mut ws = Workspace::new();
    ista_solve_ws(x, y, lambda, beta0, cfg, &mut ws)
}

/// [`ista_solve`] on a caller-provided reusable [`Workspace`].
pub fn ista_solve_ws<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &IstaConfig,
    ws: &mut Workspace,
) -> SolveResult {
    let mu = spectral_norm_sq(x, 200, 0xC0FFEE).max(1e-300);
    let mut strategy = IstaStrategy {
        mu,
        z: Vec::new(),
        beta_prev: Vec::new(),
        grad: Vec::new(),
        t_mom: 1.0,
        fista: cfg.fista,
        fresh: true,
    };
    let ecfg = EngineConfig {
        tol: cfg.tol,
        max_epochs: cfg.max_epochs,
        gap_freq: cfg.gap_freq,
        k: cfg.k,
        extrapolate: cfg.extrapolate,
        best_dual: cfg.best_dual,
        screen: false,
        trace: cfg.trace,
        stop: StopRule::DualityGap,
        ..EngineConfig::default()
    };
    let init = match beta0 {
        Some(b) => Init::Warm(b),
        None => Init::Zeros,
    };
    let outcome = engine::solve(x, y, lambda, init, None, &ecfg, ws, &mut strategy);
    ws.solve_result(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::data::synth;
    use crate::lasso::dual as d;

    #[test]
    fn spectral_norm_matches_known() {
        // X = diag(3, 1) -> ||X||_2^2 = 9
        let x = DenseMatrix::from_row_major(2, 2, &[3.0, 0.0, 0.0, 1.0]);
        let mu = spectral_norm_sq(&x, 500, 1);
        assert!((mu - 9.0).abs() < 1e-6, "mu={mu}");
    }

    #[test]
    fn ista_matches_cd_solution() {
        let ds = synth::leukemia_mini(10);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 5.0;
        let ista = ista_solve(&ds.x, &ds.y, lambda, None, &IstaConfig { tol: 1e-10, ..Default::default() });
        let cd = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-10, ..Default::default() },
        );
        assert!(ista.converged);
        let pi = crate::lasso::primal::primal(&ds.x, &ds.y, &ista.beta, lambda);
        let pc = crate::lasso::primal::primal(&ds.x, &ds.y, &cd.beta, lambda);
        assert!((pi - pc).abs() < 1e-8, "ISTA {pi} vs CD {pc}");
    }

    #[test]
    fn fista_not_slower_than_ista() {
        let ds = synth::leukemia_mini(11);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 10.0;
        let base = IstaConfig { tol: 1e-8, ..Default::default() };
        let ista = ista_solve(&ds.x, &ds.y, lambda, None, &base);
        let fista = ista_solve(&ds.x, &ds.y, lambda, None, &IstaConfig { fista: true, ..base });
        assert!(fista.converged);
        assert!(
            fista.epochs <= ista.epochs,
            "FISTA ({}) should need no more epochs than ISTA ({})",
            fista.epochs,
            ista.epochs
        );
    }

    #[test]
    fn theorem1_extrapolation_converges_to_theta_hat() {
        // Theorem 1: with ISTA residuals, θ_accel → θ̂. Check that after
        // enough epochs the accelerated dual objective is very close to
        // the optimal dual value (gap of the extrapolated point ≈ 0).
        let ds = synth::leukemia_mini(12);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 5.0;
        let out = ista_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &IstaConfig { tol: 1e-12, trace: true, best_dual: false, ..Default::default() },
        );
        assert!(out.converged);
        let p_star = crate::lasso::primal::primal(&ds.x, &ds.y, &out.beta, lambda);
        let last = out.trace.last().unwrap();
        let d_acc = last.dual_accel.expect("extrapolation active by the end");
        // dual value of extrapolated point ~ P* (strong duality)
        assert!(
            (p_star - d_acc).abs() < 1e-7,
            "θ_accel near-optimal: P*={p_star}, D(θ_accel)={d_acc}"
        );
    }

    #[test]
    fn final_residual_is_at_beta() {
        let ds = synth::leukemia_mini(13);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 4.0;
        let out = ista_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &IstaConfig { fista: true, tol: 1e-8, ..Default::default() },
        );
        let mut expect = vec![0.0; ds.y.len()];
        crate::lasso::primal::residual(&ds.x, &ds.y, &out.beta, &mut expect);
        for i in 0..expect.len() {
            assert!((out.r[i] - expect[i]).abs() < 1e-12, "i={i}");
        }
    }
}
