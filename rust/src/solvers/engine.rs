//! The shared solver engine: one iterate/check loop, one set of scratch
//! buffers, every solver a thin strategy on top.
//!
//! Motivation (see `ARCHITECTURE.md`): before this layer existed, each of
//! the five Lasso solvers (cd, ista/fista, glmnet, blitz, celer) carried
//! its own copy of the residual bookkeeping, the periodic
//! gap-check → dual-update → screen → stop sequence, and its own freshly
//! allocated `beta`/`r`/`Xᵀr`/extrapolation buffers per call. The engine
//! centralizes both:
//!
//! - [`Workspace`] owns every solver-lifetime buffer (primal iterate,
//!   residual, dual state + extrapolation ring, correlation scratch,
//!   screening state). It is reusable across solves: a warm-started
//!   λ path reuses one workspace for the whole path, and CELER/Blitz
//!   reuse a nested workspace for all inner subproblem solves — so the
//!   hot path performs no per-λ or per-outer-iteration allocation.
//! - [`solve`] runs the epoch loop: call the [`Strategy`] for one primal
//!   epoch, then (every `gap_freq` epochs) refresh the dual point,
//!   evaluate the duality gap, optionally apply dynamic Gap Safe
//!   screening, record a trace entry, and test the stopping rule.
//!
//! Strategies implement only what genuinely differs between solvers: the
//! primal epoch (cyclic CD vs. a proximal-gradient step vs. a
//! prox-Newton/IRLS sweep) and, for FISTA, which residual the dual
//! machinery should see.
//!
//! The loop is generic over the [`Datafit`] (the GLM follow-up paper's
//! observation that dual extrapolation + working sets apply verbatim to
//! any smooth separable datafit): [`solve_datafit`] threads a `Datafit`
//! through the primal value, the dual update and the Gap Safe radius,
//! while [`solve`] is the quadratic (Lasso) instantiation — bit-identical
//! to the pre-datafit engine.
//!
//! Paper map: the epoch → gap-check → dual-update loop is **Algorithm 1**
//! (cyclic CD with dual extrapolation every `f` epochs; θ_res from
//! Eq. 4, θ_accel from Definition 1, best-dual from Eq. 13); the
//! equivalent dual view of the same iteration — Dykstra's algorithm on
//! the slab intersection — is **Algorithms 2–3**, implemented in
//! [`crate::solvers::dykstra`]. To solve several λ's of a path at once,
//! the batched engine in [`crate::solvers::batch`] runs B copies of this
//! loop interleaved over shared design sweeps.

use crate::data::design::DesignOps;
use crate::datafit::{Datafit, Quadratic};
use crate::lasso::primal;
use crate::penalty::{Penalty, L1};
use crate::screening::ScreeningState;
use crate::solvers::{DualScratch, DualState, GapCheck, SolveResult};
use crate::util::error::{FaultEvent, FaultKind, RecoveryAction, SolveOutcome};
use crate::util::fault::FaultPlan;
use crate::util::soft_threshold;
use std::time::Instant;

/// How many checkpoint rollbacks a single engine run may perform before
/// the watchdog gives up and returns the last certified state.
pub const MAX_RECOVERIES: usize = 3;

/// How the engine decides it is done.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopRule {
    /// Stop when the duality gap drops below `tol` (checked every
    /// `gap_freq` epochs; maintains the dual state).
    DualityGap,
    /// Stop when the primal objective decreases by less than `tol`
    /// between epochs (checked every epoch; the GLMNET criterion — no
    /// dual machinery runs at all).
    PrimalDecrease,
}

/// Engine configuration (the union of what the strategies need).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Stopping tolerance; its meaning depends on [`StopRule`].
    pub tol: f64,
    /// Maximum primal epochs.
    pub max_epochs: usize,
    /// Dual/gap evaluation frequency in epochs (ignored by
    /// [`StopRule::PrimalDecrease`], which checks every epoch).
    pub gap_freq: usize,
    /// Extrapolation depth K.
    pub k: usize,
    /// Compute θ_accel (Definition 1).
    pub extrapolate: bool,
    /// Keep the best dual point across checks (Eq. 13).
    pub best_dual: bool,
    /// Dynamic Gap Safe screening.
    pub screen: bool,
    /// Record a [`GapCheck`] per dual evaluation.
    pub trace: bool,
    /// Stopping rule.
    pub stop: StopRule,
    /// Wall-clock budget in seconds (checked at every stop-rule
    /// evaluation). `None` = unlimited. On expiry the run returns its
    /// partial-but-certified state with
    /// [`SolveOutcome::BudgetExhausted`].
    pub max_seconds: Option<f64>,
    /// Fault-injection plan (inert by default; see
    /// [`crate::util::fault`]).
    pub faults: FaultPlan,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            tol: 1e-6,
            max_epochs: 10_000,
            gap_freq: 10,
            k: 5,
            extrapolate: true,
            best_dual: true,
            screen: true,
            trace: false,
            stop: StopRule::DualityGap,
            max_seconds: None,
            faults: FaultPlan::none(),
        }
    }
}

/// How to initialize the primal iterate for a run.
#[derive(Debug, Clone, Copy)]
pub enum Init<'a> {
    /// β = 0, residual = y.
    Zeros,
    /// Copy the given β and compute the residual with one matvec.
    Warm(&'a [f64]),
    /// The workspace already holds a valid (β, r) pair for this design —
    /// continue from it without recomputing anything. Used by GLMNET's
    /// repeated KKT passes, which resume CD on a grown active set.
    Resume,
}

/// What a run reports. The solution itself (β, r, θ) stays in the
/// [`Workspace`], so outer loops (CELER, Blitz, GLMNET, paths) can read
/// it in place; [`Workspace::solve_result`] clones it out for the public
/// one-shot APIs.
#[derive(Debug, Clone)]
pub struct EngineOutcome {
    /// Final duality gap (`f64::INFINITY` when the stop rule never
    /// evaluates one).
    pub gap: f64,
    /// Primal epochs consumed.
    pub epochs: usize,
    /// Whether the stopping rule was met (vs. the epoch cap).
    pub converged: bool,
    /// Per-check trace (empty unless `cfg.trace`).
    pub trace: Vec<GapCheck>,
    /// How the run ended: `Certified`, `BudgetExhausted` (epoch cap or
    /// wall-clock budget) or `Recovered` (watchdog rollbacks occurred —
    /// the result is still gap-certified when `converged` holds).
    pub status: SolveOutcome,
}

/// A solver strategy: the per-epoch primal update, plus optional hooks
/// for solvers whose dual machinery needs a different residual than the
/// one the epochs maintain (FISTA).
///
/// Strategies are generic over the [`Datafit`] `F` (default: the
/// quadratic Lasso fit) and the [`Penalty`] `P` (default: plain ℓ₁).
/// For a non-quadratic datafit the epoch must keep **three** quantities
/// consistent: β, the linear predictor `xw = Xβ`, and the generalized
/// residual `r = −∇F(xw)` — see [`crate::solvers::glm::ProxNewtonCd`].
/// Quadratic strategies may ignore `xw` entirely (the engine never reads
/// it for `F = Quadratic`). Strategies that hard-code the ℓ₁
/// soft-threshold (FISTA, the f32 sweep, prox-Newton) implement only
/// `P = L1`; [`CdStrategy`] takes the penalty generically.
pub trait Strategy<D: DesignOps, F: Datafit = Quadratic, P: Penalty = L1> {
    /// Run one primal epoch, updating `beta` and `r` (and, for GLM
    /// datafits, `xw`) in place.
    ///
    /// `active` is the engine-maintained active set (all non-empty
    /// columns minus anything screened); `norms_sq` are cached `‖x_j‖²`.
    /// Strategies are free to ignore `active` (ISTA updates every
    /// coordinate with full-vector operations).
    fn epoch(
        &mut self,
        x: &D,
        y: &[f64],
        lambda: f64,
        beta: &mut [f64],
        r: &mut [f64],
        xw: &mut [f64],
        active: &[usize],
        norms_sq: &[f64],
        datafit: &F,
        penalty: &P,
    );

    /// Synchronize the engine-visible iterate with any strategy-private
    /// state **before** a gap check. Called at the top of every
    /// [`StopRule::DualityGap`] check, with mutable access to `beta` and
    /// `r`. Default: no-op (f64 strategies have no private iterate, so
    /// the historical path is untouched bit for bit). The f32 sweep
    /// strategy ([`crate::solvers::sweep32::F32CdStrategy`]) overrides
    /// this to promote its f32 β into `beta` and recompute `r = y − Xβ`
    /// exactly in f64 — the certification step that makes every gap /
    /// screening decision an exact f64 bound.
    fn sync_check_state(&mut self, x: &D, y: &[f64], beta: &mut [f64], r: &mut [f64]) {
        let _ = (x, y, beta, r);
    }

    /// Write the residual the dual update / primal value should use into
    /// `out`. Default: the maintained residual itself. FISTA overrides
    /// this because its epochs maintain `y − Xz` (momentum point) while
    /// checks must evaluate at β.
    fn fill_check_residual(&mut self, x: &D, y: &[f64], beta: &[f64], r: &[f64], out: &mut [f64]) {
        let _ = (x, y, beta);
        out.copy_from_slice(r);
    }

    /// Called once after the loop so the workspace residual reflects the
    /// returned β. Default: no-op (CD already maintains `r = y − Xβ`).
    fn finalize(&mut self, x: &D, y: &[f64], beta: &[f64], r: &mut [f64]) {
        let _ = (x, y, beta, r);
    }

    /// Notification that the engine watchdog detected a fault and rolled
    /// the iterate back to the last certified checkpoint. Strategies
    /// with private state must resynchronize from the restored (β, r);
    /// the f32 sweep strategy additionally escalates to f64 epochs (its
    /// f32 shadow may carry the corruption that triggered the fault).
    /// Returns the [`RecoveryAction`] to record in the fault event.
    fn on_fault(&mut self) -> RecoveryAction {
        RecoveryAction::RolledBack
    }
}

/// Cyclic coordinate descent over the active set — the strategy behind
/// `cd_solve`, GLMNET's inner passes, and the CELER/Blitz subproblem
/// solves (where `x` is a zero-copy
/// [`DesignView`](crate::data::view::DesignView)).
pub struct CdStrategy;

/// Largest supported [`Penalty::group_size`] for the stack-allocated
/// group-CD buffers (no heap traffic on the epoch hot path).
pub const MAX_GROUP: usize = 64;

impl<D: DesignOps, P: Penalty> Strategy<D, Quadratic, P> for CdStrategy {
    fn epoch(
        &mut self,
        x: &D,
        _y: &[f64],
        lambda: f64,
        beta: &mut [f64],
        r: &mut [f64],
        _xw: &mut [f64],
        active: &[usize],
        norms_sq: &[f64],
        _datafit: &Quadratic,
        penalty: &P,
    ) {
        if P::IS_L1 {
            // The historical ℓ₁ loop, expression for expression (the
            // bit-identity invariant — `lambda / nrm` stays one division).
            for &j in active {
                let nrm = norms_sq[j];
                let g = x.col_dot(j, r);
                let old = beta[j];
                let new = soft_threshold(old + g / nrm, lambda / nrm);
                if new != old {
                    x.col_axpy(j, old - new, r);
                    beta[j] = new;
                }
            }
        } else if P::SEPARABLE {
            // Generic separable prox in the same fused update shape.
            for &j in active {
                let nrm = norms_sq[j];
                let g = x.col_dot(j, r);
                let old = beta[j];
                let new = penalty.prox(j, old + g / nrm, lambda, nrm);
                if new != old {
                    x.col_axpy(j, old - new, r);
                    beta[j] = new;
                }
            }
        } else {
            // Group CD: one block prox per contiguous group, majorized by
            // the group Frobenius curvature L_g = Σ_{k∈g} ‖x_k‖² ≥ ‖X_g‖₂²
            // (a safe Lipschitz bound, so the prox step is a monotone MM
            // update). `active` is sorted, so each group is visited once,
            // keyed on its first active member; zero-norm members inside
            // a group contribute nothing to either L_g or the gradient.
            let gs = penalty.group_size();
            assert!(gs <= MAX_GROUP, "group size {gs} exceeds MAX_GROUP = {MAX_GROUP}");
            let p = beta.len();
            let mut u = [0.0f64; MAX_GROUP];
            let mut old = [0.0f64; MAX_GROUP];
            let mut last_group = usize::MAX;
            for &j in active {
                let g_idx = j / gs;
                if g_idx == last_group {
                    continue;
                }
                last_group = g_idx;
                let start = g_idx * gs;
                let end = (start + gs).min(p);
                let width = end - start;
                let mut l_g = 0.0;
                for k in start..end {
                    l_g += norms_sq[k];
                }
                if l_g == 0.0 {
                    continue;
                }
                for (t, k) in (start..end).enumerate() {
                    old[t] = beta[k];
                    u[t] = beta[k] + x.col_dot(k, r) / l_g;
                }
                penalty.prox_vec(&u[..width], lambda, l_g, &mut beta[start..end]);
                for (t, k) in (start..end).enumerate() {
                    let new = beta[k];
                    if new != old[t] {
                        x.col_axpy(k, old[t] - new, r);
                    }
                }
            }
        }
    }
}

/// Reusable solver state. One workspace serves any number of sequential
/// solves (different λ, different working sets, different solvers); its
/// buffers are resized — never reallocated once warm — on each run.
#[derive(Default)]
pub struct Workspace {
    /// Primal iterate β (length p of the most recent run).
    pub beta: Vec<f64>,
    /// Maintained generalized residual `−∇F(Xβ)` (length n; the plain
    /// residual `y − Xβ` for the quadratic datafit).
    pub r: Vec<f64>,
    /// Linear predictor `Xβ` (length n). Maintained by GLM strategies
    /// and consumed by the datafit's primal value and the GLM screening
    /// fix-up; quadratic strategies leave it at its `init_primal` state
    /// (it is never read on the quadratic path after initialization).
    pub xw: Vec<f64>,
    /// Check-time residual (FISTA evaluates at β, not the iterate).
    pub r_check: Vec<f64>,
    /// Cached `‖x_j‖²` for the current design.
    pub norms_sq: Vec<f64>,
    /// Cached `‖x_j‖` (screening uses plain norms).
    pub col_norms: Vec<f64>,
    /// Engine-maintained active set.
    pub active: Vec<usize>,
    /// Dual point machinery (θ, Xᵀθ, extrapolation ring).
    pub dual: DualState,
    /// Gap-check scratch (Xᵀr, accel buffers).
    pub scratch: DualScratch,
    /// Dynamic screening state.
    pub screening: ScreeningState,
    /// Outer-loop scratch for working-set solvers (CELER/Blitz): dual
    /// candidates and pricing buffers.
    pub theta: Vec<f64>,
    pub theta_inner: Vec<f64>,
    pub theta_res: Vec<f64>,
    pub xtheta: Vec<f64>,
    pub xtheta_inner: Vec<f64>,
    pub d_scores: Vec<f64>,
    /// Subproblem warm-start coefficients (length |W_t|).
    pub beta_ws: Vec<f64>,
    /// Nested workspace for inner (working-set) solves, allocated on
    /// first use and reused for every subsequent subproblem.
    pub inner: Option<Box<Workspace>>,
    /// Lane workspace for batched multi-λ path solves (see
    /// [`crate::solvers::batch`]), allocated on the first batched run
    /// and reused — so a coordinator worker thread carries both the
    /// sequential and the batched engine state in one place.
    pub batch: Option<Box<crate::solvers::batch::BatchWorkspace>>,
    /// Block-coefficient workspace for Multi-Task solves (see
    /// [`crate::solvers::block`]), allocated on the first MT run and
    /// reused — a coordinator worker or λ-path driver carries the
    /// scalar, batched and block engine state in one place.
    pub mt: Option<Box<crate::solvers::block::BlockWorkspace>>,
    /// Watchdog checkpoint: the (β, r, xw, θ) snapshot taken at the last
    /// healthy gap check, restored on a non-finite/divergence fault.
    /// `ckpt_xw` stays empty on the quadratic path (xw is never read
    /// there); `ckpt_theta` preserves the certified dual point so an
    /// aborted run still returns a (β, θ, gap) certificate.
    pub ckpt_beta: Vec<f64>,
    pub ckpt_r: Vec<f64>,
    pub ckpt_xw: Vec<f64>,
    pub ckpt_theta: Vec<f64>,
}

/// Fill the cached `‖x_j‖²` / `‖x_j‖` vectors for a design, reusing the
/// buffers' capacity. The one buffer-preparation sequence shared by the
/// sequential workspace ([`Workspace::init_primal`]) and the batched
/// lane workspace ([`crate::solvers::batch`]).
pub(crate) fn fill_norm_caches<D: DesignOps>(
    x: &D,
    norms_sq: &mut Vec<f64>,
    col_norms: &mut Vec<f64>,
) {
    let p = x.p();
    norms_sq.resize(p, 0.0);
    crate::util::par::par_fill_cost(norms_sq, x.col_cost_hint(), |j| x.col_norm_sq(j));
    col_norms.resize(p, 0.0);
    for j in 0..p {
        col_norms[j] = norms_sq[j].sqrt();
    }
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Initialize the primal state for a solve on `x`: cached column
    /// norms, β from `beta0` (zeros when `None`), and the residual
    /// `r = y − Xβ`. Shared by [`solve`]'s non-Resume path and the
    /// outer working-set loops (CELER / Blitz / GLMNET), so the
    /// buffer-preparation sequence exists exactly once.
    pub fn init_primal<D: DesignOps>(&mut self, x: &D, y: &[f64], beta0: Option<&[f64]>) {
        self.init_primal_datafit(x, y, beta0, &Quadratic);
    }

    /// Datafit-generic [`Workspace::init_primal`]: one matvec fills the
    /// linear predictor `xw = Xβ`, then the datafit derives the
    /// generalized residual `r = −∇F(xw)` (for the quadratic fit that is
    /// exactly `y − Xβ`, value for value).
    pub fn init_primal_datafit<D: DesignOps, F: Datafit>(
        &mut self,
        x: &D,
        y: &[f64],
        beta0: Option<&[f64]>,
        datafit: &F,
    ) {
        let n = x.n();
        let p = x.p();
        assert_eq!(y.len(), n);
        fill_norm_caches(x, &mut self.norms_sq, &mut self.col_norms);
        self.beta.resize(p, 0.0);
        match beta0 {
            Some(b) => {
                assert_eq!(b.len(), p);
                self.beta.copy_from_slice(b);
            }
            None => self.beta.fill(0.0),
        }
        self.xw.resize(n, 0.0);
        self.r.resize(n, 0.0);
        primal::glm_state(x, datafit, y, &self.beta, &mut self.xw, &mut self.r);
    }

    /// Take the nested inner workspace (creating it on first use). The
    /// caller must hand it back via [`Workspace::put_inner`] — taking it
    /// out breaks the borrow between the outer workspace (whose buffers
    /// back the `DesignView`) and the inner solve's mutable state.
    pub fn take_inner(&mut self) -> Box<Workspace> {
        self.inner.take().unwrap_or_default()
    }

    /// Return the nested inner workspace after an inner solve.
    pub fn put_inner(&mut self, inner: Box<Workspace>) {
        self.inner = Some(inner);
    }

    /// Take the batched multi-λ lane workspace (creating it on first
    /// use); hand it back via [`Workspace::put_batch`].
    pub fn take_batch(&mut self) -> Box<crate::solvers::batch::BatchWorkspace> {
        self.batch.take().unwrap_or_default()
    }

    /// Return the batched lane workspace after a batched path run.
    pub fn put_batch(&mut self, batch: Box<crate::solvers::batch::BatchWorkspace>) {
        self.batch = Some(batch);
    }

    /// Take the block-coefficient (Multi-Task) workspace, creating it on
    /// first use; hand it back via [`Workspace::put_mt`].
    pub fn take_mt(&mut self) -> Box<crate::solvers::block::BlockWorkspace> {
        self.mt.take().unwrap_or_default()
    }

    /// Return the block-coefficient workspace after a Multi-Task run.
    pub fn put_mt(&mut self, mt: Box<crate::solvers::block::BlockWorkspace>) {
        self.mt = Some(mt);
    }

    /// Clone the workspace's solution out into a [`SolveResult`].
    pub fn solve_result(&self, outcome: EngineOutcome) -> SolveResult {
        SolveResult {
            beta: self.beta.clone(),
            r: self.r.clone(),
            theta: self.dual.theta.clone(),
            gap: outcome.gap,
            epochs: outcome.epochs,
            converged: outcome.converged,
            trace: outcome.trace,
            status: outcome.status,
        }
    }
}

/// The engine's primal objective `F(Xβ) + λΩ(β)`. The `P = L1`
/// instantiation delegates to [`primal::glm_primal_value`] — the
/// historical expression tree, bit for bit.
#[inline]
fn penalty_primal<F: Datafit, P: Penalty>(
    datafit: &F,
    y: &[f64],
    xw: &[f64],
    r: &[f64],
    beta: &[f64],
    lambda: f64,
    penalty: &P,
) -> f64 {
    if P::IS_L1 {
        return primal::glm_primal_value(datafit, y, xw, r, beta, lambda);
    }
    datafit.value(y, xw, r) + penalty.value(lambda, beta)
}

/// Run the engine: `strategy` epochs over `x` until `cfg.stop` fires or
/// `cfg.max_epochs` is reached. The solution is left in `ws` (β in
/// `ws.beta`, residual in `ws.r`, dual point in `ws.dual.theta`).
///
/// `active0`: explicit initial active set (GLMNET's strong/ever-active
/// set); `None` means every non-empty column.
///
/// Shorthand for [`solve_datafit`] with the quadratic (Lasso) datafit.
pub fn solve<D: DesignOps, S: Strategy<D>>(
    x: &D,
    y: &[f64],
    lambda: f64,
    init: Init<'_>,
    active0: Option<&[usize]>,
    cfg: &EngineConfig,
    ws: &mut Workspace,
    strategy: &mut S,
) -> EngineOutcome {
    solve_datafit(x, y, lambda, init, active0, cfg, ws, strategy, &Quadratic)
}

/// Datafit-generic engine loop: the epoch → gap-check → dual-update →
/// screen → stop sequence of [`solve`], for any [`Datafit`] `F`.
///
/// The generalized residual `−∇F(Xβ)` flows through the identical dual
/// machinery (Eq. 4 rescale, extrapolation ring, Eq. 13 best-dual); the
/// differences are confined to the datafit calls: the primal value, the
/// conjugate (dual) value, and the Gap Safe radius `√(2·L·gap)/λ`. For a
/// non-quadratic `F`, screening patches the linear predictor `ws.xw`
/// and refreshes `r` wholesale (the residual is not linear in β), and is
/// skipped entirely when the datafit has no global Lipschitz constant
/// (Poisson). The `F = Quadratic` instantiation is bit-identical to the
/// historical engine — pinned in `tests/prop_glm.rs`.
///
/// Shorthand for [`solve_penalty`] with the plain ℓ₁ penalty.
pub fn solve_datafit<D: DesignOps, F: Datafit, S: Strategy<D, F>>(
    x: &D,
    y: &[f64],
    lambda: f64,
    init: Init<'_>,
    active0: Option<&[usize]>,
    cfg: &EngineConfig,
    ws: &mut Workspace,
    strategy: &mut S,
    datafit: &F,
) -> EngineOutcome {
    solve_penalty(x, y, lambda, init, active0, cfg, ws, strategy, datafit, &L1)
}

/// Penalty-generic engine loop: the epoch → gap-check → dual-update →
/// screen → stop sequence for any ([`Datafit`] `F`, [`Penalty`] `P`)
/// pair a strategy implements. The penalty surfaces in exactly four
/// places: the epoch's prox (inside the [`Strategy`]), the primal value
/// (`F(Xβ) + λΩ(β)`), the dual update (Ω^D rescale + conjugate term, via
/// [`DualState::update_penalty`]) and the Gap Safe rule
/// ([`ScreeningState::screen_penalty`]). Non-ℓ₁ penalties screen only
/// under the quadratic datafit — the combined GLM × generic-penalty
/// radius is not implemented, so that configuration runs unscreened
/// (and is currently unreachable: the GLM strategies are `P = L1`).
/// The `P = L1` instantiation is bit-identical to [`solve_datafit`] —
/// pinned in `tests/prop_penalty.rs`.
pub fn solve_penalty<D: DesignOps, F: Datafit, P: Penalty, S: Strategy<D, F, P>>(
    x: &D,
    y: &[f64],
    lambda: f64,
    init: Init<'_>,
    active0: Option<&[usize]>,
    cfg: &EngineConfig,
    ws: &mut Workspace,
    strategy: &mut S,
    datafit: &F,
    penalty: &P,
) -> EngineOutcome {
    debug_assert!(
        P::IS_L1 || F::IS_QUADRATIC,
        "generic penalties currently pair with the quadratic datafit only"
    );
    let n = x.n();
    let p = x.p();
    assert_eq!(y.len(), n);
    let start = Instant::now();
    let resume = matches!(init, Init::Resume);

    // ---- buffers (capacity reused across runs) ----
    if !resume {
        let beta0 = match init {
            Init::Zeros => None,
            Init::Warm(b) => Some(b),
            Init::Resume => unreachable!(),
        };
        ws.init_primal_datafit(x, y, beta0, datafit);
        ws.dual.reset(n, p, cfg.k.max(1), cfg.extrapolate, cfg.best_dual);
        ws.scratch.prepare(n, p);
        ws.screening.reset_all_active(p);
    } else {
        // Resume continues a previous run's (β, r) without re-resetting
        // the dual/screening state — which is only sound when that state
        // is not consulted. Guard the unsupported combinations instead
        // of silently reusing a stale dual point or screened set.
        assert!(
            matches!(cfg.stop, StopRule::PrimalDecrease) && !cfg.screen,
            "Init::Resume supports only StopRule::PrimalDecrease without \
             screening (the dual/screening state is not re-initialized)"
        );
        assert_eq!(ws.beta.len(), p, "Resume requires a prepared workspace");
        assert_eq!(ws.r.len(), n, "Resume requires a prepared workspace");
        assert_eq!(ws.norms_sq.len(), p, "Resume requires cached norms");
        if !F::IS_QUADRATIC {
            // GLM primal values read the predictor, so a resumed run
            // must inherit a consistent xw from the previous run.
            assert_eq!(ws.xw.len(), n, "Resume requires a prepared predictor");
        }
    }
    ws.r_check.resize(n, 0.0);

    // ---- active set ----
    ws.active.clear();
    match active0 {
        Some(a) => {
            let norms = &ws.norms_sq;
            ws.active.extend(a.iter().copied().filter(|&j| norms[j] > 0.0));
        }
        None => {
            // Empty columns can never enter the model; drop them up-front
            // so the epoch loop never touches them.
            let norms = &ws.norms_sq;
            ws.active.extend((0..p).filter(|&j| norms[j] > 0.0));
        }
    }

    let use_gap = matches!(cfg.stop, StopRule::DualityGap);
    let mut trace: Vec<GapCheck> = Vec::new();
    let mut gap = f64::INFINITY;
    let mut epochs = 0usize;
    let mut converged = false;
    let mut prev_obj = if use_gap {
        f64::INFINITY
    } else {
        penalty_primal(datafit, y, &ws.xw, &ws.r, &ws.beta, lambda, penalty)
    };
    // Watchdog bookkeeping. On the healthy path these are pure reads and
    // checkpoint memcpys — no floating-point operation changes, so the
    // no-fault run stays bit-identical to the pre-watchdog engine
    // (pinned in tests/prop_penalty.rs).
    let mut faults: Vec<FaultEvent> = Vec::new();
    let mut recoveries = 0usize;
    let mut has_ckpt = false;
    let mut ckpt_primal = f64::INFINITY;
    let mut ckpt_gap = f64::INFINITY;

    if use_gap {
        // Seed the checkpoint with the initial state so a fault at the
        // very first gap check still has a finite state to roll back to
        // (the init iterate is valid; its gap is simply unknown).
        ws.ckpt_beta.resize(p, 0.0);
        ws.ckpt_beta.copy_from_slice(&ws.beta);
        ws.ckpt_r.resize(n, 0.0);
        ws.ckpt_r.copy_from_slice(&ws.r);
        if F::IS_QUADRATIC {
            ws.ckpt_xw.clear();
        } else {
            ws.ckpt_xw.resize(n, 0.0);
            ws.ckpt_xw.copy_from_slice(&ws.xw);
        }
        ws.ckpt_theta.resize(n, 0.0);
        ws.ckpt_theta.copy_from_slice(&ws.dual.theta);
        has_ckpt = true;
    }

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        // ---- one primal epoch ----
        strategy.epoch(
            x,
            y,
            lambda,
            &mut ws.beta,
            &mut ws.r,
            &mut ws.xw,
            &ws.active,
            &ws.norms_sq,
            datafit,
            penalty,
        );

        match cfg.stop {
            StopRule::PrimalDecrease => {
                let obj = penalty_primal(datafit, y, &ws.xw, &ws.r, &ws.beta, lambda, penalty);
                if prev_obj - obj < cfg.tol {
                    converged = true;
                    break;
                }
                prev_obj = obj;
                if let Some(limit) = cfg.max_seconds {
                    if start.elapsed().as_secs_f64() >= limit {
                        break;
                    }
                }
            }
            StopRule::DualityGap => {
                if epoch % cfg.gap_freq == 0 || epoch == cfg.max_epochs {
                    strategy.sync_check_state(x, y, &mut ws.beta, &mut ws.r);
                    cfg.faults.inject_nan_residual(epoch, &mut ws.r);
                    strategy.fill_check_residual(x, y, &ws.beta, &ws.r, &mut ws.r_check);
                    let (d_res, d_accel) = if P::IS_L1 {
                        ws.dual.update_datafit(x, y, lambda, &ws.r_check, &mut ws.scratch, datafit)
                    } else {
                        ws.dual.update_penalty(x, y, lambda, &ws.r_check, &mut ws.scratch, penalty)
                    };
                    let p_val =
                        penalty_primal(datafit, y, &ws.xw, &ws.r_check, &ws.beta, lambda, penalty);
                    gap = p_val - ws.dual.dval;
                    // ---- watchdog: non-finite / divergence detection with
                    // certified-checkpoint rollback. Detection is a pure
                    // read of values the check already computed.
                    let diverged = ckpt_primal.is_finite()
                        && p_val.is_finite()
                        // FISTA restarts and prox-Newton line-search misses
                        // are non-monotone by design — only a gross blow-up
                        // past the last certified primal counts as a fault.
                        && p_val > 100.0 * (ckpt_primal.abs() + 1.0);
                    if !gap.is_finite() && !(p_val.is_finite() && ws.dual.dval.is_finite()) || diverged {
                        let kind = if !p_val.is_finite() {
                            FaultKind::NonFiniteResidual
                        } else if !ws.dual.dval.is_finite() {
                            FaultKind::NonFiniteDual
                        } else if diverged {
                            FaultKind::PrimalDivergence
                        } else {
                            FaultKind::NonFiniteGap
                        };
                        if has_ckpt && recoveries < MAX_RECOVERIES {
                            // Roll back to the last certified checkpoint,
                            // flush the extrapolation ring (a corrupted θ
                            // in the ring would re-poison the next accel
                            // point), and let the strategy resync.
                            recoveries += 1;
                            ws.beta.copy_from_slice(&ws.ckpt_beta);
                            ws.r.copy_from_slice(&ws.ckpt_r);
                            if !ws.ckpt_xw.is_empty() {
                                ws.xw.copy_from_slice(&ws.ckpt_xw);
                            }
                            ws.dual.reset(n, p, cfg.k.max(1), cfg.extrapolate, cfg.best_dual);
                            let action = strategy.on_fault();
                            faults.push(FaultEvent { kind, epoch, action });
                            gap = ckpt_gap;
                            continue;
                        }
                        // Recovery budget exhausted (or nothing to roll
                        // back to): restore the last certified state and
                        // stop — never return a non-finite iterate.
                        faults.push(FaultEvent { kind, epoch, action: RecoveryAction::Aborted });
                        if has_ckpt {
                            ws.beta.copy_from_slice(&ws.ckpt_beta);
                            ws.r.copy_from_slice(&ws.ckpt_r);
                            if !ws.ckpt_xw.is_empty() {
                                ws.xw.copy_from_slice(&ws.ckpt_xw);
                            }
                            ws.dual.theta.resize(n, 0.0);
                            ws.dual.theta.copy_from_slice(&ws.ckpt_theta);
                        }
                        gap = ckpt_gap;
                        converged = false;
                        break;
                    }
                    // Screen only while unconverged: the reported (β, gap)
                    // pair must be the one that passed the stopping test —
                    // a screening mutation after the final check would go
                    // uncorrected.
                    if cfg.screen && gap > cfg.tol {
                        if F::IS_QUADRATIC {
                            // Residual-linear fast path: screening zeroes
                            // β_j and patches r incrementally
                            // (`screen_penalty` delegates to the historical
                            // `screen` when P = L1 — same bits).
                            let n_screened = ws.screening.screen_penalty(
                                x,
                                &ws.dual.xtheta,
                                &ws.col_norms,
                                gap,
                                lambda,
                                penalty,
                                &mut ws.beta,
                                &mut ws.r,
                            );
                            if n_screened > 0 {
                                // Keep the predictor consistent for
                                // strategies that rebuild r from it
                                // (prox-Newton on the quadratic datafit):
                                // r is exactly y − Xβ here, so xw = y − r.
                                // Plain CD never reads xw; the fix-up is
                                // one n-pass per screening event.
                                for i in 0..n {
                                    ws.xw[i] = y[i] - ws.r[i];
                                }
                            }
                        } else if datafit.lipschitz().is_finite() {
                            // GLM Gap Safe: radius √(2·L·gap)/λ, patch the
                            // predictor, refresh r once if anything moved.
                            let radius = crate::screening::gap_safe_radius_glm(
                                gap,
                                lambda,
                                datafit.lipschitz(),
                            );
                            let n_screened = ws.screening.screen_glm(
                                x,
                                &ws.dual.xtheta,
                                &ws.col_norms,
                                radius,
                                &mut ws.beta,
                                &mut ws.xw,
                            );
                            if n_screened > 0 {
                                datafit.fill_residual(y, &ws.xw, &mut ws.r);
                            }
                        }
                        let screening = &ws.screening;
                        ws.active.retain(|&j| !screening.is_screened(j));
                    }
                    // ---- healthy check: refresh the certified
                    // checkpoint (taken post-screening so a rollback
                    // restores a state consistent with the screened set).
                    ws.ckpt_beta.copy_from_slice(&ws.beta);
                    ws.ckpt_r.copy_from_slice(&ws.r);
                    if !F::IS_QUADRATIC {
                        ws.ckpt_xw.copy_from_slice(&ws.xw);
                    }
                    ws.ckpt_theta.copy_from_slice(&ws.dual.theta);
                    ckpt_primal = p_val;
                    ckpt_gap = gap;
                    if cfg.trace {
                        trace.push(GapCheck {
                            epoch,
                            primal: p_val,
                            dual_res: d_res,
                            dual_accel: d_accel,
                            gap,
                            n_screened: ws.screening.n_screened(),
                            seconds: start.elapsed().as_secs_f64(),
                        });
                    }
                    if gap <= cfg.tol {
                        converged = true;
                        break;
                    }
                    if let Some(limit) = cfg.max_seconds {
                        if start.elapsed().as_secs_f64() >= limit {
                            break;
                        }
                    }
                }
            }
        }
    }

    strategy.finalize(x, y, &ws.beta, &mut ws.r);
    let status = SolveOutcome::from_run(converged, gap, epochs, faults);
    EngineOutcome { gap, epochs, converged, trace, status }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;

    fn engine_cfg(tol: f64) -> EngineConfig {
        EngineConfig {
            tol,
            max_epochs: 10_000,
            gap_freq: 10,
            k: 5,
            extrapolate: true,
            best_dual: true,
            screen: false,
            trace: false,
            stop: StopRule::DualityGap,
            ..EngineConfig::default()
        }
    }

    #[test]
    fn engine_solves_orthogonal_design() {
        // Unit-norm orthogonal columns: β̂_j = ST(x_jᵀy, λ).
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.4];
        let mut ws = Workspace::new();
        let out = solve(&x, &y, 1.0, Init::Zeros, None, &engine_cfg(1e-12), &mut ws, &mut CdStrategy);
        assert!(out.converged);
        assert!((ws.beta[0] - 2.0).abs() < 1e-10);
        assert_eq!(ws.beta[1], 0.0);
    }

    #[test]
    fn workspace_reuse_is_equivalent_to_fresh() {
        let ds = crate::data::synth::leukemia_mini(77);
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 10.0;
        let cfg = engine_cfg(1e-9);
        let mut fresh = Workspace::new();
        let a = solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut fresh, &mut CdStrategy);
        // dirty the reused workspace with an unrelated solve first
        let mut reused = Workspace::new();
        let _ = solve(&ds.x, &ds.y, lambda * 3.0, Init::Zeros, None, &cfg, &mut reused, &mut CdStrategy);
        let b = solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut reused, &mut CdStrategy);
        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.gap, b.gap);
        assert_eq!(fresh.beta, reused.beta);
        assert_eq!(fresh.r, reused.r);
        assert_eq!(fresh.dual.theta, reused.dual.theta);
    }

    #[test]
    fn primal_decrease_stop_matches_manual_loop() {
        let ds = crate::data::synth::leukemia_mini(78);
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let cfg = EngineConfig { tol: 1e-8, stop: StopRule::PrimalDecrease, ..engine_cfg(1e-8) };
        let mut ws = Workspace::new();
        let out = solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut ws, &mut CdStrategy);
        assert!(out.converged, "primal-decrease loop terminates");
        // the gap field is untouched by this stop rule
        assert!(out.gap.is_infinite());
    }

    #[test]
    fn resume_continues_without_reinit() {
        let ds = crate::data::synth::leukemia_mini(79);
        let lambda = crate::lasso::dual::lambda_max(&ds.x, &ds.y) / 5.0;
        let mut cfg = EngineConfig { stop: StopRule::PrimalDecrease, ..engine_cfg(1e-10) };
        cfg.max_epochs = 3;
        let mut ws = Workspace::new();
        let _ = solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut ws, &mut CdStrategy);
        let obj_after_first = primal::primal_from_residual(&ws.r, &ws.beta, lambda);
        cfg.max_epochs = 10_000;
        cfg.tol = 1e-12;
        let out = solve(&ds.x, &ds.y, lambda, Init::Resume, None, &cfg, &mut ws, &mut CdStrategy);
        assert!(out.converged);
        let obj_final = primal::primal_from_residual(&ws.r, &ws.beta, lambda);
        assert!(obj_final <= obj_after_first + 1e-12, "resume only improves");
    }
}
