//! Sparse GLM solvers (logistic / Poisson Lasso) on the unified engine.
//!
//! This is the crate's instantiation of *Dual Extrapolation for Sparse
//! Generalized Linear Models* (Massias et al., 2019): the CELER outer
//! loop ([`crate::solvers::celer::celer_solve_datafit`]), the engine's
//! epoch → gap-check → dual-update → screen → stop loop
//! ([`crate::solvers::engine::solve_datafit`]), the extrapolation ring
//! and the Gap Safe rules all run on the **generalized residual**
//! `−∇F(Xβ)` of a [`Datafit`]; the only genuinely new piece a GLM needs
//! is the primal epoch, supplied here as [`ProxNewtonCd`]:
//!
//! 1. freeze the IRLS curvature weights `wᵢ = fᵢ''(x_iᵀβ)` and build the
//!    prox-Newton quadratic model
//!    `β ↦ −⟨r, Xδ⟩ + ½(Xδ)ᵀW(Xδ) + λ‖β+δ‖₁`;
//! 2. run cyclic CD passes on the model over the active set — the
//!    per-coordinate curvature is `x_jᵀWx_j`
//!    ([`DesignOps::col_wnorm_sq`]) and the model residual
//!    `ρ = r − W·Xδ` is maintained by [`DesignOps::col_waxpy`];
//! 3. backtracking line search on the true primal along the Newton
//!    direction (Xβ is linear in β, so the predictor interpolates
//!    between two cached snapshots — no extra matvec per halving).
//!
//! With the quadratic datafit the weights are identically 1 and step 2
//! reduces to the plain CD epoch, so a single strategy covers the whole
//! family; the quadratic solvers keep their dedicated `CdStrategy`
//! anyway for the bit-identity pin.
//!
//! Entry points: [`sparse_logreg_solve`] / [`sparse_poisson_solve`]
//! (CELER working-set solves), [`glm_cd_solve`] (full-design prox-Newton
//! with optional Gap Safe screening — the unscreened reference of the
//! property tests), and [`crate::solvers::path::glm_path`] for
//! warm-started λ paths.

use crate::data::design::{DesignMatrix, DesignOps};
use crate::datafit::{Datafit, GlmFamily, Logistic, Poisson};
use crate::lasso::primal;
use crate::solvers::celer::{celer_solve_datafit, CelerConfig, CelerOutput};
use crate::solvers::cd::CdConfig;
use crate::solvers::engine::{self, Init, Strategy, Workspace};
use crate::solvers::SolveResult;
use crate::util::soft_threshold;

/// Curvature floor: a coordinate whose weighted norm underflows (all its
/// observations sit in a flat region of the loss) would otherwise take
/// an unbounded Newton step; the line search would reject it, but the
/// floor keeps the step finite in the first place.
const WEIGHT_FLOOR: f64 = 1e-12;

/// Prox-Newton / IRLS-weighted CD epoch — the GLM [`Strategy`].
///
/// One engine epoch = one prox-Newton step: refresh weights, `cd_passes`
/// cyclic CD sweeps on the quadratic model, then a monotone backtracking
/// line search. The strategy owns all its scratch (weights, model
/// residual, snapshots), sized on first use and reused across epochs,
/// λ-path steps and working-set sizes — a warm solve allocates nothing.
#[derive(Debug, Clone)]
pub struct ProxNewtonCd {
    /// CD sweeps on the frozen quadratic model per prox-Newton step.
    pub cd_passes: usize,
    /// Line-search halving cap.
    pub max_halvings: usize,
    /// IRLS weights `fᵢ''(xwᵢ)` (length n).
    weights: Vec<f64>,
    /// Model residual `ρ = r − W·Xδ` during the sweep; reused as the
    /// predictor delta `xw − xw0` during the line search (length n).
    rho: Vec<f64>,
    /// Epoch-start predictor snapshot (length n).
    xw0: Vec<f64>,
    /// Epoch-start iterate snapshot (length p).
    beta0: Vec<f64>,
    /// Accumulated coordinate deltas of the sweep (length p).
    dbeta: Vec<f64>,
    /// Weighted per-coordinate curvatures `x_jᵀWx_j` (length p).
    lj: Vec<f64>,
}

impl Default for ProxNewtonCd {
    fn default() -> Self {
        ProxNewtonCd {
            cd_passes: 1,
            max_halvings: 20,
            weights: Vec::new(),
            rho: Vec::new(),
            xw0: Vec::new(),
            beta0: Vec::new(),
            dbeta: Vec::new(),
            lj: Vec::new(),
        }
    }
}

impl ProxNewtonCd {
    pub fn new(cd_passes: usize) -> Self {
        ProxNewtonCd { cd_passes: cd_passes.max(1), ..Default::default() }
    }
}

impl<D: DesignOps, F: Datafit> Strategy<D, F> for ProxNewtonCd {
    fn epoch(
        &mut self,
        x: &D,
        y: &[f64],
        lambda: f64,
        beta: &mut [f64],
        r: &mut [f64],
        xw: &mut [f64],
        active: &[usize],
        _norms_sq: &[f64],
        datafit: &F,
        _penalty: &crate::penalty::L1,
    ) {
        let n = y.len();
        let p = beta.len();
        self.weights.resize(n, 0.0);
        self.rho.resize(n, 0.0);
        self.xw0.resize(n, 0.0);
        self.beta0.resize(p, 0.0);
        self.dbeta.resize(p, 0.0);
        self.lj.resize(p, 0.0);

        // ---- freeze the quadratic model at the current iterate ----
        datafit.fill_weights(y, xw, &mut self.weights);
        for w in self.weights.iter_mut() {
            if !(*w >= WEIGHT_FLOOR) {
                *w = WEIGHT_FLOOR;
            }
        }
        for &j in active {
            self.lj[j] = x.col_wnorm_sq(j, &self.weights);
        }
        let p_old = datafit.value(y, xw, r) + lambda * primal::l1_norm(beta);
        self.xw0.copy_from_slice(xw);
        self.beta0.copy_from_slice(beta);
        self.rho.copy_from_slice(r);
        for &j in active {
            self.dbeta[j] = 0.0;
        }

        // ---- CD on the model: g_j = x_jᵀρ, L_j = x_jᵀWx_j ----
        for _ in 0..self.cd_passes.max(1) {
            for &j in active {
                let ljj = self.lj[j];
                if ljj <= 0.0 {
                    continue;
                }
                let g = x.col_dot(j, &self.rho);
                let old = beta[j];
                let new = soft_threshold(old + g / ljj, lambda / ljj);
                let d = new - old;
                if d != 0.0 {
                    beta[j] = new;
                    self.dbeta[j] += d;
                    x.col_axpy(j, d, xw);
                    x.col_waxpy(j, -d, &self.weights, &mut self.rho);
                }
            }
        }

        // ---- monotone backtracking on the Newton direction ----
        // Xβ is linear in β: xw(t) = xw0 + t·(xw_full − xw0), so each
        // halving is O(n + |active|), no matvec. ρ is dead; reuse it as
        // the predictor delta.
        for i in 0..n {
            self.rho[i] = xw[i] - self.xw0[i];
        }
        datafit.fill_residual(y, xw, r);
        let mut p_new = datafit.value(y, xw, r) + lambda * primal::l1_norm(beta);
        let mut t = 1.0;
        let mut halvings = 0;
        // `!(≤)` also catches NaN/∞ objectives (e.g. Poisson overflow
        // at an overshot predictor) and backtracks out of them.
        while !(p_new <= p_old) && halvings < self.max_halvings {
            t *= 0.5;
            halvings += 1;
            for &j in active {
                beta[j] = self.beta0[j] + t * self.dbeta[j];
            }
            for i in 0..n {
                xw[i] = self.xw0[i] + t * self.rho[i];
            }
            datafit.fill_residual(y, xw, r);
            p_new = datafit.value(y, xw, r) + lambda * primal::l1_norm(beta);
        }
        if !(p_new <= p_old) {
            // No decrease at the smallest step: numerically at the
            // optimum of this model — restore the epoch-start iterate so
            // the maintained state stays exactly primal-consistent.
            for &j in active {
                beta[j] = self.beta0[j];
            }
            xw.copy_from_slice(&self.xw0);
            datafit.fill_residual(y, xw, r);
        }
    }
}

/// CELER (working sets + dual extrapolation) on an arbitrary GLM
/// datafit, on a caller-provided reusable [`Workspace`]. `strategy`
/// carries the prox-Newton scratch — reuse one across a warm-started
/// path ([`crate::solvers::path::glm_path`] does).
pub fn glm_celer_solve_with<F: Datafit>(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    datafit: &F,
    cfg: &CelerConfig,
    ws: &mut Workspace,
    strategy: &mut ProxNewtonCd,
) -> CelerOutput {
    datafit.validate_targets(y);
    match x {
        DesignMatrix::Dense(d) => {
            celer_solve_datafit(d, y, lambda, beta0, datafit, cfg, ws, strategy)
        }
        DesignMatrix::Sparse(s) => {
            celer_solve_datafit(s, y, lambda, beta0, datafit, cfg, ws, strategy)
        }
        DesignMatrix::Ooc(o) => {
            celer_solve_datafit(o, y, lambda, beta0, datafit, cfg, ws, strategy)
        }
        DesignMatrix::Sharded(sh) => {
            celer_solve_datafit(sh, y, lambda, beta0, datafit, cfg, ws, strategy)
        }
    }
}

/// [`glm_celer_solve_with`] with family selected at runtime (the λ-path
/// / coordinator / CLI entry — one match, then fully monomorphized).
pub fn glm_celer_solve_ws(
    x: &DesignMatrix,
    y: &[f64],
    family: GlmFamily,
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
    ws: &mut Workspace,
    strategy: &mut ProxNewtonCd,
) -> CelerOutput {
    match family {
        GlmFamily::Logistic => {
            glm_celer_solve_with(x, y, lambda, beta0, &Logistic, cfg, ws, strategy)
        }
        GlmFamily::Poisson => {
            glm_celer_solve_with(x, y, lambda, beta0, &Poisson, cfg, ws, strategy)
        }
    }
}

/// Solve the ℓ1-regularized **logistic regression** (sparse logreg)
/// with CELER: labels `y ∈ {−1, +1}`, objective
/// `Σᵢ ln(1 + e^{−yᵢx_iᵀβ}) + λ‖β‖₁`, duality gap certified by the
/// extrapolated dual point.
pub fn sparse_logreg_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
) -> CelerOutput {
    let mut ws = Workspace::new();
    sparse_logreg_solve_ws(x, y, lambda, beta0, cfg, &mut ws)
}

/// Validating front door for [`sparse_logreg_solve`]: non-finite
/// design/label entries, dimension mismatches, labels outside {−1, +1}
/// and a bad λ come back as a typed
/// [`SolveError`](crate::util::error::SolveError) instead of a panic,
/// before the first epoch runs.
pub fn try_sparse_logreg_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
) -> Result<CelerOutput, crate::util::error::SolveError> {
    crate::data::validate::validate_problem(x, y)?;
    crate::data::validate::validate_family_labels(GlmFamily::Logistic, y)?;
    validate_lambda(lambda)?;
    Ok(sparse_logreg_solve(x, y, lambda, beta0, cfg))
}

/// [`sparse_logreg_solve`] on a caller-provided reusable [`Workspace`].
pub fn sparse_logreg_solve_ws(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
    ws: &mut Workspace,
) -> CelerOutput {
    let mut strategy = ProxNewtonCd::default();
    glm_celer_solve_with(x, y, lambda, beta0, &Logistic, cfg, ws, &mut strategy)
}

/// Solve the ℓ1-regularized **Poisson regression** with CELER: counts
/// `y ≥ 0`, objective `Σᵢ (e^{x_iᵀβ} − yᵢx_iᵀβ) + λ‖β‖₁`. No global
/// Lipschitz constant exists, so Gap Safe screening is off; working
/// sets, dual extrapolation and the gap certificate all apply.
pub fn sparse_poisson_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
) -> CelerOutput {
    let mut ws = Workspace::new();
    sparse_poisson_solve_ws(x, y, lambda, beta0, cfg, &mut ws)
}

/// Validating front door for [`sparse_poisson_solve`]: non-finite
/// design/label entries, dimension mismatches, negative counts and a
/// bad λ come back as a typed
/// [`SolveError`](crate::util::error::SolveError) instead of a panic,
/// before the first epoch runs.
pub fn try_sparse_poisson_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
) -> Result<CelerOutput, crate::util::error::SolveError> {
    crate::data::validate::validate_problem(x, y)?;
    crate::data::validate::validate_family_labels(GlmFamily::Poisson, y)?;
    validate_lambda(lambda)?;
    Ok(sparse_poisson_solve(x, y, lambda, beta0, cfg))
}

fn validate_lambda(lambda: f64) -> Result<(), crate::util::error::SolveError> {
    if !lambda.is_finite() || lambda <= 0.0 {
        return Err(crate::util::error::SolveError::BadGrid {
            index: 0,
            value: lambda,
            reason: "lambda must be finite and > 0",
        });
    }
    Ok(())
}

/// [`sparse_poisson_solve`] on a caller-provided reusable [`Workspace`].
pub fn sparse_poisson_solve_ws(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CelerConfig,
    ws: &mut Workspace,
) -> CelerOutput {
    let mut strategy = ProxNewtonCd::default();
    glm_celer_solve_with(x, y, lambda, beta0, &Poisson, cfg, ws, &mut strategy)
}

/// Full-design prox-Newton CD with the engine's gap checks — the GLM
/// analogue of [`crate::solvers::cd::cd_solve`] (no working sets;
/// `cfg.screen` toggles GLM Gap Safe screening; `cfg.extrapolate`
/// toggles θ_accel). This is the unscreened reference the property
/// tests certify the working-set solver against.
pub fn glm_cd_solve<F: Datafit>(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    datafit: &F,
    cfg: &CdConfig,
) -> SolveResult {
    let mut ws = Workspace::new();
    glm_cd_solve_ws(x, y, lambda, beta0, datafit, cfg, &mut ws)
}

/// [`glm_cd_solve`] on a caller-provided reusable [`Workspace`].
pub fn glm_cd_solve_ws<F: Datafit>(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    datafit: &F,
    cfg: &CdConfig,
    ws: &mut Workspace,
) -> SolveResult {
    datafit.validate_targets(y);
    let init = match beta0 {
        Some(b) => Init::Warm(b),
        None => Init::Zeros,
    };
    let mut strategy = ProxNewtonCd::default();
    let outcome = match x {
        DesignMatrix::Dense(d) => engine::solve_datafit(
            d,
            y,
            lambda,
            init,
            None,
            &cfg.engine(),
            ws,
            &mut strategy,
            datafit,
        ),
        DesignMatrix::Sparse(s) => engine::solve_datafit(
            s,
            y,
            lambda,
            init,
            None,
            &cfg.engine(),
            ws,
            &mut strategy,
            datafit,
        ),
        DesignMatrix::Ooc(o) => engine::solve_datafit(
            o,
            y,
            lambda,
            init,
            None,
            &cfg.engine(),
            ws,
            &mut strategy,
            datafit,
        ),
        DesignMatrix::Sharded(sh) => engine::solve_datafit(
            sh,
            y,
            lambda,
            init,
            None,
            &cfg.engine(),
            ws,
            &mut strategy,
            datafit,
        ),
    };
    ws.solve_result(outcome)
}

/// `λ_max` for sparse logistic regression: `‖Xᵀy‖_∞ / 2`.
pub fn logreg_lambda_max<D: DesignOps>(x: &D, y: &[f64]) -> f64 {
    crate::lasso::dual::glm_lambda_max(x, y, &Logistic)
}

/// `λ_max` for sparse Poisson regression: `‖Xᵀ(y − 1)‖_∞`.
pub fn poisson_lambda_max<D: DesignOps>(x: &D, y: &[f64]) -> f64 {
    crate::lasso::dual::glm_lambda_max(x, y, &Poisson)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lasso::dual;

    fn logreg_problem(seed: u64) -> (DesignMatrix, Vec<f64>) {
        let ds = synth::logreg_mini(seed);
        (ds.x, ds.y)
    }

    #[test]
    fn logreg_converges_with_certificate() {
        let (x, y) = logreg_problem(60);
        let lambda = logreg_lambda_max(&x, &y) / 10.0;
        let cfg = CelerConfig { tol: 1e-8, ..Default::default() };
        let out = sparse_logreg_solve(&x, &y, lambda, None, &cfg);
        assert!(out.result.converged, "gap = {}", out.gap());
        assert!(out.gap() <= cfg.tol);
        // recompute the certificate independently
        let datafit = Logistic;
        let n = crate::data::design::DesignOps::n(&x);
        let mut xw = vec![0.0; n];
        let mut r = vec![0.0; n];
        crate::lasso::primal::glm_state(&x, &datafit, &y, &out.result.beta, &mut xw, &mut r);
        let p_val =
            crate::lasso::primal::glm_primal_value(&datafit, &y, &xw, &r, &out.result.beta, lambda);
        let d_val = datafit.dual(&y, &out.result.theta, lambda, 0.0);
        assert!((p_val - d_val - out.gap()).abs() < 1e-9, "gap recomputes");
        assert!(dual::is_feasible(&x, &out.result.theta, 1e-9));
        assert!(out.support_size() > 0, "non-trivial model at λ_max/10");
    }

    #[test]
    fn logreg_matches_full_prox_newton_reference() {
        let (x, y) = logreg_problem(61);
        let lambda = logreg_lambda_max(&x, &y) / 20.0;
        let tol = 1e-9;
        let ws_out =
            sparse_logreg_solve(&x, &y, lambda, None, &CelerConfig { tol, ..Default::default() });
        let full = glm_cd_solve(
            &x,
            &y,
            lambda,
            None,
            &Logistic,
            &CdConfig { tol: tol / 10.0, ..Default::default() },
        );
        assert!(ws_out.result.converged && full.converged);
        let n = crate::data::design::DesignOps::n(&x);
        let (mut xw, mut r) = (vec![0.0; n], vec![0.0; n]);
        let datafit = Logistic;
        crate::lasso::primal::glm_state(&x, &datafit, &y, &ws_out.result.beta, &mut xw, &mut r);
        let p_ws =
            crate::lasso::primal::glm_primal_value(&datafit, &y, &xw, &r, &ws_out.result.beta, lambda);
        crate::lasso::primal::glm_state(&x, &datafit, &y, &full.beta, &mut xw, &mut r);
        let p_full =
            crate::lasso::primal::glm_primal_value(&datafit, &y, &xw, &r, &full.beta, lambda);
        assert!(
            p_ws - p_full <= 2.0 * tol,
            "celer-logreg {p_ws} vs reference {p_full}"
        );
    }

    #[test]
    fn logreg_warm_start_short_circuits() {
        let (x, y) = logreg_problem(62);
        let lambda = logreg_lambda_max(&x, &y) / 8.0;
        let cfg = CelerConfig { tol: 1e-8, ..Default::default() };
        let first = sparse_logreg_solve(&x, &y, lambda, None, &cfg);
        let warm = sparse_logreg_solve(&x, &y, lambda, Some(&first.result.beta), &cfg);
        assert!(warm.result.converged);
        assert!(
            warm.result.epochs <= first.result.epochs,
            "warm {} vs cold {}",
            warm.result.epochs,
            first.result.epochs
        );
    }

    #[test]
    fn logreg_screening_agrees_with_unscreened() {
        let (x, y) = logreg_problem(63);
        let lambda = logreg_lambda_max(&x, &y) / 15.0;
        let base = CdConfig { tol: 1e-9, ..Default::default() };
        let plain = glm_cd_solve(&x, &y, lambda, None, &Logistic, &base);
        let screened = glm_cd_solve(
            &x,
            &y,
            lambda,
            None,
            &Logistic,
            &CdConfig { screen: true, trace: true, ..base },
        );
        assert!(plain.converged && screened.converged);
        let datafit = Logistic;
        let n = crate::data::design::DesignOps::n(&x);
        let (mut xw, mut r) = (vec![0.0; n], vec![0.0; n]);
        crate::lasso::primal::glm_state(&x, &datafit, &y, &plain.beta, &mut xw, &mut r);
        let pa = crate::lasso::primal::glm_primal_value(&datafit, &y, &xw, &r, &plain.beta, lambda);
        crate::lasso::primal::glm_state(&x, &datafit, &y, &screened.beta, &mut xw, &mut r);
        let pb =
            crate::lasso::primal::glm_primal_value(&datafit, &y, &xw, &r, &screened.beta, lambda);
        assert!((pa - pb).abs() < 1e-7, "screening preserves the solution");
        // the ¼-Lipschitz radius actually screens on this problem
        assert!(
            screened.trace.last().unwrap().n_screened > 0,
            "logistic Gap Safe screened nothing"
        );
    }

    #[test]
    fn poisson_converges_with_certificate() {
        let ds = synth::poisson_mini(64);
        let lambda = poisson_lambda_max(&ds.x, &ds.y) / 5.0;
        let cfg = CelerConfig { tol: 1e-8, ..Default::default() };
        let out = sparse_poisson_solve(&ds.x, &ds.y, lambda, None, &cfg);
        assert!(out.result.converged, "gap = {}", out.gap());
        let datafit = Poisson;
        let d_val = datafit.dual(&ds.y, &out.result.theta, lambda, 0.0);
        assert!(d_val.is_finite(), "dual point in the conjugate domain");
        assert!(dual::is_feasible(&ds.x, &out.result.theta, 1e-9));
    }

    #[test]
    fn quadratic_prox_newton_matches_plain_cd() {
        // With unit weights the prox-Newton model IS the quadratic
        // problem, so the strategy must land on the same objective as
        // CdStrategy (not bitwise — update order within an epoch differs
        // via the line-search bookkeeping — but both gap-certified).
        let ds = synth::leukemia_mini(65);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
        let tol = 1e-10;
        let pn = glm_cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::datafit::Quadratic,
            &CdConfig { tol, ..Default::default() },
        );
        let cd = crate::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CdConfig { tol, ..Default::default() },
        );
        assert!(pn.converged && cd.converged);
        let pa = crate::lasso::primal::primal(&ds.x, &ds.y, &pn.beta, lambda);
        let pb = crate::lasso::primal::primal(&ds.x, &ds.y, &cd.beta, lambda);
        assert!((pa - pb).abs() <= 2.0 * tol, "{pa} vs {pb}");
    }

    #[test]
    fn quadratic_prox_newton_with_screening_stays_consistent() {
        // Regression: the engine's quadratic screening branch patches r
        // incrementally AND must keep the predictor xw consistent,
        // because ProxNewtonCd rebuilds r from xw at every epoch — a
        // stale xw would silently resurrect screened coefficients.
        let ds = synth::leukemia_mini(67);
        let lambda = dual::lambda_max(&ds.x, &ds.y) / 12.0;
        let tol = 1e-9;
        let plain = glm_cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::datafit::Quadratic,
            &CdConfig { tol, screen: false, ..Default::default() },
        );
        let screened = glm_cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &crate::datafit::Quadratic,
            &CdConfig { tol, screen: true, trace: true, ..Default::default() },
        );
        assert!(plain.converged && screened.converged);
        assert!(
            screened.trace.last().unwrap().n_screened > 0,
            "screening must actually fire for this regression test"
        );
        let pa = crate::lasso::primal::primal(&ds.x, &ds.y, &plain.beta, lambda);
        let pb = crate::lasso::primal::primal(&ds.x, &ds.y, &screened.beta, lambda);
        assert!((pa - pb).abs() <= 2.0 * tol, "{pa} vs {pb}");
        // the reported residual must be the true residual of the
        // reported beta (state consistency)
        let mut expect = vec![0.0; ds.x.n()];
        crate::lasso::primal::residual(&ds.x, &ds.y, &screened.beta, &mut expect);
        for i in 0..expect.len() {
            assert!((screened.r[i] - expect[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    #[should_panic(expected = "labels")]
    fn logreg_rejects_continuous_targets() {
        let ds = synth::leukemia_mini(66);
        let _ = sparse_logreg_solve(
            &ds.x,
            &ds.y,
            1.0,
            None,
            &CelerConfig::default(),
        );
    }
}
