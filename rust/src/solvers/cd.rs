//! Cyclic coordinate descent with dual extrapolation (Algorithm 1),
//! optionally combined with dynamic Gap Safe screening (§3).
//!
//! This is simultaneously:
//! - the scikit-learn-style baseline (`extrapolate = false, screen = false`),
//! - the "Gap Safe + θ_res / θ_accel" solvers of Figure 3,
//! - and CELER's inner solver (invoked on a working-set subproblem).
//!
//! The epoch/gap-check loop itself lives in [`crate::solvers::engine`];
//! this file only maps [`CdConfig`] onto it.

use crate::data::design::DesignOps;
use crate::data::{validate, DesignMatrix};
use crate::solvers::engine::{self, CdStrategy, EngineConfig, Init, StopRule, Workspace};
use crate::solvers::{Precision, SolveResult};
use crate::util::error::SolveError;
use crate::util::fault::FaultPlan;

/// Configuration for [`cd_solve`].
#[derive(Debug, Clone)]
pub struct CdConfig {
    /// Duality-gap tolerance ε.
    pub tol: f64,
    /// Maximum CD epochs.
    pub max_epochs: usize,
    /// Gap/dual evaluation frequency `f` in epochs (paper default: 10).
    pub gap_freq: usize,
    /// Extrapolation depth K (paper default: 5).
    pub k: usize,
    /// Compute θ_accel (Definition 1). When false only θ_res is used.
    pub extrapolate: bool,
    /// Keep the best dual point across checks (Eq. 13). Fig. 2 disables
    /// this to expose the raw behaviour of each dual point.
    pub best_dual: bool,
    /// Dynamic Gap Safe screening.
    pub screen: bool,
    /// Record a [`crate::solvers::GapCheck`] per dual evaluation.
    pub trace: bool,
    /// Arithmetic precision of the CD epochs. [`Precision::F32`] runs
    /// f32 sweeps with f64 certification at every gap check (see
    /// [`crate::solvers::sweep32`]); gaps and screening stay exact f64
    /// either way.
    pub precision: Precision,
    /// Wall-clock budget in seconds (`None` = unlimited). On expiry the
    /// solve returns its partial-but-certified state with
    /// `SolveOutcome::BudgetExhausted`.
    pub max_seconds: Option<f64>,
    /// Fault-injection plan (inert by default; see [`crate::util::fault`]).
    pub faults: FaultPlan,
}

impl Default for CdConfig {
    fn default() -> Self {
        CdConfig {
            tol: 1e-6,
            max_epochs: 50_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
            best_dual: true,
            screen: false,
            trace: false,
            precision: Precision::F64,
            max_seconds: None,
            faults: FaultPlan::none(),
        }
    }
}

impl CdConfig {
    /// scikit-learn-style vanilla CD: θ_res only, no screening.
    pub fn vanilla() -> Self {
        CdConfig { extrapolate: false, ..Default::default() }
    }

    /// The equivalent engine configuration.
    pub(crate) fn engine(&self) -> EngineConfig {
        EngineConfig {
            tol: self.tol,
            max_epochs: self.max_epochs,
            gap_freq: self.gap_freq,
            k: self.k,
            extrapolate: self.extrapolate,
            best_dual: self.best_dual,
            screen: self.screen,
            trace: self.trace,
            stop: StopRule::DualityGap,
            max_seconds: self.max_seconds,
            faults: self.faults.clone(),
        }
    }
}

/// Solve the Lasso by cyclic CD. `beta0` warm-starts the iterate.
pub fn cd_solve<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CdConfig,
) -> SolveResult {
    let mut ws = Workspace::new();
    cd_solve_ws(x, y, lambda, beta0, cfg, &mut ws)
}

/// [`cd_solve`] on a caller-provided [`Workspace`] — reusing one
/// workspace across a warm-started λ path makes every solve after the
/// first allocation-free.
pub fn cd_solve_ws<D: DesignOps>(
    x: &D,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CdConfig,
    ws: &mut Workspace,
) -> SolveResult {
    let init = match beta0 {
        Some(b) => Init::Warm(b),
        None => Init::Zeros,
    };
    let outcome = match cfg.precision {
        Precision::F64 => {
            engine::solve(x, y, lambda, init, None, &cfg.engine(), ws, &mut CdStrategy)
        }
        Precision::F32 => {
            let mut strat = crate::solvers::sweep32::F32CdStrategy::new(x);
            engine::solve(x, y, lambda, init, None, &cfg.engine(), ws, &mut strat)
        }
    };
    ws.solve_result(outcome)
}

/// Validating [`cd_solve`]: rejects non-finite design/label entries,
/// dimension mismatches, and a bad λ **before the first epoch** with a
/// typed [`SolveError`]. On clean inputs it is the plain `cd_solve`,
/// bit for bit.
pub fn try_cd_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    beta0: Option<&[f64]>,
    cfg: &CdConfig,
) -> Result<SolveResult, SolveError> {
    validate::validate_problem(x, y)?;
    if !lambda.is_finite() || lambda <= 0.0 {
        return Err(SolveError::BadGrid {
            index: 0,
            value: lambda,
            reason: "lambda must be finite and > 0",
        });
    }
    Ok(cd_solve(x, y, lambda, beta0, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::data::design::DesignMatrix;
    use crate::data::design::DesignOps;
    use crate::data::synth;
    use crate::lasso::dual as d;
    use crate::lasso::kkt;

    #[test]
    fn orthogonal_design_closed_form() {
        // Unit-norm orthogonal columns: β̂_j = ST(x_jᵀy, λ).
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.4];
        let out = cd_solve(&x, &y, 1.0, None, &CdConfig { tol: 1e-12, ..Default::default() });
        assert!((out.beta[0] - 2.0).abs() < 1e-10);
        assert_eq!(out.beta[1], 0.0);
        assert!(out.converged);
    }

    #[test]
    fn kkt_satisfied_at_solution() {
        let ds = synth::leukemia_mini(1);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 10.0;
        let out = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol: 1e-10, ..Default::default() });
        assert!(out.converged, "gap={}", out.gap);
        let viol = kkt::max_violation(&ds.x, &out.r, &out.beta, lambda);
        assert!(viol < 1e-4, "max KKT violation {viol}");
    }

    #[test]
    fn gap_upper_bounds_suboptimality() {
        let ds = synth::leukemia_mini(2);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 5.0;
        // High-precision reference
        let reference = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol: 1e-13, ..Default::default() });
        let p_star = crate::lasso::primal::primal(&ds.x, &ds.y, &reference.beta, lambda);
        // Loose run with trace
        let out = cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CdConfig { tol: 1e-4, trace: true, ..Default::default() },
        );
        for chk in &out.trace {
            assert!(
                chk.gap >= chk.primal - p_star - 1e-12,
                "gap {} must dominate suboptimality {}",
                chk.gap,
                chk.primal - p_star
            );
        }
    }

    #[test]
    fn extrapolation_tightens_gap() {
        // On a correlated dense problem the extrapolated gap at a given
        // epoch budget should be no worse (usually much better) than the
        // plain residual gap.
        let ds = synth::leukemia_mini(3);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 20.0;
        let budget = 300;
        let base = CdConfig {
            tol: 1e-14,
            max_epochs: budget,
            trace: true,
            best_dual: false,
            screen: false,
            ..Default::default()
        };
        let with = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { extrapolate: true, ..base.clone() });
        // Somewhere along the run θ_accel must strictly beat θ_res (the
        // Fig. 2 effect); pointwise domination at every check is not
        // guaranteed (the paper's curves are bumpy too).
        let mut produced = 0;
        let mut wins = 0;
        for chk in &with.trace {
            if let Some(da) = chk.dual_accel {
                produced += 1;
                if da > chk.dual_res {
                    wins += 1;
                }
            }
        }
        assert!(produced > 0, "extrapolation never produced a point in {budget} epochs");
        assert!(wins > 0, "θ_accel never beat θ_res across {produced} checks");
    }

    #[test]
    fn screening_does_not_change_solution() {
        let ds = synth::leukemia_mini(4);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 10.0;
        let cfg_plain = CdConfig { tol: 1e-10, screen: false, ..Default::default() };
        let cfg_screen = CdConfig { tol: 1e-10, screen: true, trace: true, ..Default::default() };
        let a = cd_solve(&ds.x, &ds.y, lambda, None, &cfg_plain);
        let b = cd_solve(&ds.x, &ds.y, lambda, None, &cfg_screen);
        let pa = crate::lasso::primal::primal(&ds.x, &ds.y, &a.beta, lambda);
        let pb = crate::lasso::primal::primal(&ds.x, &ds.y, &b.beta, lambda);
        assert!((pa - pb).abs() < 1e-8, "objectives must agree: {pa} vs {pb}");
        // screening must have actually screened something on this problem
        assert!(b.trace.last().unwrap().n_screened > 0);
    }

    #[test]
    fn warm_start_reduces_epochs() {
        let ds = synth::leukemia_mini(5);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 8.0;
        let cfg = CdConfig { tol: 1e-8, ..Default::default() };
        let cold = cd_solve(&ds.x, &ds.y, lambda, None, &cfg);
        let warm = cd_solve(&ds.x, &ds.y, lambda, Some(&cold.beta), &cfg);
        assert!(warm.epochs <= cold.epochs);
        // A fresh run needs K+1 gap checks before θ_accel exists, so the
        // warm restart may still spend a few extrapolation warmup rounds;
        // it must nonetheless finish within that warmup budget.
        assert!(
            warm.epochs <= (cfg.k + 2) * cfg.gap_freq,
            "warm start from optimum converges within extrapolation warmup: {} epochs",
            warm.epochs
        );
    }

    #[test]
    fn lambda_above_max_gives_zero() {
        let ds = synth::leukemia_mini(6);
        let lmax = d::lambda_max(&ds.x, &ds.y);
        let out = cd_solve(&ds.x, &ds.y, lmax * 1.01, None, &CdConfig::default());
        assert_eq!(out.support_size(), 0);
    }

    #[test]
    fn sparse_and_dense_agree() {
        let ds = synth::leukemia_mini(7);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 6.0;
        let dense_out = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol: 1e-11, ..Default::default() });
        // densify -> sparsify and resolve
        let (n, p) = (ds.x.n(), ds.x.p());
        let mut buf = Vec::new();
        ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut buf);
        let xs = DesignMatrix::Sparse(crate::data::csc::CscMatrix::from_dense(n, p, &buf));
        let sparse_out = cd_solve(&xs, &ds.y, lambda, None, &CdConfig { tol: 1e-11, ..Default::default() });
        for j in 0..p {
            assert!(
                (dense_out.beta[j] - sparse_out.beta[j]).abs() < 1e-6,
                "j={j}: {} vs {}",
                dense_out.beta[j],
                sparse_out.beta[j]
            );
        }
    }

    #[test]
    fn workspace_variant_matches_one_shot() {
        let ds = synth::leukemia_mini(8);
        let lambda = d::lambda_max(&ds.x, &ds.y) / 7.0;
        let cfg = CdConfig { tol: 1e-9, ..Default::default() };
        let one_shot = cd_solve(&ds.x, &ds.y, lambda, None, &cfg);
        let mut ws = crate::solvers::engine::Workspace::new();
        // dirty the workspace first, then reuse it
        let _ = cd_solve_ws(&ds.x, &ds.y, lambda * 2.0, None, &cfg, &mut ws);
        let reused = cd_solve_ws(&ds.x, &ds.y, lambda, None, &cfg, &mut ws);
        assert_eq!(one_shot.beta, reused.beta);
        assert_eq!(one_shot.epochs, reused.epochs);
        assert_eq!(one_shot.gap, reused.gap);
    }
}
