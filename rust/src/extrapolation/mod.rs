//! Dual extrapolation (paper §2.2, Definition 1).
//!
//! Maintains the last K+1 residuals `r^{t-K}, …, r^t` (sampled every `f`
//! epochs by the solvers) and produces the extrapolated residual
//!
//! ```text
//! r_accel = Σ_{k=1}^{K} c_k r^{t+1-k},   c = z / (zᵀ1),
//! (UᵀU) z = 1_K,   U = [r^{t+1-K}−r^{t-K}, …, r^t−r^{t-1}]
//! ```
//!
//! Ill-conditioning policy (paper §5): when the K×K system is numerically
//! singular we do NOT Tikhonov-regularize — we simply report `None` and the
//! caller falls back to `θ_res` for this round.

use std::collections::VecDeque;

/// Default extrapolation depth (paper: K = 5).
pub const DEFAULT_K: usize = 5;

/// Relative pivot tolerance declaring `UᵀU` singular.
const SINGULAR_TOL: f64 = 1e-12;

/// Ring buffer of residuals with extrapolation.
#[derive(Debug, Clone)]
pub struct ResidualBuffer {
    k: usize,
    buf: VecDeque<Vec<f64>>,
    /// Retired slots kept for reuse so `clear`/`reset` do not discard the
    /// ring's allocations (one warm-started λ path reuses one buffer).
    spare: Vec<Vec<f64>>,
    /// Count of extrapolation attempts that hit the singular fallback.
    pub singular_fallbacks: usize,
    /// Count of successful extrapolations.
    pub successes: usize,
}

impl ResidualBuffer {
    /// New buffer extrapolating from K residuals (stores K+1).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "extrapolation depth K must be >= 1");
        ResidualBuffer {
            k,
            buf: VecDeque::with_capacity(k + 2),
            spare: Vec::new(),
            singular_fallbacks: 0,
            successes: 0,
        }
    }

    /// Extrapolation depth K.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of stored residuals (≤ K+1).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Record the current residual (O(n) copy). Once the ring is full the
    /// evicted slot's allocation is reused, so steady-state pushes are
    /// allocation-free.
    pub fn push(&mut self, r: &[f64]) {
        let mut slot = if self.buf.len() == self.k + 1 {
            self.buf.pop_front().expect("ring is full")
        } else if let Some(s) = self.spare.pop() {
            s
        } else {
            Vec::new()
        };
        slot.clear();
        slot.extend_from_slice(r);
        self.buf.push_back(slot);
    }

    /// Drop all stored residuals (e.g. when the design matrix of the
    /// subproblem changes between CELER outer iterations). The slots'
    /// allocations are retained for reuse.
    pub fn clear(&mut self) {
        self.spare.extend(self.buf.drain(..));
    }

    /// Reset to a fresh buffer of depth `k`, zeroing the fallback/success
    /// counters. Used by the solver engine to reuse one buffer across
    /// solves (warm-started λ paths) without reallocating the ring.
    pub fn reset(&mut self, k: usize) {
        assert!(k >= 1, "extrapolation depth K must be >= 1");
        self.k = k;
        self.clear();
        self.singular_fallbacks = 0;
        self.successes = 0;
    }

    /// Compute the extrapolated residual, or `None` when fewer than K+1
    /// residuals are stored or the system is singular / degenerate.
    pub fn extrapolate(&mut self) -> Option<Vec<f64>> {
        if self.buf.len() < self.k + 1 {
            return None;
        }
        let k = self.k;
        let n = self.buf[0].len();
        // U columns: d_i = r_{i+1} − r_i (i = 0..K), oldest diff first.
        let mut diffs: Vec<Vec<f64>> = Vec::with_capacity(k);
        for i in 0..k {
            let (a, b) = (&self.buf[i], &self.buf[i + 1]);
            diffs.push((0..n).map(|t| b[t] - a[t]).collect());
        }
        let cols: Vec<&[f64]> = diffs.iter().map(|d| d.as_slice()).collect();
        let g = crate::util::linalg::gram(&cols);
        let ones = vec![1.0; k];
        // Fast path: the paper's formula c = z/(zᵀ1), (UᵀU)z = 1. When the
        // Gram matrix is singular (converged or collinear trajectories) we
        // solve the underlying constrained least-squares problem on the
        // non-null eigenspace instead; if even that degenerates we report
        // None and the caller falls back to θ_res (paper §5).
        let c = match crate::util::linalg::solve(&g, &ones, k, SINGULAR_TOL) {
            Some(z) => {
                let zsum: f64 = z.iter().sum();
                if !zsum.is_finite() || zsum.abs() < 1e-300 {
                    None
                } else {
                    Some(z.iter().map(|&v| v / zsum).collect::<Vec<f64>>())
                }
            }
            None => None,
        };
        let c = match c.or_else(|| crate::util::linalg::min_quadratic_on_simplex_affine(&g, k)) {
            Some(c) => c,
            None => {
                self.singular_fallbacks += 1;
                return None;
            }
        };
        // c_i applies to the NEWER residual of diff i: r_{i+1}.
        let mut r_accel = vec![0.0; n];
        for i in 0..k {
            crate::util::linalg::axpy(c[i], &self.buf[i + 1], &mut r_accel);
        }
        if !r_accel.iter().all(|v| v.is_finite()) {
            self.singular_fallbacks += 1;
            return None;
        }
        self.successes += 1;
        Some(r_accel)
    }
}

/// Extrapolate a noiseless VAR sequence `x^{t+1} = A x^t + b` exactly:
/// used in tests; mirrors Scieur et al. (2016, Prop. 2.2).
#[cfg(test)]
fn var_step(a: &[f64], b: &[f64], x: &[f64], n: usize) -> Vec<f64> {
    let mut out = b.to_vec();
    for i in 0..n {
        for j in 0..n {
            out[i] += a[i * n + j] * x[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_k_plus_one() {
        let mut buf = ResidualBuffer::new(3);
        for i in 0..3 {
            buf.push(&[i as f64, 1.0]);
            assert!(buf.extrapolate().is_none());
        }
        buf.push(&[3.0, 1.0]);
        // 4 residuals stored, K=3 -> can try (may still be singular: the
        // sequence is linear so diffs are collinear)
        assert_eq!(buf.len(), 4);
    }

    #[test]
    fn ring_keeps_k_plus_one() {
        let mut buf = ResidualBuffer::new(2);
        for i in 0..10 {
            buf.push(&[i as f64]);
        }
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.buf[2], vec![9.0]);
    }

    #[test]
    fn exact_on_var_process() {
        // x^{t+1} = A x^t + b with spectral radius < 1 converges to the
        // fixed point x* = (I-A)^{-1} b; extrapolation with K = n+1 diffs
        // recovers x* to machine precision (Scieur Prop 2.2: the error
        // polynomial needs degree ≥ the minimal polynomial's, here n).
        let n = 3;
        let a = vec![
            0.5, 0.1, 0.0, //
            0.0, 0.3, 0.2, //
            0.1, 0.0, 0.4,
        ];
        let b = vec![1.0, -0.5, 0.25];
        // fixed point by long iteration
        let mut xstar = vec![0.0; n];
        for _ in 0..2000 {
            xstar = var_step(&a, &b, &xstar, n);
        }
        let k = n + 1;
        let mut buf = ResidualBuffer::new(k);
        let mut x = vec![0.0; n];
        for _ in 0..(k + 1) {
            buf.push(&x);
            x = var_step(&a, &b, &x, n);
        }
        let acc = buf.extrapolate().expect("VAR system extrapolates");
        for i in 0..n {
            assert!(
                (acc[i] - xstar[i]).abs() < 1e-9,
                "i={i}: {} vs {}",
                acc[i],
                xstar[i]
            );
        }
        assert_eq!(buf.successes, 1);
    }

    #[test]
    fn constant_sequence_extrapolates_to_itself() {
        // All diffs zero → G = 0 → uniform weights → the constant back.
        let mut buf = ResidualBuffer::new(2);
        for _ in 0..3 {
            buf.push(&[1.0, 2.0]);
        }
        let acc = buf.extrapolate().expect("degenerate but consistent");
        assert!((acc[0] - 1.0).abs() < 1e-12);
        assert!((acc[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn non_finite_residuals_fall_back() {
        let mut buf = ResidualBuffer::new(2);
        buf.push(&[1.0]);
        buf.push(&[f64::NAN]);
        buf.push(&[2.0]);
        assert!(buf.extrapolate().is_none());
        assert_eq!(buf.singular_fallbacks, 1);
    }

    #[test]
    fn clear_resets() {
        let mut buf = ResidualBuffer::new(2);
        for i in 0..3 {
            buf.push(&[i as f64]);
        }
        buf.clear();
        assert!(buf.is_empty());
        assert!(buf.extrapolate().is_none());
    }

    #[test]
    fn geometric_sequence_extrapolates_to_limit() {
        // Collinear diffs make UᵀU rank-1; the constrained solver still
        // finds the exact limit (0) of the geometric sequence.
        let mut buf = ResidualBuffer::new(2);
        buf.push(&[1.0, 0.0]);
        buf.push(&[0.5, 0.0]);
        buf.push(&[0.25, 0.0]);
        let acc = buf.extrapolate().expect("geometric sequence extrapolates");
        assert!(acc[0].abs() < 1e-10, "{acc:?}");
    }
}
