//! Dual extrapolation (paper §2.2, Definition 1).
//!
//! Maintains the last K+1 residuals `r^{t-K}, …, r^t` (sampled every `f`
//! epochs by the solvers) and produces the extrapolated residual
//!
//! ```text
//! r_accel = Σ_{k=1}^{K} c_k r^{t+1-k},   c = z / (zᵀ1),
//! (UᵀU) z = 1_K,   U = [r^{t+1-K}−r^{t-K}, …, r^t−r^{t-1}]
//! ```
//!
//! Ill-conditioning policy (paper §5): when the K×K system is numerically
//! singular we do NOT Tikhonov-regularize — we simply report `None` and the
//! caller falls back to `θ_res` for this round.

use std::collections::VecDeque;

/// Default extrapolation depth (paper: K = 5).
pub const DEFAULT_K: usize = 5;

/// Relative pivot tolerance declaring `UᵀU` singular.
const SINGULAR_TOL: f64 = 1e-12;

/// Reusable scratch for [`ResidualBuffer::extrapolate_into`]: the K
/// length-n diff vectors, the K×K Gram matrix, and the output residual
/// that `extrapolate()` used to allocate on every call. One scratch per
/// solver lane lives inside
/// [`DualScratch`](crate::solvers::DualScratch), so steady-state
/// extrapolation performs no heap allocation.
#[derive(Debug, Clone, Default)]
pub struct ExtrapScratch {
    /// Diff columns `U = [r^{t+1-K}−r^{t-K}, …]`, each length n.
    diffs: Vec<Vec<f64>>,
    /// Gram matrix `UᵀU` (K×K).
    gram: Vec<f64>,
    /// Right-hand side 1_K.
    ones: Vec<f64>,
    /// Extrapolated residual (valid after a successful
    /// [`ResidualBuffer::extrapolate_into`]).
    pub r_accel: Vec<f64>,
}

/// Ring buffer of residuals with extrapolation.
#[derive(Debug, Clone)]
pub struct ResidualBuffer {
    k: usize,
    buf: VecDeque<Vec<f64>>,
    /// Retired slots kept for reuse so `clear`/`reset` do not discard the
    /// ring's allocations (one warm-started λ path reuses one buffer).
    spare: Vec<Vec<f64>>,
    /// Count of extrapolation attempts that hit the singular fallback.
    pub singular_fallbacks: usize,
    /// Count of successful extrapolations.
    pub successes: usize,
}

impl ResidualBuffer {
    /// New buffer extrapolating from K residuals (stores K+1).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "extrapolation depth K must be >= 1");
        ResidualBuffer {
            k,
            buf: VecDeque::with_capacity(k + 2),
            spare: Vec::new(),
            singular_fallbacks: 0,
            successes: 0,
        }
    }

    /// Extrapolation depth K.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of stored residuals (≤ K+1).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Record the current residual (O(n) copy). Once the ring is full the
    /// evicted slot's allocation is reused, so steady-state pushes are
    /// allocation-free.
    pub fn push(&mut self, r: &[f64]) {
        let mut slot = if self.buf.len() == self.k + 1 {
            self.buf.pop_front().expect("ring is full")
        } else if let Some(s) = self.spare.pop() {
            s
        } else {
            Vec::new()
        };
        slot.clear();
        slot.extend_from_slice(r);
        self.buf.push_back(slot);
    }

    /// Drop all stored residuals (e.g. when the design matrix of the
    /// subproblem changes between CELER outer iterations). The slots'
    /// allocations are retained for reuse.
    pub fn clear(&mut self) {
        self.spare.extend(self.buf.drain(..));
    }

    /// Reset to a fresh buffer of depth `k`, zeroing the fallback/success
    /// counters. Used by the solver engine to reuse one buffer across
    /// solves (warm-started λ paths) without reallocating the ring.
    pub fn reset(&mut self, k: usize) {
        assert!(k >= 1, "extrapolation depth K must be >= 1");
        self.k = k;
        self.clear();
        self.singular_fallbacks = 0;
        self.successes = 0;
    }

    /// Compute the extrapolated residual into a fresh vector, or `None`
    /// when fewer than K+1 residuals are stored or the system is
    /// singular / degenerate. Allocating convenience wrapper around
    /// [`ResidualBuffer::extrapolate_into`] for tests, examples and
    /// one-shot callers; the solver engine uses the scratch variant.
    pub fn extrapolate(&mut self) -> Option<Vec<f64>> {
        let mut scratch = ExtrapScratch::default();
        if self.extrapolate_into(&mut scratch) {
            Some(std::mem::take(&mut scratch.r_accel))
        } else {
            None
        }
    }

    /// Compute the extrapolated residual into `scratch.r_accel`,
    /// returning whether it succeeded. All O(K·n) temporaries (the K diff
    /// vectors, the Gram matrix, the output) live in `scratch`, so a call
    /// is allocation-free once the scratch is warm — this is what lets
    /// one [`ExtrapScratch`] per batch lane serve an entire λ grid.
    pub fn extrapolate_into(&mut self, scratch: &mut ExtrapScratch) -> bool {
        if self.buf.len() < self.k + 1 {
            return false;
        }
        let k = self.k;
        let n = self.buf[0].len();
        // U columns: d_i = r_{i+1} − r_i (i = 0..K), oldest diff first.
        if scratch.diffs.len() < k {
            scratch.diffs.resize_with(k, Vec::new);
        }
        for i in 0..k {
            let (a, b) = (&self.buf[i], &self.buf[i + 1]);
            let d = &mut scratch.diffs[i];
            d.clear();
            d.resize(n, 0.0);
            crate::util::linalg::sub(a, b, d);
        }
        // Gram matrix G = UᵀU, into the reusable K×K buffer.
        scratch.gram.resize(k * k, 0.0);
        for a in 0..k {
            for b in a..k {
                let v = crate::util::linalg::dot(&scratch.diffs[a], &scratch.diffs[b]);
                scratch.gram[a * k + b] = v;
                scratch.gram[b * k + a] = v;
            }
        }
        // Diverging trajectories overflow here first: ‖d‖ ≳ 1e154 squares
        // into ±inf (or NaN) Gram entries, and neither the LU solve nor
        // the simplex fallback is meaningful on those — report failure so
        // the caller falls back to θ_res before corrupted coefficients
        // can blend a "finite but wrong" r_accel.
        if !scratch.gram.iter().all(|v| v.is_finite()) {
            self.singular_fallbacks += 1;
            return false;
        }
        scratch.ones.clear();
        scratch.ones.resize(k, 1.0);
        // Fast path: the paper's formula c = z/(zᵀ1), (UᵀU)z = 1. When the
        // Gram matrix is singular (converged or collinear trajectories) we
        // solve the underlying constrained least-squares problem on the
        // non-null eigenspace instead; if even that degenerates we report
        // failure and the caller falls back to θ_res (paper §5).
        let c = match crate::util::linalg::solve(&scratch.gram, &scratch.ones, k, SINGULAR_TOL) {
            Some(z) => {
                let zsum: f64 = z.iter().sum();
                if !zsum.is_finite() || zsum.abs() < 1e-300 {
                    None
                } else {
                    Some(z.iter().map(|&v| v / zsum).collect::<Vec<f64>>())
                }
            }
            None => None,
        };
        let c = match c
            .or_else(|| crate::util::linalg::min_quadratic_on_simplex_affine(&scratch.gram, k))
        {
            Some(c) => c,
            None => {
                self.singular_fallbacks += 1;
                return false;
            }
        };
        // c_i applies to the NEWER residual of diff i: r_{i+1}.
        scratch.r_accel.clear();
        scratch.r_accel.resize(n, 0.0);
        for i in 0..k {
            crate::util::linalg::axpy(c[i], &self.buf[i + 1], &mut scratch.r_accel);
        }
        if !scratch.r_accel.iter().all(|v| v.is_finite()) {
            self.singular_fallbacks += 1;
            return false;
        }
        self.successes += 1;
        true
    }
}

/// Extrapolate a noiseless VAR sequence `x^{t+1} = A x^t + b` exactly:
/// used in tests; mirrors Scieur et al. (2016, Prop. 2.2).
#[cfg(test)]
fn var_step(a: &[f64], b: &[f64], x: &[f64], n: usize) -> Vec<f64> {
    let mut out = b.to_vec();
    for i in 0..n {
        for j in 0..n {
            out[i] += a[i * n + j] * x[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_k_plus_one() {
        let mut buf = ResidualBuffer::new(3);
        for i in 0..3 {
            buf.push(&[i as f64, 1.0]);
            assert!(buf.extrapolate().is_none());
        }
        buf.push(&[3.0, 1.0]);
        // 4 residuals stored, K=3 -> can try (may still be singular: the
        // sequence is linear so diffs are collinear)
        assert_eq!(buf.len(), 4);
    }

    #[test]
    fn ring_keeps_k_plus_one() {
        let mut buf = ResidualBuffer::new(2);
        for i in 0..10 {
            buf.push(&[i as f64]);
        }
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.buf[2], vec![9.0]);
    }

    #[test]
    fn exact_on_var_process() {
        // x^{t+1} = A x^t + b with spectral radius < 1 converges to the
        // fixed point x* = (I-A)^{-1} b; extrapolation with K = n+1 diffs
        // recovers x* to machine precision (Scieur Prop 2.2: the error
        // polynomial needs degree ≥ the minimal polynomial's, here n).
        let n = 3;
        let a = vec![
            0.5, 0.1, 0.0, //
            0.0, 0.3, 0.2, //
            0.1, 0.0, 0.4,
        ];
        let b = vec![1.0, -0.5, 0.25];
        // fixed point by long iteration
        let mut xstar = vec![0.0; n];
        for _ in 0..2000 {
            xstar = var_step(&a, &b, &xstar, n);
        }
        let k = n + 1;
        let mut buf = ResidualBuffer::new(k);
        let mut x = vec![0.0; n];
        for _ in 0..(k + 1) {
            buf.push(&x);
            x = var_step(&a, &b, &x, n);
        }
        let acc = buf.extrapolate().expect("VAR system extrapolates");
        for i in 0..n {
            assert!(
                (acc[i] - xstar[i]).abs() < 1e-9,
                "i={i}: {} vs {}",
                acc[i],
                xstar[i]
            );
        }
        assert_eq!(buf.successes, 1);
    }

    #[test]
    fn constant_sequence_extrapolates_to_itself() {
        // All diffs zero → G = 0 → uniform weights → the constant back.
        let mut buf = ResidualBuffer::new(2);
        for _ in 0..3 {
            buf.push(&[1.0, 2.0]);
        }
        let acc = buf.extrapolate().expect("degenerate but consistent");
        assert!((acc[0] - 1.0).abs() < 1e-12);
        assert!((acc[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn non_finite_residuals_fall_back() {
        let mut buf = ResidualBuffer::new(2);
        buf.push(&[1.0]);
        buf.push(&[f64::NAN]);
        buf.push(&[2.0]);
        assert!(buf.extrapolate().is_none());
        assert_eq!(buf.singular_fallbacks, 1);
    }

    #[test]
    fn overflowing_colinear_ring_falls_back() {
        // Colinear residuals at ~1e200: every pairwise diff dot product
        // overflows to +inf, so the Gram matrix is non-finite while all
        // stored residuals are still finite. The guard must report
        // failure (θ_res fallback) instead of blending garbage weights.
        let mut buf = ResidualBuffer::new(2);
        buf.push(&[1e200, 2e200]);
        buf.push(&[-1e200, -2e200]);
        buf.push(&[1e200, 2e200]);
        assert!(buf.extrapolate().is_none());
        assert_eq!(buf.singular_fallbacks, 1);
        assert_eq!(buf.successes, 0);
    }

    #[test]
    fn clear_resets() {
        let mut buf = ResidualBuffer::new(2);
        for i in 0..3 {
            buf.push(&[i as f64]);
        }
        buf.clear();
        assert!(buf.is_empty());
        assert!(buf.extrapolate().is_none());
    }

    #[test]
    fn scratch_reuse_matches_allocating_path() {
        // A dirty, differently-sized scratch must give the same result as
        // the allocating wrapper (the batch lanes reuse one scratch per
        // lane across many λ's and problem sizes).
        let n = 3;
        let a = vec![
            0.5, 0.1, 0.0, //
            0.0, 0.3, 0.2, //
            0.1, 0.0, 0.4,
        ];
        let b = vec![1.0, -0.5, 0.25];
        let k = n + 1;
        let mut scratch = ExtrapScratch::default();
        // dirty the scratch with an unrelated, larger problem first
        {
            let mut buf = ResidualBuffer::new(k + 2);
            let mut x = vec![0.0; 8];
            for step in 0..(k + 4) {
                buf.push(&x);
                for (i, v) in x.iter_mut().enumerate() {
                    *v = 0.9 * *v + (i + step) as f64;
                }
            }
            let _ = buf.extrapolate_into(&mut scratch);
        }
        let mut buf_a = ResidualBuffer::new(k);
        let mut buf_b = ResidualBuffer::new(k);
        let mut x = vec![0.0; n];
        for _ in 0..(k + 1) {
            buf_a.push(&x);
            buf_b.push(&x);
            x = var_step(&a, &b, &x, n);
        }
        let fresh = buf_a.extrapolate().expect("VAR system extrapolates");
        assert!(buf_b.extrapolate_into(&mut scratch));
        assert_eq!(scratch.r_accel, fresh);
    }

    #[test]
    fn geometric_sequence_extrapolates_to_limit() {
        // Collinear diffs make UᵀU rank-1; the constrained solver still
        // finds the exact limit (0) of the geometric sequence.
        let mut buf = ResidualBuffer::new(2);
        buf.push(&[1.0, 0.0]);
        buf.push(&[0.5, 0.0]);
        buf.push(&[0.25, 0.0]);
        let acc = buf.extrapolate().expect("geometric sequence extrapolates");
        assert!(acc[0].abs() < 1e-10, "{acc:?}");
    }
}
