//! # Celer: a Fast Solver for the Lasso with Dual Extrapolation
//!
//! Production-quality reproduction of Massias, Gramfort & Salmon (ICML
//! 2018) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordination contribution: the CELER
//!   working-set outer loop, Gap Safe screening, dual extrapolation, the
//!   λ-path scheduler with warm starts (sequential or batched multi-λ
//!   lanes, [`solvers::batch`]), plus every baseline the paper compares
//!   against (vanilla CD, ISTA/FISTA, Blitz, GLMNET-style, Dykstra).
//! - **Layer 2/1 (python/, build-time only)** — JAX compute graphs and
//!   Pallas kernels for the inner-solver hot spots, AOT-lowered to HLO
//!   text and executed from Rust through the PJRT C API ([`runtime`]).
//!
//! See `ARCHITECTURE.md` for the data → engine → solver → path layering.
//! The repo-level README below covers building, testing and running the
//! per-figure example drivers.
#![doc = include_str!("../../README.md")]

// Solver kernels naturally thread many slices through one call; capping
// the argument count would force ad-hoc context structs on hot paths.
#![allow(clippy::too_many_arguments)]

pub mod coordinator;
pub mod data;
pub mod datafit;
pub mod extrapolation;
pub mod lasso;
pub mod multitask;
pub mod penalty;
pub mod report;
pub mod runtime;
pub mod screening;
pub mod solvers;
pub mod util;
pub mod ws;
