//! `celer` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   solve            solve one Lasso instance on a named dataset
//!   path             run a λ-path with one or more solvers (parallel cells)
//!   datasets         list the built-in synthetic datasets
//!   artifacts-check  validate the AOT artifact manifest + compile all HLO
//!   gen-data         export a synthetic dataset in svmlight format
//!   convert          build an on-disk column store from svmlight or a dataset
//!
//! Arguments are `--key value` pairs (offline build: no clap; parser in
//! `cli` below).

use celer::coordinator::{self, PathJob};
use celer::data::design::DesignOps;
use celer::lasso::dual;
use celer::report::{fmt_sci, fmt_secs, Table};
use celer::runtime::{engine_cd_solve, XlaEngine};
use celer::solvers::celer::{celer_solve_on, CelerConfig};

mod cli {
    use std::collections::BTreeMap;

    /// Parsed command line: subcommand + `--key value` flags.
    pub struct Args {
        pub command: String,
        pub flags: BTreeMap<String, String>,
    }

    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let command = argv.first().cloned().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let key = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got {:?}", argv[i]))?;
            let val = argv
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Args { command, flags })
    }

    impl Args {
        pub fn get(&self, key: &str) -> Option<&str> {
            self.flags.get(key).map(|s| s.as_str())
        }

        pub fn get_or(&self, key: &str, default: &str) -> String {
            self.get(key).unwrap_or(default).to_string()
        }

        pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
            match self.get(key) {
                None => Ok(default),
                Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
            }
        }

        pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
            match self.get(key) {
                None => Ok(default),
                Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
            }
        }
    }
}

const HELP: &str = "\
celer — Lasso with dual extrapolation (ICML 2018 reproduction)

USAGE: celer <command> [--flag value]...

COMMANDS:
  solve            --dataset <name> [--seed 0] [--lambda-ratio 0.05]
                   [--tol 1e-6] [--solver celer-prune] [--engine native|xla]
  path             --dataset <name> | --store <a.cstore>[,<b.cstore>,...]
                   [--num-lambdas 100] [--inv-ratio 100]
                   [--tol 1e-6] [--solvers celer-prune,blitz] [--workers 2]
                   [--max-seconds <budget>] (partial-but-certified prefix)
                   (--store streams the design out-of-core from disk;
                    a comma-separated list opens a sharded store, one
                    prefetch stream per shard, and prints per-shard +
                    combined io counters after the run)
  datasets         list built-in datasets
  artifacts-check  [--dir artifacts] validate + compile every HLO artifact
  gen-data         --dataset <name> --out <file.svm> [--seed 0]
  convert          --in <file.svm> --out <file.cstore> [--min-features 0]
                   or --dataset <name> --out <file.cstore> [--seed 0]
                   [--shards N] splits columns into N standalone stores
                   ({out}.s0 .. {out}.s{N-1}) for `path --store a,b,...`
  help             this message

SOLVERS: celer-prune celer-safe blitz glmnet cd-vanilla gapsafe-cd-res
         gapsafe-cd-accel cd-batched (batched multi-λ lanes; path only)
         celer-mt (Multi-Task CELER on the block engine; q = 1 on grids)
         celer-logreg (sparse logistic regression on the GLM engine;
                       grid targets are binarized by sign)
         celer-enet (elastic net, α = 0.5, on the penalty-generic engine)
         celer-wlasso (weighted ℓ₁ with column-norm weights)
DATASETS: leukemia-sim leukemia-mini finance-sim finance-mini bctcga-sim toy-2x2
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = cli::parse(argv)?;
    match args.command.as_str() {
        "solve" => cmd_solve(&args),
        "path" => cmd_path(&args),
        "datasets" => cmd_datasets(),
        "artifacts-check" => cmd_artifacts_check(&args),
        "gen-data" => cmd_gen_data(&args),
        "convert" => cmd_convert(&args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

fn cmd_solve(args: &cli::Args) -> anyhow::Result<()> {
    let name = args.get_or("dataset", "leukemia-sim");
    let seed = args.get_usize("seed", 0)? as u64;
    let ratio = args.get_f64("lambda-ratio", 0.05)?;
    let tol = args.get_f64("tol", 1e-6)?;
    let engine = args.get_or("engine", "native");
    let ds = coordinator::load_dataset(&name, seed)?;
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let lambda = lmax * ratio;
    println!(
        "dataset={name} n={} p={} nnz={} λ_max={:.4e} λ={:.4e}",
        ds.x.n(),
        ds.x.p(),
        ds.x.nnz(),
        lmax,
        lambda
    );
    match engine.as_str() {
        "native" => {
            let solver = args.get_or("solver", "celer-prune");
            let sw = std::time::Instant::now();
            let (gap, support, epochs, converged) = match solver.as_str() {
                "celer-prune" | "celer" => {
                    let out = celer_solve_on(
                        &ds.x,
                        &ds.y,
                        lambda,
                        None,
                        &CelerConfig { tol, ..Default::default() },
                    );
                    (out.gap(), out.support_size(), out.result.epochs, out.result.converged)
                }
                other => {
                    let ps = celer::solvers::path::PathSolver::by_name(other, tol)
                        .ok_or_else(|| anyhow::anyhow!("unknown solver {other}"))?;
                    // celer-logreg solves on sign-binarized labels, whose
                    // λ_max anchor is ‖Xᵀsign(y)‖_∞/2 — scaling the
                    // quadratic λ_max by the ratio instead could put λ
                    // above it and silently return the empty model.
                    let lambda = if matches!(other, "celer-logreg" | "logreg") {
                        let labels = celer::datafit::sign_labels(&ds.y);
                        celer::solvers::glm::logreg_lambda_max(&ds.x, &labels) * ratio
                    } else {
                        lambda
                    };
                    let res = celer::solvers::path::run_path(&ds.x, &ds.y, &[lambda], &ps, false);
                    let step = res
                        .steps
                        .first()
                        .ok_or_else(|| anyhow::anyhow!("solver {other} produced no step"))?;
                    (step.gap, step.support_size, step.epochs, step.converged)
                }
            };
            println!(
                "solver={solver} time={} gap={} |support|={support} epochs={epochs} converged={converged}",
                fmt_secs(sw.elapsed().as_secs_f64()),
                fmt_sci(gap),
            );
        }
        "xla" => {
            // AOT path: dense gather + engine-driven Algorithm 1.
            let dir = celer::runtime::default_artifacts_dir();
            let mut eng = XlaEngine::load(&dir)?;
            let (n, p) = (ds.x.n(), ds.x.p());
            let mut x_cm = Vec::new();
            ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut x_cm);
            let sw = std::time::Instant::now();
            let out = engine_cd_solve(&mut eng, &x_cm, n, p, &ds.y, lambda, tol, 2000, 5)?;
            println!(
                "engine=xla time={} gap={} |support|={} blocks={} converged={}",
                fmt_secs(sw.elapsed().as_secs_f64()),
                fmt_sci(out.gap),
                out.beta.iter().filter(|&&b| b != 0.0).count(),
                out.blocks,
                out.converged
            );
        }
        other => anyhow::bail!("unknown engine {other} (native|xla)"),
    }
    Ok(())
}

fn cmd_path(args: &cli::Args) -> anyhow::Result<()> {
    let name = args.get_or("dataset", "leukemia-sim");
    let seed = args.get_usize("seed", 0)? as u64;
    let num = args.get_usize("num-lambdas", 100)?;
    anyhow::ensure!(num >= 1, "--num-lambdas must be at least 1");
    let inv_ratio = args.get_f64("inv-ratio", 100.0)?;
    let tol = args.get_f64("tol", 1e-6)?;
    anyhow::ensure!(tol.is_finite() && tol > 0.0, "--tol must be finite and > 0");
    let workers = args.get_usize("workers", 2)?;
    let max_seconds = match args.get("max-seconds") {
        None => None,
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("--max-seconds: {e}"))?,
        ),
    };
    let solvers = args.get_or("solvers", "celer-prune,blitz");
    // --store routes the whole path through the out-of-core column
    // store: the f64 design streams from disk in prefetched chunks and
    // never has to be resident. Solutions are bit-identical to the
    // in-memory solve of the same matrix (tests/prop_ooc.rs). A
    // comma-separated list opens a sharded store — one file, chunk
    // cache, and prefetch thread per shard (tests/prop_shard.rs).
    let ds = match args.get("store") {
        Some(spec) => {
            let paths: Vec<std::path::PathBuf> =
                spec.split(',').map(|s| std::path::PathBuf::from(s.trim())).collect();
            let (x, y) = if paths.len() == 1 {
                let (store, y) = celer::data::OocColumnStore::open_dataset(&paths[0])?;
                (celer::data::DesignMatrix::Ooc(store), y)
            } else {
                let (store, y) = celer::data::ShardedStore::open_dataset(&paths)?;
                (celer::data::DesignMatrix::Sharded(store), y)
            };
            let p = x.p();
            celer::data::synth::SynthDataset {
                name: format!("store:{spec}"),
                x,
                y,
                beta_true: vec![0.0; p],
            }
        }
        None => coordinator::load_dataset(&name, seed)?,
    };
    let name = ds.name.clone();
    let grid = coordinator::standard_grid(&ds, inv_ratio, num);
    let jobs: Vec<PathJob> = solvers
        .split(',')
        .map(|s| {
            let solver_name = s.trim().to_string();
            // celer-logreg runs on sign-binarized labels; anchor its grid
            // at the logistic λ_max of those labels (‖Xᵀsign(y)‖_∞/2) —
            // the quadratic anchor can exceed it by orders of magnitude
            // on large-scale targets, making every grid point trivial.
            let grid = if matches!(solver_name.as_str(), "celer-logreg" | "logreg") {
                let labels = celer::datafit::sign_labels(&ds.y);
                celer::solvers::path::lambda_grid(
                    celer::solvers::glm::logreg_lambda_max(&ds.x, &labels),
                    1.0 / inv_ratio,
                    num,
                )
            } else if matches!(solver_name.as_str(), "celer-enet" | "enet") {
                // β = 0 stays optimal until λα reaches ‖Xᵀy‖_∞, so the
                // elastic-net grid anchors at the quadratic λ_max / α.
                let pen = celer::penalty::ElasticNet::new(0.5);
                celer::solvers::path::lambda_grid(
                    celer::lasso::dual::penalty_lambda_max(&ds.x, &ds.y, &pen),
                    1.0 / inv_ratio,
                    num,
                )
            } else if matches!(solver_name.as_str(), "celer-wlasso" | "wlasso") {
                // Anchor at max_j |x_jᵀy| / w_j over the penalized
                // (w > 0) features of the column-norm weights.
                let pen =
                    celer::penalty::WeightedL1::new(celer::penalty::scale_weights(&ds.x));
                celer::solvers::path::lambda_grid(
                    celer::lasso::dual::penalty_lambda_max(&ds.x, &ds.y, &pen),
                    1.0 / inv_ratio,
                    num,
                )
            } else {
                grid.clone()
            };
            PathJob { solver_name, tol, grid, store_betas: false }
        })
        .collect();
    println!(
        "dataset={name} n={} p={} grid={} λ ∈ [{:.3e}, {:.3e}] ε={tol:.0e}",
        ds.x.n(),
        ds.x.p(),
        num,
        grid[num - 1],
        grid[0]
    );
    let results = match max_seconds {
        None => coordinator::run_path_jobs(&ds, jobs, workers)?,
        // With a budget, route through the guardrailed API: typed
        // validation up front, per-job quarantine, and a partial-but-
        // certified grid prefix when the clock runs out.
        Some(limit) => coordinator::run_path_jobs_robust(
            &ds,
            jobs,
            workers,
            &celer::coordinator::scheduler::RobustPolicy::default(),
            Some(limit),
        )?
        .into_iter()
        .collect::<Result<Vec<_>, _>>()?,
    };
    let mut table = Table::new(
        "Lasso path",
        &["solver", "time", "epochs", "max gap", "final |S|", "all converged"],
    );
    for r in &results {
        table.row(vec![
            r.solver.clone(),
            fmt_secs(r.total_seconds),
            r.steps.iter().map(|s| s.epochs).sum::<usize>().to_string(),
            fmt_sci(r.steps.iter().map(|s| s.gap).fold(0.0, f64::max)),
            r.steps.last().map(|s| s.support_size).unwrap_or(0).to_string(),
            r.all_converged().to_string(),
        ]);
    }
    print!("{}", table.render());
    // Out-of-core runs report their stream traffic after the solve:
    // synchronous reads (sweep-path misses) plus the prefetch thread's
    // loads / already-cached hits / bytes moved ahead of the sweep.
    let fmt_io = |tag: &str, io: &celer::data::ooc::IoStats| {
        println!(
            "io {tag}: read {:.1} MiB in {} chunk loads ({} sync misses); \
             prefetch {} loads, {} hits, {:.1} MiB",
            io.bytes_read as f64 / (1024.0 * 1024.0),
            io.chunks_loaded,
            io.sync_misses,
            io.prefetch_loads,
            io.prefetch_hits,
            io.bytes_prefetched as f64 / (1024.0 * 1024.0),
        );
    };
    match &ds.x {
        celer::data::DesignMatrix::Ooc(store) => fmt_io("store", &store.io_stats()),
        celer::data::DesignMatrix::Sharded(store) => {
            for (s, io) in store.io_stats_per_shard().iter().enumerate() {
                let (c0, c1) = store.shard_cols(s);
                fmt_io(&format!("shard {s} [cols {c0}..{c1}]"), io);
            }
            fmt_io("combined", &store.io_stats());
        }
        _ => {}
    }
    Ok(())
}

fn cmd_datasets() -> anyhow::Result<()> {
    let mut table = Table::new(
        "built-in datasets (synthetic stand-ins, DESIGN.md §4)",
        &["name", "n", "p", "storage", "stands in for"],
    );
    for (name, paper) in [
        ("leukemia-sim", "leukemia (LIBSVM)"),
        ("leukemia-mini", "test-scale leukemia"),
        ("finance-sim", "Finance/E2006-log1p"),
        ("finance-mini", "test-scale Finance"),
        ("bctcga-sim", "bcTCGA (TCGA)"),
        ("toy-2x2", "Fig. 1 toy"),
    ] {
        let ds = coordinator::load_dataset(name, 0)?;
        table.row(vec![
            name.to_string(),
            ds.x.n().to_string(),
            ds.x.p().to_string(),
            if ds.x.is_sparse() { "sparse CSC" } else { "dense" }.to_string(),
            paper.to_string(),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_artifacts_check(args: &cli::Args) -> anyhow::Result<()> {
    use celer::runtime::Engine as _;
    let dir: std::path::PathBuf = args
        .get("dir")
        .map(Into::into)
        .unwrap_or_else(celer::runtime::default_artifacts_dir);
    let mut eng = XlaEngine::load(&dir)?;
    let specs = eng.registry().artifacts.clone();
    println!("manifest: {} artifacts in {}", specs.len(), dir.display());
    // Smoke-run one inner_solve bucket if present: proves PJRT execution.
    if let Some(spec) = specs.iter().find(|s| s.op == "inner_solve") {
        let (n, w) = (spec.n, spec.w);
        let x_cm = vec![0.0; n * w];
        let y = vec![1.0; n];
        let beta = vec![0.0; w];
        let (b, r) = eng.inner_solve(&x_cm, n, w, &y, &beta, 1.0)?;
        anyhow::ensure!(b.iter().all(|&v| v == 0.0));
        anyhow::ensure!(r == y, "zero design leaves residual = y");
        println!("inner_solve n={n} w={w}: compile+execute OK");
    }
    let mut table = Table::new("artifacts", &["op", "file", "n", "w", "p", "k", "f"]);
    for s in &specs {
        table.row(vec![
            s.op.clone(),
            s.file.clone(),
            s.n.to_string(),
            s.w.to_string(),
            s.p.to_string(),
            s.k.to_string(),
            s.f.to_string(),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_gen_data(args: &cli::Args) -> anyhow::Result<()> {
    let name = args.get_or("dataset", "finance-mini");
    let seed = args.get_usize("seed", 0)? as u64;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out <file.svm> required"))?;
    let ds = coordinator::load_dataset(&name, seed)?;
    let mut f = std::io::BufWriter::new(std::fs::File::create(out)?);
    celer::data::svmlight::write_svmlight(
        &mut f,
        &celer::data::svmlight::Dataset { x: ds.x, y: ds.y },
    )?;
    println!("wrote {name} (seed {seed}) to {out}");
    Ok(())
}

fn cmd_convert(args: &cli::Args) -> anyhow::Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out <file.cstore> required"))?;
    let out_path = std::path::Path::new(out);
    let shards = args.get_usize("shards", 1)?;
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    if shards == 1 {
        let meta = match args.get("in") {
            Some(src) => {
                let min_features = args.get_usize("min-features", 0)?;
                celer::data::ooc::svmlight_to_store(
                    std::path::Path::new(src),
                    out_path,
                    min_features,
                )?
            }
            None => {
                let name = args.get_or("dataset", "finance-mini");
                let seed = args.get_usize("seed", 0)? as u64;
                let ds = coordinator::load_dataset(&name, seed)?;
                celer::data::ooc::write_store(out_path, &ds.x, &ds.y)?
            }
        };
        println!("wrote column store {out}: n={} p={} nnz={}", meta.n, meta.p, meta.nnz);
        return Ok(());
    }

    // Sharded convert: materialize (X, y) once, then write contiguous
    // column ranges as standalone stores ({out}.s0 .. {out}.s{N-1}).
    // Each shard carries the full label vector, so any shard opens on
    // its own and `ShardedStore::open` can cross-check them bitwise.
    let (x, y) = match args.get("in") {
        Some(src) => {
            let min_features = args.get_usize("min-features", 0)?;
            let f = std::fs::File::open(src)
                .map_err(|e| anyhow::anyhow!("cannot open svmlight source {src}: {e}"))?;
            let ds = celer::data::svmlight::parse_svmlight_typed(f, min_features)?;
            (ds.x, ds.y)
        }
        None => {
            let name = args.get_or("dataset", "finance-mini");
            let seed = args.get_usize("seed", 0)? as u64;
            let ds = coordinator::load_dataset(&name, seed)?;
            (ds.x, ds.y)
        }
    };
    // More shards than columns would leave empty stores; clamp.
    let shards = shards.min(x.p().max(1));
    let paths = celer::data::shard::shard_paths(out_path, shards);
    let metas = celer::data::shard::write_sharded_store(&paths, &x, &y)?;
    for (path, meta) in paths.iter().zip(&metas) {
        println!(
            "wrote shard {}: n={} cols={} nnz={}",
            path.display(),
            meta.n,
            meta.p,
            meta.nnz
        );
    }
    println!(
        "sharded store complete: {} shards, p={} nnz={} (open with --store {})",
        shards,
        x.p(),
        metas.iter().map(|m| m.nnz).sum::<usize>(),
        paths.iter().map(|p| p.display().to_string()).collect::<Vec<_>>().join(",")
    );
    Ok(())
}
