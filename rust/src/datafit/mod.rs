//! Datafit abstraction: the per-observation loss of a sparse GLM.
//!
//! The Celer follow-up *Dual Extrapolation for Sparse Generalized Linear
//! Models* (Massias, Vaiter, Gramfort & Salmon, 2019) shows the whole
//! working-set + extrapolated-dual machinery of this crate applies to any
//! problem of the form
//!
//! ```text
//! min_β  P(β) = Σᵢ fᵢ(x_iᵀβ) + λ‖β‖₁
//! ```
//!
//! where every `fᵢ` is convex with an `L`-Lipschitz derivative. The dual
//! is `max_{‖Xᵀθ‖_∞ ≤ 1} D(θ) = −Σᵢ fᵢ*(−λθᵢ)`, the optimality link is
//! `θ̂ = −∇F(Xβ̂)/λ`, and the **generalized residual**
//!
//! ```text
//! rᵢ = −fᵢ'(x_iᵀβ)        (quadratic: rᵢ = yᵢ − x_iᵀβ)
//! ```
//!
//! plays exactly the role the plain residual plays for the Lasso: the
//! Eq. 4 rescale `θ = r / max(λ, ‖Xᵀr‖_∞)` yields a feasible dual point,
//! the extrapolation ring of [`crate::extrapolation`] runs on the
//! residual sequence unchanged, and the Gap Safe sphere of Ndiaye et al.
//! (*Gap Safe screening rules for sparsity enforcing penalties*) has
//! radius `√(2·L·gap)/λ` (L = 1 recovers the Lasso radius).
//!
//! [`Datafit`] is that abstraction: each implementor supplies the
//! gradient/raw-residual, the primal and conjugate (dual) values, the
//! IRLS curvature weights, the Lipschitz constant feeding the screening
//! radius, the feasible-rescale denominator and the `λ_max` anchor. The
//! solver layers ([`crate::solvers::engine`], [`crate::solvers::celer`],
//! [`crate::solvers::glm`]) are generic over it.
//!
//! **Bit-identity invariant:** [`Quadratic`] reproduces, expression for
//! expression, the arithmetic the pre-datafit engine inlined
//! (`½‖r‖²`, the Eq. 4 denominator, the fused `D(θ_res)` loop of
//! `DualState::update`, `‖y‖²` caching). The quadratic path through the
//! generic engine is therefore bit-identical to the historical
//! `engine::solve` — pinned by `tests/prop_glm.rs`.

use crate::data::design::DesignOps;

/// `x·ln(x)` with the `0·ln(0) = 0` limit (entropy terms of the logistic
/// and Poisson conjugates).
#[inline]
fn xlogx(x: f64) -> f64 {
    if x <= 0.0 {
        0.0
    } else {
        x * x.ln()
    }
}

/// Numerically stable `ln(1 + eᶻ)` (softplus).
#[inline]
fn log1p_exp(z: f64) -> f64 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// Logistic sigmoid `σ(z) = 1/(1 + e⁻ᶻ)`.
#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// A separable GLM datafit `F(u) = Σᵢ fᵢ(uᵢ)` evaluated at `u = Xβ`.
///
/// Implementors are zero-sized marker types; everything is `#[inline]`
/// element-wise arithmetic so the solver loops monomorphize with no
/// dispatch cost. See the module docs for the math and the
/// quadratic bit-identity contract.
pub trait Datafit: Sync {
    /// True only for [`Quadratic`]. Enables the residual-linear fast
    /// paths that are exact for the quadratic datafit only: the engine's
    /// incremental screening fix-up (`r += βⱼxⱼ`) and the `Xβ`-free
    /// bookkeeping of the plain CD strategies.
    const IS_QUADRATIC: bool = false;

    /// Display name ("quadratic", "logistic", "poisson").
    fn name(&self) -> &'static str;

    /// Global Lipschitz constant `L` of every `fᵢ'` (quadratic 1,
    /// logistic ¼). `f64::INFINITY` when no global constant exists
    /// (Poisson) — Gap Safe screening is then disabled, everything else
    /// still runs.
    fn lipschitz(&self) -> f64;

    /// Per-solve scalar cached from `y` and handed back to
    /// [`Datafit::dual`] / [`Datafit::dual_scaled`] at every gap check.
    /// Quadratic: `‖y‖²`. The entropy-form conjugates need nothing.
    fn conj_cache(&self, y: &[f64]) -> f64 {
        let _ = y;
        0.0
    }

    /// Datafit value `F(Xβ)` (without the λ‖β‖₁ penalty). `xw = Xβ` is
    /// the maintained linear predictor and `r` the maintained
    /// generalized residual; the quadratic fit reads only `r`
    /// (`½‖r‖²`), the GLM fits only `xw`.
    fn value(&self, y: &[f64], xw: &[f64], r: &[f64]) -> f64;

    /// Generalized residual `out_i = −fᵢ'(xwᵢ)`.
    fn fill_residual(&self, y: &[f64], xw: &[f64], out: &mut [f64]);

    /// IRLS curvature weights `out_i = fᵢ''(xwᵢ)` — the per-observation
    /// Hessian of the prox-Newton quadratic model
    /// ([`crate::solvers::glm::ProxNewtonCd`]).
    fn fill_weights(&self, y: &[f64], xw: &[f64], out: &mut [f64]);

    /// Dual objective `D(θ) = −Σᵢ fᵢ*(−λθᵢ)` at an explicit point.
    /// Returns `−∞` when θ leaves the conjugate domain (a rescaled
    /// residual never does; an extrapolated candidate may — the caller's
    /// best-of comparison then discards it).
    fn dual(&self, y: &[f64], theta: &[f64], lambda: f64, cache: f64) -> f64;

    /// `D(r·inv)` without materializing θ — the fused form every gap
    /// check uses on the residual-rescaled point.
    fn dual_scaled(&self, y: &[f64], r: &[f64], inv: f64, lambda: f64, cache: f64) -> f64;

    /// Feasible-rescale denominator of Eq. 4: `θ = r/denom` with
    /// `denom = max(λ, ‖Xᵀr‖_∞)` for every current fit. A hook so a
    /// datafit with extra dual box constraints can tighten it.
    #[inline]
    fn rescale_denom(&self, lambda: f64, xt_r_inf: f64) -> f64 {
        lambda.max(xt_r_inf)
    }

    /// The generalized residual at β = 0 (`−∇F(0)`): returns `y` itself
    /// when that is exact (quadratic), otherwise fills and returns `buf`.
    /// This is the direction the working-set solvers initialize θ from,
    /// and the vector behind `λ_max`.
    fn residual_at_zero<'a>(&self, y: &'a [f64], buf: &'a mut Vec<f64>) -> &'a [f64];

    /// `λ_max = ‖Xᵀ(−∇F(0))‖_∞`, the smallest λ with β̂ = 0.
    /// Quadratic: `‖Xᵀy‖_∞`; logistic: `‖Xᵀy‖_∞/2`; Poisson:
    /// `‖Xᵀ(y−1)‖_∞`.
    fn lambda_max<D: DesignOps>(&self, x: &D, y: &[f64]) -> f64 {
        let mut buf = Vec::new();
        x.xt_abs_max(self.residual_at_zero(y, &mut buf))
    }

    /// Panic with a clear message when `y` is outside the datafit's
    /// target domain (logistic: labels in {−1, +1}; Poisson: y ≥ 0).
    fn validate_targets(&self, y: &[f64]) {
        let _ = y;
    }
}

/// The Lasso datafit `F(Xβ) = ½‖y − Xβ‖²`.
///
/// Every expression below is copied verbatim from the pre-datafit solver
/// paths (see the module-level bit-identity invariant); do not "simplify"
/// them — reassociating a sum changes result bits and breaks the pinned
/// equality tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct Quadratic;

impl Datafit for Quadratic {
    const IS_QUADRATIC: bool = true;

    fn name(&self) -> &'static str {
        "quadratic"
    }

    #[inline]
    fn lipschitz(&self) -> f64 {
        1.0
    }

    #[inline]
    fn conj_cache(&self, y: &[f64]) -> f64 {
        crate::util::linalg::dot(y, y)
    }

    #[inline]
    fn value(&self, _y: &[f64], _xw: &[f64], r: &[f64]) -> f64 {
        0.5 * crate::util::linalg::dot(r, r)
    }

    #[inline]
    fn fill_residual(&self, y: &[f64], xw: &[f64], out: &mut [f64]) {
        for i in 0..y.len() {
            out[i] = y[i] - xw[i];
        }
    }

    #[inline]
    fn fill_weights(&self, _y: &[f64], _xw: &[f64], out: &mut [f64]) {
        out.fill(1.0);
    }

    #[inline]
    fn dual(&self, y: &[f64], theta: &[f64], lambda: f64, cache: f64) -> f64 {
        crate::lasso::dual::dual_objective_cached(y, theta, lambda, cache)
    }

    #[inline]
    fn dual_scaled(&self, y: &[f64], r: &[f64], inv: f64, lambda: f64, cache: f64) -> f64 {
        // D(θ_res) without materializing θ_res: θ = r·inv. Exactly the
        // loop `DualState::update` historically inlined.
        let mut dist_sq = 0.0;
        for i in 0..y.len() {
            let d = r[i] * inv - y[i] / lambda;
            dist_sq += d * d;
        }
        0.5 * cache - 0.5 * lambda * lambda * dist_sq
    }

    #[inline]
    fn residual_at_zero<'a>(&self, y: &'a [f64], _buf: &'a mut Vec<f64>) -> &'a [f64] {
        y
    }
}

/// Logistic-regression datafit `fᵢ(t) = ln(1 + e^{−yᵢt})`, labels
/// `yᵢ ∈ {−1, +1}`.
///
/// Generalized residual `rᵢ = yᵢ·σ(−yᵢ xwᵢ)`, curvature
/// `fᵢ'' = σ(1−σ) ≤ ¼`, conjugate `fᵢ*(−λθᵢ) = s ln s + (1−s)ln(1−s)`
/// with `s = λyᵢθᵢ ∈ [0, 1]`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Logistic;

impl Datafit for Logistic {
    fn name(&self) -> &'static str {
        "logistic"
    }

    #[inline]
    fn lipschitz(&self) -> f64 {
        0.25
    }

    #[inline]
    fn value(&self, y: &[f64], xw: &[f64], _r: &[f64]) -> f64 {
        let mut acc = 0.0;
        for i in 0..y.len() {
            acc += log1p_exp(-y[i] * xw[i]);
        }
        acc
    }

    #[inline]
    fn fill_residual(&self, y: &[f64], xw: &[f64], out: &mut [f64]) {
        for i in 0..y.len() {
            out[i] = y[i] * sigmoid(-y[i] * xw[i]);
        }
    }

    #[inline]
    fn fill_weights(&self, y: &[f64], xw: &[f64], out: &mut [f64]) {
        for i in 0..y.len() {
            let s = sigmoid(-y[i] * xw[i]);
            out[i] = s * (1.0 - s);
        }
    }

    fn dual(&self, y: &[f64], theta: &[f64], lambda: f64, _cache: f64) -> f64 {
        let mut acc = 0.0;
        for i in 0..y.len() {
            let s = lambda * y[i] * theta[i];
            if !(0.0..=1.0).contains(&s) {
                return f64::NEG_INFINITY;
            }
            acc -= xlogx(s) + xlogx(1.0 - s);
        }
        acc
    }

    fn dual_scaled(&self, y: &[f64], r: &[f64], inv: f64, lambda: f64, _cache: f64) -> f64 {
        let mut acc = 0.0;
        for i in 0..y.len() {
            let s = lambda * y[i] * (r[i] * inv);
            if !(0.0..=1.0).contains(&s) {
                return f64::NEG_INFINITY;
            }
            acc -= xlogx(s) + xlogx(1.0 - s);
        }
        acc
    }

    #[inline]
    fn residual_at_zero<'a>(&self, y: &'a [f64], buf: &'a mut Vec<f64>) -> &'a [f64] {
        // σ(0) = ½ ⇒ r(0) = y/2, hence λ_max = ‖Xᵀy‖_∞ / 2.
        buf.clear();
        buf.extend(y.iter().map(|&v| 0.5 * v));
        buf
    }

    fn validate_targets(&self, y: &[f64]) {
        assert!(
            y.iter().all(|&v| v == 1.0 || v == -1.0),
            "logistic datafit requires labels in {{-1, +1}}"
        );
    }
}

/// Poisson-regression datafit `fᵢ(t) = e^t − yᵢt` (log link, counts
/// `yᵢ ≥ 0`; the `ln yᵢ!` constant is dropped — it cancels in the gap).
///
/// Generalized residual `rᵢ = yᵢ − e^{xwᵢ}`, curvature `fᵢ'' = e^{xwᵢ}`
/// (no global Lipschitz constant ⇒ screening is off), conjugate
/// `fᵢ*(−λθᵢ) = s ln s − s` with `s = yᵢ − λθᵢ ≥ 0`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Poisson;

impl Datafit for Poisson {
    fn name(&self) -> &'static str {
        "poisson"
    }

    #[inline]
    fn lipschitz(&self) -> f64 {
        f64::INFINITY
    }

    #[inline]
    fn value(&self, y: &[f64], xw: &[f64], _r: &[f64]) -> f64 {
        let mut acc = 0.0;
        for i in 0..y.len() {
            acc += xw[i].exp() - y[i] * xw[i];
        }
        acc
    }

    #[inline]
    fn fill_residual(&self, y: &[f64], xw: &[f64], out: &mut [f64]) {
        for i in 0..y.len() {
            out[i] = y[i] - xw[i].exp();
        }
    }

    #[inline]
    fn fill_weights(&self, _y: &[f64], xw: &[f64], out: &mut [f64]) {
        for (o, &u) in out.iter_mut().zip(xw.iter()) {
            *o = u.exp();
        }
    }

    fn dual(&self, y: &[f64], theta: &[f64], lambda: f64, _cache: f64) -> f64 {
        let mut acc = 0.0;
        for i in 0..y.len() {
            let s = y[i] - lambda * theta[i];
            if s < 0.0 {
                return f64::NEG_INFINITY;
            }
            acc += s - xlogx(s);
        }
        acc
    }

    fn dual_scaled(&self, y: &[f64], r: &[f64], inv: f64, lambda: f64, _cache: f64) -> f64 {
        let mut acc = 0.0;
        for i in 0..y.len() {
            let s = y[i] - lambda * (r[i] * inv);
            if s < 0.0 {
                return f64::NEG_INFINITY;
            }
            acc += s - xlogx(s);
        }
        acc
    }

    #[inline]
    fn residual_at_zero<'a>(&self, y: &'a [f64], buf: &'a mut Vec<f64>) -> &'a [f64] {
        // e⁰ = 1 ⇒ r(0) = y − 1, hence λ_max = ‖Xᵀ(y − 1)‖_∞.
        buf.clear();
        buf.extend(y.iter().map(|&v| v - 1.0));
        buf
    }

    fn validate_targets(&self, y: &[f64]) {
        assert!(
            y.iter().all(|&v| v >= 0.0 && v.is_finite()),
            "poisson datafit requires non-negative targets"
        );
    }
}

/// ±1 labels by sign (`y ≥ 0 → +1`; identity on vectors that are
/// already ±1 labels) — the canonical binarization the
/// `"celer-logreg"` grid route applies before handing targets to
/// [`Logistic`]. Lives next to the datafit it feeds; the synthetic-data
/// module re-exports it.
pub fn sign_labels(y: &[f64]) -> Vec<f64> {
    y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect()
}

/// Runtime selector for the non-quadratic datafits, used by the λ-path /
/// CLI / coordinator plumbing ([`crate::solvers::path::glm_path`]). The
/// solver cores stay statically generic; this enum is matched once at
/// the public entry, like [`crate::data::design::DesignMatrix`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlmFamily {
    Logistic,
    Poisson,
}

impl GlmFamily {
    pub fn name(&self) -> &'static str {
        match self {
            GlmFamily::Logistic => "logistic",
            GlmFamily::Poisson => "poisson",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_gradient_matches_residual<F: Datafit>(f: &F, y: &[f64], xw: &[f64]) {
        let n = y.len();
        let mut r = vec![0.0; n];
        f.fill_residual(y, xw, &mut r);
        let eps = 1e-6;
        let mut up = xw.to_vec();
        let mut dn = xw.to_vec();
        for i in 0..n {
            up[i] = xw[i] + eps;
            dn[i] = xw[i] - eps;
            // value() must not read r for the GLM fits; pass the true
            // residual of the perturbed point anyway for the quadratic.
            let mut ru = vec![0.0; n];
            let mut rd = vec![0.0; n];
            f.fill_residual(y, &up, &mut ru);
            f.fill_residual(y, &dn, &mut rd);
            let g = (f.value(y, &up, &ru) - f.value(y, &dn, &rd)) / (2.0 * eps);
            assert!(
                (g - (-r[i])).abs() < 1e-5,
                "{} grad i={i}: fd {g} vs -r {}",
                f.name(),
                -r[i]
            );
            up[i] = xw[i];
            dn[i] = xw[i];
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let y_reg = [1.0, -2.0, 0.5, 3.0];
        let y_cls = [1.0, -1.0, 1.0, -1.0];
        let y_cnt = [0.0, 1.0, 3.0, 2.0];
        let xw = [0.3, -0.8, 1.2, -0.1];
        fd_gradient_matches_residual(&Quadratic, &y_reg, &xw);
        fd_gradient_matches_residual(&Logistic, &y_cls, &xw);
        fd_gradient_matches_residual(&Poisson, &y_cnt, &xw);
    }

    fn weights_match_fd<F: Datafit>(f: &F, y: &[f64], xw: &[f64]) {
        let eps = 1e-6;
        let n = y.len();
        let mut w = vec![0.0; n];
        f.fill_weights(y, xw, &mut w);
        for i in 0..n {
            let mut up = xw.to_vec();
            let mut dn = xw.to_vec();
            up[i] += eps;
            dn[i] -= eps;
            let (mut ru, mut rd) = (vec![0.0; n], vec![0.0; n]);
            f.fill_residual(y, &up, &mut ru);
            f.fill_residual(y, &dn, &mut rd);
            // w = f'' = -(dr/du)
            let fd = -(ru[i] - rd[i]) / (2.0 * eps);
            assert!((w[i] - fd).abs() < 1e-5, "{} w i={i}", f.name());
        }
    }

    #[test]
    fn weights_match_fd_of_residual() {
        let y_cls = [1.0, -1.0, 1.0];
        let y_cnt = [2.0, 0.0, 1.0];
        let xw = [0.4, -1.1, 0.0];
        weights_match_fd(&Logistic, &y_cls, &xw);
        weights_match_fd(&Poisson, &y_cnt, &xw);
    }

    #[test]
    fn quadratic_matches_legacy_expressions() {
        let y = [1.0, 2.0, -0.5];
        let xw = [0.2, 1.0, 0.0];
        let mut r = vec![0.0; 3];
        Quadratic.fill_residual(&y, &xw, &mut r);
        for i in 0..3 {
            assert_eq!(r[i].to_bits(), (y[i] - xw[i]).to_bits());
        }
        let v = Quadratic.value(&y, &xw, &r);
        assert_eq!(
            v.to_bits(),
            (0.5 * crate::util::linalg::dot(&r, &r)).to_bits()
        );
        let cache = Quadratic.conj_cache(&y);
        assert_eq!(cache.to_bits(), crate::util::linalg::dot(&y, &y).to_bits());
        let lambda = 0.7;
        let inv = 1.0 / 2.5;
        let theta: Vec<f64> = r.iter().map(|&v| v * inv).collect();
        let a = Quadratic.dual_scaled(&y, &r, inv, lambda, cache);
        let b = crate::lasso::dual::dual_objective_cached(&y, &theta, lambda, cache);
        assert_eq!(a.to_bits(), b.to_bits(), "fused dual equals materialized");
    }

    fn fenchel_young_holds<F: Datafit>(f: &F, y: &[f64], xw: &[f64], lambda: f64) {
        let n = y.len();
        let mut r = vec![0.0; n];
        f.fill_residual(y, xw, &mut r);
        // θ = r/λ is in the conjugate domain by construction
        let theta: Vec<f64> = r.iter().map(|&v| v / lambda).collect();
        let d = f.dual(y, &theta, lambda, f.conj_cache(y));
        let p = f.value(y, xw, &r);
        assert!(d.is_finite(), "{}", f.name());
        // Fenchel–Young: F(u) + F*(−λθ) ≥ ⟨u, −λθ⟩, i.e. with λθ = r:
        // P_datafit − D ≥ −⟨xw, r⟩.
        assert!(
            p - d >= -crate::util::linalg::dot(xw, &r) - 1e-10,
            "{}: P {p} D {d}",
            f.name()
        );
    }

    #[test]
    fn dual_at_link_point_respects_fenchel_young() {
        let y_cls = [1.0, -1.0, 1.0, 1.0];
        let y_cnt = [2.0, 1.0, 0.0, 3.0];
        let xw = [0.1, -0.3, 0.2, 0.4];
        fenchel_young_holds(&Logistic, &y_cls, &xw, 0.9);
        fenchel_young_holds(&Poisson, &y_cnt, &xw, 0.9);
    }

    #[test]
    fn out_of_domain_duals_are_rejected() {
        let y = [1.0, -1.0];
        // λyθ > 1 on the first coordinate
        assert_eq!(
            Logistic.dual(&y, &[2.0, 0.0], 1.0, 0.0),
            f64::NEG_INFINITY
        );
        // y − λθ < 0
        assert_eq!(
            Poisson.dual(&[0.5, 1.0], &[1.0, 0.0], 1.0, 0.0),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn lambda_max_anchors() {
        use crate::data::dense::DenseMatrix;
        let x = DenseMatrix::from_row_major(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let y = [1.0, -1.0, 1.0];
        // quadratic: ‖Xᵀy‖_∞ = max(|1+1|, |-1+1|) = 2
        assert_eq!(Quadratic.lambda_max(&x, &y), 2.0);
        // logistic: half of it
        assert_eq!(Logistic.lambda_max(&x, &y), 1.0);
        // poisson: y−1 = [0,−1,0] ⇒ Xᵀ(y−1) = [0, −1] ⇒ λ_max = 1
        let counts = [1.0, 0.0, 1.0];
        assert_eq!(Poisson.lambda_max(&x, &counts), 1.0);
    }

    #[test]
    #[should_panic(expected = "labels")]
    fn logistic_rejects_non_labels() {
        Logistic.validate_targets(&[1.0, 0.5]);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn poisson_rejects_negative_counts() {
        Poisson.validate_targets(&[1.0, -0.5]);
    }
}
