//! Gap Safe screening rules (paper §3, Eq. 9).
//!
//! A feature j can be *safely* discarded (its optimal coefficient is 0)
//! whenever, for any primal–dual feasible pair (β, θ):
//!
//! ```text
//! |x_jᵀθ| < 1 − ‖x_j‖ · √(2·G(β,θ)/λ²)
//! ```
//!
//! Screening is *dynamic*: applied repeatedly along solver iterations with
//! ever-better (β, θ), discarding more and more features.

use crate::data::design::DesignOps;

/// Gap Safe ball radius `√(2·gap/λ²)`.
#[inline]
pub fn gap_safe_radius(gap: f64, lambda: f64) -> f64 {
    (2.0 * gap.max(0.0)).sqrt() / lambda
}

/// GLM Gap Safe ball radius `√(2·L·gap)/λ` (Ndiaye et al., *Gap Safe
/// screening rules for sparsity enforcing penalties*): when every `fᵢ'`
/// is `L`-Lipschitz, each `fᵢ*` is `(1/L)`-strongly convex, so the dual
/// objective is `(λ²/L)`-strongly concave and the dual optimum lies
/// within this radius of any feasible θ. `L = 1` recovers
/// [`gap_safe_radius`]; `L = ∞` (Poisson — no global constant) yields an
/// infinite radius, i.e. nothing is ever screened.
#[inline]
pub fn gap_safe_radius_glm(gap: f64, lambda: f64, lipschitz: f64) -> f64 {
    if !lipschitz.is_finite() {
        return f64::INFINITY;
    }
    (2.0 * lipschitz * gap.max(0.0)).sqrt() / lambda
}

/// The Gap-Safe importance score `d_j(θ) = (1 − |x_jᵀθ|) / ‖x_j‖`
/// (Eq. 10). Feature j is screenable iff `d_j(θ) > radius`.
#[inline]
pub fn d_score(xj_theta_abs: f64, col_norm: f64) -> f64 {
    if col_norm == 0.0 {
        // Empty column: never correlated with anything; maximally screenable.
        f64::INFINITY
    } else {
        (1.0 - xj_theta_abs) / col_norm
    }
}

/// Fill the Gap-Safe pricing scores `d_j(θ)` (Eq. 10) for all features
/// in one (pooled when large) pass. Shared by the CELER and Blitz
/// working-set builders; `xtheta[j] = x_jᵀθ` and `col_norms[j] = ‖x_j‖`
/// are the caller's cached vectors, so this pass touches no design
/// storage — unit per-item cost.
pub fn fill_d_scores(xtheta: &[f64], col_norms: &[f64], out: &mut [f64]) {
    assert_eq!(xtheta.len(), col_norms.len());
    assert_eq!(out.len(), xtheta.len());
    crate::util::par::par_fill_cost(out, 1, |j| d_score(xtheta[j].abs(), col_norms[j]));
}

/// Penalty-generic [`fill_d_scores`]: each feature's score comes from
/// [`Penalty::d_score`](crate::penalty::Penalty::d_score) (slab width α
/// for the elastic net, per-weight slabs for weighted ℓ₁, group-shared
/// scores for group-ℓ₂). The `P = L1` instantiation is [`fill_d_scores`]
/// expression for expression, so CELER's ℓ₁ pricing bits are unchanged.
pub fn fill_d_scores_penalty<P: crate::penalty::Penalty>(
    xtheta: &[f64],
    col_norms: &[f64],
    lambda: f64,
    penalty: &P,
    out: &mut [f64],
) {
    assert_eq!(xtheta.len(), col_norms.len());
    assert_eq!(out.len(), xtheta.len());
    if P::IS_L1 {
        crate::util::par::par_fill_cost(out, 1, |j| d_score(xtheta[j].abs(), col_norms[j]));
        return;
    }
    crate::util::par::par_fill_cost(out, 1, |j| penalty.d_score(j, lambda, xtheta, col_norms));
}

/// Dynamic screening state over a problem with p features.
#[derive(Debug, Clone, Default)]
pub struct ScreeningState {
    /// Currently active (not screened) feature indices, in increasing order.
    active: Vec<usize>,
    /// Per-feature screened flag.
    screened: Vec<bool>,
}

impl ScreeningState {
    /// All features active.
    pub fn all_active(p: usize) -> Self {
        ScreeningState { active: (0..p).collect(), screened: vec![false; p] }
    }

    /// Re-initialize to all-active over `p` features, reusing capacity
    /// (the solver engine calls this once per solve on a shared workspace).
    pub fn reset_all_active(&mut self, p: usize) {
        self.active.clear();
        self.active.extend(0..p);
        self.screened.clear();
        self.screened.resize(p, false);
    }

    pub fn active(&self) -> &[usize] {
        &self.active
    }

    pub fn is_screened(&self, j: usize) -> bool {
        self.screened[j]
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_screened(&self) -> usize {
        self.screened.len() - self.active.len()
    }

    /// Apply the Gap Safe rule with dual point θ (given via the
    /// correlation vector `xtheta[j] = x_jᵀθ` over ALL features) and gap.
    ///
    /// Screened features with non-zero current coefficients are zeroed and
    /// the residual is updated accordingly (`r += β_j x_j`), which is safe
    /// because the rule guarantees β̂_j = 0.
    ///
    /// Returns the number of features screened this call.
    pub fn screen<D: DesignOps>(
        &mut self,
        x: &D,
        xtheta: &[f64],
        col_norms: &[f64],
        gap: f64,
        lambda: f64,
        beta: &mut [f64],
        r: &mut [f64],
    ) -> usize {
        let radius = gap_safe_radius(gap, lambda);
        // Numerical-safety margin: at (near-)optimal pairs the gap can
        // round to exactly 0 while support features have |x_jᵀθ| a few
        // ulps below 1 (d_j ≈ 1e-15 > radius = 0) — without a margin the
        // rule would wrongly discard the entire support. 1e-12 on the
        // d scale is orders of magnitude below any real screening margin.
        let threshold = radius + 1e-12;
        let before = self.active.len();
        let screened = &mut self.screened;
        self.active.retain(|&j| {
            let keep = d_score(xtheta[j].abs(), col_norms[j]) <= threshold;
            if !keep {
                screened[j] = true;
                if beta[j] != 0.0 {
                    // r = y − Xβ; removing β_j adds β_j·x_j back.
                    x.col_axpy(j, beta[j], r);
                    beta[j] = 0.0;
                }
            }
            keep
        });
        before - self.active.len()
    }

    /// Penalty-generic [`ScreeningState::screen`] (quadratic datafit):
    /// the keep test uses the penalty's
    /// [`d_score`](crate::penalty::Penalty::d_score) and
    /// [`gap_safe_radius`](crate::penalty::Penalty::gap_safe_radius),
    /// with the same residual fix-up and numerical-safety margin as the
    /// ℓ₁ rule. Group penalties screen whole groups at once (every
    /// member shares the group score, so the retain test agrees across
    /// the group); weighted-ℓ₁ `w = 0` features carry a negative score
    /// and are never discarded. The `P = L1` instantiation delegates to
    /// [`ScreeningState::screen`] wholesale — bit-identical decisions.
    pub fn screen_penalty<D: DesignOps, P: crate::penalty::Penalty>(
        &mut self,
        x: &D,
        xtheta: &[f64],
        col_norms: &[f64],
        gap: f64,
        lambda: f64,
        penalty: &P,
        beta: &mut [f64],
        r: &mut [f64],
    ) -> usize {
        if P::IS_L1 {
            return self.screen(x, xtheta, col_norms, gap, lambda, beta, r);
        }
        let radius = penalty.gap_safe_radius(gap, lambda);
        // Same numerical-safety margin as the ℓ₁ rule (see `screen`).
        let threshold = radius + 1e-12;
        let before = self.active.len();
        let screened = &mut self.screened;
        self.active.retain(|&j| {
            let keep = penalty.d_score(j, lambda, xtheta, col_norms) <= threshold;
            if !keep {
                screened[j] = true;
                if beta[j] != 0.0 {
                    // r = y − Xβ; removing β_j adds β_j·x_j back.
                    x.col_axpy(j, beta[j], r);
                    beta[j] = 0.0;
                }
            }
            keep
        });
        before - self.active.len()
    }

    /// GLM variant of [`ScreeningState::screen`]: same Gap Safe test,
    /// but with the **caller-supplied radius** (from
    /// [`gap_safe_radius_glm`] with the datafit's Lipschitz constant)
    /// and the **linear predictor** fixed instead of the residual.
    ///
    /// For a non-quadratic datafit the generalized residual is not
    /// linear in β, so zeroing a screened β_j cannot patch `r` with an
    /// axpy; instead `xw = Xβ` is patched (`xw −= β_j·x_j`) and the
    /// caller refreshes `r = −∇F(xw)` once after the sweep (the engine
    /// does this only when something was screened).
    pub fn screen_glm<D: DesignOps>(
        &mut self,
        x: &D,
        xtheta: &[f64],
        col_norms: &[f64],
        radius: f64,
        beta: &mut [f64],
        xw: &mut [f64],
    ) -> usize {
        // Same numerical-safety margin as the quadratic rule (see
        // `screen`); +∞ radius (no global Lipschitz constant) keeps
        // every feature: d ≤ ∞ always holds.
        let threshold = radius + 1e-12;
        let before = self.active.len();
        let screened = &mut self.screened;
        self.active.retain(|&j| {
            let keep = d_score(xtheta[j].abs(), col_norms[j]) <= threshold;
            if !keep {
                screened[j] = true;
                if beta[j] != 0.0 {
                    // xw = Xβ; zeroing β_j removes its column contribution.
                    x.col_axpy(j, -beta[j], xw);
                    beta[j] = 0.0;
                }
            }
            keep
        });
        before - self.active.len()
    }

    /// Block-row variant of [`ScreeningState::screen`] for width-`q`
    /// coefficient blocks (Multi-Task Lasso, paper §7): the rule uses
    /// the block d-score `d_j(Θ) = (1 − ‖x_jᵀΘ‖₂)/‖x_j‖` — the caller
    /// passes the cached row norms `xtheta_rows[j] = ‖x_jᵀΘ‖₂` from the
    /// block dual state — and a screened row is zeroed with the
    /// lane-major q×n residual fixed through the multi-RHS lane kernel
    /// (`r_t += B_{jt}·x_j` for every task). `q = 1` dispatches to the
    /// exact scalar kernels, so the block engine's q = 1 path stays
    /// bit-identical to [`ScreeningState::screen`].
    pub fn screen_block<D: DesignOps>(
        &mut self,
        x: &D,
        xtheta_rows: &[f64],
        col_norms: &[f64],
        gap: f64,
        lambda: f64,
        n: usize,
        q: usize,
        lanes: &[usize],
        beta: &mut [f64],
        r: &mut [f64],
    ) -> usize {
        let radius = gap_safe_radius(gap, lambda);
        // Same numerical-safety margin as the scalar rule (see `screen`).
        let threshold = radius + 1e-12;
        let before = self.active.len();
        let screened = &mut self.screened;
        self.active.retain(|&j| {
            let keep = d_score(xtheta_rows[j].abs(), col_norms[j]) <= threshold;
            if !keep {
                screened[j] = true;
                let row = &mut beta[j * q..(j + 1) * q];
                if row.iter().any(|&v| v != 0.0) {
                    // R = Y − XB; zeroing B_j adds B_{jt}·x_j back.
                    if q == 1 {
                        x.col_axpy(j, row[0], r);
                    } else {
                        x.col_axpy_lanes(j, row, r, n, lanes);
                    }
                    row.fill(0.0);
                }
            }
            keep
        });
        before - self.active.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::lasso::{dual, primal};

    #[test]
    fn radius_shrinks_with_gap() {
        assert_eq!(gap_safe_radius(0.0, 2.0), 0.0);
        assert!(gap_safe_radius(1.0, 2.0) > gap_safe_radius(0.5, 2.0));
        assert_eq!(gap_safe_radius(-1.0, 2.0), 0.0, "negative gap clamped");
    }

    #[test]
    fn d_score_empty_column_is_infinite() {
        assert_eq!(d_score(0.5, 0.0), f64::INFINITY);
        assert!((d_score(0.25, 0.5) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn fill_d_scores_matches_pointwise() {
        let xtheta = [0.25, -0.5, 0.0, 0.99];
        let norms = [0.5, 1.0, 0.0, 2.0];
        let mut out = vec![0.0; 4];
        fill_d_scores(&xtheta, &norms, &mut out);
        for j in 0..4 {
            let expect = d_score(xtheta[j].abs(), norms[j]);
            assert_eq!(out[j].to_bits(), expect.to_bits(), "j={j}");
        }
    }

    #[test]
    fn screening_is_safe_on_orthogonal_design() {
        // Orthogonal design with unit columns: beta_hat = ST(X^T y, lambda).
        // Feature 1 has tiny correlation -> should be screened once the
        // gap is small; feature 0 must never be screened.
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.1];
        let lambda = 1.0;
        // exact solution: beta = [2, 0]; theta_hat = (y - X beta)/lambda = [1, 0.1]
        let beta_hat = [2.0, 0.0];
        let mut r = vec![0.0; 2];
        primal::residual(&x, &y, &beta_hat, &mut r);
        let theta = dual::rescale_to_feasible(&x, &r, lambda);
        let gap = primal::primal_from_residual(&r, &beta_hat, lambda)
            - dual::dual_objective(&y, &theta, lambda);
        assert!(gap < 1e-12, "optimal pair has zero gap, got {gap}");

        let mut state = ScreeningState::all_active(2);
        let mut beta = beta_hat.to_vec();
        let mut xtheta = vec![0.0; 2];
        use crate::data::design::DesignOps;
        x.xt_vec(&theta, &mut xtheta);
        let norms = vec![1.0, 1.0];
        let k = state.screen(&x, &xtheta, &norms, gap, lambda, &mut beta, &mut r);
        assert_eq!(k, 1);
        assert!(state.is_screened(1));
        assert!(!state.is_screened(0));
        assert_eq!(state.active(), &[0]);
    }

    #[test]
    fn screening_zeroes_beta_and_fixes_residual() {
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.1];
        let lambda = 1.0;
        // current iterate has beta_1 != 0 but feature 1 is screenable with
        // a tight-enough pair: force it by using the optimal theta and a
        // beta close to optimal.
        let mut beta = vec![2.0, 0.05];
        let mut r = vec![0.0; 2];
        primal::residual(&x, &y, &beta, &mut r);
        let theta = vec![1.0, 0.1]; // optimal dual point
        let gap = primal::primal_from_residual(&r, &beta, lambda)
            - dual::dual_objective(&y, &theta, lambda);
        let mut state = ScreeningState::all_active(2);
        use crate::data::design::DesignOps;
        let mut xtheta = vec![0.0; 2];
        x.xt_vec(&theta, &mut xtheta);
        let norms = vec![1.0, 1.0];
        state.screen(&x, &xtheta, &norms, gap, lambda, &mut beta, &mut r);
        if state.is_screened(1) {
            assert_eq!(beta[1], 0.0);
            // residual must equal y - X beta for the zeroed beta
            let mut expect = vec![0.0; 2];
            primal::residual(&x, &y, &beta, &mut expect);
            for i in 0..2 {
                assert!((r[i] - expect[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn glm_radius_reduces_to_quadratic_at_l1_and_disables_at_inf() {
        for (gap, lambda) in [(0.5, 1.0), (1e-7, 0.3), (0.0, 2.0)] {
            assert_eq!(
                gap_safe_radius_glm(gap, lambda, 1.0).to_bits(),
                gap_safe_radius(gap, lambda).to_bits(),
                "L = 1 is the Lasso radius"
            );
        }
        // logistic: √(2·¼·gap)/λ = √(gap/2)/λ
        let r = gap_safe_radius_glm(0.08, 2.0, 0.25);
        assert!((r - (0.04f64).sqrt() / 2.0).abs() < 1e-15);
        assert_eq!(gap_safe_radius_glm(0.5, 1.0, f64::INFINITY), f64::INFINITY);
        assert_eq!(gap_safe_radius_glm(0.0, 1.0, f64::INFINITY), f64::INFINITY);
    }

    #[test]
    fn screen_glm_matches_quadratic_decisions_and_fixes_predictor() {
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.1];
        let lambda = 1.0;
        let mut beta_a = vec![2.0, 0.05];
        let mut r = vec![0.0; 2];
        primal::residual(&x, &y, &beta_a, &mut r);
        let theta = vec![1.0, 0.1];
        let gap = primal::primal_from_residual(&r, &beta_a, lambda)
            - dual::dual_objective(&y, &theta, lambda);
        use crate::data::design::DesignOps;
        let mut xtheta = vec![0.0; 2];
        x.xt_vec(&theta, &mut xtheta);
        let norms = vec![1.0, 1.0];
        let mut sa = ScreeningState::all_active(2);
        let ka = sa.screen(&x, &xtheta, &norms, gap, lambda, &mut beta_a, &mut r);
        // same problem through the GLM door with the quadratic radius
        let mut beta_b = vec![2.0, 0.05];
        let mut xw = vec![0.0; 2];
        x.matvec(&beta_b, &mut xw);
        let mut sb = ScreeningState::all_active(2);
        let kb = sb.screen_glm(
            &x,
            &xtheta,
            &norms,
            gap_safe_radius_glm(gap, lambda, 1.0),
            &mut beta_b,
            &mut xw,
        );
        assert_eq!(ka, kb);
        assert_eq!(sa.active(), sb.active());
        assert_eq!(beta_a, beta_b);
        // the predictor now equals X·(screened β)
        let mut expect = vec![0.0; 2];
        x.matvec(&beta_b, &mut expect);
        for i in 0..2 {
            assert!((xw[i] - expect[i]).abs() < 1e-12);
        }
        // infinite radius screens nothing
        let mut s_inf = ScreeningState::all_active(2);
        let mut b = vec![2.0, 0.05];
        let k =
            s_inf.screen_glm(&x, &xtheta, &norms, f64::INFINITY, &mut b, &mut xw);
        assert_eq!(k, 0);
        assert_eq!(s_inf.n_active(), 2);
    }

    #[test]
    fn screen_block_q1_matches_scalar_and_fixes_block_residual() {
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.1];
        let lambda = 1.0;
        let norms = vec![1.0, 1.0];
        // q = 1: identical decisions and state to the scalar rule.
        let theta = vec![1.0, 0.1];
        let mut xtheta = vec![0.0; 2];
        use crate::data::design::DesignOps;
        x.xt_vec(&theta, &mut xtheta);
        let rows: Vec<f64> = xtheta.iter().map(|v| v.abs()).collect();
        let mut beta_a = vec![2.0, 0.05];
        let mut r_a = vec![0.0; 2];
        primal::residual(&x, &y, &beta_a, &mut r_a);
        let gap = primal::primal_from_residual(&r_a, &beta_a, lambda)
            - dual::dual_objective(&y, &theta, lambda);
        let mut beta_b = beta_a.clone();
        let mut r_b = r_a.clone();
        let mut sa = ScreeningState::all_active(2);
        let mut sb = ScreeningState::all_active(2);
        let ka = sa.screen(&x, &xtheta, &norms, gap, lambda, &mut beta_a, &mut r_a);
        let lanes = [0usize];
        let kb =
            sb.screen_block(&x, &rows, &norms, gap, lambda, 2, 1, &lanes, &mut beta_b, &mut r_b);
        assert_eq!(ka, kb);
        assert_eq!(sa.active(), sb.active());
        assert_eq!(beta_a, beta_b);
        assert_eq!(r_a, r_b);

        // q = 2: a screened row is zeroed and every task residual is
        // restored to Y − XB.
        let q = 2;
        let lanes = [0usize, 1];
        let yb = [3.0, 0.1, -1.0, 0.2]; // lane-major 2×2
        let mut beta = vec![2.0, -1.0, 0.05, 0.02]; // rows: [2,-1], [0.05,0.02]
        let mut r = vec![0.0; 4];
        for t in 0..q {
            let bt: Vec<f64> = (0..2).map(|j| beta[j * q + t]).collect();
            let mut rt = vec![0.0; 2];
            primal::residual(&x, &yb[t * 2..(t + 1) * 2], &bt, &mut rt);
            r[t * 2..(t + 1) * 2].copy_from_slice(&rt);
        }
        // rows chosen so feature 1 screens (tiny correlation, tiny gap)
        let rows = vec![1.0, 0.05];
        let mut st = ScreeningState::all_active(2);
        let k = st.screen_block(&x, &rows, &norms, 1e-8, lambda, 2, q, &lanes, &mut beta, &mut r);
        assert_eq!(k, 1);
        assert!(st.is_screened(1));
        assert_eq!(&beta[2..4], &[0.0, 0.0]);
        for t in 0..q {
            let bt: Vec<f64> = (0..2).map(|j| beta[j * q + t]).collect();
            let mut expect = vec![0.0; 2];
            primal::residual(&x, &yb[t * 2..(t + 1) * 2], &bt, &mut expect);
            for i in 0..2 {
                assert!((r[t * 2 + i] - expect[i]).abs() < 1e-12, "t={t} i={i}");
            }
        }
    }

    #[test]
    fn large_gap_screens_nothing() {
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let mut state = ScreeningState::all_active(2);
        let mut beta = vec![0.0, 0.0];
        let mut r = vec![3.0, 0.1];
        let xtheta = vec![0.9, 0.05];
        let norms = vec![1.0, 1.0];
        // gap so large the radius exceeds every d_j
        let k = state.screen(&x, &xtheta, &norms, 100.0, 1.0, &mut beta, &mut r);
        assert_eq!(k, 0);
        assert_eq!(state.n_active(), 2);
    }
}
