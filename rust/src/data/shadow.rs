//! f32 shadow designs for the mixed-precision sweep mode.
//!
//! [`Precision::F32`](crate::solvers::Precision) runs CD epochs on an
//! f32 copy of the design (plus f32 β/r iterates) and recomputes
//! residual, duality gap, and Gap Safe screening in f64 before any
//! screen/stop decision (see `solvers/sweep32.rs` and the batch engine).
//! The shadow is therefore *iteration state only*: nothing read from it
//! ever enters a certificate, so casting the design to f32 once up
//! front is safe. Dense designs shadow the full column-major buffer
//! (halving the memory traffic of every epoch — the CD inner loop is
//! memory-bound, so this is where the f32 speedup comes from); CSC
//! designs keep their index structure and cast only the stored values.
//!
//! Shadows are built with **shard-local first touch**
//! ([`crate::util::par::alloc_first_touch`]): each fixed shard of the
//! f32 buffer is written by the pool worker that will later sweep it,
//! so on NUMA machines the shadow's pages land on the sweeping socket
//! instead of wherever the allocating thread happened to run. Placement
//! never changes the stored bits — serial and pooled builds are
//! identical (pinned in `tests/prop_pool.rs`).

use crate::data::design::DesignOps;
use crate::data::ooc::F32Stream;
use crate::util::par::alloc_first_touch;

/// An f32 copy of a design matrix, column-addressable like the f64
/// original. Kernels mirror the f32 kernels of [`crate::util::simd`].
#[derive(Debug, Clone)]
pub struct ShadowF32 {
    n: usize,
    p: usize,
    kind: Kind,
}

#[derive(Debug, Clone)]
enum Kind {
    /// Column-major n×p values.
    Dense { data: Vec<f32> },
    /// CSC mirror: same index structure as the source, f32 values.
    Sparse { indptr: Vec<usize>, indices: Vec<u32>, data: Vec<f32> },
    /// Chunk-streamed shadow over out-of-core stores: one
    /// [`F32Stream`] per shard (a single store is the one-shard case),
    /// columns routed by the cumulative `col_starts` offsets. Nothing
    /// is resident beyond each stream's small LRU of recycled f32
    /// chunk buffers — no full-design f32 copy ever exists. Every
    /// kernel runs the identical per-entry arithmetic as the `Sparse`
    /// arm on identically-cast slices, so iterates (and therefore the
    /// f64 certificates of the sweep mode) are bit-identical to a
    /// resident sparse shadow of the same store.
    Streamed { sources: Vec<F32Stream>, col_starts: Vec<usize> },
}

/// Owning stream + local column index of global column `j`.
#[inline]
fn route<'a>(sources: &'a [F32Stream], col_starts: &[usize], j: usize) -> (&'a F32Stream, usize) {
    let s = col_starts.partition_point(|&c| c <= j) - 1;
    (&sources[s], j - col_starts[s])
}

impl ShadowF32 {
    /// Shadow of a dense column-major buffer, first-touched per shard.
    pub fn from_dense_col_major(n: usize, p: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), n * p);
        let data = alloc_first_touch(n * p, 1, |i| data[i] as f32);
        ShadowF32 { n, p, kind: Kind::Dense { data } }
    }

    /// Shadow of CSC arrays (row indices must be < n; the caller is a
    /// validated `CscMatrix`). The value and index buffers are
    /// first-touched per shard; `indptr` is small and stays plain.
    pub fn from_csc(n: usize, p: usize, indptr: &[usize], indices: &[u32], data: &[f64]) -> Self {
        assert_eq!(indptr.len(), p + 1);
        assert_eq!(indices.len(), data.len());
        debug_assert!(indices.iter().all(|&i| (i as usize) < n));
        let nnz = data.len();
        let indices = alloc_first_touch(nnz, 1, |e| indices[e]);
        let data = alloc_first_touch(nnz, 1, |e| data[e] as f32);
        ShadowF32 { n, p, kind: Kind::Sparse { indptr: indptr.to_vec(), indices, data } }
    }

    /// Shadow from owned, already-f32 CSC parts — the streaming path of
    /// the out-of-core store ([`crate::data::ooc::OocColumnStore`]),
    /// which casts chunk by chunk while the f64 entries are resident and
    /// hands the buffers over without a second pass. Row indices must be
    /// < n and `indptr` monotone with `indptr[p] == indices.len()` (the
    /// store validates both at open/decode time).
    pub fn sparse_from_parts(
        n: usize,
        p: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f32>,
    ) -> Self {
        assert_eq!(indptr.len(), p + 1);
        assert_eq!(indices.len(), data.len());
        assert_eq!(*indptr.last().expect("p + 1 >= 1"), data.len());
        debug_assert!(indices.iter().all(|&i| (i as usize) < n));
        ShadowF32 { n, p, kind: Kind::Sparse { indptr, indices, data } }
    }

    /// Dense shadow of an arbitrary design, built through the generic
    /// `gather_dense` accessor in bounded column chunks (the f64
    /// staging buffer never exceeds 128 columns).
    pub fn dense_from_design<D: DesignOps + ?Sized>(x: &D) -> Self {
        let (n, p) = (x.n(), x.p());
        let mut data = Vec::with_capacity(n * p);
        let mut stage = Vec::new();
        let mut j = 0;
        while j < p {
            let hi = (j + 128).min(p);
            let cols: Vec<usize> = (j..hi).collect();
            x.gather_dense(&cols, &mut stage);
            data.extend(stage.iter().map(|&v| v as f32));
            j = hi;
        }
        ShadowF32 { n, p, kind: Kind::Dense { data } }
    }

    /// Chunk-streamed shadow over one [`F32Stream`] per store shard
    /// (pass a single stream for an unsharded store). Columns are
    /// concatenated in source order; all sources must share `n`.
    pub fn streamed(sources: Vec<F32Stream>) -> Self {
        assert!(!sources.is_empty(), "streamed shadow needs at least one source");
        let n = sources[0].n();
        let mut col_starts = Vec::with_capacity(sources.len() + 1);
        col_starts.push(0usize);
        for s in &sources {
            assert_eq!(s.n(), n, "streamed shadow sources disagree on n");
            col_starts.push(col_starts.last().unwrap() + s.p());
        }
        let p = *col_starts.last().unwrap();
        ShadowF32 { n, p, kind: Kind::Streamed { sources, col_starts } }
    }

    /// For streamed shadows: `(resident bytes, peak resident bytes,
    /// bound)` summed across sources, where `bound` is the guaranteed
    /// cache ceiling (capacity × largest chunk per source). `None` for
    /// resident shadows. This is what the no-full-copy acceptance
    /// criterion asserts on.
    pub fn stream_stats(&self) -> Option<(u64, u64, u64)> {
        match &self.kind {
            Kind::Streamed { sources, .. } => Some(sources.iter().fold((0, 0, 0), |a, s| {
                (
                    a.0 + s.resident_bytes(),
                    a.1 + s.peak_resident_bytes(),
                    a.2 + s.resident_bound_bytes(),
                )
            })),
            _ => None,
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// `x_jᵀ v` in f32.
    #[inline]
    pub fn col_dot(&self, j: usize, v: &[f32]) -> f32 {
        match &self.kind {
            Kind::Dense { data } => {
                crate::util::simd::dot_f32(&data[j * self.n..(j + 1) * self.n], v)
            }
            Kind::Sparse { indptr, indices, data } => {
                let (lo, hi) = (indptr[j], indptr[j + 1]);
                // Row indices come from a validated CSC matrix: < n ≤ v.len().
                unsafe { crate::util::simd::gather_dot_f32(&indices[lo..hi], &data[lo..hi], v) }
            }
            Kind::Streamed { sources, col_starts } => {
                let (src, lj) = route(sources, col_starts, j);
                // Row indices are validated < n at chunk decode time.
                src.with_col(lj, |idx, val| unsafe {
                    crate::util::simd::gather_dot_f32(idx, val, v)
                })
            }
        }
    }

    /// `out += alpha · x_j` in f32.
    #[inline]
    pub fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]) {
        match &self.kind {
            Kind::Dense { data } => {
                crate::util::simd::axpy_f32(alpha, &data[j * self.n..(j + 1) * self.n], out)
            }
            Kind::Sparse { indptr, indices, data } => {
                let (lo, hi) = (indptr[j], indptr[j + 1]);
                unsafe {
                    crate::util::simd::gather_axpy_f32(
                        &indices[lo..hi],
                        &data[lo..hi],
                        alpha,
                        out,
                    )
                }
            }
            Kind::Streamed { sources, col_starts } => {
                let (src, lj) = route(sources, col_starts, j);
                src.with_col(lj, |idx, val| unsafe {
                    crate::util::simd::gather_axpy_f32(idx, val, alpha, out)
                })
            }
        }
    }

    /// Multi-RHS f32 column dot over lane-strided buffers — the f32
    /// mirror of [`DesignOps::col_dot_lanes`], cache-blocked for dense
    /// storage and decode-once for sparse.
    pub fn col_dot_lanes(&self, j: usize, v: &[f32], n: usize, lanes: &[usize], out: &mut [f32]) {
        debug_assert_eq!(n, self.n);
        debug_assert_eq!(lanes.len(), out.len());
        out.fill(0.0);
        match &self.kind {
            Kind::Dense { data } => {
                const BLOCK: usize = 512;
                let col = &data[j * n..(j + 1) * n];
                let mut i = 0;
                while i < n {
                    let hi = (i + BLOCK).min(n);
                    let cb = &col[i..hi];
                    for (o, &k) in out.iter_mut().zip(lanes.iter()) {
                        *o += crate::util::simd::dot_f32(cb, &v[k * n + i..k * n + hi]);
                    }
                    i = hi;
                }
            }
            Kind::Sparse { indptr, indices, data } => {
                let (lo, hi) = (indptr[j], indptr[j + 1]);
                for e in lo..hi {
                    let row = indices[e] as usize;
                    let xv = data[e];
                    for (t, &k) in lanes.iter().enumerate() {
                        out[t] += xv * v[k * n + row];
                    }
                }
            }
            Kind::Streamed { sources, col_starts } => {
                // Identical per-entry loop (same entry order, same
                // accumulation order) as the Sparse arm — bit-identical
                // lane iterates.
                let (src, lj) = route(sources, col_starts, j);
                src.with_col(lj, |idx, val| {
                    for (&row, &xv) in idx.iter().zip(val) {
                        let row = row as usize;
                        for (t, &k) in lanes.iter().enumerate() {
                            out[t] += xv * v[k * n + row];
                        }
                    }
                });
            }
        }
    }

    /// Multi-RHS f32 column axpy, lane layout as in `col_dot_lanes`.
    pub fn col_axpy_lanes(
        &self,
        j: usize,
        alphas: &[f32],
        v: &mut [f32],
        n: usize,
        lanes: &[usize],
    ) {
        debug_assert_eq!(n, self.n);
        debug_assert_eq!(lanes.len(), alphas.len());
        match &self.kind {
            Kind::Dense { data } => {
                const BLOCK: usize = 512;
                let col = &data[j * n..(j + 1) * n];
                let mut i = 0;
                while i < n {
                    let hi = (i + BLOCK).min(n);
                    let cb = &col[i..hi];
                    for (&alpha, &k) in alphas.iter().zip(lanes.iter()) {
                        if alpha != 0.0 {
                            crate::util::simd::axpy_f32(alpha, cb, &mut v[k * n + i..k * n + hi]);
                        }
                    }
                    i = hi;
                }
            }
            Kind::Sparse { indptr, indices, data } => {
                let (lo, hi) = (indptr[j], indptr[j + 1]);
                for e in lo..hi {
                    let row = indices[e] as usize;
                    let xv = data[e];
                    for (t, &k) in lanes.iter().enumerate() {
                        let alpha = alphas[t];
                        if alpha != 0.0 {
                            v[k * n + row] += alpha * xv;
                        }
                    }
                }
            }
            Kind::Streamed { sources, col_starts } => {
                let (src, lj) = route(sources, col_starts, j);
                src.with_col(lj, |idx, val| {
                    for (&row, &xv) in idx.iter().zip(val) {
                        let row = row as usize;
                        for (t, &k) in lanes.iter().enumerate() {
                            let alpha = alphas[t];
                            if alpha != 0.0 {
                                v[k * n + row] += alpha * xv;
                            }
                        }
                    }
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csc::CscMatrix;
    use crate::data::dense::DenseMatrix;
    use crate::util::rng::Rng;

    fn pair(seed: u64, n: usize, p: usize) -> (DenseMatrix, CscMatrix) {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0; n * p];
        for v in data.iter_mut() {
            if rng.uniform() < 0.5 {
                *v = rng.normal();
            }
        }
        (DenseMatrix::from_col_major(n, p, data.clone()), CscMatrix::from_dense(n, p, &data))
    }

    #[test]
    fn shadows_track_f64_designs() {
        let (d, s) = pair(3, 29, 7);
        let sd = d.shadow_f32();
        let ss = s.shadow_f32();
        let mut rng = Rng::new(4);
        let v64: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
        let v32: Vec<f32> = v64.iter().map(|&v| v as f32).collect();
        for x in [&sd, &ss] {
            assert_eq!((x.n(), x.p()), (29, 7));
            for j in 0..7 {
                let exact = d.col_dot(j, &v64);
                let approx = x.col_dot(j, &v32) as f64;
                assert!((exact - approx).abs() < 1e-4, "j={j}: {exact} vs {approx}");
                let mut out = v32.clone();
                x.col_axpy(j, 0.5, &mut out);
                let mut ref64 = v64.clone();
                d.col_axpy(j, 0.5, &mut ref64);
                for i in 0..29 {
                    assert!((out[i] as f64 - ref64[i]).abs() < 1e-4, "axpy j={j} i={i}");
                }
            }
        }
        // dense and sparse shadows agree with each other exactly on
        // single-column dots of a dense-castable input? Not bitwise (the
        // gather order differs); tolerance suffices.
        for j in 0..7 {
            let a = sd.col_dot(j, &v32);
            let b = ss.col_dot(j, &v32);
            assert!((a - b).abs() < 1e-3, "j={j}");
        }
    }

    #[test]
    fn lane_kernels_match_per_lane_loops() {
        let (d, s) = pair(5, 23, 6);
        let n = 23;
        let mut rng = Rng::new(6);
        let v: Vec<f32> = (0..4 * n).map(|_| rng.normal() as f32).collect();
        let lanes = [0usize, 2, 3];
        let alphas = [0.5f32, 0.0, -1.25];
        for x in [&d.shadow_f32(), &s.shadow_f32()] {
            for j in 0..6 {
                let mut got = vec![0.0f32; lanes.len()];
                x.col_dot_lanes(j, &v, n, &lanes, &mut got);
                for (t, &k) in lanes.iter().enumerate() {
                    let expect = x.col_dot(j, &v[k * n..(k + 1) * n]);
                    assert!((got[t] - expect).abs() < 1e-3, "dot j={j} lane={k}");
                }
                let mut batched = v.clone();
                x.col_axpy_lanes(j, &alphas, &mut batched, n, &lanes);
                let mut manual = v.clone();
                for (t, &k) in lanes.iter().enumerate() {
                    if alphas[t] != 0.0 {
                        x.col_axpy(j, alphas[t], &mut manual[k * n..(k + 1) * n]);
                    }
                }
                assert_eq!(batched, manual, "axpy j={j}");
            }
        }
    }

    #[test]
    fn generic_dense_fallback_matches_override() {
        let (d, _) = pair(8, 11, 5);
        let a = d.shadow_f32();
        let b = ShadowF32::dense_from_design(&d);
        let v: Vec<f32> = (0..11).map(|i| (i as f32) * 0.25 - 1.0).collect();
        for j in 0..5 {
            assert_eq!(a.col_dot(j, &v).to_bits(), b.col_dot(j, &v).to_bits());
        }
    }
}
