//! Unified design-matrix abstraction.
//!
//! Solvers are written generically over [`DesignOps`] so that the inner
//! loops monomorphize for both dense and sparse storage (no dynamic
//! dispatch on the hot path). The public API wraps both in the
//! [`DesignMatrix`] enum and dispatches once at entry.

use crate::data::csc::CscMatrix;
use crate::data::dense::DenseMatrix;
use crate::data::ooc::OocColumnStore;
use crate::data::shard::ShardedStore;

/// The column-oriented operations coordinate descent and screening need.
pub trait DesignOps: Sync {
    /// Number of observations (rows).
    fn n(&self) -> usize;
    /// Number of features (columns).
    fn p(&self) -> usize;
    /// `x_jᵀ v`.
    fn col_dot(&self, j: usize, v: &[f64]) -> f64;
    /// `out += alpha · x_j`.
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]);
    /// `‖x_j‖²`.
    fn col_norm_sq(&self, j: usize) -> f64;
    /// Number of stored non-zeros in column j.
    fn col_nnz(&self, j: usize) -> usize;
    /// `out = X β`.
    fn matvec(&self, beta: &[f64], out: &mut [f64]);
    /// `out = Xᵀ v` (parallelized over columns).
    fn xt_vec(&self, v: &[f64], out: &mut [f64]);
    /// Gather columns `cols` into a dense column-major buffer (n × |cols|).
    fn gather_dense(&self, cols: &[usize], out: &mut Vec<f64>);
    /// Total stored non-zeros.
    fn nnz(&self) -> usize;

    /// Multi-RHS column dot: `out[t] = x_jᵀ v_lanes[t]` where lane `k`'s
    /// vector is the slice `v[k·n .. (k+1)·n]` of a strided buffer and
    /// `lanes[t]` selects which lanes participate.
    ///
    /// This is THE multi-RHS kernel of the crate — the batched multi-λ
    /// engine ([`crate::solvers::batch`], lanes = concurrent λ's) and
    /// the block-coefficient / Multi-Task engine
    /// ([`crate::solvers::block`], lanes = the q tasks of a lane-major
    /// residual matrix) both run on it. The default implementation
    /// performs one [`DesignOps::col_dot`] per lane, while the dense/CSC
    /// storage backends override it with a single sweep over the column
    /// that streams all lanes at once — the column's values (and, for
    /// CSC, its row indices) are loaded and decoded once per sweep
    /// instead of once per lane.
    fn col_dot_lanes(&self, j: usize, v: &[f64], n: usize, lanes: &[usize], out: &mut [f64]) {
        debug_assert_eq!(lanes.len(), out.len());
        for (o, &k) in out.iter_mut().zip(lanes.iter()) {
            *o = self.col_dot(j, &v[k * n..(k + 1) * n]);
        }
    }

    /// Multi-RHS column axpy: `v_lanes[t] += alphas[t] · x_j` for every
    /// participating lane (zero coefficients are skipped). Lane layout
    /// matches [`DesignOps::col_dot_lanes`].
    fn col_axpy_lanes(&self, j: usize, alphas: &[f64], v: &mut [f64], n: usize, lanes: &[usize]) {
        debug_assert_eq!(lanes.len(), alphas.len());
        for (&alpha, &k) in alphas.iter().zip(lanes.iter()) {
            if alpha != 0.0 {
                self.col_axpy(j, alpha, &mut v[k * n..(k + 1) * n]);
            }
        }
    }

    /// Weighted squared column norm `Σᵢ wᵢ·x_ij²` — the exact
    /// per-coordinate curvature `x_jᵀ W x_j` of the prox-Newton /
    /// IRLS-weighted CD epoch ([`crate::solvers::glm::ProxNewtonCd`]),
    /// where `w_i = fᵢ''(x_iᵀβ)` are the datafit's curvature weights.
    fn col_wnorm_sq(&self, j: usize, w: &[f64]) -> f64;

    /// Weighted column axpy `out_i += alpha·wᵢ·x_ij` — maintains the
    /// prox-Newton model residual `ρ = r − W·Xδ` after a coordinate
    /// step, touching only the column's stored entries.
    fn col_waxpy(&self, j: usize, alpha: f64, w: &[f64], out: &mut [f64]);

    /// Estimated flops for touching one column in a full-design scan —
    /// the work model behind the serial/parallel cutoff in
    /// [`crate::util::par`]. The cutoff gates on `p × hint`, not on p
    /// alone: a p = 4096, n = 10⁵ dense `Xᵀv` is ~4·10⁸ flops and must
    /// parallelize even though its item count looks small.
    fn col_cost_hint(&self) -> usize {
        self.n().max(1)
    }

    /// `‖Xᵀ v‖_∞` (used by dual rescaling and λ_max).
    fn xt_abs_max(&self, v: &[f64]) -> f64 {
        crate::util::par::par_max_cost(self.p(), self.col_cost_hint(), |j| {
            self.col_dot(j, v).abs()
        })
        .max(0.0)
    }

    /// Fused `out = Xᵀv` + `‖Xᵀv‖_∞`: one sharded pass over the columns
    /// produces the correlation vector *and* its infinity norm — the
    /// pair every dual rescale (Eq. 4: `θ = r / max(λ, ‖Xᵀr‖_∞)`)
    /// needs. Replaces a pooled fill followed by a separate serial max
    /// scan, halving the full-p passes per gap check.
    fn xt_vec_abs_max(&self, v: &[f64], out: &mut [f64]) -> f64 {
        assert_eq!(v.len(), self.n());
        assert_eq!(out.len(), self.p());
        crate::util::par::par_fill_abs_max(out, self.col_cost_hint(), |j| self.col_dot(j, v))
    }

    /// All column squared norms.
    fn col_norms_sq(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.p()];
        crate::util::par::par_fill_cost(&mut out, self.col_cost_hint(), |j| self.col_norm_sq(j));
        out
    }

    /// Build the f32 shadow of this design for the mixed-precision
    /// sweep mode ([`crate::solvers::Precision::F32`]). The default
    /// materializes densely through `gather_dense`; storage backends
    /// override it to preserve sparsity (CSC) or cast in place (dense).
    fn shadow_f32(&self) -> crate::data::shadow::ShadowF32 {
        crate::data::shadow::ShadowF32::dense_from_design(self)
    }
}

/// A design matrix: dense column-major, sparse CSC, an out-of-core
/// column store streaming CSC chunks from disk, or a design sharded
/// across multiple stores with independent prefetch streams.
#[derive(Debug, Clone)]
pub enum DesignMatrix {
    Dense(DenseMatrix),
    Sparse(CscMatrix),
    Ooc(OocColumnStore),
    Sharded(ShardedStore),
}

impl DesignMatrix {
    /// Restrict to the given columns, preserving storage kind.
    pub fn select_columns(&self, cols: &[usize]) -> DesignMatrix {
        match self {
            DesignMatrix::Dense(d) => {
                let mut buf = Vec::new();
                d.gather_dense(cols, &mut buf);
                DesignMatrix::Dense(DenseMatrix::from_col_major(d.n(), cols.len(), buf))
            }
            DesignMatrix::Sparse(s) => DesignMatrix::Sparse(s.select_columns(cols)),
            // A working-set restriction is by definition small enough to
            // be resident: materialize it in memory.
            DesignMatrix::Ooc(o) => DesignMatrix::Sparse(o.select_columns_csc(cols)),
            DesignMatrix::Sharded(s) => DesignMatrix::Sparse(s.select_columns_csc(cols)),
        }
    }

    /// True if sparse storage (the out-of-core stores hold CSC entries).
    pub fn is_sparse(&self) -> bool {
        matches!(
            self,
            DesignMatrix::Sparse(_) | DesignMatrix::Ooc(_) | DesignMatrix::Sharded(_)
        )
    }

    /// Density of stored non-zeros.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n() as f64 * self.p() as f64)
    }
}

/// Dispatch a [`DesignOps`] method through the enum.
macro_rules! dispatch {
    ($self:ident, $m:ident $(, $a:expr)*) => {
        match $self {
            DesignMatrix::Dense(d) => d.$m($($a),*),
            DesignMatrix::Sparse(s) => s.$m($($a),*),
            DesignMatrix::Ooc(o) => o.$m($($a),*),
            DesignMatrix::Sharded(sh) => sh.$m($($a),*),
        }
    };
}

impl DesignOps for DesignMatrix {
    fn n(&self) -> usize {
        dispatch!(self, n)
    }
    fn p(&self) -> usize {
        dispatch!(self, p)
    }
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        dispatch!(self, col_dot, j, v)
    }
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        dispatch!(self, col_axpy, j, alpha, out)
    }
    fn col_norm_sq(&self, j: usize) -> f64 {
        dispatch!(self, col_norm_sq, j)
    }
    fn col_nnz(&self, j: usize) -> usize {
        dispatch!(self, col_nnz, j)
    }
    fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        dispatch!(self, matvec, beta, out)
    }
    fn xt_vec(&self, v: &[f64], out: &mut [f64]) {
        dispatch!(self, xt_vec, v, out)
    }
    fn gather_dense(&self, cols: &[usize], out: &mut Vec<f64>) {
        dispatch!(self, gather_dense, cols, out)
    }
    fn nnz(&self) -> usize {
        dispatch!(self, nnz)
    }
    fn col_dot_lanes(&self, j: usize, v: &[f64], n: usize, lanes: &[usize], out: &mut [f64]) {
        dispatch!(self, col_dot_lanes, j, v, n, lanes, out)
    }
    fn col_axpy_lanes(&self, j: usize, alphas: &[f64], v: &mut [f64], n: usize, lanes: &[usize]) {
        dispatch!(self, col_axpy_lanes, j, alphas, v, n, lanes)
    }
    fn col_wnorm_sq(&self, j: usize, w: &[f64]) -> f64 {
        dispatch!(self, col_wnorm_sq, j, w)
    }
    fn col_waxpy(&self, j: usize, alpha: f64, w: &[f64], out: &mut [f64]) {
        dispatch!(self, col_waxpy, j, alpha, w, out)
    }
    fn col_cost_hint(&self) -> usize {
        dispatch!(self, col_cost_hint)
    }
    fn xt_abs_max(&self, v: &[f64]) -> f64 {
        dispatch!(self, xt_abs_max, v)
    }
    fn xt_vec_abs_max(&self, v: &[f64], out: &mut [f64]) -> f64 {
        dispatch!(self, xt_vec_abs_max, v, out)
    }
    fn col_norms_sq(&self) -> Vec<f64> {
        dispatch!(self, col_norms_sq)
    }
    fn shadow_f32(&self) -> crate::data::shadow::ShadowF32 {
        dispatch!(self, shadow_f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_pair(seed: u64, n: usize, p: usize, density: f64) -> (DesignMatrix, DesignMatrix) {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0; n * p];
        for v in dense.iter_mut() {
            if rng.uniform() < density {
                *v = rng.normal();
            }
        }
        let d = DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, dense.clone()));
        let s = DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &dense));
        (d, s)
    }

    #[test]
    fn dense_sparse_agree() {
        let (d, s) = random_pair(42, 17, 23, 0.3);
        let mut rng = Rng::new(1);
        let v: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
        let beta: Vec<f64> = (0..23).map(|_| rng.normal()).collect();
        assert_eq!(d.n(), s.n());
        assert_eq!(d.nnz(), s.nnz());
        for j in 0..23 {
            assert!((d.col_dot(j, &v) - s.col_dot(j, &v)).abs() < 1e-12);
            assert!((d.col_norm_sq(j) - s.col_norm_sq(j)).abs() < 1e-12);
        }
        let (mut a, mut b) = (vec![0.0; 17], vec![0.0; 17]);
        d.matvec(&beta, &mut a);
        s.matvec(&beta, &mut b);
        for i in 0..17 {
            assert!((a[i] - b[i]).abs() < 1e-12);
        }
        assert!((d.xt_abs_max(&v) - s.xt_abs_max(&v)).abs() < 1e-12);
        let (cn_d, cn_s) = (d.col_norms_sq(), s.col_norms_sq());
        for j in 0..23 {
            assert!((cn_d[j] - cn_s[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn select_columns_both_kinds() {
        let (d, s) = random_pair(7, 10, 8, 0.5);
        let cols = [5, 1, 6];
        let ds = d.select_columns(&cols);
        let ss = s.select_columns(&cols);
        assert_eq!(ds.p(), 3);
        assert_eq!(ss.p(), 3);
        let v: Vec<f64> = (0..10).map(|i| i as f64).collect();
        for c in 0..3 {
            assert!((ds.col_dot(c, &v) - d.col_dot(cols[c], &v)).abs() < 1e-12);
            assert!((ss.col_dot(c, &v) - s.col_dot(cols[c], &v)).abs() < 1e-12);
        }
    }

    #[test]
    fn fused_xt_vec_abs_max_matches_separate() {
        let (d, s) = random_pair(45, 19, 31, 0.4);
        let mut rng = Rng::new(9);
        let v: Vec<f64> = (0..19).map(|_| rng.normal()).collect();
        for x in [&d, &s] {
            let mut fused = vec![0.0; 31];
            let m = x.xt_vec_abs_max(&v, &mut fused);
            let mut plain = vec![0.0; 31];
            x.xt_vec(&v, &mut plain);
            assert_eq!(fused, plain, "fused fill equals xt_vec");
            let expect = plain.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
            assert_eq!(m.to_bits(), expect.to_bits(), "fused max equals scan");
            assert!((m - x.xt_abs_max(&v)).abs() < 1e-15);
        }
    }

    #[test]
    fn cost_hints_reflect_storage() {
        let (d, s) = random_pair(46, 40, 25, 0.1);
        assert_eq!(d.col_cost_hint(), 40, "dense hint is n");
        let expect = (s.nnz() / 25).max(1);
        assert_eq!(s.col_cost_hint(), expect, "sparse hint is mean nnz");
    }

    #[test]
    fn density_reported() {
        let (_, s) = random_pair(3, 50, 40, 0.1);
        let d = s.density();
        assert!(d > 0.02 && d < 0.25, "density={d}");
    }

    #[test]
    fn weighted_ops_match_manual_loops() {
        let (d, s) = random_pair(47, 15, 11, 0.4);
        let mut rng = Rng::new(6);
        let w: Vec<f64> = (0..15).map(|_| rng.uniform() + 0.1).collect();
        let v: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let mut dense_cols = Vec::new();
        d.gather_dense(&(0..11).collect::<Vec<_>>(), &mut dense_cols);
        for x in [&d, &s] {
            for j in 0..11 {
                let col = &dense_cols[j * 15..(j + 1) * 15];
                let expect_wn: f64 = (0..15).map(|i| w[i] * col[i] * col[i]).sum();
                assert!(
                    (x.col_wnorm_sq(j, &w) - expect_wn).abs() < 1e-12,
                    "wnorm j={j}"
                );
                let mut got = v.clone();
                x.col_waxpy(j, -1.75, &w, &mut got);
                for i in 0..15 {
                    let expect = v[i] + -1.75 * w[i] * col[i];
                    assert!((got[i] - expect).abs() < 1e-12, "waxpy j={j} i={i}");
                }
            }
        }
        // the view delegates through its column map
        let norms = d.col_norms_sq();
        let cols = [3usize, 0, 9];
        let view = crate::data::view::DesignView::new(&d, &cols, &norms);
        for (c, &j) in cols.iter().enumerate() {
            assert_eq!(
                view.col_wnorm_sq(c, &w).to_bits(),
                d.col_wnorm_sq(j, &w).to_bits()
            );
            let (mut a, mut b) = (v.clone(), v.clone());
            view.col_waxpy(c, 0.3, &w, &mut a);
            d.col_waxpy(j, 0.3, &w, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn lane_ops_match_per_lane_loops() {
        // 4 strided lanes, only lanes {0, 2, 3} participate: the batched
        // sweep must equal one col_dot / col_axpy per selected lane.
        let (d, s) = random_pair(44, 13, 9, 0.4);
        let n = 13;
        let mut rng = Rng::new(5);
        let v: Vec<f64> = (0..4 * n).map(|_| rng.normal()).collect();
        let lanes = [0usize, 2, 3];
        let alphas = [0.5, 0.0, -1.25];
        for x in [&d, &s] {
            for j in 0..9 {
                let mut got = vec![0.0; lanes.len()];
                x.col_dot_lanes(j, &v, n, &lanes, &mut got);
                for (t, &k) in lanes.iter().enumerate() {
                    let expect = x.col_dot(j, &v[k * n..(k + 1) * n]);
                    assert!((got[t] - expect).abs() < 1e-12, "dot j={j} lane={k}");
                }
                let mut batched = v.clone();
                x.col_axpy_lanes(j, &alphas, &mut batched, n, &lanes);
                let mut manual = v.clone();
                for (t, &k) in lanes.iter().enumerate() {
                    x.col_axpy(j, alphas[t], &mut manual[k * n..(k + 1) * n]);
                }
                assert_eq!(batched, manual, "axpy j={j}");
                // single non-zero lane (the CSC fast path) and all-zero
                let single = [0.0, 0.7, 0.0];
                let mut batched = v.clone();
                x.col_axpy_lanes(j, &single, &mut batched, n, &lanes);
                let mut manual = v.clone();
                x.col_axpy(j, 0.7, &mut manual[2 * n..3 * n]);
                assert_eq!(batched, manual, "axpy single j={j}");
                let mut untouched = v.clone();
                x.col_axpy_lanes(j, &[0.0; 3], &mut untouched, n, &lanes);
                assert_eq!(untouched, v, "axpy all-zero j={j}");
            }
        }
    }
}
