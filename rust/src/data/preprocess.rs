//! Dataset preprocessing, matching the paper's pipeline (§6.1–6.2):
//!
//! - drop features with fewer than `min_nnz` non-zero entries,
//! - set every feature column to unit ℓ2 norm,
//! - center `y` and set it to unit ℓ2 norm,
//! - optionally append an unregularized-in-spirit intercept column
//!   (constant 1/√n so it is unit-norm).

use crate::data::csc::CscMatrix;
use crate::data::dense::DenseMatrix;
use crate::data::design::{DesignMatrix, DesignOps};

/// Preprocessing configuration.
#[derive(Debug, Clone, Copy)]
pub struct PreprocessConfig {
    /// Drop columns with strictly fewer stored non-zeros than this.
    pub min_nnz: usize,
    /// Rescale every kept column to unit ℓ2 norm.
    pub normalize_columns: bool,
    /// Center y to zero mean and rescale to unit ℓ2 norm.
    pub standardize_y: bool,
    /// Append a constant intercept column (unit ℓ2 norm).
    pub add_intercept: bool,
}

impl Default for PreprocessConfig {
    fn default() -> Self {
        PreprocessConfig {
            min_nnz: 1,
            normalize_columns: true,
            standardize_y: true,
            add_intercept: false,
        }
    }
}

/// The paper's Finance preprocessing: min 3 nnz, unit columns, standardized
/// y, intercept appended.
pub fn finance_config() -> PreprocessConfig {
    PreprocessConfig { min_nnz: 3, normalize_columns: true, standardize_y: true, add_intercept: true }
}

/// Report of what preprocessing did.
#[derive(Debug, Clone)]
pub struct PreprocessReport {
    pub kept_columns: Vec<usize>,
    pub dropped: usize,
    pub y_mean: f64,
    pub y_norm: f64,
}

/// Apply preprocessing; returns the new (X, y) and a report.
pub fn preprocess(
    x: &DesignMatrix,
    y: &[f64],
    cfg: &PreprocessConfig,
) -> (DesignMatrix, Vec<f64>, PreprocessReport) {
    let n = x.n();
    assert_eq!(y.len(), n);

    // 1. column filtering
    let kept: Vec<usize> = (0..x.p()).filter(|&j| x.col_nnz(j) >= cfg.min_nnz).collect();
    let dropped = x.p() - kept.len();
    let mut xk = if kept.len() == x.p() { x.clone() } else { x.select_columns(&kept) };

    // 2. column normalization
    if cfg.normalize_columns {
        xk = normalize_columns(xk);
    }

    // 3. intercept
    if cfg.add_intercept {
        xk = append_intercept(xk);
    }

    // 4. y standardization
    let mut y2 = y.to_vec();
    let mut y_mean = 0.0;
    let mut y_norm = 1.0;
    if cfg.standardize_y {
        y_mean = y2.iter().sum::<f64>() / n as f64;
        for v in y2.iter_mut() {
            *v -= y_mean;
        }
        y_norm = crate::util::linalg::norm(&y2);
        if y_norm > 0.0 {
            for v in y2.iter_mut() {
                *v /= y_norm;
            }
        }
    }

    (xk, y2, PreprocessReport { kept_columns: kept, dropped, y_mean, y_norm })
}

/// Rescale all non-empty columns to unit ℓ2 norm.
pub fn normalize_columns(x: DesignMatrix) -> DesignMatrix {
    match x {
        DesignMatrix::Dense(mut d) => {
            for j in 0..d.p() {
                let nrm = d.col_norm_sq(j).sqrt();
                if nrm > 0.0 {
                    for v in d.col_mut(j) {
                        *v /= nrm;
                    }
                }
            }
            DesignMatrix::Dense(d)
        }
        DesignMatrix::Sparse(mut s) => {
            for j in 0..s.p() {
                let nrm = s.col_norm_sq(j).sqrt();
                if nrm > 0.0 {
                    for v in s.col_values_mut(j) {
                        *v /= nrm;
                    }
                }
            }
            DesignMatrix::Sparse(s)
        }
        // Preprocessing mutates entries, which a read-only store cannot:
        // materialize, then normalize in memory.
        DesignMatrix::Ooc(o) => normalize_columns(DesignMatrix::Sparse(o.to_csc())),
        DesignMatrix::Sharded(sh) => normalize_columns(DesignMatrix::Sparse(sh.to_csc())),
    }
}

/// Append a constant column `1/√n` (unit ℓ2 norm).
pub fn append_intercept(x: DesignMatrix) -> DesignMatrix {
    let n = x.n();
    let c = 1.0 / (n as f64).sqrt();
    match x {
        DesignMatrix::Dense(d) => {
            let p = d.p();
            let mut data = d.raw().to_vec();
            data.extend(std::iter::repeat(c).take(n));
            DesignMatrix::Dense(DenseMatrix::from_col_major(n, p + 1, data))
        }
        DesignMatrix::Sparse(s) => {
            let p = s.p();
            let mut dense = Vec::new();
            // rebuild CSC with one extra full column
            let mut cols: Vec<Vec<(u32, f64)>> = Vec::with_capacity(p + 1);
            for j in 0..p {
                s.gather_dense(&[j], &mut dense);
                cols.push(
                    dense
                        .iter()
                        .enumerate()
                        .filter(|(_, &v)| v != 0.0)
                        .map(|(i, &v)| (i as u32, v))
                        .collect(),
                );
            }
            cols.push((0..n as u32).map(|i| (i, c)).collect());
            DesignMatrix::Sparse(CscMatrix::from_columns(n, cols))
        }
        DesignMatrix::Ooc(o) => append_intercept(DesignMatrix::Sparse(o.to_csc())),
        DesignMatrix::Sharded(sh) => append_intercept(DesignMatrix::Sparse(sh.to_csc())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sparse(seed: u64, n: usize, p: usize, density: f64) -> DesignMatrix {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0; n * p];
        for v in dense.iter_mut() {
            if rng.uniform() < density {
                *v = rng.normal();
            }
        }
        DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &dense))
    }

    #[test]
    fn normalize_gives_unit_columns() {
        let x = random_sparse(1, 20, 10, 0.5);
        let xn = normalize_columns(x);
        for j in 0..10 {
            let ns = xn.col_norm_sq(j);
            if xn.col_nnz(j) > 0 {
                assert!((ns - 1.0).abs() < 1e-12, "col {j}: {ns}");
            }
        }
    }

    #[test]
    fn min_nnz_filters() {
        // col0: 2 nnz, col1: 1 nnz, col2: 3 nnz
        let x = DesignMatrix::Sparse(CscMatrix::from_columns(
            3,
            vec![
                vec![(0, 1.0), (1, 1.0)],
                vec![(2, 1.0)],
                vec![(0, 1.0), (1, 1.0), (2, 1.0)],
            ],
        ));
        let y = vec![1.0, 2.0, 3.0];
        let cfg = PreprocessConfig { min_nnz: 2, ..Default::default() };
        let (x2, _, rep) = preprocess(&x, &y, &cfg);
        assert_eq!(x2.p(), 2);
        assert_eq!(rep.kept_columns, vec![0, 2]);
        assert_eq!(rep.dropped, 1);
    }

    #[test]
    fn y_standardized() {
        let x = random_sparse(2, 10, 4, 0.5);
        let y: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let (_, y2, rep) = preprocess(&x, &y, &PreprocessConfig::default());
        let mean: f64 = y2.iter().sum::<f64>() / 10.0;
        assert!(mean.abs() < 1e-12);
        assert!((crate::util::linalg::norm(&y2) - 1.0).abs() < 1e-12);
        assert!((rep.y_mean - 4.5).abs() < 1e-12);
    }

    #[test]
    fn intercept_appended_unit_norm_both_kinds() {
        for x in [random_sparse(3, 16, 5, 0.4), {
            let mut rng = Rng::new(4);
            let data: Vec<f64> = (0..16 * 5).map(|_| rng.normal()).collect();
            DesignMatrix::Dense(crate::data::dense::DenseMatrix::from_col_major(16, 5, data))
        }] {
            let xi = append_intercept(x);
            assert_eq!(xi.p(), 6);
            assert!((xi.col_norm_sq(5) - 1.0).abs() < 1e-12);
            assert_eq!(xi.col_nnz(5), 16);
        }
    }

    #[test]
    fn finance_config_matches_paper() {
        let cfg = finance_config();
        assert_eq!(cfg.min_nnz, 3);
        assert!(cfg.add_intercept);
        assert!(cfg.normalize_columns && cfg.standardize_y);
    }
}
