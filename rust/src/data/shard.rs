//! Multi-store column sharding: one design split across several
//! out-of-core stores, each with its own prefetch stream.
//!
//! [`crate::data::ooc::OocColumnStore`] made a single file sweepable at
//! disk bandwidth, but it is one file with one prefetcher: a second
//! disk (or a second NUMA node's I/O path) adds nothing. A
//! [`ShardedStore`] splits the columns into contiguous ranges, each
//! backed by its own store — own file, own LRU chunk cache, own
//! background prefetch thread — and implements the full
//! [`DesignOps`] surface by routing every column op to its owning
//! shard. Full-design scans (`xt_vec`, the fused rescale, column
//! norms) run on the *group-aligned* pool grids of
//! [`crate::util::par`]: work units are snapped to shard boundaries and
//! handed out round-robin across shards, so concurrently running
//! workers drain **different** prefetch streams — aggregate bandwidth
//! scales with the shard count (BENCH_10.json) instead of serializing
//! on one stream. This is the stepping stone from NUMA nodes to
//! distributed workers: a shard is already a self-contained store that
//! could live on another machine.
//!
//! **Bit-identity.** Sharding changes which file a column's bytes come
//! from and which worker touches them — never the bytes, the kernels,
//! or any fold order that matters: per-column ops run the identical
//! entry slices through the identical `util::simd` / `csc` kernels,
//! per-index fills have one writer per slot, and the only cross-shard
//! reductions are max folds (order-insensitive). λ-paths on a
//! `ShardedStore` are therefore bit-identical (β and gap certificates)
//! to the single-store and in-memory CSC solves — pinned in
//! `tests/prop_shard.rs` across shard counts and misaligned bounds.
//!
//! **Validation.** Every shard is a complete CELERCS1 store holding the
//! full label segment. [`ShardedStore::open`] cross-checks the shards:
//! a missing or corrupt file, a row-count mismatch, or label segments
//! that disagree bitwise are all typed [`SolveError::StoreFormat`] —
//! shards from different datasets cannot be silently mixed.

use crate::data::csc::CscMatrix;
use crate::data::design::DesignOps;
use crate::data::ooc::{self, F32Stream, IoStats, OocColumnStore, StoreMeta};
use crate::util::error::SolveError;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

struct ShardInner {
    shards: Vec<OocColumnStore>,
    /// Cumulative column offsets: shard s owns global columns
    /// `col_starts[s] .. col_starts[s+1]`; length = shards + 1.
    col_starts: Vec<usize>,
    n: usize,
    p: usize,
    nnz: usize,
}

/// A design sharded across multiple [`OocColumnStore`]s by contiguous
/// column range. Cloning is cheap (a shared handle); each shard's chunk
/// cache and prefetch thread are shared across clones, exactly like the
/// single-store handle.
#[derive(Clone)]
pub struct ShardedStore {
    inner: Arc<ShardInner>,
}

impl fmt::Debug for ShardedStore {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ShardedStore")
            .field("shards", &self.inner.shards.len())
            .field("n", &self.inner.n)
            .field("p", &self.inner.p)
            .field("nnz", &self.inner.nnz)
            .field("col_starts", &self.inner.col_starts)
            .finish()
    }
}

impl ShardedStore {
    /// Open a sharded store with default chunking; see
    /// [`ShardedStore::open_with`].
    pub fn open(paths: &[PathBuf]) -> Result<ShardedStore, SolveError> {
        ShardedStore::open_with(paths, ooc::DEFAULT_CHUNK_BYTES, 0)
    }

    /// Open the shard files in column order with an explicit per-shard
    /// chunk byte budget and cache size (`0` = auto, as for
    /// [`OocColumnStore::open_with`]). Shards must agree on `n` and
    /// hold bitwise-identical label segments; any structural defect in
    /// any shard is a typed [`SolveError::StoreFormat`].
    pub fn open_with(
        paths: &[PathBuf],
        chunk_bytes: usize,
        cache_chunks: usize,
    ) -> Result<ShardedStore, SolveError> {
        if paths.is_empty() {
            return Err(SolveError::StoreFormat {
                path: String::new(),
                detail: "sharded store needs at least one shard path".into(),
            });
        }
        let mut shards = Vec::with_capacity(paths.len());
        for path in paths {
            shards.push(OocColumnStore::open_with(path, chunk_bytes, cache_chunks)?);
        }
        let n = shards[0].meta().n;
        let y0 = shards[0].read_labels()?;
        for s in &shards[1..] {
            let m = s.meta();
            if m.n != n {
                return Err(SolveError::StoreFormat {
                    path: s.path().display().to_string(),
                    detail: format!(
                        "shard row count n = {} disagrees with shard 0 ({}) at {}",
                        m.n,
                        n,
                        shards[0].path().display()
                    ),
                });
            }
            let y = s.read_labels()?;
            if y.len() != y0.len()
                || y.iter().zip(&y0).any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(SolveError::StoreFormat {
                    path: s.path().display().to_string(),
                    detail: format!(
                        "shard label segment differs from shard 0 ({}) — shards of \
                         different datasets cannot be mixed",
                        shards[0].path().display()
                    ),
                });
            }
        }
        let mut col_starts = Vec::with_capacity(shards.len() + 1);
        col_starts.push(0usize);
        let mut nnz = 0usize;
        for s in &shards {
            let m = s.meta();
            col_starts.push(col_starts.last().unwrap() + m.p);
            nnz += m.nnz;
        }
        let p = *col_starts.last().unwrap();
        Ok(ShardedStore { inner: Arc::new(ShardInner { shards, col_starts, n, p, nnz }) })
    }

    /// Open a sharded store and read its labels (from shard 0; open
    /// already verified every shard carries the identical segment).
    pub fn open_dataset(paths: &[PathBuf]) -> Result<(ShardedStore, Vec<f64>), SolveError> {
        let store = ShardedStore::open(paths)?;
        let y = store.read_labels()?;
        Ok((store, y))
    }

    /// Read the label segment (verified identical across shards).
    pub fn read_labels(&self) -> Result<Vec<f64>, SolveError> {
        self.inner.shards[0].read_labels()
    }

    /// Combined shape metadata.
    pub fn meta(&self) -> StoreMeta {
        StoreMeta { n: self.inner.n, p: self.inner.p, nnz: self.inner.nnz }
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Shard `s`'s store handle.
    pub fn shard(&self, s: usize) -> &OocColumnStore {
        &self.inner.shards[s]
    }

    /// Global column range owned by shard `s`.
    pub fn shard_cols(&self, s: usize) -> (usize, usize) {
        (self.inner.col_starts[s], self.inner.col_starts[s + 1])
    }

    /// Cumulative column boundaries (length = shards + 1) — the group
    /// bounds handed to the aligned pool scans.
    pub fn col_starts(&self) -> &[usize] {
        &self.inner.col_starts
    }

    /// Per-shard I/O counters, in shard order.
    pub fn io_stats_per_shard(&self) -> Vec<IoStats> {
        self.inner.shards.iter().map(|s| s.io_stats()).collect()
    }

    /// Combined I/O counters across all shards.
    pub fn io_stats(&self) -> IoStats {
        self.inner.shards.iter().fold(IoStats::default(), |a, s| a.merge(s.io_stats()))
    }

    /// Owning shard and shard-local column index of global column `j`.
    #[inline]
    fn locate(&self, j: usize) -> (usize, usize) {
        debug_assert!(j < self.inner.p);
        let s = self.inner.col_starts.partition_point(|&c| c <= j) - 1;
        (s, j - self.inner.col_starts[s])
    }

    /// Run `f` on column j's stored `(row indices, values)` slices,
    /// served from the owning shard's chunk cache.
    #[inline]
    pub fn with_col<R>(&self, j: usize, f: impl FnOnce(&[u32], &[f64]) -> R) -> R {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].with_col(lj, f)
    }

    /// Materialize the selected columns as an in-memory CSC matrix
    /// (working-set restriction; the hot paths use zero-copy views).
    pub fn select_columns_csc(&self, keep: &[usize]) -> CscMatrix {
        let cols: Vec<Vec<(u32, f64)>> = keep
            .iter()
            .map(|&j| {
                self.with_col(j, |idx, val| {
                    idx.iter().copied().zip(val.iter().copied()).collect()
                })
            })
            .collect();
        CscMatrix::from_columns(self.inner.n, cols)
    }

    /// Materialize the whole sharded design as an in-memory CSC matrix
    /// (tests / problems that fit in RAM).
    pub fn to_csc(&self) -> CscMatrix {
        self.select_columns_csc(&(0..self.inner.p).collect::<Vec<_>>())
    }

    /// Stream every shard through the finiteness gate, reporting the
    /// first offender with its *global* column index.
    pub fn validate_values(&self) -> Result<(), SolveError> {
        for (s, shard) in self.inner.shards.iter().enumerate() {
            shard.validate_values().map_err(|e| match e {
                SolveError::NonFiniteDesign { row, col, value } => {
                    SolveError::NonFiniteDesign {
                        row,
                        col: col + self.inner.col_starts[s],
                        value,
                    }
                }
                other => other,
            })?;
        }
        Ok(())
    }
}

impl DesignOps for ShardedStore {
    #[inline]
    fn n(&self) -> usize {
        self.inner.n
    }

    #[inline]
    fn p(&self) -> usize {
        self.inner.p
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_dot(lj, v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_axpy(lj, alpha, out)
    }

    #[inline]
    fn col_norm_sq(&self, j: usize) -> f64 {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_norm_sq(lj)
    }

    fn col_nnz(&self, j: usize) -> usize {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_nnz(lj)
    }

    fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.inner.p);
        assert_eq!(out.len(), self.inner.n);
        out.fill(0.0);
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                self.col_axpy(j, b, out);
            }
        }
    }

    fn col_cost_hint(&self) -> usize {
        (self.inner.nnz / self.inner.p.max(1)).max(1)
    }

    fn xt_vec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.inner.n);
        assert_eq!(out.len(), self.inner.p);
        crate::util::par::par_fill_cost_grouped(
            out,
            self.col_cost_hint(),
            &self.inner.col_starts,
            |j| self.col_dot(j, v),
        );
    }

    fn xt_abs_max(&self, v: &[f64]) -> f64 {
        crate::util::par::par_max_cost_grouped(
            self.inner.p,
            self.col_cost_hint(),
            &self.inner.col_starts,
            |j| self.col_dot(j, v).abs(),
        )
        .max(0.0)
    }

    fn xt_vec_abs_max(&self, v: &[f64], out: &mut [f64]) -> f64 {
        assert_eq!(v.len(), self.inner.n);
        assert_eq!(out.len(), self.inner.p);
        crate::util::par::par_fill_abs_max_grouped(
            out,
            self.col_cost_hint(),
            &self.inner.col_starts,
            |j| self.col_dot(j, v),
        )
    }

    fn col_norms_sq(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.inner.p];
        crate::util::par::par_fill_cost_grouped(
            &mut out,
            self.col_cost_hint(),
            &self.inner.col_starts,
            |j| self.col_norm_sq(j),
        );
        out
    }

    fn gather_dense(&self, cols: &[usize], out: &mut Vec<f64>) {
        let n = self.inner.n;
        out.clear();
        out.resize(cols.len() * n, 0.0);
        for (c, &j) in cols.iter().enumerate() {
            let dst = &mut out[c * n..(c + 1) * n];
            self.with_col(j, |idx, val| {
                for (&i, &v) in idx.iter().zip(val) {
                    dst[i as usize] = v;
                }
            });
        }
    }

    fn nnz(&self) -> usize {
        self.inner.nnz
    }

    fn shadow_f32(&self) -> crate::data::shadow::ShadowF32 {
        // One chunk-streamed f32 source per shard: the f32 sweep rides
        // every shard's prefetch stream, peak resident shadow bytes stay
        // bounded by (cache capacity × chunk size) × shards — never a
        // full-design copy.
        crate::data::shadow::ShadowF32::streamed(
            self.inner.shards.iter().map(|s| F32Stream::new(s.clone())).collect(),
        )
    }

    #[inline]
    fn col_wnorm_sq(&self, j: usize, w: &[f64]) -> f64 {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_wnorm_sq(lj, w)
    }

    #[inline]
    fn col_waxpy(&self, j: usize, alpha: f64, w: &[f64], out: &mut [f64]) {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_waxpy(lj, alpha, w, out)
    }

    fn col_dot_lanes(&self, j: usize, v: &[f64], n: usize, lanes: &[usize], out: &mut [f64]) {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_dot_lanes(lj, v, n, lanes, out)
    }

    fn col_axpy_lanes(&self, j: usize, alphas: &[f64], v: &mut [f64], n: usize, lanes: &[usize]) {
        let (s, lj) = self.locate(j);
        self.inner.shards[s].col_axpy_lanes(lj, alphas, v, n, lanes)
    }
}

// ---------------------------------------------------------------------
// Shard writer
// ---------------------------------------------------------------------

/// Even column bounds for `k` shards over `p` columns: shard `s` covers
/// `⌊s·p/k⌋ .. ⌊(s+1)·p/k⌋` (sizes differ by at most one column).
pub fn even_bounds(p: usize, k: usize) -> Vec<usize> {
    assert!(k >= 1, "shard count must be >= 1");
    (0..=k).map(|s| s * p / k).collect()
}

/// Split `(x, y)` into one standalone store per path with evenly sized
/// contiguous column ranges. Each shard file is a complete CELERCS1
/// store (full label segment), openable on its own or as part of the
/// sharded set.
pub fn write_sharded_store<D: DesignOps + ?Sized>(
    paths: &[PathBuf],
    x: &D,
    y: &[f64],
) -> Result<Vec<StoreMeta>, SolveError> {
    write_sharded_store_with_bounds(paths, x, y, &even_bounds(x.p(), paths.len().max(1)))
}

/// [`write_sharded_store`] with explicit column bounds (cumulative,
/// `bounds[0] = 0`, last = p, monotone; one more entry than paths) —
/// deliberately misaligned shard splits are how `tests/prop_shard.rs`
/// stresses the routing.
pub fn write_sharded_store_with_bounds<D: DesignOps + ?Sized>(
    paths: &[PathBuf],
    x: &D,
    y: &[f64],
    bounds: &[usize],
) -> Result<Vec<StoreMeta>, SolveError> {
    let bad = |detail: String| SolveError::StoreFormat { path: String::new(), detail };
    if paths.is_empty() {
        return Err(bad("sharded store needs at least one shard path".into()));
    }
    if bounds.len() != paths.len() + 1
        || bounds[0] != 0
        || *bounds.last().unwrap() != x.p()
        || bounds.windows(2).any(|w| w[0] > w[1])
    {
        return Err(bad(format!(
            "shard bounds {bounds:?} are not a monotone 0..={} split into {} ranges",
            x.p(),
            paths.len()
        )));
    }
    paths
        .iter()
        .enumerate()
        .map(|(s, path)| ooc::write_store_cols(path.as_path(), x, y, bounds[s], bounds[s + 1]))
        .collect()
}

/// Shard file path convention of `celer convert --shards N`: the base
/// output path for a single shard, `{out}.s{k}` for k ≥ 2 shards.
pub fn shard_paths(out: &Path, shards: usize) -> Vec<PathBuf> {
    if shards <= 1 {
        vec![out.to_path_buf()]
    } else {
        (0..shards)
            .map(|s| {
                let mut os = out.as_os_str().to_os_string();
                os.push(format!(".s{s}"));
                PathBuf::from(os)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("celer_shard_unit_{}_{name}", std::process::id()))
    }

    fn random_csc(seed: u64, n: usize, p: usize, density: f64) -> (CscMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0; n * p];
        for v in dense.iter_mut() {
            if rng.uniform() < density {
                *v = rng.normal();
            }
        }
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (CscMatrix::from_dense(n, p, &dense), y)
    }

    #[test]
    fn even_bounds_cover_and_balance() {
        for (p, k) in [(10, 3), (7, 7), (5, 1), (3, 5)] {
            let b = even_bounds(p, k);
            assert_eq!(b.len(), k + 1);
            assert_eq!((b[0], *b.last().unwrap()), (0, p));
            assert!(b.windows(2).all(|w| w[0] <= w[1]));
            assert!(b.windows(2).all(|w| w[1] - w[0] <= p.div_ceil(k)));
        }
    }

    #[test]
    fn sharded_roundtrip_matches_csc() {
        let (csc, y) = random_csc(21, 19, 13, 0.4);
        let paths = vec![tmp("rt.s0"), tmp("rt.s1"), tmp("rt.s2")];
        let metas = write_sharded_store(&paths, &csc, &y).unwrap();
        assert_eq!(metas.iter().map(|m| m.p).sum::<usize>(), 13);
        let store = ShardedStore::open_with(&paths, 256, 2).unwrap();
        assert_eq!(store.meta(), StoreMeta { n: 19, p: 13, nnz: csc.nnz() });
        assert_eq!(store.read_labels().unwrap(), y);
        let v: Vec<f64> = (0..19).map(|i| (i as f64) * 0.5 - 4.0).collect();
        for j in 0..13 {
            assert_eq!(store.col_nnz(j), csc.col_nnz(j));
            assert_eq!(store.col_dot(j, &v).to_bits(), csc.col_dot(j, &v).to_bits());
            assert_eq!(store.col_norm_sq(j).to_bits(), csc.col_norm_sq(j).to_bits());
        }
        let round = store.to_csc();
        for j in 0..13 {
            assert_eq!(round.col(j), csc.col(j));
        }
        for p in &paths {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn open_rejects_mixed_and_missing_shards() {
        let (a, ya) = random_csc(31, 11, 6, 0.5);
        let (b, yb) = random_csc(32, 11, 6, 0.5);
        let pa = vec![tmp("mix_a.s0"), tmp("mix_a.s1")];
        let pb = vec![tmp("mix_b.s0"), tmp("mix_b.s1")];
        write_sharded_store(&pa, &a, &ya).unwrap();
        write_sharded_store(&pb, &b, &yb).unwrap();
        // Mixing shards of different datasets: labels disagree.
        match ShardedStore::open(&[pa[0].clone(), pb[1].clone()]) {
            Err(SolveError::StoreFormat { .. }) => {}
            other => panic!("expected StoreFormat on mixed shards, got {other:?}"),
        }
        // Missing shard file.
        match ShardedStore::open(&[pa[0].clone(), tmp("does_not_exist.s1")]) {
            Err(SolveError::StoreFormat { .. }) => {}
            other => panic!("expected StoreFormat on missing shard, got {other:?}"),
        }
        for p in pa.iter().chain(&pb) {
            let _ = std::fs::remove_file(p);
        }
    }
}
