//! Out-of-core CSC column store: designs larger than RAM, swept at
//! disk bandwidth.
//!
//! The paper's speedup story is about touching less of the design per
//! epoch (working sets, Gap Safe screening); once p ≫ RAM the remaining
//! bottleneck is the memory hierarchy itself — the sweep runs at
//! whatever bandwidth the storage layer delivers. This module grows the
//! svmlight reader ([`crate::data::svmlight`]) into an on-disk column
//! store so the f64 design never has to be resident:
//!
//! - **On-disk layout** (all integers little-endian):
//!   `magic "CELERCS1" | version u32 | flags u32 | n u64 | p u64 |
//!   nnz u64 | y (n × f64) | indptr ((p+1) × u64) | indices (nnz × u32)
//!   | data (nnz × f64)` — a complete dataset in one file, CSC segments
//!   laid out exactly like the in-memory [`CscMatrix`].
//! - **Chunked column access**: columns are grouped into byte-bounded
//!   chunks (default [`DEFAULT_CHUNK_BYTES`]); a chunk is read with
//!   positioned reads (`std::os::unix::fs::FileExt::read_at` — `&self`,
//!   thread-safe, no seek state) and decoded into a pooled buffer held
//!   in a small LRU cache (a handful of chunks, sized to the worker
//!   count — the sharded scans of [`crate::util::par`] give each worker
//!   a contiguous column range, so one resident chunk per worker
//!   suffices).
//! - **Double-buffered prefetch**: the first touch of chunk k hints a
//!   dedicated background I/O thread at chunk k+1, so the next chunk
//!   streams from disk into a recycled buffer while the workers sweep
//!   the current one. Pool workers never block on prefetch I/O — a miss
//!   simply loads synchronously on the touching thread.
//! - **Bit-identity**: every kernel runs on the same decoded
//!   `(indices, values)` entry slices as the in-memory CSC path —
//!   single-column ops through the same `util::simd` gather kernels,
//!   lane ops through the shared decode-once entry kernels in
//!   [`crate::data::csc`] — so a λ-path solved against the store is
//!   bit-identical (β, gap certificates) to the in-memory solve
//!   (pinned in `tests/prop_ooc.rs`). Caching and prefetch affect only
//!   *when* bytes move, never the arithmetic.
//!
//! The batched multi-λ engine ([`crate::solvers::batch`]) is the
//! natural amortizer here: each column fetched from disk serves B
//! λ-lanes (and q block widths), so the per-lane I/O cost shrinks by
//! the lane count — `BENCH_9.json` records the measured amortization
//! factor.
//!
//! **Failure policy.** Everything checkable up front is a typed
//! [`SolveError::StoreFormat`] at [`OocColumnStore::open`] (bad magic,
//! version, truncated segments, non-monotone column index) — a corrupt
//! header can never panic. Mid-file corruption (a stored row index ≥ n)
//! is caught at chunk-decode time and fail-stops with a clear panic:
//! column accessors cannot return `Result` on the hot path, and the
//! check is what keeps the unchecked gather kernels sound. Streaming
//! the whole store through the PR-8 validation gate
//! ([`crate::data::validate::validate_design`]) reports non-finite
//! entries as typed errors before any epoch runs.

use crate::data::csc::{self, CscMatrix};
use crate::data::design::DesignOps;
use crate::data::svmlight::parse_svmlight_typed;
use crate::util::error::SolveError;
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// File magic: "CELER Column Store v1".
pub const MAGIC: [u8; 8] = *b"CELERCS1";
/// Store format version written/accepted by this build.
pub const VERSION: u32 = 1;
/// Fixed header length in bytes (magic + version + flags + n + p + nnz).
const HEADER_LEN: u64 = 40;
/// Bytes of stored entries per chunk (soft bound; every chunk holds at
/// least one column). 4 MiB ≈ a few hundred k entries — large enough to
/// amortize a positioned read, small enough that a handful of resident
/// chunks stay cache-friendly.
pub const DEFAULT_CHUNK_BYTES: usize = 4 << 20;
/// Stored bytes per entry: u32 row index + f64 value.
const ENTRY_BYTES: usize = 12;

/// Shape metadata of a written/opened store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreMeta {
    pub n: usize,
    pub p: usize,
    pub nnz: usize,
}

/// I/O counters of one store (or, via [`IoStats::merge`], an aggregate
/// across the shards of a [`crate::data::shard::ShardedStore`]) since
/// open. Sweep-path loads and prefetch-thread loads are counted
/// separately, so stream health is observable per shard: a healthy
/// pipeline shows `sync_misses` ≪ chunks swept, with the bytes arriving
/// through `bytes_prefetched` instead of `bytes_read`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoStats {
    /// Bytes of stored entries decoded on the sweep path itself.
    pub bytes_read: u64,
    /// Chunks decoded on the sweep path itself.
    pub chunks_loaded: u64,
    /// Cache misses the prefetcher failed to hide (every one of these
    /// blocked a worker on disk I/O).
    pub sync_misses: u64,
    /// Chunks the prefetch thread streamed in ahead of use.
    pub prefetch_loads: u64,
    /// Prefetch hints that found the chunk already resident (the
    /// pipeline was ahead of the hint — no I/O needed).
    pub prefetch_hits: u64,
    /// Bytes of stored entries streamed in by the prefetch thread.
    pub bytes_prefetched: u64,
}

impl IoStats {
    /// Element-wise sum: the combined view across shards.
    pub fn merge(mut self, other: IoStats) -> IoStats {
        self.bytes_read += other.bytes_read;
        self.chunks_loaded += other.chunks_loaded;
        self.sync_misses += other.sync_misses;
        self.prefetch_loads += other.prefetch_loads;
        self.prefetch_hits += other.prefetch_hits;
        self.bytes_prefetched += other.bytes_prefetched;
        self
    }

    /// Total bytes decoded from disk on any path (sweep + prefetch).
    pub fn total_bytes(&self) -> u64 {
        self.bytes_read + self.bytes_prefetched
    }
}

/// Shared atomic backing of [`IoStats`]: written by the sweep path and
/// the prefetch thread, snapshotted by [`OocColumnStore::io_stats`].
#[derive(Default)]
struct IoCounters {
    bytes_read: AtomicU64,
    chunks_loaded: AtomicU64,
    sync_misses: AtomicU64,
    prefetch_loads: AtomicU64,
    prefetch_hits: AtomicU64,
    bytes_prefetched: AtomicU64,
}

impl IoCounters {
    fn snapshot(&self) -> IoStats {
        IoStats {
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            chunks_loaded: self.chunks_loaded.load(Ordering::Relaxed),
            sync_misses: self.sync_misses.load(Ordering::Relaxed),
            prefetch_loads: self.prefetch_loads.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            bytes_prefetched: self.bytes_prefetched.load(Ordering::Relaxed),
        }
    }
}

fn ferr(path: &Path, detail: impl Into<String>) -> SolveError {
    SolveError::StoreFormat { path: path.display().to_string(), detail: detail.into() }
}

// ---------------------------------------------------------------------
// Geometry: offsets, column ranges, chunk plan
// ---------------------------------------------------------------------

/// Immutable shape + layout of an opened store: segment offsets, the
/// resident column index (`indptr`), and the chunk plan.
struct Geometry {
    n: usize,
    p: usize,
    nnz: usize,
    /// Column pointers (entry offsets), length p+1 — resident in memory
    /// like the svmlight reader's; only indices/values stream from disk.
    indptr: Vec<u64>,
    /// Chunk c covers columns `chunk_starts[c] .. chunk_starts[c+1]`;
    /// length = nchunks + 1 with `chunk_starts[nchunks] = p`.
    chunk_starts: Vec<usize>,
    idx_off: u64,
    data_off: u64,
}

impl Geometry {
    fn nchunks(&self) -> usize {
        self.chunk_starts.len() - 1
    }

    /// Entry range of column j.
    #[inline]
    fn col_range(&self, j: usize) -> (usize, usize) {
        (self.indptr[j] as usize, self.indptr[j + 1] as usize)
    }

    /// Chunk containing column j.
    #[inline]
    fn chunk_of(&self, j: usize) -> usize {
        debug_assert!(j < self.p);
        self.chunk_starts.partition_point(|&s| s <= j) - 1
    }

    /// Column range of chunk c.
    #[inline]
    fn chunk_cols(&self, c: usize) -> (usize, usize) {
        (self.chunk_starts[c], self.chunk_starts[c + 1])
    }

    /// Entry range of chunk c.
    #[inline]
    fn chunk_entries(&self, c: usize) -> (usize, usize) {
        let (j0, j1) = self.chunk_cols(c);
        (self.indptr[j0] as usize, self.indptr[j1] as usize)
    }

    /// Greedy chunk plan: accumulate columns until the stored bytes
    /// exceed the budget (always at least one column per chunk). The
    /// plan depends only on (indptr, chunk_bytes) — deterministic.
    fn plan_chunks(&mut self, chunk_bytes: usize) {
        let budget = chunk_bytes.max(ENTRY_BYTES);
        let mut starts = vec![0usize];
        let mut acc = 0usize;
        for j in 0..self.p {
            let (lo, hi) = self.col_range(j);
            let b = (hi - lo) * ENTRY_BYTES;
            if acc > 0 && acc + b > budget {
                starts.push(j);
                acc = 0;
            }
            acc += b;
        }
        starts.push(self.p);
        self.chunk_starts = starts;
    }
}

// ---------------------------------------------------------------------
// Chunk cache: LRU over decoded chunks, recycled (pooled) buffers
// ---------------------------------------------------------------------

/// One decoded chunk: the stored entries of a contiguous column range.
struct ChunkData {
    /// First entry index covered (offset into the on-disk segments).
    entry0: usize,
    indices: Vec<u32>,
    values: Vec<f64>,
}

struct CacheInner {
    map: HashMap<usize, Arc<ChunkData>>,
    /// Access order, least-recent first.
    lru: VecDeque<usize>,
    /// Recycled decode buffers from evicted chunks (the "pooled
    /// buffer": a steady-state sweep allocates nothing per chunk).
    free: Vec<(Vec<u32>, Vec<f64>)>,
    /// Recycled raw read buffers.
    raw: Vec<Vec<u8>>,
}

struct Cache {
    capacity: usize,
    inner: Mutex<CacheInner>,
}

impl Cache {
    fn new(capacity: usize) -> Cache {
        Cache {
            capacity: capacity.max(2),
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                lru: VecDeque::new(),
                free: Vec::new(),
                raw: Vec::new(),
            }),
        }
    }

    /// Cache lookup; a hit refreshes the LRU position.
    fn get(&self, c: usize) -> Option<Arc<ChunkData>> {
        let mut st = self.inner.lock().unwrap();
        let hit = st.map.get(&c).cloned();
        if hit.is_some() {
            if let Some(pos) = st.lru.iter().position(|&k| k == c) {
                st.lru.remove(pos);
            }
            st.lru.push_back(c);
        }
        hit
    }

    /// Take recycled decode + raw buffers (empty vectors when none).
    fn take_buffers(&self) -> (Vec<u32>, Vec<f64>, Vec<u8>) {
        let mut st = self.inner.lock().unwrap();
        let (idx, val) = st.free.pop().unwrap_or_default();
        let raw = st.raw.pop().unwrap_or_default();
        (idx, val, raw)
    }

    /// Publish a freshly decoded chunk; if another thread raced us to
    /// it, keep the incumbent and recycle ours. Evicts LRU chunks past
    /// capacity, recycling their buffers when unshared.
    fn publish(&self, c: usize, data: ChunkData, raw: Vec<u8>) -> Arc<ChunkData> {
        let mut st = self.inner.lock().unwrap();
        st.raw.push(raw);
        if let Some(existing) = st.map.get(&c).cloned() {
            st.free.push((data.indices, data.values));
            return existing;
        }
        let arc = Arc::new(data);
        st.map.insert(c, arc.clone());
        st.lru.push_back(c);
        while st.map.len() > self.capacity {
            let Some(victim) = st.lru.pop_front() else { break };
            if let Some(old) = st.map.remove(&victim) {
                if let Ok(owned) = Arc::try_unwrap(old) {
                    st.free.push((owned.indices, owned.values));
                }
            }
        }
        arc
    }
}

/// Read + decode chunk `c` into (recycled) buffers, validate its row
/// indices, and publish it. Shared by the touching thread (cache miss)
/// and the prefetch thread.
fn load_chunk(file: &File, path: &Path, geom: &Geometry, cache: &Cache, c: usize) -> Arc<ChunkData> {
    if let Some(d) = cache.get(c) {
        return d;
    }
    let (e0, e1) = geom.chunk_entries(c);
    let m = e1 - e0;
    let (mut idx, mut val, mut raw) = cache.take_buffers();
    let read = |raw: &mut Vec<u8>, len: usize, off: u64| {
        raw.clear();
        raw.resize(len, 0);
        // Environmental I/O failures after a validated open (device
        // error, file unlinked + truncated underneath us) fail-stop.
        file.read_exact_at(raw, off).unwrap_or_else(|e| {
            panic!("celer column store {}: chunk {c} read failed: {e}", path.display())
        });
    };
    read(&mut raw, 4 * m, geom.idx_off + 4 * e0 as u64);
    idx.clear();
    idx.reserve(m);
    idx.extend(raw.chunks_exact(4).map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
    // This bound is what keeps the unchecked gather kernels sound
    // against mid-file corruption; see the module-level failure policy.
    for &i in &idx {
        assert!(
            (i as usize) < geom.n,
            "celer column store {}: corrupt row index {i} >= n = {} in chunk {c}",
            path.display(),
            geom.n
        );
    }
    read(&mut raw, 8 * m, geom.data_off + 8 * e0 as u64);
    val.clear();
    val.reserve(m);
    val.extend(
        raw.chunks_exact(8)
            .map(|b| f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])),
    );
    cache.publish(c, ChunkData { entry0: e0, indices: idx, values: val }, raw)
}

// ---------------------------------------------------------------------
// Prefetcher: one background I/O thread per store
// ---------------------------------------------------------------------

struct PfState {
    /// Latest requested chunk (latest-wins: sweeps move forward, a
    /// stale hint is worthless by the time it would be honored).
    want: Option<usize>,
    shutdown: bool,
}

struct PfShared {
    state: Mutex<PfState>,
    cv: Condvar,
}

struct Prefetcher {
    shared: Arc<PfShared>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    fn start(
        file: Arc<File>,
        path: PathBuf,
        geom: Arc<Geometry>,
        cache: Arc<Cache>,
        io: Arc<IoCounters>,
    ) -> Prefetcher {
        let shared = Arc::new(PfShared {
            state: Mutex::new(PfState { want: None, shutdown: false }),
            cv: Condvar::new(),
        });
        let sh = shared.clone();
        let handle = std::thread::Builder::new()
            .name("celer-ooc-prefetch".into())
            .spawn(move || loop {
                let c = {
                    let mut st = sh.state.lock().unwrap();
                    loop {
                        if st.shutdown {
                            return;
                        }
                        if let Some(c) = st.want.take() {
                            break c;
                        }
                        st = sh.cv.wait(st).unwrap();
                    }
                };
                if cache.get(c).is_some() {
                    // Hint already landed (an earlier prefetch or a
                    // sweep-path load beat us) — one lock round-trip.
                    io.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    // A racing sweep-path load between the check and
                    // here is benign — `publish` keeps the incumbent —
                    // so the counters are stream-health telemetry, not
                    // an exact disk ledger.
                    load_chunk(&file, &path, &geom, &cache, c);
                    let (e0, e1) = geom.chunk_entries(c);
                    io.prefetch_loads.fetch_add(1, Ordering::Relaxed);
                    io.bytes_prefetched
                        .fetch_add(((e1 - e0) * ENTRY_BYTES) as u64, Ordering::Relaxed);
                }
            })
            .expect("spawn ooc prefetch thread");
        Prefetcher { shared, handle: Some(handle) }
    }

    fn request(&self, c: usize) {
        let mut st = self.shared.state.lock().unwrap();
        st.want = Some(c);
        self.shared.cv.notify_one();
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.cv.notify_one();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------
// The store handle
// ---------------------------------------------------------------------

struct StoreInner {
    path: PathBuf,
    file: Arc<File>,
    geom: Arc<Geometry>,
    cache: Arc<Cache>,
    prefetch: Prefetcher,
    /// Most recently touched chunk; the transition to a new chunk is
    /// what triggers the successor hint (double-buffer pipeline).
    last_chunk: AtomicUsize,
    /// Stream-health counters, shared with the prefetch thread.
    io: Arc<IoCounters>,
}

/// An on-disk CSC column store implementing [`DesignOps`]: the engine,
/// views, and lane kernels run on it unchanged. Cloning is cheap (a
/// shared handle); the chunk cache and prefetcher are per-store, shared
/// across clones.
#[derive(Clone)]
pub struct OocColumnStore {
    inner: Arc<StoreInner>,
}

impl fmt::Debug for OocColumnStore {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OocColumnStore")
            .field("path", &self.inner.path)
            .field("n", &self.inner.geom.n)
            .field("p", &self.inner.geom.p)
            .field("nnz", &self.inner.geom.nnz)
            .field("chunks", &self.inner.geom.nchunks())
            .finish()
    }
}

impl OocColumnStore {
    /// Open a store with default chunking ([`DEFAULT_CHUNK_BYTES`]) and
    /// an auto-sized cache (worker count + 2, min 4). Every structural
    /// defect — bad magic, unsupported version, truncated file,
    /// non-monotone column index — is a typed
    /// [`SolveError::StoreFormat`]; this function never panics on a
    /// corrupt file.
    pub fn open(path: &Path) -> Result<OocColumnStore, SolveError> {
        OocColumnStore::open_with(path, DEFAULT_CHUNK_BYTES, 0)
    }

    /// [`OocColumnStore::open`] with explicit chunk byte budget and
    /// cache size in chunks (`0` = auto).
    pub fn open_with(
        path: &Path,
        chunk_bytes: usize,
        cache_chunks: usize,
    ) -> Result<OocColumnStore, SolveError> {
        let file = File::open(path).map_err(|e| ferr(path, format!("cannot open: {e}")))?;
        let flen = file.metadata().map_err(|e| ferr(path, format!("cannot stat: {e}")))?.len();
        if flen < HEADER_LEN {
            return Err(ferr(
                path,
                format!("file too short for header: {flen} bytes < {HEADER_LEN}"),
            ));
        }
        let mut head = [0u8; HEADER_LEN as usize];
        file.read_exact_at(&mut head, 0)
            .map_err(|e| ferr(path, format!("header read failed: {e}")))?;
        if head[..8] != MAGIC {
            return Err(ferr(path, "bad magic (not a celer column store)"));
        }
        let u32le = |o: usize| u32::from_le_bytes([head[o], head[o + 1], head[o + 2], head[o + 3]]);
        let u64le = |o: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&head[o..o + 8]);
            u64::from_le_bytes(b)
        };
        let version = u32le(8);
        if version != VERSION {
            return Err(ferr(path, format!("unsupported version {version} (expected {VERSION})")));
        }
        let (n64, p64, nnz64) = (u64le(16), u64le(24), u64le(32));
        let to_usize = |v: u64, what: &str| -> Result<usize, SolveError> {
            usize::try_from(v).map_err(|_| ferr(path, format!("{what} = {v} overflows usize")))
        };
        let n = to_usize(n64, "n")?;
        let p = to_usize(p64, "p")?;
        let nnz = to_usize(nnz64, "nnz")?;
        if n64 > u32::MAX as u64 {
            return Err(ferr(path, format!("n = {n} exceeds the u32 row-index range")));
        }
        // Segment offsets; checked arithmetic so a hostile header can't
        // wrap the expected length into a bogus match.
        let expect = (|| {
            let y_end = HEADER_LEN.checked_add(n64.checked_mul(8)?)?;
            let indptr_end = y_end.checked_add(p64.checked_add(1)?.checked_mul(8)?)?;
            let idx_end = indptr_end.checked_add(nnz64.checked_mul(4)?)?;
            idx_end.checked_add(nnz64.checked_mul(8)?)
        })()
        .ok_or_else(|| ferr(path, "header shape overflows the file length computation"))?;
        if flen != expect {
            return Err(ferr(
                path,
                format!(
                    "truncated or oversized file: header (n={n}, p={p}, nnz={nnz}) \
                     implies {expect} bytes, found {flen}"
                ),
            ));
        }
        let indptr_off = HEADER_LEN + n64 * 8;
        let idx_off = indptr_off + (p64 + 1) * 8;
        let data_off = idx_off + nnz64 * 4;
        // Read the resident column index and validate monotonicity.
        let mut raw = vec![0u8; (p + 1) * 8];
        file.read_exact_at(&mut raw, indptr_off)
            .map_err(|e| ferr(path, format!("indptr read failed: {e}")))?;
        let indptr: Vec<u64> = raw
            .chunks_exact(8)
            .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
            .collect();
        if indptr[0] != 0 {
            return Err(ferr(path, format!("indptr[0] = {} (expected 0)", indptr[0])));
        }
        if let Some(j) = indptr.windows(2).position(|w| w[0] > w[1]) {
            return Err(ferr(
                path,
                format!("non-monotone column index at column {j}: {} > {}", indptr[j], indptr[j + 1]),
            ));
        }
        if indptr[p] != nnz64 {
            return Err(ferr(
                path,
                format!("indptr[p] = {} does not match nnz = {nnz}", indptr[p]),
            ));
        }
        let mut geom =
            Geometry { n, p, nnz, indptr, chunk_starts: Vec::new(), idx_off, data_off };
        geom.plan_chunks(chunk_bytes);
        let geom = Arc::new(geom);
        let capacity = if cache_chunks > 0 {
            cache_chunks
        } else {
            (crate::util::par::num_threads() + 2).max(4)
        };
        let cache = Arc::new(Cache::new(capacity));
        let file = Arc::new(file);
        let io = Arc::new(IoCounters::default());
        let prefetch = Prefetcher::start(
            file.clone(),
            path.to_path_buf(),
            geom.clone(),
            cache.clone(),
            io.clone(),
        );
        Ok(OocColumnStore {
            inner: Arc::new(StoreInner {
                path: path.to_path_buf(),
                file,
                geom,
                cache,
                prefetch,
                last_chunk: AtomicUsize::new(usize::MAX),
                io,
            }),
        })
    }

    /// Open a store and read its label segment: the out-of-core face of
    /// [`crate::data::svmlight::Dataset`].
    pub fn open_dataset(path: &Path) -> Result<(OocColumnStore, Vec<f64>), SolveError> {
        let store = OocColumnStore::open(path)?;
        let y = store.read_labels()?;
        Ok((store, y))
    }

    /// Read the y segment (length n) from disk.
    pub fn read_labels(&self) -> Result<Vec<f64>, SolveError> {
        let n = self.inner.geom.n;
        let mut raw = vec![0u8; n * 8];
        self.inner
            .file
            .read_exact_at(&mut raw, HEADER_LEN)
            .map_err(|e| ferr(&self.inner.path, format!("labels read failed: {e}")))?;
        Ok(raw
            .chunks_exact(8)
            .map(|b| f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
            .collect())
    }

    /// Shape metadata.
    pub fn meta(&self) -> StoreMeta {
        let g = &self.inner.geom;
        StoreMeta { n: g.n, p: g.p, nnz: g.nnz }
    }

    /// Path this store was opened from.
    pub fn path(&self) -> &Path {
        &self.inner.path
    }

    /// Number of column chunks in the streaming plan.
    pub fn nchunks(&self) -> usize {
        self.inner.geom.nchunks()
    }

    /// I/O counters since open. Sweep-path loads (`bytes_read`,
    /// `chunks_loaded`, `sync_misses`) and prefetch-thread activity
    /// (`prefetch_loads`, `prefetch_hits`, `bytes_prefetched`) are
    /// tallied separately: a low `sync_misses` relative to
    /// [`OocColumnStore::nchunks`] per sweep — with the bytes showing up
    /// in `bytes_prefetched` — is direct evidence of the overlap the
    /// double buffer bought. `celer path --store` prints this per shard.
    pub fn io_stats(&self) -> IoStats {
        self.inner.io.snapshot()
    }

    /// Largest stored-entry count of any chunk in the plan: the
    /// buffer-sizing bound for streamed consumers (one recycled buffer
    /// of this many entries can hold any chunk).
    pub fn max_chunk_entries(&self) -> usize {
        let g = &self.inner.geom;
        (0..g.nchunks())
            .map(|c| {
                let (e0, e1) = g.chunk_entries(c);
                e1 - e0
            })
            .max()
            .unwrap_or(0)
    }

    /// Fetch the chunk containing column range work, maintaining the
    /// prefetch pipeline: the first touch of a new chunk hints the
    /// background thread at its successor.
    fn chunk(&self, c: usize) -> Arc<ChunkData> {
        let i = &*self.inner;
        if i.last_chunk.swap(c, Ordering::Relaxed) != c && c + 1 < i.geom.nchunks() {
            i.prefetch.request(c + 1);
        }
        if let Some(d) = i.cache.get(c) {
            return d;
        }
        i.io.sync_misses.fetch_add(1, Ordering::Relaxed);
        let d = load_chunk(&i.file, &i.path, &i.geom, &i.cache, c);
        let (e0, e1) = i.geom.chunk_entries(c);
        i.io.bytes_read.fetch_add(((e1 - e0) * ENTRY_BYTES) as u64, Ordering::Relaxed);
        i.io.chunks_loaded.fetch_add(1, Ordering::Relaxed);
        d
    }

    /// Run `f` on column j's stored `(row indices, values)` slices —
    /// the same entry slices the in-memory [`CscMatrix::col`] returns,
    /// served from the chunk cache.
    #[inline]
    pub fn with_col<R>(&self, j: usize, f: impl FnOnce(&[u32], &[f64]) -> R) -> R {
        let g = &self.inner.geom;
        let chunk = self.chunk(g.chunk_of(j));
        let (lo, hi) = g.col_range(j);
        let (lo, hi) = (lo - chunk.entry0, hi - chunk.entry0);
        f(&chunk.indices[lo..hi], &chunk.values[lo..hi])
    }

    /// Materialize the selected columns as an in-memory CSC matrix
    /// (working-set restriction; the hot paths use zero-copy views).
    pub fn select_columns_csc(&self, keep: &[usize]) -> CscMatrix {
        let n = self.inner.geom.n;
        let cols: Vec<Vec<(u32, f64)>> = keep
            .iter()
            .map(|&j| self.with_col(j, |idx, val| idx.iter().copied().zip(val.iter().copied()).collect()))
            .collect();
        CscMatrix::from_columns(n, cols)
    }

    /// Materialize the whole store as an in-memory CSC matrix,
    /// streaming chunk by chunk (tests / problems that fit in RAM).
    pub fn to_csc(&self) -> CscMatrix {
        let g = &self.inner.geom;
        let mut indices = Vec::with_capacity(g.nnz);
        let mut data = Vec::with_capacity(g.nnz);
        for c in 0..g.nchunks() {
            let chunk = self.chunk(c);
            indices.extend_from_slice(&chunk.indices);
            data.extend_from_slice(&chunk.values);
        }
        let indptr: Vec<usize> = g.indptr.iter().map(|&v| v as usize).collect();
        CscMatrix::new(g.n, g.p, indptr, indices, data)
    }

    /// Stream every stored entry through the PR-8 validation gate's
    /// finiteness check, reporting the first offender as a typed
    /// [`SolveError::NonFiniteDesign`]. Backs
    /// [`crate::data::validate::validate_design`] for out-of-core
    /// designs.
    pub fn validate_values(&self) -> Result<(), SolveError> {
        let g = &self.inner.geom;
        for c in 0..g.nchunks() {
            let chunk = self.chunk(c);
            let (j0, j1) = g.chunk_cols(c);
            for j in j0..j1 {
                let (lo, hi) = g.col_range(j);
                for e in lo..hi {
                    let v = chunk.values[e - chunk.entry0];
                    if !v.is_finite() {
                        return Err(SolveError::NonFiniteDesign {
                            row: chunk.indices[e - chunk.entry0] as usize,
                            col: j,
                            value: v,
                        });
                    }
                }
            }
        }
        Ok(())
    }
}

impl DesignOps for OocColumnStore {
    #[inline]
    fn n(&self) -> usize {
        self.inner.geom.n
    }

    #[inline]
    fn p(&self) -> usize {
        self.inner.geom.p
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        // SAFETY: row indices are validated < n at chunk decode — the
        // same soundness argument as the in-memory CSC path.
        self.with_col(j, |idx, val| unsafe { crate::util::simd::gather_dot(idx, val, v) })
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        self.with_col(j, |idx, val| unsafe {
            crate::util::simd::gather_axpy(idx, val, alpha, out)
        })
    }

    #[inline]
    fn col_norm_sq(&self, j: usize) -> f64 {
        self.with_col(j, |_, val| crate::util::simd::dot(val, val))
    }

    fn col_nnz(&self, j: usize) -> usize {
        let (lo, hi) = self.inner.geom.col_range(j);
        hi - lo
    }

    fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        let g = &self.inner.geom;
        assert_eq!(beta.len(), g.p);
        assert_eq!(out.len(), g.n);
        out.fill(0.0);
        for j in 0..g.p {
            let b = beta[j];
            if b != 0.0 {
                self.col_axpy(j, b, out);
            }
        }
    }

    fn col_cost_hint(&self) -> usize {
        // Mean stored nnz per column — the same work model as the
        // in-memory CSC, so serial/parallel gating decisions match.
        let g = &self.inner.geom;
        (g.nnz / g.p.max(1)).max(1)
    }

    fn xt_vec(&self, v: &[f64], out: &mut [f64]) {
        let g = &self.inner.geom;
        assert_eq!(v.len(), g.n);
        assert_eq!(out.len(), g.p);
        // Sharded like CSC: workers get contiguous column ranges, so
        // concurrent chunk demand stays within the cache capacity.
        crate::util::par::par_fill_cost(out, self.col_cost_hint(), |j| self.col_dot(j, v));
    }

    fn gather_dense(&self, cols: &[usize], out: &mut Vec<f64>) {
        let n = self.inner.geom.n;
        out.clear();
        out.resize(cols.len() * n, 0.0);
        for (c, &j) in cols.iter().enumerate() {
            let dst = &mut out[c * n..(c + 1) * n];
            self.with_col(j, |idx, val| {
                for (&i, &v) in idx.iter().zip(val) {
                    dst[i as usize] = v;
                }
            });
        }
    }

    fn nnz(&self) -> usize {
        self.inner.geom.nnz
    }

    fn shadow_f32(&self) -> crate::data::shadow::ShadowF32 {
        // Chunk-streamed shadow: NO full f32 copy is ever materialized.
        // Each chunk is re-decoded to half width on demand into recycled
        // buffers riding the store's chunk plan and prefetcher, so on
        // p ≫ RAM problems *neither* precision's design is resident
        // (peak shadow bytes ≤ cache capacity × chunk size, asserted in
        // `tests/prop_shard.rs`). The cast per entry is the same
        // `v as f32` the resident shadow performs — kernels are
        // bit-identical to a resident sparse shadow of the same store.
        crate::data::shadow::ShadowF32::streamed(vec![F32Stream::new(self.clone())])
    }

    #[inline]
    fn col_wnorm_sq(&self, j: usize, w: &[f64]) -> f64 {
        self.with_col(j, |idx, val| unsafe { crate::util::simd::gather_wssq(idx, val, w) })
    }

    #[inline]
    fn col_waxpy(&self, j: usize, alpha: f64, w: &[f64], out: &mut [f64]) {
        debug_assert_eq!(w.len(), out.len());
        self.with_col(j, |idx, val| unsafe {
            crate::util::simd::gather_waxpy(idx, val, alpha, w, out)
        })
    }

    // Batched lane sweeps run on the SAME decode-once entry kernels as
    // the in-memory CSC (`csc::lane_dot_entries` / `lane_axpy_entries`)
    // over the same entry slices — bit-identical by construction, and
    // the amortization point of the whole store: one disk fetch serves
    // every live lane.
    fn col_dot_lanes(&self, j: usize, v: &[f64], n: usize, lanes: &[usize], out: &mut [f64]) {
        self.with_col(j, |idx, val| unsafe {
            csc::lane_dot_entries(idx, val, v, n, lanes, out)
        })
    }

    fn col_axpy_lanes(&self, j: usize, alphas: &[f64], v: &mut [f64], n: usize, lanes: &[usize]) {
        self.with_col(j, |idx, val| unsafe {
            csc::lane_axpy_entries(idx, val, alphas, v, n, lanes)
        })
    }
}

// ---------------------------------------------------------------------
// Streamed f32 chunks: half-width re-decode riding the chunk plan
// ---------------------------------------------------------------------

/// Resident bytes of one cached f32 chunk entry: u32 row index + f32
/// value (the half-width mirror of [`ENTRY_BYTES`]).
const F32_ENTRY_BYTES: usize = 8;

/// One half-width decoded chunk: the stored entries of a contiguous
/// column range, values cast `f64 → f32` (the identical cast the
/// resident [`crate::data::shadow::ShadowF32`] constructors perform, so
/// every downstream f32 kernel is bit-identical to the resident path).
struct F32Chunk {
    entry0: usize,
    indices: Vec<u32>,
    values: Vec<f32>,
}

struct F32CacheInner {
    map: HashMap<usize, Arc<F32Chunk>>,
    lru: VecDeque<usize>,
    /// Recycled buffers from evicted chunks — a steady-state f32 sweep
    /// allocates nothing per chunk, like the f64 cache.
    free: Vec<(Vec<u32>, Vec<f32>)>,
}

struct F32Shared {
    capacity: usize,
    inner: Mutex<F32CacheInner>,
    /// Bytes currently held by cached f32 chunks (indices + values).
    resident: AtomicU64,
    /// High-water mark of `resident` — what `tests/prop_shard.rs`
    /// asserts against the no-full-copy bound.
    peak: AtomicU64,
}

/// A chunk-streamed f32 view of an [`OocColumnStore`]: columns are
/// served as `(row indices, f32 values)` slices re-decoded per chunk
/// into a small LRU of recycled buffers. The f64 chunk is pulled
/// through the store's own cache + prefetch pipeline (`store.chunk`),
/// so the background thread still overlaps disk I/O with the sweep and
/// the cast itself runs at RAM speed. Peak resident shadow bytes are
/// bounded by `capacity × max chunk bytes` — never the full design.
/// Cloning shares the cache (like the store handle).
pub struct F32Stream {
    store: OocColumnStore,
    shared: Arc<F32Shared>,
}

impl Clone for F32Stream {
    fn clone(&self) -> F32Stream {
        F32Stream { store: self.store.clone(), shared: self.shared.clone() }
    }
}

impl fmt::Debug for F32Stream {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("F32Stream")
            .field("store", &self.store)
            .field("cache_chunks", &self.shared.capacity)
            .field("resident_bytes", &self.resident_bytes())
            .field("peak_resident_bytes", &self.peak_resident_bytes())
            .finish()
    }
}

impl F32Stream {
    /// Stream with an auto-sized f32 cache (same capacity rule as the
    /// store's f64 chunk cache: worker count + 2, min 4).
    pub fn new(store: OocColumnStore) -> F32Stream {
        F32Stream::with_capacity(store, 0)
    }

    /// Stream with an explicit f32 cache size in chunks (`0` = match
    /// the store's f64 cache capacity).
    pub fn with_capacity(store: OocColumnStore, cache_chunks: usize) -> F32Stream {
        let capacity =
            if cache_chunks > 0 { cache_chunks.max(2) } else { store.inner.cache.capacity };
        F32Stream {
            store,
            shared: Arc::new(F32Shared {
                capacity,
                inner: Mutex::new(F32CacheInner {
                    map: HashMap::new(),
                    lru: VecDeque::new(),
                    free: Vec::new(),
                }),
                resident: AtomicU64::new(0),
                peak: AtomicU64::new(0),
            }),
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.store.inner.geom.n
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.store.inner.geom.p
    }

    /// The backing store (e.g. for io_stats of the shared f64 stream).
    pub fn store(&self) -> &OocColumnStore {
        &self.store
    }

    /// Bytes currently held by cached f32 chunks.
    pub fn resident_bytes(&self) -> u64 {
        self.shared.resident.load(Ordering::Relaxed)
    }

    /// High-water mark of [`F32Stream::resident_bytes`] since open —
    /// the quantity the no-full-copy acceptance bound is asserted on.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.shared.peak.load(Ordering::Relaxed)
    }

    /// Upper bound on [`F32Stream::peak_resident_bytes`]: cache
    /// capacity × the largest chunk's f32 footprint.
    pub fn resident_bound_bytes(&self) -> u64 {
        (self.shared.capacity * self.store.max_chunk_entries() * F32_ENTRY_BYTES) as u64
    }

    /// Fetch (or re-decode) the f32 chunk `c`.
    fn chunk32(&self, c: usize) -> Arc<F32Chunk> {
        {
            let mut st = self.shared.inner.lock().unwrap();
            if let Some(hit) = st.map.get(&c).cloned() {
                if let Some(pos) = st.lru.iter().position(|&k| k == c) {
                    st.lru.remove(pos);
                }
                st.lru.push_back(c);
                return hit;
            }
        }
        // Miss: pull the f64 chunk through the store's cache + prefetch
        // pipeline (this is what keeps the background thread streaming
        // ahead of the f32 sweep), then cast into recycled buffers. The
        // f64 Arc is dropped as soon as the cast completes — the f32
        // cache never pins full-width chunks.
        let f64c = self.store.chunk(c);
        let (mut idx, mut val) = {
            let mut st = self.shared.inner.lock().unwrap();
            st.free.pop().unwrap_or_default()
        };
        idx.clear();
        idx.extend_from_slice(&f64c.indices);
        val.clear();
        val.reserve(f64c.values.len());
        val.extend(f64c.values.iter().map(|&v| v as f32));
        let chunk = F32Chunk { entry0: f64c.entry0, indices: idx, values: val };
        drop(f64c);
        let mut st = self.shared.inner.lock().unwrap();
        // Race-safe publish: keep the incumbent, recycle ours.
        if let Some(existing) = st.map.get(&c).cloned() {
            st.free.push((chunk.indices, chunk.values));
            return existing;
        }
        let mut delta = (chunk.indices.len() * F32_ENTRY_BYTES) as i64;
        let arc = Arc::new(chunk);
        st.map.insert(c, arc.clone());
        st.lru.push_back(c);
        while st.map.len() > self.shared.capacity {
            let Some(victim) = st.lru.pop_front() else { break };
            if let Some(old) = st.map.remove(&victim) {
                delta -= (old.indices.len() * F32_ENTRY_BYTES) as i64;
                if let Ok(owned) = Arc::try_unwrap(old) {
                    st.free.push((owned.indices, owned.values));
                }
            }
        }
        // Accounting under the lock, after eviction settles, so `peak`
        // never records the transient capacity+1 state.
        let resident = if delta >= 0 {
            self.shared.resident.fetch_add(delta as u64, Ordering::Relaxed) + delta as u64
        } else {
            self.shared.resident.fetch_sub((-delta) as u64, Ordering::Relaxed) - (-delta) as u64
        };
        self.shared.peak.fetch_max(resident, Ordering::Relaxed);
        arc
    }

    /// Run `f` on column j's `(row indices, f32 values)` slices — the
    /// same entry slices (same order, same `as f32` cast) a resident
    /// sparse [`crate::data::shadow::ShadowF32`] of this store holds.
    #[inline]
    pub fn with_col<R>(&self, j: usize, f: impl FnOnce(&[u32], &[f32]) -> R) -> R {
        let g = &self.store.inner.geom;
        let chunk = self.chunk32(g.chunk_of(j));
        let (lo, hi) = g.col_range(j);
        let (lo, hi) = (lo - chunk.entry0, hi - chunk.entry0);
        f(&chunk.indices[lo..hi], &chunk.values[lo..hi])
    }
}

// ---------------------------------------------------------------------
// Writer + converters
// ---------------------------------------------------------------------

/// Write `(x, y)` as a column-store file. Works for any design storage:
/// columns are materialized through `gather_dense` and explicit zeros
/// are dropped, so a dense-written and a sparse-written store of the
/// same matrix hold identical entries (pinned in `tests/prop_ooc.rs`).
/// The source is swept three times (count, indices, values) so the
/// writer streams sequentially — no in-memory copy of the store is ever
/// built.
pub fn write_store<D: DesignOps + ?Sized>(
    path: &Path,
    x: &D,
    y: &[f64],
) -> Result<StoreMeta, SolveError> {
    write_store_cols(path, x, y, 0, x.p())
}

/// [`write_store`] restricted to the column range `j0..j1`: the written
/// file is a complete, standalone store of shape `(n, j1 − j0)` holding
/// the full label segment — the shard writer of
/// [`crate::data::shard::write_sharded_store`]. The entry bytes of
/// column `j0 + k` are identical to those the whole-design writer emits
/// for column `j0 + k`, so a sharded split concatenates bit-for-bit to
/// the single store (pinned in `tests/prop_shard.rs`).
pub fn write_store_cols<D: DesignOps + ?Sized>(
    path: &Path,
    x: &D,
    y: &[f64],
    j0: usize,
    j1: usize,
) -> Result<StoreMeta, SolveError> {
    let n = x.n();
    if j0 > j1 || j1 > x.p() {
        return Err(ferr(
            path,
            format!("column range {j0}..{j1} out of bounds for p = {}", x.p()),
        ));
    }
    let p = j1 - j0;
    if y.len() != n {
        return Err(SolveError::DimensionMismatch { rows: n, labels: y.len() });
    }
    if n > u32::MAX as usize {
        return Err(ferr(path, format!("n = {n} exceeds the u32 row-index range")));
    }
    let io = |e: std::io::Error| ferr(path, format!("write failed: {e}"));
    // Pass 1: per-column non-zero counts -> indptr.
    let mut col = Vec::new();
    let mut indptr = Vec::with_capacity(p + 1);
    indptr.push(0u64);
    let mut nnz = 0u64;
    for j in j0..j1 {
        x.gather_dense(&[j], &mut col);
        nnz += col.iter().filter(|&&v| v != 0.0).count() as u64;
        indptr.push(nnz);
    }
    let f = File::create(path).map_err(io)?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC).map_err(io)?;
    w.write_all(&VERSION.to_le_bytes()).map_err(io)?;
    w.write_all(&0u32.to_le_bytes()).map_err(io)?; // flags
    w.write_all(&(n as u64).to_le_bytes()).map_err(io)?;
    w.write_all(&(p as u64).to_le_bytes()).map_err(io)?;
    w.write_all(&nnz.to_le_bytes()).map_err(io)?;
    for &v in y {
        w.write_all(&v.to_le_bytes()).map_err(io)?;
    }
    for &v in &indptr {
        w.write_all(&v.to_le_bytes()).map_err(io)?;
    }
    // Pass 2: row indices.
    for j in j0..j1 {
        x.gather_dense(&[j], &mut col);
        for (i, &v) in col.iter().enumerate() {
            if v != 0.0 {
                w.write_all(&(i as u32).to_le_bytes()).map_err(io)?;
            }
        }
    }
    // Pass 3: values.
    for j in j0..j1 {
        x.gather_dense(&[j], &mut col);
        for &v in col.iter() {
            if v != 0.0 {
                w.write_all(&v.to_le_bytes()).map_err(io)?;
            }
        }
    }
    w.flush().map_err(io)?;
    Ok(StoreMeta { n, p, nnz: nnz as usize })
}

/// Convert an svmlight file to a column store: the out-of-core
/// ingestion path (`svmlight → parse → store`), with every parse defect
/// reported as the reader's typed [`SolveError::Parse`].
pub fn svmlight_to_store(
    src: &Path,
    dst: &Path,
    min_features: usize,
) -> Result<StoreMeta, SolveError> {
    let f = File::open(src)
        .map_err(|e| ferr(src, format!("cannot open svmlight source: {e}")))?;
    let ds = parse_svmlight_typed(f, min_features)?;
    write_store(dst, &ds.x, &ds.y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("celer_ooc_unit_{}_{name}", std::process::id()))
    }

    fn random_csc(seed: u64, n: usize, p: usize, density: f64) -> (CscMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0; n * p];
        for v in dense.iter_mut() {
            if rng.uniform() < density {
                *v = rng.normal();
            }
        }
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (CscMatrix::from_dense(n, p, &dense), y)
    }

    #[test]
    fn roundtrip_matches_csc_bitwise() {
        let (csc, y) = random_csc(3, 37, 29, 0.3);
        let path = tmp("roundtrip.cstore");
        let meta = write_store(&path, &csc, &y).unwrap();
        assert_eq!(meta, StoreMeta { n: 37, p: 29, nnz: csc.nnz() });
        // Tiny chunks so multiple chunks + eviction are exercised.
        let store = OocColumnStore::open_with(&path, 256, 3).unwrap();
        assert!(store.nchunks() > 1, "want a multi-chunk plan");
        assert_eq!(store.read_labels().unwrap(), y);
        let v: Vec<f64> = (0..37).map(|i| (i as f64) * 0.25 - 3.0).collect();
        for j in 0..29 {
            assert_eq!(store.col_nnz(j), csc.col_nnz(j));
            assert_eq!(store.col_dot(j, &v).to_bits(), csc.col_dot(j, &v).to_bits());
            assert_eq!(store.col_norm_sq(j).to_bits(), csc.col_norm_sq(j).to_bits());
        }
        let round = store.to_csc();
        assert_eq!(round.nnz(), csc.nnz());
        for j in 0..29 {
            assert_eq!(round.col(j), csc.col(j));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn dense_and_sparse_written_stores_are_identical() {
        let (csc, y) = random_csc(11, 23, 17, 0.4);
        let dense = DenseMatrix::from_col_major(23, 17, csc.to_dense_col_major());
        let (pa, pb) = (tmp("dw.cstore"), tmp("sw.cstore"));
        write_store(&pa, &dense, &y).unwrap();
        write_store(&pb, &csc, &y).unwrap();
        let a = std::fs::read(&pa).unwrap();
        let b = std::fs::read(&pb).unwrap();
        assert_eq!(a, b, "dense-written and sparse-written bytes differ");
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }

    #[test]
    fn open_rejects_corrupt_headers_typed() {
        let (csc, y) = random_csc(7, 9, 5, 0.5);
        let path = tmp("corrupt.cstore");
        write_store(&path, &csc, &y).unwrap();
        let good = std::fs::read(&path).unwrap();
        let fails = |bytes: &[u8], what: &str| {
            std::fs::write(&path, bytes).unwrap();
            match OocColumnStore::open(&path) {
                Err(SolveError::StoreFormat { .. }) => {}
                other => panic!("{what}: expected StoreFormat, got {other:?}"),
            }
        };
        fails(&good[..20], "truncated header");
        fails(&good[..good.len() - 3], "truncated data segment");
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        fails(&bad, "bad magic");
        let mut bad = good.clone();
        bad[8] = 99; // version
        fails(&bad, "bad version");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn validate_values_streams_nonfinite() {
        let (csc, y) = random_csc(13, 8, 6, 0.6);
        let path = tmp("nonfinite.cstore");
        let meta = write_store(&path, &csc, &y).unwrap();
        let store = OocColumnStore::open(&path).unwrap();
        assert!(store.validate_values().is_ok());
        // Poison one stored value in the data segment.
        let mut bytes = std::fs::read(&path).unwrap();
        let data_off = bytes.len() - meta.nnz * 8;
        bytes[data_off..data_off + 8].copy_from_slice(&f64::NAN.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let store = OocColumnStore::open(&path).unwrap();
        match store.validate_values() {
            Err(SolveError::NonFiniteDesign { .. }) => {}
            other => panic!("expected NonFiniteDesign, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn select_columns_matches_csc() {
        let (csc, y) = random_csc(17, 19, 11, 0.35);
        let path = tmp("select.cstore");
        write_store(&path, &csc, &y).unwrap();
        let store = OocColumnStore::open_with(&path, 128, 2).unwrap();
        let keep = [7usize, 0, 9, 7];
        let a = store.select_columns_csc(&keep);
        let b = csc.select_columns(&keep);
        for c in 0..keep.len() {
            assert_eq!(a.col(c), b.col(c), "col {c}");
        }
        let _ = std::fs::remove_file(&path);
    }
}
