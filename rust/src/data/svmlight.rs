//! svmlight / LIBSVM format reader and writer.
//!
//! The paper's datasets (leukemia, Finance/E2006-log1p) ship in this
//! format; this module lets users run the solver on the real files when
//! they have them. Format per line:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based and strictly increasing within a line. Comments
//! start with `#` (rest of line ignored).

use crate::data::csc::CscMatrix;
use crate::data::design::DesignMatrix;
use std::io::{BufRead, BufReader, Read, Write};

/// A loaded regression dataset: design matrix + targets.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: DesignMatrix,
    pub y: Vec<f64>,
}

/// Parse svmlight-format text into a sparse dataset.
///
/// `min_features` can force a minimum feature count (columns beyond the
/// maximum seen index are empty).
pub fn parse_svmlight<R: Read>(reader: R, min_features: usize) -> anyhow::Result<Dataset> {
    let buf = BufReader::new(reader);
    let mut y = Vec::new();
    // row-oriented triplets, converted to CSC at the end
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_feature = 0usize;
    for (lineno, line) in buf.lines().enumerate() {
        let line = line?;
        let line = match line.find('#') {
            Some(pos) => &line[..pos],
            None => &line[..],
        };
        let mut parts = line.split_whitespace();
        let label = match parts.next() {
            None => continue, // blank line
            Some(l) => l
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("line {}: bad label {l:?}: {e}", lineno + 1))?,
        };
        let mut row = Vec::new();
        let mut prev_idx = 0usize;
        for tok in parts {
            let (is, vs) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {}: bad pair {tok:?}", lineno + 1))?;
            let idx: usize = is
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad index {is:?}: {e}", lineno + 1))?;
            let val: f64 = vs
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad value {vs:?}: {e}", lineno + 1))?;
            if idx == 0 {
                anyhow::bail!("line {}: svmlight indices are 1-based, got 0", lineno + 1);
            }
            if idx <= prev_idx {
                anyhow::bail!("line {}: indices must be strictly increasing", lineno + 1);
            }
            prev_idx = idx;
            max_feature = max_feature.max(idx);
            if val != 0.0 {
                row.push((idx - 1, val));
            }
        }
        y.push(label);
        rows.push(row);
    }
    let n = y.len();
    let p = max_feature.max(min_features);
    // transpose rows -> columns
    let mut cols: Vec<Vec<(u32, f64)>> = vec![Vec::new(); p];
    for (i, row) in rows.into_iter().enumerate() {
        for (j, v) in row {
            cols[j].push((i as u32, v));
        }
    }
    Ok(Dataset { x: DesignMatrix::Sparse(CscMatrix::from_columns(n, cols)), y })
}

/// Load an svmlight file from disk.
pub fn load_svmlight(path: &std::path::Path) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open {}: {e}", path.display()))?;
    parse_svmlight(f, 0)
}

/// Write a dataset in svmlight format.
pub fn write_svmlight<W: Write>(w: &mut W, ds: &Dataset) -> anyhow::Result<()> {
    use crate::data::design::DesignOps;
    let n = ds.x.n();
    let p = ds.x.p();
    // Column-oriented storage: build row views first.
    let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    let mut col = Vec::new();
    for j in 0..p {
        col.clear();
        ds.x.gather_dense(&[j], &mut col);
        for (i, &v) in col.iter().enumerate() {
            if v != 0.0 {
                rows[i].push((j + 1, v));
            }
        }
    }
    for i in 0..n {
        write!(w, "{}", ds.y[i])?;
        for &(j, v) in &rows[i] {
            write!(w, " {}:{}", j, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::design::DesignOps;

    #[test]
    fn parse_basic() {
        let text = "1.5 1:2.0 3:4.0\n-0.5 2:1.0\n";
        let ds = parse_svmlight(text.as_bytes(), 0).unwrap();
        assert_eq!(ds.y, vec![1.5, -0.5]);
        assert_eq!(ds.x.n(), 2);
        assert_eq!(ds.x.p(), 3);
        assert_eq!(ds.x.col_dot(0, &[1.0, 1.0]), 2.0);
        assert_eq!(ds.x.col_dot(2, &[1.0, 0.0]), 4.0);
    }

    #[test]
    fn comments_and_blank_lines() {
        let text = "# header\n1 1:1 # trailing\n\n2 2:2\n";
        let ds = parse_svmlight(text.as_bytes(), 0).unwrap();
        assert_eq!(ds.y, vec![1.0, 2.0]);
        assert_eq!(ds.x.p(), 2);
    }

    #[test]
    fn min_features_pads() {
        let ds = parse_svmlight("1 1:1\n".as_bytes(), 10).unwrap();
        assert_eq!(ds.x.p(), 10);
        assert_eq!(ds.x.col_nnz(9), 0);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_svmlight("1 0:1\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn rejects_decreasing_indices() {
        assert!(parse_svmlight("1 3:1 2:1\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn rejects_malformed_pair() {
        assert!(parse_svmlight("1 abc\n".as_bytes(), 0).is_err());
        assert!(parse_svmlight("x 1:1\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn round_trip() {
        let text = "1 1:2 3:4\n-1 2:0.5\n0.25 1:1 2:1 3:1\n";
        let ds = parse_svmlight(text.as_bytes(), 0).unwrap();
        let mut out = Vec::new();
        write_svmlight(&mut out, &ds).unwrap();
        let ds2 = parse_svmlight(&out[..], 0).unwrap();
        assert_eq!(ds.y, ds2.y);
        assert_eq!(ds.x.nnz(), ds2.x.nnz());
        let v = vec![1.0, 2.0, 3.0];
        for j in 0..3 {
            assert_eq!(ds.x.col_dot(j, &v), ds2.x.col_dot(j, &v));
        }
    }
}
