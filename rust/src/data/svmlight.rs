//! svmlight / LIBSVM format reader and writer.
//!
//! The paper's datasets (leukemia, Finance/E2006-log1p) ship in this
//! format; this module lets users run the solver on the real files when
//! they have them. Format per line:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based and strictly increasing within a line. Comments
//! start with `#` (rest of line ignored).

use crate::data::csc::CscMatrix;
use crate::data::design::DesignMatrix;
use crate::util::error::SolveError;
use std::io::{BufRead, BufReader, Read, Write};

/// A loaded regression dataset: design matrix + targets.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: DesignMatrix,
    pub y: Vec<f64>,
}

/// Whitespace tokens of one line with their 0-based byte offsets, so
/// errors can point at an exact column.
fn tokens(line: &str) -> Vec<(usize, &str)> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        let start = i;
        while i < bytes.len() && !bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        if i > start {
            out.push((start, &line[start..i]));
        }
    }
    out
}

/// Parse svmlight-format text into a sparse dataset, reporting every
/// defect as a typed [`SolveError::Parse`] with 1-based line and column
/// — a corrupted file can never panic the loader, and non-finite labels
/// or values are rejected at the gate (the solver guardrails assume
/// finite inputs past validation).
///
/// `min_features` can force a minimum feature count (columns beyond the
/// maximum seen index are empty).
pub fn parse_svmlight_typed<R: Read>(
    reader: R,
    min_features: usize,
) -> Result<Dataset, SolveError> {
    let err = |line: usize, col: usize, msg: String| SolveError::Parse { line, col, msg };
    let buf = BufReader::new(reader);
    let mut y = Vec::new();
    // row-oriented triplets, converted to CSC at the end
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_feature = 0usize;
    for (lineno, line) in buf.lines().enumerate() {
        let lno = lineno + 1;
        let line = line.map_err(|e| err(lno, 1, format!("read error: {e}")))?;
        let line = match line.find('#') {
            Some(pos) => &line[..pos],
            None => &line[..],
        };
        let toks = tokens(line);
        let Some(&(label_off, label_tok)) = toks.first() else {
            continue; // blank line
        };
        let label = label_tok
            .parse::<f64>()
            .map_err(|e| err(lno, label_off + 1, format!("bad label {label_tok:?}: {e}")))?;
        if !label.is_finite() {
            return Err(err(lno, label_off + 1, format!("non-finite label {label_tok:?}")));
        }
        let mut row = Vec::new();
        let mut prev_idx = 0usize;
        for &(off, tok) in &toks[1..] {
            let col = off + 1;
            let (is, vs) = tok
                .split_once(':')
                .ok_or_else(|| err(lno, col, format!("bad pair {tok:?} (expected index:value)")))?;
            let idx: usize = is
                .parse()
                .map_err(|e| err(lno, col, format!("bad index {is:?}: {e}")))?;
            let vcol = col + is.len() + 1;
            let val: f64 = vs
                .parse()
                .map_err(|e| err(lno, vcol, format!("bad value {vs:?}: {e}")))?;
            if !val.is_finite() {
                return Err(err(lno, vcol, format!("non-finite value {vs:?}")));
            }
            if idx == 0 {
                return Err(err(lno, col, "svmlight indices are 1-based, got 0".into()));
            }
            if idx <= prev_idx {
                return Err(err(
                    lno,
                    col,
                    format!("indices must be strictly increasing ({idx} after {prev_idx})"),
                ));
            }
            prev_idx = idx;
            max_feature = max_feature.max(idx);
            if val != 0.0 {
                row.push((idx - 1, val));
            }
        }
        y.push(label);
        rows.push(row);
    }
    let n = y.len();
    let p = max_feature.max(min_features);
    // transpose rows -> columns
    let mut cols: Vec<Vec<(u32, f64)>> = vec![Vec::new(); p];
    for (i, row) in rows.into_iter().enumerate() {
        for (j, v) in row {
            cols[j].push((i as u32, v));
        }
    }
    Ok(Dataset { x: DesignMatrix::Sparse(CscMatrix::from_columns(n, cols)), y })
}

/// [`parse_svmlight_typed`] behind the crate's `anyhow`-style interface.
pub fn parse_svmlight<R: Read>(reader: R, min_features: usize) -> anyhow::Result<Dataset> {
    Ok(parse_svmlight_typed(reader, min_features)?)
}

/// Load an svmlight file from disk.
pub fn load_svmlight(path: &std::path::Path) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open {}: {e}", path.display()))?;
    parse_svmlight(f, 0)
}

/// Write a dataset in svmlight format.
pub fn write_svmlight<W: Write>(w: &mut W, ds: &Dataset) -> anyhow::Result<()> {
    use crate::data::design::DesignOps;
    let n = ds.x.n();
    let p = ds.x.p();
    // Column-oriented storage: build row views first.
    let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    let mut col = Vec::new();
    for j in 0..p {
        col.clear();
        ds.x.gather_dense(&[j], &mut col);
        for (i, &v) in col.iter().enumerate() {
            if v != 0.0 {
                rows[i].push((j + 1, v));
            }
        }
    }
    for i in 0..n {
        write!(w, "{}", ds.y[i])?;
        for &(j, v) in &rows[i] {
            write!(w, " {}:{}", j, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::design::DesignOps;

    #[test]
    fn parse_basic() {
        let text = "1.5 1:2.0 3:4.0\n-0.5 2:1.0\n";
        let ds = parse_svmlight(text.as_bytes(), 0).unwrap();
        assert_eq!(ds.y, vec![1.5, -0.5]);
        assert_eq!(ds.x.n(), 2);
        assert_eq!(ds.x.p(), 3);
        assert_eq!(ds.x.col_dot(0, &[1.0, 1.0]), 2.0);
        assert_eq!(ds.x.col_dot(2, &[1.0, 0.0]), 4.0);
    }

    #[test]
    fn comments_and_blank_lines() {
        let text = "# header\n1 1:1 # trailing\n\n2 2:2\n";
        let ds = parse_svmlight(text.as_bytes(), 0).unwrap();
        assert_eq!(ds.y, vec![1.0, 2.0]);
        assert_eq!(ds.x.p(), 2);
    }

    #[test]
    fn min_features_pads() {
        let ds = parse_svmlight("1 1:1\n".as_bytes(), 10).unwrap();
        assert_eq!(ds.x.p(), 10);
        assert_eq!(ds.x.col_nnz(9), 0);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_svmlight("1 0:1\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn rejects_decreasing_indices() {
        assert!(parse_svmlight("1 3:1 2:1\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn rejects_malformed_pair() {
        assert!(parse_svmlight("1 abc\n".as_bytes(), 0).is_err());
        assert!(parse_svmlight("x 1:1\n".as_bytes(), 0).is_err());
    }

    fn parse_err(text: &str) -> SolveError {
        parse_svmlight_typed(text.as_bytes(), 0).unwrap_err()
    }

    #[test]
    fn typed_errors_carry_line_and_column() {
        // bad label on line 2, column 1
        match parse_err("1 1:1\nxyz 1:1\n") {
            SolveError::Parse { line, col, msg } => {
                assert_eq!((line, col), (2, 1));
                assert!(msg.contains("bad label"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
        // pair without a colon: line 1, after "1 " → column 3
        match parse_err("1 abc\n") {
            SolveError::Parse { line, col, msg } => {
                assert_eq!((line, col), (1, 3));
                assert!(msg.contains("bad pair"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
        // non-numeric value: "2.5 7:zz" → value starts at column 7
        match parse_err("2.5 7:zz\n") {
            SolveError::Parse { line, col, msg } => {
                assert_eq!((line, col), (1, 7));
                assert!(msg.contains("bad value"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn typed_errors_reject_structural_defects() {
        for (text, needle) in [
            ("1 0:1\n", "1-based"),
            ("1 3:1 2:1\n", "strictly increasing"),
            ("1 2:nan\n", "non-finite value"),
            ("inf 1:1\n", "non-finite label"),
            ("1 1:\n", "bad value"),
            ("1 :5\n", "bad index"),
        ] {
            match parse_err(text) {
                SolveError::Parse { msg, .. } => {
                    assert!(msg.contains(needle), "{text:?}: {msg}")
                }
                other => panic!("{text:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_input_is_an_error_not_a_panic() {
        // A file cut mid-token must parse or error — never panic.
        let text = "1 1:2.0 3:4.0\n-0.5 2:1.0 5:3.";
        match parse_svmlight_typed(text.as_bytes(), 0) {
            // "3." parses as 3.0 under Rust float grammar: accepted.
            Ok(ds) => assert_eq!(ds.y.len(), 2),
            Err(SolveError::Parse { line, .. }) => assert_eq!(line, 2),
            Err(other) => panic!("{other:?}"),
        }
        // cut mid-pair: definitely an error
        assert!(parse_svmlight_typed("1 1:2.0\n0.5 4".as_bytes(), 0).is_err());
    }

    #[test]
    fn round_trip() {
        let text = "1 1:2 3:4\n-1 2:0.5\n0.25 1:1 2:1 3:1\n";
        let ds = parse_svmlight(text.as_bytes(), 0).unwrap();
        let mut out = Vec::new();
        write_svmlight(&mut out, &ds).unwrap();
        let ds2 = parse_svmlight(&out[..], 0).unwrap();
        assert_eq!(ds.y, ds2.y);
        assert_eq!(ds.x.nnz(), ds2.x.nnz());
        let v = vec![1.0, 2.0, 3.0];
        for j in 0..3 {
            assert_eq!(ds.x.col_dot(j, &v), ds2.x.col_dot(j, &v));
        }
    }
}
