//! Pre-epoch input validation: every rejection here happens **before
//! the first CD epoch**, so a bad request never reaches the hot loop.
//!
//! These checks back the `try_*` solver entry points
//! (`try_cd_solve`, `try_celer_solve`, `try_lasso_path`,
//! `try_glm_path`, …). The historical panicking paths
//! (`Datafit::validate_targets`) are unchanged; this module is the
//! typed, non-panicking face of the same contracts, plus the
//! non-finite / dimension checks the panicking paths never did.

use crate::data::{DesignMatrix, DesignOps};
use crate::datafit::GlmFamily;
use crate::util::error::SolveError;

/// Reject NaN/±∞ design entries. Scans stored entries only (CSC zeros
/// are implicitly finite); reports the first offender as (row, col).
pub fn validate_design(x: &DesignMatrix) -> Result<(), SolveError> {
    match x {
        DesignMatrix::Dense(d) => {
            for j in 0..d.p() {
                for (i, &v) in d.col(j).iter().enumerate() {
                    if !v.is_finite() {
                        return Err(SolveError::NonFiniteDesign { row: i, col: j, value: v });
                    }
                }
            }
        }
        DesignMatrix::Sparse(s) => {
            for j in 0..s.p() {
                let (rows, vals) = s.col(j);
                for (&i, &v) in rows.iter().zip(vals.iter()) {
                    if !v.is_finite() {
                        return Err(SolveError::NonFiniteDesign {
                            row: i as usize,
                            col: j,
                            value: v,
                        });
                    }
                }
            }
        }
        // Streams the store chunk by chunk — the whole design never has
        // to be resident even for validation.
        DesignMatrix::Ooc(o) => o.validate_values()?,
        // Per-shard streaming with global column indices in the report.
        DesignMatrix::Sharded(sh) => sh.validate_values()?,
    }
    Ok(())
}

/// Reject NaN/±∞ labels.
pub fn validate_labels(y: &[f64]) -> Result<(), SolveError> {
    for (i, &v) in y.iter().enumerate() {
        if !v.is_finite() {
            return Err(SolveError::NonFiniteLabels { index: i, value: v });
        }
    }
    Ok(())
}

/// Full problem check: dimensions, then design, then labels.
pub fn validate_problem(x: &DesignMatrix, y: &[f64]) -> Result<(), SolveError> {
    if x.n() != y.len() {
        return Err(SolveError::DimensionMismatch { rows: x.n(), labels: y.len() });
    }
    validate_design(x)?;
    validate_labels(y)
}

/// Per-datafit label-domain check (the typed twin of the panicking
/// `Datafit::validate_targets`): logistic requires ±1 labels, Poisson
/// requires finite counts ≥ 0.
pub fn validate_family_labels(family: GlmFamily, y: &[f64]) -> Result<(), SolveError> {
    match family {
        GlmFamily::Logistic => {
            for (i, &v) in y.iter().enumerate() {
                if v != 1.0 && v != -1.0 {
                    return Err(SolveError::LabelDomain {
                        family: "logistic",
                        index: i,
                        value: v,
                        expected: "labels in {-1, +1}",
                    });
                }
            }
        }
        GlmFamily::Poisson => {
            for (i, &v) in y.iter().enumerate() {
                if !v.is_finite() || v < 0.0 {
                    return Err(SolveError::LabelDomain {
                        family: "poisson",
                        index: i,
                        value: v,
                        expected: "finite counts >= 0",
                    });
                }
            }
        }
    }
    Ok(())
}

/// Penalty-weight sanity: NaN and negative weights are rejected;
/// `w = 0` (unpenalized) and `w = +inf` (hard-zeroed) are legal
/// `WeightedL1` semantics.
pub fn validate_weights(w: &[f64]) -> Result<(), SolveError> {
    for (i, &v) in w.iter().enumerate() {
        if v.is_nan() || v < 0.0 {
            return Err(SolveError::BadWeight { index: i, value: v });
        }
    }
    Ok(())
}

/// λ-grid sanity: every entry finite and > 0, grid non-increasing
/// (warm starts walk λ downward), and non-empty.
pub fn validate_grid(grid: &[f64]) -> Result<(), SolveError> {
    if grid.is_empty() {
        return Err(SolveError::BadGrid { index: 0, value: f64::NAN, reason: "empty grid" });
    }
    for (i, &l) in grid.iter().enumerate() {
        if !l.is_finite() || l <= 0.0 {
            return Err(SolveError::BadGrid {
                index: i,
                value: l,
                reason: "lambda must be finite and > 0",
            });
        }
        if i > 0 && l > grid[i - 1] {
            return Err(SolveError::BadGrid {
                index: i,
                value: l,
                reason: "grid must be non-increasing",
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CscMatrix, DenseMatrix};

    fn dense(n: usize, p: usize, data: Vec<f64>) -> DesignMatrix {
        DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data))
    }

    fn sparse_of(n: usize, p: usize, data: &[f64]) -> DesignMatrix {
        DesignMatrix::Sparse(CscMatrix::from_dense(n, p, data))
    }

    #[test]
    fn accepts_clean_problem_dense_and_sparse() {
        let data = vec![1.0, 0.0, -2.0, 3.0, 0.0, 0.5];
        let y = vec![0.1, -0.2];
        for x in [dense(2, 3, data.clone()), sparse_of(2, 3, &data)] {
            assert!(validate_problem(&x, &y).is_ok());
        }
    }

    #[test]
    fn rejects_nan_design_with_position() {
        let mut data = vec![1.0, 0.0, -2.0, 3.0, 0.0, 0.5];
        data[2] = f64::NAN; // column 1, row 0 (col-major, n = 2)
        for x in [dense(2, 3, data.clone()), sparse_of(2, 3, &data)] {
            match validate_design(&x) {
                Err(SolveError::NonFiniteDesign { row, col, .. }) => {
                    assert_eq!((row, col), (0, 1));
                }
                other => panic!("expected NonFiniteDesign, got {other:?}"),
            }
        }
    }

    #[test]
    fn rejects_inf_labels() {
        assert!(matches!(
            validate_labels(&[0.0, f64::INFINITY]),
            Err(SolveError::NonFiniteLabels { index: 1, .. })
        ));
    }

    #[test]
    fn rejects_dimension_mismatch() {
        let x = dense(2, 1, vec![1.0, 2.0]);
        assert!(matches!(
            validate_problem(&x, &[1.0, 2.0, 3.0]),
            Err(SolveError::DimensionMismatch { rows: 2, labels: 3 })
        ));
    }

    #[test]
    fn family_domains() {
        assert!(validate_family_labels(GlmFamily::Logistic, &[1.0, -1.0]).is_ok());
        assert!(matches!(
            validate_family_labels(GlmFamily::Logistic, &[1.0, 0.5]),
            Err(SolveError::LabelDomain { family: "logistic", index: 1, .. })
        ));
        assert!(validate_family_labels(GlmFamily::Poisson, &[0.0, 3.0]).is_ok());
        assert!(matches!(
            validate_family_labels(GlmFamily::Poisson, &[2.0, -1.0]),
            Err(SolveError::LabelDomain { family: "poisson", index: 1, .. })
        ));
        assert!(validate_family_labels(GlmFamily::Poisson, &[f64::NAN]).is_err());
    }

    #[test]
    fn weight_semantics() {
        assert!(validate_weights(&[0.0, 1.0, f64::INFINITY]).is_ok(), "0 and inf are legal");
        assert!(matches!(
            validate_weights(&[1.0, -0.5]),
            Err(SolveError::BadWeight { index: 1, .. })
        ));
        assert!(validate_weights(&[f64::NAN]).is_err());
    }

    #[test]
    fn grid_must_be_positive_descending() {
        assert!(validate_grid(&[1.0, 0.5, 0.5, 0.1]).is_ok(), "ties allowed");
        assert!(matches!(validate_grid(&[]), Err(SolveError::BadGrid { .. })));
        assert!(validate_grid(&[1.0, 0.0]).is_err(), "zero lambda");
        assert!(validate_grid(&[1.0, f64::NAN]).is_err());
        assert!(matches!(
            validate_grid(&[0.5, 1.0]),
            Err(SolveError::BadGrid { index: 1, .. })
        ));
    }
}
