//! Dense column-major design matrix.
//!
//! Column-major layout matches the access pattern of coordinate descent:
//! the inner loop reads/updates one feature column `x_j` at a time, so each
//! column is a contiguous slice.

use crate::data::design::DesignOps;

/// Dense n×p design matrix, column-major.
#[derive(Debug, Clone)]
pub struct DenseMatrix {
    n: usize,
    p: usize,
    /// Column-major values, `data[j*n + i] = X[i, j]`.
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Build from column-major data (length n·p).
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * p, "dense data must be n*p");
        DenseMatrix { n, p, data }
    }

    /// Build from row-major data (length n·p); transposes into column-major.
    pub fn from_row_major(n: usize, p: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), n * p);
        let mut cm = vec![0.0; n * p];
        for i in 0..n {
            for j in 0..p {
                cm[j * n + i] = data[i * p + j];
            }
        }
        DenseMatrix { n, p, data: cm }
    }

    /// All-zeros matrix.
    pub fn zeros(n: usize, p: usize) -> Self {
        DenseMatrix { n, p, data: vec![0.0; n * p] }
    }

    /// Column `j` as a contiguous slice.
    #[inline(always)]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Mutable column `j`.
    #[inline(always)]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    /// Entry accessor (test/debug convenience).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    /// Raw column-major buffer.
    pub fn raw(&self) -> &[f64] {
        &self.data
    }
}

impl DesignOps for DenseMatrix {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn p(&self) -> usize {
        self.p
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        crate::util::linalg::dot(self.col(j), v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        crate::util::linalg::axpy(alpha, self.col(j), out);
    }

    #[inline]
    fn col_norm_sq(&self, j: usize) -> f64 {
        let c = self.col(j);
        crate::util::linalg::dot(c, c)
    }

    fn col_nnz(&self, j: usize) -> usize {
        self.col(j).iter().filter(|&&v| v != 0.0).count()
    }

    fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for j in 0..self.p {
            let b = beta[j];
            if b != 0.0 {
                self.col_axpy(j, b, out);
            }
        }
    }

    fn xt_vec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.n);
        assert_eq!(out.len(), self.p);
        // Cost hint n: each column dot streams the full column.
        crate::util::par::par_fill_cost(out, self.n.max(1), |j| {
            crate::util::linalg::dot(self.col(j), v)
        });
    }

    fn gather_dense(&self, cols: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(cols.len() * self.n);
        for &j in cols {
            out.extend_from_slice(self.col(j));
        }
    }

    fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    fn shadow_f32(&self) -> crate::data::shadow::ShadowF32 {
        crate::data::shadow::ShadowF32::from_dense_col_major(self.n, self.p, &self.data)
    }

    #[inline]
    fn col_wnorm_sq(&self, j: usize, w: &[f64]) -> f64 {
        crate::util::simd::wssq(w, self.col(j))
    }

    #[inline]
    fn col_waxpy(&self, j: usize, alpha: f64, w: &[f64], out: &mut [f64]) {
        crate::util::simd::waxpy(alpha, w, self.col(j), out);
    }

    // Batched multi-λ sweeps (see `solvers/batch.rs`): process the column
    // in row blocks so each block is loaded from memory once and reused
    // from L1 by every lane, instead of streaming the full column once
    // per lane. BLOCK is a multiple of the simd accumulator width, so
    // every block but the last feeds `simd::dot`/`simd::axpy` tail-free
    // register tiles.
    fn col_dot_lanes(&self, j: usize, v: &[f64], n: usize, lanes: &[usize], out: &mut [f64]) {
        debug_assert_eq!(n, self.n);
        debug_assert_eq!(lanes.len(), out.len());
        const BLOCK: usize = 256;
        let col = self.col(j);
        out.fill(0.0);
        let mut i = 0;
        while i < n {
            let hi = (i + BLOCK).min(n);
            let cb = &col[i..hi];
            for (o, &k) in out.iter_mut().zip(lanes.iter()) {
                *o += crate::util::linalg::dot(cb, &v[k * n + i..k * n + hi]);
            }
            i = hi;
        }
    }

    fn col_axpy_lanes(&self, j: usize, alphas: &[f64], v: &mut [f64], n: usize, lanes: &[usize]) {
        debug_assert_eq!(n, self.n);
        debug_assert_eq!(lanes.len(), alphas.len());
        const BLOCK: usize = 256;
        let col = self.col(j);
        let mut i = 0;
        while i < n {
            let hi = (i + BLOCK).min(n);
            let cb = &col[i..hi];
            for (&alpha, &k) in alphas.iter().zip(lanes.iter()) {
                if alpha != 0.0 {
                    crate::util::linalg::axpy(alpha, cb, &mut v[k * n + i..k * n + hi]);
                }
            }
            i = hi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::design::DesignOps;

    fn sample() -> DenseMatrix {
        // X = [[1, 2], [3, 4], [5, 6]] (n=3, p=2)
        DenseMatrix::from_row_major(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn layout_round_trip() {
        let x = sample();
        assert_eq!(x.col(0), &[1.0, 3.0, 5.0]);
        assert_eq!(x.col(1), &[2.0, 4.0, 6.0]);
        assert_eq!(x.get(1, 1), 4.0);
    }

    #[test]
    fn col_ops() {
        let x = sample();
        let v = [1.0, 1.0, 1.0];
        assert_eq!(x.col_dot(0, &v), 9.0);
        assert_eq!(x.col_norm_sq(1), 4.0 + 16.0 + 36.0);
        let mut out = vec![1.0, 1.0, 1.0];
        x.col_axpy(0, 2.0, &mut out);
        assert_eq!(out, vec![3.0, 7.0, 11.0]);
        assert_eq!(x.col_nnz(0), 3);
    }

    #[test]
    fn matvec_xt_vec() {
        let x = sample();
        let mut r = vec![0.0; 3];
        x.matvec(&[1.0, -1.0], &mut r);
        assert_eq!(r, vec![-1.0, -1.0, -1.0]);
        let mut xt = vec![0.0; 2];
        x.xt_vec(&[1.0, 0.0, -1.0], &mut xt);
        assert_eq!(xt, vec![-4.0, -4.0]);
    }

    #[test]
    fn gather() {
        let x = sample();
        let mut buf = Vec::new();
        x.gather_dense(&[1, 0], &mut buf);
        assert_eq!(buf, vec![2.0, 4.0, 6.0, 1.0, 3.0, 5.0]);
    }

    #[test]
    fn nnz_counts() {
        let x = DenseMatrix::from_col_major(2, 2, vec![0.0, 1.0, 2.0, 0.0]);
        assert_eq!(x.nnz(), 2);
    }
}
