//! Zero-copy column-restricted views of a design matrix.
//!
//! CELER and Blitz repeatedly solve subproblems on `X_{W_t}` for a
//! working set `W_t` that changes every outer iteration. Materializing
//! that restriction (`DesignMatrix::select_columns`) copies `n·|W_t|`
//! dense entries — or the corresponding CSC runs — on **every** outer
//! iteration. [`DesignView`] replaces the copy with a borrow: it wraps a
//! parent design plus an index set and implements [`DesignOps`] by
//! translating local column indices through the index set, so the inner
//! solver's monomorphized hot loops (`col_dot` / `col_axpy`) read the
//! parent's storage directly.
//!
//! Per-column norms are carried over from the parent (the caller passes
//! the parent's cached `‖x_j‖²` vector), so a view never recomputes
//! column norms either — `col_norm_sq` is an array lookup.
//!
//! Paper map: the index sets being viewed are the working sets `W_t` of
//! CELER's Algorithm 4, built by ranking features with the `d_j(θ)`
//! pricing of Eqs. 10–11 (see [`crate::ws::build_working_set`]); the
//! inner solve the view feeds is Algorithm 1 on the restricted design.
//! Views also pass through the batched multi-λ lane ops
//! ([`DesignOps::col_dot_lanes`] / [`DesignOps::col_axpy_lanes`]) by
//! index translation, so a batched sweep can run on a restriction too.

use crate::data::design::DesignOps;

/// A borrowed restriction of a design matrix to a set of columns.
///
/// Local column `c` of the view is parent column `cols[c]`. The view is
/// cheap to construct (three pointer-sized fields), implements
/// [`DesignOps`], and works for any parent — dense, CSC, or the
/// [`DesignMatrix`](crate::data::design::DesignMatrix) enum — without
/// copying matrix data.
#[derive(Debug, Clone, Copy)]
pub struct DesignView<'a, D: DesignOps> {
    parent: &'a D,
    /// Local-to-parent column map (view column `c` ↦ parent column
    /// `cols[c]`). Duplicates are allowed; every entry must be `< parent.p()`.
    cols: &'a [usize],
    /// Parent-wide cached squared column norms (length `parent.p()`).
    parent_norms_sq: &'a [f64],
}

impl<'a, D: DesignOps> DesignView<'a, D> {
    /// Restrict `parent` to `cols`, reusing the parent's cached squared
    /// column norms (`parent_norms_sq[j] = ‖x_j‖²`, length `parent.p()`).
    pub fn new(parent: &'a D, cols: &'a [usize], parent_norms_sq: &'a [f64]) -> Self {
        assert_eq!(
            parent_norms_sq.len(),
            parent.p(),
            "parent norms must cover every parent column"
        );
        assert!(
            cols.iter().all(|&j| j < parent.p()),
            "view columns must be valid parent columns"
        );
        DesignView { parent, cols, parent_norms_sq }
    }

    /// The local-to-parent column map.
    pub fn cols(&self) -> &[usize] {
        self.cols
    }

    /// The parent design.
    pub fn parent(&self) -> &D {
        self.parent
    }
}

impl<D: DesignOps> DesignOps for DesignView<'_, D> {
    #[inline]
    fn n(&self) -> usize {
        self.parent.n()
    }

    #[inline]
    fn p(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        self.parent.col_dot(self.cols[j], v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        self.parent.col_axpy(self.cols[j], alpha, out);
    }

    #[inline]
    fn col_norm_sq(&self, j: usize) -> f64 {
        self.parent_norms_sq[self.cols[j]]
    }

    #[inline]
    fn col_nnz(&self, j: usize) -> usize {
        self.parent.col_nnz(self.cols[j])
    }

    fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.cols.len());
        assert_eq!(out.len(), self.parent.n());
        out.fill(0.0);
        for (c, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                self.parent.col_axpy(self.cols[c], b, out);
            }
        }
    }

    #[inline]
    fn col_cost_hint(&self) -> usize {
        // Approximate: a view's columns cost what the parent's average
        // column costs (exact for dense; mean-field for CSC).
        self.parent.col_cost_hint()
    }

    fn xt_vec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.parent.n());
        assert_eq!(out.len(), self.cols.len());
        crate::util::par::par_fill_cost(out, self.parent.col_cost_hint(), |c| {
            self.parent.col_dot(self.cols[c], v)
        });
    }

    fn gather_dense(&self, cols: &[usize], out: &mut Vec<f64>) {
        // Translate local indices to parent indices, then delegate.
        let mapped: Vec<usize> = cols.iter().map(|&c| self.cols[c]).collect();
        self.parent.gather_dense(&mapped, out);
    }

    fn nnz(&self) -> usize {
        self.cols.iter().map(|&j| self.parent.col_nnz(j)).sum()
    }

    #[inline]
    fn col_dot_lanes(&self, j: usize, v: &[f64], n: usize, lanes: &[usize], out: &mut [f64]) {
        self.parent.col_dot_lanes(self.cols[j], v, n, lanes, out);
    }

    #[inline]
    fn col_axpy_lanes(&self, j: usize, alphas: &[f64], v: &mut [f64], n: usize, lanes: &[usize]) {
        self.parent.col_axpy_lanes(self.cols[j], alphas, v, n, lanes);
    }

    #[inline]
    fn col_wnorm_sq(&self, j: usize, w: &[f64]) -> f64 {
        self.parent.col_wnorm_sq(self.cols[j], w)
    }

    #[inline]
    fn col_waxpy(&self, j: usize, alpha: f64, w: &[f64], out: &mut [f64]) {
        self.parent.col_waxpy(self.cols[j], alpha, w, out);
    }

    fn xt_abs_max(&self, v: &[f64]) -> f64 {
        crate::util::par::par_max_cost(self.cols.len(), self.parent.col_cost_hint(), |c| {
            self.parent.col_dot(self.cols[c], v).abs()
        })
        .max(0.0)
    }

    fn col_norms_sq(&self) -> Vec<f64> {
        self.cols.iter().map(|&j| self.parent_norms_sq[j]).collect()
    }

    // `shadow_f32` keeps the trait default: a view's restriction is
    // materialized densely into the shadow, which is the right trade —
    // working sets are small, and the shadow is rebuilt per inner solve.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csc::CscMatrix;
    use crate::data::dense::DenseMatrix;
    use crate::data::design::DesignMatrix;
    use crate::util::rng::Rng;

    fn random_pair(seed: u64, n: usize, p: usize, density: f64) -> (DesignMatrix, DesignMatrix) {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0; n * p];
        for v in dense.iter_mut() {
            if rng.uniform() < density {
                *v = rng.normal();
            }
        }
        let d = DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, dense.clone()));
        let s = DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &dense));
        (d, s)
    }

    fn check_view_matches_materialized(x: &DesignMatrix, cols: &[usize]) {
        let norms = x.col_norms_sq();
        let view = DesignView::new(x, cols, &norms);
        let mat = x.select_columns(cols);
        let n = x.n();
        let k = cols.len();
        assert_eq!(view.p(), k);
        assert_eq!(view.n(), n);
        assert_eq!(view.nnz(), mat.nnz());

        let mut rng = Rng::new(99);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let beta: Vec<f64> = (0..k).map(|_| rng.normal()).collect();

        for c in 0..k {
            assert_eq!(view.col_dot(c, &v), mat.col_dot(c, &v), "col_dot c={c}");
            assert_eq!(view.col_norm_sq(c), mat.col_norm_sq(c), "norm c={c}");
            assert_eq!(view.col_nnz(c), mat.col_nnz(c), "nnz c={c}");
        }

        let (mut a, mut b) = (vec![0.0; n], vec![0.0; n]);
        view.matvec(&beta, &mut a);
        mat.matvec(&beta, &mut b);
        assert_eq!(a, b, "matvec");

        let (mut a, mut b) = (vec![0.0; k], vec![0.0; k]);
        view.xt_vec(&v, &mut a);
        mat.xt_vec(&v, &mut b);
        assert_eq!(a, b, "xt_vec");

        assert_eq!(view.xt_abs_max(&v), mat.xt_abs_max(&v), "xt_abs_max");
        assert_eq!(view.col_norms_sq(), mat.col_norms_sq(), "col_norms_sq");

        let (mut a, mut b) = (vec![0.0; k], vec![0.0; k]);
        let ma = view.xt_vec_abs_max(&v, &mut a);
        let mb = mat.xt_vec_abs_max(&v, &mut b);
        assert_eq!(a, b, "xt_vec_abs_max fill");
        assert_eq!(ma.to_bits(), mb.to_bits(), "xt_vec_abs_max norm");

        let (mut a, mut b) = (Vec::new(), Vec::new());
        view.gather_dense(&(0..k).collect::<Vec<_>>(), &mut a);
        mat.gather_dense(&(0..k).collect::<Vec<_>>(), &mut b);
        assert_eq!(a, b, "gather_dense");

        let mut axpy_a = vec![1.0; n];
        let mut axpy_b = vec![1.0; n];
        view.col_axpy(0, -2.5, &mut axpy_a);
        mat.col_axpy(0, -2.5, &mut axpy_b);
        assert_eq!(axpy_a, axpy_b, "col_axpy");
    }

    #[test]
    fn dense_view_matches_materialized() {
        let (d, _) = random_pair(11, 23, 31, 0.6);
        check_view_matches_materialized(&d, &[4, 0, 17, 30, 17]);
    }

    #[test]
    fn sparse_view_matches_materialized() {
        let (_, s) = random_pair(12, 19, 27, 0.3);
        check_view_matches_materialized(&s, &[1, 26, 13, 2]);
    }

    #[test]
    fn view_over_concrete_types() {
        // The view must compose with concrete (non-enum) parents too —
        // that is what the solvers monomorphize over.
        let (d, s) = random_pair(13, 10, 12, 0.5);
        let cols = [3usize, 7, 11];
        let v: Vec<f64> = (0..10).map(|i| i as f64 * 0.5 - 2.0).collect();
        if let DesignMatrix::Dense(dd) = &d {
            let norms = dd.col_norms_sq();
            let view = DesignView::new(dd, &cols, &norms);
            for (c, &j) in cols.iter().enumerate() {
                assert_eq!(view.col_dot(c, &v), dd.col_dot(j, &v));
            }
        } else {
            panic!("dense expected");
        }
        if let DesignMatrix::Sparse(ss) = &s {
            let norms = ss.col_norms_sq();
            let view = DesignView::new(ss, &cols, &norms);
            for (c, &j) in cols.iter().enumerate() {
                assert_eq!(view.col_dot(c, &v), ss.col_dot(j, &v));
            }
        } else {
            panic!("sparse expected");
        }
    }

    #[test]
    #[should_panic(expected = "view columns must be valid")]
    fn out_of_range_column_rejected() {
        let (d, _) = random_pair(14, 5, 4, 1.0);
        let norms = d.col_norms_sq();
        let cols = [4usize];
        let _ = DesignView::new(&d, &cols, &norms);
    }

    #[test]
    fn empty_view_is_consistent() {
        let (d, _) = random_pair(15, 6, 5, 1.0);
        let norms = d.col_norms_sq();
        let cols: [usize; 0] = [];
        let view = DesignView::new(&d, &cols, &norms);
        assert_eq!(view.p(), 0);
        assert_eq!(view.nnz(), 0);
        let mut out = vec![7.0; 6];
        view.matvec(&[], &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
