//! Synthetic dataset generators standing in for the paper's datasets.
//!
//! We do not redistribute *leukemia*, *Finance/E2006-log1p* or *bcTCGA*;
//! these generators produce datasets in the same structural regime (shape,
//! sparsity pattern, correlation, signal-to-noise), which is what the
//! paper's experiments actually exercise. See DESIGN.md §4 for the
//! substitution argument. Real files in svmlight format can be used instead
//! via `celer::data::svmlight::load_svmlight`.

use crate::data::csc::CscMatrix;
use crate::data::dense::DenseMatrix;
use crate::data::design::{DesignMatrix, DesignOps};
use crate::data::preprocess::{self, PreprocessConfig};
use crate::util::rng::Rng;

/// A generated dataset with its ground truth.
#[derive(Debug, Clone)]
pub struct SynthDataset {
    pub name: String,
    pub x: DesignMatrix,
    pub y: Vec<f64>,
    /// Ground-truth coefficients used to simulate y (pre-preprocessing).
    pub beta_true: Vec<f64>,
}

/// Configuration for the dense correlated generator.
#[derive(Debug, Clone, Copy)]
pub struct DenseSynthConfig {
    pub n: usize,
    pub p: usize,
    /// AR(1) correlation between adjacent features.
    pub rho: f64,
    /// Number of non-zero ground-truth coefficients.
    pub support: usize,
    /// Signal-to-noise ratio ‖Xβ*‖ / ‖ε‖.
    pub snr: f64,
}

/// Dense Gaussian design with AR(1) feature correlation, sparse truth.
pub fn dense_correlated(seed: u64, cfg: &DenseSynthConfig, name: &str) -> SynthDataset {
    let DenseSynthConfig { n, p, rho, support, snr } = *cfg;
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0; n * p];
    let scale = (1.0 - rho * rho).sqrt();
    // AR(1) across features, independent across observations:
    // x_{i,j} = rho * x_{i,j-1} + sqrt(1-rho^2) * eps
    for i in 0..n {
        let mut prev = rng.normal();
        data[i] = prev;
        for j in 1..p {
            let v = rho * prev + scale * rng.normal();
            data[j * n + i] = v;
            prev = v;
        }
    }
    let x = DenseMatrix::from_col_major(n, p, data);

    let mut beta_true = vec![0.0; p];
    for &j in &rng.sample_indices(p, support.min(p)) {
        // signs alternate via rng; magnitudes in [0.5, 1.5]
        let sgn = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        beta_true[j] = sgn * rng.uniform_range(0.5, 1.5);
    }
    let mut signal = vec![0.0; n];
    x.matvec(&beta_true, &mut signal);
    let sig_norm = crate::util::linalg::norm(&signal);
    let mut y = signal;
    let mut noise: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let noise_norm = crate::util::linalg::norm(&noise);
    if noise_norm > 0.0 && snr > 0.0 {
        let f = sig_norm / (snr * noise_norm);
        for v in noise.iter_mut() {
            *v *= f;
        }
    }
    for i in 0..n {
        y[i] += noise[i];
    }
    SynthDataset { name: name.to_string(), x: DesignMatrix::Dense(x), y, beta_true }
}

/// leukemia-like: dense, n=72, p=7129, correlated columns (gene-expression
/// regime), preprocessed as in the paper (unit columns, standardized y).
pub fn leukemia_sim(seed: u64) -> SynthDataset {
    let cfg = DenseSynthConfig { n: 72, p: 7129, rho: 0.5, support: 40, snr: 10.0 };
    let raw = dense_correlated(seed, &cfg, "leukemia-sim");
    finish(raw, &PreprocessConfig::default())
}

/// Smaller leukemia-like dataset for unit/integration tests.
pub fn leukemia_mini(seed: u64) -> SynthDataset {
    let cfg = DenseSynthConfig { n: 48, p: 500, rho: 0.5, support: 15, snr: 10.0 };
    let raw = dense_correlated(seed, &cfg, "leukemia-mini");
    finish(raw, &PreprocessConfig::default())
}

/// bcTCGA-like: dense, n=536, p=17322 (+ intercept → 17323), AR(1).
pub fn bctcga_sim(seed: u64) -> SynthDataset {
    let cfg = DenseSynthConfig { n: 536, p: 17322, rho: 0.6, support: 60, snr: 8.0 };
    let raw = dense_correlated(seed, &cfg, "bctcga-sim");
    let pp = PreprocessConfig { add_intercept: true, ..Default::default() };
    finish(raw, &pp)
}

/// Configuration for the sparse "Finance-like" generator.
#[derive(Debug, Clone, Copy)]
pub struct SparseSynthConfig {
    pub n: usize,
    pub p: usize,
    /// Mean extra non-zeros per column beyond `min_nnz` (exponential tail,
    /// occasionally boosted into heavy columns — the TF-IDF regime).
    pub mean_extra_nnz: f64,
    /// Maximum nnz of the densest column, as a fraction of n.
    pub max_col_fill: f64,
    /// Minimum nnz per column before preprocessing.
    pub min_nnz: usize,
    /// Features per correlation cluster. Real n-gram features co-occur in
    /// the same documents: features within a cluster draw most of their
    /// rows from a shared pool, which is what makes the Lasso dual hard
    /// (and dual extrapolation worthwhile). 0 disables clustering.
    pub cluster_size: usize,
    /// Fraction of each feature's rows drawn from its cluster pool.
    pub cluster_affinity: f64,
    /// Ground-truth support size.
    pub support: usize,
    pub snr: f64,
}

impl Default for SparseSynthConfig {
    fn default() -> Self {
        // ~8× scaled-down Finance/E2006-log1p (n=16087, p=1.67M).
        SparseSynthConfig {
            n: 2000,
            p: 200_000,
            mean_extra_nnz: 12.0,
            max_col_fill: 0.3,
            min_nnz: 4,
            cluster_size: 50,
            cluster_affinity: 0.9,
            support: 200,
            snr: 1.5,
        }
    }
}

/// Sparse design with exponential-tail column densities, clustered
/// (correlated) row supports and TF-IDF-like positive values — the
/// E2006-log1p regime. Ground truth drawn from the denser columns.
pub fn sparse_powerlaw(seed: u64, cfg: &SparseSynthConfig, name: &str) -> SynthDataset {
    let SparseSynthConfig {
        n,
        p,
        mean_extra_nnz,
        max_col_fill,
        min_nnz,
        cluster_size,
        cluster_affinity,
        support,
        snr,
    } = *cfg;
    let mut rng = Rng::new(seed);
    let max_nnz = (((n as f64) * max_col_fill) as usize).max(min_nnz);

    // Cluster row pools: each pool is a set of "documents" its features
    // co-occur in. Pool size ~3× the mean column density.
    let n_clusters = if cluster_size == 0 { 0 } else { p.div_ceil(cluster_size) };
    let pool_size = ((min_nnz as f64 + mean_extra_nnz) * 3.0) as usize + 4;
    let pools: Vec<Vec<usize>> = (0..n_clusters)
        .map(|_| rng.sample_indices(n, pool_size.min(n)))
        .collect();

    let mut cols: Vec<Vec<(u32, f64)>> = Vec::with_capacity(p);
    let mut row_flags = vec![false; n];
    for j in 0..p {
        // exponential density tail + a 1% chance of a heavy column
        let mut nnz = min_nnz + (-mean_extra_nnz * rng.uniform().max(1e-12).ln()) as usize;
        if rng.uniform() < 0.01 {
            nnz = nnz.max(rng.below(max_nnz.max(1)) + min_nnz);
        }
        let nnz = nnz.clamp(min_nnz, max_nnz.min(n));
        // draw rows: mostly from the cluster pool, rest uniform
        static EMPTY: Vec<usize> = Vec::new();
        let pool = if n_clusters > 0 { &pools[j / cluster_size.max(1) % n_clusters] } else { &EMPTY };
        let mut rows = Vec::with_capacity(nnz);
        for v in row_flags.iter_mut() {
            *v = false;
        }
        while rows.len() < nnz {
            let i = if n_clusters > 0 && rng.uniform() < cluster_affinity && !pool.is_empty() {
                pool[rng.below(pool.len())]
            } else {
                rng.below(n)
            };
            if !row_flags[i] {
                row_flags[i] = true;
                rows.push(i);
            }
        }
        rows.sort_unstable();
        let col: Vec<(u32, f64)> = rows
            .into_iter()
            .map(|i| {
                // log1p-TFIDF-like: positive, heavy-ish tail
                let v = (1.0 + rng.uniform() * 20.0).ln() * rng.uniform_range(0.2, 1.0);
                (i as u32, v)
            })
            .collect();
        cols.push(col);
    }
    let x = CscMatrix::from_columns(n, cols);

    // ground truth on reasonably dense columns so the signal is observable
    let dense_cols: Vec<usize> =
        (0..p).filter(|&j| x.col_nnz(j) >= (0.01 * n as f64).max(4.0) as usize).collect();
    let mut beta_true = vec![0.0; p];
    let k = support.min(dense_cols.len());
    let picks = rng.sample_indices(dense_cols.len(), k);
    for &pi in &picks {
        let j = dense_cols[pi];
        let sgn = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        beta_true[j] = sgn * rng.uniform_range(0.5, 2.0);
    }
    let mut signal = vec![0.0; n];
    x.matvec(&beta_true, &mut signal);
    let sig_norm = crate::util::linalg::norm(&signal);
    let mut y = signal;
    let mut noise: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let noise_norm = crate::util::linalg::norm(&noise);
    if noise_norm > 0.0 && snr > 0.0 {
        let f = sig_norm / (snr * noise_norm);
        for v in noise.iter_mut() {
            *v *= f;
        }
    }
    for i in 0..n {
        y[i] += noise[i];
    }
    SynthDataset { name: name.to_string(), x: DesignMatrix::Sparse(x), y, beta_true }
}

/// Finance-like sparse dataset with the paper's preprocessing
/// (min-3-nnz filter, unit columns, standardized y, intercept column).
pub fn finance_sim(seed: u64) -> SynthDataset {
    let raw = sparse_powerlaw(seed, &SparseSynthConfig::default(), "finance-sim");
    finish(raw, &preprocess::finance_config())
}

/// Small sparse dataset for tests.
pub fn finance_mini(seed: u64) -> SynthDataset {
    let cfg = SparseSynthConfig { n: 200, p: 2000, support: 20, ..Default::default() };
    let raw = sparse_powerlaw(seed, &cfg, "finance-mini");
    finish(raw, &preprocess::finance_config())
}

/// The 2×2 toy problem of Figure 1: two correlated unit-norm features.
pub fn toy_2x2() -> SynthDataset {
    // x1 and x2 at an acute angle; y placed so that y/λ projects onto the
    // corner of the two slabs (both constraints active at the solution).
    let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.6, 0.0, 0.8]);
    let x = match preprocess::normalize_columns(DesignMatrix::Dense(x)) {
        DesignMatrix::Dense(d) => d,
        _ => unreachable!(),
    };
    let y = vec![1.5, 0.9];
    SynthDataset {
        name: "toy-2x2".into(),
        x: DesignMatrix::Dense(x),
        y,
        beta_true: vec![0.0, 0.0],
    }
}

/// Dense stress design for the parallel-runtime tests and benches:
/// n = 64, p = 8192 standard-normal entries, so a full-p scan
/// (p × n = 2¹⁹ flops) clears the work-based parallel threshold of
/// `util::par`. `y` is a standard-normal n-vector; no preprocessing.
pub fn dense_scan_stress(seed: u64) -> SynthDataset {
    let (n, p) = (64usize, 8192usize);
    let mut rng = Rng::new(seed);
    let data: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    SynthDataset {
        name: "dense-scan-stress".into(),
        x: DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data)),
        y,
        beta_true: Vec::new(),
    }
}

/// Sparse (CSC) stress design for the parallel-runtime tests and
/// benches: n = 64, p = 32768 at ~20% density, so p × mean-nnz ≈ 4·10⁵
/// clears the parallel threshold under the *sparse* cost model
/// (`col_cost_hint` = mean nnz). `y` is a standard-normal n-vector.
pub fn sparse_scan_stress(seed: u64) -> SynthDataset {
    let (n, p) = (64usize, 32768usize);
    let mut rng = Rng::new(seed);
    let mut dense = vec![0.0; n * p];
    for v in dense.iter_mut() {
        if rng.uniform() < 0.2 {
            *v = rng.normal();
        }
    }
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    SynthDataset {
        name: "sparse-scan-stress".into(),
        x: DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &dense)),
        y,
        beta_true: Vec::new(),
    }
}

/// The label binarization lives next to the [`Logistic`] datafit; the
/// synthetic generators and tests reach it from here too.
///
/// [`Logistic`]: crate::datafit::Logistic
pub use crate::datafit::sign_labels;

/// Binary-classification dataset for the sparse logistic solvers: the
/// `leukemia_mini` design with labels `sign(y)` — the signal is the same
/// sparse linear model, observed through its sign.
pub fn logreg_mini(seed: u64) -> SynthDataset {
    let mut ds = leukemia_mini(seed);
    ds.y = sign_labels(&ds.y);
    ds.name = "logreg-mini".into();
    ds
}

/// Count-data dataset for the sparse Poisson solvers: the
/// `leukemia_mini` design with counts `round(exp(2·y/‖y‖_∞))` — small
/// non-negative integers driven by the same sparse signal
/// (deterministic; no Poisson sampler needed for the solver tests).
pub fn poisson_mini(seed: u64) -> SynthDataset {
    let mut ds = leukemia_mini(seed);
    let ymax = ds.y.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1e-12);
    ds.y = ds.y.iter().map(|&v| (2.0 * v / ymax).exp().round()).collect();
    ds.name = "poisson-mini".into();
    ds
}

fn finish(raw: SynthDataset, cfg: &PreprocessConfig) -> SynthDataset {
    let (x, y, rep) = preprocess::preprocess(&raw.x, &raw.y, cfg);
    // remap beta_true through kept columns (+0 for intercept)
    let mut beta_true: Vec<f64> = rep.kept_columns.iter().map(|&j| raw.beta_true[j]).collect();
    if cfg.add_intercept {
        beta_true.push(0.0);
    }
    SynthDataset { name: raw.name, x, y, beta_true }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glm_targets_are_in_domain() {
        let lr = logreg_mini(7);
        assert!(lr.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(lr.y.iter().any(|&v| v == 1.0) && lr.y.iter().any(|&v| v == -1.0));
        let ps = poisson_mini(7);
        assert!(ps.y.iter().all(|&v| v >= 0.0 && v == v.round()));
        assert!(ps.y.iter().any(|&v| v > 0.0));
        assert_eq!(sign_labels(&[0.0, -0.1, 3.0]), vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn leukemia_mini_shape_and_norms() {
        let ds = leukemia_mini(0);
        assert_eq!(ds.x.n(), 48);
        assert_eq!(ds.x.p(), 500);
        for j in 0..ds.x.p() {
            assert!((ds.x.col_norm_sq(j) - 1.0).abs() < 1e-10);
        }
        let mean: f64 = ds.y.iter().sum::<f64>() / 48.0;
        assert!(mean.abs() < 1e-12);
        assert!((crate::util::linalg::norm(&ds.y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn finance_mini_sparse_regime() {
        let ds = finance_mini(0);
        assert!(ds.x.is_sparse());
        assert_eq!(ds.x.n(), 200);
        // preprocessing may drop nothing (min_nnz enforced at generation)
        assert!(ds.x.p() >= 2000, "intercept appended");
        assert!(ds.x.density() < 0.2, "must stay sparse: {}", ds.x.density());
        // every kept column has >= 3 nnz except none; intercept is dense
        let p = ds.x.p();
        assert_eq!(ds.x.col_nnz(p - 1), 200, "intercept column is full");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = leukemia_mini(5);
        let b = leukemia_mini(5);
        assert_eq!(a.y, b.y);
        let v = vec![1.0; 48];
        for j in (0..500).step_by(97) {
            assert_eq!(a.x.col_dot(j, &v), b.x.col_dot(j, &v));
        }
        let c = leukemia_mini(6);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn correlation_structure_present() {
        let cfg = DenseSynthConfig { n: 2000, p: 3, rho: 0.8, support: 0, snr: 1.0 };
        let ds = dense_correlated(1, &cfg, "t");
        // empirical corr(x0, x1) should be near rho
        let x = match &ds.x {
            DesignMatrix::Dense(d) => d,
            _ => unreachable!(),
        };
        let c01 = crate::util::linalg::dot(x.col(0), x.col(1))
            / (x.col_norm_sq(0).sqrt() * x.col_norm_sq(1).sqrt());
        assert!((c01 - 0.8).abs() < 0.06, "corr={c01}");
    }

    #[test]
    fn snr_controls_noise() {
        let hi = dense_correlated(
            3,
            &DenseSynthConfig { n: 100, p: 50, rho: 0.0, support: 5, snr: 100.0 },
            "hi",
        );
        // residual from ground truth should be tiny relative to y
        let mut fit = vec![0.0; 100];
        hi.x.matvec(&hi.beta_true, &mut fit);
        let resid: f64 = hi
            .y
            .iter()
            .zip(&fit)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let ynorm = crate::util::linalg::norm(&hi.y);
        assert!(resid / ynorm < 0.05, "snr=100 => resid tiny: {}", resid / ynorm);
    }

    #[test]
    fn toy_is_unit_norm() {
        let ds = toy_2x2();
        assert_eq!(ds.x.n(), 2);
        assert!((ds.x.col_norm_sq(0) - 1.0).abs() < 1e-12);
        assert!((ds.x.col_norm_sq(1) - 1.0).abs() < 1e-12);
    }
}
