//! Compressed sparse column (CSC) design matrix.
//!
//! CSC is the natural sparse layout for coordinate descent: each feature
//! column `x_j` is a contiguous (indices, values) run, so the per-feature
//! dot/axpy used by CD touch only `nnz(x_j)` entries.

use crate::data::design::DesignOps;

/// Decode-once batched multi-lane dot over one column's stored entries
/// (see `col_dot_lanes`): each (row index, value) pair is decoded once
/// and applied to every lane. Entries are processed in PAIRS
/// (`out[t] += x₀·v₀ + x₁·v₁` per lane, odd tail entry accumulated
/// alone) so each lane carries two independent gather chains; this
/// pairwise order is part of the kernel-layer reduction contract
/// mirrored in `tests/prop_simd.rs`. Shared by the in-memory
/// [`CscMatrix`] and the out-of-core column store
/// ([`crate::data::ooc::OocColumnStore`]) so both produce bit-identical
/// lane sweeps from the same stored entries.
///
/// # Safety
/// Every row index must be `< n`, and `(k + 1) · n <= v.len()` for every
/// lane `k` in `lanes`. `idx` and `val` must have equal length.
pub(crate) unsafe fn lane_dot_entries(
    idx: &[u32],
    val: &[f64],
    v: &[f64],
    n: usize,
    lanes: &[usize],
    out: &mut [f64],
) {
    debug_assert_eq!(lanes.len(), out.len());
    debug_assert_eq!(idx.len(), val.len());
    debug_assert!(lanes.iter().all(|&k| (k + 1) * n <= v.len()));
    debug_assert!(idx.iter().all(|&i| (i as usize) < n));
    out.fill(0.0);
    let m = idx.len();
    let main = m - m % 2;
    let mut e = 0;
    while e < main {
        let row0 = *idx.get_unchecked(e) as usize;
        let row1 = *idx.get_unchecked(e + 1) as usize;
        let xv0 = *val.get_unchecked(e);
        let xv1 = *val.get_unchecked(e + 1);
        for (t, &k) in lanes.iter().enumerate() {
            let base = k * n;
            *out.get_unchecked_mut(t) +=
                xv0 * v.get_unchecked(base + row0) + xv1 * v.get_unchecked(base + row1);
        }
        e += 2;
    }
    if main < m {
        let row = *idx.get_unchecked(main) as usize;
        let xv = *val.get_unchecked(main);
        for (t, &k) in lanes.iter().enumerate() {
            *out.get_unchecked_mut(t) += xv * v.get_unchecked(k * n + row);
        }
    }
}

/// Decode-once batched multi-lane axpy over one column's stored entries
/// (see `col_axpy_lanes`). In a CD sweep most lanes leave most columns
/// unchanged, so the common cases are 0 or 1 non-zero alphas — those
/// dispatch to the single-lane gather kernel instead of branching per
/// stored entry. Shared with the out-of-core store like
/// [`lane_dot_entries`].
///
/// # Safety
/// Same contract as [`lane_dot_entries`], with `v` as the mutable
/// lane-strided buffer.
pub(crate) unsafe fn lane_axpy_entries(
    idx: &[u32],
    val: &[f64],
    alphas: &[f64],
    v: &mut [f64],
    n: usize,
    lanes: &[usize],
) {
    debug_assert_eq!(lanes.len(), alphas.len());
    debug_assert_eq!(idx.len(), val.len());
    debug_assert!(lanes.iter().all(|&k| (k + 1) * n <= v.len()));
    debug_assert!(idx.iter().all(|&i| (i as usize) < n));
    let nz = alphas.iter().filter(|&&a| a != 0.0).count();
    if nz == 0 {
        return;
    }
    if nz == 1 {
        let t = alphas.iter().position(|&a| a != 0.0).expect("nz == 1");
        let k = lanes[t];
        crate::util::simd::gather_axpy(idx, val, alphas[t], &mut v[k * n..(k + 1) * n]);
        return;
    }
    for e in 0..idx.len() {
        let row = *idx.get_unchecked(e) as usize;
        let xv = *val.get_unchecked(e);
        for (t, &k) in lanes.iter().enumerate() {
            let alpha = *alphas.get_unchecked(t);
            if alpha != 0.0 {
                *v.get_unchecked_mut(k * n + row) += alpha * xv;
            }
        }
    }
}

/// Sparse n×p matrix in CSC format.
#[derive(Debug, Clone)]
pub struct CscMatrix {
    n: usize,
    p: usize,
    /// Column pointers, length p+1.
    indptr: Vec<usize>,
    /// Row indices, length nnz, strictly increasing within a column.
    indices: Vec<u32>,
    /// Values, length nnz.
    data: Vec<f64>,
}

impl CscMatrix {
    /// Build from raw CSC arrays. Validates structure.
    pub fn new(n: usize, p: usize, indptr: Vec<usize>, indices: Vec<u32>, data: Vec<f64>) -> Self {
        assert_eq!(indptr.len(), p + 1, "indptr must have p+1 entries");
        assert_eq!(indices.len(), data.len());
        assert_eq!(*indptr.last().unwrap(), data.len());
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]));
        debug_assert!(indices.iter().all(|&i| (i as usize) < n));
        CscMatrix { n, p, indptr, indices, data }
    }

    /// Build from per-column (row, value) triplets.
    pub fn from_columns(n: usize, cols: Vec<Vec<(u32, f64)>>) -> Self {
        let p = cols.len();
        let mut indptr = Vec::with_capacity(p + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for mut col in cols {
            col.sort_by_key(|&(i, _)| i);
            for (i, v) in col {
                assert!((i as usize) < n);
                if v != 0.0 {
                    indices.push(i);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CscMatrix { n, p, indptr, indices, data }
    }

    /// Build from a dense column-major buffer, dropping zeros.
    pub fn from_dense(n: usize, p: usize, dense_col_major: &[f64]) -> Self {
        assert_eq!(dense_col_major.len(), n * p);
        let mut indptr = Vec::with_capacity(p + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for j in 0..p {
            for i in 0..n {
                let v = dense_col_major[j * n + i];
                if v != 0.0 {
                    indices.push(i as u32);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CscMatrix { n, p, indptr, indices, data }
    }

    /// Column `j` as (row indices, values).
    #[inline(always)]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// Mutable values of column `j` (indices immutable).
    pub fn col_values_mut(&mut self, j: usize) -> &mut [f64] {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        &mut self.data[lo..hi]
    }

    /// Keep only the columns in `keep` (in the given order).
    pub fn select_columns(&self, keep: &[usize]) -> CscMatrix {
        let mut indptr = Vec::with_capacity(keep.len() + 1);
        let total: usize = keep.iter().map(|&j| self.indptr[j + 1] - self.indptr[j]).sum();
        let mut indices = Vec::with_capacity(total);
        let mut data = Vec::with_capacity(total);
        indptr.push(0);
        for &j in keep {
            let (idx, val) = self.col(j);
            indices.extend_from_slice(idx);
            data.extend_from_slice(val);
            indptr.push(indices.len());
        }
        CscMatrix { n: self.n, p: keep.len(), indptr, indices, data }
    }

    /// Dense column-major copy (tests / small problems only).
    pub fn to_dense_col_major(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n * self.p];
        for j in 0..self.p {
            let (idx, val) = self.col(j);
            for (&i, &v) in idx.iter().zip(val) {
                out[j * self.n + i as usize] = v;
            }
        }
        out
    }
}

impl DesignOps for CscMatrix {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn p(&self) -> usize {
        self.p
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let (idx, val) = self.col(j);
        // Hot path (≈half of every CD epoch's memory traffic). Row
        // indices are validated < n at construction, so the unchecked
        // gather is sound; four accumulators hide the gather latency
        // (see `util::simd` for the accumulator-order contract).
        unsafe { crate::util::simd::gather_dot(idx, val, v) }
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        let (idx, val) = self.col(j);
        unsafe { crate::util::simd::gather_axpy(idx, val, alpha, out) }
    }

    #[inline]
    fn col_norm_sq(&self, j: usize) -> f64 {
        let (_, val) = self.col(j);
        // Stored values are contiguous, so the width-8 kernel applies.
        crate::util::simd::dot(val, val)
    }

    fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }

    fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for j in 0..self.p {
            let b = beta[j];
            if b != 0.0 {
                self.col_axpy(j, b, out);
            }
        }
    }

    fn col_cost_hint(&self) -> usize {
        // Mean stored nnz per column: a full-design scan touches each
        // stored entry once, so p × hint ≈ nnz(X).
        (self.data.len() / self.p.max(1)).max(1)
    }

    fn xt_vec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.n);
        assert_eq!(out.len(), self.p);
        // Parallel over columns: each column's (indices, values) run is
        // independent and reads from the shared vector v.
        crate::util::par::par_fill_cost(out, self.col_cost_hint(), |j| self.col_dot(j, v));
    }

    fn gather_dense(&self, cols: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.resize(cols.len() * self.n, 0.0);
        for (c, &j) in cols.iter().enumerate() {
            let (idx, val) = self.col(j);
            let dst = &mut out[c * self.n..(c + 1) * self.n];
            for (&i, &v) in idx.iter().zip(val) {
                dst[i as usize] = v;
            }
        }
    }

    fn nnz(&self) -> usize {
        self.data.len()
    }

    fn shadow_f32(&self) -> crate::data::shadow::ShadowF32 {
        crate::data::shadow::ShadowF32::from_csc(
            self.n,
            self.p,
            &self.indptr,
            &self.indices,
            &self.data,
        )
    }

    #[inline]
    fn col_wnorm_sq(&self, j: usize, w: &[f64]) -> f64 {
        let (idx, val) = self.col(j);
        unsafe { crate::util::simd::gather_wssq(idx, val, w) }
    }

    #[inline]
    fn col_waxpy(&self, j: usize, alpha: f64, w: &[f64], out: &mut [f64]) {
        let (idx, val) = self.col(j);
        debug_assert_eq!(w.len(), out.len());
        unsafe { crate::util::simd::gather_waxpy(idx, val, alpha, w, out) }
    }

    // Batched multi-λ sweeps (see `solvers/batch.rs`): the shared
    // decode-once entry kernels ([`lane_dot_entries`] /
    // [`lane_axpy_entries`]) run directly on the column's stored-entry
    // slices — the same kernels the out-of-core store calls on its
    // chunk-cached slices, so both storages produce identical bits.
    fn col_dot_lanes(&self, j: usize, v: &[f64], n: usize, lanes: &[usize], out: &mut [f64]) {
        let (idx, val) = self.col(j);
        // SAFETY: row indices are validated < n at construction and the
        // lane bounds are debug-asserted inside the kernel.
        unsafe { lane_dot_entries(idx, val, v, n, lanes, out) }
    }

    fn col_axpy_lanes(&self, j: usize, alphas: &[f64], v: &mut [f64], n: usize, lanes: &[usize]) {
        let (idx, val) = self.col(j);
        // SAFETY: as in `col_dot_lanes`.
        unsafe { lane_axpy_entries(idx, val, alphas, v, n, lanes) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::design::DesignOps;

    /// X = [[1, 0], [0, 2], [3, 0]]  (n=3, p=2)
    fn sample() -> CscMatrix {
        CscMatrix::from_columns(3, vec![vec![(0, 1.0), (2, 3.0)], vec![(1, 2.0)]])
    }

    #[test]
    fn structure() {
        let x = sample();
        assert_eq!(x.n(), 3);
        assert_eq!(x.p(), 2);
        assert_eq!(x.nnz(), 3);
        assert_eq!(x.col_nnz(0), 2);
        let (idx, val) = x.col(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(val, &[1.0, 3.0]);
    }

    #[test]
    fn ops_match_dense_oracle() {
        let x = sample();
        let dense = crate::data::dense::DenseMatrix::from_col_major(3, 2, x.to_dense_col_major());
        let v = [0.5, -1.0, 2.0];
        for j in 0..2 {
            assert_eq!(x.col_dot(j, &v), dense.col_dot(j, &v));
            assert_eq!(x.col_norm_sq(j), dense.col_norm_sq(j));
        }
        let beta = [2.0, -3.0];
        let (mut a, mut b) = (vec![0.0; 3], vec![0.0; 3]);
        x.matvec(&beta, &mut a);
        dense.matvec(&beta, &mut b);
        assert_eq!(a, b);
        let (mut a, mut b) = (vec![0.0; 2], vec![0.0; 2]);
        x.xt_vec(&v, &mut a);
        dense.xt_vec(&v, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn select_columns_keeps_structure() {
        let x = sample();
        let sub = x.select_columns(&[1]);
        assert_eq!(sub.p(), 1);
        assert_eq!(sub.col(0).0, &[1]);
        assert_eq!(sub.col(0).1, &[2.0]);
        // reorder + duplicate
        let sub2 = x.select_columns(&[1, 0, 1]);
        assert_eq!(sub2.p(), 3);
        assert_eq!(sub2.col(2).1, &[2.0]);
    }

    #[test]
    fn gather_dense_pads_zeros() {
        let x = sample();
        let mut buf = Vec::new();
        x.gather_dense(&[0, 1], &mut buf);
        assert_eq!(buf, vec![1.0, 0.0, 3.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn from_dense_round_trip() {
        let dense = vec![1.0, 0.0, 3.0, 0.0, 2.0, 0.0];
        let x = CscMatrix::from_dense(3, 2, &dense);
        assert_eq!(x.to_dense_col_major(), dense);
        assert_eq!(x.nnz(), 3);
    }

    #[test]
    fn from_columns_sorts_and_drops_zeros() {
        let x = CscMatrix::from_columns(4, vec![vec![(3, 1.0), (1, 2.0), (2, 0.0)]]);
        let (idx, val) = x.col(0);
        assert_eq!(idx, &[1, 3]);
        assert_eq!(val, &[2.0, 1.0]);
    }
}
