//! Data substrate: design matrices (dense + CSC sparse), svmlight I/O,
//! synthetic dataset generators, and the paper's preprocessing pipeline.

pub mod csc;
pub mod dense;
pub mod design;
pub mod preprocess;
pub mod svmlight;
pub mod synth;

pub use csc::CscMatrix;
pub use dense::DenseMatrix;
pub use design::{DesignMatrix, DesignOps};
