//! Data substrate: design matrices (dense + CSC sparse + out-of-core
//! column store + multi-store shards), zero-copy column-restricted
//! views, svmlight I/O, synthetic dataset generators, and the paper's
//! preprocessing pipeline.

pub mod csc;
pub mod dense;
pub mod design;
pub mod ooc;
pub mod preprocess;
pub mod shadow;
pub mod shard;
pub mod svmlight;
pub mod synth;
pub mod validate;
pub mod view;

pub use csc::CscMatrix;
pub use dense::DenseMatrix;
pub use design::{DesignMatrix, DesignOps};
pub use ooc::OocColumnStore;
pub use shard::ShardedStore;
pub use view::DesignView;
