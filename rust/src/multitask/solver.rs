//! Multi-Task Lasso solvers on the shared block-coefficient engine
//! (paper §7, Eqs. 20–24).
//!
//! The problem is `min_B ½‖Y − XB‖_F² + λ Σ_j ‖B_{j·}‖₂` (Eq. 20) with
//! dual feasible set `{Θ : ‖x_jᵀΘ‖₂ ≤ 1 ∀j}` (Eq. 22) and block-CD
//! update `B_{j·} ← BST(B_{j·} + x_jᵀR/‖x_j‖², λ/‖x_j‖²)` (Eq. 21).
//! Dual extrapolation runs on the vectorized residual matrices exactly
//! as Definition 1 (the VAR argument carries over row-wise, Eq. 23), and
//! the working-set pricing is `d_j(Θ) = (1 − ‖x_jᵀΘ‖₂)/‖x_j‖` (Eq. 24 —
//! the §7 form of Eqs. 10–11).
//!
//! Both solvers are thin layers over [`crate::solvers::block`]:
//!
//! - [`mt_bcd_solve`] runs [`BlockCdStrategy`] on the full design
//!   through [`solve_blocks`] — Algorithm 1 lifted to matrix residuals.
//! - [`mt_celer_solve`] is Algorithm 4 with block d-scores: it prices
//!   features with [`crate::screening::fill_d_scores`] on the cached
//!   `‖x_jᵀΘ‖₂` rows, builds `W_t` with [`crate::ws::build_working_set`],
//!   and solves every subproblem on a **zero-copy**
//!   [`DesignView`](crate::data::view::DesignView) of `X_{W_t}` with a
//!   nested, persistent [`BlockWorkspace`] — no `select_columns`
//!   materialization and no per-outer-iteration allocation once warm.
//!
//! The public API keeps the row-major n×q layout for `Y`/residual/Θ;
//! internally everything is lane-major so all design access goes through
//! the one pair of multi-RHS kernels shared with the batched engine
//! ([`DesignOps::col_dot_lanes`] / [`DesignOps::col_axpy_lanes`]).

use crate::data::design::{DesignMatrix, DesignOps};
use crate::data::view::DesignView;
use crate::lasso::dual;
use crate::multitask::{lanes_to_rowmajor, rowmajor_to_lanes, TaskMatrix};
use crate::solvers::block::{
    block_support, primal_from_residual_blocks, solve_blocks, xt_rows_max, BlockCdStrategy,
    BlockWorkspace,
};
use crate::solvers::engine::{EngineConfig, Init, StopRule};
use crate::ws::{build_working_set, WsPolicy};

/// Maximum outer (working-set) iterations of [`mt_celer_solve`].
const MT_MAX_OUTER: usize = 50;

/// Primal objective `P(B) = ½‖R‖_F² + λ‖B‖_{2,1}` from the residual
/// (any consistent layout; Frobenius terms are layout-agnostic).
pub fn mt_primal(r: &[f64], b: &TaskMatrix, lambda: f64) -> f64 {
    0.5 * crate::util::linalg::dot(r, r) + lambda * b.l21_norm()
}

/// Dual objective `D(Θ) = ½‖Y‖_F² − (λ²/2)‖Θ − Y/λ‖_F²` — exactly the
/// scalar [`dual::dual_objective`] on the vectorized matrices. The
/// solvers themselves use the `‖Y‖_F²`-cached variant
/// ([`dual::dual_objective_cached`]) so the norm is computed once per
/// solve, not at every gap check.
pub fn mt_dual(y: &[f64], theta: &[f64], lambda: f64) -> f64 {
    dual::dual_objective(y, theta, lambda)
}

/// `out[j] = ‖x_jᵀΘ‖₂` for a row-major n×q `theta` — the §7 dual
/// feasibility / pricing quantity, computed with the shared multi-RHS
/// kernels (one-shot convenience wrapper over
/// [`xt_rows_max`](crate::solvers::block::xt_rows_max); the solvers use
/// the allocation-free workspace path).
pub fn mt_xt_row_norms<D: DesignOps>(x: &D, theta: &[f64], q: usize, out: &mut [f64]) {
    let n = x.n();
    let p = x.p();
    assert_eq!(theta.len(), n * q, "theta must be row-major n×q");
    assert_eq!(out.len(), p);
    let mut theta_lanes = Vec::new();
    rowmajor_to_lanes(theta, n, q, &mut theta_lanes);
    let lanes: Vec<usize> = (0..q).collect();
    let mut block = vec![0.0; p * q];
    xt_rows_max(x, &theta_lanes, n, q, &lanes, &mut block, out);
}

/// `λ_max = max_j ‖x_jᵀY‖₂` — smallest λ with B̂ = 0 (Y row-major n×q).
pub fn mt_lambda_max<D: DesignOps>(x: &D, y: &[f64], q: usize) -> f64 {
    let mut rows = vec![0.0; x.p()];
    mt_xt_row_norms(x, y, q, &mut rows);
    rows.into_iter().fold(0.0, f64::max)
}

/// Configuration for the Multi-Task solvers.
#[derive(Debug, Clone)]
pub struct MtConfig {
    pub tol: f64,
    pub max_epochs: usize,
    pub gap_freq: usize,
    pub k: usize,
    pub extrapolate: bool,
}

impl Default for MtConfig {
    fn default() -> Self {
        MtConfig {
            tol: 1e-6,
            max_epochs: 20_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
        }
    }
}

impl MtConfig {
    /// The block-engine configuration for an inner/full solve at `tol`.
    fn engine_cfg(&self, tol: f64) -> EngineConfig {
        EngineConfig {
            tol,
            max_epochs: self.max_epochs,
            gap_freq: self.gap_freq,
            k: self.k,
            extrapolate: self.extrapolate,
            best_dual: true,
            screen: false,
            trace: false,
            stop: StopRule::DualityGap,
            ..EngineConfig::default()
        }
    }
}

/// Multi-Task solve result.
#[derive(Debug, Clone)]
pub struct MtResult {
    pub b: TaskMatrix,
    /// Residual Y − XB, row-major n×q.
    pub r: Vec<f64>,
    /// Best feasible dual point, row-major n×q.
    pub theta: Vec<f64>,
    pub gap: f64,
    pub epochs: usize,
    pub converged: bool,
    /// Typed outcome (certified / budget-exhausted / recovered).
    pub status: crate::util::error::SolveOutcome,
}

/// Cyclic block-CD for the Multi-Task Lasso with dual extrapolation
/// (Algorithm 1 lifted to matrix residuals): one
/// [`BlockCdStrategy`] run of the shared block engine on the full
/// design. `y` is row-major n×q.
pub fn mt_bcd_solve(
    x: &DesignMatrix,
    y: &[f64],
    q: usize,
    lambda: f64,
    b0: Option<&TaskMatrix>,
    cfg: &MtConfig,
) -> MtResult {
    let mut ws = BlockWorkspace::new();
    if let Some(b) = b0 {
        assert_eq!((b.p, b.q), (crate::data::design::DesignOps::p(x), q));
    }
    let b0 = b0.map(|b| b.data.as_slice());
    match x {
        DesignMatrix::Dense(d) => mt_bcd_generic(d, y, q, lambda, b0, cfg, &mut ws),
        DesignMatrix::Sparse(s) => mt_bcd_generic(s, y, q, lambda, b0, cfg, &mut ws),
        DesignMatrix::Ooc(o) => mt_bcd_generic(o, y, q, lambda, b0, cfg, &mut ws),
        DesignMatrix::Sharded(sh) => mt_bcd_generic(sh, y, q, lambda, b0, cfg, &mut ws),
    }
}

fn mt_bcd_generic<D: DesignOps>(
    x: &D,
    y: &[f64],
    q: usize,
    lambda: f64,
    b0: Option<&[f64]>,
    cfg: &MtConfig,
    ws: &mut BlockWorkspace,
) -> MtResult {
    let n = x.n();
    let p = x.p();
    assert_eq!(y.len(), n * q, "Y must be row-major n×q");
    rowmajor_to_lanes(y, n, q, &mut ws.y_lanes);
    let y_lanes = std::mem::take(&mut ws.y_lanes);
    let init = match b0 {
        Some(b) => Init::Warm(b),
        None => Init::Zeros,
    };
    let out = solve_blocks(
        x,
        &y_lanes,
        q,
        lambda,
        init,
        None,
        &cfg.engine_cfg(cfg.tol),
        ws,
        &mut BlockCdStrategy,
    );
    ws.y_lanes = y_lanes;
    let b = TaskMatrix { p, q, data: ws.beta.clone() };
    let mut r = Vec::new();
    lanes_to_rowmajor(&ws.r, n, q, &mut r);
    let mut theta = Vec::new();
    lanes_to_rowmajor(&ws.dual.theta, n, q, &mut theta);
    MtResult {
        b,
        r,
        theta,
        gap: out.gap,
        epochs: out.epochs,
        converged: out.converged,
        status: out.status,
    }
}

/// CELER-style working-set Multi-Task solver (Algorithm 4 with the §7
/// block d-scores): rank rows by `d_j(Θ) = (1 − ‖x_jᵀΘ‖₂)/‖x_j‖`,
/// solve subproblems on zero-copy [`DesignView`]s of `X_{W_t}` with the
/// block engine, warm-started, with the pruning working-set policy.
pub fn mt_celer_solve(
    x: &DesignMatrix,
    y: &[f64],
    q: usize,
    lambda: f64,
    cfg: &MtConfig,
) -> MtResult {
    let mut ws = BlockWorkspace::new();
    mt_celer_solve_ws(x, y, q, lambda, None, cfg, &mut ws)
}

/// [`mt_celer_solve`] on a caller-provided reusable [`BlockWorkspace`]
/// with an optional warm start (`b0`: p×q row-major blocks, the
/// `TaskMatrix::data` layout). The λ-path driver
/// ([`crate::solvers::path::run_mt_path`]) reuses one workspace for the
/// whole warm-started path, eliminating per-λ reallocation of B / R /
/// XᵀR / the extrapolation ring.
pub fn mt_celer_solve_ws(
    x: &DesignMatrix,
    y: &[f64],
    q: usize,
    lambda: f64,
    b0: Option<&[f64]>,
    cfg: &MtConfig,
    ws: &mut BlockWorkspace,
) -> MtResult {
    // Dispatch once; outer loop and view-based inner solves monomorphize.
    match x {
        DesignMatrix::Dense(d) => mt_celer_generic(d, y, q, lambda, b0, cfg, ws),
        DesignMatrix::Sparse(s) => mt_celer_generic(s, y, q, lambda, b0, cfg, ws),
        DesignMatrix::Ooc(o) => mt_celer_generic(o, y, q, lambda, b0, cfg, ws),
        DesignMatrix::Sharded(sh) => mt_celer_generic(sh, y, q, lambda, b0, cfg, ws),
    }
}

fn mt_celer_generic<D: DesignOps>(
    x: &D,
    y: &[f64],
    q: usize,
    lambda: f64,
    b0: Option<&[f64]>,
    cfg: &MtConfig,
    ws: &mut BlockWorkspace,
) -> MtResult {
    let n = x.n();
    let p = x.p();
    assert_eq!(y.len(), n * q, "Y must be row-major n×q");
    rowmajor_to_lanes(y, n, q, &mut ws.y_lanes);
    let y_lanes = std::mem::take(&mut ws.y_lanes);

    // ---- outer-loop state in the reusable workspace ----
    ws.init_primal(x, &y_lanes, q, b0);
    ws.scratch.prepare(n, q, p);
    // ‖Y‖_F² once per solve: every outer gap check reuses it.
    let y_norm_sq = crate::util::linalg::dot(&y_lanes, &y_lanes);

    // init: Θ⁰ = Θ⁰_inner = Y / max_j ‖x_jᵀY‖₂ (Algorithm 4, Eq. 22)
    let lmax = xt_rows_max(
        x,
        &y_lanes,
        n,
        q,
        &ws.lanes,
        &mut ws.scratch.xtr,
        &mut ws.scratch.xtr_rows,
    )
    .max(f64::MIN_POSITIVE);
    ws.theta.clear();
    ws.theta.extend(y_lanes.iter().map(|&v| v / lmax));
    ws.theta_inner.clear();
    ws.theta_inner.extend_from_slice(&ws.theta);
    ws.theta_res.clear();
    ws.theta_res.resize(q * n, 0.0);
    // ‖x_jᵀΘ_inner‖₂ rows, maintained by the lift step (one multi-RHS
    // sweep serves both the feasibility rescale and the next pricing).
    ws.xtheta_inner_rows.resize(p, 0.0);
    xt_rows_max(
        x,
        &ws.theta_inner,
        n,
        q,
        &ws.lanes,
        &mut ws.scratch.xtr_acc,
        &mut ws.xtheta_inner_rows,
    );
    ws.xtheta_rows.resize(p, 0.0);
    ws.d_scores.resize(p, 0.0);

    // warm start: p₁ = |S_{B⁰}| when B⁰ ≠ 0 (Algorithm 4)
    let mut policy = WsPolicy::default();
    let s0 = block_support(&ws.beta, q).len();
    if s0 > 0 {
        policy.p1 = s0;
    }

    let mut inner_ws = ws.take_inner();
    let mut prev_ws: Vec<usize> = block_support(&ws.beta, q);
    let mut prev_ws_size = 0usize;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut total_inner_epochs = 0usize;
    let mut prev_gap = f64::INFINITY;
    let mut all_faults: Vec<crate::util::error::FaultEvent> = Vec::new();

    for t_out in 1..=MT_MAX_OUTER {
        // ---- Θ^t = argmax D over {Θ^{t-1}, Θ_inner^{t-1}, Θ_res^t} ----
        // Fused Frobenius rescale (Eq. 4 lifted to §7): XᵀR blocks, the
        // pricing row norms and max_j ‖x_jᵀR‖₂ in one pooled pass.
        let denom = lambda.max(xt_rows_max(
            x,
            &ws.r,
            n,
            q,
            &ws.lanes,
            &mut ws.scratch.xtr,
            &mut ws.scratch.xtr_rows,
        ));
        {
            let r = &ws.r;
            ws.theta_res.clear();
            ws.theta_res.extend(r.iter().map(|&v| v / denom));
        }
        let d_prev = dual::dual_objective_cached(&y_lanes, &ws.theta, lambda, y_norm_sq);
        let d_inner = dual::dual_objective_cached(&y_lanes, &ws.theta_inner, lambda, y_norm_sq);
        let d_res = dual::dual_objective_cached(&y_lanes, &ws.theta_res, lambda, y_norm_sq);
        // argmax with first-wins ties ([`dual::best_dual_point`] order).
        let mut winner = 0usize;
        let mut d_best = d_prev;
        if d_inner > d_best {
            winner = 1;
            d_best = d_inner;
        }
        if d_res > d_best {
            winner = 2;
            d_best = d_res;
        }
        match winner {
            1 => {
                let (theta, theta_inner) = (&mut ws.theta, &ws.theta_inner);
                theta.copy_from_slice(theta_inner);
            }
            2 => {
                let (theta, theta_res) = (&mut ws.theta, &ws.theta_res);
                theta.copy_from_slice(theta_res);
            }
            _ => {}
        }

        // ---- global gap / stop ----
        let p_val = primal_from_residual_blocks(&ws.r, &ws.beta, q, lambda);
        gap = p_val - d_best;
        let support = block_support(&ws.beta, q);
        if gap <= cfg.tol {
            converged = true;
            break;
        }

        // Pricing deliberately uses only the FRESH candidates
        // {Θ_inner^{t-1}, Θ_res^t} — same rationale as the scalar CELER
        // (a stale-but-tight Θ^{t-1} freezes the priorities). The row
        // norms for Θ_res come free from the rescale pass above.
        if d_res > d_inner {
            let (rows, xtr_rows) = (&mut ws.xtheta_rows, &ws.scratch.xtr_rows);
            for (o, &v) in rows.iter_mut().zip(xtr_rows.iter()) {
                *o = v / denom;
            }
        } else {
            let (rows, inner_rows) = (&mut ws.xtheta_rows, &ws.xtheta_inner_rows);
            rows.copy_from_slice(inner_rows);
        }
        // d_j(Θ) through the shared Gap-Safe pricing helper (empty
        // columns get +∞ and are excluded by build_working_set).
        crate::screening::fill_d_scores(&ws.xtheta_rows, &ws.col_norms, &mut ws.d_scores);

        // Stagnation safeguard + working-set policy: identical to the
        // scalar CELER outer loop (solvers/celer.rs).
        let stagnated = t_out >= 2 && gap > 0.9 * prev_gap;
        prev_gap = gap;
        // MT always runs the pruning policy (WsPolicy::default()), so
        // the support is forced in; under stagnation the previous WS is
        // kept too (the monotone-doubling fallback).
        let forced_vec: Vec<usize>;
        let forced: &[usize] = if !stagnated {
            &support
        } else {
            forced_vec = {
                let mut f = prev_ws.clone();
                f.extend(support.iter().copied());
                f.sort_unstable();
                f.dedup();
                f
            };
            &forced_vec
        };
        let mut pt = policy.next_size(t_out, prev_ws_size, support.len(), p);
        if stagnated {
            pt = pt.max((2 * prev_ws_size).min(p));
        }
        let pt = pt.max(forced.len());
        let ws_idx = build_working_set(&mut ws.d_scores, forced, pt);

        // ---- inner solve on a zero-copy view of X_{W_t} ----
        let eps_t = 0.3 * gap;
        ws.beta_ws.clear();
        {
            let beta = &ws.beta;
            ws.beta_ws.reserve(ws_idx.len() * q);
            for &j in &ws_idx {
                ws.beta_ws.extend_from_slice(&beta[j * q..(j + 1) * q]);
            }
        }
        let inner_cfg = cfg.engine_cfg(eps_t);
        let inner_epochs = {
            let view = DesignView::new(x, &ws_idx, &ws.norms_sq);
            let outcome = solve_blocks(
                &view,
                &y_lanes,
                q,
                lambda,
                Init::Warm(&ws.beta_ws),
                None,
                &inner_cfg,
                &mut inner_ws,
                &mut BlockCdStrategy,
            );
            all_faults.extend_from_slice(outcome.status.faults());
            outcome.epochs
        };
        total_inner_epochs += inner_epochs;

        // ---- lift the subproblem solution back ----
        ws.beta.fill(0.0);
        for (i, &j) in ws_idx.iter().enumerate() {
            ws.beta[j * q..(j + 1) * q].copy_from_slice(&inner_ws.beta[i * q..(i + 1) * q]);
        }
        ws.r.copy_from_slice(&inner_ws.r);
        // Θ_inner: subproblem-feasible; rescale by max(1, max_j ‖x_jᵀΘ‖₂)
        // for full-design feasibility (Θ is unit-scale). The fused sweep
        // doubles as next iteration's pricing rows.
        let s = xt_rows_max(
            x,
            &inner_ws.dual.theta,
            n,
            q,
            &ws.lanes,
            &mut ws.scratch.xtr_acc,
            &mut ws.xtheta_inner_rows,
        )
        .max(1.0);
        let inv_s = 1.0 / s;
        ws.theta_inner.clear();
        ws.theta_inner.extend(inner_ws.dual.theta.iter().map(|&v| v * inv_s));
        for v in ws.xtheta_inner_rows.iter_mut() {
            *v *= inv_s;
        }

        prev_ws_size = ws_idx.len();
        prev_ws = ws_idx;
    }

    ws.put_inner(inner_ws);
    ws.y_lanes = y_lanes;
    let b = TaskMatrix { p, q, data: ws.beta.clone() };
    let mut r = Vec::new();
    lanes_to_rowmajor(&ws.r, n, q, &mut r);
    let mut theta = Vec::new();
    lanes_to_rowmajor(&ws.theta, n, q, &mut theta);
    let status =
        crate::util::error::SolveOutcome::from_run(converged, gap, total_inner_epochs, all_faults);
    MtResult { b, r, theta, gap, epochs: total_inner_epochs, converged, status }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::util::rng::Rng;

    fn random_mt(seed: u64, n: usize, p: usize, q: usize) -> (DesignMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0; n * p];
        for v in data.iter_mut() {
            *v = rng.normal();
        }
        for j in 0..p {
            let nrm: f64 =
                data[j * n..(j + 1) * n].iter().map(|v| v * v).sum::<f64>().sqrt();
            for v in data[j * n..(j + 1) * n].iter_mut() {
                *v /= nrm;
            }
        }
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        (DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data)), y)
    }

    #[test]
    fn lambda_max_zeroes_b() {
        let (x, y) = random_mt(1, 12, 8, 3);
        let lmax = mt_lambda_max(&x, &y, 3);
        let out = mt_bcd_solve(&x, &y, 3, lmax * 1.001, None, &MtConfig::default());
        assert_eq!(out.b.support().len(), 0);
        let out2 = mt_bcd_solve(&x, &y, 3, lmax * 0.9, None, &MtConfig::default());
        assert!(!out2.b.support().is_empty());
    }

    #[test]
    fn q1_reduces_to_lasso() {
        let (x, y) = random_mt(2, 16, 12, 1);
        let lambda = mt_lambda_max(&x, &y, 1) / 4.0;
        let mt =
            mt_bcd_solve(&x, &y, 1, lambda, None, &MtConfig { tol: 1e-10, ..Default::default() });
        let st = crate::solvers::cd::cd_solve(
            &x,
            &y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-10, ..Default::default() },
        );
        for j in 0..12 {
            assert!(
                (mt.b.row(j)[0] - st.beta[j]).abs() < 1e-7,
                "j={j}: {} vs {}",
                mt.b.row(j)[0],
                st.beta[j]
            );
        }
    }

    #[test]
    fn gap_certificate_valid() {
        let (x, y) = random_mt(3, 14, 20, 4);
        let lambda = mt_lambda_max(&x, &y, 4) / 5.0;
        let out =
            mt_bcd_solve(&x, &y, 4, lambda, None, &MtConfig { tol: 1e-8, ..Default::default() });
        assert!(out.converged, "gap {}", out.gap);
        // dual feasibility: max_j ‖x_jᵀΘ‖₂ ≤ 1
        let mut norms = vec![0.0; 20];
        mt_xt_row_norms(&x, &out.theta, 4, &mut norms);
        assert!(norms.iter().all(|&v| v <= 1.0 + 1e-10));
        // recomputed gap matches (row-major recompute reorders the
        // Frobenius sums, so equality holds to summation roundoff)
        let g = mt_primal(&out.r, &out.b, lambda) - mt_dual(&y, &out.theta, lambda);
        assert!((g - out.gap).abs() < 1e-9);
        assert!(g >= -1e-9);
    }

    #[test]
    fn celer_mt_matches_bcd() {
        let (x, y) = random_mt(4, 20, 60, 3);
        let lambda = mt_lambda_max(&x, &y, 3) / 8.0;
        let a = mt_celer_solve(&x, &y, 3, lambda, &MtConfig { tol: 1e-9, ..Default::default() });
        let b =
            mt_bcd_solve(&x, &y, 3, lambda, None, &MtConfig { tol: 1e-10, ..Default::default() });
        assert!(a.converged, "celer-mt gap {}", a.gap);
        let pa = mt_primal(&a.r, &a.b, lambda);
        let pb = mt_primal(&b.r, &b.b, lambda);
        assert!(pa - pb < 1e-7, "{pa} vs {pb}");
    }

    #[test]
    fn extrapolation_helps_or_ties_mt() {
        let (x, y) = random_mt(5, 24, 80, 2);
        let lambda = mt_lambda_max(&x, &y, 2) / 10.0;
        let with =
            mt_bcd_solve(&x, &y, 2, lambda, None, &MtConfig { tol: 1e-9, ..Default::default() });
        let without = mt_bcd_solve(
            &x,
            &y,
            2,
            lambda,
            None,
            &MtConfig { tol: 1e-9, extrapolate: false, ..Default::default() },
        );
        assert!(with.converged && without.converged);
        assert!(with.epochs <= without.epochs);
    }

    #[test]
    fn row_sparsity_structure() {
        // solutions are row-sparse: a row is entirely zero or entirely active
        let (x, y) = random_mt(6, 18, 40, 3);
        let lambda = mt_lambda_max(&x, &y, 3) / 3.0;
        let out =
            mt_bcd_solve(&x, &y, 3, lambda, None, &MtConfig { tol: 1e-10, ..Default::default() });
        for j in 0..40 {
            let row = out.b.row(j);
            let nz = row.iter().filter(|&&v| v != 0.0).count();
            assert!(nz == 0 || nz == 3, "row {j} partially zero: {row:?}");
        }
    }

    #[test]
    fn workspace_variant_matches_one_shot() {
        let (x, y) = random_mt(7, 18, 50, 3);
        let lambda = mt_lambda_max(&x, &y, 3) / 6.0;
        let cfg = MtConfig { tol: 1e-9, ..Default::default() };
        let one_shot = mt_celer_solve(&x, &y, 3, lambda, &cfg);
        let mut ws = BlockWorkspace::new();
        // dirty the workspace with a different λ (and width) first
        let y1: Vec<f64> = y.iter().step_by(3).copied().collect();
        let _ = mt_celer_solve_ws(&x, &y1, 1, lambda * 2.0, None, &cfg, &mut ws);
        let reused = mt_celer_solve_ws(&x, &y, 3, lambda, None, &cfg, &mut ws);
        assert_eq!(one_shot.b.data, reused.b.data);
        assert_eq!(one_shot.gap.to_bits(), reused.gap.to_bits());
        assert_eq!(one_shot.epochs, reused.epochs);
    }

    #[test]
    fn warm_start_from_solution_converges_immediately() {
        let (x, y) = random_mt(8, 16, 30, 2);
        let lambda = mt_lambda_max(&x, &y, 2) / 5.0;
        let cfg = MtConfig { tol: 1e-9, ..Default::default() };
        let first = mt_celer_solve(&x, &y, 2, lambda, &cfg);
        assert!(first.converged);
        let mut ws = BlockWorkspace::new();
        let warm = mt_celer_solve_ws(&x, &y, 2, lambda, Some(&first.b.data), &cfg, &mut ws);
        assert!(warm.converged);
        // Warm-started from the solution the outer loop either certifies
        // immediately (0 inner epochs) or needs at most a token polish —
        // never more work than the cold solve.
        assert!(
            warm.epochs <= first.epochs,
            "warm {} vs cold {}",
            warm.epochs,
            first.epochs
        );
        let (pw, pc) = (mt_primal(&warm.r, &warm.b, lambda), mt_primal(&first.r, &first.b, lambda));
        assert!((pw - pc).abs() <= 2.0 * cfg.tol, "{pw} vs {pc}");
    }
}
