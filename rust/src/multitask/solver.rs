//! Block-coordinate-descent Multi-Task Lasso solver with dual
//! extrapolation and a CELER-style working-set outer loop (paper §7).

use crate::data::design::{DesignMatrix, DesignOps};
use crate::extrapolation::ResidualBuffer;
use crate::multitask::{block_soft_threshold, TaskMatrix};
use crate::util::select::k_smallest_indices;

/// ½‖Y‖_F² as a flat row-major n×q buffer helper.
fn frob_sq(a: &[f64]) -> f64 {
    a.iter().map(|v| v * v).sum()
}

/// Primal objective `P(B) = ½‖R‖_F² + λ‖B‖_{2,1}` from the residual.
pub fn mt_primal(r: &[f64], b: &TaskMatrix, lambda: f64) -> f64 {
    0.5 * frob_sq(r) + lambda * b.l21_norm()
}

/// Dual objective `D(Θ) = ½‖Y‖_F² − (λ²/2)‖Θ − Y/λ‖_F²`.
pub fn mt_dual(y: &[f64], theta: &[f64], lambda: f64) -> f64 {
    let mut dist = 0.0;
    for i in 0..y.len() {
        let d = theta[i] - y[i] / lambda;
        dist += d * d;
    }
    0.5 * frob_sq(y) - 0.5 * lambda * lambda * dist
}

/// `‖x_jᵀΘ‖₂` per feature; Θ is row-major n×q.
fn xt_theta_row_norms<D: DesignOpsMt>(x: &D, theta: &[f64], q: usize, out: &mut [f64]) {
    let p = x.p();
    debug_assert_eq!(out.len(), p);
    // per-column: x_jᵀΘ (q-vector) then its norm — q strided dots per
    // column, so the work hint is q × the design's per-column cost.
    crate::util::par::par_fill_cost(out, x.col_cost_hint().saturating_mul(q.max(1)), |j| {
        let mut acc = 0.0;
        for t in 0..q {
            let v = x.col_dot_strided(j, theta, q, t);
            acc += v * v;
        }
        acc.sqrt()
    });
}

/// Extension trait: strided column ops for row-major matrix right-hand
/// sides (the Multi-Task residual is n×q).
pub trait DesignOpsMt: DesignOps {
    /// `Σ_i x[i,j] · m[i*q + t]`.
    fn col_dot_strided(&self, j: usize, m: &[f64], q: usize, t: usize) -> f64;
    /// `m[i*q + t] += alpha · x[i,j]` for all i.
    fn col_axpy_strided(&self, j: usize, alpha: f64, m: &mut [f64], q: usize, t: usize);
}

impl DesignOpsMt for crate::data::dense::DenseMatrix {
    fn col_dot_strided(&self, j: usize, m: &[f64], q: usize, t: usize) -> f64 {
        let col = self.col(j);
        let mut acc = 0.0;
        for (i, &v) in col.iter().enumerate() {
            acc += v * m[i * q + t];
        }
        acc
    }

    fn col_axpy_strided(&self, j: usize, alpha: f64, m: &mut [f64], q: usize, t: usize) {
        let col = self.col(j);
        for (i, &v) in col.iter().enumerate() {
            m[i * q + t] += alpha * v;
        }
    }
}

impl DesignOpsMt for crate::data::csc::CscMatrix {
    fn col_dot_strided(&self, j: usize, m: &[f64], q: usize, t: usize) -> f64 {
        let (idx, val) = self.col(j);
        let mut acc = 0.0;
        for k in 0..idx.len() {
            acc += val[k] * m[idx[k] as usize * q + t];
        }
        acc
    }

    fn col_axpy_strided(&self, j: usize, alpha: f64, m: &mut [f64], q: usize, t: usize) {
        let (idx, val) = self.col(j);
        for k in 0..idx.len() {
            m[idx[k] as usize * q + t] += alpha * val[k];
        }
    }
}

impl DesignOpsMt for DesignMatrix {
    fn col_dot_strided(&self, j: usize, m: &[f64], q: usize, t: usize) -> f64 {
        match self {
            DesignMatrix::Dense(d) => d.col_dot_strided(j, m, q, t),
            DesignMatrix::Sparse(s) => s.col_dot_strided(j, m, q, t),
        }
    }

    fn col_axpy_strided(&self, j: usize, alpha: f64, m: &mut [f64], q: usize, t: usize) {
        match self {
            DesignMatrix::Dense(d) => d.col_axpy_strided(j, alpha, m, q, t),
            DesignMatrix::Sparse(s) => s.col_axpy_strided(j, alpha, m, q, t),
        }
    }
}

/// `λ_max = max_j ‖x_jᵀY‖₂` — smallest λ with B̂ = 0.
pub fn mt_lambda_max<D: DesignOpsMt>(x: &D, y: &[f64], q: usize) -> f64 {
    let mut norms = vec![0.0; x.p()];
    xt_theta_row_norms(x, y, q, &mut norms);
    norms.into_iter().fold(0.0, f64::max)
}

/// Configuration for the Multi-Task solvers.
#[derive(Debug, Clone)]
pub struct MtConfig {
    pub tol: f64,
    pub max_epochs: usize,
    pub gap_freq: usize,
    pub k: usize,
    pub extrapolate: bool,
}

impl Default for MtConfig {
    fn default() -> Self {
        MtConfig {
            tol: 1e-6,
            max_epochs: 20_000,
            gap_freq: 10,
            k: crate::extrapolation::DEFAULT_K,
            extrapolate: true,
        }
    }
}

/// Multi-Task solve result.
#[derive(Debug, Clone)]
pub struct MtResult {
    pub b: TaskMatrix,
    /// Residual Y − XB, row-major n×q.
    pub r: Vec<f64>,
    /// Best feasible dual point, row-major n×q.
    pub theta: Vec<f64>,
    pub gap: f64,
    pub epochs: usize,
    pub converged: bool,
}

/// Cyclic block-CD for the Multi-Task Lasso with dual extrapolation
/// (Algorithm 1 lifted to matrix residuals).
pub fn mt_bcd_solve(
    x: &DesignMatrix,
    y: &[f64],
    q: usize,
    lambda: f64,
    b0: Option<&TaskMatrix>,
    cfg: &MtConfig,
) -> MtResult {
    let (n, p) = (x.n(), x.p());
    assert_eq!(y.len(), n * q, "Y must be row-major n×q");
    let mut b = b0.cloned().unwrap_or_else(|| TaskMatrix::zeros(p, q));
    assert_eq!((b.p, b.q), (p, q));

    // R = Y − XB
    let mut r = y.to_vec();
    for j in 0..p {
        for t in 0..q {
            let v = b.row(j)[t];
            if v != 0.0 {
                x.col_axpy_strided(j, -v, &mut r, q, t);
            }
        }
    }
    let norms_sq = x.col_norms_sq();

    let mut buffer = ResidualBuffer::new(cfg.k);
    let mut best_theta = vec![0.0; n * q];
    let mut best_dual = f64::NEG_INFINITY;
    let mut gap = f64::INFINITY;
    let mut epochs = 0;
    let mut converged = false;
    let mut row_norms = vec![0.0; p];
    let mut u = vec![0.0; q];

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        for j in 0..p {
            let nrm = norms_sq[j];
            if nrm == 0.0 {
                continue;
            }
            // u = B_j + x_jᵀR / ‖x_j‖²
            for t in 0..q {
                u[t] = b.row(j)[t] + x.col_dot_strided(j, &r, q, t) / nrm;
            }
            block_soft_threshold(&mut u, lambda / nrm);
            for t in 0..q {
                let old = b.row(j)[t];
                let delta = u[t] - old;
                if delta != 0.0 {
                    x.col_axpy_strided(j, -delta, &mut r, q, t);
                    b.row_mut(j)[t] = u[t];
                }
            }
        }

        if epoch % cfg.gap_freq == 0 || epoch == cfg.max_epochs {
            buffer.push(&r);
            // candidate residual-like matrices: R and its extrapolation
            let mut cands: Vec<Vec<f64>> = vec![r.clone()];
            if cfg.extrapolate {
                if let Some(acc) = buffer.extrapolate() {
                    cands.push(acc);
                }
            }
            for cand in cands {
                // Θ = C / max(λ, max_j ‖x_jᵀC‖₂)
                xt_theta_row_norms(x, &cand, q, &mut row_norms);
                let denom = row_norms.iter().fold(lambda, |m, &v| m.max(v));
                let theta: Vec<f64> = cand.iter().map(|&v| v / denom).collect();
                let d = mt_dual(y, &theta, lambda);
                if d > best_dual {
                    best_dual = d;
                    best_theta = theta;
                }
            }
            gap = mt_primal(&r, &b, lambda) - best_dual;
            if gap <= cfg.tol {
                converged = true;
                break;
            }
        }
    }
    MtResult { b, r, theta: best_theta, gap, epochs, converged }
}

/// CELER-style working-set Multi-Task solver: rank rows by
/// `d_j(Θ) = (1 − ‖x_jᵀΘ‖₂)/‖x_j‖` and solve subproblems with
/// [`mt_bcd_solve`], warm-started, pruning WS size to `2·|row support|`.
pub fn mt_celer_solve(
    x: &DesignMatrix,
    y: &[f64],
    q: usize,
    lambda: f64,
    cfg: &MtConfig,
) -> MtResult {
    let (n, p) = (x.n(), x.p());
    let mut b = TaskMatrix::zeros(p, q);
    let mut r = y.to_vec();
    let col_norms: Vec<f64> = x.col_norms_sq().iter().map(|v| v.sqrt()).collect();
    let mut theta = {
        let lmax = mt_lambda_max(x, y, q).max(f64::MIN_POSITIVE);
        y.iter().map(|&v| v / lmax).collect::<Vec<f64>>()
    };
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut epochs = 0;
    let mut row_norms = vec![0.0; p];
    let mut prev_ws_len = 0usize;

    for t_out in 1..=50 {
        // Θ candidates: previous Θ and rescaled residual; keep the better.
        xt_theta_row_norms(x, &r, q, &mut row_norms);
        let denom = row_norms.iter().fold(lambda, |m, &v| m.max(v));
        let theta_res: Vec<f64> = r.iter().map(|&v| v / denom).collect();
        if mt_dual(y, &theta_res, lambda) > mt_dual(y, &theta, lambda) {
            theta.copy_from_slice(&theta_res);
        }
        gap = mt_primal(&r, &b, lambda) - mt_dual(y, &theta, lambda);
        if gap <= cfg.tol {
            converged = true;
            break;
        }

        // d_j scores on the FRESH residual point: a stale-but-tight Θ
        // freezes the priorities and stalls the WS (same pricing rule as
        // the single-task CELER, see solvers/celer.rs).
        xt_theta_row_norms(x, &theta_res, q, &mut row_norms);
        let mut scores: Vec<f64> = (0..p)
            .map(|j| {
                if col_norms[j] == 0.0 {
                    f64::MAX
                } else {
                    (1.0 - row_norms[j]) / col_norms[j]
                }
            })
            .collect();
        let support = b.support();
        for &j in &support {
            scores[j] = -1.0;
        }
        let stagnated = t_out >= 2 && prev_ws_len > 0;
        let pt = if t_out == 1 {
            100.min(p)
        } else {
            (2 * support.len().max(1)).max(if stagnated { prev_ws_len } else { 0 }).min(p)
        }
        .max(support.len());
        let mut ws = k_smallest_indices(&scores, pt);
        ws.sort_unstable();
        prev_ws_len = ws.len();

        // subproblem
        let x_ws = x.select_columns(&ws);
        let mut b_ws = TaskMatrix::zeros(ws.len(), q);
        for (i, &j) in ws.iter().enumerate() {
            b_ws.row_mut(i).copy_from_slice(b.row(j));
        }
        let inner_cfg = MtConfig { tol: 0.3 * gap, ..cfg.clone() };
        let inner = mt_bcd_solve(&x_ws, y, q, lambda, Some(&b_ws), &inner_cfg);
        epochs += inner.epochs;
        b = TaskMatrix::zeros(p, q);
        for (i, &j) in ws.iter().enumerate() {
            b.row_mut(j).copy_from_slice(inner.b.row(i));
        }
        r.copy_from_slice(&inner.r);
        // lift the inner dual point: rescale to full feasibility
        xt_theta_row_norms(x, &inner.theta, q, &mut row_norms);
        let s = row_norms.iter().fold(1.0f64, |m, &v| m.max(v));
        let lifted: Vec<f64> = inner.theta.iter().map(|&v| v / s).collect();
        if mt_dual(y, &lifted, lambda) > mt_dual(y, &theta, lambda) {
            theta = lifted;
        }
    }
    let _ = n;
    MtResult { b, r, theta, gap, epochs, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::util::rng::Rng;

    fn random_mt(seed: u64, n: usize, p: usize, q: usize) -> (DesignMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0; n * p];
        for v in data.iter_mut() {
            *v = rng.normal();
        }
        for j in 0..p {
            let nrm: f64 =
                data[j * n..(j + 1) * n].iter().map(|v| v * v).sum::<f64>().sqrt();
            for v in data[j * n..(j + 1) * n].iter_mut() {
                *v /= nrm;
            }
        }
        let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
        (DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data)), y)
    }

    #[test]
    fn lambda_max_zeroes_b() {
        let (x, y) = random_mt(1, 12, 8, 3);
        let lmax = mt_lambda_max(&x, &y, 3);
        let out = mt_bcd_solve(&x, &y, 3, lmax * 1.001, None, &MtConfig::default());
        assert_eq!(out.b.support().len(), 0);
        let out2 = mt_bcd_solve(&x, &y, 3, lmax * 0.9, None, &MtConfig::default());
        assert!(!out2.b.support().is_empty());
    }

    #[test]
    fn q1_reduces_to_lasso() {
        let (x, y) = random_mt(2, 16, 12, 1);
        let lambda = mt_lambda_max(&x, &y, 1) / 4.0;
        let mt = mt_bcd_solve(&x, &y, 1, lambda, None, &MtConfig { tol: 1e-10, ..Default::default() });
        let st = crate::solvers::cd::cd_solve(
            &x,
            &y,
            lambda,
            None,
            &crate::solvers::cd::CdConfig { tol: 1e-10, ..Default::default() },
        );
        for j in 0..12 {
            assert!(
                (mt.b.row(j)[0] - st.beta[j]).abs() < 1e-7,
                "j={j}: {} vs {}",
                mt.b.row(j)[0],
                st.beta[j]
            );
        }
    }

    #[test]
    fn gap_certificate_valid() {
        let (x, y) = random_mt(3, 14, 20, 4);
        let lambda = mt_lambda_max(&x, &y, 4) / 5.0;
        let out = mt_bcd_solve(&x, &y, 4, lambda, None, &MtConfig { tol: 1e-8, ..Default::default() });
        assert!(out.converged, "gap {}", out.gap);
        // dual feasibility: max_j ||x_j^T Θ||₂ ≤ 1
        let mut norms = vec![0.0; 20];
        xt_theta_row_norms(&x, &out.theta, 4, &mut norms);
        assert!(norms.iter().all(|&v| v <= 1.0 + 1e-10));
        // recomputed gap matches
        let g = mt_primal(&out.r, &out.b, lambda) - mt_dual(&y, &out.theta, lambda);
        assert!((g - out.gap).abs() < 1e-12);
        assert!(g >= -1e-12);
    }

    #[test]
    fn celer_mt_matches_bcd() {
        let (x, y) = random_mt(4, 20, 60, 3);
        let lambda = mt_lambda_max(&x, &y, 3) / 8.0;
        let a = mt_celer_solve(&x, &y, 3, lambda, &MtConfig { tol: 1e-9, ..Default::default() });
        let b = mt_bcd_solve(&x, &y, 3, lambda, None, &MtConfig { tol: 1e-10, ..Default::default() });
        assert!(a.converged, "celer-mt gap {}", a.gap);
        let pa = mt_primal(&a.r, &a.b, lambda);
        let pb = mt_primal(&b.r, &b.b, lambda);
        assert!(pa - pb < 1e-7, "{pa} vs {pb}");
    }

    #[test]
    fn extrapolation_helps_or_ties_mt() {
        let (x, y) = random_mt(5, 24, 80, 2);
        let lambda = mt_lambda_max(&x, &y, 2) / 10.0;
        let with = mt_bcd_solve(&x, &y, 2, lambda, None, &MtConfig { tol: 1e-9, ..Default::default() });
        let without = mt_bcd_solve(
            &x,
            &y,
            2,
            lambda,
            None,
            &MtConfig { tol: 1e-9, extrapolate: false, ..Default::default() },
        );
        assert!(with.converged && without.converged);
        assert!(with.epochs <= without.epochs);
    }

    #[test]
    fn row_sparsity_structure() {
        // solutions are row-sparse: a row is entirely zero or entirely active
        let (x, y) = random_mt(6, 18, 40, 3);
        let lambda = mt_lambda_max(&x, &y, 3) / 3.0;
        let out = mt_bcd_solve(&x, &y, 3, lambda, None, &MtConfig { tol: 1e-10, ..Default::default() });
        for j in 0..40 {
            let row = out.b.row(j);
            let nz = row.iter().filter(|&&v| v != 0.0).count();
            assert!(nz == 0 || nz == 3, "row {j} partially zero: {row:?}");
        }
    }
}
