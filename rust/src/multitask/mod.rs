//! Multi-Task Lasso with dual extrapolation (paper §7, Discussion).
//!
//! The paper notes that the whole methodology — dual extrapolation, Gap
//! Safe screening, working sets — applies verbatim to any
//! `min_B F(B) + λΩ(B)` with Ω row-separable. This module instantiates it
//! for the Multi-Task Lasso,
//!
//! ```text
//! min_{B ∈ R^{p×q}} ½‖Y − XB‖_F² + λ Σ_j ‖B_{j·}‖₂ ,
//! ```
//!
//! whose dual feasible set is `{Θ : ‖x_jᵀΘ‖₂ ≤ 1 ∀j}` and whose block-CD
//! update is the group soft-threshold
//! `B_{j·} ← BST(B_{j·} + x_jᵀR/‖x_j‖², λ/‖x_j‖²)`.
//!
//! Residuals are n×q matrices; dual extrapolation runs on their
//! vectorization, exactly as Definition 1 (the VAR argument carries over
//! row-wise).

pub mod solver;

/// Group (row) soft-threshold: `BST(u, t) = u · max(0, 1 − t/‖u‖)`.
#[inline]
pub fn block_soft_threshold(u: &mut [f64], t: f64) {
    let norm = crate::util::linalg::norm(u);
    if norm <= t {
        u.fill(0.0);
    } else {
        let scale = 1.0 - t / norm;
        for v in u.iter_mut() {
            *v *= scale;
        }
    }
}

/// Row-major p×q coefficient matrix for the Multi-Task Lasso.
#[derive(Debug, Clone)]
pub struct TaskMatrix {
    pub p: usize,
    pub q: usize,
    /// Row-major: `data[j*q + t]` = coefficient of feature j for task t.
    pub data: Vec<f64>,
}

impl TaskMatrix {
    pub fn zeros(p: usize, q: usize) -> Self {
        TaskMatrix { p, q, data: vec![0.0; p * q] }
    }

    #[inline]
    pub fn row(&self, j: usize) -> &[f64] {
        &self.data[j * self.q..(j + 1) * self.q]
    }

    #[inline]
    pub fn row_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.q..(j + 1) * self.q]
    }

    /// Rows with non-zero ℓ2 norm (the row support).
    pub fn support(&self) -> Vec<usize> {
        (0..self.p).filter(|&j| self.row(j).iter().any(|&v| v != 0.0)).collect()
    }

    /// Σ_j ‖B_{j·}‖₂ (the ℓ2,1 norm).
    pub fn l21_norm(&self) -> f64 {
        (0..self.p).map(|j| crate::util::linalg::norm(self.row(j))).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bst_shrinks_or_zeroes() {
        let mut u = vec![3.0, 4.0]; // norm 5
        block_soft_threshold(&mut u, 1.0);
        // scale (1 - 1/5) = 0.8
        assert!((u[0] - 2.4).abs() < 1e-12);
        assert!((u[1] - 3.2).abs() < 1e-12);
        let mut v = vec![0.3, 0.4]; // norm 0.5 <= 1
        block_soft_threshold(&mut v, 1.0);
        assert_eq!(v, vec![0.0, 0.0]);
    }

    #[test]
    fn bst_reduces_to_scalar_st_for_q1() {
        for &x in &[-3.0, -0.5, 0.0, 0.5, 3.0] {
            let mut u = vec![x];
            block_soft_threshold(&mut u, 1.0);
            assert!((u[0] - crate::util::soft_threshold(x, 1.0)).abs() < 1e-15);
        }
    }

    #[test]
    fn task_matrix_rows_and_norms() {
        let mut b = TaskMatrix::zeros(3, 2);
        b.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        assert_eq!(b.support(), vec![1]);
        assert!((b.l21_norm() - 5.0).abs() < 1e-12);
        assert_eq!(b.row(0), &[0.0, 0.0]);
    }
}
