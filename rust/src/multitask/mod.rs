//! Multi-Task Lasso with dual extrapolation (paper §7, Discussion).
//!
//! The paper notes that the whole methodology — dual extrapolation, Gap
//! Safe screening, working sets — applies verbatim to any
//! `min_B F(B) + λΩ(B)` with Ω row-separable. This module instantiates it
//! for the Multi-Task Lasso,
//!
//! ```text
//! min_{B ∈ R^{p×q}} ½‖Y − XB‖_F² + λ Σ_j ‖B_{j·}‖₂ ,
//! ```
//!
//! whose dual feasible set is `{Θ : ‖x_jᵀΘ‖₂ ≤ 1 ∀j}` and whose block-CD
//! update is the group soft-threshold
//! `B_{j·} ← BST(B_{j·} + x_jᵀR/‖x_j‖², λ/‖x_j‖²)`.
//!
//! Residuals are n×q matrices; dual extrapolation runs on their
//! vectorization, exactly as Definition 1 (the VAR argument carries over
//! row-wise).

pub mod solver;

/// Transpose a row-major n×q matrix (`a[i·q + t]`, the public Multi-Task
/// API layout) into the lane-major q×n layout (`out[t·n + i]`) the block
/// engine and the multi-RHS design kernels
/// ([`DesignOps::col_dot_lanes`](crate::data::design::DesignOps::col_dot_lanes))
/// operate on. `q = 1` is a plain copy.
pub fn rowmajor_to_lanes(a: &[f64], n: usize, q: usize, out: &mut Vec<f64>) {
    assert_eq!(a.len(), n * q);
    out.clear();
    out.resize(n * q, 0.0);
    if q == 1 {
        out.copy_from_slice(a);
        return;
    }
    for i in 0..n {
        for t in 0..q {
            out[t * n + i] = a[i * q + t];
        }
    }
}

/// Inverse of [`rowmajor_to_lanes`]: lane-major q×n back to row-major n×q.
pub fn lanes_to_rowmajor(a: &[f64], n: usize, q: usize, out: &mut Vec<f64>) {
    assert_eq!(a.len(), n * q);
    out.clear();
    out.resize(n * q, 0.0);
    if q == 1 {
        out.copy_from_slice(a);
        return;
    }
    for t in 0..q {
        for i in 0..n {
            out[i * q + t] = a[t * n + i];
        }
    }
}

/// Group (row) soft-threshold: `BST(u, t) = u · max(0, 1 − t/‖u‖)`.
#[inline]
pub fn block_soft_threshold(u: &mut [f64], t: f64) {
    let norm = crate::util::linalg::norm(u);
    if norm <= t {
        u.fill(0.0);
    } else {
        let scale = 1.0 - t / norm;
        for v in u.iter_mut() {
            *v *= scale;
        }
    }
}

/// Row-major p×q coefficient matrix for the Multi-Task Lasso.
#[derive(Debug, Clone)]
pub struct TaskMatrix {
    pub p: usize,
    pub q: usize,
    /// Row-major: `data[j*q + t]` = coefficient of feature j for task t.
    pub data: Vec<f64>,
}

impl TaskMatrix {
    pub fn zeros(p: usize, q: usize) -> Self {
        TaskMatrix { p, q, data: vec![0.0; p * q] }
    }

    #[inline]
    pub fn row(&self, j: usize) -> &[f64] {
        &self.data[j * self.q..(j + 1) * self.q]
    }

    #[inline]
    pub fn row_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.q..(j + 1) * self.q]
    }

    /// Rows with non-zero ℓ2 norm (the row support).
    pub fn support(&self) -> Vec<usize> {
        (0..self.p).filter(|&j| self.row(j).iter().any(|&v| v != 0.0)).collect()
    }

    /// Σ_j ‖B_{j·}‖₂ (the ℓ2,1 norm; width-8 accumulator fold over the
    /// row norms — see `util::simd` for the reduction-order contract).
    pub fn l21_norm(&self) -> f64 {
        crate::util::simd::sum_by(self.p, |j| crate::util::linalg::norm(self.row(j)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bst_shrinks_or_zeroes() {
        let mut u = vec![3.0, 4.0]; // norm 5
        block_soft_threshold(&mut u, 1.0);
        // scale (1 - 1/5) = 0.8
        assert!((u[0] - 2.4).abs() < 1e-12);
        assert!((u[1] - 3.2).abs() < 1e-12);
        let mut v = vec![0.3, 0.4]; // norm 0.5 <= 1
        block_soft_threshold(&mut v, 1.0);
        assert_eq!(v, vec![0.0, 0.0]);
    }

    #[test]
    fn bst_reduces_to_scalar_st_for_q1() {
        for &x in &[-3.0, -0.5, 0.0, 0.5, 3.0] {
            let mut u = vec![x];
            block_soft_threshold(&mut u, 1.0);
            assert!((u[0] - crate::util::soft_threshold(x, 1.0)).abs() < 1e-15);
        }
    }

    #[test]
    fn transpose_round_trip() {
        let a: Vec<f64> = (0..12).map(|v| v as f64).collect(); // 4×3 row-major
        let mut lanes = Vec::new();
        rowmajor_to_lanes(&a, 4, 3, &mut lanes);
        assert_eq!(lanes[0], a[0]); // (i=0, t=0)
        assert_eq!(lanes[4], a[3]); // lane 1 starts at row 0, task 1
        let mut back = Vec::new();
        lanes_to_rowmajor(&lanes, 4, 3, &mut back);
        assert_eq!(back, a);
        // q = 1 is the identity
        let mut one = Vec::new();
        rowmajor_to_lanes(&a, 12, 1, &mut one);
        assert_eq!(one, a);
    }

    #[test]
    fn task_matrix_rows_and_norms() {
        let mut b = TaskMatrix::zeros(3, 2);
        b.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        assert_eq!(b.support(), vec![1]);
        assert!((b.l21_norm() - 5.0).abs() < 1e-12);
        assert_eq!(b.row(0), &[0.0, 0.0]);
    }
}
