//! Lightweight metrics for experiment runs: wall-clock timers and
//! monotonic counters, exported as JSON for EXPERIMENTS.md tooling.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// A named set of counters/gauges for one experiment run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    values: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `v` to counter `name`.
    pub fn add(&mut self, name: &str, v: f64) {
        *self.values.entry(name.to_string()).or_insert(0.0) += v;
    }

    /// Set gauge `name` to `v`.
    pub fn set(&mut self, name: &str, v: f64) {
        self.values.insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.get(name).copied()
    }

    /// Export as a JSON object.
    pub fn to_json(&self) -> Json {
        Json::Obj(self.values.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.add("epochs", 10.0);
        m.add("epochs", 5.0);
        m.set("gap", 1e-6);
        assert_eq!(m.get("epochs"), Some(15.0));
        assert_eq!(m.get("gap"), Some(1e-6));
        assert_eq!(m.get("missing"), None);
    }

    #[test]
    fn json_export_round_trips() {
        let mut m = Metrics::new();
        m.set("a", 1.0);
        m.set("b", 2.5);
        let parsed = crate::util::json::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(parsed.get("b").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.seconds();
        let b = sw.seconds();
        assert!(b >= a);
    }
}
