//! Parallel job scheduler for experiment grids.
//!
//! Experiment cells (solver × tolerance × dataset) are independent; the
//! scheduler fans them out over a worker pool with a shared index queue
//! and collects results in input order. λ-path cells are NOT split —
//! warm-start chains couple the grid points, so a "job" is a whole
//! path. Within a job the worker either walks the grid sequentially or
//! feeds it into the batched multi-λ lane engine
//! ([`crate::solvers::batch`]); both reuse the worker's per-thread
//! state from `init()`.
//!
//! **Nested-parallelism policy**: when the grid workers alone saturate
//! the machine (`workers ≥ CELER_NUM_THREADS`), each worker executes
//! inside [`crate::util::par::run_serial`], so the solvers' full-p
//! scans (`xt_vec`, KKT, screening) take the serial path instead of
//! contending for the shared persistent pool (never oversubscription,
//! never nested submission). With fewer workers than threads the
//! machine has idle cores, so workers keep pool access — the pool
//! serializes concurrent submissions, so scans from different cells
//! take turns at full width rather than stacking threads. Results are
//! identical under every policy: reductions use a fixed shard grid
//! (see `util::par`), so the schedule never changes the bits.

use crate::util::error::SolveError;
use crate::util::fault::FaultPlan;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f` over all items on `workers` threads; results keep input order.
pub fn run_parallel<I, O, F>(items: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(&I) -> O + Sync,
{
    run_parallel_with_state(items, workers, || (), |_, item| f(item))
}

/// Like [`run_parallel`], but each worker thread builds one `init()`
/// state up front and threads it through every job it claims. The
/// coordinator uses this to give each worker a reusable solver
/// [`Workspace`](crate::solvers::engine::Workspace): all path jobs a
/// worker executes share one set of solver buffers.
pub fn run_parallel_with_state<I, O, S, F, G>(
    items: Vec<I>,
    workers: usize,
    init: G,
    f: F,
) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(&mut S, &I) -> O + Sync,
    G: Fn() -> S + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        let mut state = init();
        return items.iter().map(|item| f(&mut state, item)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
    // Nested-parallelism policy (see module docs): once the grid
    // workers alone saturate the machine, their inner scans go serial;
    // below saturation they keep (serialized) access to the pool.
    let serial_scans = workers >= crate::util::par::num_threads();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let work = || {
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let out = f(&mut state, &items[i]);
                        *slots[i].lock().unwrap() = Some(out);
                    }
                };
                if serial_scans {
                    crate::util::par::run_serial(work);
                } else {
                    work();
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

/// Fault policy for [`run_parallel_robust`]: per-job soft timeout,
/// bounded retry on panic, and the fault-injection hooks the harness
/// tests use to provoke both.
#[derive(Debug, Clone)]
pub struct RobustPolicy {
    /// Per-attempt wall-clock limit. A job whose attempt runs longer
    /// reports [`SolveError::JobTimeout`] (the attempt is not preempted
    /// — the scheduler is cooperative — but its result is discarded so
    /// a stalled cell cannot masquerade as a certified one).
    pub timeout_seconds: Option<f64>,
    /// Panicking jobs are retried on a rebuilt worker state up to this
    /// many times before being quarantined as
    /// [`SolveError::JobPoisoned`].
    pub max_retries: usize,
    /// Injection hooks polled inside every job attempt (inert by
    /// default and without the `fault-inject` feature).
    pub faults: FaultPlan,
}

impl Default for RobustPolicy {
    fn default() -> Self {
        RobustPolicy { timeout_seconds: None, max_retries: 1, faults: FaultPlan::none() }
    }
}

fn panic_detail(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// [`run_parallel_with_state`] hardened against misbehaving jobs: every
/// attempt runs under `catch_unwind`, a panicking job is retried (with
/// 1 ms · 2^attempt backoff) on a freshly rebuilt worker state — the
/// panicked state is discarded, it may hold torn buffers — and a job
/// that exhausts its retries is quarantined as
/// [`SolveError::JobPoisoned`] without taking the rest of the grid down
/// with it. Slot order is preserved; healthy jobs are unaffected.
pub fn run_parallel_robust<I, O, S, F, G>(
    items: Vec<I>,
    workers: usize,
    policy: &RobustPolicy,
    init: G,
    f: F,
) -> Vec<Result<O, SolveError>>
where
    I: Sync,
    O: Send,
    F: Fn(&mut S, &I) -> O + Sync,
    G: Fn() -> S + Sync,
{
    let policy = policy.clone();
    let init = &init;
    let f = &f;
    let indexed: Vec<(usize, I)> = items.into_iter().enumerate().collect();
    run_parallel_with_state(
        indexed,
        workers,
        || Some(init()),
        move |state: &mut Option<S>, job_item| {
            let (job, item) = (job_item.0, &job_item.1);
            let attempts = policy.max_retries + 1;
            let mut detail = String::new();
            for attempt in 0..attempts {
                if attempt > 0 {
                    // Exponential backoff before a retry: transient
                    // contention (e.g. an allocator hiccup) gets a
                    // moment to clear.
                    let ms = 1u64 << (attempt - 1).min(10) as u32;
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
                let st = state.get_or_insert_with(init);
                let t0 = std::time::Instant::now();
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    policy.faults.maybe_panic_shard();
                    policy.faults.maybe_delay_worker();
                    f(st, item)
                }));
                let seconds = t0.elapsed().as_secs_f64();
                match run {
                    Ok(out) => {
                        if let Some(limit) = policy.timeout_seconds {
                            if seconds > limit {
                                return Err(SolveError::JobTimeout { job, seconds });
                            }
                        }
                        return Ok(out);
                    }
                    Err(payload) => detail = panic_detail(payload.as_ref()),
                }
                // The state a panic unwound through may be torn
                // (half-filled buffers, inconsistent lengths): rebuild
                // from scratch before the retry.
                *state = None;
            }
            Err(SolveError::JobPoisoned { job, attempts, detail })
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = run_parallel(items, 8, |&i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(run_parallel(vec![1, 2, 3], 1, |&i| i + 1), vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(run_parallel(empty, 4, |&i: &i32| i).is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(run_parallel(vec![5], 16, |&i| i), vec![5]);
    }

    #[test]
    fn per_worker_state_is_reused() {
        // each worker counts how many jobs it handled; the counts must
        // sum to the number of items (state persists across jobs).
        let items: Vec<usize> = (0..40).collect();
        let out = run_parallel_with_state(
            items,
            4,
            || 0usize,
            |count, &i| {
                *count += 1;
                (i, *count)
            },
        );
        assert_eq!(out.len(), 40);
        let total: usize = out.iter().filter(|(_, c)| *c == 1).count();
        // at most `workers` jobs can be "first job on a fresh state"
        assert!(total <= 4, "fresh states: {total}");
        for (i, (item, _)) in out.iter().enumerate() {
            assert_eq!(*item, i, "order preserved");
        }
    }

    #[test]
    fn workers_serial_scope_follows_saturation_policy() {
        // Saturating worker counts get serial-scoped inner scans; a
        // sub-saturating count keeps pool access (scope stays off).
        let threads = crate::util::par::num_threads();
        let saturated = run_parallel(vec![(); 2 * threads.max(1)], threads.max(2), |_| {
            crate::util::par::in_serial_scope()
        });
        assert!(saturated.iter().all(|&b| b), "workers ≥ threads ⇒ serial scope");
        if threads > 2 {
            let below = run_parallel(vec![(); 4], 2, |_| crate::util::par::in_serial_scope());
            assert!(below.iter().all(|&b| !b), "workers < threads ⇒ pool access");
        }
        // The single-worker path runs on the caller and keeps whatever
        // scope the caller has (pool access by default).
        let here = crate::util::par::in_serial_scope();
        let single = run_parallel(vec![()], 1, |_| crate::util::par::in_serial_scope());
        assert_eq!(single[0], here);
    }

    #[test]
    fn robust_healthy_jobs_pass_through() {
        let out =
            run_parallel_robust(vec![1, 2, 3], 2, &RobustPolicy::default(), || (), |_, &i| i * 2);
        let vals: Vec<i32> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, vec![2, 4, 6]);
    }

    #[test]
    fn robust_quarantines_always_panicking_job() {
        let policy = RobustPolicy { max_retries: 2, ..Default::default() };
        let out = run_parallel_robust(vec![0usize, 1, 2], 2, &policy, || (), |_, &i| {
            if i == 1 {
                panic!("job 1 always dies");
            }
            i
        });
        assert_eq!(out[0].as_ref().unwrap(), &0);
        assert_eq!(out[2].as_ref().unwrap(), &2);
        match &out[1] {
            Err(SolveError::JobPoisoned { job, attempts, detail }) => {
                assert_eq!((*job, *attempts), (1, 3));
                assert!(detail.contains("always dies"), "{detail}");
            }
            other => panic!("expected JobPoisoned, got {other:?}"),
        }
    }

    #[test]
    fn robust_retries_transient_panic_on_fresh_state() {
        let tries = AtomicUsize::new(0);
        let out = run_parallel_robust(
            vec![7usize],
            1,
            &RobustPolicy::default(),
            || 0usize,
            |state, &i| {
                *state += 1;
                if tries.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("transient");
                }
                (i, *state)
            },
        );
        // retried once, and the retry ran on a rebuilt state (its
        // per-state counter restarted at 1)
        assert_eq!(out[0].as_ref().unwrap(), &(7, 1));
        assert_eq!(tries.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn robust_timeout_flags_slow_job() {
        let policy = RobustPolicy { timeout_seconds: Some(0.01), ..Default::default() };
        let out = run_parallel_robust(vec![0usize, 1], 2, &policy, || (), |_, &i| {
            if i == 0 {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            i
        });
        assert!(matches!(out[0], Err(SolveError::JobTimeout { job: 0, .. })), "{:?}", out[0]);
        assert_eq!(out[1].as_ref().unwrap(), &1);
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn robust_recovers_injected_shard_panic() {
        let faults = crate::util::fault::FaultPlan::armed();
        faults.arm_shard_panic();
        let policy = RobustPolicy { faults, ..Default::default() };
        let out = run_parallel_robust(vec![5usize], 1, &policy, || (), |_, &i| i + 1);
        // the injected panic is one-shot, so the retry runs clean
        assert_eq!(out[0].as_ref().unwrap(), &6);
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn robust_times_out_injected_delay() {
        let faults = crate::util::fault::FaultPlan::armed();
        faults.arm_worker_delay(50);
        let policy =
            RobustPolicy { timeout_seconds: Some(0.01), faults, ..Default::default() };
        let out = run_parallel_robust(vec![0usize], 1, &policy, || (), |_, &i| i);
        assert!(matches!(out[0], Err(SolveError::JobTimeout { .. })), "{:?}", out[0]);
    }

    #[test]
    fn uneven_work_completes() {
        let items: Vec<u64> = (0..32).collect();
        let out = run_parallel(items, 4, |&i| {
            // deliberately uneven busy work
            let mut acc = 0u64;
            for t in 0..(i * 1000) {
                acc = acc.wrapping_add(t);
            }
            (i, acc).0
        });
        assert_eq!(out, (0..32).collect::<Vec<_>>());
    }
}
