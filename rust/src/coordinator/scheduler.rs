//! Parallel job scheduler for experiment grids.
//!
//! Experiment cells (solver × tolerance × dataset) are independent; the
//! scheduler fans them out over a worker pool with a shared index queue
//! and collects results in input order. λ-path cells are NOT split —
//! warm-start chains couple the grid points, so a "job" is a whole
//! path. Within a job the worker either walks the grid sequentially or
//! feeds it into the batched multi-λ lane engine
//! ([`crate::solvers::batch`]); both reuse the worker's per-thread
//! state from `init()`.
//!
//! **Nested-parallelism policy**: when the grid workers alone saturate
//! the machine (`workers ≥ CELER_NUM_THREADS`), each worker executes
//! inside [`crate::util::par::run_serial`], so the solvers' full-p
//! scans (`xt_vec`, KKT, screening) take the serial path instead of
//! contending for the shared persistent pool (never oversubscription,
//! never nested submission). With fewer workers than threads the
//! machine has idle cores, so workers keep pool access — the pool
//! serializes concurrent submissions, so scans from different cells
//! take turns at full width rather than stacking threads. Results are
//! identical under every policy: reductions use a fixed shard grid
//! (see `util::par`), so the schedule never changes the bits.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f` over all items on `workers` threads; results keep input order.
pub fn run_parallel<I, O, F>(items: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(&I) -> O + Sync,
{
    run_parallel_with_state(items, workers, || (), |_, item| f(item))
}

/// Like [`run_parallel`], but each worker thread builds one `init()`
/// state up front and threads it through every job it claims. The
/// coordinator uses this to give each worker a reusable solver
/// [`Workspace`](crate::solvers::engine::Workspace): all path jobs a
/// worker executes share one set of solver buffers.
pub fn run_parallel_with_state<I, O, S, F, G>(
    items: Vec<I>,
    workers: usize,
    init: G,
    f: F,
) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(&mut S, &I) -> O + Sync,
    G: Fn() -> S + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        let mut state = init();
        return items.iter().map(|item| f(&mut state, item)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
    // Nested-parallelism policy (see module docs): once the grid
    // workers alone saturate the machine, their inner scans go serial;
    // below saturation they keep (serialized) access to the pool.
    let serial_scans = workers >= crate::util::par::num_threads();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let work = || {
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let out = f(&mut state, &items[i]);
                        *slots[i].lock().unwrap() = Some(out);
                    }
                };
                if serial_scans {
                    crate::util::par::run_serial(work);
                } else {
                    work();
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = run_parallel(items, 8, |&i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(run_parallel(vec![1, 2, 3], 1, |&i| i + 1), vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(run_parallel(empty, 4, |&i: &i32| i).is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(run_parallel(vec![5], 16, |&i| i), vec![5]);
    }

    #[test]
    fn per_worker_state_is_reused() {
        // each worker counts how many jobs it handled; the counts must
        // sum to the number of items (state persists across jobs).
        let items: Vec<usize> = (0..40).collect();
        let out = run_parallel_with_state(
            items,
            4,
            || 0usize,
            |count, &i| {
                *count += 1;
                (i, *count)
            },
        );
        assert_eq!(out.len(), 40);
        let total: usize = out.iter().filter(|(_, c)| *c == 1).count();
        // at most `workers` jobs can be "first job on a fresh state"
        assert!(total <= 4, "fresh states: {total}");
        for (i, (item, _)) in out.iter().enumerate() {
            assert_eq!(*item, i, "order preserved");
        }
    }

    #[test]
    fn workers_serial_scope_follows_saturation_policy() {
        // Saturating worker counts get serial-scoped inner scans; a
        // sub-saturating count keeps pool access (scope stays off).
        let threads = crate::util::par::num_threads();
        let saturated = run_parallel(vec![(); 2 * threads.max(1)], threads.max(2), |_| {
            crate::util::par::in_serial_scope()
        });
        assert!(saturated.iter().all(|&b| b), "workers ≥ threads ⇒ serial scope");
        if threads > 2 {
            let below = run_parallel(vec![(); 4], 2, |_| crate::util::par::in_serial_scope());
            assert!(below.iter().all(|&b| !b), "workers < threads ⇒ pool access");
        }
        // The single-worker path runs on the caller and keeps whatever
        // scope the caller has (pool access by default).
        let here = crate::util::par::in_serial_scope();
        let single = run_parallel(vec![()], 1, |_| crate::util::par::in_serial_scope());
        assert_eq!(single[0], here);
    }

    #[test]
    fn uneven_work_completes() {
        let items: Vec<u64> = (0..32).collect();
        let out = run_parallel(items, 4, |&i| {
            // deliberately uneven busy work
            let mut acc = 0u64;
            for t in 0..(i * 1000) {
                acc = acc.wrapping_add(t);
            }
            (i, acc).0
        });
        assert_eq!(out, (0..32).collect::<Vec<_>>());
    }
}
