//! Parallel job scheduler for experiment grids.
//!
//! Experiment cells (solver × tolerance × dataset) are independent; the
//! scheduler fans them out over a worker pool with a shared index queue
//! and collects results in input order. λ-path cells are NOT split —
//! warm-start chains are sequential by construction, so a "job" is a
//! whole path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f` over all items on `workers` threads; results keep input order.
pub fn run_parallel<I, O, F>(items: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(&I) -> O + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(&items[i]);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = run_parallel(items, 8, |&i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(run_parallel(vec![1, 2, 3], 1, |&i| i + 1), vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(run_parallel(empty, 4, |&i: &i32| i).is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(run_parallel(vec![5], 16, |&i| i), vec![5]);
    }

    #[test]
    fn uneven_work_completes() {
        let items: Vec<u64> = (0..32).collect();
        let out = run_parallel(items, 4, |&i| {
            // deliberately uneven busy work
            let mut acc = 0u64;
            for t in 0..(i * 1000) {
                acc = acc.wrapping_add(t);
            }
            (i, acc).0
        });
        assert_eq!(out, (0..32).collect::<Vec<_>>());
    }
}
