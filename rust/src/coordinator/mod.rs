//! Experiment coordinator: named datasets, experiment-grid jobs and the
//! parallel scheduler that drives the paper's tables and figures.
//!
//! The coordinator is the piece a downstream user scripts against:
//! `ExperimentGrid` enumerates (dataset × solver × ε) cells, the
//! scheduler fans independent cells out over threads (warm-start chains
//! within a λ-path stay sequential), and every cell reports wall-clock +
//! convergence metadata for the report writers.
//!
//! Each worker thread owns one solver
//! [`Workspace`](crate::solvers::engine::Workspace) reused across every
//! path job it claims, so the solver buffers (β, r, dual state,
//! extrapolation ring, nested working-set workspace) are allocated once
//! per worker, not once per λ or per job.
//!
//! Grid cells can run in two schedules: the sequential per-λ chain, or
//! the batched multi-λ engine (`solver_name: "cd-batched"`), where the
//! worker feeds its job's λ grid into B concurrent lanes of
//! [`crate::solvers::batch`] instead of looping over the grid — the
//! lane workspace also lives in (and is reused from) the worker's
//! `Workspace`. Multi-task grid jobs route the same way
//! (`solver_name: "celer-mt"`): the block-coefficient workspace lives in
//! the worker's `Workspace` (`ws.mt`), so MT cells share the per-thread
//! buffer-reuse story with every other solver. Sparse-GLM grid jobs
//! (`solver_name: "celer-logreg"`) run CELER on the logistic datafit
//! with the dataset's targets binarized by sign — the same engine
//! workspace serves them too.

pub mod metrics;
pub mod scheduler;

use crate::data::synth::{self, SynthDataset};
use crate::solvers::engine::Workspace;
use crate::solvers::path::{
    lambda_grid, run_path_budgeted, run_path_with_workspace, PathResult, PathSolver,
};
use crate::util::error::SolveError;

/// Named dataset loader (synthetic stand-ins for the paper's datasets —
/// see DESIGN.md §4; real svmlight files can be loaded via `data::svmlight`).
pub fn load_dataset(name: &str, seed: u64) -> anyhow::Result<SynthDataset> {
    Ok(match name {
        "leukemia-sim" => synth::leukemia_sim(seed),
        "leukemia-mini" => synth::leukemia_mini(seed),
        "finance-sim" => synth::finance_sim(seed),
        "finance-mini" => synth::finance_mini(seed),
        "bctcga-sim" => synth::bctcga_sim(seed),
        "toy-2x2" => synth::toy_2x2(),
        other => anyhow::bail!(
            "unknown dataset {other:?} (expected leukemia-sim, leukemia-mini, \
             finance-sim, finance-mini, bctcga-sim, toy-2x2)"
        ),
    })
}

/// One experiment cell: a solver on a λ-path at a tolerance.
#[derive(Debug, Clone)]
pub struct PathJob {
    pub solver_name: String,
    pub tol: f64,
    /// λ grid (descending).
    pub grid: Vec<f64>,
    pub store_betas: bool,
}

/// Resolve every job's solver name up front, so workers never re-parse
/// (and never need a "can't happen" unwrap on a name that validated
/// moments earlier).
fn resolve_jobs(jobs: Vec<PathJob>) -> Result<Vec<(PathSolver, PathJob)>, SolveError> {
    jobs.into_iter()
        .map(|j| match PathSolver::by_name(&j.solver_name, j.tol) {
            Some(s) => Ok((s, j)),
            None => Err(SolveError::BadConfig {
                what: format!("unknown solver {:?}", j.solver_name),
            }),
        })
        .collect()
}

/// Run a grid of path jobs on one dataset, parallel across cells.
pub fn run_path_jobs(
    ds: &SynthDataset,
    jobs: Vec<PathJob>,
    workers: usize,
) -> anyhow::Result<Vec<PathResult>> {
    let resolved = resolve_jobs(jobs)?;
    let results =
        scheduler::run_parallel_with_state(resolved, workers, Workspace::new, |ws, cell| {
            let (solver, job) = (&cell.0, &cell.1);
            run_path_with_workspace(&ds.x, &ds.y, &job.grid, solver, job.store_betas, ws)
        });
    Ok(results)
}

/// [`run_path_jobs`] with the full guardrail stack: typed validation of
/// the dataset and every job before any epoch runs, per-job panic
/// retry / timeout / quarantine from
/// [`scheduler::run_parallel_robust`], and an optional per-job
/// wall-clock budget (`max_seconds`) under which each path returns its
/// partial-but-certified prefix. One poisoned cell surfaces as an `Err`
/// in its slot; the rest of the grid still completes.
pub fn run_path_jobs_robust(
    ds: &SynthDataset,
    jobs: Vec<PathJob>,
    workers: usize,
    policy: &scheduler::RobustPolicy,
    max_seconds: Option<f64>,
) -> Result<Vec<Result<PathResult, SolveError>>, SolveError> {
    crate::data::validate::validate_problem(&ds.x, &ds.y)?;
    for j in &jobs {
        crate::data::validate::validate_grid(&j.grid)?;
    }
    let resolved = resolve_jobs(jobs)?;
    Ok(scheduler::run_parallel_robust(
        resolved,
        workers,
        policy,
        Workspace::new,
        |ws, cell| {
            let (solver, job) = (&cell.0, &cell.1);
            run_path_budgeted(&ds.x, &ds.y, &job.grid, solver, job.store_betas, max_seconds, ws)
        },
    ))
}

/// Convenience: the paper's standard grid for a dataset (λmax → λmax/ratio).
pub fn standard_grid(ds: &SynthDataset, inv_ratio: f64, num: usize) -> Vec<f64> {
    let lmax = crate::lasso::dual::lambda_max(&ds.x, &ds.y);
    lambda_grid(lmax, 1.0 / inv_ratio, num)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_loader_known_and_unknown() {
        assert!(load_dataset("leukemia-mini", 0).is_ok());
        assert!(load_dataset("toy-2x2", 0).is_ok());
        assert!(load_dataset("bogus", 0).is_err());
    }

    #[test]
    fn path_jobs_run_in_parallel_and_agree_with_serial() {
        let ds = load_dataset("leukemia-mini", 3).unwrap();
        let grid = standard_grid(&ds, 10.0, 4);
        let jobs: Vec<PathJob> = ["celer-prune", "blitz"]
            .iter()
            .map(|s| PathJob {
                solver_name: s.to_string(),
                tol: 1e-6,
                grid: grid.clone(),
                store_betas: false,
            })
            .collect();
        let par = run_path_jobs(&ds, jobs.clone(), 2).unwrap();
        let ser = run_path_jobs(&ds, jobs, 1).unwrap();
        assert_eq!(par.len(), 2);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.solver, b.solver);
            assert_eq!(a.steps.len(), b.steps.len());
            for (sa, sb) in a.steps.iter().zip(&b.steps) {
                assert_eq!(sa.support_size, sb.support_size, "{}", a.solver);
            }
        }
    }

    #[test]
    fn batched_jobs_run_through_the_scheduler() {
        let ds = load_dataset("leukemia-mini", 9).unwrap();
        let grid = standard_grid(&ds, 10.0, 5);
        let tol = 1e-8;
        let jobs: Vec<PathJob> = ["cd-batched", "gapsafe-cd-accel"]
            .iter()
            .map(|s| PathJob {
                solver_name: s.to_string(),
                tol,
                grid: grid.clone(),
                store_betas: true,
            })
            .collect();
        let out = run_path_jobs(&ds, jobs, 2).unwrap();
        assert_eq!(out[0].solver, "cd-batched");
        for r in &out {
            assert!(r.all_converged(), "{} converged", r.solver);
            assert_eq!(r.steps.len(), grid.len());
        }
        // batched and sequential grids agree on the certified objectives
        for (i, (sb, ss)) in out[0].steps.iter().zip(&out[1].steps).enumerate() {
            let pb = crate::lasso::primal::primal(
                &ds.x,
                &ds.y,
                sb.beta.as_ref().unwrap(),
                grid[i],
            );
            let ps = crate::lasso::primal::primal(
                &ds.x,
                &ds.y,
                ss.beta.as_ref().unwrap(),
                grid[i],
            );
            assert!((pb - ps).abs() <= 2.0 * tol, "λ#{i}: {pb} vs {ps}");
        }
    }

    #[test]
    fn mt_jobs_route_through_by_name_like_batched() {
        // "celer-mt" grid cells dispatch through the same by_name path
        // as every other solver; workers keep the block workspace in
        // their per-thread engine Workspace.
        let ds = load_dataset("leukemia-mini", 12).unwrap();
        let grid = standard_grid(&ds, 10.0, 4);
        let tol = 1e-8;
        let jobs: Vec<PathJob> = ["celer-mt", "celer-prune"]
            .iter()
            .map(|s| PathJob {
                solver_name: s.to_string(),
                tol,
                grid: grid.clone(),
                store_betas: true,
            })
            .collect();
        let out = run_path_jobs(&ds, jobs, 2).unwrap();
        assert_eq!(out[0].solver, "celer-mt");
        for r in &out {
            assert!(r.all_converged(), "{} converged", r.solver);
        }
        for (i, (sm, sc)) in out[0].steps.iter().zip(&out[1].steps).enumerate() {
            let pm = crate::lasso::primal::primal(
                &ds.x,
                &ds.y,
                sm.beta.as_ref().unwrap(),
                grid[i],
            );
            let pc = crate::lasso::primal::primal(
                &ds.x,
                &ds.y,
                sc.beta.as_ref().unwrap(),
                grid[i],
            );
            assert!((pm - pc).abs() <= 2.0 * tol, "λ#{i}: {pm} vs {pc}");
        }
    }

    #[test]
    fn logreg_jobs_route_through_by_name() {
        // "celer-logreg" grid cells dispatch through the same by_name
        // path as every other solver; continuous targets are binarized
        // by sign inside the path driver, and every step is certified.
        let ds = load_dataset("leukemia-mini", 14).unwrap();
        let labels = crate::data::synth::sign_labels(&ds.y);
        let lmax = crate::solvers::glm::logreg_lambda_max(&ds.x, &labels);
        let grid = crate::solvers::path::lambda_grid(lmax, 0.1, 3);
        let tol = 1e-6;
        let jobs: Vec<PathJob> = ["celer-logreg", "celer-prune"]
            .iter()
            .map(|s| PathJob {
                solver_name: s.to_string(),
                tol,
                grid: grid.clone(),
                store_betas: false,
            })
            .collect();
        let out = run_path_jobs(&ds, jobs, 2).unwrap();
        assert_eq!(out[0].solver, "celer-logreg");
        assert!(out[0].all_converged(), "logreg grid cells certified");
        for s in &out[0].steps {
            assert!(s.gap <= tol);
        }
    }

    #[test]
    fn penalty_jobs_route_through_by_name() {
        // "celer-enet" / "celer-wlasso" grid cells dispatch through the
        // same by_name path as every other solver; each penalty's grid
        // anchors at its own λ_max so the first cell starts sparse.
        let ds = load_dataset("leukemia-mini", 15).unwrap();
        let tol = 1e-7;
        let enet = crate::penalty::ElasticNet::new(0.5);
        let wlasso = crate::penalty::WeightedL1::new(crate::penalty::scale_weights(&ds.x));
        let jobs: Vec<PathJob> = [
            ("celer-enet", crate::lasso::dual::penalty_lambda_max(&ds.x, &ds.y, &enet)),
            ("celer-wlasso", crate::lasso::dual::penalty_lambda_max(&ds.x, &ds.y, &wlasso)),
        ]
        .iter()
        .map(|(s, lmax)| PathJob {
            solver_name: s.to_string(),
            tol,
            grid: crate::solvers::path::lambda_grid(*lmax, 0.1, 3),
            store_betas: false,
        })
        .collect();
        let out = run_path_jobs(&ds, jobs, 2).unwrap();
        assert_eq!(out[0].solver, "celer-enet");
        assert_eq!(out[1].solver, "celer-wlasso");
        for r in &out {
            assert!(r.all_converged(), "{} grid cells certified", r.solver);
            for s in &r.steps {
                assert!(s.gap <= tol);
            }
            // λ_max anchoring: the first cell's solution is empty (or
            // nearly so), deeper cells select features.
            assert!(r.steps.last().unwrap().support_size > 0, "{}", r.solver);
        }
    }

    #[test]
    fn rejects_unknown_solver() {
        let ds = load_dataset("leukemia-mini", 3).unwrap();
        let jobs = vec![PathJob {
            solver_name: "nope".into(),
            tol: 1e-6,
            grid: vec![0.1],
            store_betas: false,
        }];
        assert!(run_path_jobs(&ds, jobs, 1).is_err());
    }

    #[test]
    fn robust_jobs_match_plain_jobs_and_type_errors() {
        let ds = load_dataset("leukemia-mini", 3).unwrap();
        let grid = standard_grid(&ds, 10.0, 4);
        let job = |name: &str| PathJob {
            solver_name: name.to_string(),
            tol: 1e-6,
            grid: grid.clone(),
            store_betas: false,
        };
        let plain = run_path_jobs(&ds, vec![job("celer-prune")], 1).unwrap();
        let robust = run_path_jobs_robust(
            &ds,
            vec![job("celer-prune")],
            1,
            &scheduler::RobustPolicy::default(),
            None,
        )
        .unwrap();
        let r = robust[0].as_ref().unwrap();
        assert_eq!(r.steps.len(), plain[0].steps.len());
        for (a, b) in r.steps.iter().zip(&plain[0].steps) {
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "robust wrapper changes no bits");
        }
        // unknown solver: typed error before any epoch
        let err = run_path_jobs_robust(
            &ds,
            vec![job("nope")],
            1,
            &scheduler::RobustPolicy::default(),
            None,
        )
        .unwrap_err();
        assert!(matches!(err, SolveError::BadConfig { .. }), "{err:?}");
        // bad grid: typed error before any epoch
        let mut bad = job("celer-prune");
        bad.grid = vec![f64::NAN];
        let err = run_path_jobs_robust(
            &ds,
            vec![bad],
            1,
            &scheduler::RobustPolicy::default(),
            None,
        )
        .unwrap_err();
        assert!(matches!(err, SolveError::BadGrid { .. }), "{err:?}");
    }

    #[test]
    fn robust_jobs_budget_truncates_but_certifies() {
        // An already-expired budget returns empty (or prefix) paths —
        // never an error, never an uncertified step.
        let ds = load_dataset("leukemia-mini", 4).unwrap();
        let grid = standard_grid(&ds, 10.0, 4);
        let jobs = vec![PathJob {
            solver_name: "celer-prune".into(),
            tol: 1e-6,
            grid,
            store_betas: false,
        }];
        let out = run_path_jobs_robust(
            &ds,
            jobs,
            1,
            &scheduler::RobustPolicy::default(),
            Some(0.0),
        )
        .unwrap();
        let r = out[0].as_ref().unwrap();
        assert!(r.steps.is_empty(), "expired budget ⇒ empty prefix");
    }

    #[test]
    fn standard_grid_spans_ratio() {
        let ds = load_dataset("leukemia-mini", 1).unwrap();
        let g = standard_grid(&ds, 100.0, 10);
        assert_eq!(g.len(), 10);
        assert!((g[0] / g[9] - 100.0).abs() < 1e-9);
    }
}
