//! Vectorized kernel layer: fixed-width multi-accumulator unrolling.
//!
//! Rust (like C at `-O3` without `-ffast-math`) must preserve the exact
//! floating-point semantics of the source, so the autovectorizer can
//! never reassociate a naive reduction loop `acc += a[i] * b[i]` into
//! SIMD lanes — the scalar dot/norm kernels of the CD epoch leave 4–8×
//! of per-core FLOP throughput on the table. The fix needs no nightly
//! features and no intrinsics: write the reduction with a **fixed
//! number of independent accumulators** (8 for contiguous f64/f32
//! kernels — an f64x4-pair / f32x8 shape on AVX2, one f64x8 on
//! AVX-512 — and 4 for CSC gather kernels, where the index decode
//! dominates) and the autovectorizer keeps them in vector registers.
//! Element-wise kernels (`axpy`-shaped loops) carry no reduction, so
//! unrolling them is bitwise-neutral and vectorizes for free.
//!
//! # Accumulator-order contract
//!
//! Changing the association order changes the rounding, so every
//! reduction in this module follows ONE documented order, mirrored by
//! the test-local scalar references in `tests/prop_simd.rs`:
//!
//! 1. lane assignment: element `i` accumulates into `acc[i % W]`
//!    (`W = 8` contiguous, `W = 4` gather) — full chunks feed lanes
//!    `0..W` in order, and the final partial chunk (the scalar tail)
//!    folds element `main + l` into `acc[l]`;
//! 2. lane reduction: a fixed pairwise tree,
//!    `((a0+a1) + (a2+a3)) + ((a4+a5) + (a6+a7))` for `W = 8` and
//!    `(a0+a1) + (a2+a3)` for `W = 4`.
//!
//! Every reduction the solver engine performs — `linalg::{dot, norm,
//! asum}`, the design kernels `col_dot` / `col_norm_sq` /
//! `col_wnorm_sq`, the block/multitask norm folds ([`sum_by`]) — routes
//! through these kernels, so the crate has exactly one place where
//! reduction order is defined. The results are deterministic for a
//! given input (the contract is a pure function of the length), which
//! is what keeps the pooled thread-count-invariance guarantees of
//! `util::par` intact.

/// Accumulator width for contiguous f64/f32 kernels.
pub const WIDTH: usize = 8;
/// Accumulator width for CSC gather kernels.
pub const GATHER_WIDTH: usize = 4;

#[inline(always)]
fn reduce8(acc: [f64; WIDTH]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

#[inline(always)]
fn reduce8_f32(acc: [f32; WIDTH]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

#[inline(always)]
fn reduce4(acc: [f64; GATHER_WIDTH]) -> f64 {
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

#[inline(always)]
fn reduce4_f32(acc: [f32; GATHER_WIDTH]) -> f32 {
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// Dot product `Σᵢ aᵢ·bᵢ` under the module's accumulator contract.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let main = len - len % WIDTH;
    let mut acc = [0.0f64; WIDTH];
    for (ca, cb) in a[..main].chunks_exact(WIDTH).zip(b[..main].chunks_exact(WIDTH)) {
        for l in 0..WIDTH {
            acc[l] += ca[l] * cb[l];
        }
    }
    for l in 0..(len - main) {
        acc[l] += a[main + l] * b[main + l];
    }
    reduce8(acc)
}

/// Sum of absolute values `Σᵢ |aᵢ|` (the ℓ1 norm fold).
#[inline]
pub fn asum(a: &[f64]) -> f64 {
    let len = a.len();
    let main = len - len % WIDTH;
    let mut acc = [0.0f64; WIDTH];
    for ca in a[..main].chunks_exact(WIDTH) {
        for l in 0..WIDTH {
            acc[l] += ca[l].abs();
        }
    }
    for l in 0..(len - main) {
        acc[l] += a[main + l].abs();
    }
    reduce8(acc)
}

/// Weighted squared sum `Σᵢ wᵢ·cᵢ²` (the prox-Newton curvature kernel).
#[inline]
pub fn wssq(w: &[f64], c: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), c.len());
    let len = c.len();
    let main = len - len % WIDTH;
    let mut acc = [0.0f64; WIDTH];
    for (cw, cc) in w[..main].chunks_exact(WIDTH).zip(c[..main].chunks_exact(WIDTH)) {
        for l in 0..WIDTH {
            acc[l] += cw[l] * cc[l] * cc[l];
        }
    }
    for l in 0..(len - main) {
        acc[l] += w[main + l] * c[main + l] * c[main + l];
    }
    reduce8(acc)
}

/// `y += alpha · x`. Element-wise (no reduction), so the unrolled form
/// is bitwise-identical to the naive loop — unrolling only hands the
/// autovectorizer a branch-free body.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let len = x.len();
    let main = len - len % WIDTH;
    for (cy, cx) in y[..main].chunks_exact_mut(WIDTH).zip(x[..main].chunks_exact(WIDTH)) {
        for l in 0..WIDTH {
            cy[l] += alpha * cx[l];
        }
    }
    for i in main..len {
        y[i] += alpha * x[i];
    }
}

/// `out[i] += alpha · w[i] · c[i]` (weighted axpy; element-wise).
#[inline]
pub fn waxpy(alpha: f64, w: &[f64], c: &[f64], out: &mut [f64]) {
    debug_assert_eq!(w.len(), c.len());
    debug_assert_eq!(out.len(), c.len());
    let len = c.len();
    let main = len - len % WIDTH;
    for ((co, cw), cc) in out[..main]
        .chunks_exact_mut(WIDTH)
        .zip(w[..main].chunks_exact(WIDTH))
        .zip(c[..main].chunks_exact(WIDTH))
    {
        for l in 0..WIDTH {
            co[l] += alpha * cw[l] * cc[l];
        }
    }
    for i in main..len {
        out[i] += alpha * w[i] * c[i];
    }
}

/// `out[i] = b[i] − a[i]` (element-wise difference; the extrapolation
/// ring's `U` columns `r^{t+1} − r^t`).
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(out.len(), a.len());
    let len = a.len();
    let main = len - len % WIDTH;
    for ((co, ca), cb) in out[..main]
        .chunks_exact_mut(WIDTH)
        .zip(a[..main].chunks_exact(WIDTH))
        .zip(b[..main].chunks_exact(WIDTH))
    {
        for l in 0..WIDTH {
            co[l] = cb[l] - ca[l];
        }
    }
    for i in main..len {
        out[i] = b[i] - a[i];
    }
}

/// Generic indexed fold `Σᵢ f(i)` under the width-8 accumulator
/// contract — the one reduction order for sums whose terms are not a
/// contiguous slice (block row norms, multitask ℓ2,1 folds).
#[inline]
pub fn sum_by<F: FnMut(usize) -> f64>(len: usize, mut f: F) -> f64 {
    let main = len - len % WIDTH;
    let mut acc = [0.0f64; WIDTH];
    let mut i = 0;
    while i < main {
        for l in 0..WIDTH {
            acc[l] += f(i + l);
        }
        i += WIDTH;
    }
    for l in 0..(len - main) {
        acc[l] += f(main + l);
    }
    reduce8(acc)
}

// ---------------------------------------------------------------------
// CSC gather kernels: unrolled over the (indices, values) entry arrays.
// The gather load dominates, so 4 accumulators suffice to hide its
// latency; element `k` accumulates into `acc[k % 4]`.
// ---------------------------------------------------------------------

/// Gathered dot `Σₖ val[k] · v[idx[k]]`.
///
/// # Safety
/// Every `idx[k] as usize` must be `< v.len()`. CSC constructors
/// validate row indices against n, so design-kernel callers pass
/// full-length (≥ n) vectors.
#[inline]
pub unsafe fn gather_dot(idx: &[u32], val: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    debug_assert!(idx.iter().all(|&i| (i as usize) < v.len()));
    let m = idx.len();
    let main = m - m % GATHER_WIDTH;
    let mut acc = [0.0f64; GATHER_WIDTH];
    let mut k = 0;
    while k < main {
        for l in 0..GATHER_WIDTH {
            acc[l] += *val.get_unchecked(k + l)
                * *v.get_unchecked(*idx.get_unchecked(k + l) as usize);
        }
        k += GATHER_WIDTH;
    }
    for l in 0..(m - main) {
        acc[l] += *val.get_unchecked(main + l)
            * *v.get_unchecked(*idx.get_unchecked(main + l) as usize);
    }
    reduce4(acc)
}

/// Gathered weighted squared sum `Σₖ w[idx[k]] · val[k]²`.
///
/// # Safety
/// Every `idx[k] as usize` must be `< w.len()`.
#[inline]
pub unsafe fn gather_wssq(idx: &[u32], val: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    debug_assert!(idx.iter().all(|&i| (i as usize) < w.len()));
    let m = idx.len();
    let main = m - m % GATHER_WIDTH;
    let mut acc = [0.0f64; GATHER_WIDTH];
    let mut k = 0;
    while k < main {
        for l in 0..GATHER_WIDTH {
            let x = *val.get_unchecked(k + l);
            acc[l] += *w.get_unchecked(*idx.get_unchecked(k + l) as usize) * x * x;
        }
        k += GATHER_WIDTH;
    }
    for l in 0..(m - main) {
        let x = *val.get_unchecked(main + l);
        acc[l] += *w.get_unchecked(*idx.get_unchecked(main + l) as usize) * x * x;
    }
    reduce4(acc)
}

/// Scatter `out[idx[k]] += alpha · val[k]`. No reduction (each output
/// element is touched at most once per column — CSC row indices are
/// strictly increasing), so no unrolling is needed for exactness; the
/// plain loop is kept here so every gather/scatter kernel lives in one
/// module.
///
/// # Safety
/// Every `idx[k] as usize` must be `< out.len()`.
#[inline]
pub unsafe fn gather_axpy(idx: &[u32], val: &[f64], alpha: f64, out: &mut [f64]) {
    debug_assert_eq!(idx.len(), val.len());
    debug_assert!(idx.iter().all(|&i| (i as usize) < out.len()));
    for k in 0..idx.len() {
        *out.get_unchecked_mut(*idx.get_unchecked(k) as usize) += alpha * *val.get_unchecked(k);
    }
}

/// Weighted scatter `out[i] += alpha · w[i] · val[k]` at `i = idx[k]`.
///
/// # Safety
/// Every `idx[k] as usize` must be `< out.len()` and `< w.len()`.
#[inline]
pub unsafe fn gather_waxpy(idx: &[u32], val: &[f64], alpha: f64, w: &[f64], out: &mut [f64]) {
    debug_assert_eq!(idx.len(), val.len());
    debug_assert_eq!(w.len(), out.len());
    debug_assert!(idx.iter().all(|&i| (i as usize) < out.len()));
    for k in 0..idx.len() {
        let i = *idx.get_unchecked(k) as usize;
        *out.get_unchecked_mut(i) += alpha * *w.get_unchecked(i) * *val.get_unchecked(k);
    }
}

// ---------------------------------------------------------------------
// f32 kernels (the f32 sweep mode of `solvers/sweep32.rs` /
// `solvers/batch.rs`): same shapes, f32x8 accumulators.
// ---------------------------------------------------------------------

/// f32 dot product under the same width-8 accumulator contract.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let main = len - len % WIDTH;
    let mut acc = [0.0f32; WIDTH];
    for (ca, cb) in a[..main].chunks_exact(WIDTH).zip(b[..main].chunks_exact(WIDTH)) {
        for l in 0..WIDTH {
            acc[l] += ca[l] * cb[l];
        }
    }
    for l in 0..(len - main) {
        acc[l] += a[main + l] * b[main + l];
    }
    reduce8_f32(acc)
}

/// f32 `y += alpha · x` (element-wise).
#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let len = x.len();
    let main = len - len % WIDTH;
    for (cy, cx) in y[..main].chunks_exact_mut(WIDTH).zip(x[..main].chunks_exact(WIDTH)) {
        for l in 0..WIDTH {
            cy[l] += alpha * cx[l];
        }
    }
    for i in main..len {
        y[i] += alpha * x[i];
    }
}

/// f32 gathered dot.
///
/// # Safety
/// Every `idx[k] as usize` must be `< v.len()`.
#[inline]
pub unsafe fn gather_dot_f32(idx: &[u32], val: &[f32], v: &[f32]) -> f32 {
    debug_assert_eq!(idx.len(), val.len());
    debug_assert!(idx.iter().all(|&i| (i as usize) < v.len()));
    let m = idx.len();
    let main = m - m % GATHER_WIDTH;
    let mut acc = [0.0f32; GATHER_WIDTH];
    let mut k = 0;
    while k < main {
        for l in 0..GATHER_WIDTH {
            acc[l] += *val.get_unchecked(k + l)
                * *v.get_unchecked(*idx.get_unchecked(k + l) as usize);
        }
        k += GATHER_WIDTH;
    }
    for l in 0..(m - main) {
        acc[l] += *val.get_unchecked(main + l)
            * *v.get_unchecked(*idx.get_unchecked(main + l) as usize);
    }
    reduce4_f32(acc)
}

/// f32 scatter `out[idx[k]] += alpha · val[k]`.
///
/// # Safety
/// Every `idx[k] as usize` must be `< out.len()`.
#[inline]
pub unsafe fn gather_axpy_f32(idx: &[u32], val: &[f32], alpha: f32, out: &mut [f32]) {
    debug_assert_eq!(idx.len(), val.len());
    debug_assert!(idx.iter().all(|&i| (i as usize) < out.len()));
    for k in 0..idx.len() {
        *out.get_unchecked_mut(*idx.get_unchecked(k) as usize) += alpha * *val.get_unchecked(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The documented contract, written the slow way: element `i` into
    /// `acc[i % W]`, then the fixed pairwise tree.
    fn ref_fold8<F: Fn(usize) -> f64>(len: usize, f: F) -> f64 {
        let mut acc = [0.0f64; 8];
        for i in 0..len {
            acc[i % 8] += f(i);
        }
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
    }

    fn ref_fold4<F: Fn(usize) -> f64>(len: usize, f: F) -> f64 {
        let mut acc = [0.0f64; 4];
        for i in 0..len {
            acc[i % 4] += f(i);
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    const LENS: [usize; 14] = [0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 31, 33, 64, 257];

    #[test]
    fn dot_matches_contract_bitwise() {
        let mut rng = Rng::new(1);
        for &n in &LENS {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let expect = ref_fold8(n, |i| a[i] * b[i]);
            assert_eq!(dot(&a, &b).to_bits(), expect.to_bits(), "n={n}");
        }
    }

    #[test]
    fn asum_wssq_match_contract_bitwise() {
        let mut rng = Rng::new(2);
        for &n in &LENS {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
            assert_eq!(asum(&a).to_bits(), ref_fold8(n, |i| a[i].abs()).to_bits(), "n={n}");
            let expect = ref_fold8(n, |i| w[i] * a[i] * a[i]);
            assert_eq!(wssq(&w, &a).to_bits(), expect.to_bits(), "n={n}");
        }
    }

    #[test]
    fn sum_by_matches_contract_bitwise() {
        for &n in &LENS {
            let f = |i: usize| ((i * 2654435761) % 997) as f64 * 1e-3 - 0.25;
            assert_eq!(sum_by(n, f).to_bits(), ref_fold8(n, f).to_bits(), "n={n}");
        }
    }

    #[test]
    fn elementwise_kernels_match_naive_bitwise() {
        let mut rng = Rng::new(3);
        for &n in &LENS {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let y0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut y = y0.clone();
            axpy(-1.3, &x, &mut y);
            let naive: Vec<f64> = (0..n).map(|i| y0[i] + -1.3 * x[i]).collect();
            assert_eq!(y, naive, "axpy n={n}");
            let mut y = y0.clone();
            waxpy(0.7, &w, &x, &mut y);
            let naive: Vec<f64> = (0..n).map(|i| y0[i] + 0.7 * w[i] * x[i]).collect();
            assert_eq!(y, naive, "waxpy n={n}");
            let mut d = vec![0.0; n];
            sub(&x, &y0, &mut d);
            let naive: Vec<f64> = (0..n).map(|i| y0[i] - x[i]).collect();
            assert_eq!(d, naive, "sub n={n}");
        }
    }

    #[test]
    fn gather_kernels_match_contract_bitwise() {
        let mut rng = Rng::new(4);
        let n = 37;
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        for &m in &[0usize, 1, 2, 3, 4, 5, 7, 8, 13, 37] {
            let idx: Vec<u32> = (0..m).map(|k| ((k * 7) % n) as u32).collect();
            let val: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let expect = ref_fold4(m, |k| val[k] * v[idx[k] as usize]);
            let got = unsafe { gather_dot(&idx, &val, &v) };
            assert_eq!(got.to_bits(), expect.to_bits(), "gather_dot m={m}");
            let expect = ref_fold4(m, |k| w[idx[k] as usize] * val[k] * val[k]);
            let got = unsafe { gather_wssq(&idx, &val, &w) };
            assert_eq!(got.to_bits(), expect.to_bits(), "gather_wssq m={m}");
        }
    }

    #[test]
    fn f32_kernels_match_f64_within_f32_resolution() {
        let mut rng = Rng::new(5);
        for &n in &[5usize, 64, 257] {
            let a64: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b64: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let a32: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let exact = dot(&a64, &b64);
            let approx = dot_f32(&a32, &b32) as f64;
            let scale = asum(&a64).max(1.0);
            assert!((exact - approx).abs() < 1e-4 * scale, "n={n}: {exact} vs {approx}");
            let mut y32: Vec<f32> = b32.clone();
            axpy_f32(0.5, &a32, &mut y32);
            for i in 0..n {
                assert_eq!(y32[i], b32[i] + 0.5 * a32[i], "axpy_f32 i={i}");
            }
        }
    }
}
