//! Typed error/outcome surface for the solver stack (the robustness
//! layer's vocabulary — see ARCHITECTURE.md §"Robustness layer").
//!
//! Three families of types live here:
//!
//! - [`SolveError`]: everything that can be rejected **before the first
//!   epoch** (non-finite inputs, dimension mismatches, label-domain and
//!   weight violations — produced by [`crate::data::validate`]), typed
//!   parse failures from the svmlight reader, and scheduler-level job
//!   failures (poisoned / timed-out cells). Implements
//!   `std::error::Error`, so `?` lifts it into `anyhow::Result`
//!   contexts for free.
//! - [`SolveOutcome`]: how a run that *did* start ended. `Certified`
//!   means the stopping rule fired with a valid duality-gap
//!   certificate; `BudgetExhausted` means an epoch or wall-clock budget
//!   ran out first (the returned iterate is still the best certified
//!   state); `Recovered` means one or more in-loop faults were detected
//!   and the engine rolled back to its last gap-certified checkpoint —
//!   a `Recovered` run that reports `converged = true` is exactly as
//!   certified as a clean one (the final gap is recomputable from the
//!   returned (β, θ) pair).
//! - [`FaultEvent`]/[`FaultKind`]/[`RecoveryAction`]: the audit trail a
//!   watchdog leaves behind, one event per detected fault.

use std::fmt;

/// A typed, pre-epoch or scheduler-level failure. Every public `try_*`
/// entry point returns `Result<_, SolveError>`; the historical
/// panicking/silent paths are unchanged.
#[derive(Debug, Clone, PartialEq)]
pub enum SolveError {
    /// A design-matrix entry is NaN or ±∞.
    NonFiniteDesign { row: usize, col: usize, value: f64 },
    /// A label/target entry is NaN or ±∞.
    NonFiniteLabels { index: usize, value: f64 },
    /// `y.len()` does not match the design's row count.
    DimensionMismatch { rows: usize, labels: usize },
    /// A target violates the datafit's domain (logistic: ±1 labels;
    /// Poisson: finite counts ≥ 0).
    LabelDomain { family: &'static str, index: usize, value: f64, expected: &'static str },
    /// A penalty weight is NaN or negative (0 = unpenalized and +∞ =
    /// hard-zeroed are both legal).
    BadWeight { index: usize, value: f64 },
    /// A λ-grid entry is non-finite, non-positive, or the grid is not
    /// non-increasing.
    BadGrid { index: usize, value: f64, reason: &'static str },
    /// A configuration value is unusable (unknown solver name, zero
    /// grid, …).
    BadConfig { what: String },
    /// Typed parse failure (svmlight reader): 1-based line and column.
    Parse { line: usize, col: usize, msg: String },
    /// An on-disk column store failed structural validation at open
    /// (bad magic, unsupported version, truncated segments, non-monotone
    /// column index) or could not be read/written.
    StoreFormat { path: String, detail: String },
    /// A scheduler job panicked on every attempt and was quarantined.
    JobPoisoned { job: usize, attempts: usize, detail: String },
    /// A scheduler job exceeded its per-job timeout on every attempt.
    JobTimeout { job: usize, seconds: f64 },
}

impl fmt::Display for SolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SolveError::NonFiniteDesign { row, col, value } => {
                write!(f, "non-finite design entry X[{row}, {col}] = {value}")
            }
            SolveError::NonFiniteLabels { index, value } => {
                write!(f, "non-finite label y[{index}] = {value}")
            }
            SolveError::DimensionMismatch { rows, labels } => {
                write!(f, "dimension mismatch: design has {rows} rows but y has {labels} entries")
            }
            SolveError::LabelDomain { family, index, value, expected } => {
                write!(f, "{family} datafit requires {expected}; got y[{index}] = {value}")
            }
            SolveError::BadWeight { index, value } => {
                write!(f, "penalty weight w[{index}] = {value} (must be finite ≥ 0, or +inf)")
            }
            SolveError::BadGrid { index, value, reason } => {
                write!(f, "bad λ grid at index {index} (λ = {value}): {reason}")
            }
            SolveError::BadConfig { what } => write!(f, "bad configuration: {what}"),
            SolveError::Parse { line, col, msg } => {
                write!(f, "parse error at line {line}, column {col}: {msg}")
            }
            SolveError::StoreFormat { path, detail } => {
                write!(f, "column store {path}: {detail}")
            }
            SolveError::JobPoisoned { job, attempts, detail } => {
                write!(f, "job {job} quarantined after {attempts} attempt(s): {detail}")
            }
            SolveError::JobTimeout { job, seconds } => {
                write!(f, "job {job} exceeded its {seconds}s timeout")
            }
        }
    }
}

impl std::error::Error for SolveError {}

/// What an in-loop watchdog detected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The duality gap evaluated to NaN/∞ at a check.
    NonFiniteGap,
    /// The primal value (or the residual feeding it) went non-finite.
    NonFiniteResidual,
    /// The dual objective went non-finite.
    NonFiniteDual,
    /// The primal objective exploded past the divergence guard.
    PrimalDivergence,
    /// A parallel shard/job closure panicked.
    ShardPanic,
    /// A worker exceeded its per-job timeout.
    WorkerDelay,
}

/// What the watchdog did about it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Rolled back to the last gap-certified checkpoint (β, r, best
    /// dual flushed) and continued.
    RolledBack,
    /// Rolled back and additionally escalated f32 sweeps to f64 epochs.
    EscalatedF64,
    /// Restarted the λ-lane from its warm-start seed.
    Restarted,
    /// Gave up: the recovery budget was exhausted; the last certified
    /// state was restored and the run terminated early.
    Aborted,
    /// A scheduler job was retried on a fresh worker state.
    Retried,
    /// A scheduler job was quarantined (typed error returned).
    Quarantined,
}

/// One watchdog event: what was detected, when, and the response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// Epoch (engine) or attempt number (scheduler) at detection.
    pub epoch: usize,
    pub action: RecoveryAction,
}

/// How a run ended. Carried by
/// [`EngineOutcome`](crate::solvers::engine::EngineOutcome) and
/// [`SolveResult`](crate::solvers::SolveResult).
#[derive(Debug, Clone, PartialEq)]
pub enum SolveOutcome {
    /// The stopping rule fired; the result carries a valid certificate.
    Certified,
    /// An epoch or wall-clock budget ran out before the tolerance was
    /// met. `gap`/`epochs` snapshot the partial-but-certified state.
    BudgetExhausted { gap: f64, epochs: usize },
    /// In-loop faults were detected and recovered from (see the event
    /// list). The result is still gap-certified when `converged` holds.
    Recovered { faults: Vec<FaultEvent> },
}

impl Default for SolveOutcome {
    fn default() -> Self {
        SolveOutcome::Certified
    }
}

impl SolveOutcome {
    /// The canonical status mapping shared by every solver loop:
    /// recorded faults dominate (a recovered run stays `Recovered` even
    /// if it later converged — the event list is the audit trail), then
    /// budget exhaustion, then `Certified`.
    pub fn from_run(converged: bool, gap: f64, epochs: usize, faults: Vec<FaultEvent>) -> Self {
        if !faults.is_empty() {
            SolveOutcome::Recovered { faults }
        } else if !converged {
            SolveOutcome::BudgetExhausted { gap, epochs }
        } else {
            SolveOutcome::Certified
        }
    }

    /// True when no fault was recorded and no budget ran out.
    pub fn is_certified(&self) -> bool {
        matches!(self, SolveOutcome::Certified)
    }

    /// The recorded fault events (empty unless `Recovered`).
    pub fn faults(&self) -> &[FaultEvent] {
        match self {
            SolveOutcome::Recovered { faults } => faults,
            _ => &[],
        }
    }

    /// Fold another loop's status into this one (outer loops aggregate
    /// the statuses of their inner solves): fault lists concatenate,
    /// and `BudgetExhausted` survives unless faults dominate.
    pub fn absorb(&mut self, other: SolveOutcome) {
        match other {
            SolveOutcome::Certified => {}
            SolveOutcome::BudgetExhausted { gap, epochs } => {
                if matches!(self, SolveOutcome::Certified) {
                    *self = SolveOutcome::BudgetExhausted { gap, epochs };
                }
            }
            SolveOutcome::Recovered { faults: mut other_faults } => match self {
                SolveOutcome::Recovered { faults } => faults.append(&mut other_faults),
                _ => *self = SolveOutcome::Recovered { faults: other_faults },
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = SolveError::NonFiniteDesign { row: 3, col: 7, value: f64::NAN };
        assert!(e.to_string().contains("X[3, 7]"));
        let e = SolveError::Parse { line: 12, col: 4, msg: "bad value".into() };
        assert!(e.to_string().contains("line 12"));
        assert!(e.to_string().contains("column 4"));
    }

    #[test]
    fn question_mark_lifts_into_anyhow() {
        fn inner() -> anyhow::Result<()> {
            Err(SolveError::DimensionMismatch { rows: 5, labels: 4 })?;
            Ok(())
        }
        let msg = inner().unwrap_err().to_string();
        assert!(msg.contains("dimension mismatch"), "{msg}");
    }

    #[test]
    fn from_run_mapping() {
        assert!(SolveOutcome::from_run(true, 1e-9, 10, Vec::new()).is_certified());
        assert_eq!(
            SolveOutcome::from_run(false, 0.5, 100, Vec::new()),
            SolveOutcome::BudgetExhausted { gap: 0.5, epochs: 100 }
        );
        let ev = FaultEvent {
            kind: FaultKind::NonFiniteGap,
            epoch: 20,
            action: RecoveryAction::RolledBack,
        };
        let s = SolveOutcome::from_run(true, 1e-9, 10, vec![ev]);
        assert_eq!(s.faults(), &[ev]);
    }

    #[test]
    fn absorb_merges_faults_and_budgets() {
        let ev = |e: usize| FaultEvent {
            kind: FaultKind::NonFiniteResidual,
            epoch: e,
            action: RecoveryAction::RolledBack,
        };
        let mut s = SolveOutcome::Certified;
        s.absorb(SolveOutcome::BudgetExhausted { gap: 0.1, epochs: 5 });
        assert_eq!(s, SolveOutcome::BudgetExhausted { gap: 0.1, epochs: 5 });
        s.absorb(SolveOutcome::Recovered { faults: vec![ev(1)] });
        s.absorb(SolveOutcome::Recovered { faults: vec![ev(2)] });
        assert_eq!(s.faults().len(), 2);
    }
}
