//! Deterministic pseudo-random number generation.
//!
//! We avoid external RNG crates so that synthetic datasets are bit-for-bit
//! reproducible across platforms and releases. The generator is
//! xoshiro256++ (Blackman & Vigna), seeded through SplitMix64.

/// xoshiro256++ generator with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from the Marsaglia polar method.
    cached_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (n > 0), via rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal variate (Marsaglia polar method, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.cached_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal variate with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(*idx.last().unwrap() < 100);
    }

    #[test]
    fn sample_indices_full() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(5, 5);
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
