//! Data-parallel primitives on the persistent worker pool, with
//! deterministic reductions and a work-based serial/parallel cutoff.
//!
//! The build is fully offline (no rayon), so the two shapes of
//! parallelism the solver needs — index-parallel fill and index-parallel
//! reduce — are implemented here over [`crate::util::pool`]: long-lived
//! workers parked on a condvar, shards claimed off one atomic counter.
//! No `std::thread` spawn happens on any per-gap-check or per-epoch
//! path.
//!
//! **Deterministic reductions.** Work is always decomposed over a fixed
//! grid of [`SHARDS`] index shards, *independently of the thread count*,
//! and partial results are folded in shard order. The serial path runs
//! the exact same shard decomposition. Consequently `par_sum` /
//! `par_max` / [`par_fill_abs_max`] return bit-identical results for
//! any `CELER_NUM_THREADS` on any machine — gaps and dual points are
//! reproducible (pinned by `tests/prop_pool.rs` and the CI thread
//! matrix).
//!
//! **Work-based cutoff.** The old implementation gated on item *count*
//! alone, so a p = 4096, n = 10⁵ dense `xt_vec` (~4·10⁸ flops) ran
//! serially while a p = 10⁴ trivial fill parallelized. The gate is now
//! `items × per-item cost ≥` [`PAR_WORK_THRESHOLD`]; design backends
//! supply the cost via
//! [`DesignOps::col_cost_hint`](crate::data::design::DesignOps::col_cost_hint)
//! (≈ n for dense columns, mean nnz for CSC).
//!
//! Thread count: `CELER_NUM_THREADS` env var, else
//! `std::thread::available_parallelism()`.

use crate::util::pool::{self, SyncPtr};
use std::cell::Cell;
use std::sync::OnceLock;

/// Fixed shard-grid size. Reduction results depend on this constant
/// (fold order) but never on the thread count.
pub const SHARDS: usize = 64;

/// Minimum estimated work (items × per-item cost, roughly flops) before
/// a scan is handed to the pool; below it the sharded serial path runs.
/// A pool dispatch costs ~1–2µs of wakeup latency, so ~2.6·10⁵ flops
/// (tens of µs) amortizes it comfortably.
pub const PAR_WORK_THRESHOLD: usize = 1 << 18;

/// Number of executor threads (pool workers + the submitting thread).
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("CELER_NUM_THREADS") {
            if let Ok(v) = s.parse::<usize>() {
                return v.max(1);
            }
        }
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    })
}

thread_local! {
    static SERIAL_SCOPE: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread must not submit pool jobs (it *is* a
/// pool worker, or a coordinator grid worker — the nested-parallelism
/// policy).
pub fn in_serial_scope() -> bool {
    SERIAL_SCOPE.with(|c| c.get())
}

/// Run `f` with pool parallelism disabled on this thread: every `par_*`
/// call inside takes the serial path. Results are unchanged (the shard
/// decomposition is fixed); only the execution schedule differs. Used
/// by pool workers and coordinator grid workers to prevent nested pool
/// submission, and by tests to pin serial ≡ pooled bit-equality.
pub fn run_serial<R>(f: impl FnOnce() -> R) -> R {
    struct Reset(bool);
    impl Drop for Reset {
        fn drop(&mut self) {
            SERIAL_SCOPE.with(|c| c.set(self.0));
        }
    }
    let _guard = Reset(SERIAL_SCOPE.with(|c| c.replace(true)));
    f()
}

/// Should a scan of the given estimated work go to the pool?
pub(crate) fn parallel_shards(work: usize) -> bool {
    work >= PAR_WORK_THRESHOLD && num_threads() > 1 && !in_serial_scope()
}

/// Index range of shard `s` over `0..n` (fixed grid: depends on n only).
#[inline]
fn shard_bounds(n: usize, s: usize) -> (usize, usize) {
    let chunk = n.div_ceil(SHARDS).max(1);
    ((s * chunk).min(n), ((s + 1) * chunk).min(n))
}

/// `out[i] = f(i)` for all i; pooled when the estimated work
/// (`out.len() × per_item_cost`) is large.
pub fn par_fill_cost<F>(out: &mut [f64], per_item_cost: usize, f: F)
where
    F: Fn(usize) -> f64 + Sync,
{
    let n = out.len();
    if !parallel_shards(n.saturating_mul(per_item_cost.max(1))) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let ptr = SyncPtr(out.as_mut_ptr());
    pool::global().run(SHARDS, &|s| {
        let (lo, hi) = shard_bounds(n, s);
        for i in lo..hi {
            // SAFETY: shard index ranges are disjoint (one writer per i).
            unsafe { *ptr.0.add(i) = f(i) };
        }
    });
}

/// Fused fill + infinity norm: `out[i] = f(i)` and `max_i |out[i]|` in
/// one pass (0.0 when `out` is empty). This is the shape of every dual
/// rescale (Eq. 4): the correlation vector Xᵀθ *and* its max are needed
/// together, and fusing them halves the number of full-p scans.
pub fn par_fill_abs_max<F>(out: &mut [f64], per_item_cost: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    let n = out.len();
    if n == 0 {
        return 0.0;
    }
    if !parallel_shards(n.saturating_mul(per_item_cost.max(1))) {
        let mut m = 0.0f64;
        for (i, o) in out.iter_mut().enumerate() {
            let v = f(i);
            *o = v;
            m = m.max(v.abs());
        }
        return m;
    }
    let mut partials = [0.0f64; SHARDS];
    let out_ptr = SyncPtr(out.as_mut_ptr());
    let part_ptr = SyncPtr(partials.as_mut_ptr());
    pool::global().run(SHARDS, &|s| {
        let (lo, hi) = shard_bounds(n, s);
        let mut m = 0.0f64;
        for i in lo..hi {
            let v = f(i);
            // SAFETY: shard index ranges are disjoint (one writer per i).
            unsafe { *out_ptr.0.add(i) = v };
            m = m.max(v.abs());
        }
        // SAFETY: each shard writes only its own partial slot.
        unsafe { *part_ptr.0.add(s) = m };
    });
    partials.iter().fold(0.0f64, |a, &b| a.max(b))
}

// ---------------------------------------------------------------------
// Group-aligned scans: worker locality matched to store-shard locality
// ---------------------------------------------------------------------
//
// A [`crate::data::shard::ShardedStore`] splits the columns into
// contiguous ranges, each backed by its own store with its own chunk
// cache and prefetch thread. The plain fixed grid of [`SHARDS`] would
// march every worker through shard 0's columns first, so all concurrent
// workers drain the SAME prefetch stream while the other shards' disks
// sit idle. The grouped scans below instead snap the work-unit grid to
// the group bounds (each group split into `⌈SHARDS / ngroups⌉`
// sub-units) and hand units out round-robin ACROSS groups: unit u
// belongs to group `u % ngroups`, so the first `ngroups` concurrently
// claimed units land in `ngroups` different groups — each pool worker
// drains its own prefetch stream. The decomposition depends only on
// `(bounds, SHARDS)`, never the thread count, and the only reductions
// offered are per-index fills and max folds (order-insensitive on the
// non-NaN data these scans produce), so results are bit-identical to
// the ungrouped scans — pinned in `tests/prop_shard.rs`.

/// Index range of sub-unit `u` of a grouped grid (`bounds` are the
/// cumulative group boundaries; `units_per_group` sub-units per group).
#[inline]
fn grouped_unit(bounds: &[usize], units_per_group: usize, u: usize) -> (usize, usize) {
    let ngroups = bounds.len() - 1;
    let (g, sub) = (u % ngroups, u / ngroups);
    let (g0, g1) = (bounds[g], bounds[g + 1]);
    let len = g1 - g0;
    let chunk = len.div_ceil(units_per_group).max(1);
    (g0 + (sub * chunk).min(len), g0 + ((sub + 1) * chunk).min(len))
}

/// [`par_fill_cost`] with the work grid aligned to `bounds` (cumulative
/// group boundaries, `bounds[0] = 0`, last = `out.len()`) and units
/// interleaved round-robin across groups. Identical results — each
/// `out[i]` is written exactly once with `f(i)` — different locality.
pub fn par_fill_cost_grouped<F>(out: &mut [f64], per_item_cost: usize, bounds: &[usize], f: F)
where
    F: Fn(usize) -> f64 + Sync,
{
    let n = out.len();
    debug_assert!(bounds.len() >= 2 && bounds[0] == 0 && *bounds.last().unwrap() == n);
    if bounds.len() <= 2 {
        return par_fill_cost(out, per_item_cost, f);
    }
    if !parallel_shards(n.saturating_mul(per_item_cost.max(1))) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let ngroups = bounds.len() - 1;
    let units_per_group = SHARDS.div_ceil(ngroups).max(1);
    let ptr = SyncPtr(out.as_mut_ptr());
    pool::global().run(ngroups * units_per_group, &|u| {
        let (lo, hi) = grouped_unit(bounds, units_per_group, u);
        for i in lo..hi {
            // SAFETY: sub-unit index ranges are disjoint (one writer per i).
            unsafe { *ptr.0.add(i) = f(i) };
        }
    });
}

/// [`par_fill_abs_max`] with a group-aligned, round-robin work grid.
/// The fold is a max over `|f(i)| ≥ 0` partials — order-insensitive —
/// so the returned value is bit-identical to the ungrouped scan.
pub fn par_fill_abs_max_grouped<F>(
    out: &mut [f64],
    per_item_cost: usize,
    bounds: &[usize],
    f: F,
) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    let n = out.len();
    debug_assert!(bounds.len() >= 2 && bounds[0] == 0 && *bounds.last().unwrap() == n);
    if bounds.len() <= 2 {
        return par_fill_abs_max(out, per_item_cost, f);
    }
    if n == 0 {
        return 0.0;
    }
    if !parallel_shards(n.saturating_mul(per_item_cost.max(1))) {
        let mut m = 0.0f64;
        for (i, o) in out.iter_mut().enumerate() {
            let v = f(i);
            *o = v;
            m = m.max(v.abs());
        }
        return m;
    }
    let ngroups = bounds.len() - 1;
    let units_per_group = SHARDS.div_ceil(ngroups).max(1);
    let total = ngroups * units_per_group;
    let mut partials = vec![0.0f64; total];
    let out_ptr = SyncPtr(out.as_mut_ptr());
    let part_ptr = SyncPtr(partials.as_mut_ptr());
    pool::global().run(total, &|u| {
        let (lo, hi) = grouped_unit(bounds, units_per_group, u);
        let mut m = 0.0f64;
        for i in lo..hi {
            let v = f(i);
            // SAFETY: sub-unit index ranges are disjoint (one writer per i).
            unsafe { *out_ptr.0.add(i) = v };
            m = m.max(v.abs());
        }
        // SAFETY: each sub-unit writes only its own partial slot.
        unsafe { *part_ptr.0.add(u) = m };
    });
    partials.iter().fold(0.0f64, |a, &b| a.max(b))
}

/// [`par_max_cost`] with a group-aligned, round-robin work grid. Max
/// folds are order-insensitive, so the value matches the ungrouped scan
/// bit for bit.
pub fn par_max_cost_grouped<F>(n: usize, per_item_cost: usize, bounds: &[usize], f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    debug_assert!(bounds.len() >= 2 && bounds[0] == 0 && *bounds.last().unwrap() == n);
    if bounds.len() <= 2 || !parallel_shards(n.saturating_mul(per_item_cost.max(1))) {
        return par_max_cost(n, per_item_cost, f);
    }
    let ngroups = bounds.len() - 1;
    let units_per_group = SHARDS.div_ceil(ngroups).max(1);
    let total = ngroups * units_per_group;
    let mut partials = vec![f64::NEG_INFINITY; total];
    let part_ptr = SyncPtr(partials.as_mut_ptr());
    pool::global().run(total, &|u| {
        let (lo, hi) = grouped_unit(bounds, units_per_group, u);
        let mut m = f64::NEG_INFINITY;
        for i in lo..hi {
            m = m.max(f(i));
        }
        // SAFETY: each sub-unit writes only its own partial slot.
        unsafe { *part_ptr.0.add(u) = m };
    });
    partials.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Block-row variant of [`par_fill_abs_max`], for width-`q` coefficient
/// blocks (Multi-Task Lasso, paper §7): for every row `j`, `f(j, slot)`
/// fills the `q`-wide slot `block[j·q .. (j+1)·q]` (e.g. with `x_jᵀR`
/// via [`DesignOps::col_dot_lanes`](crate::data::design::DesignOps::col_dot_lanes))
/// and returns the row's norm, which lands in `rows[j]`; the call
/// returns `max_j |rows[j]|` folded in fixed shard order — deterministic
/// for any thread count, exactly like the scalar fused fill. This is the
/// shape of the block dual rescale (Eq. 4 with `‖x_jᵀR‖₂` in place of
/// `|x_jᵀr|`): the correlation block, the pricing row norms and their
/// max in one sharded pass.
pub fn par_fill_rows_max<F>(
    block: &mut [f64],
    rows: &mut [f64],
    q: usize,
    per_item_cost: usize,
    f: F,
) -> f64
where
    F: Fn(usize, &mut [f64]) -> f64 + Sync,
{
    assert!(q >= 1, "block width q must be >= 1");
    let p = rows.len();
    assert_eq!(block.len(), p * q, "block must be p×q");
    if p == 0 {
        return 0.0;
    }
    if !parallel_shards(p.saturating_mul(per_item_cost.max(1))) {
        let mut m = 0.0f64;
        for j in 0..p {
            let v = f(j, &mut block[j * q..(j + 1) * q]);
            rows[j] = v;
            m = m.max(v.abs());
        }
        return m;
    }
    let mut partials = [0.0f64; SHARDS];
    let block_ptr = SyncPtr(block.as_mut_ptr());
    let rows_ptr = SyncPtr(rows.as_mut_ptr());
    let part_ptr = SyncPtr(partials.as_mut_ptr());
    pool::global().run(SHARDS, &|s| {
        let (lo, hi) = shard_bounds(p, s);
        let mut m = 0.0f64;
        for j in lo..hi {
            // SAFETY: shard row ranges are disjoint, so the q-wide block
            // slots and the rows entries have one writer each.
            let slot = unsafe { std::slice::from_raw_parts_mut(block_ptr.0.add(j * q), q) };
            let v = f(j, slot);
            unsafe { *rows_ptr.0.add(j) = v };
            m = m.max(v.abs());
        }
        // SAFETY: each shard writes only its own partial slot.
        unsafe { *part_ptr.0.add(s) = m };
    });
    partials.iter().fold(0.0f64, |a, &b| a.max(b))
}

/// `max_i f(i)` over `0..n` (−∞ for n = 0); pooled when the work is
/// large, deterministic either way (fixed shard fold).
pub fn par_max_cost<F>(n: usize, per_item_cost: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    if n == 0 {
        return f64::NEG_INFINITY;
    }
    let mut partials = [f64::NEG_INFINITY; SHARDS];
    if parallel_shards(n.saturating_mul(per_item_cost.max(1))) {
        let part_ptr = SyncPtr(partials.as_mut_ptr());
        pool::global().run(SHARDS, &|s| {
            let (lo, hi) = shard_bounds(n, s);
            let mut m = f64::NEG_INFINITY;
            for i in lo..hi {
                m = m.max(f(i));
            }
            // SAFETY: each shard writes only its own partial slot.
            unsafe { *part_ptr.0.add(s) = m };
        });
    } else {
        for (s, slot) in partials.iter_mut().enumerate() {
            let (lo, hi) = shard_bounds(n, s);
            let mut m = f64::NEG_INFINITY;
            for i in lo..hi {
                m = m.max(f(i));
            }
            *slot = m;
        }
    }
    partials.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// `sum_i f(i)` over `0..n`; pooled when the work is large. The sum is
/// always accumulated per fixed shard and folded in shard order, so the
/// result is bit-identical for any thread count (including serial).
pub fn par_sum_cost<F>(n: usize, per_item_cost: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    if n == 0 {
        return 0.0;
    }
    let mut partials = [0.0f64; SHARDS];
    if parallel_shards(n.saturating_mul(per_item_cost.max(1))) {
        let part_ptr = SyncPtr(partials.as_mut_ptr());
        pool::global().run(SHARDS, &|s| {
            let (lo, hi) = shard_bounds(n, s);
            let mut acc = 0.0;
            for i in lo..hi {
                acc += f(i);
            }
            // SAFETY: each shard writes only its own partial slot.
            unsafe { *part_ptr.0.add(s) = acc };
        });
    } else {
        for (s, slot) in partials.iter_mut().enumerate() {
            let (lo, hi) = shard_bounds(n, s);
            let mut acc = 0.0;
            for i in lo..hi {
                acc += f(i);
            }
            *slot = acc;
        }
    }
    partials.iter().sum()
}

/// Allocate a length-`len` vector whose element `i` is `f(i)`, with
/// each fixed shard written — **first-touched** — by the pool worker
/// that owns it. On NUMA machines the OS backs a page on the node of
/// the first writing thread, so the shard a worker later sweeps lives
/// on its own socket (shard-local placement), replacing the
/// allocation-order placement a plain `collect()` gives (every page on
/// the allocating thread's node). The *contents* are `f(0..len)` either
/// way — placement is invisible to arithmetic, so serial and pooled
/// builds are bit-identical for any `CELER_NUM_THREADS` (pinned in
/// `tests/prop_pool.rs`). Below the work cutoff the serial path runs.
pub fn alloc_first_touch<T, F>(len: usize, per_item_cost: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut v: Vec<T> = Vec::with_capacity(len);
    if !parallel_shards(len.saturating_mul(per_item_cost.max(1))) {
        for i in 0..len {
            v.push(f(i));
        }
        return v;
    }
    let ptr = SyncPtr(v.as_mut_ptr());
    pool::global().run(SHARDS, &|s| {
        let (lo, hi) = shard_bounds(len, s);
        for i in lo..hi {
            // SAFETY: shard index ranges are disjoint (one writer per
            // slot) and lie within the reserved capacity.
            unsafe { ptr.0.add(i).write(f(i)) };
        }
    });
    // SAFETY: the shards cover 0..len, so every slot was initialized.
    // (If a shard panicked, the pool re-raises before we get here and
    // the vector drops with len 0 — never exposing uninitialized slots.)
    unsafe { v.set_len(len) };
    v
}

/// `Vec::resize(len, T::default())` with first-touch placement when the
/// vector must reallocate: the grown buffer is rebuilt shard-by-shard on
/// the pool ([`alloc_first_touch`]), preserving the prefix. Same
/// contents as a plain resize in every case; only the page placement of
/// a fresh allocation differs. Lane tiles and residual buffers in the
/// batch engine go through here so their pages land on the sockets that
/// sweep them.
pub fn resize_first_touch<T>(v: &mut Vec<T>, len: usize)
where
    T: Copy + Default + Send + Sync,
{
    if len <= v.capacity() {
        v.resize(len, T::default());
        return;
    }
    let old = std::mem::take(v);
    *v = alloc_first_touch(len, 1, |i| if i < old.len() { old[i] } else { T::default() });
}

/// `out[i] = f(i)` for all i (unit per-item cost).
pub fn par_fill<F>(out: &mut [f64], f: F)
where
    F: Fn(usize) -> f64 + Sync,
{
    par_fill_cost(out, 1, f);
}

/// `max_i f(i)` over `0..n` (unit per-item cost).
pub fn par_max<F>(n: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    par_max_cost(n, 1, f)
}

/// `sum_i f(i)` over `0..n` (unit per-item cost).
pub fn par_sum<F>(n: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    par_sum_cost(n, 1, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_small_and_large() {
        for n in [0usize, 3, 100, SHARDS + 1, PAR_WORK_THRESHOLD + 17] {
            let mut out = vec![0.0; n];
            par_fill(&mut out, |i| (i * 2) as f64);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, (i * 2) as f64);
            }
        }
    }

    #[test]
    fn max_matches_serial() {
        let n = PAR_WORK_THRESHOLD + 1234;
        let f = |i: usize| ((i * 7919) % 104729) as f64;
        let serial = (0..n).map(f).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(par_max(n, f), serial);
        assert_eq!(par_max(0, f), f64::NEG_INFINITY);
        assert_eq!(par_max(5, |i| i as f64), 4.0);
    }

    #[test]
    fn sum_matches_fixed_shard_fold() {
        // The reduction contract: per-shard accumulation in index order,
        // shard partials folded in shard order — for ANY thread count.
        let n = PAR_WORK_THRESHOLD + 55;
        let f = |i: usize| ((i * 2654435761) % 1000) as f64 * 1e-3 + 1.0 / (i + 1) as f64;
        let chunk = n.div_ceil(SHARDS).max(1);
        let mut expect = 0.0;
        for s in 0..SHARDS {
            let (lo, hi) = ((s * chunk).min(n), ((s + 1) * chunk).min(n));
            let mut acc = 0.0;
            for i in lo..hi {
                acc += f(i);
            }
            expect += acc;
        }
        assert_eq!(par_sum(n, f).to_bits(), expect.to_bits(), "bit-exact shard fold");
        assert_eq!(par_sum(0, f), 0.0);
    }

    #[test]
    fn serial_scope_is_bit_identical() {
        let n = PAR_WORK_THRESHOLD + 999;
        let f = |i: usize| 1.0 / (1.0 + i as f64);
        let pooled = par_sum(n, f);
        let serial = run_serial(|| par_sum(n, f));
        assert_eq!(pooled.to_bits(), serial.to_bits());
        assert!(!in_serial_scope());
        run_serial(|| assert!(in_serial_scope()));
    }

    #[test]
    fn fill_abs_max_fuses_fill_and_norm() {
        for n in [0usize, 7, PAR_WORK_THRESHOLD + 3] {
            let mut fused = vec![0.0; n];
            let f = |i: usize| if i % 3 == 0 { -(i as f64) } else { i as f64 * 0.5 };
            let m = par_fill_abs_max(&mut fused, 1, f);
            let mut plain = vec![0.0; n];
            par_fill(&mut plain, f);
            assert_eq!(fused, plain);
            let expect = plain.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
            assert_eq!(m.to_bits(), expect.to_bits());
        }
    }

    #[test]
    fn fill_rows_max_matches_serial_and_scalar() {
        // Block fill (q = 3): serial vs pooled bit-identical, rows hold
        // the per-row norms, and the returned max folds in shard order.
        let q = 3;
        for p in [0usize, 5, 1000] {
            let f = |j: usize, slot: &mut [f64]| {
                for (t, s) in slot.iter_mut().enumerate() {
                    *s = (j as f64 - 2.0) * 0.5 + t as f64;
                }
                slot.iter().map(|v| v * v).sum::<f64>().sqrt()
            };
            let (mut b1, mut r1) = (vec![0.0; p * q], vec![0.0; p]);
            let (mut b2, mut r2) = (vec![0.0; p * q], vec![0.0; p]);
            let m1 = par_fill_rows_max(&mut b1, &mut r1, q, 1, f);
            let m2 = par_fill_rows_max(&mut b2, &mut r2, q, PAR_WORK_THRESHOLD, f);
            assert_eq!(b1, b2, "p={p}");
            assert_eq!(r1, r2);
            assert_eq!(m1.to_bits(), m2.to_bits());
            let serial = run_serial(|| {
                let (mut b, mut r) = (vec![0.0; p * q], vec![0.0; p]);
                let m = par_fill_rows_max(&mut b, &mut r, q, PAR_WORK_THRESHOLD, f);
                (b, r, m)
            });
            assert_eq!(b2, serial.0);
            assert_eq!(r2, serial.1);
            assert_eq!(m2.to_bits(), serial.2.to_bits());
        }
        // q = 1 degenerates to the scalar fused fill's results.
        let p = 64;
        let g = |j: usize| (j as f64) - 30.0;
        let (mut blk, mut rows) = (vec![0.0; p], vec![0.0; p]);
        let m = par_fill_rows_max(&mut blk, &mut rows, 1, 1, |j, slot| {
            slot[0] = g(j);
            slot[0].abs()
        });
        let mut plain = vec![0.0; p];
        let expect = par_fill_abs_max(&mut plain, 1, g);
        assert_eq!(blk, plain);
        assert_eq!(m.to_bits(), expect.to_bits());
    }

    #[test]
    fn work_gating_uses_cost_hint() {
        // Below the work threshold with unit cost, above it with a large
        // per-item cost — both must produce the same (correct) result.
        let n = 4096; // n alone is far below PAR_WORK_THRESHOLD
        let f = |i: usize| (i as f64).sqrt();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        par_fill_cost(&mut a, 1, f);
        par_fill_cost(&mut b, 100_000, f); // n × cost ≥ threshold → pooled
        assert_eq!(a, b);
        assert_eq!(par_sum_cost(n, 1, f).to_bits(), par_sum_cost(n, 100_000, f).to_bits());
        assert_eq!(par_max_cost(n, 1, f), par_max_cost(n, 100_000, f));
    }

    #[test]
    fn first_touch_alloc_matches_plain_collect() {
        for n in [0usize, 9, SHARDS + 3, PAR_WORK_THRESHOLD + 31] {
            let a: Vec<f64> = alloc_first_touch(n, 1, |i| (i as f64).sin());
            let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
            assert_eq!(a, b, "n={n}");
            let serial: Vec<f64> = run_serial(|| alloc_first_touch(n, 1, |i| (i as f64).sin()));
            assert_eq!(a, serial, "pooled vs serial placement, n={n}");
        }
    }

    #[test]
    fn first_touch_resize_has_plain_resize_semantics() {
        let big = PAR_WORK_THRESHOLD + 5;
        let mut a: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut b = a.clone();
        resize_first_touch(&mut a, big);
        b.resize(big, 0.0);
        assert_eq!(a, b, "grow past capacity");
        resize_first_touch(&mut a, 10);
        b.resize(10, 0.0);
        assert_eq!(a, b, "shrink");
        resize_first_touch(&mut a, 40); // within capacity: plain resize
        b.resize(40, 0.0);
        assert_eq!(a, b);
    }

    #[test]
    fn threads_positive() {
        assert!(num_threads() >= 1);
    }
}
